//! Fixture: an unallowlisted `unwrap()` in non-test `net/` code —
//! must trigger `panic-discipline` and nothing else.

pub fn header_word(frame: &[u8]) -> u64 {
    u64::from_le_bytes(frame.get(..8).map(|s| s.try_into().ok()).flatten().unwrap())
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_exempt() {
        let v: Option<u32> = Some(3);
        assert_eq!(v.unwrap(), 3);
    }
}
