//! Fixture: `encoded_len`'s GradientChunk arm forgets the u32
//! word-count prefix (15 B vs the builder's 19 B header) — must
//! trigger `frame-encode-rule` and nothing else.

const T_MASKED_CHUNK: u8 = 22;
const T_GRADIENT_CHUNK: u8 = 23;

pub fn begin_masked_chunk(
    w: &mut Writer,
    round: u32,
    from: u16,
    tag: u8,
    shard: u16,
    offset: u32,
    total: u32,
    count: u32,
) {
    w.u8(T_MASKED_CHUNK);
    w.u32(round);
    w.u16(from);
    w.u8(tag);
    w.u16(shard);
    w.u32(offset);
    w.u32(total);
    w.u32(count);
}

pub fn begin_gradient_chunk(
    w: &mut Writer,
    round: u32,
    shard: u16,
    offset: u32,
    total: u32,
    count: u32,
) {
    w.u8(T_GRADIENT_CHUNK);
    w.u32(round);
    w.u16(shard);
    w.u32(offset);
    w.u32(total);
    w.u32(count);
}

impl Msg {
    pub fn encoded_len(&self) -> usize {
        match self {
            Msg::MaskedChunk { words, .. } => 1 + 4 + 2 + 1 + 2 + 4 + 4 + 4 + 8 * words.len(),
            Msg::GradientChunk { words, .. } => 1 + 4 + 2 + 4 + 4 + 8 * words.len(),
        }
    }

    pub fn encode_into(&self, w: &mut Writer) {
        match self {
            Msg::MaskedChunk { round, from, tag, shard, offset, total, words } => {
                w.u8(T_MASKED_CHUNK);
                w.u32(*round);
                w.u16(*from);
                w.u8(*tag);
                w.u16(*shard);
                w.u32(*offset);
                w.u32(*total);
                w.u64s(words);
            }
            Msg::GradientChunk { round, shard, offset, total, words } => {
                w.u8(T_GRADIENT_CHUNK);
                w.u32(*round);
                w.u16(*shard);
                w.u32(*offset);
                w.u32(*total);
                w.u64s(words);
            }
        }
    }

    pub fn decode(r: &mut Reader) -> Option<Msg> {
        match r.u8() {
            T_MASKED_CHUNK => None,
            T_GRADIENT_CHUNK => None,
            _ => None,
        }
    }
}
