//! Fixture: violates nothing — the self-test's zero-findings control.

pub fn wrap_sum(words: &[u64]) -> u64 {
    words.iter().fold(0u64, |a, &w| a.wrapping_add(w))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_wraps() {
        assert_eq!(wrap_sum(&[u64::MAX, 1]), 0);
    }
}
