//! Fixture: a `VFL_*` env var that is not declared in the registry —
//! must trigger `env-registry` and nothing else.

pub fn knob() -> bool {
    std::env::var("VFL_UNREGISTERED_KNOB").is_ok()
}
