//! Fixture: an unallowlisted unbounded `mpsc::channel()` in non-test
//! code — must trigger `bounded-channels` and nothing else.

pub fn spawn_pipeline() {
    let (tx, rx) = std::sync::mpsc::channel();
    tx.send(1u64).ok();
    drop(rx);
}
