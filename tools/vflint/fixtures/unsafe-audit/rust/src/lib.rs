//! Fixture: an unsafe site with no SAFETY comment and no inventory
//! entry — must trigger `unsafe-audit` (twice) and nothing else.

pub fn first_word(v: &[u64]) -> u64 {
    unsafe { *v.as_ptr() }
}
