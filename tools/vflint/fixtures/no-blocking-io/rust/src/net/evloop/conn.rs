//! Fixture: a blocking write inside `net/evloop/` non-test code —
//! must trigger `no-blocking-io` and nothing else.

use std::io::Write;
use std::net::TcpStream;

pub fn send_frame(sock: &mut TcpStream, frame: &[u8]) -> std::io::Result<()> {
    sock.write_all(frame)
}
