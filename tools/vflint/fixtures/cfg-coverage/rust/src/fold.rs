//! Fixture: a `#[target_feature]` intrinsic fn with no
//! `// vflint: scalar-ref = <fn>` annotation — must trigger
//! `cfg-coverage` and nothing else (the unsafe site itself is
//! SAFETY-commented and inventoried).

pub fn fold(dst: &mut [u64]) {
    for v in dst.iter_mut() {
        *v = v.wrapping_add(1);
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    /// # Safety
    /// SAFETY: caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn fold(dst: &mut [u64]) {
        for v in dst.iter_mut() {
            *v = v.wrapping_add(1);
        }
    }
}
