#!/usr/bin/env python3
"""vflint — toolchain-free invariant analyzer for the vfl secure-aggregation stack.

Every safety property this reproduction rests on (exact pairwise-mask
cancellation, the frame-encode rule, the evloop no-blocking-write
invariant, the bit-invisibility contract of the thread families) lives
in doc comments and builder discipline.  No Rust toolchain has ever
been present in the authoring containers, so nothing machine-checks
them.  This analyzer does: it is hand-rolled, stdlib-only Python 3
(no rustc, no pip), parses ``rust/src/**``, ``rust/tests/**``,
``rust/benches/**`` and ``.github/workflows/ci.yml`` with a small
brace/comment/string-aware line scanner, and enforces seven named
checks, each with a per-check allowlist:

  unsafe-audit       every `unsafe` site carries a SAFETY justification
                     (``// SAFETY:`` comment or ``# Safety`` doc
                     section) AND appears in the reviewed
                     ``unsafe_inventory.txt``; stale inventory entries
                     fail too.
  no-blocking-io     ``write_all`` / ``read_exact`` /
                     ``set_nonblocking(false)`` are forbidden in
                     non-test ``net/evloop/`` code — the event loop
                     must never block on a socket.
  bounded-channels   unbounded ``mpsc::channel()`` is forbidden in
                     non-test ``rust/src`` code (``sync_channel`` only);
                     deliberately-unbounded funnels (the ``LoopEvt``
                     event channel) must be allowlisted with a
                     justification.
  env-registry       every ``VFL_*`` literal in the Rust tree must be
                     declared in ``env_registry.txt``; every ``ci``-tier
                     entry must be exercised by ``ci.yml``; drift in any
                     direction fails (unknown var, stale entry,
                     unregistered var in CI).
  frame-encode-rule  the message tag constants and the 22/19-byte chunk
                     and 14-byte partial-sum header widths are
                     cross-checked between ``Msg::encode_into``,
                     ``Msg::encoded_len``, the ``begin_*`` zero-copy
                     builders, ``decode``, and the Table-2 accounting
                     constants in ``coordinator/streaming.rs`` — the
                     zero-copy path cannot silently diverge from
                     ``Msg::encode()``.
  panic-discipline   ``unwrap()`` / ``expect(`` are forbidden in
                     non-test ``net/``, ``coordinator/``, ``secagg/``
                     code except allowlisted sites with a stated reason.
  cfg-coverage       every ``#[target_feature]`` intrinsic fn must name
                     its scalar reference implementation
                     (``// vflint: scalar-ref = <fn>`` — defined in the
                     same file outside arch-gated code) and both must be
                     referenced by a ``#[cfg(test)]`` bit-identity test
                     in the same file.

Exit status: 0 when every check is clean (allowlisted findings are
reported as suppressed counts only), 1 when any unallowlisted finding
or stale allowlist/inventory/registry entry remains, 2 on usage error.

``--self-test`` runs the analyzer over the fixture corpus in
``fixtures/`` (each fixture tree violates exactly one check) and exits
non-zero unless every fixture triggers exactly its intended check and
the ``clean`` tree triggers none.
"""

from __future__ import annotations

import argparse
import os
import re
import sys
from dataclasses import dataclass, field

TOOL_DIR = os.path.dirname(os.path.abspath(__file__))
DEFAULT_ROOT = os.path.dirname(os.path.dirname(TOOL_DIR))

ALLOWLIST = "allowlist.txt"
INVENTORY = "unsafe_inventory.txt"
ENV_REGISTRY = "env_registry.txt"
CI_YML = os.path.join(".github", "workflows", "ci.yml")

CHECKS = [
    "unsafe-audit",
    "no-blocking-io",
    "bounded-channels",
    "env-registry",
    "frame-encode-rule",
    "panic-discipline",
    "cfg-coverage",
]

# ---------------------------------------------------------------------------
# Rust source scanner: comment/string stripping + test/arch span detection
# ---------------------------------------------------------------------------


def strip_rust(text):
    """Return (code, code_str) line lists aligned with the input lines.

    ``code``     — comments stripped AND string/char literal contents
                   blanked (delimiters kept), for keyword/structure
                   matching without literal false-positives.
    ``code_str`` — comments stripped, string contents kept, for
                   scanning literals such as env-var names.
    Handles line comments, nested block comments, string escapes, raw
    strings (``r#"..."#``), byte strings, and char literals (vs
    lifetimes).  Newlines are preserved so line numbers stay aligned.
    """
    code = []
    code_str = []
    i = 0
    n = len(text)
    NORMAL, LINE_COMMENT, BLOCK_COMMENT, STRING, RAW_STRING, CHAR = range(6)
    state = NORMAL
    depth = 0  # nested block comments
    raw_hashes = 0
    out_c = []  # current code line
    out_s = []  # current code_str line
    while i < n:
        ch = text[i]
        if ch == "\n":
            code.append("".join(out_c))
            code_str.append("".join(out_s))
            out_c, out_s = [], []
            if state == LINE_COMMENT:
                state = NORMAL
            i += 1
            continue
        if state == NORMAL:
            two = text[i : i + 2]
            if two == "//":
                state = LINE_COMMENT
                i += 2
                continue
            if two == "/*":
                state = BLOCK_COMMENT
                depth = 1
                i += 2
                continue
            if ch == '"':
                out_c.append('"')
                out_s.append('"')
                state = STRING
                i += 1
                continue
            # raw / byte string openers: r", r#", br", b"
            m = re.match(r'(?:b?r)(#*)"', text[i:])
            if m and ch in "rb":
                # make sure this is not part of an identifier (e.g. `var"`)
                if i == 0 or not (text[i - 1].isalnum() or text[i - 1] == "_"):
                    raw_hashes = len(m.group(1))
                    out_c.append(text[i : i + m.end()])
                    out_s.append(text[i : i + m.end()])
                    i += m.end()
                    state = RAW_STRING
                    continue
            if ch == "b" and text[i : i + 2] == 'b"':
                out_c.append('b"')
                out_s.append('b"')
                state = STRING
                i += 2
                continue
            if ch == "'":
                # char literal iff it closes within a couple chars;
                # otherwise it is a lifetime tick
                m = re.match(r"'(\\.[^']*|[^'\\])'", text[i:])
                if m:
                    out_c.append("' '" if len(m.group(0)) > 2 else "''")
                    out_s.append(text[i : i + m.end()])
                    i += m.end()
                    continue
                out_c.append("'")
                out_s.append("'")
                i += 1
                continue
            out_c.append(ch)
            out_s.append(ch)
            i += 1
            continue
        if state == LINE_COMMENT:
            i += 1
            continue
        if state == BLOCK_COMMENT:
            two = text[i : i + 2]
            if two == "/*":
                depth += 1
                i += 2
                continue
            if two == "*/":
                depth -= 1
                i += 2
                if depth == 0:
                    state = NORMAL
                continue
            i += 1
            continue
        if state == STRING:
            if ch == "\\":
                # `\` + newline is a string continuation: keep the line
                # break so numbering stays aligned
                if text[i + 1 : i + 2] == "\n":
                    code.append("".join(out_c))
                    code_str.append("".join(out_s))
                    out_c, out_s = [], []
                else:
                    out_s.append(text[i : i + 2])
                i += 2
                continue
            if ch == '"':
                out_c.append('"')
                out_s.append('"')
                state = NORMAL
                i += 1
                continue
            out_s.append(ch)
            i += 1
            continue
        if state == RAW_STRING:
            closer = '"' + "#" * raw_hashes
            if text[i : i + len(closer)] == closer:
                out_c.append(closer)
                out_s.append(closer)
                i += len(closer)
                state = NORMAL
                continue
            out_s.append(ch)
            i += 1
            continue
        if state == CHAR:  # pragma: no cover — folded into NORMAL above
            i += 1
    code.append("".join(out_c))
    code_str.append("".join(out_s))
    return code, code_str


def item_span(code, start):
    """Brace span (start_line, end_line) of the item whose header begins
    at 0-based line ``start``: scans forward to the first ``{`` then to
    its matching close.  Returns (start, start) for brace-less items
    (``;``-terminated) so callers can treat them as one-liners."""
    i = start
    depth = 0
    opened = False
    while i < len(code):
        line = code[i]
        if not opened and ";" in line.split("{")[0] and "{" not in line:
            return (start, i)
        for ch in line:
            if ch == "{":
                depth += 1
                opened = True
            elif ch == "}":
                depth -= 1
                if opened and depth == 0:
                    return (start, i)
        i += 1
    return (start, len(code) - 1)


ATTR_RE = re.compile(r"\s*#!?\[")
COMMENT_RE = re.compile(r"\s*(//|/\*|\*)")


@dataclass
class SourceFile:
    path: str  # repo-relative, forward slashes
    raw: list
    code: list
    code_str: list
    test_spans: list = field(default_factory=list)
    arch_spans: list = field(default_factory=list)

    @classmethod
    def load(cls, root, relpath):
        with open(os.path.join(root, relpath), encoding="utf-8") as f:
            text = f.read()
        raw = text.split("\n")
        code, code_str = strip_rust(text)
        assert len(code) == len(raw), f"scanner lost line alignment in {relpath}"
        sf = cls(relpath.replace(os.sep, "/"), raw, code, code_str)
        for i, line in enumerate(code):
            if re.search(r"#\[cfg\(test\)\]|#\[cfg\(all\([^)]*\btest\b", line):
                sf.test_spans.append(item_span(code, i))
            if re.search(r"#\[cfg\([^)]*target_arch", line) or re.search(
                r"#\[cfg\(all\([^)]*target_arch", line
            ):
                sf.arch_spans.append(item_span(code, i))
        return sf

    def in_test(self, lineno0):
        return any(a <= lineno0 <= b for a, b in self.test_spans)

    def in_arch_gate(self, lineno0):
        return any(a <= lineno0 <= b for a, b in self.arch_spans)

    def comment_block_above(self, lineno0):
        """The contiguous comment/attribute lines directly above
        ``lineno0`` (raw text, in order).  Attributes are transparent so
        ``#[target_feature]`` between a doc comment and its fn does not
        break the block."""
        block = []
        i = lineno0 - 1
        while i >= 0:
            stripped = self.raw[i].strip()
            if COMMENT_RE.match(self.raw[i]) or ATTR_RE.match(self.raw[i]):
                block.append(stripped)
                i -= 1
                continue
            break
        block.reverse()
        return block


@dataclass
class Finding:
    check: str
    path: str
    line: int  # 1-based
    message: str
    raw_line: str = ""

    def fmt(self):
        return f"{self.path}:{self.line}: {self.message}"


# ---------------------------------------------------------------------------
# Config files: allowlist, unsafe inventory, env registry
# ---------------------------------------------------------------------------


@dataclass
class ConfigEntry:
    lineno: int
    fields: tuple
    justification: str
    used: int = 0


def load_config(path, n_fields):
    """Parse ``field1: field2[: field3] # justification`` lines.
    Returns (entries, errors).  Missing file => ([], [])."""
    entries, errors = [], []
    if not os.path.exists(path):
        return entries, errors
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            line = line.rstrip("\n")
            if not line.strip() or line.lstrip().startswith("#"):
                continue
            body, sep, just = line.partition(" # ")
            if not sep:
                errors.append((lineno, "entry has no ` # justification` clause"))
                continue
            if not just.strip():
                errors.append((lineno, "empty justification"))
                continue
            parts = [p.strip() for p in body.split(":", n_fields - 1)]
            if len(parts) != n_fields or not all(parts):
                errors.append((lineno, f"expected {n_fields} `:`-separated fields"))
                continue
            entries.append(ConfigEntry(lineno, tuple(parts), just.strip()))
    return entries, errors


class Allowlist:
    """``check: path: substring # justification`` — suppresses findings
    of ``check`` in ``path`` whose raw line contains ``substring``."""

    def __init__(self, root):
        self.path = os.path.join(root, "tools", "vflint", ALLOWLIST)
        self.entries, self.errors = load_config(self.path, 3)

    def suppress(self, finding):
        for e in self.entries:
            check, path, substr = e.fields
            if check == finding.check and path == finding.path and substr in finding.raw_line:
                e.used += 1
                return True
        return False

    def stale(self):
        out = []
        for ln, msg in self.errors:
            out.append(Finding("allowlist", f"tools/vflint/{ALLOWLIST}", ln, f"malformed entry: {msg}"))
        for e in self.entries:
            if e.fields[0] not in CHECKS:
                out.append(
                    Finding(
                        "allowlist",
                        f"tools/vflint/{ALLOWLIST}",
                        e.lineno,
                        f"unknown check {e.fields[0]!r}",
                    )
                )
            elif e.used == 0:
                out.append(
                    Finding(
                        "allowlist",
                        f"tools/vflint/{ALLOWLIST}",
                        e.lineno,
                        f"stale entry (matches nothing): {': '.join(e.fields)}",
                    )
                )
        return out


# ---------------------------------------------------------------------------
# Check 1: unsafe-audit
# ---------------------------------------------------------------------------

UNSAFE_RE = re.compile(r"\bunsafe\b")
SAFETY_RE = re.compile(r"SAFETY[:\s]|#\s*Safety")


def check_unsafe_audit(files, root):
    findings = []
    inv_path = os.path.join(root, "tools", "vflint", INVENTORY)
    entries, errors = load_config(inv_path, 2)
    for ln, msg in errors:
        findings.append(Finding("unsafe-audit", f"tools/vflint/{INVENTORY}", ln, f"malformed entry: {msg}"))
    for sf in files:
        for i, line in enumerate(sf.code):
            if not UNSAFE_RE.search(line):
                continue
            raw = sf.raw[i]
            # SAFETY justification: on the same line, or anywhere in the
            # contiguous comment/attr block directly above.
            covered = bool(SAFETY_RE.search(raw))
            if not covered:
                covered = any(SAFETY_RE.search(c) for c in sf.comment_block_above(i))
            if not covered:
                findings.append(
                    Finding(
                        "unsafe-audit",
                        sf.path,
                        i + 1,
                        "unsafe site without a `// SAFETY:` comment or `# Safety` doc section",
                        raw,
                    )
                )
            matched = False
            for e in entries:
                path, substr = e.fields
                if path == sf.path and substr in raw:
                    e.used += 1
                    matched = True
            if not matched:
                findings.append(
                    Finding(
                        "unsafe-audit",
                        sf.path,
                        i + 1,
                        f"unsafe site not in the reviewed inventory (tools/vflint/{INVENTORY})",
                        raw,
                    )
                )
    for e in entries:
        if e.used == 0:
            findings.append(
                Finding(
                    "unsafe-audit",
                    f"tools/vflint/{INVENTORY}",
                    e.lineno,
                    f"stale inventory entry (matches no unsafe site): {': '.join(e.fields)}",
                )
            )
    return findings


# ---------------------------------------------------------------------------
# Check 2: no-blocking-io
# ---------------------------------------------------------------------------

BLOCKING_RE = re.compile(r"\.write_all\s*\(|\.read_exact\s*\(|set_nonblocking\s*\(\s*false")


def check_no_blocking_io(files, root):
    findings = []
    for sf in files:
        if "/net/evloop/" not in "/" + sf.path:
            continue
        for i, line in enumerate(sf.code):
            if sf.in_test(i):
                continue
            m = BLOCKING_RE.search(line)
            if m:
                findings.append(
                    Finding(
                        "no-blocking-io",
                        sf.path,
                        i + 1,
                        f"blocking socket call `{m.group(0).strip('(. ')}` inside the event loop "
                        "(poller threads must never block on a socket)",
                        sf.raw[i],
                    )
                )
    return findings


# ---------------------------------------------------------------------------
# Check 3: bounded-channels
# ---------------------------------------------------------------------------

CHANNEL_RE = re.compile(r"(?<![A-Za-z0-9_])channel\s*(?:::<[^>()]*>)?\s*\(\s*\)")


def check_bounded_channels(files, root):
    findings = []
    for sf in files:
        if not sf.path.startswith("rust/src/"):
            continue
        for i, line in enumerate(sf.code):
            if sf.in_test(i):
                continue
            for m in CHANNEL_RE.finditer(line):
                if line[: m.start()].endswith("sync_"):
                    continue
                findings.append(
                    Finding(
                        "bounded-channels",
                        sf.path,
                        i + 1,
                        "unbounded `mpsc::channel()` on a hot path — use `sync_channel` "
                        "(bounded, backpressure) or allowlist with a justification",
                        sf.raw[i],
                    )
                )
    return findings


# ---------------------------------------------------------------------------
# Check 4: env-registry
# ---------------------------------------------------------------------------

ENV_RE = re.compile(r"\bVFL_[A-Z0-9_]+\b")
ENV_TIERS = ("ci", "bench")


def check_env_registry(files, root):
    findings = []
    reg_path = os.path.join(root, "tools", "vflint", ENV_REGISTRY)
    entries, errors = load_config(reg_path, 2)
    for ln, msg in errors:
        findings.append(Finding("env-registry", f"tools/vflint/{ENV_REGISTRY}", ln, f"malformed entry: {msg}"))
    reg = {}
    for e in entries:
        name, tier = e.fields
        if tier not in ENV_TIERS:
            findings.append(
                Finding(
                    "env-registry",
                    f"tools/vflint/{ENV_REGISTRY}",
                    e.lineno,
                    f"unknown tier {tier!r} for {name} (want one of {ENV_TIERS})",
                )
            )
            continue
        reg[name] = e
    # occurrences in the Rust tree (comment-stripped, strings kept:
    # env-var names live inside string literals)
    seen = {}
    for sf in files:
        for i, line in enumerate(sf.code_str):
            for m in ENV_RE.finditer(line):
                seen.setdefault(m.group(0), (sf.path, i + 1))
    for name, (path, line) in sorted(seen.items()):
        if name not in reg:
            findings.append(
                Finding(
                    "env-registry",
                    path,
                    line,
                    f"env var {name} not declared in tools/vflint/{ENV_REGISTRY}",
                )
            )
    for name, e in sorted(reg.items()):
        if name not in seen:
            findings.append(
                Finding(
                    "env-registry",
                    f"tools/vflint/{ENV_REGISTRY}",
                    e.lineno,
                    f"stale registry entry: {name} appears nowhere in the Rust tree",
                )
            )
    # CI cross-check
    ci_path = os.path.join(root, CI_YML)
    ci_vars = {}
    if os.path.exists(ci_path):
        with open(ci_path, encoding="utf-8") as f:
            for lineno, line in enumerate(f, 1):
                for m in ENV_RE.finditer(line):
                    ci_vars.setdefault(m.group(0), lineno)
        for name, e in sorted(reg.items()):
            if e.fields[1] == "ci" and name not in ci_vars:
                findings.append(
                    Finding(
                        "env-registry",
                        f"tools/vflint/{ENV_REGISTRY}",
                        e.lineno,
                        f"{name} is registered as a CI axis but never appears in {CI_YML}",
                    )
                )
        for name, lineno in sorted(ci_vars.items()):
            if name not in reg or reg[name].fields[1] != "ci":
                findings.append(
                    Finding(
                        "env-registry",
                        CI_YML,
                        lineno,
                        f"{name} is exercised by CI but not registered as tier `ci` "
                        f"in tools/vflint/{ENV_REGISTRY}",
                    )
                )
    return findings


# ---------------------------------------------------------------------------
# Check 5: frame-encode-rule
# ---------------------------------------------------------------------------

WIDTHS = {"u8": 1, "u16": 2, "u32": 4, "u64": 8, "f32": 4}
OP_RE = re.compile(r"\bw\.(u8|u16|u32|u64|f32s|f32|bytes|fixed|u64s_raw|u64s)\s*\(\s*([A-Za-z0-9_*.]*)")


def fn_span(sf, name):
    for i, line in enumerate(sf.code):
        if re.search(rf"\bfn\s+{name}\b", line):
            return item_span(sf.code, i)
    return None


def writer_ops(sf, span):
    """Ordered (op, first_arg) writer calls within ``span``."""
    ops = []
    for i in range(span[0], span[1] + 1):
        for m in OP_RE.finditer(sf.code[i]):
            ops.append((m.group(1), m.group(2), i + 1))
    return ops


def match_arm_expr(sf, span, variant):
    """The expression text of a one-line-expression match arm
    ``Msg::Variant { .. } => <expr>,`` within ``span`` (used on
    ``encoded_len``)."""
    text = None
    for i in range(span[0], span[1] + 1):
        if re.search(rf"Msg::{variant}\b", sf.code[i]):
            # accumulate until the arm ends (balanced braces, trailing ,)
            j = i
            buf = []
            depth = 0
            while j <= span[1]:
                seg = sf.code[j]
                buf.append(seg)
                depth += seg.count("{") - seg.count("}")
                if j > i or "=>" in seg:
                    if depth <= 0 and seg.rstrip().endswith(","):
                        break
                j += 1
            text = " ".join(buf)
            break
    if text is None:
        return None
    _, _, expr = text.partition("=>")
    return expr


def arm_span(sf, fn, variant):
    """Line span of the ``Msg::Variant { ... } => { ... }`` arm inside
    fn ``fn``.  Brace counting starts after the ``=>`` so the
    destructuring pattern's own braces don't close the span early."""
    fspan = fn_span(sf, fn)
    if fspan is None:
        return None
    for i in range(fspan[0], fspan[1] + 1):
        if not re.search(rf"Msg::{variant}\b", sf.code[i]):
            continue
        # find the line carrying the `=>` (patterns here are one-line,
        # but tolerate a wrapped pattern)
        j = i
        while j <= fspan[1] and "=>" not in sf.code[j]:
            j += 1
        if j > fspan[1]:
            return (i, i)
        col = sf.code[j].index("=>") + 2
        depth = 0
        opened = False
        k = j
        while k <= fspan[1]:
            seg = sf.code[k][col:] if k == j else sf.code[k]
            for ch in seg:
                if ch == "{":
                    depth += 1
                    opened = True
                elif ch == "}":
                    depth -= 1
                    if opened and depth == 0:
                        return (i, k)
            if k == j and not opened and seg.strip():
                return (i, j)  # one-line expression arm
            k += 1
        return (i, fspan[1])
    return None


def const_sum(expr):
    """Sum of the constant terms of a ``a + b + c * d.len()`` size
    expression; dynamic terms (containing ``*`` or an identifier) are
    skipped."""
    if expr is None:
        return None
    total = 0
    # strip one level of braces/parens wrapping
    expr = expr.strip().rstrip(",").strip()
    while expr.startswith("{") and expr.endswith("}"):
        expr = expr[1:-1].strip()
    for term in expr.split("+"):
        term = term.strip()
        if re.fullmatch(r"\d+", term):
            total += int(term)
    return total


def check_frame_encode(files, root):
    findings = []
    msgs = next((sf for sf in files if sf.path.endswith("coordinator/messages.rs")), None)
    if msgs is None:
        return findings  # fixture trees without a wire layer: nothing to check

    def fail(line, message):
        findings.append(Finding("frame-encode-rule", msgs.path, line, message))

    # 1. tag constants: unique values, each used by encode_into AND decode
    tags = {}
    for i, line in enumerate(msgs.code):
        m = re.search(r"const\s+(T_[A-Z0-9_]+)\s*:\s*u8\s*=\s*(\d+)\s*;", line)
        if m:
            name, val = m.group(1), int(m.group(2))
            for other, (oval, _) in tags.items():
                if oval == val:
                    fail(i + 1, f"duplicate message tag value {val}: {name} collides with {other}")
            tags[name] = (val, i + 1)
    enc_span = fn_span(msgs, "encode_into")
    dec_span = fn_span(msgs, "decode")
    for name, (_, lineno) in sorted(tags.items(), key=lambda kv: kv[1][1]):
        for span, what in ((enc_span, "encode_into"), (dec_span, "decode")):
            if span is None:
                continue
            body = "\n".join(msgs.code[span[0] : span[1] + 1])
            if not re.search(rf"\b{name}\b", body):
                fail(lineno, f"tag constant {name} never used in `{what}` — dead or drifted arm")

    # 2. chunk builders vs encode arms vs encoded_len vs streaming constants
    streaming = next((sf for sf in files if sf.path.endswith("coordinator/streaming.rs")), None)
    stream_consts = {}
    if streaming is not None:
        for i, line in enumerate(streaming.code):
            m = re.search(r"const\s+([A-Z0-9_]+)\s*:\s*u64\s*=\s*(\d+)\s*;", line)
            if m:
                stream_consts[m.group(1)] = (int(m.group(2)), i + 1)

    specs = [
        ("begin_masked_chunk", "MaskedChunk", "T_MASKED_CHUNK", "CHUNK_MSG_HEADER_BYTES"),
        ("begin_gradient_chunk", "GradientChunk", "T_GRADIENT_CHUNK", "GRAD_CHUNK_MSG_HEADER_BYTES"),
        ("begin_partial_sum", "PartialSum", "T_PARTIAL_SUM", "PARTIAL_SUM_HEADER_BYTES"),
    ]
    for builder, variant, tag_const, stream_const in specs:
        bspan = fn_span(msgs, builder)
        if bspan is None:
            fail(1, f"zero-copy builder `{builder}` not found")
            continue
        bops = writer_ops(msgs, bspan)
        if not bops:
            fail(bspan[0] + 1, f"`{builder}` writes nothing")
            continue
        # builder must open with the variant tag byte
        if bops[0][0] != "u8" or bops[0][1] != tag_const:
            fail(bops[0][2], f"`{builder}` must start with `w.u8({tag_const})`, got `w.{bops[0][0]}({bops[0][1]})`")
        # builder header width = sum of fixed-width ops
        widths = [WIDTHS.get(op) for op, _, _ in bops]
        if None in widths:
            bad = bops[widths.index(None)]
            fail(bad[2], f"`{builder}` uses non-fixed-width writer op `w.{bad[0]}` — header width unverifiable")
            continue
        header = sum(widths)
        # builder must end with the u32 word-count prefix (the `u64s`
        # encoding = u32 count + raw words)
        if bops[-1][0] != "u32":
            fail(bops[-1][2], f"`{builder}` must end with the u32 word-count prefix, got `w.{bops[-1][0]}`")
        # encode_into arm: same ops with the trailing count+words fused
        # into one `w.u64s(words)`
        aspan = arm_span(msgs, "encode_into", variant)
        if aspan is None:
            fail(bspan[0] + 1, f"no `encode_into` arm found for Msg::{variant}")
        else:
            aops = writer_ops(msgs, aspan)

            def norm(arg):
                # `*round` / `self.round` / `round` all name the field
                return arg.lstrip("*").split(".")[-1]

            want = [(op, norm(arg)) for op, arg, _ in bops[:-1]] + [("u64s", "words")]
            got = [(op, norm(arg)) for op, arg, _ in aops]
            if got != want:
                fail(
                    aspan[0] + 1,
                    f"encode_into arm for Msg::{variant} diverges from `{builder}`: "
                    f"builder implies {want}, arm writes {got} — the zero-copy path "
                    "would not be byte-identical to Msg::encode()",
                )
            if aops and (aops[0][0] != "u8" or aops[0][1] != tag_const):
                fail(aops[0][2], f"encode_into arm for Msg::{variant} does not open with `w.u8({tag_const})`")
        # encoded_len arm constant part must equal the builder header
        lspan = fn_span(msgs, "encoded_len")
        lsum = const_sum(match_arm_expr(msgs, lspan, variant)) if lspan else None
        if lsum is None:
            fail(1, f"no `encoded_len` arm found for Msg::{variant}")
        elif lsum != header:
            fail(
                lspan[0] + 1,
                f"encoded_len constant part for Msg::{variant} is {lsum} B "
                f"but `{builder}` writes a {header}-byte header",
            )
        # Table-2 accounting constant must match
        if streaming is not None:
            if stream_const not in stream_consts:
                fail(1, f"streaming.rs does not define {stream_const}")
            elif stream_consts[stream_const][0] != header:
                findings.append(
                    Finding(
                        "frame-encode-rule",
                        streaming.path,
                        stream_consts[stream_const][1],
                        f"{stream_const} = {stream_consts[stream_const][0]} but the wire header "
                        f"written by `{builder}` is {header} B",
                    )
                )

    # 3. monolithic accounting constants vs encoded_len
    mono_specs = [
        ("MaskedActivation", "MONO_MSG_HEADER_BYTES"),
        ("GradientSum", "GRAD_SUM_HEADER_BYTES"),
    ]
    lspan = fn_span(msgs, "encoded_len")
    if streaming is not None and lspan is not None:
        for variant, stream_const in mono_specs:
            if stream_const not in stream_consts:
                continue
            lsum = const_sum(match_arm_expr(msgs, lspan, variant))
            if lsum is not None and lsum != stream_consts[stream_const][0]:
                findings.append(
                    Finding(
                        "frame-encode-rule",
                        streaming.path,
                        stream_consts[stream_const][1],
                        f"{stream_const} = {stream_consts[stream_const][0]} but Msg::{variant}'s "
                        f"encoded_len constant part is {lsum} B",
                    )
                )
    return findings


# ---------------------------------------------------------------------------
# Check 6: panic-discipline
# ---------------------------------------------------------------------------

PANIC_RE = re.compile(r"\.unwrap\s*\(\s*\)|\.expect\s*\(")
PANIC_DIRS = ("rust/src/net/", "rust/src/coordinator/", "rust/src/secagg/")


def check_panic_discipline(files, root):
    findings = []
    for sf in files:
        if not sf.path.startswith(PANIC_DIRS):
            continue
        for i, line in enumerate(sf.code):
            if sf.in_test(i):
                continue
            m = PANIC_RE.search(line)
            if m:
                findings.append(
                    Finding(
                        "panic-discipline",
                        sf.path,
                        i + 1,
                        f"`{m.group(0).strip('(. ')}` in protocol-path code — convert to a typed "
                        "error or allowlist with a stated reason",
                        sf.raw[i],
                    )
                )
    return findings


# ---------------------------------------------------------------------------
# Check 7: cfg-coverage
# ---------------------------------------------------------------------------

SCALAR_REF_RE = re.compile(r"vflint:\s*scalar-ref\s*=\s*([A-Za-z0-9_]+)")


def check_cfg_coverage(files, root):
    findings = []
    for sf in files:
        if not sf.path.startswith("rust/src/"):
            continue
        for i, line in enumerate(sf.code):
            if "#[target_feature" not in line:
                continue
            # the fn header follows the attribute block
            j = i + 1
            name = None
            while j < len(sf.code) and j < i + 5:
                m = re.search(r"\bfn\s+([A-Za-z0-9_]+)", sf.code[j])
                if m:
                    name = m.group(1)
                    break
                j += 1
            if name is None:
                continue
            lineno = j + 1
            block = sf.comment_block_above(j)
            refm = None
            for c in block:
                refm = SCALAR_REF_RE.search(c) or refm
            if refm is None:
                findings.append(
                    Finding(
                        "cfg-coverage",
                        sf.path,
                        lineno,
                        f"intrinsic fn `{name}` has no `// vflint: scalar-ref = <fn>` annotation "
                        "naming its scalar reference implementation",
                    )
                )
                continue
            ref = refm.group(1)
            # the scalar reference must exist in this file OUTSIDE any
            # arch-gated region (it is the portable truth the vector leg
            # is asserted against)
            ref_def = None
            for k, l2 in enumerate(sf.code):
                if re.search(rf"\bfn\s+{ref}\b", l2) and not sf.in_arch_gate(k):
                    ref_def = k
                    break
            if ref_def is None:
                findings.append(
                    Finding(
                        "cfg-coverage",
                        sf.path,
                        lineno,
                        f"scalar reference `{ref}` for `{name}` is not defined outside "
                        "arch-gated code in this file",
                    )
                )
            # both the intrinsic and its reference must be exercised by a
            # bit-identity test in the same file
            test_code = "\n".join(
                "\n".join(sf.code[a : b + 1]) for a, b in sf.test_spans
            )
            for fn in {name, ref}:
                if not re.search(rf"\b{fn}\b", test_code):
                    findings.append(
                        Finding(
                            "cfg-coverage",
                            sf.path,
                            lineno,
                            f"no `#[cfg(test)]` bit-identity test in this file references `{fn}`",
                        )
                    )
    return findings


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

CHECK_FNS = {
    "unsafe-audit": check_unsafe_audit,
    "no-blocking-io": check_no_blocking_io,
    "bounded-channels": check_bounded_channels,
    "env-registry": check_env_registry,
    "frame-encode-rule": check_frame_encode,
    "panic-discipline": check_panic_discipline,
    "cfg-coverage": check_cfg_coverage,
}

SCAN_DIRS = (
    os.path.join("rust", "src"),
    os.path.join("rust", "tests"),
    os.path.join("rust", "benches"),
)


def collect_files(root):
    files = []
    for base in SCAN_DIRS:
        top = os.path.join(root, base)
        if not os.path.isdir(top):
            continue
        for dirpath, _, names in os.walk(top):
            for name in sorted(names):
                if name.endswith(".rs"):
                    rel = os.path.relpath(os.path.join(dirpath, name), root)
                    files.append(SourceFile.load(root, rel))
    files.sort(key=lambda sf: sf.path)
    return files


def run_checks(root, quiet=False):
    """Run every check over ``root``.  Returns (findings, suppressed)."""
    files = collect_files(root)
    allow = Allowlist(root)
    findings = []
    suppressed = 0
    for check in CHECKS:
        for f in CHECK_FNS[check](files, root):
            if allow.suppress(f):
                suppressed += 1
            else:
                findings.append(f)
    findings.extend(allow.stale())
    return findings, suppressed


def report(findings, suppressed):
    by_check = {}
    for f in findings:
        by_check.setdefault(f.check, []).append(f)
    for check in CHECKS + ["allowlist"]:
        group = by_check.get(check)
        if not group:
            continue
        print(f"[{check}] {len(group)} finding(s):")
        for f in group:
            print(f"  {f.fmt()}")
    total = len(findings)
    print(
        f"vflint: {total} finding(s) across {len(by_check)} check(s), "
        f"{suppressed} allowlisted"
        if total
        else f"vflint: clean ({suppressed} allowlisted finding(s) suppressed)"
    )
    return 1 if total else 0


def self_test(fixtures_dir):
    """Each fixture tree must trigger exactly its intended check; the
    ``clean`` tree must trigger none."""
    if not os.path.isdir(fixtures_dir):
        print(f"vflint --self-test: no fixture dir at {fixtures_dir}", file=sys.stderr)
        return 2
    failures = 0
    names = sorted(os.listdir(fixtures_dir))
    covered = set()
    for name in names:
        tree = os.path.join(fixtures_dir, name)
        if not os.path.isdir(tree):
            continue
        expect_path = os.path.join(tree, "expect.txt")
        expected = None
        if os.path.exists(expect_path):
            with open(expect_path, encoding="utf-8") as f:
                expected = f.read().strip()
        findings, _ = run_checks(tree, quiet=True)
        got = sorted({f.check for f in findings})
        if name == "clean" or expected == "clean":
            ok = not findings
            want_desc = "no findings"
        else:
            if expected is None:
                print(f"  FAIL {name}: fixture tree has no expect.txt")
                failures += 1
                continue
            ok = got == [expected] and len(findings) >= 1
            covered.add(expected)
            want_desc = f"only [{expected}]"
        status = "ok  " if ok else "FAIL"
        print(f"  {status} {name}: want {want_desc}, got {got or 'none'}")
        if not ok:
            failures += 1
            for f in findings:
                print(f"         {f.check}: {f.fmt()}")
    missing = [c for c in CHECKS if c not in covered]
    if missing:
        print(f"  FAIL fixture corpus does not cover: {missing}")
        failures += 1
    print(f"vflint --self-test: {'PASS' if failures == 0 else f'{failures} failure(s)'}")
    return 0 if failures == 0 else 1


def main(argv):
    ap = argparse.ArgumentParser(prog="vflint", description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=DEFAULT_ROOT, help="repo root (default: two levels above this script)")
    ap.add_argument("--self-test", action="store_true", help="run the fixture corpus instead of the repo")
    ap.add_argument("--list-checks", action="store_true", help="print check ids and exit")
    args = ap.parse_args(argv)
    if args.list_checks:
        for c in CHECKS:
            print(c)
        return 0
    if args.self_test:
        return self_test(os.path.join(TOOL_DIR, "fixtures"))
    findings, suppressed = run_checks(args.root)
    return report(findings, suppressed)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
