//! Arbitrary-precision unsigned integer arithmetic, from scratch.
//!
//! This is the substrate under the Paillier baseline (§6.5's `phe`
//! comparator) and the DH-PSI module: little-endian `u64` limbs,
//! schoolbook multiplication, Knuth Algorithm-D division (on 32-bit
//! half-limbs), CIOS Montgomery multiplication for modular
//! exponentiation, extended Euclid for modular inverses, and
//! Miller–Rabin prime generation.

use std::cmp::Ordering;

/// An arbitrary-precision unsigned integer (little-endian u64 limbs,
/// normalized: no trailing zero limbs; zero is the empty limb vector).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BigUint {
    pub(crate) limbs: Vec<u64>,
}

impl BigUint {
    pub fn zero() -> Self {
        BigUint { limbs: vec![] }
    }

    pub fn one() -> Self {
        BigUint { limbs: vec![1] }
    }

    pub fn from_u64(v: u64) -> Self {
        if v == 0 {
            Self::zero()
        } else {
            BigUint { limbs: vec![v] }
        }
    }

    pub fn from_u128(v: u128) -> Self {
        let lo = v as u64;
        let hi = (v >> 64) as u64;
        BigUint { limbs: vec![lo, hi] }.normalized()
    }

    fn normalized(mut self) -> Self {
        while self.limbs.last() == Some(&0) {
            self.limbs.pop();
        }
        self
    }

    pub fn is_zero(&self) -> bool {
        self.limbs.is_empty()
    }

    pub fn is_one(&self) -> bool {
        self.limbs.len() == 1 && self.limbs[0] == 1
    }

    pub fn is_even(&self) -> bool {
        self.limbs.first().map_or(true, |l| l & 1 == 0)
    }

    /// Number of significant bits.
    pub fn bits(&self) -> usize {
        match self.limbs.last() {
            None => 0,
            Some(&hi) => 64 * (self.limbs.len() - 1) + (64 - hi.leading_zeros() as usize),
        }
    }

    /// Test bit `i` (little-endian).
    pub fn bit(&self, i: usize) -> bool {
        let (limb, off) = (i / 64, i % 64);
        self.limbs.get(limb).map_or(false, |l| (l >> off) & 1 == 1)
    }

    pub fn to_u64(&self) -> Option<u64> {
        match self.limbs.len() {
            0 => Some(0),
            1 => Some(self.limbs[0]),
            _ => None,
        }
    }

    /// Parse big-endian bytes.
    pub fn from_bytes_be(bytes: &[u8]) -> Self {
        let mut limbs = Vec::with_capacity(bytes.len() / 8 + 1);
        let mut cur: u64 = 0;
        let mut n = 0;
        for &b in bytes.iter().rev() {
            cur |= (b as u64) << (8 * n);
            n += 1;
            if n == 8 {
                limbs.push(cur);
                cur = 0;
                n = 0;
            }
        }
        if n > 0 {
            limbs.push(cur);
        }
        BigUint { limbs }.normalized()
    }

    /// Serialize to big-endian bytes (minimal length; zero → empty).
    pub fn to_bytes_be(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.limbs.len() * 8);
        for (i, &l) in self.limbs.iter().enumerate().rev() {
            let b = l.to_be_bytes();
            if i == self.limbs.len() - 1 {
                // strip leading zeros of the top limb
                let nz = b.iter().position(|&x| x != 0).unwrap_or(7);
                out.extend_from_slice(&b[nz..]);
            } else {
                out.extend_from_slice(&b);
            }
        }
        out
    }

    pub fn from_hex(s: &str) -> Self {
        let s: String = s.chars().filter(|c| !c.is_whitespace()).collect();
        let s = if s.len() % 2 == 1 { format!("0{s}") } else { s };
        let bytes: Vec<u8> =
            (0..s.len()).step_by(2).map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap()).collect();
        Self::from_bytes_be(&bytes)
    }

    pub fn to_hex(&self) -> String {
        if self.is_zero() {
            return "0".into();
        }
        let bytes = self.to_bytes_be();
        let mut s: String = bytes.iter().map(|b| format!("{b:02x}")).collect();
        while s.len() > 1 && s.starts_with('0') {
            s.remove(0);
        }
        s
    }

    pub fn cmp_big(&self, other: &Self) -> Ordering {
        if self.limbs.len() != other.limbs.len() {
            return self.limbs.len().cmp(&other.limbs.len());
        }
        for i in (0..self.limbs.len()).rev() {
            match self.limbs[i].cmp(&other.limbs[i]) {
                Ordering::Equal => continue,
                o => return o,
            }
        }
        Ordering::Equal
    }

    pub fn add(&self, other: &Self) -> Self {
        let (a, b) = if self.limbs.len() >= other.limbs.len() { (self, other) } else { (other, self) };
        let mut out = Vec::with_capacity(a.limbs.len() + 1);
        let mut carry = 0u64;
        for i in 0..a.limbs.len() {
            let bi = b.limbs.get(i).copied().unwrap_or(0);
            let (s1, c1) = a.limbs[i].overflowing_add(bi);
            let (s2, c2) = s1.overflowing_add(carry);
            out.push(s2);
            carry = (c1 as u64) + (c2 as u64);
        }
        if carry != 0 {
            out.push(carry);
        }
        BigUint { limbs: out }.normalized()
    }

    /// Subtraction; panics if `other > self`.
    pub fn sub(&self, other: &Self) -> Self {
        debug_assert!(self.cmp_big(other) != Ordering::Less, "BigUint underflow");
        let mut out = Vec::with_capacity(self.limbs.len());
        let mut borrow = 0u64;
        for i in 0..self.limbs.len() {
            let bi = other.limbs.get(i).copied().unwrap_or(0);
            let (d1, b1) = self.limbs[i].overflowing_sub(bi);
            let (d2, b2) = d1.overflowing_sub(borrow);
            out.push(d2);
            borrow = (b1 as u64) + (b2 as u64);
        }
        debug_assert_eq!(borrow, 0);
        BigUint { limbs: out }.normalized()
    }

    pub fn mul(&self, other: &Self) -> Self {
        if self.is_zero() || other.is_zero() {
            return Self::zero();
        }
        let mut out = vec![0u64; self.limbs.len() + other.limbs.len()];
        for (i, &a) in self.limbs.iter().enumerate() {
            let mut carry = 0u128;
            for (j, &b) in other.limbs.iter().enumerate() {
                let cur = out[i + j] as u128 + (a as u128) * (b as u128) + carry;
                out[i + j] = cur as u64;
                carry = cur >> 64;
            }
            let mut k = i + other.limbs.len();
            while carry > 0 {
                let cur = out[k] as u128 + carry;
                out[k] = cur as u64;
                carry = cur >> 64;
                k += 1;
            }
        }
        BigUint { limbs: out }.normalized()
    }

    pub fn shl_bits(&self, n: usize) -> Self {
        if self.is_zero() {
            return Self::zero();
        }
        let (limb_shift, bit_shift) = (n / 64, n % 64);
        let mut out = vec![0u64; self.limbs.len() + limb_shift + 1];
        for (i, &l) in self.limbs.iter().enumerate() {
            out[i + limb_shift] |= l << bit_shift;
            if bit_shift > 0 {
                out[i + limb_shift + 1] |= l >> (64 - bit_shift);
            }
        }
        BigUint { limbs: out }.normalized()
    }

    pub fn shr_bits(&self, n: usize) -> Self {
        let (limb_shift, bit_shift) = (n / 64, n % 64);
        if limb_shift >= self.limbs.len() {
            return Self::zero();
        }
        let mut out = Vec::with_capacity(self.limbs.len() - limb_shift);
        for i in limb_shift..self.limbs.len() {
            let mut v = self.limbs[i] >> bit_shift;
            if bit_shift > 0 && i + 1 < self.limbs.len() {
                v |= self.limbs[i + 1] << (64 - bit_shift);
            }
            out.push(v);
        }
        BigUint { limbs: out }.normalized()
    }

    /// Quotient and remainder (Knuth Algorithm D on 32-bit half-limbs).
    pub fn div_rem(&self, divisor: &Self) -> (Self, Self) {
        assert!(!divisor.is_zero(), "division by zero");
        if self.cmp_big(divisor) == Ordering::Less {
            return (Self::zero(), self.clone());
        }
        let num = to_u32_limbs(&self.limbs);
        let den = to_u32_limbs(&divisor.limbs);
        let (q, r) = if den.len() == 1 {
            div_rem_small(&num, den[0])
        } else {
            div_rem_knuth(&num, &den)
        };
        (
            BigUint { limbs: from_u32_limbs(&q) }.normalized(),
            BigUint { limbs: from_u32_limbs(&r) }.normalized(),
        )
    }

    pub fn rem(&self, modulus: &Self) -> Self {
        self.div_rem(modulus).1
    }

    pub fn add_mod(&self, other: &Self, m: &Self) -> Self {
        self.add(other).rem(m)
    }

    pub fn sub_mod(&self, other: &Self, m: &Self) -> Self {
        let a = self.rem(m);
        let b = other.rem(m);
        if a.cmp_big(&b) == Ordering::Less {
            a.add(m).sub(&b)
        } else {
            a.sub(&b)
        }
    }

    pub fn mul_mod(&self, other: &Self, m: &Self) -> Self {
        self.mul(other).rem(m)
    }

    /// Modular exponentiation. Uses Montgomery CIOS when the modulus is
    /// odd (the common case: RSA/Paillier moduli), plain square-and-
    /// multiply with division otherwise.
    pub fn mod_pow(&self, exponent: &Self, modulus: &Self) -> Self {
        assert!(!modulus.is_zero());
        if modulus.is_one() {
            return Self::zero();
        }
        if !modulus.is_even() {
            let ctx = MontCtx::new(modulus);
            return ctx.pow(self, exponent);
        }
        // fallback
        let mut base = self.rem(modulus);
        let mut result = Self::one();
        for i in 0..exponent.bits() {
            if exponent.bit(i) {
                result = result.mul_mod(&base, modulus);
            }
            base = base.mul_mod(&base, modulus);
        }
        result
    }

    /// Modular inverse via extended Euclid; `None` if gcd ≠ 1.
    pub fn mod_inverse(&self, modulus: &Self) -> Option<Self> {
        // iterative extended Euclid with signed coefficients
        let mut r0 = modulus.clone();
        let mut r1 = self.rem(modulus);
        // t coefficients with sign
        let mut t0 = (Self::zero(), false); // (magnitude, negative?)
        let mut t1 = (Self::one(), false);
        while !r1.is_zero() {
            let (q, r2) = r0.div_rem(&r1);
            // t2 = t0 - q * t1
            let qt1 = q.mul(&t1.0);
            let t2 = signed_sub(&t0, &(qt1, t1.1));
            r0 = r1;
            r1 = r2;
            t0 = t1;
            t1 = t2;
        }
        if !r0.is_one() {
            return None;
        }
        let (mag, neg) = t0;
        let mag = mag.rem(modulus);
        Some(if neg && !mag.is_zero() { modulus.sub(&mag) } else { mag })
    }

    pub fn gcd(&self, other: &Self) -> Self {
        let mut a = self.clone();
        let mut b = other.clone();
        while !b.is_zero() {
            let r = a.rem(&b);
            a = b;
            b = r;
        }
        a
    }

    /// Uniform random value in `[0, bound)` using the supplied RNG.
    pub fn random_below(bound: &Self, rng: &mut dyn FnMut(&mut [u8])) -> Self {
        assert!(!bound.is_zero());
        let bytes = (bound.bits() + 7) / 8;
        let top_bits = bound.bits() % 8;
        loop {
            let mut buf = vec![0u8; bytes];
            rng(&mut buf);
            if top_bits > 0 {
                buf[0] &= (1u8 << top_bits) - 1;
            }
            let v = Self::from_bytes_be(&buf);
            if v.cmp_big(bound) == Ordering::Less {
                return v;
            }
        }
    }

    /// Miller–Rabin probabilistic primality test with `rounds` random bases.
    pub fn is_probable_prime(&self, rounds: usize, rng: &mut dyn FnMut(&mut [u8])) -> bool {
        if self.is_zero() || self.is_one() {
            return false;
        }
        if let Some(v) = self.to_u64() {
            if v < 4 {
                return v == 2 || v == 3;
            }
        }
        if self.is_even() {
            return false;
        }
        for &p in SMALL_PRIMES {
            let pb = Self::from_u64(p);
            if self.cmp_big(&pb) == Ordering::Equal {
                return true;
            }
            if self.rem(&pb).is_zero() {
                return false;
            }
        }
        // write n-1 = d * 2^s
        let n1 = self.sub(&Self::one());
        let s = {
            let mut s = 0usize;
            while !n1.bit(s) {
                s += 1;
            }
            s
        };
        let d = n1.shr_bits(s);
        let two = Self::from_u64(2);
        let lo = two.clone();
        let hi = self.sub(&two); // bases in [2, n-2]
        'witness: for _ in 0..rounds {
            let a = loop {
                let c = Self::random_below(&hi, rng);
                if c.cmp_big(&lo) != Ordering::Less {
                    break c;
                }
            };
            let mut x = a.mod_pow(&d, self);
            if x.is_one() || x.cmp_big(&n1) == Ordering::Equal {
                continue 'witness;
            }
            for _ in 0..s - 1 {
                x = x.mul_mod(&x, self);
                if x.cmp_big(&n1) == Ordering::Equal {
                    continue 'witness;
                }
            }
            return false;
        }
        true
    }

    /// Generate a random prime with exactly `bits` bits.
    pub fn gen_prime(bits: usize, rng: &mut dyn FnMut(&mut [u8])) -> Self {
        assert!(bits >= 8);
        loop {
            let bytes = (bits + 7) / 8;
            let mut buf = vec![0u8; bytes];
            rng(&mut buf);
            // force exact bit-length and oddness
            let top = (bits - 1) % 8;
            buf[0] &= (1u8 << (top + 1)) - 1;
            buf[0] |= 1 << top;
            if top > 0 {
                buf[0] |= 1 << (top - 1); // top-two bits set: products have full length
            }
            buf[bytes - 1] |= 1;
            let cand = Self::from_bytes_be(&buf);
            if cand.is_probable_prime(16, rng) {
                return cand;
            }
        }
    }
}

fn signed_sub(a: &(BigUint, bool), b: &(BigUint, bool)) -> (BigUint, bool) {
    // compute a - b over signed magnitudes
    match (a.1, b.1) {
        (false, true) => (a.0.add(&b.0), false),  // a - (-b) = a + b
        (true, false) => (a.0.add(&b.0), true),   // -a - b = -(a+b)
        (an, _) => {
            // same sign: |a| - |b| with sign fix
            if a.0.cmp_big(&b.0) != Ordering::Less {
                (a.0.sub(&b.0), an)
            } else {
                (b.0.sub(&a.0), !an)
            }
        }
    }
}

const SMALL_PRIMES: &[u64] = &[
    2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59, 61, 67, 71, 73, 79, 83, 89, 97,
    101, 103, 107, 109, 113, 127, 131, 137, 139, 149, 151, 157, 163, 167, 173, 179, 181, 191, 193,
    197, 199, 211, 223, 227, 229, 233, 239, 241, 251, 257, 263, 269, 271, 277, 281, 283, 293, 307,
    311, 313, 317, 331, 337, 347, 349,
];

fn to_u32_limbs(limbs: &[u64]) -> Vec<u32> {
    let mut out = Vec::with_capacity(limbs.len() * 2);
    for &l in limbs {
        out.push(l as u32);
        out.push((l >> 32) as u32);
    }
    while out.last() == Some(&0) {
        out.pop();
    }
    out
}

fn from_u32_limbs(limbs: &[u32]) -> Vec<u64> {
    let mut out = Vec::with_capacity(limbs.len() / 2 + 1);
    for chunk in limbs.chunks(2) {
        let lo = chunk[0] as u64;
        let hi = chunk.get(1).copied().unwrap_or(0) as u64;
        out.push(lo | (hi << 32));
    }
    out
}

fn div_rem_small(num: &[u32], den: u32) -> (Vec<u32>, Vec<u32>) {
    let mut q = vec![0u32; num.len()];
    let mut rem: u64 = 0;
    for i in (0..num.len()).rev() {
        let cur = (rem << 32) | num[i] as u64;
        q[i] = (cur / den as u64) as u32;
        rem = cur % den as u64;
    }
    (q, vec![rem as u32])
}

/// Knuth TAOCP vol.2 Algorithm D, base 2³².
fn div_rem_knuth(num: &[u32], den: &[u32]) -> (Vec<u32>, Vec<u32>) {
    let n = den.len();
    let m = num.len() - n;
    // D1: normalize
    let shift = den[n - 1].leading_zeros();
    let mut v = shl32(den, shift);
    debug_assert_eq!(v.len(), n);
    let mut u = shl32(num, shift);
    if u.len() == num.len() {
        u.push(0);
    }
    let mut q = vec![0u32; m + 1];
    let b: u64 = 1 << 32;

    for j in (0..=m).rev() {
        // D3: estimate qhat (u128 to avoid overflow: qhat may start ≥ 2³²)
        let top = ((u[j + n] as u128) << 32) | u[j + n - 1] as u128;
        let vn1 = v[n - 1] as u128;
        let mut qhat128 = top / vn1;
        let mut rhat = top % vn1;
        loop {
            if qhat128 >= b as u128
                || qhat128 * (v[n - 2] as u128) > (rhat << 32) + u[j + n - 2] as u128
            {
                qhat128 -= 1;
                rhat += vn1;
                if rhat < b as u128 {
                    continue;
                }
            }
            break;
        }
        let mut qhat = qhat128 as u64; // < 2^32 after correction
        // D4: multiply and subtract
        let mut borrow: i64 = 0;
        let mut carry: u64 = 0;
        for i in 0..n {
            let p = qhat * v[i] as u64 + carry;
            carry = p >> 32;
            let t = u[j + i] as i64 - borrow - (p as u32) as i64;
            u[j + i] = t as u32;
            borrow = if t < 0 { 1 } else { 0 };
        }
        let t = u[j + n] as i64 - borrow - carry as i64;
        u[j + n] = t as u32;
        if t < 0 {
            // D6: add back
            qhat -= 1;
            let mut carry: u64 = 0;
            for i in 0..n {
                let s = u[j + i] as u64 + v[i] as u64 + carry;
                u[j + i] = s as u32;
                carry = s >> 32;
            }
            u[j + n] = u[j + n].wrapping_add(carry as u32);
        }
        q[j] = qhat as u32;
    }
    // D8: unnormalize remainder
    v.clear();
    let r = shr32(&u[..n], shift);
    (q, r)
}

fn shl32(x: &[u32], shift: u32) -> Vec<u32> {
    if shift == 0 {
        return x.to_vec();
    }
    let mut out = vec![0u32; x.len() + 1];
    for (i, &l) in x.iter().enumerate() {
        out[i] |= l << shift;
        out[i + 1] |= (l as u64 >> (32 - shift)) as u32;
    }
    while out.len() > x.len() && out.last() == Some(&0) {
        out.pop();
    }
    out
}

fn shr32(x: &[u32], shift: u32) -> Vec<u32> {
    if shift == 0 {
        return x.to_vec();
    }
    let mut out = vec![0u32; x.len()];
    for i in 0..x.len() {
        out[i] = x[i] >> shift;
        if i + 1 < x.len() {
            out[i] |= ((x[i + 1] as u64) << (32 - shift)) as u32;
        }
    }
    out
}

/// Montgomery context for an odd modulus: CIOS multiplication.
pub struct MontCtx {
    m: Vec<u64>,       // modulus limbs, len k
    n0inv: u64,        // -m^{-1} mod 2^64
    r2: BigUint,       // 2^{128k} mod m
    k: usize,
}

impl MontCtx {
    pub fn new(modulus: &BigUint) -> Self {
        assert!(!modulus.is_even() && !modulus.is_zero());
        let k = modulus.limbs.len();
        // n0inv via Newton: x_{i+1} = x_i * (2 - m0 * x_i) mod 2^64
        let m0 = modulus.limbs[0];
        let mut inv: u64 = 1;
        for _ in 0..6 {
            inv = inv.wrapping_mul(2u64.wrapping_sub(m0.wrapping_mul(inv)));
        }
        let n0inv = inv.wrapping_neg();
        let r2 = BigUint::one().shl_bits(128 * k).rem(modulus);
        MontCtx { m: modulus.limbs.clone(), n0inv, r2, k }
    }

    fn mont_mul(&self, a: &[u64], b: &[u64]) -> Vec<u64> {
        let k = self.k;
        let mut t = vec![0u64; k + 2];
        for i in 0..k {
            let ai = a.get(i).copied().unwrap_or(0);
            // t += a[i] * b
            let mut carry: u128 = 0;
            for j in 0..k {
                let bj = b.get(j).copied().unwrap_or(0);
                let sum = t[j] as u128 + (ai as u128) * (bj as u128) + carry;
                t[j] = sum as u64;
                carry = sum >> 64;
            }
            let sum = t[k] as u128 + carry;
            t[k] = sum as u64;
            t[k + 1] = t[k + 1].wrapping_add((sum >> 64) as u64);
            // reduce
            let mu = t[0].wrapping_mul(self.n0inv);
            let mut carry: u128 = (t[0] as u128 + (mu as u128) * (self.m[0] as u128)) >> 64;
            for j in 1..k {
                let sum = t[j] as u128 + (mu as u128) * (self.m[j] as u128) + carry;
                t[j - 1] = sum as u64;
                carry = sum >> 64;
            }
            let sum = t[k] as u128 + carry;
            t[k - 1] = sum as u64;
            t[k] = t[k + 1].wrapping_add((sum >> 64) as u64);
            t[k + 1] = 0;
        }
        t.truncate(k + 1);
        // conditional subtract m
        let mut res = BigUint { limbs: t }.normalized();
        let m = BigUint { limbs: self.m.clone() };
        while res.cmp_big(&m) != Ordering::Less {
            res = res.sub(&m);
        }
        let mut limbs = res.limbs;
        limbs.resize(k, 0);
        limbs
    }

    fn to_mont(&self, x: &BigUint) -> Vec<u64> {
        let xr = x.rem(&BigUint { limbs: self.m.clone() });
        let mut xl = xr.limbs;
        xl.resize(self.k, 0);
        let mut r2 = self.r2.limbs.clone();
        r2.resize(self.k, 0);
        self.mont_mul(&xl, &r2)
    }

    fn from_mont(&self, x: &[u64]) -> BigUint {
        let one = {
            let mut v = vec![0u64; self.k];
            v[0] = 1;
            v
        };
        BigUint { limbs: self.mont_mul(x, &one) }.normalized()
    }

    /// `base^exp mod m` via 4-bit fixed-window exponentiation.
    pub fn pow(&self, base: &BigUint, exp: &BigUint) -> BigUint {
        if exp.is_zero() {
            return BigUint::one().rem(&BigUint { limbs: self.m.clone() });
        }
        let bm = self.to_mont(base);
        // precompute base^0..base^15 in Montgomery form
        let one_m = self.to_mont(&BigUint::one());
        let mut table = Vec::with_capacity(16);
        table.push(one_m.clone());
        table.push(bm.clone());
        for i in 2..16 {
            let prev: &Vec<u64> = &table[i - 1];
            table.push(self.mont_mul(prev, &bm));
        }
        let nbits = exp.bits();
        let nwindows = (nbits + 3) / 4;
        let mut acc = one_m;
        let mut started = false;
        for w in (0..nwindows).rev() {
            if started {
                acc = self.mont_mul(&acc, &acc);
                acc = self.mont_mul(&acc, &acc);
                acc = self.mont_mul(&acc, &acc);
                acc = self.mont_mul(&acc, &acc);
            }
            let mut window = 0usize;
            for b in 0..4 {
                if exp.bit(4 * w + b) {
                    window |= 1 << b;
                }
            }
            if window != 0 {
                acc = self.mont_mul(&acc, &table[window]);
                started = true;
            } else if started {
                // nothing to multiply
            }
            if !started && window == 0 {
                continue;
            }
            started = true;
        }
        self.from_mont(&acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::DetRng;

    fn b(v: u64) -> BigUint {
        BigUint::from_u64(v)
    }

    #[test]
    fn add_sub_small() {
        assert_eq!(b(2).add(&b(3)), b(5));
        assert_eq!(b(5).sub(&b(3)), b(2));
        assert_eq!(b(5).sub(&b(5)), BigUint::zero());
    }

    #[test]
    fn add_carries_across_limbs() {
        let x = BigUint::from_hex("ffffffffffffffffffffffffffffffff");
        let y = x.add(&BigUint::one());
        assert_eq!(y.to_hex(), "100000000000000000000000000000000");
        assert_eq!(y.sub(&BigUint::one()), x);
    }

    #[test]
    fn mul_known() {
        let x = BigUint::from_hex("ffffffffffffffff");
        let y = x.mul(&x);
        assert_eq!(y.to_hex(), "fffffffffffffffe0000000000000001");
        assert_eq!(b(0).mul(&x), BigUint::zero());
    }

    #[test]
    fn div_rem_small_cases() {
        let (q, r) = b(17).div_rem(&b(5));
        assert_eq!((q, r), (b(3), b(2)));
        let (q, r) = b(4).div_rem(&b(9));
        assert_eq!((q, r), (BigUint::zero(), b(4)));
    }

    #[test]
    fn div_rem_multi_limb() {
        let x = BigUint::from_hex("123456789abcdef0123456789abcdef0123456789abcdef0");
        let y = BigUint::from_hex("fedcba9876543210fedcba98");
        let (q, r) = x.div_rem(&y);
        // verify x == q*y + r and r < y
        assert_eq!(q.mul(&y).add(&r), x);
        assert_eq!(r.cmp_big(&y), Ordering::Less);
    }

    #[test]
    fn div_rem_randomized_invariant() {
        let mut rng = DetRng::from_seed(42);
        for _ in 0..200 {
            let xb = rng.next_range(1, 40) as usize;
            let yb = rng.next_range(1, 24) as usize;
            let mut xv = vec![0u8; xb];
            let mut yv = vec![0u8; yb];
            rng.fill(&mut xv);
            rng.fill(&mut yv);
            let x = BigUint::from_bytes_be(&xv);
            let y = BigUint::from_bytes_be(&yv);
            if y.is_zero() {
                continue;
            }
            let (q, r) = x.div_rem(&y);
            assert_eq!(q.mul(&y).add(&r), x);
            assert_eq!(r.cmp_big(&y), Ordering::Less);
        }
    }

    #[test]
    fn shifts() {
        let x = BigUint::from_hex("1234");
        assert_eq!(x.shl_bits(8).to_hex(), "123400");
        assert_eq!(x.shl_bits(64).shr_bits(64), x);
        assert_eq!(x.shr_bits(100), BigUint::zero());
    }

    #[test]
    fn mod_pow_small() {
        // 3^7 mod 10 = 2187 mod 10 = 7  (even modulus path)
        assert_eq!(b(3).mod_pow(&b(7), &b(10)), b(7));
        // odd modulus path via Montgomery
        assert_eq!(b(3).mod_pow(&b(7), &b(11)), b(9)); // 2187 = 198*11+9
        assert_eq!(b(2).mod_pow(&b(0), &b(7)), b(1));
        assert_eq!(b(5).mod_pow(&b(117), &b(19)), b(1)); // fermat: 5^18=1, 117=6*18+9 → 5^9 mod 19 = 1? check: 5^2=6,5^4=36=17,5^8=17^2=289=4,5^9=20=1 yes
    }

    #[test]
    fn mod_pow_matches_naive_randomized() {
        let mut rng = DetRng::from_seed(7);
        for _ in 0..30 {
            let mut bb = [0u8; 12];
            let mut ee = [0u8; 4];
            let mut mm = [0u8; 10];
            rng.fill(&mut bb);
            rng.fill(&mut ee);
            rng.fill(&mut mm);
            mm[9] |= 1; // odd modulus
            let base = BigUint::from_bytes_be(&bb);
            let exp = BigUint::from_bytes_be(&ee[..2]);
            let m = BigUint::from_bytes_be(&mm);
            if m.is_zero() || m.is_one() {
                continue;
            }
            // naive
            let mut want = BigUint::one();
            let br = base.rem(&m);
            for i in (0..exp.bits()).rev() {
                want = want.mul_mod(&want, &m);
                if exp.bit(i) {
                    want = want.mul_mod(&br, &m);
                }
            }
            assert_eq!(base.mod_pow(&exp, &m), want);
        }
    }

    #[test]
    fn mod_inverse_works() {
        let m = b(101);
        for a in 1..100u64 {
            let inv = b(a).mod_inverse(&m).unwrap();
            assert_eq!(b(a).mul_mod(&inv, &m), BigUint::one(), "a={a}");
        }
        assert!(b(6).mod_inverse(&b(9)).is_none()); // gcd = 3
    }

    #[test]
    fn probable_primes() {
        let mut rng_f = DetRng::from_seed(1).as_fill_fn();
        for p in [2u64, 3, 5, 7, 65537, 2147483647] {
            assert!(b(p).is_probable_prime(16, &mut rng_f), "{p} should be prime");
        }
        for c in [1u64, 4, 100, 65541, 2147483649] {
            assert!(!b(c).is_probable_prime(16, &mut rng_f), "{c} should be composite");
        }
        // known 128-bit prime: 2^127 - 1 (Mersenne)
        let m127 = BigUint::one().shl_bits(127).sub(&BigUint::one());
        assert!(m127.is_probable_prime(16, &mut rng_f));
    }

    #[test]
    fn gen_prime_has_exact_bits() {
        let mut rng_f = DetRng::from_seed(99).as_fill_fn();
        let p = BigUint::gen_prime(96, &mut rng_f);
        assert_eq!(p.bits(), 96);
        assert!(p.is_probable_prime(16, &mut rng_f));
    }

    #[test]
    fn bytes_roundtrip() {
        let x = BigUint::from_hex("0123456789abcdef00ff");
        assert_eq!(BigUint::from_bytes_be(&x.to_bytes_be()), x);
        assert_eq!(BigUint::zero().to_bytes_be(), Vec::<u8>::new());
    }

    #[test]
    fn hex_roundtrip() {
        for s in ["0", "1", "ff", "123456789abcdef", "deadbeefdeadbeefdeadbeefdeadbeef1"] {
            assert_eq!(BigUint::from_hex(s).to_hex(), s.to_string());
        }
    }

    #[test]
    fn random_below_in_range() {
        let mut rng_f = DetRng::from_seed(5).as_fill_fn();
        let bound = BigUint::from_hex("10000000001");
        for _ in 0..50 {
            let v = BigUint::random_below(&bound, &mut rng_f);
            assert_eq!(v.cmp_big(&bound), Ordering::Less);
        }
    }

    #[test]
    fn mont_pow_large_modulus() {
        // Fermat test as a self-check of Montgomery: a^(p-1) ≡ 1 mod p
        let p = BigUint::from_hex("ffffffffffffffffffffffffffffff61"); // 2^128 - 159, prime
        let a = BigUint::from_hex("123456789");
        let e = p.sub(&BigUint::one());
        assert_eq!(a.mod_pow(&e, &p), BigUint::one());
    }
}
