//! Paillier cryptosystem, from scratch (the `phe` comparator of §6.5).
//!
//! Additively homomorphic public-key encryption over ℤ_{n²}:
//! `Enc(a) · Enc(b) = Enc(a+b)` and `Enc(a)^k = Enc(k·a)`. The paper's
//! Figure-2 ablation compares secure-aggregation dot products against
//! exactly this scheme (Python `phe`); here it is implemented on the
//! in-crate [`BigUint`](super::bigint::BigUint) with the standard
//! optimizations `phe` itself uses: g = n+1 (so `g^m = 1 + n·m mod n²`)
//! and CRT decryption.

use super::bigint::{BigUint, MontCtx};
use std::cmp::Ordering;
use std::sync::{Arc, OnceLock};

/// A Paillier public key (modulus n).
#[derive(Clone)]
pub struct PublicKey {
    pub n: BigUint,
    pub n_squared: BigUint,
    /// Max encodable magnitude: values are encoded in [0, n/3) positive,
    /// (2n/3, n) negative, mirroring `phe`'s signed encoding.
    pub max_int: BigUint,
    /// Cached Montgomery context for n² (every encryption/scalar-mul is
    /// a mod-n² exponentiation; rebuilding the context costs an
    /// 8192-bit division each time).
    ctx_n2: Arc<OnceLock<MontCtx>>,
}

/// A Paillier private key (CRT form).
#[derive(Clone)]
pub struct PrivateKey {
    pub public: PublicKey,
    p: BigUint,
    q: BigUint,
    p_squared: BigUint,
    q_squared: BigUint,
    hp: BigUint, // L_p(g^{p-1} mod p^2)^{-1} mod p
    hq: BigUint,
    p_inv_q: BigUint, // p^{-1} mod q
    ctx_p2: Arc<OnceLock<MontCtx>>,
    ctx_q2: Arc<OnceLock<MontCtx>>,
}

/// A Paillier ciphertext (element of ℤ_{n²}).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Ciphertext(pub BigUint);

fn l_function(x: &BigUint, n: &BigUint) -> BigUint {
    // L(x) = (x - 1) / n  — exact division
    x.sub(&BigUint::one()).div_rem(n).0
}

impl PublicKey {
    fn new(n: BigUint) -> Self {
        let n_squared = n.mul(&n);
        let max_int = n.div_rem(&BigUint::from_u64(3)).0;
        PublicKey { n, n_squared, max_int, ctx_n2: Arc::new(OnceLock::new()) }
    }

    fn ctx(&self) -> &MontCtx {
        self.ctx_n2.get_or_init(|| MontCtx::new(&self.n_squared))
    }

    /// Encrypt an unsigned plaintext m < n with fresh randomness from `rng`.
    pub fn encrypt(&self, m: &BigUint, rng: &mut dyn FnMut(&mut [u8])) -> Ciphertext {
        assert!(m.cmp_big(&self.n) == Ordering::Less, "plaintext out of range");
        // g = n+1: g^m = (1 + n)^m = 1 + n*m (mod n^2)
        let nm = self.n.mul(m).rem(&self.n_squared);
        let gm = nm.add(&BigUint::one()).rem(&self.n_squared);
        // r^n mod n^2 for random r in [1, n) coprime to n
        let r = loop {
            let r = BigUint::random_below(&self.n, rng);
            if !r.is_zero() && r.gcd(&self.n).is_one() {
                break r;
            }
        };
        let rn = self.ctx().pow(&r, &self.n);
        Ciphertext(gm.mul_mod(&rn, &self.n_squared))
    }

    /// Encrypt a signed 64-bit integer using phe-style wraparound encoding.
    pub fn encrypt_i64(&self, v: i64, rng: &mut dyn FnMut(&mut [u8])) -> Ciphertext {
        self.encrypt(&self.encode_i64(v), rng)
    }

    /// Signed encoding: negatives map to n − |v|.
    pub fn encode_i64(&self, v: i64) -> BigUint {
        if v >= 0 {
            BigUint::from_u64(v as u64)
        } else {
            self.n.sub(&BigUint::from_u64(v.unsigned_abs()))
        }
    }

    /// Homomorphic addition: Enc(a) ⊞ Enc(b) = Enc(a+b).
    pub fn add(&self, a: &Ciphertext, b: &Ciphertext) -> Ciphertext {
        Ciphertext(a.0.mul_mod(&b.0, &self.n_squared))
    }

    /// Homomorphic plaintext addition: Enc(a) ⊞ k.
    pub fn add_plain(&self, a: &Ciphertext, k: &BigUint) -> Ciphertext {
        let nk = self.n.mul(k).rem(&self.n_squared).add(&BigUint::one()).rem(&self.n_squared);
        Ciphertext(a.0.mul_mod(&nk, &self.n_squared))
    }

    /// Homomorphic scalar multiplication: Enc(a)^k = Enc(k·a).
    pub fn mul_plain(&self, a: &Ciphertext, k: &BigUint) -> Ciphertext {
        Ciphertext(self.ctx().pow(&a.0, k))
    }

    /// Scalar multiplication by a signed 64-bit value.
    pub fn mul_plain_i64(&self, a: &Ciphertext, k: i64) -> Ciphertext {
        self.mul_plain(a, &self.encode_i64(k))
    }
}

impl PrivateKey {
    /// Generate a keypair with an n of `n_bits` bits.
    pub fn generate(n_bits: usize, rng: &mut dyn FnMut(&mut [u8])) -> Self {
        assert!(n_bits >= 64, "key too small");
        loop {
            let p = BigUint::gen_prime(n_bits / 2, rng);
            let q = BigUint::gen_prime(n_bits - n_bits / 2, rng);
            if p == q {
                continue;
            }
            let n = p.mul(&q);
            if n.bits() != n_bits {
                continue;
            }
            return Self::from_primes(p, q);
        }
    }

    /// Build the CRT decryption context from primes p, q.
    pub fn from_primes(p: BigUint, q: BigUint) -> Self {
        let n = p.mul(&q);
        let public = PublicKey::new(n.clone());
        let p_squared = p.mul(&p);
        let q_squared = q.mul(&q);
        // g = n + 1
        let g = n.add(&BigUint::one());
        let p1 = p.sub(&BigUint::one());
        let q1 = q.sub(&BigUint::one());
        let hp = l_function(&g.mod_pow(&p1, &p_squared), &p)
            .mod_inverse(&p)
            .expect("hp inverse");
        let hq = l_function(&g.mod_pow(&q1, &q_squared), &q)
            .mod_inverse(&q)
            .expect("hq inverse");
        let p_inv_q = p.mod_inverse(&q).expect("p^-1 mod q");
        PrivateKey { public, p, q, p_squared, q_squared, hp, hq, p_inv_q, ctx_p2: Arc::new(OnceLock::new()), ctx_q2: Arc::new(OnceLock::new()) }
    }

    /// Decrypt to the unsigned representative in [0, n).
    pub fn decrypt(&self, c: &Ciphertext) -> BigUint {
        let p1 = self.p.sub(&BigUint::one());
        let q1 = self.q.sub(&BigUint::one());
        // mp = L_p(c^{p-1} mod p^2) * hp mod p
        let ctx_p = self.ctx_p2.get_or_init(|| MontCtx::new(&self.p_squared));
        let ctx_q = self.ctx_q2.get_or_init(|| MontCtx::new(&self.q_squared));
        let mp = l_function(&ctx_p.pow(&c.0.rem(&self.p_squared), &p1), &self.p)
            .mul_mod(&self.hp, &self.p);
        let mq = l_function(&ctx_q.pow(&c.0.rem(&self.q_squared), &q1), &self.q)
            .mul_mod(&self.hq, &self.q);
        // CRT combine
        let diff = mq.sub_mod(&mp, &self.q);
        let u = diff.mul_mod(&self.p_inv_q, &self.q);
        mp.add(&u.mul(&self.p))
    }

    /// Decrypt with signed decoding (inverse of [`PublicKey::encode_i64`]).
    pub fn decrypt_i64(&self, c: &Ciphertext) -> i64 {
        let m = self.decrypt(c);
        let n = &self.public.n;
        if m.cmp_big(&self.public.max_int) == Ordering::Greater {
            // negative value
            let mag = n.sub(&m);
            -(mag.to_u64().expect("magnitude fits u64") as i64)
        } else {
            m.to_u64().expect("value fits u64") as i64
        }
    }
}

/// An encrypted dot-product engine mirroring the paper's HE ablation:
/// the client encrypts its feature vector; the server multiplies by
/// plaintext weights and sums, all under encryption.
pub struct EncryptedDot<'k> {
    pub key: &'k PublicKey,
}

impl<'k> EncryptedDot<'k> {
    /// Enc(x) · w  for a (d,) encrypted vector and (d, h) plain weight
    /// matrix (values fixed-point i64) → (h,) encrypted outputs.
    pub fn matvec(&self, enc_x: &[Ciphertext], w: &[Vec<i64>]) -> Vec<Ciphertext> {
        let d = enc_x.len();
        assert_eq!(d, w.len());
        let h = w[0].len();
        (0..h)
            .map(|j| {
                let mut acc: Option<Ciphertext> = None;
                for i in 0..d {
                    let term = self.key.mul_plain_i64(&enc_x[i], w[i][j]);
                    acc = Some(match acc {
                        None => term,
                        Some(a) => self.key.add(&a, &term),
                    });
                }
                acc.expect("d > 0")
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::DetRng;

    fn small_key() -> PrivateKey {
        // fixed 128-bit primes for fast deterministic tests
        let mut rng = DetRng::from_seed(11).as_fill_fn();
        let p = BigUint::gen_prime(128, &mut rng);
        let q = {
            let mut q = BigUint::gen_prime(128, &mut rng);
            while q == p {
                q = BigUint::gen_prime(128, &mut rng);
            }
            q
        };
        PrivateKey::from_primes(p, q)
    }

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let sk = small_key();
        let pk = &sk.public;
        let mut rng = DetRng::from_seed(1).as_fill_fn();
        for v in [0u64, 1, 42, 1 << 40, u32::MAX as u64] {
            let m = BigUint::from_u64(v);
            let c = pk.encrypt(&m, &mut rng);
            assert_eq!(sk.decrypt(&c), m, "v={v}");
        }
    }

    #[test]
    fn signed_roundtrip() {
        let sk = small_key();
        let pk = &sk.public;
        let mut rng = DetRng::from_seed(2).as_fill_fn();
        for v in [0i64, 1, -1, 123456, -123456, i32::MAX as i64, i32::MIN as i64] {
            let c = pk.encrypt_i64(v, &mut rng);
            assert_eq!(sk.decrypt_i64(&c), v, "v={v}");
        }
    }

    #[test]
    fn homomorphic_addition() {
        let sk = small_key();
        let pk = &sk.public;
        let mut rng = DetRng::from_seed(3).as_fill_fn();
        let a = pk.encrypt_i64(1234, &mut rng);
        let b = pk.encrypt_i64(-234, &mut rng);
        assert_eq!(sk.decrypt_i64(&pk.add(&a, &b)), 1000);
        let c = pk.add_plain(&a, &BigUint::from_u64(66));
        assert_eq!(sk.decrypt_i64(&c), 1300);
    }

    #[test]
    fn homomorphic_scalar_mul() {
        let sk = small_key();
        let pk = &sk.public;
        let mut rng = DetRng::from_seed(4).as_fill_fn();
        let a = pk.encrypt_i64(37, &mut rng);
        assert_eq!(sk.decrypt_i64(&pk.mul_plain_i64(&a, 100)), 3700);
        assert_eq!(sk.decrypt_i64(&pk.mul_plain_i64(&a, -3)), -111);
        let neg = pk.encrypt_i64(-5, &mut rng);
        assert_eq!(sk.decrypt_i64(&pk.mul_plain_i64(&neg, -7)), 35);
    }

    #[test]
    fn semantic_security_randomized() {
        // same plaintext encrypts to different ciphertexts
        let sk = small_key();
        let pk = &sk.public;
        let mut rng = DetRng::from_seed(5).as_fill_fn();
        let c1 = pk.encrypt_i64(9, &mut rng);
        let c2 = pk.encrypt_i64(9, &mut rng);
        assert_ne!(c1, c2);
        assert_eq!(sk.decrypt_i64(&c1), sk.decrypt_i64(&c2));
    }

    #[test]
    fn encrypted_matvec_matches_plain() {
        let sk = small_key();
        let pk = &sk.public;
        let mut rng = DetRng::from_seed(6).as_fill_fn();
        let x: Vec<i64> = vec![3, -1, 4, 1];
        let w: Vec<Vec<i64>> = vec![vec![1, 2], vec![0, -1], vec![2, 2], vec![-3, 5]];
        let enc_x: Vec<Ciphertext> = x.iter().map(|&v| pk.encrypt_i64(v, &mut rng)).collect();
        let dot = EncryptedDot { key: pk };
        let enc_y = dot.matvec(&enc_x, &w);
        let want: Vec<i64> = (0..2)
            .map(|j| (0..4).map(|i| x[i] * w[i][j]).sum())
            .collect();
        let got: Vec<i64> = enc_y.iter().map(|c| sk.decrypt_i64(c)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn generate_real_keypair() {
        // end-to-end keygen at a small-but-real size
        let mut rng = DetRng::from_seed(7).as_fill_fn();
        let sk = PrivateKey::generate(256, &mut rng);
        assert_eq!(sk.public.n.bits(), 256);
        let mut rng2 = DetRng::from_seed(8).as_fill_fn();
        let c = sk.public.encrypt_i64(-987654321, &mut rng2);
        assert_eq!(sk.decrypt_i64(&c), -987654321);
    }
}
