//! Shamir t-of-n secret sharing over GF(2⁶¹−1), from scratch.
//!
//! Bonawitz et al. (2017) make secure aggregation robust to client
//! dropouts by secret-sharing each client's PRG seed among all peers;
//! if a client drops mid-round, any t surviving peers can reconstruct
//! its pairwise masks so the aggregate still cancels. The paper (§5.1)
//! positions this as the path to the malicious/robust setting; our
//! [`crate::secagg::dropout`] module builds on this primitive.

/// The Mersenne prime 2⁶¹ − 1 (field modulus).
pub const P: u64 = (1u64 << 61) - 1;

#[inline]
fn add(a: u64, b: u64) -> u64 {
    let s = a + b; // < 2^62, no overflow
    if s >= P {
        s - P
    } else {
        s
    }
}

#[inline]
fn sub(a: u64, b: u64) -> u64 {
    if a >= b {
        a - b
    } else {
        a + P - b
    }
}

#[inline]
fn mul(a: u64, b: u64) -> u64 {
    let t = (a as u128) * (b as u128);
    // fast Mersenne reduction: t = hi*2^61 + lo ≡ hi + lo (mod 2^61-1)
    let lo = (t & ((1u128 << 61) - 1)) as u64;
    let hi = (t >> 61) as u64;
    let mut r = lo + hi;
    if r >= P {
        r -= P;
    }
    // one more fold possible when hi is large
    if r >= P {
        r -= P;
    }
    r
}

fn pow(mut base: u64, mut exp: u64) -> u64 {
    let mut acc = 1u64;
    base %= P;
    while exp > 0 {
        if exp & 1 == 1 {
            acc = mul(acc, base);
        }
        base = mul(base, base);
        exp >>= 1;
    }
    acc
}

#[inline]
fn inv(a: u64) -> u64 {
    assert!(a % P != 0, "no inverse of zero");
    pow(a, P - 2)
}

/// One share: the evaluation point x (party index + 1) and value y.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Share {
    pub x: u64,
    pub y: u64,
}

/// Split `secret` (< P) into `n` shares with threshold `t`
/// (any `t` shares reconstruct; fewer reveal nothing).
pub fn split(secret: u64, t: usize, n: usize, rng: &mut dyn FnMut(&mut [u8])) -> Vec<Share> {
    assert!(t >= 1 && t <= n, "invalid threshold");
    assert!(secret < P, "secret out of field");
    // random polynomial of degree t-1 with a_0 = secret
    let mut coeffs = vec![secret];
    for _ in 1..t {
        let mut b = [0u8; 8];
        loop {
            rng(&mut b);
            let v = u64::from_le_bytes(b) & ((1u64 << 61) - 1);
            if v < P {
                coeffs.push(v);
                break;
            }
        }
    }
    (1..=n as u64)
        .map(|x| {
            // Horner evaluation
            let mut y = 0u64;
            for &c in coeffs.iter().rev() {
                y = add(mul(y, x), c);
            }
            Share { x, y }
        })
        .collect()
}

/// Reconstruct the secret from at least `t` distinct shares via
/// Lagrange interpolation at x = 0.
pub fn reconstruct(shares: &[Share]) -> u64 {
    assert!(!shares.is_empty());
    let mut secret = 0u64;
    for (i, si) in shares.iter().enumerate() {
        let mut num = 1u64;
        let mut den = 1u64;
        for (j, sj) in shares.iter().enumerate() {
            if i == j {
                continue;
            }
            assert_ne!(si.x, sj.x, "duplicate share x");
            num = mul(num, sj.x % P);
            den = mul(den, sub(sj.x % P, si.x % P));
        }
        let li = mul(num, inv(den));
        secret = add(secret, mul(si.y, li));
    }
    secret
}

/// Split an arbitrary byte string into per-chunk shares (each 60-bit
/// chunk shared independently). Returns one `Vec<Share>` per party.
pub fn split_bytes(data: &[u8], t: usize, n: usize, rng: &mut dyn FnMut(&mut [u8])) -> Vec<Vec<Share>> {
    let chunks = chunk_bytes(data);
    let mut per_party: Vec<Vec<Share>> = vec![Vec::with_capacity(chunks.len()); n];
    for &c in &chunks {
        let shares = split(c, t, n, rng);
        for (p, s) in shares.into_iter().enumerate() {
            per_party[p].push(s);
        }
    }
    per_party
}

/// Reconstruct bytes previously shared with [`split_bytes`].
/// `party_shares` holds each participating party's full share vector;
/// `len` is the original byte length.
pub fn reconstruct_bytes(party_shares: &[Vec<Share>], len: usize) -> Vec<u8> {
    assert!(!party_shares.is_empty());
    let n_chunks = party_shares[0].len();
    let mut chunks = Vec::with_capacity(n_chunks);
    for c in 0..n_chunks {
        let shares: Vec<Share> = party_shares.iter().map(|p| p[c]).collect();
        chunks.push(reconstruct(&shares));
    }
    unchunk_bytes(&chunks, len)
}

fn chunk_bytes(data: &[u8]) -> Vec<u64> {
    // 7 bytes (56 bits) per chunk: always < P
    data.chunks(7)
        .map(|c| {
            let mut b = [0u8; 8];
            b[..c.len()].copy_from_slice(c);
            u64::from_le_bytes(b)
        })
        .collect()
}

fn unchunk_bytes(chunks: &[u64], len: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(len);
    for &c in chunks {
        out.extend_from_slice(&c.to_le_bytes()[..7]);
    }
    out.truncate(len);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::DetRng;

    #[test]
    fn field_ops_sane() {
        assert_eq!(add(P - 1, 1), 0);
        assert_eq!(sub(0, 1), P - 1);
        assert_eq!(mul(P - 1, P - 1), 1); // (-1)^2
        for a in [1u64, 2, 12345, P - 2] {
            assert_eq!(mul(a, inv(a)), 1, "a={a}");
        }
    }

    #[test]
    fn split_reconstruct_roundtrip() {
        let mut rng = DetRng::from_seed(1).as_fill_fn();
        for (t, n) in [(1usize, 1usize), (2, 3), (3, 5), (5, 5), (4, 10)] {
            let secret = 0x0123_4567_89ab_cdefu64 % P;
            let shares = split(secret, t, n, &mut rng);
            assert_eq!(shares.len(), n);
            // exactly t shares suffice
            assert_eq!(reconstruct(&shares[..t]), secret, "t={t} n={n}");
            // any t-subset suffices (take the last t)
            assert_eq!(reconstruct(&shares[n - t..]), secret);
            // all shares also work
            assert_eq!(reconstruct(&shares), secret);
        }
    }

    #[test]
    fn fewer_than_t_shares_do_not_reconstruct() {
        let mut rng = DetRng::from_seed(2).as_fill_fn();
        let secret = 42u64;
        let shares = split(secret, 3, 5, &mut rng);
        // 2 shares interpolate to something else (whp)
        let wrong = reconstruct(&shares[..2]);
        assert_ne!(wrong, secret);
    }

    #[test]
    fn shares_leak_nothing_statistically_coarse() {
        // share y-values of two different secrets should not be equal
        let mut rng_a = DetRng::from_seed(3).as_fill_fn();
        let mut rng_b = DetRng::from_seed(3).as_fill_fn(); // same coin flips!
        let sa = split(1, 2, 3, &mut rng_a);
        let sb = split(2, 2, 3, &mut rng_b);
        // same randomness, different secret → different shares
        assert_ne!(sa, sb);
    }

    #[test]
    fn bytes_roundtrip() {
        let mut rng = DetRng::from_seed(4).as_fill_fn();
        let secret: Vec<u8> = (0..32u8).collect(); // e.g. an X25519 seed
        let parties = split_bytes(&secret, 3, 5, &mut rng);
        assert_eq!(parties.len(), 5);
        let rec = reconstruct_bytes(&parties[1..4], secret.len());
        assert_eq!(rec, secret);
    }

    #[test]
    fn exactly_t_shares_reconstruct_any_subset() {
        // every size-t subset of the n shares reconstructs; this is the
        // exact guarantee dropout recovery leans on when it takes the
        // first t surrendered bundles in source-id order
        let mut rng = DetRng::from_seed(21).as_fill_fn();
        let (t, n) = (3usize, 5usize);
        let secret = 0x00ab_cdefu64;
        let shares = split(secret, t, n, &mut rng);
        for a in 0..n {
            for b in (a + 1)..n {
                for c in (b + 1)..n {
                    let subset = [shares[a], shares[b], shares[c]];
                    assert_eq!(reconstruct(&subset), secret, "subset ({a},{b},{c})");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "duplicate share x")]
    fn duplicate_x_coordinates_rejected() {
        let mut rng = DetRng::from_seed(22).as_fill_fn();
        let shares = split(99, 2, 3, &mut rng);
        let dup = [shares[0], shares[0]];
        let _ = reconstruct(&dup);
    }

    #[test]
    fn corrupted_share_yields_wrong_secret_not_crash() {
        // a flipped bit in any single share of a t-sized set perturbs
        // the interpolation: reconstruction succeeds but the output is
        // wrong (detectable upstream via the seed commitment)
        let mut rng = DetRng::from_seed(23).as_fill_fn();
        let secret = 0x0123_4567u64;
        let shares = split(secret, 3, 5, &mut rng);
        for victim in 0..3 {
            let mut bad = [shares[0], shares[1], shares[2]];
            bad[victim].y ^= 1;
            assert_ne!(reconstruct(&bad), secret, "corrupting share {victim}");
        }
    }

    #[test]
    #[should_panic(expected = "invalid threshold")]
    fn threshold_above_n_rejected_at_split() {
        let mut rng = DetRng::from_seed(24).as_fill_fn();
        let _ = split(1, 4, 3, &mut rng);
    }

    #[test]
    #[should_panic(expected = "invalid threshold")]
    fn zero_threshold_rejected_at_split() {
        let mut rng = DetRng::from_seed(25).as_fill_fn();
        let _ = split(1, 0, 3, &mut rng);
    }

    #[test]
    fn randomized_roundtrip_many() {
        let mut seed_rng = DetRng::from_seed(5);
        for _ in 0..50 {
            let secret = seed_rng.next_u64() % P;
            let n = seed_rng.next_range(1, 9) as usize;
            let t = seed_rng.next_range(1, n as u64 + 1) as usize;
            let mut rng = DetRng::from_seed(seed_rng.next_u64()).as_fill_fn();
            let shares = split(secret, t, n, &mut rng);
            assert_eq!(reconstruct(&shares[..t]), secret, "t={t} n={n}");
        }
    }
}
