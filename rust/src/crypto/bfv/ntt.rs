//! Number-theoretic transform over ℤ_q for negacyclic polynomial
//! multiplication in R_q = ℤ_q[x]/(xⁿ+1), from scratch.
//!
//! Forward/inverse NTT with ψ-premultiplication (ψ a primitive 2n-th
//! root of unity), giving O(n log n) negacyclic convolution — the same
//! core trick Microsoft SEAL uses.

/// Modular multiplication in u64 via u128 widening.
#[inline(always)]
pub fn mulmod(a: u64, b: u64, q: u64) -> u64 {
    ((a as u128 * b as u128) % q as u128) as u64
}

#[inline(always)]
pub fn addmod(a: u64, b: u64, q: u64) -> u64 {
    let s = a + b;
    if s >= q {
        s - q
    } else {
        s
    }
}

#[inline(always)]
pub fn submod(a: u64, b: u64, q: u64) -> u64 {
    if a >= b {
        a - b
    } else {
        a + q - b
    }
}

pub fn powmod(mut base: u64, mut exp: u64, q: u64) -> u64 {
    let mut acc = 1u64;
    base %= q;
    while exp > 0 {
        if exp & 1 == 1 {
            acc = mulmod(acc, base, q);
        }
        base = mulmod(base, base, q);
        exp >>= 1;
    }
    acc
}

pub fn invmod(a: u64, q: u64) -> u64 {
    powmod(a, q - 2, q) // q prime
}

/// Deterministic Miller–Rabin for u64 (complete witness set).
pub fn is_prime_u64(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    for p in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        if n == p {
            return true;
        }
        if n % p == 0 {
            return false;
        }
    }
    let mut d = n - 1;
    let mut s = 0;
    while d % 2 == 0 {
        d /= 2;
        s += 1;
    }
    'witness: for a in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        let mut x = powmod(a, d, n);
        if x == 1 || x == n - 1 {
            continue;
        }
        for _ in 0..s - 1 {
            x = mulmod(x, x, n);
            if x == n - 1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

/// Find the largest prime q < 2⁶¹ with q ≡ 1 (mod 2n).
pub fn find_ntt_prime(two_n: u64) -> u64 {
    let mut q = (1u64 << 61) - ((1u64 << 61) % two_n) + 1;
    loop {
        if q < (1 << 60) {
            panic!("no NTT prime found");
        }
        if is_prime_u64(q) {
            return q;
        }
        q -= two_n;
    }
}

/// NTT context for ring dimension n (power of two) and prime q ≡ 1 mod 2n.
pub struct NttContext {
    pub n: usize,
    pub q: u64,
    psi_pows: Vec<u64>,     // ψ^i for i in 0..n
    psi_inv_pows: Vec<u64>, // ψ^{-i}
    omega_pows: Vec<u64>,   // ω^i, ω = ψ²
    omega_inv_pows: Vec<u64>,
    n_inv: u64,
}

impl NttContext {
    pub fn new(n: usize, q: u64) -> Self {
        assert!(n.is_power_of_two());
        assert_eq!((q - 1) % (2 * n as u64), 0, "q must be ≡ 1 mod 2n");
        // find ψ: primitive 2n-th root. Take x^((q-1)/2n); it's primitive iff ψ^n = -1.
        let exp = (q - 1) / (2 * n as u64);
        let mut x = 3u64;
        let psi = loop {
            let cand = powmod(x, exp, q);
            if powmod(cand, n as u64, q) == q - 1 {
                break cand;
            }
            x += 1;
            assert!(x < 10_000, "no primitive root found");
        };
        let psi_inv = invmod(psi, q);
        let omega = mulmod(psi, psi, q);
        let omega_inv = invmod(omega, q);
        let mut psi_pows = Vec::with_capacity(n);
        let mut psi_inv_pows = Vec::with_capacity(n);
        let mut omega_pows = Vec::with_capacity(n);
        let mut omega_inv_pows = Vec::with_capacity(n);
        let (mut a, mut b, mut c, mut d) = (1u64, 1u64, 1u64, 1u64);
        for _ in 0..n {
            psi_pows.push(a);
            psi_inv_pows.push(b);
            omega_pows.push(c);
            omega_inv_pows.push(d);
            a = mulmod(a, psi, q);
            b = mulmod(b, psi_inv, q);
            c = mulmod(c, omega, q);
            d = mulmod(d, omega_inv, q);
        }
        let n_inv = invmod(n as u64, q);
        NttContext { n, q, psi_pows, psi_inv_pows, omega_pows, omega_inv_pows, n_inv }
    }

    fn bit_reverse(a: &mut [u64]) {
        let n = a.len();
        let mut j = 0usize;
        for i in 1..n {
            let mut bit = n >> 1;
            while j & bit != 0 {
                j ^= bit;
                bit >>= 1;
            }
            j |= bit;
            if i < j {
                a.swap(i, j);
            }
        }
    }

    fn ntt_in_place(&self, a: &mut [u64], pows: &[u64]) {
        let n = self.n;
        let q = self.q;
        Self::bit_reverse(a);
        let mut len = 2;
        while len <= n {
            let step = n / len;
            for start in (0..n).step_by(len) {
                for k in 0..len / 2 {
                    let w = pows[k * step];
                    let u = a[start + k];
                    let v = mulmod(a[start + k + len / 2], w, q);
                    a[start + k] = addmod(u, v, q);
                    a[start + k + len / 2] = submod(u, v, q);
                }
            }
            len <<= 1;
        }
    }

    /// Forward negacyclic NTT (ψ-premultiplied).
    pub fn forward(&self, a: &mut [u64]) {
        assert_eq!(a.len(), self.n);
        for i in 0..self.n {
            a[i] = mulmod(a[i], self.psi_pows[i], self.q);
        }
        self.ntt_in_place(a, &self.omega_pows.clone());
    }

    /// Inverse negacyclic NTT.
    pub fn inverse(&self, a: &mut [u64]) {
        assert_eq!(a.len(), self.n);
        self.ntt_in_place(a, &self.omega_inv_pows.clone());
        for i in 0..self.n {
            a[i] = mulmod(mulmod(a[i], self.n_inv, self.q), self.psi_inv_pows[i], self.q);
        }
    }

    /// Negacyclic polynomial product via NTT.
    pub fn multiply(&self, a: &[u64], b: &[u64]) -> Vec<u64> {
        let mut fa = a.to_vec();
        let mut fb = b.to_vec();
        self.forward(&mut fa);
        self.forward(&mut fb);
        for i in 0..self.n {
            fa[i] = mulmod(fa[i], fb[i], self.q);
        }
        self.inverse(&mut fa);
        fa
    }
}

/// Schoolbook negacyclic multiplication (test oracle, O(n²)).
pub fn negacyclic_mul_naive(a: &[u64], b: &[u64], q: u64) -> Vec<u64> {
    let n = a.len();
    let mut out = vec![0u64; n];
    for i in 0..n {
        for j in 0..n {
            let prod = mulmod(a[i], b[j], q);
            let k = i + j;
            if k < n {
                out[k] = addmod(out[k], prod, q);
            } else {
                out[k - n] = submod(out[k - n], prod, q); // x^n = -1
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::DetRng;

    #[test]
    fn prime_finder() {
        let q = find_ntt_prime(8192);
        assert!(is_prime_u64(q));
        assert_eq!((q - 1) % 8192, 0);
        assert!(q > (1 << 60));
    }

    #[test]
    fn known_primes() {
        assert!(is_prime_u64(2));
        assert!(is_prime_u64((1 << 61) - 1)); // Mersenne
        assert!(!is_prime_u64(1));
        assert!(!is_prime_u64((1u64 << 61) - 3)); // 2305843009213693949 = ?
        assert!(is_prime_u64(65537));
        assert!(!is_prime_u64(65536));
        // strong pseudoprime check: 3215031751 fools bases {2,3,5,7}? It's composite.
        assert!(!is_prime_u64(3215031751));
    }

    #[test]
    fn ntt_roundtrip() {
        for n in [8usize, 64, 1024] {
            let q = find_ntt_prime(2 * n as u64);
            let ctx = NttContext::new(n, q);
            let mut rng = DetRng::from_seed(n as u64);
            let orig: Vec<u64> = (0..n).map(|_| rng.next_u64() % q).collect();
            let mut a = orig.clone();
            ctx.forward(&mut a);
            assert_ne!(a, orig);
            ctx.inverse(&mut a);
            assert_eq!(a, orig, "n={n}");
        }
    }

    #[test]
    fn ntt_mul_matches_naive() {
        for n in [8usize, 32, 128] {
            let q = find_ntt_prime(2 * n as u64);
            let ctx = NttContext::new(n, q);
            let mut rng = DetRng::from_seed(7 + n as u64);
            let a: Vec<u64> = (0..n).map(|_| rng.next_u64() % q).collect();
            let b: Vec<u64> = (0..n).map(|_| rng.next_u64() % q).collect();
            assert_eq!(ctx.multiply(&a, &b), negacyclic_mul_naive(&a, &b, q), "n={n}");
        }
    }

    #[test]
    fn negacyclic_wraparound_sign() {
        // (x^{n-1}) * x = x^n = -1
        let n = 8;
        let q = find_ntt_prime(16);
        let ctx = NttContext::new(n, q);
        let mut a = vec![0u64; n];
        a[n - 1] = 1;
        let mut b = vec![0u64; n];
        b[1] = 1;
        let c = ctx.multiply(&a, &b);
        let mut want = vec![0u64; n];
        want[0] = q - 1; // -1
        assert_eq!(c, want);
    }

    #[test]
    fn linearity() {
        let n = 64;
        let q = find_ntt_prime(128);
        let ctx = NttContext::new(n, q);
        let mut rng = DetRng::from_seed(3);
        let a: Vec<u64> = (0..n).map(|_| rng.next_u64() % q).collect();
        let b: Vec<u64> = (0..n).map(|_| rng.next_u64() % q).collect();
        let c: Vec<u64> = (0..n).map(|_| rng.next_u64() % q).collect();
        // (a+b)*c == a*c + b*c
        let ab: Vec<u64> = (0..n).map(|i| addmod(a[i], b[i], q)).collect();
        let lhs = ctx.multiply(&ab, &c);
        let ac = ctx.multiply(&a, &c);
        let bc = ctx.multiply(&b, &c);
        let rhs: Vec<u64> = (0..n).map(|i| addmod(ac[i], bc[i], q)).collect();
        assert_eq!(lhs, rhs);
    }
}
