//! BFV leveled homomorphic encryption, from scratch — the
//! Microsoft-SEAL comparator of the paper's Figure-2 ablation.
//!
//! Single-modulus RLWE BFV over R_q = ℤ_q[x]/(xⁿ+1):
//! * keygen: ternary secret `s`, public key `(b, a)` with
//!   `b = −(a·s + e)`,
//! * `Enc(m) = (b·u + e₁ + Δ·m, a·u + e₂)` with Δ = ⌊q/t⌋,
//! * `Dec(c) = ⌈t·(c₀ + c₁·s)/q⌋ mod t`,
//! * homomorphic ct+ct addition and ct×plaintext multiplication — the
//!   two operations the encrypted dot-product workload needs.
//!
//! The Figure-2 workload encrypts scalars as degree-0 plaintexts
//! (mirroring the paper's un-batched SEAL-Python loops) but the scheme
//! itself is full-ring, and [`Bfv::dot_packed`] shows the
//! coefficient-packing optimization SEAL users would apply.

pub mod ntt;

use ntt::{addmod, mulmod, submod, NttContext};

/// BFV parameter set.
pub struct BfvParams {
    /// Ring dimension (power of two).
    pub n: usize,
    /// Ciphertext modulus (NTT-friendly prime < 2⁶¹).
    pub q: u64,
    /// Plaintext modulus.
    pub t: u64,
    /// Δ = ⌊q/t⌋.
    pub delta: u64,
}

impl BfvParams {
    /// SEAL-like defaults: n = 4096, 61-bit q, t = 2³².
    pub fn default_4096() -> Self {
        Self::new(4096, 1 << 32)
    }

    /// Smaller ring for tests.
    pub fn new(n: usize, t: u64) -> Self {
        let q = ntt::find_ntt_prime(2 * n as u64);
        BfvParams { n, q, t, delta: q / t }
    }
}

/// A plaintext polynomial (coefficients mod t).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Plaintext(pub Vec<u64>);

/// A ciphertext pair (c0, c1) ∈ R_q².
#[derive(Clone, Debug)]
pub struct BfvCiphertext {
    pub c0: Vec<u64>,
    pub c1: Vec<u64>,
}

/// The BFV context: parameters + NTT tables + keys.
pub struct Bfv {
    pub params: BfvParams,
    ntt: NttContext,
    secret: Vec<u64>,  // ternary in {q-1, 0, 1}
    pk_b: Vec<u64>,
    pk_a: Vec<u64>,
}

fn sample_ternary(n: usize, q: u64, rng: &mut dyn FnMut(&mut [u8])) -> Vec<u64> {
    let mut buf = vec![0u8; n];
    rng(&mut buf);
    buf.iter()
        .map(|&b| match b % 3 {
            0 => 0u64,
            1 => 1u64,
            _ => q - 1, // −1
        })
        .collect()
}

/// Centered binomial error, σ ≈ 3.2 (η = 21 paired bits).
fn sample_error(n: usize, q: u64, rng: &mut dyn FnMut(&mut [u8])) -> Vec<u64> {
    let mut out = Vec::with_capacity(n);
    let mut buf = vec![0u8; n * 6]; // 48 bits per coefficient: 21+21 used
    rng(&mut buf);
    for i in 0..n {
        let bits = u64::from_le_bytes({
            let mut b = [0u8; 8];
            b[..6].copy_from_slice(&buf[6 * i..6 * i + 6]);
            b
        });
        let a = (bits & ((1 << 21) - 1)).count_ones() as i64;
        let b = ((bits >> 21) & ((1 << 21) - 1)).count_ones() as i64;
        let e = a - b;
        out.push(if e >= 0 { e as u64 } else { q - (-e) as u64 });
    }
    out
}

fn sample_uniform(n: usize, q: u64, rng: &mut dyn FnMut(&mut [u8])) -> Vec<u64> {
    let mut out = Vec::with_capacity(n);
    let mut buf = vec![0u8; n * 8];
    rng(&mut buf);
    for i in 0..n {
        let v = u64::from_le_bytes(buf[8 * i..8 * i + 8].try_into().unwrap());
        out.push(v % q); // negligible bias for q near 2^61
    }
    out
}

impl Bfv {
    /// Generate keys.
    pub fn keygen(params: BfvParams, rng: &mut dyn FnMut(&mut [u8])) -> Self {
        let ntt = NttContext::new(params.n, params.q);
        let q = params.q;
        let n = params.n;
        let secret = sample_ternary(n, q, rng);
        let pk_a = sample_uniform(n, q, rng);
        let e = sample_error(n, q, rng);
        // b = -(a*s + e)
        let as_ = ntt.multiply(&pk_a, &secret);
        let pk_b: Vec<u64> = (0..n).map(|i| submod(0, addmod(as_[i], e[i], q), q)).collect();
        Bfv { params, ntt, secret, pk_b, pk_a }
    }

    /// Encode a signed scalar as a degree-0 plaintext (mod t).
    pub fn encode_scalar(&self, v: i64) -> Plaintext {
        let t = self.params.t;
        let mut poly = vec![0u64; self.params.n];
        poly[0] = if v >= 0 { (v as u64) % t } else { t - ((-v) as u64 % t) };
        Plaintext(poly)
    }

    /// Decode coefficient 0 as a signed scalar.
    pub fn decode_scalar(&self, pt: &Plaintext) -> i64 {
        let t = self.params.t;
        let v = pt.0[0] % t;
        if v > t / 2 {
            -((t - v) as i64)
        } else {
            v as i64
        }
    }

    /// Encode a signed vector into polynomial coefficients (packing).
    pub fn encode_coeffs(&self, vs: &[i64]) -> Plaintext {
        assert!(vs.len() <= self.params.n);
        let t = self.params.t;
        let mut poly = vec![0u64; self.params.n];
        for (i, &v) in vs.iter().enumerate() {
            poly[i] = if v >= 0 { (v as u64) % t } else { t - ((-v) as u64 % t) };
        }
        Plaintext(poly)
    }

    pub fn encrypt(&self, pt: &Plaintext, rng: &mut dyn FnMut(&mut [u8])) -> BfvCiphertext {
        let q = self.params.q;
        let n = self.params.n;
        let u = sample_ternary(n, q, rng);
        let e1 = sample_error(n, q, rng);
        let e2 = sample_error(n, q, rng);
        let bu = self.ntt.multiply(&self.pk_b, &u);
        let au = self.ntt.multiply(&self.pk_a, &u);
        // SEAL-style exact scaling ⌈m·q/t⌋ (plain Δ=⌊q/t⌋ injects an
        // m·(q mod t)/q rounding error that breaks large plaintexts)
        let t = self.params.t;
        let scale = |m: u64| -> u64 {
            (((m % t) as u128 * q as u128 + (t as u128) / 2) / t as u128) as u64 % q
        };
        let c0: Vec<u64> = (0..n)
            .map(|i| addmod(addmod(bu[i], e1[i], q), scale(pt.0[i]), q))
            .collect();
        let c1: Vec<u64> = (0..n).map(|i| addmod(au[i], e2[i], q)).collect();
        BfvCiphertext { c0, c1 }
    }

    pub fn decrypt(&self, ct: &BfvCiphertext) -> Plaintext {
        let q = self.params.q;
        let t = self.params.t;
        let n = self.params.n;
        let c1s = self.ntt.multiply(&ct.c1, &self.secret);
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let v = addmod(ct.c0[i], c1s[i], q);
            // m = round(t * v / q) mod t
            let m = (((v as u128) * (t as u128) + (q as u128) / 2) / (q as u128)) as u64 % t;
            out.push(m);
        }
        Plaintext(out)
    }

    /// Homomorphic ciphertext addition.
    pub fn add(&self, a: &BfvCiphertext, b: &BfvCiphertext) -> BfvCiphertext {
        let q = self.params.q;
        BfvCiphertext {
            c0: a.c0.iter().zip(&b.c0).map(|(&x, &y)| addmod(x, y, q)).collect(),
            c1: a.c1.iter().zip(&b.c1).map(|(&x, &y)| addmod(x, y, q)).collect(),
        }
    }

    /// Homomorphic ct × plaintext multiplication. Plaintext coefficients
    /// in [0, t) are lifted to *signed* representatives mod q — treating
    /// t−|w| as a literal (≈2³²) multiplier would blow up the noise.
    pub fn mul_plain(&self, a: &BfvCiphertext, pt: &Plaintext) -> BfvCiphertext {
        let t = self.params.t;
        let q = self.params.q;
        let lifted: Vec<u64> = pt
            .0
            .iter()
            .map(|&c| {
                let c = c % t;
                if c > t / 2 {
                    q - (t - c)
                } else {
                    c
                }
            })
            .collect();
        BfvCiphertext {
            c0: self.ntt.multiply(&a.c0, &lifted),
            c1: self.ntt.multiply(&a.c1, &lifted),
        }
    }

    /// Scalar ct × k (degree-0 fast path: coefficient-wise scaling).
    pub fn mul_scalar(&self, a: &BfvCiphertext, k: i64) -> BfvCiphertext {
        let q = self.params.q;
        let ku = if k >= 0 { (k as u64) % q } else { q - ((-k) as u64 % q) };
        BfvCiphertext {
            c0: a.c0.iter().map(|&x| mulmod(x, ku, q)).collect(),
            c1: a.c1.iter().map(|&x| mulmod(x, ku, q)).collect(),
        }
    }

    /// Encrypted dot product, naive per-element layout (one ciphertext
    /// per scalar) — this is what the paper benchmarks against.
    pub fn dot_naive(&self, enc_x: &[BfvCiphertext], w: &[i64]) -> BfvCiphertext {
        assert_eq!(enc_x.len(), w.len());
        let mut acc = self.mul_scalar(&enc_x[0], w[0]);
        for i in 1..enc_x.len() {
            acc = self.add(&acc, &self.mul_scalar(&enc_x[i], w[i]));
        }
        acc
    }

    /// Encrypted dot product with coefficient packing: x packed as
    /// Σ xᵢ·xⁱ, w packed reversed; the product's coefficient (d−1)
    /// equals the dot product. One ciphertext per *vector*.
    pub fn dot_packed(&self, enc_x: &BfvCiphertext, w: &[i64], d: usize) -> (BfvCiphertext, usize) {
        // w_poly = Σ w_{d-1-j} x^j so coeff d-1 of product = Σ x_i w_i
        let mut wrev: Vec<i64> = vec![0; d];
        for j in 0..d {
            wrev[j] = w[d - 1 - j];
        }
        let pt = self.encode_coeffs(&wrev);
        (self.mul_plain(enc_x, &pt), d - 1)
    }

    /// Decode a signed value from a specific coefficient.
    pub fn decode_coeff(&self, pt: &Plaintext, idx: usize) -> i64 {
        let t = self.params.t;
        let v = pt.0[idx] % t;
        if v > t / 2 {
            -((t - v) as i64)
        } else {
            v as i64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::DetRng;

    fn ctx(n: usize) -> Bfv {
        let mut rng = DetRng::from_seed(n as u64 + 1).as_fill_fn();
        Bfv::keygen(BfvParams::new(n, 1 << 32), &mut rng)
    }

    #[test]
    fn encrypt_decrypt_scalar() {
        let bfv = ctx(256);
        let mut rng = DetRng::from_seed(2).as_fill_fn();
        for v in [0i64, 1, -1, 4096, -99999, (1 << 30), -(1 << 30)] {
            let ct = bfv.encrypt(&bfv.encode_scalar(v), &mut rng);
            let pt = bfv.decrypt(&ct);
            assert_eq!(bfv.decode_scalar(&pt), v, "v={v}");
        }
    }

    #[test]
    fn homomorphic_add() {
        let bfv = ctx(256);
        let mut rng = DetRng::from_seed(3).as_fill_fn();
        let a = bfv.encrypt(&bfv.encode_scalar(1234), &mut rng);
        let b = bfv.encrypt(&bfv.encode_scalar(-234), &mut rng);
        let c = bfv.add(&a, &b);
        assert_eq!(bfv.decode_scalar(&bfv.decrypt(&c)), 1000);
    }

    #[test]
    fn scalar_mul() {
        let bfv = ctx(256);
        let mut rng = DetRng::from_seed(4).as_fill_fn();
        let a = bfv.encrypt(&bfv.encode_scalar(37), &mut rng);
        assert_eq!(bfv.decode_scalar(&bfv.decrypt(&bfv.mul_scalar(&a, 100))), 3700);
        assert_eq!(bfv.decode_scalar(&bfv.decrypt(&bfv.mul_scalar(&a, -3))), -111);
    }

    #[test]
    fn dot_naive_matches_plain() {
        let bfv = ctx(256);
        let mut rng = DetRng::from_seed(5).as_fill_fn();
        let x = [3i64, -1, 4, 1, -5, 9, 2, -6];
        let w = [2i64, 7, -1, 8, 2, -8, 1, 8];
        let enc: Vec<BfvCiphertext> =
            x.iter().map(|&v| bfv.encrypt(&bfv.encode_scalar(v), &mut rng)).collect();
        let ct = bfv.dot_naive(&enc, &w);
        let want: i64 = x.iter().zip(&w).map(|(a, b)| a * b).sum();
        assert_eq!(bfv.decode_scalar(&bfv.decrypt(&ct)), want);
    }

    #[test]
    fn dot_packed_matches_plain() {
        let bfv = ctx(256);
        let mut rng = DetRng::from_seed(6).as_fill_fn();
        let x = [31i64, -17, 42, 11, -53, 97, 23, -61];
        let w = [12i64, 75, -13, 85, 20, -83, 17, 86];
        let enc_x = bfv.encrypt(&bfv.encode_coeffs(&x), &mut rng);
        let (ct, idx) = bfv.dot_packed(&enc_x, &w, x.len());
        let want: i64 = x.iter().zip(&w).map(|(a, b)| a * b).sum();
        assert_eq!(bfv.decode_coeff(&bfv.decrypt(&ct), idx), want);
    }

    #[test]
    fn fixed_point_dot_survives_noise() {
        // the ablation's actual workload shape: scale-2^12 fixed point,
        // 8-element dot products
        let bfv = ctx(512);
        let mut rng = DetRng::from_seed(7).as_fill_fn();
        let scale = 1i64 << 12;
        let xf = [0.5f64, -0.25, 1.5, 0.125, -2.0, 0.75, 0.3, -0.6];
        let wf = [1.0f64, -1.5, 0.5, 2.0, 0.25, -0.125, 0.8, 0.4];
        let x: Vec<i64> = xf.iter().map(|v| (v * scale as f64) as i64).collect();
        let w: Vec<i64> = wf.iter().map(|v| (v * scale as f64) as i64).collect();
        let enc: Vec<BfvCiphertext> =
            x.iter().map(|&v| bfv.encrypt(&bfv.encode_scalar(v), &mut rng)).collect();
        let ct = bfv.dot_naive(&enc, &w);
        let got = bfv.decode_scalar(&bfv.decrypt(&ct));
        let want: i64 = x.iter().zip(&w).map(|(a, b)| a * b).sum();
        assert_eq!(got, want);
        // and the decoded float is close to the real dot product
        let approx = got as f64 / (scale as f64 * scale as f64);
        let real: f64 = xf.iter().zip(&wf).map(|(a, b)| a * b).sum();
        assert!((approx - real).abs() < 1e-3, "approx={approx} real={real}");
    }

    #[test]
    fn default_params_shape() {
        let p = BfvParams::default_4096();
        assert_eq!(p.n, 4096);
        assert!(ntt::is_prime_u64(p.q));
        assert_eq!((p.q - 1) % 8192, 0);
        assert!(p.delta > 1 << 28);
    }
}
