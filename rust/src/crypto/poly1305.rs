//! Poly1305 one-time authenticator (RFC 8439), from scratch.
//!
//! 26-bit-limb implementation (poly1305-donna style) over the prime
//! 2¹³⁰ − 5.

/// Poly1305 incremental MAC.
pub struct Poly1305 {
    r: [u64; 5],
    s: [u64; 5], // r[i] * 5 for i>=1, used in the reduction
    pad: [u32; 4],
    h: [u64; 5],
    buf: [u8; 16],
    buf_len: usize,
}

#[inline]
fn le32(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

impl Poly1305 {
    pub fn new(key: &[u8; 32]) -> Self {
        let r0 = (le32(&key[0..4]) & 0x3ffffff) as u64;
        let r1 = ((le32(&key[3..7]) >> 2) & 0x3ffff03) as u64;
        let r2 = ((le32(&key[6..10]) >> 4) & 0x3ffc0ff) as u64;
        let r3 = ((le32(&key[9..13]) >> 6) & 0x3f03fff) as u64;
        let r4 = ((le32(&key[12..16]) >> 8) & 0x00fffff) as u64;
        Poly1305 {
            r: [r0, r1, r2, r3, r4],
            s: [0, r1 * 5, r2 * 5, r3 * 5, r4 * 5],
            pad: [le32(&key[16..20]), le32(&key[20..24]), le32(&key[24..28]), le32(&key[28..32])],
            h: [0; 5],
            buf: [0u8; 16],
            buf_len: 0,
        }
    }

    fn block(&mut self, block: &[u8; 16], hibit: u64) {
        let [r0, r1, r2, r3, r4] = self.r;
        let [_, s1, s2, s3, s4] = self.s;

        // h += m
        let mut h0 = self.h[0] + ((le32(&block[0..4]) & 0x3ffffff) as u64);
        let mut h1 = self.h[1] + (((le32(&block[3..7]) >> 2) & 0x3ffffff) as u64);
        let mut h2 = self.h[2] + (((le32(&block[6..10]) >> 4) & 0x3ffffff) as u64);
        let mut h3 = self.h[3] + (((le32(&block[9..13]) >> 6) & 0x3ffffff) as u64);
        let mut h4 = self.h[4] + (((le32(&block[12..16]) >> 8) as u64) | (hibit << 24));

        // h *= r (mod 2^130 - 5), schoolbook with delayed carries
        let d0 = (h0 as u128) * (r0 as u128) + (h1 as u128) * (s4 as u128) + (h2 as u128) * (s3 as u128) + (h3 as u128) * (s2 as u128) + (h4 as u128) * (s1 as u128);
        let d1 = (h0 as u128) * (r1 as u128) + (h1 as u128) * (r0 as u128) + (h2 as u128) * (s4 as u128) + (h3 as u128) * (s3 as u128) + (h4 as u128) * (s2 as u128);
        let d2 = (h0 as u128) * (r2 as u128) + (h1 as u128) * (r1 as u128) + (h2 as u128) * (r0 as u128) + (h3 as u128) * (s4 as u128) + (h4 as u128) * (s3 as u128);
        let d3 = (h0 as u128) * (r3 as u128) + (h1 as u128) * (r2 as u128) + (h2 as u128) * (r1 as u128) + (h3 as u128) * (r0 as u128) + (h4 as u128) * (s4 as u128);
        let d4 = (h0 as u128) * (r4 as u128) + (h1 as u128) * (r3 as u128) + (h2 as u128) * (r2 as u128) + (h3 as u128) * (r1 as u128) + (h4 as u128) * (r0 as u128);

        let mut c: u64;
        c = (d0 >> 26) as u64;
        h0 = (d0 as u64) & 0x3ffffff;
        let d1 = d1 + c as u128;
        c = (d1 >> 26) as u64;
        h1 = (d1 as u64) & 0x3ffffff;
        let d2 = d2 + c as u128;
        c = (d2 >> 26) as u64;
        h2 = (d2 as u64) & 0x3ffffff;
        let d3 = d3 + c as u128;
        c = (d3 >> 26) as u64;
        h3 = (d3 as u64) & 0x3ffffff;
        let d4 = d4 + c as u128;
        c = (d4 >> 26) as u64;
        h4 = (d4 as u64) & 0x3ffffff;
        h0 += c * 5;
        c = h0 >> 26;
        h0 &= 0x3ffffff;
        h1 += c;

        self.h = [h0, h1, h2, h3, h4];
    }

    pub fn update(&mut self, mut data: &[u8]) {
        if self.buf_len > 0 {
            let take = (16 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 16 {
                let b = self.buf;
                self.block(&b, 1);
                self.buf_len = 0;
            }
        }
        while data.len() >= 16 {
            let mut b = [0u8; 16];
            b.copy_from_slice(&data[..16]);
            self.block(&b, 1);
            data = &data[16..];
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    pub fn finalize(mut self) -> [u8; 16] {
        if self.buf_len > 0 {
            // pad final partial block with 0x01 then zeros; hibit = 0
            let mut b = [0u8; 16];
            b[..self.buf_len].copy_from_slice(&self.buf[..self.buf_len]);
            b[self.buf_len] = 1;
            self.block(&b, 0);
        }
        let [mut h0, mut h1, mut h2, mut h3, mut h4] = self.h;

        // fully carry h
        let mut c;
        c = h1 >> 26;
        h1 &= 0x3ffffff;
        h2 += c;
        c = h2 >> 26;
        h2 &= 0x3ffffff;
        h3 += c;
        c = h3 >> 26;
        h3 &= 0x3ffffff;
        h4 += c;
        c = h4 >> 26;
        h4 &= 0x3ffffff;
        h0 += c * 5;
        c = h0 >> 26;
        h0 &= 0x3ffffff;
        h1 += c;

        // compute h + -p
        let mut g0 = h0.wrapping_add(5);
        c = g0 >> 26;
        g0 &= 0x3ffffff;
        let mut g1 = h1.wrapping_add(c);
        c = g1 >> 26;
        g1 &= 0x3ffffff;
        let mut g2 = h2.wrapping_add(c);
        c = g2 >> 26;
        g2 &= 0x3ffffff;
        let mut g3 = h3.wrapping_add(c);
        c = g3 >> 26;
        g3 &= 0x3ffffff;
        let g4 = h4.wrapping_add(c).wrapping_sub(1 << 26);

        // select h if h < p, else h - p
        let mask = (g4 >> 63).wrapping_sub(1); // all ones if h >= p
        let h0 = (h0 & !mask) | (g0 & mask);
        let h1 = (h1 & !mask) | (g1 & mask);
        let h2 = (h2 & !mask) | (g2 & mask);
        let h3 = (h3 & !mask) | (g3 & mask);
        let h4 = (h4 & !mask) | (g4 & mask);

        // h = h % 2^128, serialize to 4 u32 words
        let w0 = (h0 | (h1 << 26)) as u32;
        let w1 = ((h1 >> 6) | (h2 << 20)) as u32;
        let w2 = ((h2 >> 12) | (h3 << 14)) as u32;
        let w3 = ((h3 >> 18) | (h4 << 8)) as u32;

        // tag = (h + pad) % 2^128
        let mut f: u64;
        let mut out = [0u8; 16];
        f = (w0 as u64) + (self.pad[0] as u64);
        out[0..4].copy_from_slice(&(f as u32).to_le_bytes());
        f = (w1 as u64) + (self.pad[1] as u64) + (f >> 32);
        out[4..8].copy_from_slice(&(f as u32).to_le_bytes());
        f = (w2 as u64) + (self.pad[2] as u64) + (f >> 32);
        out[8..12].copy_from_slice(&(f as u32).to_le_bytes());
        f = (w3 as u64) + (self.pad[3] as u64) + (f >> 32);
        out[12..16].copy_from_slice(&(f as u32).to_le_bytes());
        out
    }
}

/// One-shot Poly1305 MAC.
pub fn poly1305(key: &[u8; 32], msg: &[u8]) -> [u8; 16] {
    let mut p = Poly1305::new(key);
    p.update(msg);
    p.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unhex(s: &str) -> Vec<u8> {
        let s: String = s.chars().filter(|c| !c.is_whitespace()).collect();
        (0..s.len()).step_by(2).map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap()).collect()
    }

    fn hex(b: &[u8]) -> String {
        b.iter().map(|x| format!("{x:02x}")).collect()
    }

    // RFC 8439 §2.5.2.
    #[test]
    fn rfc8439_vector() {
        let key: [u8; 32] = unhex("85d6be7857556d337f4452fe42d506a80103808afb0db2fd4abff6af4149f51b")
            .try_into()
            .unwrap();
        let tag = poly1305(&key, b"Cryptographic Forum Research Group");
        assert_eq!(hex(&tag), "a8061dc1305136c6c22b8baf0c0127a9");
    }

    // RFC 8439 Appendix A.3 test vector #1 (all-zero key and message).
    #[test]
    fn zero_key_zero_msg() {
        let key = [0u8; 32];
        let tag = poly1305(&key, &[0u8; 64]);
        assert_eq!(hex(&tag), "00000000000000000000000000000000");
    }

    // RFC 8439 A.3 #3: r = all-ones-ish clamped, tests the h >= p path.
    #[test]
    fn wrap_around_p() {
        // A.3 #5: R = 2 with F0.. message: 2^130-5 + 4 ≡ 4 mod p... use the documented vector:
        let mut key = [0u8; 32];
        key[0] = 0x02;
        let msg = unhex("ffffffffffffffffffffffffffffffff");
        // h = 2^128-1 + 2^128 (hibit) ; h*2 mod p then +pad(0)
        let tag = poly1305(&key, &msg);
        assert_eq!(hex(&tag), "03000000000000000000000000000000");
    }

    #[test]
    fn incremental_equals_oneshot() {
        let key: [u8; 32] = core::array::from_fn(|i| (i * 7 + 1) as u8);
        let data: Vec<u8> = (0..217u32).map(|i| (i % 256) as u8).collect();
        let oneshot = poly1305(&key, &data);
        for chunk in [1usize, 5, 15, 16, 17, 100] {
            let mut p = Poly1305::new(&key);
            for c in data.chunks(chunk) {
                p.update(c);
            }
            assert_eq!(p.finalize(), oneshot, "chunk size {chunk}");
        }
    }
}
