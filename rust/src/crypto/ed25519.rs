//! Ed25519 signatures (RFC 8032), from scratch.
//!
//! The paper (§5.1) notes the honest-but-curious protocol extends to
//! *malicious* settings via a PKI that authenticates senders
//! (Bonawitz et al., 2017). This module provides that PKI primitive:
//! every protocol message can be signed by its sender and verified
//! against a registered identity key.

use super::bigint::BigUint;
use super::field25519::{sqrt_m1, Fe};
use super::sha512::sha512;

/// Edwards curve point in extended homogeneous coordinates (X:Y:Z:T),
/// x = X/Z, y = Y/Z, xy = T/Z.
#[derive(Clone, Copy)]
pub struct Point {
    x: Fe,
    y: Fe,
    z: Fe,
    t: Fe,
}

fn fe_d() -> Fe {
    // d = -121665/121666 mod p
    let num = Fe::from_u64(121665).neg();
    let den = Fe::from_u64(121666);
    num.mul(den.invert())
}

fn basepoint() -> Point {
    // B = (x, 4/5) with x "positive" (even)
    let y = Fe::from_u64(4).mul(Fe::from_u64(5).invert());
    decompress_y(&y, false).expect("basepoint decompression")
}

impl Point {
    pub fn identity() -> Point {
        Point { x: Fe::ZERO, y: Fe::ONE, z: Fe::ONE, t: Fe::ZERO }
    }

    /// Point doubling (dbl-2008-hwcd, a = −1 twist).
    pub fn double(&self) -> Point {
        let a = self.x.square();
        let b = self.y.square();
        let c = self.z.square().mul_small(2);
        let d = a.neg(); // a = -1
        let e = self.x.add(self.y).square().sub(a).sub(b);
        let g = d.add(b);
        let f = g.sub(c);
        let h = d.sub(b);
        Point { x: e.mul(f), y: g.mul(h), z: f.mul(g), t: e.mul(h) }
    }

    /// Point addition (add-2008-hwcd-3, a = −1).
    pub fn add(&self, other: &Point) -> Point {
        let d2 = fe_d().mul_small(2);
        let a = self.y.sub(self.x).mul(other.y.sub(other.x));
        let b = self.y.add(self.x).mul(other.y.add(other.x));
        let c = self.t.mul(d2).mul(other.t);
        let dd = self.z.mul_small(2).mul(other.z);
        let e = b.sub(a);
        let f = dd.sub(c);
        let g = dd.add(c);
        let h = b.add(a);
        Point { x: e.mul(f), y: g.mul(h), z: f.mul(g), t: e.mul(h) }
    }

    /// Scalar multiplication (double-and-add over the scalar bits).
    pub fn scalar_mul(&self, scalar_le: &[u8; 32]) -> Point {
        let mut acc = Point::identity();
        for i in (0..256).rev() {
            acc = acc.double();
            if (scalar_le[i / 8] >> (i % 8)) & 1 == 1 {
                acc = acc.add(self);
            }
        }
        acc
    }

    /// Compress to 32 bytes: y with the sign of x in the top bit.
    pub fn compress(&self) -> [u8; 32] {
        let zinv = self.z.invert();
        let x = self.x.mul(zinv);
        let y = self.y.mul(zinv);
        let mut out = y.to_bytes();
        if x.is_negative() {
            out[31] |= 0x80;
        }
        out
    }

    /// Projective equality: x1·z2 == x2·z1 ∧ y1·z2 == y2·z1.
    pub fn equals(&self, other: &Point) -> bool {
        self.x.mul(other.z).equals(other.x.mul(self.z))
            && self.y.mul(other.z).equals(other.y.mul(self.z))
    }
}

/// Decompress from a y coordinate and an x-sign bit.
fn decompress_y(y: &Fe, x_negative: bool) -> Option<Point> {
    // x^2 = (y^2 - 1) / (d*y^2 + 1)
    let yy = y.square();
    let u = yy.sub(Fe::ONE);
    let v = fe_d().mul(yy).add(Fe::ONE);
    // candidate root: x = u * v^3 * (u * v^7)^((p-5)/8)
    let v3 = v.square().mul(v);
    let v7 = v3.square().mul(v);
    let mut x = u.mul(v3).mul(u.mul(v7).pow_p58());
    let vxx = v.mul(x.square());
    if !vxx.equals(u) {
        if vxx.equals(u.neg()) {
            x = x.mul(sqrt_m1());
        } else {
            return None;
        }
    }
    if x.is_zero() && x_negative {
        return None; // -0 is invalid
    }
    if x.is_negative() != x_negative {
        x = x.neg();
    }
    Some(Point { x, y: *y, z: Fe::ONE, t: x.mul(*y) })
}

/// Decompress a 32-byte encoded point.
pub fn decompress(bytes: &[u8; 32]) -> Option<Point> {
    let x_neg = bytes[31] & 0x80 != 0;
    let mut yb = *bytes;
    yb[31] &= 0x7f;
    let y = Fe::from_bytes(&yb);
    // reject non-canonical y
    if y.to_bytes() != yb {
        return None;
    }
    decompress_y(&y, x_neg)
}

fn group_order() -> BigUint {
    // L = 2^252 + 27742317777372353535851937790883648493
    BigUint::from_hex("1000000000000000000000000000000014def9dea2f79cd65812631a5cf5d3ed")
}

/// Reduce a little-endian byte string modulo the group order L,
/// returning 32 little-endian bytes.
fn reduce_mod_l(bytes_le: &[u8]) -> [u8; 32] {
    let mut be = bytes_le.to_vec();
    be.reverse();
    let v = BigUint::from_bytes_be(&be).rem(&group_order());
    let mut out_be = v.to_bytes_be();
    out_be.reverse(); // now little-endian
    let mut out = [0u8; 32];
    out[..out_be.len()].copy_from_slice(&out_be);
    out
}

/// (a·b + c) mod L over little-endian 32-byte scalars.
fn muladd_mod_l(a: &[u8; 32], b: &[u8; 32], c: &[u8; 32]) -> [u8; 32] {
    let le_to_big = |x: &[u8; 32]| {
        let mut be = x.to_vec();
        be.reverse();
        BigUint::from_bytes_be(&be)
    };
    let l = group_order();
    let v = le_to_big(a).mul(&le_to_big(b)).add(&le_to_big(c)).rem(&l);
    let mut out_be = v.to_bytes_be();
    out_be.reverse();
    let mut out = [0u8; 32];
    out[..out_be.len()].copy_from_slice(&out_be);
    out
}

/// An Ed25519 signing key (seed + cached expansion).
#[derive(Clone)]
pub struct SigningKey {
    seed: [u8; 32],
    scalar: [u8; 32],
    prefix: [u8; 32],
    public: [u8; 32],
}

/// An Ed25519 verifying (public) key.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct VerifyingKey(pub [u8; 32]);

/// A 64-byte signature.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Signature(pub [u8; 64]);

impl SigningKey {
    pub fn from_seed(seed: [u8; 32]) -> Self {
        let h = sha512(&seed);
        let mut scalar = [0u8; 32];
        scalar.copy_from_slice(&h[..32]);
        scalar[0] &= 248;
        scalar[31] &= 127;
        scalar[31] |= 64;
        let mut prefix = [0u8; 32];
        prefix.copy_from_slice(&h[32..]);
        let public = basepoint().scalar_mul(&scalar).compress();
        SigningKey { seed, scalar, prefix, public }
    }

    pub fn verifying_key(&self) -> VerifyingKey {
        VerifyingKey(self.public)
    }

    pub fn seed(&self) -> &[u8; 32] {
        &self.seed
    }

    pub fn sign(&self, msg: &[u8]) -> Signature {
        // r = H(prefix || msg) mod L
        let mut h = super::sha512::Sha512::new();
        h.update(&self.prefix);
        h.update(msg);
        let r = reduce_mod_l(&h.finalize());
        let r_point = basepoint().scalar_mul(&r).compress();
        // k = H(R || A || msg) mod L
        let mut h = super::sha512::Sha512::new();
        h.update(&r_point);
        h.update(&self.public);
        h.update(msg);
        let k = reduce_mod_l(&h.finalize());
        // s = (r + k·scalar) mod L
        let s = muladd_mod_l(&k, &self.scalar, &r);
        let mut sig = [0u8; 64];
        sig[..32].copy_from_slice(&r_point);
        sig[32..].copy_from_slice(&s);
        Signature(sig)
    }
}

impl VerifyingKey {
    /// Verify a signature: checks `s·B == R + k·A`.
    pub fn verify(&self, msg: &[u8], sig: &Signature) -> bool {
        let r_bytes: [u8; 32] = sig.0[..32].try_into().unwrap();
        let s_bytes: [u8; 32] = sig.0[32..].try_into().unwrap();
        // s must be canonical (< L), per RFC 8032 §5.1.7
        {
            let mut be = s_bytes.to_vec();
            be.reverse();
            let s = BigUint::from_bytes_be(&be);
            if s.cmp_big(&group_order()) != std::cmp::Ordering::Less {
                return false;
            }
        }
        let a = match decompress(&self.0) {
            Some(p) => p,
            None => return false,
        };
        let r = match decompress(&r_bytes) {
            Some(p) => p,
            None => return false,
        };
        let mut h = super::sha512::Sha512::new();
        h.update(&r_bytes);
        h.update(&self.0);
        h.update(msg);
        let k = reduce_mod_l(&h.finalize());
        let lhs = basepoint().scalar_mul(&s_bytes);
        let rhs = r.add(&a.scalar_mul(&k));
        lhs.equals(&rhs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unhex(s: &str) -> Vec<u8> {
        let s: String = s.chars().filter(|c| !c.is_whitespace()).collect();
        (0..s.len()).step_by(2).map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap()).collect()
    }

    // RFC 8032 §7.1 TEST 1 (empty message).
    #[test]
    fn rfc8032_test1() {
        let seed: [u8; 32] =
            unhex("9d61b19deffd5a60ba844af492ec2cc44449c5697b326919703bac031cae7f60").try_into().unwrap();
        let sk = SigningKey::from_seed(seed);
        assert_eq!(
            sk.verifying_key().0.to_vec(),
            unhex("d75a980182b10ab7d54bfed3c964073a0ee172f3daa62325af021a68f707511a")
        );
        let sig = sk.sign(b"");
        assert_eq!(
            sig.0.to_vec(),
            unhex(
                "e5564300c360ac729086e2cc806e828a84877f1eb8e5d974d873e06522490155\
                 5fb8821590a33bacc61e39701cf9b46bd25bf5f0595bbe24655141438e7a100b"
            )
        );
        assert!(sk.verifying_key().verify(b"", &sig));
    }

    // RFC 8032 §7.1 TEST 2 (one-byte message).
    #[test]
    fn rfc8032_test2() {
        let seed: [u8; 32] =
            unhex("4ccd089b28ff96da9db6c346ec114e0f5b8a319f35aba624da8cf6ed4fb8a6fb").try_into().unwrap();
        let sk = SigningKey::from_seed(seed);
        assert_eq!(
            sk.verifying_key().0.to_vec(),
            unhex("3d4017c3e843895a92b70aa74d1b7ebc9c982ccf2ec4968cc0cd55f12af4660c")
        );
        let msg = unhex("72");
        let sig = sk.sign(&msg);
        assert_eq!(
            sig.0.to_vec(),
            unhex(
                "92a009a9f0d4cab8720e820b5f642540a2b27b5416503f8fb3762223ebdb69da\
                 085ac1e43e15996e458f3613d0f11d8c387b2eaeb4302aeeb00d291612bb0c00"
            )
        );
        assert!(sk.verifying_key().verify(&msg, &sig));
    }

    // RFC 8032 §7.1 TEST 3 (two-byte message).
    #[test]
    fn rfc8032_test3() {
        let seed: [u8; 32] =
            unhex("c5aa8df43f9f837bedb7442f31dcb7b166d38535076f094b85ce3a2e0b4458f7").try_into().unwrap();
        let sk = SigningKey::from_seed(seed);
        let msg = unhex("af82");
        let sig = sk.sign(&msg);
        assert_eq!(
            sig.0.to_vec(),
            unhex(
                "6291d657deec24024827e69c3abe01a30ce548a284743a445e3680d7db5ac3ac\
                 18ff9b538d16f290ae67f760984dc6594a7c15e9716ed28dc027beceea1ec40a"
            )
        );
        assert!(sk.verifying_key().verify(&msg, &sig));
    }

    #[test]
    fn reject_tampered() {
        let sk = SigningKey::from_seed([7u8; 32]);
        let vk = sk.verifying_key();
        let sig = sk.sign(b"round=1 payload");
        assert!(vk.verify(b"round=1 payload", &sig));
        assert!(!vk.verify(b"round=2 payload", &sig));
        let mut bad = sig;
        bad.0[3] ^= 1;
        assert!(!vk.verify(b"round=1 payload", &bad));
        // wrong key
        let vk2 = SigningKey::from_seed([8u8; 32]).verifying_key();
        assert!(!vk2.verify(b"round=1 payload", &sig));
    }

    #[test]
    fn point_arithmetic_consistency() {
        let b = basepoint();
        // 2B via double == B + B
        assert!(b.double().equals(&b.add(&b)));
        // 3B = 2B + B == B + 2B
        let b2 = b.double();
        assert!(b2.add(&b).equals(&b.add(&b2)));
        // B + identity == B
        assert!(b.add(&Point::identity()).equals(&b));
        // L·B == identity
        let l = group_order();
        let mut le = l.to_bytes_be();
        le.reverse();
        let mut sc = [0u8; 32];
        sc[..le.len()].copy_from_slice(&le);
        assert!(b.scalar_mul(&sc).equals(&Point::identity()));
    }

    #[test]
    fn compress_decompress_roundtrip() {
        let b = basepoint();
        for k in 1u8..6 {
            let p = b.scalar_mul(&{
                let mut s = [0u8; 32];
                s[0] = k;
                s
            });
            let c = p.compress();
            let q = decompress(&c).expect("valid point");
            assert!(p.equals(&q));
        }
    }
}
