//! ChaCha20 stream cipher (RFC 8439), from scratch.
//!
//! Dual use in this system:
//! * the symmetric cipher under ChaCha20-Poly1305 AEAD for sample-ID
//!   encryption during mini-batch selection (§4.0.2), and
//! * the PRG for pairwise secure-aggregation masks (Eq. 3) via
//!   [`crate::crypto::prg`].
//!
//! Two cores share one test surface:
//! * the scalar block function [`ChaCha20::block_words`] — the
//!   reference semantics, and the whole path under `VFL_SIMD=off`, and
//! * a 4-block-parallel ("vertical") core — AVX2 on x86_64, NEON on
//!   aarch64, a lane-array portable form elsewhere — selected at
//!   runtime by [`super::simd::active_isa`]. Bulk keystream requests
//!   ([`ChaCha20::keystream_u64`], [`ChaCha20::apply_keystream`]) run
//!   aligned groups of four blocks through it and fall back to single
//!   scalar blocks for the tail.
//!
//! Bit-identity between the cores is a protocol invariant, not a nice-
//! to-have: pairwise masks expanded on different machines must cancel
//! word-for-word, so every core is asserted equal to the scalar block
//! function in the tests below (and the equivalence suites re-run the
//! whole protocol under `VFL_SIMD=off` in CI).

use super::simd;

/// u64 keystream words per single ChaCha20 block (64 bytes).
pub(crate) const BLOCK_WORDS_U64: usize = 8;

/// u64 keystream words per 4-block SIMD group.
pub(crate) const X4_WORDS_U64: usize = 32;

/// Keystream bytes per 4-block SIMD group.
const X4_BYTES: usize = 256;

/// The ChaCha20 block function state.
#[derive(Clone)]
pub struct ChaCha20 {
    key: [u32; 8],
    nonce: [u32; 3],
    counter: u32,
}

impl ChaCha20 {
    /// Create a cipher instance with a 256-bit key and 96-bit nonce,
    /// starting at block `counter`.
    pub fn new(key: &[u8; 32], nonce: &[u8; 12], counter: u32) -> Self {
        let mut k = [0u32; 8];
        for i in 0..8 {
            k[i] = u32::from_le_bytes([key[4 * i], key[4 * i + 1], key[4 * i + 2], key[4 * i + 3]]);
        }
        let mut n = [0u32; 3];
        for i in 0..3 {
            n[i] = u32::from_le_bytes([nonce[4 * i], nonce[4 * i + 1], nonce[4 * i + 2], nonce[4 * i + 3]]);
        }
        ChaCha20 { key: k, nonce: n, counter }
    }

    /// The 16 output words for block index `counter`. Fully unrolled
    /// with named locals (no array bounds checks on the hot path) —
    /// the scalar reference core every SIMD core is measured against.
    #[inline]
    pub fn block_words(&self, counter: u32) -> [u32; 16] {
        let (i0, i1, i2, i3) = (0x61707865u32, 0x3320646eu32, 0x79622d32u32, 0x6b206574u32);
        let [k0, k1, k2, k3, k4, k5, k6, k7] = self.key;
        let [n0, n1, n2] = self.nonce;
        let (mut x0, mut x1, mut x2, mut x3) = (i0, i1, i2, i3);
        let (mut x4, mut x5, mut x6, mut x7) = (k0, k1, k2, k3);
        let (mut x8, mut x9, mut x10, mut x11) = (k4, k5, k6, k7);
        let (mut x12, mut x13, mut x14, mut x15) = (counter, n0, n1, n2);

        macro_rules! qr {
            ($a:ident, $b:ident, $c:ident, $d:ident) => {
                $a = $a.wrapping_add($b);
                $d = ($d ^ $a).rotate_left(16);
                $c = $c.wrapping_add($d);
                $b = ($b ^ $c).rotate_left(12);
                $a = $a.wrapping_add($b);
                $d = ($d ^ $a).rotate_left(8);
                $c = $c.wrapping_add($d);
                $b = ($b ^ $c).rotate_left(7);
            };
        }
        for _ in 0..10 {
            qr!(x0, x4, x8, x12);
            qr!(x1, x5, x9, x13);
            qr!(x2, x6, x10, x14);
            qr!(x3, x7, x11, x15);
            qr!(x0, x5, x10, x15);
            qr!(x1, x6, x11, x12);
            qr!(x2, x7, x8, x13);
            qr!(x3, x4, x9, x14);
        }
        [
            x0.wrapping_add(i0), x1.wrapping_add(i1), x2.wrapping_add(i2), x3.wrapping_add(i3),
            x4.wrapping_add(k0), x5.wrapping_add(k1), x6.wrapping_add(k2), x7.wrapping_add(k3),
            x8.wrapping_add(k4), x9.wrapping_add(k5), x10.wrapping_add(k6), x11.wrapping_add(k7),
            x12.wrapping_add(counter), x13.wrapping_add(n0), x14.wrapping_add(n1), x15.wrapping_add(n2),
        ]
    }

    /// Produce the 64-byte keystream block for block index `counter`.
    pub fn block(&self, counter: u32) -> [u8; 64] {
        let words = self.block_words(counter);
        let mut out = [0u8; 64];
        for (i, w) in words.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&w.to_le_bytes());
        }
        out
    }

    /// The four keystream blocks `counter .. counter + 4`, lane-
    /// interleaved (word-major): `out[i*4 + l]` is output word `i` of
    /// block `counter + l`. Dispatches to the active SIMD ISA; the
    /// portable core keeps the identical layout, so the de-interleave
    /// steps below are shared — and tested — on every architecture.
    fn four_blocks(&self, counter: u32) -> [u32; 64] {
        match simd::active_isa() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: active_isa() returns Avx2 only after runtime
            // detection succeeded on this CPU.
            simd::SimdIsa::Avx2 => unsafe { avx2::four_blocks(&self.key, &self.nonce, counter) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: likewise, Neon only after runtime detection.
            simd::SimdIsa::Neon => unsafe { neon::four_blocks(&self.key, &self.nonce, counter) },
            _ => x4_blocks_portable(&self.key, &self.nonce, counter),
        }
    }

    /// De-interleave four blocks straight into u64 mask words. `out`
    /// must hold exactly [`X4_WORDS_U64`] words; it receives the same
    /// values as four consecutive [`Self::block_words`] calls packed
    /// low-word-first (the [`Self::keystream_u64`] layout).
    pub(crate) fn four_blocks_u64_into(&self, counter: u32, out: &mut [u64]) {
        assert_eq!(out.len(), X4_WORDS_U64);
        let st = self.four_blocks(counter);
        for l in 0..4 {
            for j in 0..BLOCK_WORDS_U64 {
                let lo = st[(2 * j) * 4 + l] as u64;
                let hi = st[(2 * j + 1) * 4 + l] as u64;
                out[l * BLOCK_WORDS_U64 + j] = lo | (hi << 32);
            }
        }
    }

    /// Panic if a keystream request of `blocks` 64-byte blocks from
    /// `self.counter` would run the 32-bit block counter past
    /// `u32::MAX`. The old behaviour was a silent `wrapping_add` —
    /// keystream reuse after 256 GiB, which for the mask PRG means
    /// masks stop cancelling and pairs of masked tensors leak their
    /// difference. Protocol-fatal, hence a documented panic rather
    /// than a recoverable error.
    fn check_block_span(&self, blocks: u64) {
        let avail = u64::from(u32::MAX) - u64::from(self.counter) + 1;
        assert!(
            blocks <= avail,
            "ChaCha20 keystream request of {blocks} blocks from counter {}: keystream would repeat",
            self.counter
        );
    }

    /// Fill a `u64` buffer with keystream words directly (the mask-PRG
    /// fast path: skips the byte-array round-trip). With a SIMD ISA
    /// active, aligned groups of four blocks (32 words) run through
    /// the 4-block core; single scalar blocks handle the tail and are
    /// the whole path under `VFL_SIMD=off`. Output is bit-identical
    /// either way (asserted in the tests below).
    pub fn keystream_u64(&self, out: &mut [u64]) {
        self.check_block_span(out.len().div_ceil(BLOCK_WORDS_U64) as u64);
        let mut counter = self.counter;
        let mut done = 0;
        if simd::active_isa() != simd::SimdIsa::Scalar {
            while out.len() - done >= X4_WORDS_U64 {
                self.four_blocks_u64_into(counter, &mut out[done..done + X4_WORDS_U64]);
                counter = counter.wrapping_add(4);
                done += X4_WORDS_U64;
            }
        }
        for chunk in out[done..].chunks_mut(BLOCK_WORDS_U64) {
            let w = self.block_words(counter);
            for (j, o) in chunk.iter_mut().enumerate() {
                *o = (w[2 * j] as u64) | ((w[2 * j + 1] as u64) << 32);
            }
            counter = counter.wrapping_add(1);
        }
    }

    /// XOR the keystream into `data` in place (encrypt == decrypt).
    /// Same grouped dispatch as [`Self::keystream_u64`]: 256-byte
    /// groups through the 4-block core, scalar blocks for the tail.
    pub fn apply_keystream(&self, data: &mut [u8]) {
        self.check_block_span(data.len().div_ceil(64) as u64);
        let mut counter = self.counter;
        let mut done = 0;
        if simd::active_isa() != simd::SimdIsa::Scalar {
            while data.len() - done >= X4_BYTES {
                let st = self.four_blocks(counter);
                let group = &mut data[done..done + X4_BYTES];
                for l in 0..4 {
                    for i in 0..16 {
                        let k = st[i * 4 + l].to_le_bytes();
                        let o = l * 64 + i * 4;
                        group[o] ^= k[0];
                        group[o + 1] ^= k[1];
                        group[o + 2] ^= k[2];
                        group[o + 3] ^= k[3];
                    }
                }
                counter = counter.wrapping_add(4);
                done += X4_BYTES;
            }
        }
        for chunk in data[done..].chunks_mut(64) {
            let ks = self.block(counter);
            for (b, k) in chunk.iter_mut().zip(ks.iter()) {
                *b ^= k;
            }
            counter = counter.wrapping_add(1);
        }
    }

    /// Fill `out` with raw keystream bytes (PRG mode).
    pub fn keystream(&self, out: &mut [u8]) {
        out.fill(0);
        self.apply_keystream(out);
    }
}

/// One-shot encryption (RFC 8439 §2.4): XOR `data` with the keystream
/// starting at block counter 1 (block 0 is reserved for the Poly1305
/// one-time key in the AEAD construction).
pub fn chacha20_xor(key: &[u8; 32], nonce: &[u8; 12], counter: u32, data: &mut [u8]) {
    ChaCha20::new(key, nonce, counter).apply_keystream(data);
}

// ---------------------------------------------------------------------------
// 4-block-parallel cores
// ---------------------------------------------------------------------------
//
// Vertical form: 16 lanes-of-4 registers, register i holding state
// word i for blocks counter..counter+4, so the 20 rounds run on all
// four blocks at once with zero shuffles. All cores emit the same
// word-major staging layout (`out[i*4 + l]` = word i of block
// counter+l); per-lane counters use RFC wrapping semantics — the
// *request-span* guard lives in the callers above.

#[inline(always)]
fn lane_add(a: [u32; 4], b: [u32; 4]) -> [u32; 4] {
    [
        a[0].wrapping_add(b[0]),
        a[1].wrapping_add(b[1]),
        a[2].wrapping_add(b[2]),
        a[3].wrapping_add(b[3]),
    ]
}

#[inline(always)]
fn lane_xor_rotl(a: [u32; 4], b: [u32; 4], r: u32) -> [u32; 4] {
    [
        (a[0] ^ b[0]).rotate_left(r),
        (a[1] ^ b[1]).rotate_left(r),
        (a[2] ^ b[2]).rotate_left(r),
        (a[3] ^ b[3]).rotate_left(r),
    ]
}

/// Portable lane-array form of the 4-block core: the fallback when no
/// vector ISA is detected, and the layout reference the AVX2/NEON
/// cores are asserted against on capable hardware.
fn x4_blocks_portable(key: &[u32; 8], nonce: &[u32; 3], counter: u32) -> [u32; 64] {
    let splat = |w: u32| [w; 4];
    let init: [[u32; 4]; 16] = [
        splat(0x61707865), splat(0x3320646e), splat(0x79622d32), splat(0x6b206574),
        splat(key[0]), splat(key[1]), splat(key[2]), splat(key[3]),
        splat(key[4]), splat(key[5]), splat(key[6]), splat(key[7]),
        [counter, counter.wrapping_add(1), counter.wrapping_add(2), counter.wrapping_add(3)],
        splat(nonce[0]), splat(nonce[1]), splat(nonce[2]),
    ];
    let mut x = init;
    macro_rules! qr {
        ($a:literal, $b:literal, $c:literal, $d:literal) => {
            x[$a] = lane_add(x[$a], x[$b]);
            x[$d] = lane_xor_rotl(x[$d], x[$a], 16);
            x[$c] = lane_add(x[$c], x[$d]);
            x[$b] = lane_xor_rotl(x[$b], x[$c], 12);
            x[$a] = lane_add(x[$a], x[$b]);
            x[$d] = lane_xor_rotl(x[$d], x[$a], 8);
            x[$c] = lane_add(x[$c], x[$d]);
            x[$b] = lane_xor_rotl(x[$b], x[$c], 7);
        };
    }
    for _ in 0..10 {
        qr!(0, 4, 8, 12);
        qr!(1, 5, 9, 13);
        qr!(2, 6, 10, 14);
        qr!(3, 7, 11, 15);
        qr!(0, 5, 10, 15);
        qr!(1, 6, 11, 12);
        qr!(2, 7, 8, 13);
        qr!(3, 4, 9, 14);
    }
    let mut out = [0u32; 64];
    for i in 0..16 {
        out[i * 4..i * 4 + 4].copy_from_slice(&lane_add(x[i], init[i]));
    }
    out
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    /// 4-block ChaCha20 core on 128-bit lanes. Gated on AVX2 (not bare
    /// SSE2) so the xor/shift/or rotate idiom compiles to efficient
    /// VEX forms.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime (the
    /// `simd::active_isa` probe) before calling.
    // vflint: scalar-ref = x4_blocks_portable
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn four_blocks(key: &[u32; 8], nonce: &[u32; 3], counter: u32) -> [u32; 64] {
        // SAFETY: every intrinsic below is AVX2/SSE2 register
        // arithmetic or unaligned access into the owned `out` array;
        // the caller guarantees the ISA is present.
        unsafe {
            macro_rules! splat {
                ($w:expr) => {
                    _mm_set1_epi32($w as i32)
                };
            }
            // rotate-left via paired literal shifts: `32 - N` as a shift
            // const would be a generic const expr (unstable on our 1.74
            // floor), so both counts are spelled out at each call site
            macro_rules! rotl {
                ($v:expr, $l:literal, $r:literal) => {{
                    let v = $v;
                    _mm_or_si128(_mm_slli_epi32::<$l>(v), _mm_srli_epi32::<$r>(v))
                }};
            }
            macro_rules! qr {
                ($a:literal, $b:literal, $c:literal, $d:literal) => {
                    x[$a] = _mm_add_epi32(x[$a], x[$b]);
                    x[$d] = rotl!(_mm_xor_si128(x[$d], x[$a]), 16, 16);
                    x[$c] = _mm_add_epi32(x[$c], x[$d]);
                    x[$b] = rotl!(_mm_xor_si128(x[$b], x[$c]), 12, 20);
                    x[$a] = _mm_add_epi32(x[$a], x[$b]);
                    x[$d] = rotl!(_mm_xor_si128(x[$d], x[$a]), 8, 24);
                    x[$c] = _mm_add_epi32(x[$c], x[$d]);
                    x[$b] = rotl!(_mm_xor_si128(x[$b], x[$c]), 7, 25);
                };
            }
            let init: [__m128i; 16] = [
                splat!(0x61707865u32), splat!(0x3320646eu32),
                splat!(0x79622d32u32), splat!(0x6b206574u32),
                splat!(key[0]), splat!(key[1]), splat!(key[2]), splat!(key[3]),
                splat!(key[4]), splat!(key[5]), splat!(key[6]), splat!(key[7]),
                // _mm_set_epi32 is high-to-low: lane 0 (block `counter`)
                // is the LAST argument
                _mm_set_epi32(
                    counter.wrapping_add(3) as i32,
                    counter.wrapping_add(2) as i32,
                    counter.wrapping_add(1) as i32,
                    counter as i32,
                ),
                splat!(nonce[0]), splat!(nonce[1]), splat!(nonce[2]),
            ];
            let mut x = init;
            for _ in 0..10 {
                qr!(0, 4, 8, 12);
                qr!(1, 5, 9, 13);
                qr!(2, 6, 10, 14);
                qr!(3, 7, 11, 15);
                qr!(0, 5, 10, 15);
                qr!(1, 6, 11, 12);
                qr!(2, 7, 8, 13);
                qr!(3, 4, 9, 14);
            }
            let mut out = [0u32; 64];
            for i in 0..16 {
                _mm_storeu_si128(
                    out.as_mut_ptr().add(i * 4) as *mut __m128i,
                    _mm_add_epi32(x[i], init[i]),
                );
            }
            out
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;

    /// 4-block ChaCha20 core on NEON 128-bit lanes.
    ///
    /// # Safety
    /// Caller must have verified NEON support at runtime (the
    /// `simd::active_isa` probe) before calling.
    // vflint: scalar-ref = x4_blocks_portable
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn four_blocks(key: &[u32; 8], nonce: &[u32; 3], counter: u32) -> [u32; 64] {
        // SAFETY: every intrinsic below is NEON register arithmetic or
        // unaligned access into the owned `ctr`/`out` arrays; the
        // caller guarantees the ISA is present.
        unsafe {
            macro_rules! splat {
                ($w:expr) => {
                    vdupq_n_u32($w)
                };
            }
            macro_rules! rotl {
                ($v:expr, $l:literal, $r:literal) => {{
                    let v = $v;
                    vorrq_u32(vshlq_n_u32::<$l>(v), vshrq_n_u32::<$r>(v))
                }};
            }
            macro_rules! qr {
                ($a:literal, $b:literal, $c:literal, $d:literal) => {
                    x[$a] = vaddq_u32(x[$a], x[$b]);
                    x[$d] = rotl!(veorq_u32(x[$d], x[$a]), 16, 16);
                    x[$c] = vaddq_u32(x[$c], x[$d]);
                    x[$b] = rotl!(veorq_u32(x[$b], x[$c]), 12, 20);
                    x[$a] = vaddq_u32(x[$a], x[$b]);
                    x[$d] = rotl!(veorq_u32(x[$d], x[$a]), 8, 24);
                    x[$c] = vaddq_u32(x[$c], x[$d]);
                    x[$b] = rotl!(veorq_u32(x[$b], x[$c]), 7, 25);
                };
            }
            // vld1q_u32 loads lane 0 from the lowest address
            let ctr = [
                counter,
                counter.wrapping_add(1),
                counter.wrapping_add(2),
                counter.wrapping_add(3),
            ];
            let init: [uint32x4_t; 16] = [
                splat!(0x61707865u32), splat!(0x3320646eu32),
                splat!(0x79622d32u32), splat!(0x6b206574u32),
                splat!(key[0]), splat!(key[1]), splat!(key[2]), splat!(key[3]),
                splat!(key[4]), splat!(key[5]), splat!(key[6]), splat!(key[7]),
                vld1q_u32(ctr.as_ptr()),
                splat!(nonce[0]), splat!(nonce[1]), splat!(nonce[2]),
            ];
            let mut x = init;
            for _ in 0..10 {
                qr!(0, 4, 8, 12);
                qr!(1, 5, 9, 13);
                qr!(2, 6, 10, 14);
                qr!(3, 7, 11, 15);
                qr!(0, 5, 10, 15);
                qr!(1, 6, 11, 12);
                qr!(2, 7, 8, 13);
                qr!(3, 4, 9, 14);
            }
            let mut out = [0u32; 64];
            for i in 0..16 {
                vst1q_u32(out.as_mut_ptr().add(i * 4), vaddq_u32(x[i], init[i]));
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unhex(s: &str) -> Vec<u8> {
        let s: String = s.chars().filter(|c| !c.is_whitespace()).collect();
        (0..s.len()).step_by(2).map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap()).collect()
    }

    // RFC 8439 §2.3.2 block function test vector.
    #[test]
    fn rfc8439_block() {
        let key: [u8; 32] = core::array::from_fn(|i| i as u8);
        let nonce: [u8; 12] = [0, 0, 0, 9, 0, 0, 0, 0x4a, 0, 0, 0, 0];
        let c = ChaCha20::new(&key, &nonce, 1);
        let block = c.block(1);
        let expected = unhex(
            "10f1e7e4d13b5915500fdd1fa32071c4c7d1f4c733c068030422aa9ac3d46c4e\
             d2826446079faa0914c2d705d98b02a2b5129cd1de164eb9cbd083e8a2503c4e",
        );
        assert_eq!(&block[..], &expected[..]);
    }

    // RFC 8439 §2.4.2 encryption test vector.
    #[test]
    fn rfc8439_encrypt() {
        let key: [u8; 32] = core::array::from_fn(|i| i as u8);
        let nonce: [u8; 12] = [0, 0, 0, 0, 0, 0, 0, 0x4a, 0, 0, 0, 0];
        let mut msg = b"Ladies and Gentlemen of the class of '99: If I could offer you only one tip for the future, sunscreen would be it.".to_vec();
        chacha20_xor(&key, &nonce, 1, &mut msg);
        let expected = unhex(
            "6e2e359a2568f98041ba0728dd0d6981e97e7aec1d4360c20a27afccfd9fae0b\
             f91b65c5524733ab8f593dabcd62b3571639d624e65152ab8f530c359f0861d8\
             07ca0dbf500d6a6156a38e088a22b65e52bc514d16ccf806818ce91ab7793736\
             5af90bbf74a35be6b40b8eedf2785e42874d",
        );
        assert_eq!(msg, expected);
    }

    #[test]
    fn roundtrip() {
        let key = [7u8; 32];
        let nonce = [3u8; 12];
        let plain: Vec<u8> = (0..300u32).map(|i| (i % 256) as u8).collect();
        let mut data = plain.clone();
        chacha20_xor(&key, &nonce, 1, &mut data);
        assert_ne!(data, plain);
        chacha20_xor(&key, &nonce, 1, &mut data);
        assert_eq!(data, plain);
    }

    #[test]
    fn keystream_matches_xor_of_zeros() {
        let key = [1u8; 32];
        let nonce = [2u8; 12];
        let c = ChaCha20::new(&key, &nonce, 0);
        let mut a = [0u8; 130];
        c.keystream(&mut a);
        let mut b = [0u8; 130];
        c.apply_keystream(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn keystream_u64_matches_byte_path() {
        let key: [u8; 32] = core::array::from_fn(|i| (i * 11 + 3) as u8);
        let nonce = [5u8; 12];
        let c = ChaCha20::new(&key, &nonce, 0);
        let mut bytes = [0u8; 200 * 8];
        c.keystream(&mut bytes);
        let want: Vec<u64> =
            bytes.chunks_exact(8).map(|ch| u64::from_le_bytes(ch.try_into().unwrap())).collect();
        let mut words = [0u64; 200];
        c.keystream_u64(&mut words);
        assert_eq!(&words[..], &want[..]);
    }

    #[test]
    fn counter_advances_across_chunks() {
        // applying to one 128-byte buffer == two 64-byte buffers with counters 0,1
        let key = [9u8; 32];
        let nonce = [4u8; 12];
        let mut whole = [0xabu8; 128];
        ChaCha20::new(&key, &nonce, 0).apply_keystream(&mut whole);
        let mut lo = [0xabu8; 64];
        let mut hi = [0xabu8; 64];
        ChaCha20::new(&key, &nonce, 0).apply_keystream(&mut lo);
        ChaCha20::new(&key, &nonce, 1).apply_keystream(&mut hi);
        assert_eq!(&whole[..64], &lo[..]);
        assert_eq!(&whole[64..], &hi[..]);
    }

    // -- SIMD core bit-identity ------------------------------------------

    #[test]
    fn portable_x4_matches_scalar_blocks() {
        // the lane-interleaved portable core must reproduce the scalar
        // block function exactly — including where the four per-lane
        // counters straddle u32::MAX (RFC wrapping semantics; the
        // request-span guard lives in keystream_u64, not here)
        let key: [u8; 32] = core::array::from_fn(|i| (i * 31 + 5) as u8);
        let nonce: [u8; 12] = core::array::from_fn(|i| (i * 17 + 1) as u8);
        let c = ChaCha20::new(&key, &nonce, 0);
        for counter in [0u32, 1, 7, 1000, u32::MAX - 3, u32::MAX - 1] {
            let st = x4_blocks_portable(&c.key, &c.nonce, counter);
            for l in 0..4u32 {
                let want = c.block_words(counter.wrapping_add(l));
                for (i, w) in want.iter().enumerate() {
                    assert_eq!(st[i * 4 + l as usize], *w, "counter={counter} lane={l} word={i}");
                }
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_x4_matches_portable() {
        // real gate on CI hardware regardless of VFL_SIMD: calls the
        // intrinsic core directly whenever the CPU has AVX2
        if !std::arch::is_x86_feature_detected!("avx2") {
            eprintln!("skipping avx2_x4_matches_portable: no AVX2 on this host");
            return;
        }
        let key: [u8; 32] = core::array::from_fn(|i| (i * 13 + 7) as u8);
        let nonce: [u8; 12] = core::array::from_fn(|i| (i * 29 + 3) as u8);
        let c = ChaCha20::new(&key, &nonce, 0);
        for counter in [0u32, 3, 12345, u32::MAX - 3] {
            // SAFETY: AVX2 presence checked above.
            let got = unsafe { avx2::four_blocks(&c.key, &c.nonce, counter) };
            assert_eq!(got, x4_blocks_portable(&c.key, &c.nonce, counter), "counter={counter}");
        }
    }

    #[cfg(target_arch = "aarch64")]
    #[test]
    fn neon_x4_matches_portable() {
        if !std::arch::is_aarch64_feature_detected!("neon") {
            eprintln!("skipping neon_x4_matches_portable: no NEON on this host");
            return;
        }
        let key: [u8; 32] = core::array::from_fn(|i| (i * 13 + 7) as u8);
        let nonce: [u8; 12] = core::array::from_fn(|i| (i * 29 + 3) as u8);
        let c = ChaCha20::new(&key, &nonce, 0);
        for counter in [0u32, 3, 12345, u32::MAX - 3] {
            // SAFETY: NEON presence checked above.
            let got = unsafe { neon::four_blocks(&c.key, &c.nonce, counter) };
            assert_eq!(got, x4_blocks_portable(&c.key, &c.nonce, counter), "counter={counter}");
        }
    }

    #[test]
    fn keystream_u64_grouped_matches_single_blocks() {
        // whatever ISA dispatched, the grouped path must equal the
        // single-block reference for lengths on every side of the
        // 32-word group boundary
        let key: [u8; 32] = core::array::from_fn(|i| (i * 7 + 2) as u8);
        let nonce = [6u8; 12];
        for start in [0u32, 5] {
            let c = ChaCha20::new(&key, &nonce, start);
            for len in [0usize, 1, 7, 8, 9, 31, 32, 33, 63, 64, 65, 100, 131] {
                let mut got = vec![0u64; len];
                c.keystream_u64(&mut got);
                let mut want = vec![0u64; len];
                for (b, chunk) in want.chunks_mut(BLOCK_WORDS_U64).enumerate() {
                    let w = c.block_words(start + b as u32);
                    for (j, o) in chunk.iter_mut().enumerate() {
                        *o = (w[2 * j] as u64) | ((w[2 * j + 1] as u64) << 32);
                    }
                }
                assert_eq!(got, want, "start={start} len={len}");
            }
        }
    }

    #[test]
    fn apply_keystream_grouped_matches_single_blocks() {
        let key = [8u8; 32];
        let nonce = [1u8; 12];
        let c = ChaCha20::new(&key, &nonce, 2);
        let mut grouped: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        let mut reference = grouped.clone();
        c.apply_keystream(&mut grouped);
        for (b, chunk) in reference.chunks_mut(64).enumerate() {
            let ks = c.block(2 + b as u32);
            for (x, k) in chunk.iter_mut().zip(ks.iter()) {
                *x ^= k;
            }
        }
        assert_eq!(grouped, reference);
    }

    // -- 32-bit block counter boundary -----------------------------------

    #[test]
    fn keystream_to_final_block_is_allowed() {
        let c = ChaCha20::new(&[0u8; 32], &[0u8; 12], u32::MAX);
        let mut out = [0u64; BLOCK_WORDS_U64]; // exactly the last block
        c.keystream_u64(&mut out);
        assert_ne!(out, [0u64; BLOCK_WORDS_U64]);
    }

    #[test]
    #[should_panic(expected = "keystream would repeat")]
    fn keystream_past_final_block_panics() {
        let c = ChaCha20::new(&[0u8; 32], &[0u8; 12], u32::MAX);
        let mut out = [0u64; BLOCK_WORDS_U64 + 1]; // needs block u32::MAX + 1
        c.keystream_u64(&mut out);
    }

    #[test]
    #[should_panic(expected = "keystream would repeat")]
    fn apply_keystream_past_final_block_panics() {
        let c = ChaCha20::new(&[0u8; 32], &[0u8; 12], u32::MAX - 1);
        let mut data = [0u8; 64 * 2 + 1]; // needs block u32::MAX + 1
        c.apply_keystream(&mut data);
    }
}
