//! ChaCha20 stream cipher (RFC 8439), from scratch.
//!
//! Dual use in this system:
//! * the symmetric cipher under ChaCha20-Poly1305 AEAD for sample-ID
//!   encryption during mini-batch selection (§4.0.2), and
//! * the PRG for pairwise secure-aggregation masks (Eq. 3) via
//!   [`crate::crypto::prg`].

/// The ChaCha20 block function state.
#[derive(Clone)]
pub struct ChaCha20 {
    key: [u32; 8],
    nonce: [u32; 3],
    counter: u32,
}

#[inline(always)]
fn quarter_round(state: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    state[a] = state[a].wrapping_add(state[b]);
    state[d] ^= state[a];
    state[d] = state[d].rotate_left(16);
    state[c] = state[c].wrapping_add(state[d]);
    state[b] ^= state[c];
    state[b] = state[b].rotate_left(12);
    state[a] = state[a].wrapping_add(state[b]);
    state[d] ^= state[a];
    state[d] = state[d].rotate_left(8);
    state[c] = state[c].wrapping_add(state[d]);
    state[b] ^= state[c];
    state[b] = state[b].rotate_left(7);
}

impl ChaCha20 {
    /// Create a cipher instance with a 256-bit key and 96-bit nonce,
    /// starting at block `counter`.
    pub fn new(key: &[u8; 32], nonce: &[u8; 12], counter: u32) -> Self {
        let mut k = [0u32; 8];
        for i in 0..8 {
            k[i] = u32::from_le_bytes([key[4 * i], key[4 * i + 1], key[4 * i + 2], key[4 * i + 3]]);
        }
        let mut n = [0u32; 3];
        for i in 0..3 {
            n[i] = u32::from_le_bytes([nonce[4 * i], nonce[4 * i + 1], nonce[4 * i + 2], nonce[4 * i + 3]]);
        }
        ChaCha20 { key: k, nonce: n, counter }
    }

    /// The 16 output words for block index `counter`. Fully unrolled
    /// with named locals (no array bounds checks on the hot path) —
    /// the PRG that expands every pairwise mask runs through here.
    #[inline]
    pub fn block_words(&self, counter: u32) -> [u32; 16] {
        let (i0, i1, i2, i3) = (0x61707865u32, 0x3320646eu32, 0x79622d32u32, 0x6b206574u32);
        let [k0, k1, k2, k3, k4, k5, k6, k7] = self.key;
        let [n0, n1, n2] = self.nonce;
        let (mut x0, mut x1, mut x2, mut x3) = (i0, i1, i2, i3);
        let (mut x4, mut x5, mut x6, mut x7) = (k0, k1, k2, k3);
        let (mut x8, mut x9, mut x10, mut x11) = (k4, k5, k6, k7);
        let (mut x12, mut x13, mut x14, mut x15) = (counter, n0, n1, n2);

        macro_rules! qr {
            ($a:ident, $b:ident, $c:ident, $d:ident) => {
                $a = $a.wrapping_add($b);
                $d = ($d ^ $a).rotate_left(16);
                $c = $c.wrapping_add($d);
                $b = ($b ^ $c).rotate_left(12);
                $a = $a.wrapping_add($b);
                $d = ($d ^ $a).rotate_left(8);
                $c = $c.wrapping_add($d);
                $b = ($b ^ $c).rotate_left(7);
            };
        }
        for _ in 0..10 {
            qr!(x0, x4, x8, x12);
            qr!(x1, x5, x9, x13);
            qr!(x2, x6, x10, x14);
            qr!(x3, x7, x11, x15);
            qr!(x0, x5, x10, x15);
            qr!(x1, x6, x11, x12);
            qr!(x2, x7, x8, x13);
            qr!(x3, x4, x9, x14);
        }
        [
            x0.wrapping_add(i0), x1.wrapping_add(i1), x2.wrapping_add(i2), x3.wrapping_add(i3),
            x4.wrapping_add(k0), x5.wrapping_add(k1), x6.wrapping_add(k2), x7.wrapping_add(k3),
            x8.wrapping_add(k4), x9.wrapping_add(k5), x10.wrapping_add(k6), x11.wrapping_add(k7),
            x12.wrapping_add(counter), x13.wrapping_add(n0), x14.wrapping_add(n1), x15.wrapping_add(n2),
        ]
    }

    /// Produce the 64-byte keystream block for block index `counter`.
    pub fn block(&self, counter: u32) -> [u8; 64] {
        let words = self.block_words(counter);
        let mut out = [0u8; 64];
        for (i, w) in words.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&w.to_le_bytes());
        }
        out
    }

    /// Fill a `u64` buffer with keystream words directly (the mask-PRG
    /// fast path: skips the byte-array round-trip).
    pub fn keystream_u64(&self, out: &mut [u64]) {
        let mut counter = self.counter;
        for chunk in out.chunks_mut(8) {
            let w = self.block_words(counter);
            for (j, o) in chunk.iter_mut().enumerate() {
                *o = (w[2 * j] as u64) | ((w[2 * j + 1] as u64) << 32);
            }
            counter = counter.wrapping_add(1);
        }
    }

    /// XOR the keystream into `data` in place (encrypt == decrypt).
    pub fn apply_keystream(&self, data: &mut [u8]) {
        let mut counter = self.counter;
        for chunk in data.chunks_mut(64) {
            let ks = self.block(counter);
            for (b, k) in chunk.iter_mut().zip(ks.iter()) {
                *b ^= k;
            }
            counter = counter.wrapping_add(1);
        }
    }

    /// Fill `out` with raw keystream bytes (PRG mode).
    pub fn keystream(&self, out: &mut [u8]) {
        out.fill(0);
        self.apply_keystream(out);
    }
}

/// One-shot encryption (RFC 8439 §2.4): XOR `data` with the keystream
/// starting at block counter 1 (block 0 is reserved for the Poly1305
/// one-time key in the AEAD construction).
pub fn chacha20_xor(key: &[u8; 32], nonce: &[u8; 12], counter: u32, data: &mut [u8]) {
    ChaCha20::new(key, nonce, counter).apply_keystream(data);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unhex(s: &str) -> Vec<u8> {
        let s: String = s.chars().filter(|c| !c.is_whitespace()).collect();
        (0..s.len()).step_by(2).map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap()).collect()
    }

    // RFC 8439 §2.3.2 block function test vector.
    #[test]
    fn rfc8439_block() {
        let key: [u8; 32] = core::array::from_fn(|i| i as u8);
        let nonce: [u8; 12] = [0, 0, 0, 9, 0, 0, 0, 0x4a, 0, 0, 0, 0];
        let c = ChaCha20::new(&key, &nonce, 1);
        let block = c.block(1);
        let expected = unhex(
            "10f1e7e4d13b5915500fdd1fa32071c4c7d1f4c733c068030422aa9ac3d46c4e\
             d2826446079faa0914c2d705d98b02a2b5129cd1de164eb9cbd083e8a2503c4e",
        );
        assert_eq!(&block[..], &expected[..]);
    }

    // RFC 8439 §2.4.2 encryption test vector.
    #[test]
    fn rfc8439_encrypt() {
        let key: [u8; 32] = core::array::from_fn(|i| i as u8);
        let nonce: [u8; 12] = [0, 0, 0, 0, 0, 0, 0, 0x4a, 0, 0, 0, 0];
        let mut msg = b"Ladies and Gentlemen of the class of '99: If I could offer you only one tip for the future, sunscreen would be it.".to_vec();
        chacha20_xor(&key, &nonce, 1, &mut msg);
        let expected = unhex(
            "6e2e359a2568f98041ba0728dd0d6981e97e7aec1d4360c20a27afccfd9fae0b\
             f91b65c5524733ab8f593dabcd62b3571639d624e65152ab8f530c359f0861d8\
             07ca0dbf500d6a6156a38e088a22b65e52bc514d16ccf806818ce91ab7793736\
             5af90bbf74a35be6b40b8eedf2785e42874d",
        );
        assert_eq!(msg, expected);
    }

    #[test]
    fn roundtrip() {
        let key = [7u8; 32];
        let nonce = [3u8; 12];
        let plain: Vec<u8> = (0..300u32).map(|i| (i % 256) as u8).collect();
        let mut data = plain.clone();
        chacha20_xor(&key, &nonce, 1, &mut data);
        assert_ne!(data, plain);
        chacha20_xor(&key, &nonce, 1, &mut data);
        assert_eq!(data, plain);
    }

    #[test]
    fn keystream_matches_xor_of_zeros() {
        let key = [1u8; 32];
        let nonce = [2u8; 12];
        let c = ChaCha20::new(&key, &nonce, 0);
        let mut a = [0u8; 130];
        c.keystream(&mut a);
        let mut b = [0u8; 130];
        c.apply_keystream(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn keystream_u64_matches_byte_path() {
        let key: [u8; 32] = core::array::from_fn(|i| (i * 11 + 3) as u8);
        let nonce = [5u8; 12];
        let c = ChaCha20::new(&key, &nonce, 0);
        let mut bytes = [0u8; 200 * 8];
        c.keystream(&mut bytes);
        let want: Vec<u64> =
            bytes.chunks_exact(8).map(|ch| u64::from_le_bytes(ch.try_into().unwrap())).collect();
        let mut words = [0u64; 200];
        c.keystream_u64(&mut words);
        assert_eq!(&words[..], &want[..]);
    }

    #[test]
    fn counter_advances_across_chunks() {
        // applying to one 128-byte buffer == two 64-byte buffers with counters 0,1
        let key = [9u8; 32];
        let nonce = [4u8; 12];
        let mut whole = [0xabu8; 128];
        ChaCha20::new(&key, &nonce, 0).apply_keystream(&mut whole);
        let mut lo = [0xabu8; 64];
        let mut hi = [0xabu8; 64];
        ChaCha20::new(&key, &nonce, 0).apply_keystream(&mut lo);
        ChaCha20::new(&key, &nonce, 1).apply_keystream(&mut hi);
        assert_eq!(&whole[..64], &lo[..]);
        assert_eq!(&whole[64..], &hi[..]);
    }
}
