//! Runtime SIMD ISA selection for the compute hot paths.
//!
//! One probe, cached per process: AVX2 on x86_64, NEON on aarch64,
//! scalar everywhere else. `VFL_SIMD=off` pins the scalar reference
//! paths — the CI axis that re-proves SIMD ≡ scalar bit-identity, and
//! the escape hatch if a vector kernel ever misbehaves on exotic
//! hardware.
//!
//! The dispatch contract is that it is *invisible*: every vector
//! kernel behind this probe (the 4-block ChaCha20 core in
//! [`super::chacha20`], the ℤ₂⁶⁴ folds in [`crate::z64`]) produces
//! bit-identical output to its scalar twin, asserted by property tests
//! next to each kernel. The probe can therefore only change speed,
//! never protocol bytes — masks expanded on an AVX2 aggregator cancel
//! against masks expanded on a NEON phone.

use std::sync::OnceLock;

/// The instruction set the vector kernels dispatch to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdIsa {
    /// Portable scalar reference paths (also what `VFL_SIMD=off` pins).
    Scalar,
    /// x86_64 AVX2 (128-bit lanes carry the 4-block ChaCha20 core,
    /// 256-bit lanes the ℤ₂⁶⁴ accumulator folds).
    Avx2,
    /// aarch64 NEON (128-bit lanes; baseline on every aarch64 target,
    /// still probed at runtime for uniformity with x86).
    Neon,
}

impl SimdIsa {
    /// Stable lowercase name for logs and bench JSON.
    pub fn name(self) -> &'static str {
        match self {
            SimdIsa::Scalar => "scalar",
            SimdIsa::Avx2 => "avx2",
            SimdIsa::Neon => "neon",
        }
    }
}

fn probe() -> SimdIsa {
    if let Ok(v) = std::env::var("VFL_SIMD") {
        let v = v.trim();
        // same fail-loud convention as the other VFL_* env hooks: a
        // set-but-unrecognized value is a config bug, not a default
        match v.to_ascii_lowercase().as_str() {
            "off" | "0" | "scalar" => return SimdIsa::Scalar,
            "" | "on" | "auto" => {}
            other => panic!("VFL_SIMD must be off|0|scalar|on|auto, got {other:?}"),
        }
    }
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") {
        return SimdIsa::Avx2;
    }
    #[cfg(target_arch = "aarch64")]
    if std::arch::is_aarch64_feature_detected!("neon") {
        return SimdIsa::Neon;
    }
    SimdIsa::Scalar
}

/// The ISA every vector kernel dispatches to. Probed once per process
/// (`OnceLock`), so a test or bench that wants the scalar legs must
/// set `VFL_SIMD=off` before the first dispatch — which is why the CI
/// scalar axis is a separate process, not a test-local override.
pub fn active_isa() -> SimdIsa {
    static ISA: OnceLock<SimdIsa> = OnceLock::new();
    *ISA.get_or_init(probe)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_is_stable_and_arch_consistent() {
        let isa = active_isa();
        assert_eq!(isa, active_isa(), "probe must be cached, not re-run");
        #[cfg(not(target_arch = "x86_64"))]
        assert_ne!(isa, SimdIsa::Avx2);
        #[cfg(not(target_arch = "aarch64"))]
        assert_ne!(isa, SimdIsa::Neon);
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(SimdIsa::Scalar.name(), "scalar");
        assert_eq!(SimdIsa::Avx2.name(), "avx2");
        assert_eq!(SimdIsa::Neon.name(), "neon");
    }
}
