//! ChaCha20-Poly1305 AEAD (RFC 8439 §2.8), from scratch.
//!
//! This is the cipher the active party uses to encrypt sample IDs per
//! passive party during mini-batch selection (§4.0.2): each ID batch is
//! sealed under the pairwise key derived from the X25519 shared secret,
//! so only the party holding that secret can recover the IDs.

use super::chacha20::ChaCha20;
use super::hmac::ct_eq;
use super::poly1305::Poly1305;

/// Authentication tag length in bytes.
pub const TAG_LEN: usize = 16;
/// Nonce length in bytes.
pub const NONCE_LEN: usize = 12;

fn mac(otk: &[u8; 32], aad: &[u8], ct: &[u8]) -> [u8; 16] {
    let mut p = Poly1305::new(otk);
    p.update(aad);
    if aad.len() % 16 != 0 {
        p.update(&vec![0u8; 16 - aad.len() % 16]);
    }
    p.update(ct);
    if ct.len() % 16 != 0 {
        p.update(&vec![0u8; 16 - ct.len() % 16]);
    }
    p.update(&(aad.len() as u64).to_le_bytes());
    p.update(&(ct.len() as u64).to_le_bytes());
    p.finalize()
}

fn one_time_key(key: &[u8; 32], nonce: &[u8; 12]) -> [u8; 32] {
    let block0 = ChaCha20::new(key, nonce, 0).block(0);
    let mut otk = [0u8; 32];
    otk.copy_from_slice(&block0[..32]);
    otk
}

/// Encrypt `plaintext` with additional data `aad`; returns ciphertext
/// with the 16-byte tag appended.
pub fn seal(key: &[u8; 32], nonce: &[u8; 12], aad: &[u8], plaintext: &[u8]) -> Vec<u8> {
    let mut ct = plaintext.to_vec();
    ChaCha20::new(key, nonce, 1).apply_keystream(&mut ct);
    let tag = mac(&one_time_key(key, nonce), aad, &ct);
    ct.extend_from_slice(&tag);
    ct
}

/// Decrypt and verify; returns `None` if the tag does not authenticate.
pub fn open(key: &[u8; 32], nonce: &[u8; 12], aad: &[u8], sealed: &[u8]) -> Option<Vec<u8>> {
    if sealed.len() < TAG_LEN {
        return None;
    }
    let (ct, tag) = sealed.split_at(sealed.len() - TAG_LEN);
    let expect = mac(&one_time_key(key, nonce), aad, ct);
    if !ct_eq(&expect, tag) {
        return None;
    }
    let mut pt = ct.to_vec();
    ChaCha20::new(key, nonce, 1).apply_keystream(&mut pt);
    Some(pt)
}

/// Deterministic per-message nonce from a round counter and sender id.
/// Uniqueness under a fixed key is guaranteed as long as the same
/// (sender, round, seq) triple is never reused, which the coordinator's
/// key-rotation schedule enforces (§5.1: keys regenerated every K rounds).
pub fn make_nonce(sender: u16, round: u32, seq: u32) -> [u8; 12] {
    let mut n = [0u8; 12];
    n[0..2].copy_from_slice(&sender.to_le_bytes());
    n[2..6].copy_from_slice(&round.to_le_bytes());
    n[6..10].copy_from_slice(&seq.to_le_bytes());
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unhex(s: &str) -> Vec<u8> {
        let s: String = s.chars().filter(|c| !c.is_whitespace()).collect();
        (0..s.len()).step_by(2).map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap()).collect()
    }

    // RFC 8439 §2.8.2 AEAD test vector.
    #[test]
    fn rfc8439_seal() {
        let key: [u8; 32] =
            unhex("808182838485868788898a8b8c8d8e8f909192939495969798999a9b9c9d9e9f").try_into().unwrap();
        let nonce: [u8; 12] = unhex("070000004041424344454647").try_into().unwrap();
        let aad = unhex("50515253c0c1c2c3c4c5c6c7");
        let pt = b"Ladies and Gentlemen of the class of '99: If I could offer you only one tip for the future, sunscreen would be it.";
        let sealed = seal(&key, &nonce, &aad, pt);
        let expected_ct = unhex(
            "d31a8d34648e60db7b86afbc53ef7ec2a4aded51296e08fea9e2b5a736ee62d6\
             3dbea45e8ca9671282fafb69da92728b1a71de0a9e060b2905d6a5b67ecd3b36\
             92ddbd7f2d778b8c9803aee328091b58fab324e4fad675945585808b4831d7bc\
             3ff4def08e4b7a9de576d26586cec64b6116",
        );
        let expected_tag = unhex("1ae10b594f09e26a7e902ecbd0600691");
        assert_eq!(&sealed[..sealed.len() - 16], &expected_ct[..]);
        assert_eq!(&sealed[sealed.len() - 16..], &expected_tag[..]);
    }

    #[test]
    fn roundtrip_and_tamper() {
        let key = [0x42u8; 32];
        let nonce = make_nonce(1, 7, 3);
        let aad = b"batch=7";
        let pt = b"sample-ids: 1,5,9";
        let mut sealed = seal(&key, &nonce, aad, pt);
        assert_eq!(open(&key, &nonce, aad, &sealed).as_deref(), Some(&pt[..]));
        // flip one ciphertext bit
        sealed[0] ^= 1;
        assert!(open(&key, &nonce, aad, &sealed).is_none());
        sealed[0] ^= 1;
        // wrong aad
        assert!(open(&key, &nonce, b"batch=8", &sealed).is_none());
        // wrong key
        assert!(open(&[0x43u8; 32], &nonce, aad, &sealed).is_none());
        // truncated
        assert!(open(&key, &nonce, aad, &sealed[..10]).is_none());
    }

    #[test]
    fn empty_plaintext_authenticates_aad() {
        let key = [1u8; 32];
        let nonce = make_nonce(0, 0, 0);
        let sealed = seal(&key, &nonce, b"header", b"");
        assert_eq!(sealed.len(), TAG_LEN);
        assert_eq!(open(&key, &nonce, b"header", &sealed).as_deref(), Some(&b""[..]));
        assert!(open(&key, &nonce, b"Header", &sealed).is_none());
    }

    #[test]
    fn nonce_uniqueness() {
        let n1 = make_nonce(1, 2, 3);
        let n2 = make_nonce(1, 2, 4);
        let n3 = make_nonce(2, 2, 3);
        assert_ne!(n1, n2);
        assert_ne!(n1, n3);
    }
}
