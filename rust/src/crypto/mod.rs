//! From-scratch cryptographic substrates.
//!
//! Nothing in this module depends on external crates: the paper's
//! protocol (X25519 ECDH, ChaCha20-Poly1305 AEAD, HKDF, mask PRG) and
//! its baselines (Paillier, BFV) are all implemented here, with RFC /
//! NIST test vectors in each module's unit tests.

pub mod aead;
pub mod bfv;
pub mod bigint;
pub mod chacha20;
pub mod ed25519;
pub mod field25519;
pub mod hkdf;
pub mod hmac;
pub mod paillier;
pub mod poly1305;
pub mod prg;
pub mod psi;
pub mod rng;
pub mod sha256;
pub mod sha512;
pub mod shamir;
pub mod x25519;
