//! From-scratch cryptographic substrates.
//!
//! Nothing in this module depends on external crates: the paper's
//! protocol (X25519 ECDH, ChaCha20-Poly1305 AEAD, HKDF, mask PRG) and
//! its baselines (Paillier, BFV) are all implemented here, with RFC /
//! NIST test vectors in each module's unit tests.
//!
//! # SIMD dispatch model
//!
//! The compute hot path is ChaCha20 mask expansion ([`prg`] over
//! [`chacha20`]) and the ℤ₂⁶⁴ folds in [`crate::z64`]. Both dispatch
//! through one runtime probe ([`simd::active_isa`]): AVX2 on x86_64,
//! NEON on aarch64, scalar otherwise, with `VFL_SIMD=off` pinning the
//! scalar reference paths. Three rules keep this safe:
//!
//! 1. **Scalar is the semantics.** The single-block
//!    [`chacha20::ChaCha20::block_words`] core and the plain wrapping
//!    loops define the protocol; every vector kernel is an
//!    implementation of *that*, never a variant of it.
//! 2. **Bit-identity is asserted, not assumed.** Each kernel has
//!    property tests against its scalar twin across alignments and
//!    lengths, and CI re-runs the protocol equivalence suites with
//!    `VFL_SIMD=off` so a divergence fails loudly at both levels.
//! 3. **Detection is cached and data-independent.** One `OnceLock`
//!    probe per process; dispatch can change speed, never bytes —
//!    masks expanded on an AVX2 server cancel against masks from a
//!    NEON client.

pub mod aead;
pub mod bfv;
pub mod bigint;
pub mod chacha20;
pub mod ed25519;
pub mod field25519;
pub mod hkdf;
pub mod hmac;
pub mod paillier;
pub mod poly1305;
pub mod prg;
pub mod psi;
pub mod rng;
pub mod sha256;
pub mod sha512;
pub mod shamir;
pub mod simd;
pub mod x25519;
