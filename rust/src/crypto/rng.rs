//! Randomness utilities.
//!
//! * [`OsRng`] pulls entropy from `/dev/urandom` (key generation).
//! * [`DetRng`] is a deterministic ChaCha20-based generator used for
//!   reproducible experiments and property-style tests.

use super::chacha20::{ChaCha20, X4_WORDS_U64};

/// Fill `buf` with OS entropy from `/dev/urandom`.
pub fn os_random(buf: &mut [u8]) {
    use std::io::Read;
    let mut f = std::fs::File::open("/dev/urandom").expect("open /dev/urandom");
    f.read_exact(buf).expect("read /dev/urandom");
}

/// Generate a random 32-byte array from the OS.
pub fn os_random32() -> [u8; 32] {
    let mut b = [0u8; 32];
    os_random(&mut b);
    b
}

/// Blocks expanded per [`DetRng`] refill: the width of the 4-block
/// ChaCha20 core, so a full refill is one vector-core dispatch instead
/// of four scalar block expansions.
const REFILL_BLOCKS: usize = 4;

/// Deterministic ChaCha20-CTR random generator.
///
/// Refills a [`REFILL_BLOCKS`]-block (256-byte) buffer per keystream
/// dispatch through the same 4-block core the mask PRG uses; the byte
/// stream is bit-identical to the original one-block-per-refill
/// generator (asserted below), so every seeded experiment reproduces.
#[derive(Clone)]
pub struct DetRng {
    cipher: ChaCha20,
    counter: u32,
    buf: [u8; 64 * REFILL_BLOCKS],
    /// Valid bytes in `buf` (a refill near the counter limit may batch
    /// fewer than [`REFILL_BLOCKS`] blocks).
    len: usize,
    pos: usize,
}

impl DetRng {
    /// Seed from a 32-byte key.
    pub fn new(seed: [u8; 32]) -> Self {
        let cipher = ChaCha20::new(&seed, &[0u8; 12], 0);
        DetRng { cipher, counter: 0, buf: [0u8; 64 * REFILL_BLOCKS], len: 0, pos: 0 }
    }

    /// Seed from a u64 (convenience for tests/experiments).
    pub fn from_seed(seed: u64) -> Self {
        let mut key = [0u8; 32];
        key[..8].copy_from_slice(&seed.to_le_bytes());
        key[8..16].copy_from_slice(&seed.wrapping_mul(0x9e3779b97f4a7c15).to_le_bytes());
        Self::new(key)
    }

    fn refill(&mut self) {
        // same checked-counter rule as the mask PRG: a wrapped 32-bit
        // block counter silently repeats the keystream (2^32 blocks =
        // 256 GiB of output per seed — unreachable in practice, fatal
        // if reached). The original one-block refill served blocks
        // 0..=u32::MAX-1 and panicked before serving block u32::MAX;
        // the batch keeps that exact boundary by never batching past
        // the last servable block.
        let avail = u32::MAX - self.counter;
        if avail == 0 {
            panic!("DetRng exhausted 2^32 ChaCha20 blocks: keystream would repeat");
        }
        let n = (avail as usize).min(REFILL_BLOCKS);
        if n == REFILL_BLOCKS {
            // full batch: one 4-block vector-core dispatch, de-
            // interleaved to the documented keystream_u64 layout —
            // LE-serializing it reproduces 4 consecutive block() calls
            let mut group = [0u64; X4_WORDS_U64];
            self.cipher.four_blocks_u64_into(self.counter, &mut group);
            for (i, w) in group.iter().enumerate() {
                self.buf[8 * i..8 * i + 8].copy_from_slice(&w.to_le_bytes());
            }
        } else {
            for i in 0..n {
                let block = self.cipher.block(self.counter + i as u32);
                self.buf[64 * i..64 * (i + 1)].copy_from_slice(&block);
            }
        }
        self.counter += n as u32;
        self.len = 64 * n;
        self.pos = 0;
    }

    pub fn fill(&mut self, out: &mut [u8]) {
        for b in out.iter_mut() {
            if self.pos == self.len {
                self.refill();
            }
            *b = self.buf[self.pos];
            self.pos += 1;
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut b = [0u8; 8];
        self.fill(&mut b);
        u64::from_le_bytes(b)
    }

    pub fn next_u32(&mut self) -> u32 {
        let mut b = [0u8; 4];
        self.fill(&mut b);
        u32::from_le_bytes(b)
    }

    /// Uniform in `[lo, hi)` (unbiased via rejection).
    pub fn next_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi);
        let span = hi - lo;
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + v % span;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
            let u2 = (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
            if u1 > 0.0 {
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Uniform float in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Adapt into the `FnMut(&mut [u8])` shape `bigint` expects.
    pub fn as_fill_fn(self) -> impl FnMut(&mut [u8]) {
        let mut rng = self;
        move |buf: &mut [u8]| rng.fill(buf)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_range(0, (i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The pre-batch generator, reimplemented verbatim: one
    /// `cipher.block` per 64-byte refill, checked counter increment.
    /// The batched [`DetRng`] must reproduce this byte stream exactly.
    struct OneBlockRng {
        cipher: ChaCha20,
        counter: u32,
        buf: [u8; 64],
        pos: usize,
    }

    impl OneBlockRng {
        fn new(seed: [u8; 32], counter: u32) -> Self {
            OneBlockRng { cipher: ChaCha20::new(&seed, &[0u8; 12], 0), counter, buf: [0u8; 64], pos: 64 }
        }

        fn fill(&mut self, out: &mut [u8]) {
            for b in out.iter_mut() {
                if self.pos == 64 {
                    self.buf = self.cipher.block(self.counter);
                    self.counter = self.counter.checked_add(1).expect("keystream would repeat");
                    self.pos = 0;
                }
                *b = self.buf[self.pos];
                self.pos += 1;
            }
        }
    }

    /// A [`DetRng`] whose counter starts at `counter` (counter-limit
    /// boundary tests; the public constructors always start at 0).
    fn rng_at(seed: [u8; 32], counter: u32) -> DetRng {
        DetRng {
            cipher: ChaCha20::new(&seed, &[0u8; 12], 0),
            counter,
            buf: [0u8; 64 * REFILL_BLOCKS],
            len: 0,
            pos: 0,
        }
    }

    #[test]
    fn batched_stream_matches_per_block_reference() {
        // the ISSUE's identity sweep: every read size 0..=257 (empty
        // reads, sub-block, block-straddling, one-past-a-full-batch),
        // issued back to back so refills land at varied offsets
        let seed = [0xB4u8; 32];
        let mut batched = DetRng::new(seed);
        let mut reference = OneBlockRng::new(seed, 0);
        for size in 0..=257usize {
            let mut a = vec![0u8; size];
            let mut b = vec![0u8; size];
            batched.fill(&mut a);
            reference.fill(&mut b);
            assert_eq!(a, b, "read size {size}");
        }
        // and the derived draws ride the same stream
        let mut batched = DetRng::from_seed(42);
        let mut reference = OneBlockRng::new(
            {
                let mut key = [0u8; 32];
                key[..8].copy_from_slice(&42u64.to_le_bytes());
                key[8..16].copy_from_slice(&42u64.wrapping_mul(0x9e3779b97f4a7c15).to_le_bytes());
                key
            },
            0,
        );
        for _ in 0..100 {
            let mut b = [0u8; 8];
            reference.fill(&mut b);
            assert_eq!(batched.next_u64(), u64::from_le_bytes(b));
        }
    }

    #[test]
    fn short_batch_near_counter_limit_matches_reference() {
        // 3 servable blocks left: the refill must batch short (scalar
        // blocks) instead of running the 4-block core past the limit
        let seed = [0x77u8; 32];
        let start = u32::MAX - 3;
        let mut batched = rng_at(seed, start);
        let mut reference = OneBlockRng::new(seed, start);
        let mut a = vec![0u8; 3 * 64];
        let mut b = vec![0u8; 3 * 64];
        batched.fill(&mut a);
        reference.fill(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "keystream would repeat")]
    fn refill_at_final_block_panics() {
        // block u32::MAX was never servable pre-batch (checked_add
        // panicked before pos reset); the batch keeps that boundary
        let mut r = rng_at([1u8; 32], u32::MAX);
        let mut b = [0u8; 1];
        r.fill(&mut b);
    }

    #[test]
    fn deterministic() {
        let mut a = DetRng::from_seed(1);
        let mut b = DetRng::from_seed(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = DetRng::from_seed(2);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn range_bounds() {
        let mut r = DetRng::from_seed(3);
        for _ in 0..1000 {
            let v = r.next_range(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn uniformity_coarse() {
        let mut r = DetRng::from_seed(4);
        let mut buckets = [0usize; 10];
        let n = 10_000;
        for _ in 0..n {
            buckets[r.next_range(0, 10) as usize] += 1;
        }
        for &c in &buckets {
            assert!((800..1200).contains(&c), "bucket count {c} out of tolerance");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = DetRng::from_seed(5);
        let n = 20_000;
        let samples: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = DetRng::from_seed(6);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely to be identity
    }

    #[test]
    fn os_random_nonzero() {
        let a = os_random32();
        let b = os_random32();
        assert_ne!(a, b);
    }
}
