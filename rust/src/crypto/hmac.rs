//! HMAC-SHA256 (RFC 2104), from scratch.

use super::sha256::Sha256;

/// Incremental HMAC-SHA256.
#[derive(Clone)]
pub struct HmacSha256 {
    inner: Sha256,
    okey: [u8; 64],
}

impl HmacSha256 {
    pub fn new(key: &[u8]) -> Self {
        let mut k = [0u8; 64];
        if key.len() > 64 {
            let d = super::sha256::sha256(key);
            k[..32].copy_from_slice(&d);
        } else {
            k[..key.len()].copy_from_slice(key);
        }
        let mut ikey = [0u8; 64];
        let mut okey = [0u8; 64];
        for i in 0..64 {
            ikey[i] = k[i] ^ 0x36;
            okey[i] = k[i] ^ 0x5c;
        }
        let mut inner = Sha256::new();
        inner.update(&ikey);
        HmacSha256 { inner, okey }
    }

    pub fn update(&mut self, data: &[u8]) {
        self.inner.update(data);
    }

    pub fn finalize(self) -> [u8; 32] {
        let inner_digest = self.inner.finalize();
        let mut outer = Sha256::new();
        outer.update(&self.okey);
        outer.update(&inner_digest);
        outer.finalize()
    }
}

/// One-shot HMAC-SHA256.
pub fn hmac_sha256(key: &[u8], data: &[u8]) -> [u8; 32] {
    let mut h = HmacSha256::new(key);
    h.update(data);
    h.finalize()
}

/// Constant-time equality on MAC tags.
pub fn ct_eq(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut acc = 0u8;
    for (x, y) in a.iter().zip(b.iter()) {
        acc |= x ^ y;
    }
    acc == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(b: &[u8]) -> String {
        b.iter().map(|x| format!("{x:02x}")).collect()
    }

    // RFC 4231 test cases.
    #[test]
    fn rfc4231_case1() {
        let key = [0x0bu8; 20];
        assert_eq!(
            hex(&hmac_sha256(&key, b"Hi There")),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
    }

    #[test]
    fn rfc4231_case2() {
        assert_eq!(
            hex(&hmac_sha256(b"Jefe", b"what do ya want for nothing?")),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
    }

    #[test]
    fn rfc4231_case3() {
        let key = [0xaau8; 20];
        let data = [0xddu8; 50];
        assert_eq!(
            hex(&hmac_sha256(&key, &data)),
            "773ea91e36800e46854db8ebd09181a72959098b3ef8c122d9635514ced565fe"
        );
    }

    #[test]
    fn rfc4231_case6_long_key() {
        let key = [0xaau8; 131];
        assert_eq!(
            hex(&hmac_sha256(&key, b"Test Using Larger Than Block-Size Key - Hash Key First")),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }

    #[test]
    fn ct_eq_behaviour() {
        assert!(ct_eq(b"abcd", b"abcd"));
        assert!(!ct_eq(b"abcd", b"abce"));
        assert!(!ct_eq(b"abc", b"abcd"));
    }
}
