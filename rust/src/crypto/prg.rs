//! The pairwise-mask PRG of the secure-aggregation protocol (Eq. 3–4).
//!
//! Each pair of clients (i, j) shares a secret `ss_ij`; per round and
//! per tensor they expand it into a pseudo-random mask vector. Client
//! i adds `+PRG(ss_ij)` if `j > i` and `−PRG(ss_ij)` if `j < i`, so the
//! sum over all clients telescopes to zero (Eq. 4).
//!
//! Masks live in ℤ₂⁶⁴ (wrapping arithmetic) so cancellation is *exact*;
//! the fixed-point codec in [`crate::secagg`] maps float tensors into
//! that domain and back.
//!
//! Two access patterns share one keystream:
//!
//! * the monolithic helpers ([`mask_words`], [`pairwise_mask`],
//!   [`total_mask`]) materialize a whole mask vector at once, and
//! * [`MaskStream`] / [`TotalMaskStream`] yield arbitrary
//!   `(offset, len)` *windows* of the same stream for the chunked
//!   streaming pipeline — ChaCha20 is seekable per 8-word block, so a
//!   window never expands more keystream than it covers, and chunked
//!   output is bit-identical to the monolithic expansion (asserted in
//!   the tests below).

use super::chacha20::ChaCha20;
use super::hkdf;

/// Mask words per ChaCha20 block (64 keystream bytes = 8 × u64).
const WORDS_PER_BLOCK: usize = 8;

/// The ChaCha20 instance behind one (secret, round, tag) mask stream:
/// key domain-separated from other uses of the shared secret, context
/// bound into the nonce so every round and tensor gets an independent
/// stream, block counter starting at 0.
fn mask_cipher(shared_secret: &[u8; 32], round: u64, tensor_tag: u32) -> ChaCha20 {
    let key = hkdf::derive_key32(b"vfl-sa/prg/v1", shared_secret, b"mask");
    let mut nonce = [0u8; 12];
    nonce[..8].copy_from_slice(&round.to_le_bytes());
    nonce[8..12].copy_from_slice(&tensor_tag.to_le_bytes());
    ChaCha20::new(&key, &nonce, 0)
}

/// Expand a shared secret into `len` uniform u64 mask words for a given
/// (round, tensor-tag) context. The context is bound into the nonce so
/// every round and tensor gets an independent mask stream.
pub fn mask_words(shared_secret: &[u8; 32], round: u64, tensor_tag: u32, len: usize) -> Vec<u64> {
    let mut words = vec![0u64; len];
    mask_cipher(shared_secret, round, tensor_tag).keystream_u64(&mut words);
    words
}

/// The signed pairwise mask for client `me` against peer `peer`
/// (Eq. 3): added when `peer > me`, subtracted when `peer < me`.
/// Returns the delta to add (already signed in ℤ₂⁶⁴).
pub fn pairwise_mask(
    shared_secret: &[u8; 32],
    me: usize,
    peer: usize,
    round: u64,
    tensor_tag: u32,
    len: usize,
) -> Vec<u64> {
    assert_ne!(me, peer);
    let words = mask_words(shared_secret, round, tensor_tag, len);
    if peer > me {
        words
    } else {
        words.into_iter().map(|w| w.wrapping_neg()).collect()
    }
}

/// Accumulate the total mask for client `me` over all peers (Eq. 3).
pub fn total_mask(
    secrets: &[(usize, [u8; 32])], // (peer index, shared secret)
    me: usize,
    round: u64,
    tensor_tag: u32,
    len: usize,
) -> Vec<u64> {
    let mut acc = vec![0u64; len];
    for (peer, ss) in secrets {
        let delta = pairwise_mask(ss, me, *peer, round, tensor_tag, len);
        for (a, d) in acc.iter_mut().zip(delta.iter()) {
            *a = a.wrapping_add(*d);
        }
    }
    acc
}

// ---------------------------------------------------------------------------
// Windowed access: the streaming pipeline's view of the same keystream
// ---------------------------------------------------------------------------

/// One signed pairwise mask stream, addressable by `(offset, len)`
/// windows. `window` output is bit-identical to the corresponding
/// slice of [`pairwise_mask`] — ChaCha20 seeks to block `offset / 8`
/// instead of expanding from word 0.
pub struct MaskStream {
    cipher: ChaCha20,
    /// True when this peer's mask is subtracted (peer < me, Eq. 3).
    negate: bool,
}

impl MaskStream {
    /// The stream client `me` adds against `peer` for (round, tag).
    pub fn pairwise(
        shared_secret: &[u8; 32],
        me: usize,
        peer: usize,
        round: u64,
        tensor_tag: u32,
    ) -> Self {
        assert_ne!(me, peer);
        MaskStream { cipher: mask_cipher(shared_secret, round, tensor_tag), negate: peer < me }
    }

    /// Wrap-add the mask words for `[offset, offset + out.len())` into
    /// `out` (already signed, so accumulating windows from several
    /// streams is the windowed form of [`total_mask`]).
    pub fn add_window(&self, offset: usize, out: &mut [u64]) {
        if out.is_empty() {
            return;
        }
        let end = offset + out.len();
        let first_block = offset / WORDS_PER_BLOCK;
        let last_block = (end - 1) / WORDS_PER_BLOCK;
        let mut block = [0u64; WORDS_PER_BLOCK];
        for b in first_block..=last_block {
            let words = self.cipher.block_words(b as u32);
            for (j, w) in block.iter_mut().enumerate() {
                *w = (words[2 * j] as u64) | ((words[2 * j + 1] as u64) << 32);
            }
            let base = b * WORDS_PER_BLOCK;
            let lo = offset.max(base);
            let hi = end.min(base + WORDS_PER_BLOCK);
            for w in lo..hi {
                let m = block[w - base];
                let m = if self.negate { m.wrapping_neg() } else { m };
                out[w - offset] = out[w - offset].wrapping_add(m);
            }
        }
    }

    /// Materialize one window on its own (mainly for tests).
    pub fn window(&self, offset: usize, len: usize) -> Vec<u64> {
        let mut out = vec![0u64; len];
        self.add_window(offset, &mut out);
        out
    }
}

/// A client's total mask over all peers (Eq. 3) as a windowed stream:
/// the chunked twin of [`total_mask`]. Windows are wrap-added, so any
/// partition of `[0, len)` into windows reproduces the monolithic
/// vector bit-for-bit.
pub struct TotalMaskStream {
    streams: Vec<MaskStream>,
}

impl TotalMaskStream {
    pub fn new(secrets: &[(usize, [u8; 32])], me: usize, round: u64, tensor_tag: u32) -> Self {
        let streams = secrets
            .iter()
            .map(|(peer, ss)| MaskStream::pairwise(ss, me, *peer, round, tensor_tag))
            .collect();
        TotalMaskStream { streams }
    }

    /// Wrap-add the total-mask words for the window starting at
    /// `offset` into `out`.
    pub fn add_window(&self, offset: usize, out: &mut [u64]) {
        for s in &self.streams {
            s.add_window(offset, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ss(i: usize, j: usize) -> [u8; 32] {
        // symmetric synthetic shared secret for the pair {i, j}
        let (lo, hi) = if i < j { (i, j) } else { (j, i) };
        let mut s = [0u8; 32];
        s[0] = lo as u8;
        s[1] = hi as u8;
        s[2] = 0xA5;
        s
    }

    #[test]
    fn masks_cancel_over_all_parties(){
        // Eq. 4: sum over all clients of their total mask == 0
        for n in [2usize, 3, 5, 8] {
            let len = 37;
            let mut sum = vec![0u64; len];
            for me in 0..n {
                let secrets: Vec<(usize, [u8; 32])> =
                    (0..n).filter(|&p| p != me).map(|p| (p, ss(me, p))).collect();
                let m = total_mask(&secrets, me, 12, 3, len);
                for (s, v) in sum.iter_mut().zip(m.iter()) {
                    *s = s.wrapping_add(*v);
                }
            }
            assert!(sum.iter().all(|&v| v == 0), "masks must cancel for n={n}");
        }
    }

    #[test]
    fn masks_differ_per_round_and_tensor() {
        let s = ss(0, 1);
        let a = mask_words(&s, 1, 0, 8);
        let b = mask_words(&s, 2, 0, 8);
        let c = mask_words(&s, 1, 1, 8);
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn pairwise_antisymmetry() {
        let s = ss(3, 7);
        let m37 = pairwise_mask(&s, 3, 7, 5, 0, 16);
        let m73 = pairwise_mask(&s, 7, 3, 5, 0, 16);
        for (a, b) in m37.iter().zip(m73.iter()) {
            assert_eq!(a.wrapping_add(*b), 0);
        }
    }

    #[test]
    fn deterministic_given_secret() {
        let s = ss(1, 2);
        assert_eq!(mask_words(&s, 9, 4, 100), mask_words(&s, 9, 4, 100));
    }

    #[test]
    fn window_matches_monolithic_slice() {
        // every (offset, len) window — aligned or not — must equal the
        // corresponding slice of the monolithic expansion
        let s = ss(2, 5);
        let full = pairwise_mask(&s, 2, 5, 11, 3, 100);
        let stream = MaskStream::pairwise(&s, 2, 5, 11, 3);
        for (offset, len) in [(0, 100), (0, 7), (7, 9), (8, 8), (1, 1), (63, 37), (95, 5)] {
            assert_eq!(stream.window(offset, len), full[offset..offset + len], "({offset},{len})");
        }
        // negated direction too
        let full = pairwise_mask(&s, 5, 2, 11, 3, 100);
        let stream = MaskStream::pairwise(&s, 5, 2, 11, 3);
        assert_eq!(stream.window(3, 50), full[3..53]);
    }

    #[test]
    fn total_stream_windows_reassemble_total_mask() {
        // chunked expansion ≡ total_mask bit-for-bit for lengths not
        // divisible by the chunk size
        let me = 1usize;
        let secrets: Vec<(usize, [u8; 32])> =
            (0..5).filter(|&p| p != me).map(|p| (p, ss(me, p))).collect();
        for len in [1usize, 7, 8, 64, 129] {
            let full = total_mask(&secrets, me, 9, 2, len);
            let stream = TotalMaskStream::new(&secrets, me, 9, 2);
            for chunk in [1usize, 3, 8, 50] {
                let mut got = vec![0u64; len];
                let mut off = 0;
                while off < len {
                    let n = chunk.min(len - off);
                    stream.add_window(off, &mut got[off..off + n]);
                    off += n;
                }
                assert_eq!(got, full, "len={len} chunk={chunk}");
            }
        }
    }

    #[test]
    fn masked_sum_reveals_only_total() {
        // secure aggregation end-to-end in Z_2^64: three parties, values xi;
        // aggregator sees only xi + mi, sum equals sum(xi).
        let n = 3;
        let len = 10;
        let values: Vec<Vec<u64>> = (0..n)
            .map(|i| (0..len).map(|j| (i * 1000 + j) as u64).collect())
            .collect();
        let mut agg = vec![0u64; len];
        for me in 0..n {
            let secrets: Vec<(usize, [u8; 32])> =
                (0..n).filter(|&p| p != me).map(|p| (p, ss(me, p))).collect();
            let mask = total_mask(&secrets, me, 0, 0, len);
            for j in 0..len {
                let masked = values[me][j].wrapping_add(mask[j]);
                // the masked value must differ from the raw value (whp)
                assert_ne!(masked, values[me][j]);
                agg[j] = agg[j].wrapping_add(masked);
            }
        }
        let want: Vec<u64> = (0..len).map(|j| (0..n).map(|i| (i * 1000 + j) as u64).sum()).collect();
        assert_eq!(agg, want);
    }
}
