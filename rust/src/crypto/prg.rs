//! The pairwise-mask PRG of the secure-aggregation protocol (Eq. 3–4).
//!
//! Each pair of clients (i, j) shares a secret `ss_ij`; per round and
//! per tensor they expand it into a pseudo-random mask vector. Client
//! i adds `+PRG(ss_ij)` if `j > i` and `−PRG(ss_ij)` if `j < i`, so the
//! sum over all clients telescopes to zero (Eq. 4).
//!
//! Masks live in ℤ₂⁶⁴ (wrapping arithmetic) so cancellation is *exact*;
//! the fixed-point codec in [`crate::secagg`] maps float tensors into
//! that domain and back.
//!
//! Two access patterns share one keystream:
//!
//! * the monolithic helpers ([`mask_words`], [`pairwise_mask`],
//!   [`total_mask`]) materialize a whole mask vector at once, and
//! * [`MaskStream`] / [`TotalMaskStream`] yield arbitrary
//!   `(offset, len)` *windows* of the same stream for the chunked
//!   streaming pipeline — ChaCha20 is seekable per 8-word block, so a
//!   window never expands more keystream than it covers, and chunked
//!   output is bit-identical to the monolithic expansion (asserted in
//!   the tests below).
//!
//! Mask expansion is the client-side compute hot path, so the window
//! fold is SIMD-dispatched: aligned interior spans run four ChaCha20
//! blocks at a time through [`super::chacha20`]'s vector core and fold
//! with [`crate::z64`] lane adds, while `VFL_SIMD=off` (or a CPU with
//! no vector ISA) takes the original single-block scalar path. The two
//! are bit-identical for every `(offset, len)` — a hard requirement,
//! since masks expanded on different machines must cancel — and the
//! property tests below sweep exactly that.
//!
//! The ChaCha20 block counter is 32-bit: one (round, tensor) stream
//! yields at most 2³² blocks = 2³⁵ words (256 GiB). Block indices are
//! converted with a *checked* cast ([`block_counter`]) — the old
//! unchecked `b as u32` silently wrapped and reused keystream past
//! that point.

use super::chacha20::{ChaCha20, X4_WORDS_U64};
use super::hkdf;
use super::simd::{active_isa, SimdIsa};
use crate::z64;

/// Mask words per ChaCha20 block (64 keystream bytes = 8 × u64).
const WORDS_PER_BLOCK: usize = 8;

/// Checked block-index → ChaCha20 counter conversion. Past 2³² blocks
/// the 32-bit counter would wrap and reuse keystream — masks would
/// stop cancelling AND pairs of masked tensors would leak their
/// difference. Protocol-fatal, so this is a documented panic rather
/// than a `Result` on the hot path.
#[inline]
fn block_counter(block: usize) -> u32 {
    u32::try_from(block).unwrap_or_else(|_| {
        panic!("mask stream exceeded 2^32 ChaCha20 blocks (block index {block}): keystream would repeat")
    })
}

/// The ChaCha20 instance behind one (secret, round, tag) mask stream:
/// key domain-separated from other uses of the shared secret, context
/// bound into the nonce so every round and tensor gets an independent
/// stream, block counter starting at 0.
fn mask_cipher(shared_secret: &[u8; 32], round: u64, tensor_tag: u32) -> ChaCha20 {
    let key = hkdf::derive_key32(b"vfl-sa/prg/v1", shared_secret, b"mask");
    let mut nonce = [0u8; 12];
    nonce[..8].copy_from_slice(&round.to_le_bytes());
    nonce[8..12].copy_from_slice(&tensor_tag.to_le_bytes());
    ChaCha20::new(&key, &nonce, 0)
}

/// Expand a shared secret into `len` uniform u64 mask words for a given
/// (round, tensor-tag) context. The context is bound into the nonce so
/// every round and tensor gets an independent mask stream.
pub fn mask_words(shared_secret: &[u8; 32], round: u64, tensor_tag: u32, len: usize) -> Vec<u64> {
    let mut words = vec![0u64; len];
    mask_cipher(shared_secret, round, tensor_tag).keystream_u64(&mut words);
    words
}

/// The signed pairwise mask for client `me` against peer `peer`
/// (Eq. 3): added when `peer > me`, subtracted when `peer < me`.
/// Returns the delta to add (already signed in ℤ₂⁶⁴).
pub fn pairwise_mask(
    shared_secret: &[u8; 32],
    me: usize,
    peer: usize,
    round: u64,
    tensor_tag: u32,
    len: usize,
) -> Vec<u64> {
    assert_ne!(me, peer);
    let mut words = mask_words(shared_secret, round, tensor_tag, len);
    if peer < me {
        // in place: the old map/collect allocated a second full
        // tensor on the client hot path
        z64::wrap_neg(&mut words);
    }
    words
}

/// Accumulate the total mask for client `me` over all peers (Eq. 3).
/// One output allocation; each peer's stream folds straight into the
/// accumulator through the SIMD window path (the old form allocated a
/// full signed mask vector per peer).
pub fn total_mask(
    secrets: &[(usize, [u8; 32])], // (peer index, shared secret)
    me: usize,
    round: u64,
    tensor_tag: u32,
    len: usize,
) -> Vec<u64> {
    let mut acc = vec![0u64; len];
    TotalMaskStream::new(secrets, me, round, tensor_tag).add_window(0, &mut acc);
    acc
}

// ---------------------------------------------------------------------------
// Windowed access: the streaming pipeline's view of the same keystream
// ---------------------------------------------------------------------------

/// One signed pairwise mask stream, addressable by `(offset, len)`
/// windows. `window` output is bit-identical to the corresponding
/// slice of [`pairwise_mask`] — ChaCha20 seeks to block `offset / 8`
/// instead of expanding from word 0. `Clone` hands an [`ExpandPool`]
/// worker its own seekable view of the same keystream.
#[derive(Clone)]
pub struct MaskStream {
    cipher: ChaCha20,
    /// True when this peer's mask is subtracted (peer < me, Eq. 3).
    negate: bool,
}

impl MaskStream {
    /// The stream client `me` adds against `peer` for (round, tag).
    pub fn pairwise(
        shared_secret: &[u8; 32],
        me: usize,
        peer: usize,
        round: u64,
        tensor_tag: u32,
    ) -> Self {
        assert_ne!(me, peer);
        MaskStream { cipher: mask_cipher(shared_secret, round, tensor_tag), negate: peer < me }
    }

    /// Wrap-add the mask words for `[offset, offset + out.len())` into
    /// `out` (already signed, so accumulating windows from several
    /// streams is the windowed form of [`total_mask`]). Dispatches the
    /// aligned interior through the 4-block SIMD keystream core when
    /// one is active; bit-identical to [`Self::add_window_scalar`] for
    /// every `(offset, len)`.
    pub fn add_window(&self, offset: usize, out: &mut [u64]) {
        self.fold_window(offset, out, active_isa() != SimdIsa::Scalar);
    }

    /// The original single-block reference path — what `VFL_SIMD=off`
    /// pins at runtime. Public as the bit-identity anchor for the
    /// SIMD sweep tests and the scalar leg of the microbench.
    pub fn add_window_scalar(&self, offset: usize, out: &mut [u64]) {
        self.fold_window(offset, out, false);
    }

    /// Shared fold body. `x4 = true` expands aligned interior spans
    /// four blocks per keystream dispatch: a leading partial block
    /// aligns `pos` upward through the scalar core, 32-word groups run
    /// the vector core, the ragged tail is scalar again.
    fn fold_window(&self, offset: usize, out: &mut [u64], x4: bool) {
        if out.is_empty() {
            return;
        }
        let end = offset + out.len();
        let mut pos = offset; // absolute word index into the stream
        let mut block = [0u64; WORDS_PER_BLOCK];
        if pos % WORDS_PER_BLOCK != 0 {
            let b = pos / WORDS_PER_BLOCK;
            let lo = pos % WORDS_PER_BLOCK;
            let hi = end.min((b + 1) * WORDS_PER_BLOCK);
            self.block_u64(b, &mut block);
            self.fold(&mut out[..hi - pos], &block[lo..lo + (hi - pos)]);
            pos = hi;
        }
        if x4 {
            let mut group = [0u64; X4_WORDS_U64];
            while end - pos >= X4_WORDS_U64 {
                let b = pos / WORDS_PER_BLOCK;
                // checked span for the whole group — the old unchecked
                // `b as u32` is exactly the wrap bug this guards
                let counter = block_counter(b + 3) - 3;
                self.cipher.four_blocks_u64_into(counter, &mut group);
                self.fold(&mut out[pos - offset..pos - offset + X4_WORDS_U64], &group);
                pos += X4_WORDS_U64;
            }
        }
        while pos < end {
            let b = pos / WORDS_PER_BLOCK;
            let n = (end - pos).min(WORDS_PER_BLOCK);
            self.block_u64(b, &mut block);
            self.fold(&mut out[pos - offset..pos - offset + n], &block[..n]);
            pos += n;
        }
    }

    /// Fold one keystream span into the output with the stream's sign.
    /// Sign hoisted out of the word loop (the old code branched per
    /// word); both directions are lane-chunked in [`crate::z64`].
    #[inline]
    fn fold(&self, dst: &mut [u64], src: &[u64]) {
        if self.negate {
            z64::wrap_sub(dst, src);
        } else {
            z64::wrap_add(dst, src);
        }
    }

    /// One scalar keystream block as u64 mask words.
    #[inline]
    fn block_u64(&self, block: usize, out: &mut [u64; WORDS_PER_BLOCK]) {
        let words = self.cipher.block_words(block_counter(block));
        for (j, o) in out.iter_mut().enumerate() {
            *o = (words[2 * j] as u64) | ((words[2 * j + 1] as u64) << 32);
        }
    }

    /// Materialize one window on its own (mainly for tests).
    pub fn window(&self, offset: usize, len: usize) -> Vec<u64> {
        let mut out = vec![0u64; len];
        self.add_window(offset, &mut out);
        out
    }
}

/// A client's total mask over all peers (Eq. 3) as a windowed stream:
/// the chunked twin of [`total_mask`]. Windows are wrap-added, so any
/// partition of `[0, len)` into windows reproduces the monolithic
/// vector bit-for-bit — the property that makes [`ExpandPool`]'s
/// disjoint sub-window expansion bit-identical to serial. `Clone` so
/// each pool worker owns its own seekable view.
#[derive(Clone)]
pub struct TotalMaskStream {
    streams: Vec<MaskStream>,
}

impl TotalMaskStream {
    pub fn new(secrets: &[(usize, [u8; 32])], me: usize, round: u64, tensor_tag: u32) -> Self {
        let streams = secrets
            .iter()
            .map(|(peer, ss)| MaskStream::pairwise(ss, me, *peer, round, tensor_tag))
            .collect();
        TotalMaskStream { streams }
    }

    /// Wrap-add the total-mask words for the window starting at
    /// `offset` into `out`.
    pub fn add_window(&self, offset: usize, out: &mut [u64]) {
        for s in &self.streams {
            s.add_window(offset, out);
        }
    }

    /// The scalar reference leg of [`Self::add_window`] — the anchor
    /// the SIMD sweep tests pin dispatch output against, whatever ISA
    /// the host actually probed.
    pub fn add_window_scalar(&self, offset: usize, out: &mut [u64]) {
        for s in &self.streams {
            s.add_window_scalar(offset, out);
        }
    }
}

// ---------------------------------------------------------------------------
// Parallel expansion: the multi-core view of the same keystream
// ---------------------------------------------------------------------------

/// Split the absolute word window `[offset, offset + len)` into at
/// most `parts` contiguous, disjoint sub-windows, in offset order.
/// Interior cuts are aligned *up* to absolute [`X4_WORDS_U64`]-word
/// boundaries so every sub-window's grouped x4 interior stays
/// block-aligned — a perf choice only: the window-partition property
/// (`total_stream_windows_reassemble_total_mask`) makes ANY partition
/// reassemble the monolithic expansion bit-for-bit. Short windows
/// yield fewer parts (possibly one); the parts always cover the input
/// window exactly.
pub fn partition_window(offset: usize, len: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.max(1);
    let end = offset + len;
    let base = len / parts;
    let rem = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = offset;
    for k in 0..parts {
        // ideal balanced cut, then aligned up to the x4 group boundary
        let ideal = offset + (k + 1) * base + (k + 1).min(rem);
        let cut = if k + 1 == parts {
            end
        } else {
            (ideal.div_ceil(X4_WORDS_U64) * X4_WORDS_U64).min(end)
        };
        if cut > start {
            out.push((start, cut - start));
            start = cut;
        }
    }
    out
}

/// A type-erased unit of expansion work: runs on one pool worker and
/// replies through the channel its closure captured.
type ExpandTask = Box<dyn FnOnce() + Send + 'static>;

/// Bounded task-queue depth per expand worker. Fork-join batches are
/// at most one task per worker, so this never blocks the dispatcher;
/// the bound exists so a buggy caller fails loudly instead of growing
/// an unbounded queue.
const EXPAND_QUEUE_DEPTH: usize = 64;

/// A small hand-rolled fork-join pool for parallel mask expansion
/// (`--expand-workers`): the multi-core answer to one core's ChaCha20
/// keystream rate capping client masking throughput. Same std-only
/// pattern as the aggregator's accumulator
/// [`WorkerPool`](crate::coordinator::streaming::WorkerPool) — named
/// detached threads fed over bounded channels, exiting when the pool
/// drops and the channels close.
///
/// Determinism: [`Self::run`] returns results **in job order**
/// whatever order workers finish in, so a caller that partitions a
/// window with [`partition_window`], expands each sub-window on a
/// worker, and stitches the results in order produces bytes
/// bit-identical to the serial expansion — by the window-partition
/// property, not by scheduling luck.
pub struct ExpandPool {
    txs: Vec<std::sync::mpsc::SyncSender<ExpandTask>>,
}

impl ExpandPool {
    /// Spawn `workers` expansion workers (≥ 1). Threads are detached
    /// on purpose, mirroring the accumulator pool: each worker's loop
    /// ends when the pool (the only sender) drops, and workers hold
    /// nothing but transient job state, so exit-by-channel-closure is
    /// a clean shutdown.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let mut txs = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = std::sync::mpsc::sync_channel::<ExpandTask>(EXPAND_QUEUE_DEPTH);
            std::thread::Builder::new()
                .name(format!("expand-worker-{w}"))
                .spawn(move || {
                    while let Ok(task) = rx.recv() {
                        task();
                    }
                })
                .expect("spawn expand worker");
            txs.push(tx);
        }
        ExpandPool { txs }
    }

    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Fork-join: dispatch every job round-robin across the workers,
    /// wait for all replies, and return the results **in job order**
    /// (the deterministic stitch). A worker that panics loses its
    /// reply sender; the join then panics here instead of deadlocking.
    pub fn run<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> T + Send + 'static>>,
    ) -> Vec<T> {
        let n = jobs.len();
        let (rtx, rrx) = std::sync::mpsc::channel::<(usize, T)>();
        for (i, job) in jobs.into_iter().enumerate() {
            let reply = rtx.clone();
            let task: ExpandTask = Box::new(move || {
                let _ = reply.send((i, job()));
            });
            self.txs[i % self.txs.len()].send(task).expect("expand worker alive");
        }
        drop(rtx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, v) = rrx.recv().expect("expand job lost (worker panicked)");
            slots[i] = Some(v);
        }
        slots.into_iter().map(|v| v.expect("every expand job replies exactly once")).collect()
    }

    /// Expand the total-mask window `[offset, offset + out.len())`
    /// across the pool: partition into per-worker sub-windows, fold
    /// each on a worker via the seekable window path, stitch in offset
    /// order. Wrap-adds into `out`, exactly like
    /// [`TotalMaskStream::add_window`] — and bit-identical to it.
    pub fn add_window(&self, stream: &TotalMaskStream, offset: usize, out: &mut [u64]) {
        let parts = partition_window(offset, out.len(), self.workers());
        if parts.len() <= 1 {
            stream.add_window(offset, out);
            return;
        }
        let jobs: Vec<Box<dyn FnOnce() -> Vec<u64> + Send + 'static>> = parts
            .iter()
            .map(|&(off, len)| {
                let s = stream.clone();
                let f: Box<dyn FnOnce() -> Vec<u64> + Send + 'static> = Box::new(move || {
                    let mut seg = vec![0u64; len];
                    s.add_window(off, &mut seg);
                    seg
                });
                f
            })
            .collect();
        for (seg, &(off, _)) in self.run(jobs).iter().zip(&parts) {
            z64::wrap_add(&mut out[off - offset..off - offset + seg.len()], seg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ss(i: usize, j: usize) -> [u8; 32] {
        // symmetric synthetic shared secret for the pair {i, j}
        let (lo, hi) = if i < j { (i, j) } else { (j, i) };
        let mut s = [0u8; 32];
        s[0] = lo as u8;
        s[1] = hi as u8;
        s[2] = 0xA5;
        s
    }

    #[test]
    fn masks_cancel_over_all_parties(){
        // Eq. 4: sum over all clients of their total mask == 0
        for n in [2usize, 3, 5, 8] {
            let len = 37;
            let mut sum = vec![0u64; len];
            for me in 0..n {
                let secrets: Vec<(usize, [u8; 32])> =
                    (0..n).filter(|&p| p != me).map(|p| (p, ss(me, p))).collect();
                let m = total_mask(&secrets, me, 12, 3, len);
                for (s, v) in sum.iter_mut().zip(m.iter()) {
                    *s = s.wrapping_add(*v);
                }
            }
            assert!(sum.iter().all(|&v| v == 0), "masks must cancel for n={n}");
        }
    }

    #[test]
    fn masks_differ_per_round_and_tensor() {
        let s = ss(0, 1);
        let a = mask_words(&s, 1, 0, 8);
        let b = mask_words(&s, 2, 0, 8);
        let c = mask_words(&s, 1, 1, 8);
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn pairwise_antisymmetry() {
        let s = ss(3, 7);
        let m37 = pairwise_mask(&s, 3, 7, 5, 0, 16);
        let m73 = pairwise_mask(&s, 7, 3, 5, 0, 16);
        for (a, b) in m37.iter().zip(m73.iter()) {
            assert_eq!(a.wrapping_add(*b), 0);
        }
    }

    #[test]
    fn deterministic_given_secret() {
        let s = ss(1, 2);
        assert_eq!(mask_words(&s, 9, 4, 100), mask_words(&s, 9, 4, 100));
    }

    #[test]
    fn total_mask_matches_per_peer_fold() {
        // total_mask is now windowed + SIMD-grouped internally; pin it
        // to the original definition — a plain fold of signed per-peer
        // mask vectors
        let me = 2usize;
        let secrets: Vec<(usize, [u8; 32])> =
            (0..6).filter(|&p| p != me).map(|p| (p, ss(me, p))).collect();
        for len in [1usize, 7, 8, 33, 100] {
            let mut want = vec![0u64; len];
            for (peer, s) in &secrets {
                for (a, d) in want.iter_mut().zip(pairwise_mask(s, me, *peer, 4, 1, len)) {
                    *a = a.wrapping_add(d);
                }
            }
            assert_eq!(total_mask(&secrets, me, 4, 1, len), want, "len={len}");
        }
    }

    #[test]
    fn window_matches_monolithic_slice() {
        // every (offset, len) window — aligned or not — must equal the
        // corresponding slice of the monolithic expansion
        let s = ss(2, 5);
        let full = pairwise_mask(&s, 2, 5, 11, 3, 100);
        let stream = MaskStream::pairwise(&s, 2, 5, 11, 3);
        for (offset, len) in [(0, 100), (0, 7), (7, 9), (8, 8), (1, 1), (63, 37), (95, 5)] {
            assert_eq!(stream.window(offset, len), full[offset..offset + len], "({offset},{len})");
        }
        // negated direction too
        let full = pairwise_mask(&s, 5, 2, 11, 3, 100);
        let stream = MaskStream::pairwise(&s, 5, 2, 11, 3);
        assert_eq!(stream.window(3, 50), full[3..53]);
    }

    #[test]
    fn total_stream_windows_reassemble_total_mask() {
        // chunked expansion ≡ total_mask bit-for-bit for lengths not
        // divisible by the chunk size
        let me = 1usize;
        let secrets: Vec<(usize, [u8; 32])> =
            (0..5).filter(|&p| p != me).map(|p| (p, ss(me, p))).collect();
        for len in [1usize, 7, 8, 64, 129] {
            let full = total_mask(&secrets, me, 9, 2, len);
            let stream = TotalMaskStream::new(&secrets, me, 9, 2);
            for chunk in [1usize, 3, 8, 50] {
                let mut got = vec![0u64; len];
                let mut off = 0;
                while off < len {
                    let n = chunk.min(len - off);
                    stream.add_window(off, &mut got[off..off + n]);
                    off += n;
                }
                assert_eq!(got, full, "len={len} chunk={chunk}");
            }
        }
    }

    #[test]
    fn masked_sum_reveals_only_total() {
        // secure aggregation end-to-end in Z_2^64: three parties, values xi;
        // aggregator sees only xi + mi, sum equals sum(xi).
        let n = 3;
        let len = 10;
        let values: Vec<Vec<u64>> = (0..n)
            .map(|i| (0..len).map(|j| (i * 1000 + j) as u64).collect())
            .collect();
        let mut agg = vec![0u64; len];
        for me in 0..n {
            let secrets: Vec<(usize, [u8; 32])> =
                (0..n).filter(|&p| p != me).map(|p| (p, ss(me, p))).collect();
            let mask = total_mask(&secrets, me, 0, 0, len);
            for j in 0..len {
                let masked = values[me][j].wrapping_add(mask[j]);
                // the masked value must differ from the raw value (whp)
                assert_ne!(masked, values[me][j]);
                agg[j] = agg[j].wrapping_add(masked);
            }
        }
        let want: Vec<u64> = (0..len).map(|j| (0..n).map(|i| (i * 1000 + j) as u64).sum()).collect();
        assert_eq!(agg, want);
    }

    // -- parallel expansion ≡ serial --------------------------------------

    #[test]
    fn partition_covers_window_disjoint_in_order() {
        for offset in [0usize, 1, 31, 32, 33, 100, 255, 256] {
            for len in [0usize, 1, 5, 31, 32, 33, 64, 100, 257, 1000] {
                for parts in [1usize, 2, 3, 5, 8] {
                    let p = partition_window(offset, len, parts);
                    assert!(p.len() <= parts, "({offset},{len},{parts})");
                    let mut pos = offset;
                    for &(off, n) in &p {
                        assert_eq!(off, pos, "contiguous ({offset},{len},{parts})");
                        assert!(n > 0, "no empty parts ({offset},{len},{parts})");
                        pos += n;
                    }
                    assert_eq!(pos, offset + len, "covers window ({offset},{len},{parts})");
                    // interior cuts are x4-group aligned (perf contract)
                    for &(off, _) in p.iter().skip(1) {
                        assert_eq!(off % X4_WORDS_U64, 0, "({offset},{len},{parts})");
                    }
                }
            }
        }
    }

    #[test]
    fn pool_run_returns_results_in_job_order() {
        let pool = ExpandPool::new(3);
        let jobs: Vec<Box<dyn FnOnce() -> Vec<u64> + Send>> = (0..17u64)
            .map(|i| {
                let f: Box<dyn FnOnce() -> Vec<u64> + Send> = Box::new(move || vec![i, i * i]);
                f
            })
            .collect();
        let got = pool.run(jobs);
        for (i, v) in got.iter().enumerate() {
            assert_eq!(v, &vec![i as u64, (i * i) as u64]);
        }
    }

    #[test]
    fn pooled_expansion_bit_identical_to_serial() {
        // the tentpole invariant: any worker count, any (offset, len),
        // pooled expansion ≡ the serial TotalMaskStream fold
        let me = 1usize;
        let secrets: Vec<(usize, [u8; 32])> =
            (0..5).filter(|&p| p != me).map(|p| (p, ss(me, p))).collect();
        let stream = TotalMaskStream::new(&secrets, me, 9, 2);
        for workers in [1usize, 2, 3, 8] {
            let pool = ExpandPool::new(workers);
            for (offset, len) in
                [(0usize, 1usize), (0, 31), (0, 1000), (7, 257), (32, 64), (100, 513)]
            {
                let mut serial = vec![0x11u64; len];
                stream.add_window(offset, &mut serial);
                let mut pooled = vec![0x11u64; len];
                pool.add_window(&stream, offset, &mut pooled);
                assert_eq!(pooled, serial, "workers={workers} ({offset},{len})");
            }
        }
    }

    // -- SIMD ≡ scalar sweep ---------------------------------------------

    #[test]
    fn grouped_and_scalar_windows_bit_identical() {
        // the x4-grouped expansion (portable lane core on scalar-only
        // hosts, AVX2/NEON where detected) must equal the single-block
        // scalar path for every alignment: offsets and lengths chosen
        // to hit empty/partial leading blocks, 0..3 interior groups,
        // and ragged tails, in both mask directions
        let s = ss(1, 4);
        for (me, peer) in [(1usize, 4usize), (4, 1)] {
            let stream = MaskStream::pairwise(&s, me, peer, 6, 2);
            for offset in [0usize, 1, 5, 7, 8, 9, 31, 32, 33, 100, 255, 256, 257] {
                for len in [0usize, 1, 3, 8, 17, 31, 32, 33, 64, 100, 129, 257] {
                    let mut grouped = vec![0x5a5au64; len];
                    let mut scalar = grouped.clone();
                    stream.fold_window(offset, &mut grouped, true);
                    stream.fold_window(offset, &mut scalar, false);
                    assert_eq!(grouped, scalar, "me={me} offset={offset} len={len}");
                }
            }
        }
    }

    #[test]
    fn public_window_paths_agree() {
        // whatever the process-level ISA, the public dispatch and the
        // public scalar anchor must agree
        let s = ss(0, 3);
        let stream = MaskStream::pairwise(&s, 3, 0, 2, 1);
        for (offset, len) in [(0usize, 257usize), (5, 96), (32, 32), (7, 200)] {
            let mut a = vec![1u64; len];
            let mut b = vec![1u64; len];
            stream.add_window(offset, &mut a);
            stream.add_window_scalar(offset, &mut b);
            assert_eq!(a, b, "({offset},{len})");
        }
    }

    // -- 32-bit block counter boundary (the `b as u32` wrap bug) ---------

    #[cfg(target_pointer_width = "64")]
    #[test]
    fn window_at_final_block_is_allowed() {
        let s = ss(0, 1);
        let stream = MaskStream::pairwise(&s, 0, 1, 3, 0);
        let offset = ((1usize << 32) - 1) * WORDS_PER_BLOCK;
        let mut out = [0u64; WORDS_PER_BLOCK];
        stream.add_window(offset, &mut out);
        assert_ne!(out, [0u64; WORDS_PER_BLOCK]);
    }

    #[cfg(target_pointer_width = "64")]
    #[test]
    #[should_panic(expected = "keystream would repeat")]
    fn window_past_final_block_panics() {
        let s = ss(0, 1);
        let stream = MaskStream::pairwise(&s, 0, 1, 3, 0);
        let mut out = [0u64; 1];
        stream.add_window((1usize << 32) * WORDS_PER_BLOCK, &mut out);
    }

    #[cfg(target_pointer_width = "64")]
    #[test]
    fn grouped_window_to_final_block_matches_scalar() {
        let s = ss(0, 1);
        let stream = MaskStream::pairwise(&s, 0, 1, 3, 0);
        let offset = ((1usize << 32) - 4) * WORDS_PER_BLOCK;
        let mut grouped = [0u64; X4_WORDS_U64];
        stream.fold_window(offset, &mut grouped, true);
        let mut scalar = [0u64; X4_WORDS_U64];
        stream.fold_window(offset, &mut scalar, false);
        assert_eq!(grouped, scalar);
    }

    #[cfg(target_pointer_width = "64")]
    #[test]
    #[should_panic(expected = "keystream would repeat")]
    fn grouped_window_past_final_block_panics() {
        // the grouped path must check the span of the whole 4-block
        // group, not just its first block
        let s = ss(0, 1);
        let stream = MaskStream::pairwise(&s, 0, 1, 3, 0);
        let mut out = [0u64; X4_WORDS_U64];
        stream.fold_window(((1usize << 32) - 3) * WORDS_PER_BLOCK, &mut out, true);
    }
}
