//! The pairwise-mask PRG of the secure-aggregation protocol (Eq. 3–4).
//!
//! Each pair of clients (i, j) shares a secret `ss_ij`; per round and
//! per tensor they expand it into a pseudo-random mask vector. Client
//! i adds `+PRG(ss_ij)` if `j > i` and `−PRG(ss_ij)` if `j < i`, so the
//! sum over all clients telescopes to zero (Eq. 4).
//!
//! Masks live in ℤ₂⁶⁴ (wrapping arithmetic) so cancellation is *exact*;
//! the fixed-point codec in [`crate::secagg`] maps float tensors into
//! that domain and back.

use super::chacha20::ChaCha20;
use super::hkdf;

/// Expand a shared secret into `len` uniform u64 mask words for a given
/// (round, tensor-tag) context. The context is bound into the nonce so
/// every round and tensor gets an independent mask stream.
pub fn mask_words(shared_secret: &[u8; 32], round: u64, tensor_tag: u32, len: usize) -> Vec<u64> {
    // Domain-separate the PRG key from other uses of the shared secret.
    let key = hkdf::derive_key32(b"vfl-sa/prg/v1", shared_secret, b"mask");
    let mut nonce = [0u8; 12];
    nonce[..8].copy_from_slice(&round.to_le_bytes());
    nonce[8..12].copy_from_slice(&tensor_tag.to_le_bytes());
    let cipher = ChaCha20::new(&key, &nonce, 0);
    let mut words = vec![0u64; len];
    cipher.keystream_u64(&mut words);
    words
}

/// The signed pairwise mask for client `me` against peer `peer`
/// (Eq. 3): added when `peer > me`, subtracted when `peer < me`.
/// Returns the delta to add (already signed in ℤ₂⁶⁴).
pub fn pairwise_mask(
    shared_secret: &[u8; 32],
    me: usize,
    peer: usize,
    round: u64,
    tensor_tag: u32,
    len: usize,
) -> Vec<u64> {
    assert_ne!(me, peer);
    let words = mask_words(shared_secret, round, tensor_tag, len);
    if peer > me {
        words
    } else {
        words.into_iter().map(|w| w.wrapping_neg()).collect()
    }
}

/// Accumulate the total mask for client `me` over all peers (Eq. 3).
pub fn total_mask(
    secrets: &[(usize, [u8; 32])], // (peer index, shared secret)
    me: usize,
    round: u64,
    tensor_tag: u32,
    len: usize,
) -> Vec<u64> {
    let mut acc = vec![0u64; len];
    for (peer, ss) in secrets {
        let delta = pairwise_mask(ss, me, *peer, round, tensor_tag, len);
        for (a, d) in acc.iter_mut().zip(delta.iter()) {
            *a = a.wrapping_add(*d);
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ss(i: usize, j: usize) -> [u8; 32] {
        // symmetric synthetic shared secret for the pair {i, j}
        let (lo, hi) = if i < j { (i, j) } else { (j, i) };
        let mut s = [0u8; 32];
        s[0] = lo as u8;
        s[1] = hi as u8;
        s[2] = 0xA5;
        s
    }

    #[test]
    fn masks_cancel_over_all_parties(){
        // Eq. 4: sum over all clients of their total mask == 0
        for n in [2usize, 3, 5, 8] {
            let len = 37;
            let mut sum = vec![0u64; len];
            for me in 0..n {
                let secrets: Vec<(usize, [u8; 32])> =
                    (0..n).filter(|&p| p != me).map(|p| (p, ss(me, p))).collect();
                let m = total_mask(&secrets, me, 12, 3, len);
                for (s, v) in sum.iter_mut().zip(m.iter()) {
                    *s = s.wrapping_add(*v);
                }
            }
            assert!(sum.iter().all(|&v| v == 0), "masks must cancel for n={n}");
        }
    }

    #[test]
    fn masks_differ_per_round_and_tensor() {
        let s = ss(0, 1);
        let a = mask_words(&s, 1, 0, 8);
        let b = mask_words(&s, 2, 0, 8);
        let c = mask_words(&s, 1, 1, 8);
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn pairwise_antisymmetry() {
        let s = ss(3, 7);
        let m37 = pairwise_mask(&s, 3, 7, 5, 0, 16);
        let m73 = pairwise_mask(&s, 7, 3, 5, 0, 16);
        for (a, b) in m37.iter().zip(m73.iter()) {
            assert_eq!(a.wrapping_add(*b), 0);
        }
    }

    #[test]
    fn deterministic_given_secret() {
        let s = ss(1, 2);
        assert_eq!(mask_words(&s, 9, 4, 100), mask_words(&s, 9, 4, 100));
    }

    #[test]
    fn masked_sum_reveals_only_total() {
        // secure aggregation end-to-end in Z_2^64: three parties, values xi;
        // aggregator sees only xi + mi, sum equals sum(xi).
        let n = 3;
        let len = 10;
        let values: Vec<Vec<u64>> = (0..n)
            .map(|i| (0..len).map(|j| (i * 1000 + j) as u64).collect())
            .collect();
        let mut agg = vec![0u64; len];
        for me in 0..n {
            let secrets: Vec<(usize, [u8; 32])> =
                (0..n).filter(|&p| p != me).map(|p| (p, ss(me, p))).collect();
            let mask = total_mask(&secrets, me, 0, 0, len);
            for j in 0..len {
                let masked = values[me][j].wrapping_add(mask[j]);
                // the masked value must differ from the raw value (whp)
                assert_ne!(masked, values[me][j]);
                agg[j] = agg[j].wrapping_add(masked);
            }
        }
        let want: Vec<u64> = (0..len).map(|j| (0..n).map(|i| (i * 1000 + j) as u64).sum()).collect();
        assert_eq!(agg, want);
    }
}
