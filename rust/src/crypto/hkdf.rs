//! HKDF-SHA256 (RFC 5869), from scratch.
//!
//! The setup phase derives, from each raw X25519 shared secret, the
//! per-pair AEAD key (sample-ID encryption) and the per-pair PRG seed
//! (pairwise masks) with domain-separating `info` labels.

use super::hmac::hmac_sha256;

/// HKDF-Extract.
pub fn extract(salt: &[u8], ikm: &[u8]) -> [u8; 32] {
    hmac_sha256(salt, ikm)
}

/// HKDF-Expand. Panics if `out.len() > 255 * 32`.
pub fn expand(prk: &[u8; 32], info: &[u8], out: &mut [u8]) {
    assert!(out.len() <= 255 * 32, "HKDF-Expand output too long");
    let mut t: Vec<u8> = Vec::new();
    let mut written = 0usize;
    let mut counter = 1u8;
    while written < out.len() {
        let mut msg = Vec::with_capacity(t.len() + info.len() + 1);
        msg.extend_from_slice(&t);
        msg.extend_from_slice(info);
        msg.push(counter);
        let block = hmac_sha256(prk, &msg);
        let take = (out.len() - written).min(32);
        out[written..written + take].copy_from_slice(&block[..take]);
        written += take;
        t = block.to_vec();
        counter = counter.wrapping_add(1);
    }
}

/// One-shot HKDF (extract + expand).
pub fn hkdf(salt: &[u8], ikm: &[u8], info: &[u8], out: &mut [u8]) {
    let prk = extract(salt, ikm);
    expand(&prk, info, out);
}

/// Convenience: derive a 32-byte key.
pub fn derive_key32(salt: &[u8], ikm: &[u8], info: &[u8]) -> [u8; 32] {
    let mut out = [0u8; 32];
    hkdf(salt, ikm, info, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(b: &[u8]) -> String {
        b.iter().map(|x| format!("{x:02x}")).collect()
    }

    fn unhex(s: &str) -> Vec<u8> {
        (0..s.len()).step_by(2).map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap()).collect()
    }

    // RFC 5869 Test Case 1.
    #[test]
    fn rfc5869_case1() {
        let ikm = [0x0bu8; 22];
        let salt = unhex("000102030405060708090a0b0c");
        let info = unhex("f0f1f2f3f4f5f6f7f8f9");
        let prk = extract(&salt, &ikm);
        assert_eq!(hex(&prk), "077709362c2e32df0ddc3f0dc47bba6390b6c73bb50f9c3122ec844ad7c2b3e5");
        let mut okm = [0u8; 42];
        expand(&prk, &info, &mut okm);
        assert_eq!(
            hex(&okm),
            "3cb25f25faacd57a90434f64d0362f2a2d2d0a90cf1a5a4c5db02d56ecc4c5bf34007208d5b887185865"
        );
    }

    // RFC 5869 Test Case 3 (zero-length salt/info).
    #[test]
    fn rfc5869_case3() {
        let ikm = [0x0bu8; 22];
        let prk = extract(&[], &ikm);
        let mut okm = [0u8; 42];
        expand(&prk, &[], &mut okm);
        assert_eq!(
            hex(&okm),
            "8da4e775a563c18f715f802a063c5a31b8a11f5c5ee1879ec3454e5f3c738d2d9d201395faa4b61a96c8"
        );
    }

    #[test]
    fn distinct_infos_give_distinct_keys() {
        let a = derive_key32(b"salt", b"secret", b"aead");
        let b = derive_key32(b"salt", b"secret", b"prg");
        assert_ne!(a, b);
    }

    #[test]
    fn long_output() {
        let mut out = [0u8; 255 * 32];
        hkdf(b"s", b"ikm", b"info", &mut out);
        // first block must match a manual expand
        let prk = extract(b"s", b"ikm");
        let mut first = [0u8; 32];
        expand(&prk, b"info", &mut first);
        assert_eq!(&out[..32], &first);
    }
}
