//! X25519 Diffie–Hellman key agreement (RFC 7748), from scratch.
//!
//! The setup phase (§4.0.1 of the paper) has every client generate one
//! keypair per peer; the aggregator relays public keys, and each pair
//! (i, j) derives a shared secret `ss_ij = ss_ji` used for both the
//! sample-ID AEAD key and the pairwise mask PRG seed.

use super::field25519::Fe;

/// A clamped X25519 secret key (32 bytes).
#[derive(Clone)]
pub struct SecretKey(pub [u8; 32]);

/// An X25519 public key (32 bytes, u-coordinate).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct PublicKey(pub [u8; 32]);

/// RFC 7748 scalar clamping.
pub fn clamp(mut k: [u8; 32]) -> [u8; 32] {
    k[0] &= 248;
    k[31] &= 127;
    k[31] |= 64;
    k
}

/// The X25519 function: scalar multiplication on the Montgomery curve
/// via the constant-time Montgomery ladder.
pub fn x25519(scalar: &[u8; 32], u: &[u8; 32]) -> [u8; 32] {
    let k = clamp(*scalar);
    let x1 = Fe::from_bytes(u);
    let mut x2 = Fe::ONE;
    let mut z2 = Fe::ZERO;
    let mut x3 = x1;
    let mut z3 = Fe::ONE;
    let mut swap = 0u64;

    for t in (0..255).rev() {
        let kt = ((k[t / 8] >> (t % 8)) & 1) as u64;
        swap ^= kt;
        Fe::cswap(&mut x2, &mut x3, swap);
        Fe::cswap(&mut z2, &mut z3, swap);
        swap = kt;

        let a = x2.add(z2);
        let aa = a.square();
        let b = x2.sub(z2);
        let bb = b.square();
        let e = aa.sub(bb);
        let c = x3.add(z3);
        let d = x3.sub(z3);
        let da = d.mul(a);
        let cb = c.mul(b);
        x3 = da.add(cb).square();
        z3 = x1.mul(da.sub(cb).square());
        x2 = aa.mul(bb);
        z2 = e.mul(aa.add(e.mul_small(121665)));
    }
    Fe::cswap(&mut x2, &mut x3, swap);
    Fe::cswap(&mut z2, &mut z3, swap);

    x2.mul(z2.invert()).to_bytes()
}

/// The canonical base point u = 9.
pub const BASEPOINT: [u8; 32] = {
    let mut b = [0u8; 32];
    b[0] = 9;
    b
};

impl SecretKey {
    /// Create a secret key from raw entropy (clamped on use).
    pub fn from_bytes(b: [u8; 32]) -> Self {
        SecretKey(b)
    }

    /// Derive the public key `sk·G`.
    pub fn public_key(&self) -> PublicKey {
        PublicKey(x25519(&self.0, &BASEPOINT))
    }

    /// Compute the raw shared secret with a peer's public key.
    pub fn diffie_hellman(&self, peer: &PublicKey) -> [u8; 32] {
        x25519(&self.0, &peer.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unhex32(s: &str) -> [u8; 32] {
        let v: Vec<u8> =
            (0..64).step_by(2).map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap()).collect();
        v.try_into().unwrap()
    }

    // RFC 7748 §5.2 test vector 1.
    #[test]
    fn rfc7748_vector1() {
        let k = unhex32("a546e36bf0527c9d3b16154b82465edd62144c0ac1fc5a18506a2244ba449ac4");
        let u = unhex32("e6db6867583030db3594c1a424b15f7c726624ec26b3353b10a903a6d0ab1c4c");
        let out = x25519(&k, &u);
        assert_eq!(out, unhex32("c3da55379de9c6908e94ea4df28d084f32eccf03491c71f754b4075577a28552"));
    }

    // RFC 7748 §5.2 test vector 2.
    #[test]
    fn rfc7748_vector2() {
        let k = unhex32("4b66e9d4d1b4673c5ad22691957d6af5c11b6421e0ea01d42ca4169e7918ba0d");
        let u = unhex32("e5210f12786811d3f4b7959d0538ae2c31dbe7106fc03c3efc4cd549c715a493");
        let out = x25519(&k, &u);
        assert_eq!(out, unhex32("95cbde9476e8907d7aade45cb4b873f88b595a68799fa152e6f8f7647aac7957"));
    }

    // RFC 7748 §5.2 iterated vector (1 and 1000 iterations).
    #[test]
    fn rfc7748_iterated() {
        let mut k = unhex32("0900000000000000000000000000000000000000000000000000000000000000");
        let mut u = k;
        for _ in 0..1 {
            let out = x25519(&k, &u);
            u = k;
            k = out;
        }
        assert_eq!(k, unhex32("422c8e7a6227d7bca1350b3e2bb7279f7897b87bb6854b783c60e80311ae3079"));
        for _ in 1..1000 {
            let out = x25519(&k, &u);
            u = k;
            k = out;
        }
        assert_eq!(k, unhex32("684cf59ba83309552800ef566f2f4d3c1c3887c49360e3875f2eb94d99532c51"));
    }

    // RFC 7748 §6.1 Diffie-Hellman test.
    #[test]
    fn rfc7748_dh() {
        let alice_sk = SecretKey::from_bytes(unhex32(
            "77076d0a7318a57d3c16c17251b26645df4c2f87ebc0992ab177fba51db92c2a",
        ));
        let bob_sk = SecretKey::from_bytes(unhex32(
            "5dab087e624a8a4b79e17f8b83800ee66f3bb1292618b6fd1c2f8b27ff88e0eb",
        ));
        let alice_pk = alice_sk.public_key();
        let bob_pk = bob_sk.public_key();
        assert_eq!(alice_pk.0, unhex32("8520f0098930a754748b7ddcb43ef75a0dbf3a0d26381af4eba4a98eaa9b4e6a"));
        assert_eq!(bob_pk.0, unhex32("de9edb7d7b7dc1b4d35b61c2ece435373f8343c85b78674dadfc7e146f882b4f"));
        let ss_a = alice_sk.diffie_hellman(&bob_pk);
        let ss_b = bob_sk.diffie_hellman(&alice_pk);
        assert_eq!(ss_a, ss_b);
        assert_eq!(ss_a, unhex32("4a5d9d5ba4ce2de1728e3bf480350f25e07e21c947d19e3376f09b3c1e161742"));
    }

    #[test]
    fn shared_secret_symmetry_random() {
        // deterministic pseudo-random keys
        for seed in 0u8..8 {
            let a = SecretKey::from_bytes(core::array::from_fn(|i| (i as u8).wrapping_mul(3).wrapping_add(seed)));
            let b = SecretKey::from_bytes(core::array::from_fn(|i| (i as u8).wrapping_mul(7).wrapping_add(seed + 1)));
            assert_eq!(a.diffie_hellman(&b.public_key()), b.diffie_hellman(&a.public_key()));
        }
    }
}
