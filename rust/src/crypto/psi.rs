//! Diffie–Hellman Private Set Intersection, from scratch.
//!
//! §4.0.2 of the paper assumes sample alignment "can be realized by
//! Private Set Intersection (Lu & Ding, 2020)". This module implements
//! the classic semi-honest DH-PSI: both parties hash their IDs into a
//! prime-order group and blind them with secret exponents; commutativity
//! of exponentiation lets them match doubly-blinded values without
//! revealing non-intersecting IDs.
//!
//! Group: the quadratic-residue subgroup of ℤ_p* for the 1536-bit MODP
//! prime of RFC 3526 (group 5); hashing into the group squares the
//! SHA-256-expanded digest.

use super::bigint::BigUint;
use super::sha256::Sha256;

/// RFC 3526 1536-bit MODP prime.
const MODP_1536: &str = "\
FFFFFFFFFFFFFFFFC90FDAA22168C234C4C6628B80DC1CD129024E088A67CC74\
020BBEA63B139B22514A08798E3404DDEF9519B3CD3A431B302B0A6DF25F1437\
4FE1356D6D51C245E485B576625E7EC6F44C42E9A637ED6B0BFF5CB6F406B7ED\
EE386BFB5A899FA5AE9F24117C4B1FE649286651ECE45B3DC2007CB8A163BF05\
98DA48361C55D39A69163FA8FD24CF5F83655D23DCA3AD961C62F356208552BB\
9ED529077096966D670C354E4ABC9804F1746C08CA237327FFFFFFFFFFFFFFFF";

/// PSI group context (shared, public parameters).
pub struct PsiGroup {
    pub p: BigUint,
    /// (p-1)/2, the order of the QR subgroup.
    pub q: BigUint,
}

impl Default for PsiGroup {
    fn default() -> Self {
        Self::new()
    }
}

impl PsiGroup {
    pub fn new() -> Self {
        let p = BigUint::from_hex(MODP_1536);
        let q = p.sub(&BigUint::one()).shr_bits(1);
        PsiGroup { p, q }
    }

    /// Hash an identifier into the QR subgroup: H(id) expanded to the
    /// modulus width, reduced mod p, then squared.
    pub fn hash_to_group(&self, id: &[u8]) -> BigUint {
        // expand SHA-256(id || counter) to 192 bytes
        let mut bytes = Vec::with_capacity(192);
        let mut counter = 0u32;
        while bytes.len() < 192 {
            let mut h = Sha256::new();
            h.update(b"vfl-sa/psi/v1");
            h.update(id);
            h.update(&counter.to_be_bytes());
            bytes.extend_from_slice(&h.finalize());
            counter += 1;
        }
        let x = BigUint::from_bytes_be(&bytes).rem(&self.p);
        x.mul_mod(&x, &self.p) // square → QR subgroup
    }

    /// Sample a secret exponent in [1, q).
    pub fn random_exponent(&self, rng: &mut dyn FnMut(&mut [u8])) -> BigUint {
        loop {
            let e = BigUint::random_below(&self.q, rng);
            if !e.is_zero() && !e.is_one() {
                return e;
            }
        }
    }

    /// Blind a group element with a secret exponent.
    pub fn blind(&self, elem: &BigUint, exp: &BigUint) -> BigUint {
        elem.mod_pow(exp, &self.p)
    }
}

/// One PSI participant holding an ID set and a secret exponent.
pub struct PsiParty {
    pub ids: Vec<Vec<u8>>,
    exp: BigUint,
}

impl PsiParty {
    pub fn new(ids: Vec<Vec<u8>>, group: &PsiGroup, rng: &mut dyn FnMut(&mut [u8])) -> Self {
        PsiParty { ids, exp: group.random_exponent(rng) }
    }

    /// Round 1: H(id)^a for each own id.
    pub fn blind_own(&self, group: &PsiGroup) -> Vec<BigUint> {
        self.ids.iter().map(|id| group.blind(&group.hash_to_group(id), &self.exp)).collect()
    }

    /// Round 2: raise the peer's blinded values to our exponent.
    pub fn blind_peer(&self, group: &PsiGroup, peer_blinded: &[BigUint]) -> Vec<BigUint> {
        peer_blinded.iter().map(|e| group.blind(e, &self.exp)).collect()
    }
}

/// Compute the intersection (as indices into `a_ids`) given both
/// double-blinded sets. `a_double[i]` must correspond to `a_ids[i]`.
pub fn intersect_indices(a_double: &[BigUint], b_double: &[BigUint]) -> Vec<usize> {
    use std::collections::HashSet;
    let b_set: HashSet<Vec<u8>> = b_double.iter().map(|e| e.to_bytes_be()).collect();
    a_double
        .iter()
        .enumerate()
        .filter(|(_, e)| b_set.contains(&e.to_bytes_be()))
        .map(|(i, _)| i)
        .collect()
}

/// Full two-party PSI exchange (driver used by tests and the sample-
/// alignment phase of the coordinator).
pub fn run_psi(a: &PsiParty, b: &PsiParty, group: &PsiGroup) -> (Vec<usize>, Vec<usize>) {
    let a1 = a.blind_own(group);
    let b1 = b.blind_own(group);
    // each raises the other's to their own exponent: H(id)^(ab)
    let a2 = b.blind_peer(group, &a1); // a's ids double-blinded
    let b2 = a.blind_peer(group, &b1); // b's ids double-blinded
    (intersect_indices(&a2, &b2), intersect_indices(&b2, &a2))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::DetRng;

    fn ids(v: &[&str]) -> Vec<Vec<u8>> {
        v.iter().map(|s| s.as_bytes().to_vec()).collect()
    }

    #[test]
    fn intersection_found() {
        let group = PsiGroup::new();
        let mut rng = DetRng::from_seed(1).as_fill_fn();
        let a = PsiParty::new(ids(&["alice", "bob", "carol", "dave"]), &group, &mut rng);
        let b = PsiParty::new(ids(&["eve", "bob", "dave", "frank", "grace"]), &group, &mut rng);
        let (ia, ib) = run_psi(&a, &b, &group);
        let got_a: Vec<&[u8]> = ia.iter().map(|&i| a.ids[i].as_slice()).collect();
        assert_eq!(got_a, vec![b"bob".as_slice(), b"dave".as_slice()]);
        let got_b: Vec<&[u8]> = ib.iter().map(|&i| b.ids[i].as_slice()).collect();
        assert_eq!(got_b.len(), 2);
        assert!(got_b.contains(&b"bob".as_slice()) && got_b.contains(&b"dave".as_slice()));
    }

    #[test]
    fn empty_intersection() {
        let group = PsiGroup::new();
        let mut rng = DetRng::from_seed(2).as_fill_fn();
        let a = PsiParty::new(ids(&["x1", "x2"]), &group, &mut rng);
        let b = PsiParty::new(ids(&["y1", "y2"]), &group, &mut rng);
        let (ia, ib) = run_psi(&a, &b, &group);
        assert!(ia.is_empty() && ib.is_empty());
    }

    #[test]
    fn blinding_hides_ids() {
        // the same id blinded under different exponents must differ
        let group = PsiGroup::new();
        let mut rng = DetRng::from_seed(3).as_fill_fn();
        let a = PsiParty::new(ids(&["secret-id"]), &group, &mut rng);
        let b = PsiParty::new(ids(&["secret-id"]), &group, &mut rng);
        let ba = a.blind_own(&group);
        let bb = b.blind_own(&group);
        assert_ne!(ba[0], bb[0]);
        // ...but double-blinding commutes
        let (ia, _) = run_psi(&a, &b, &group);
        assert_eq!(ia, vec![0]);
    }

    #[test]
    fn hash_to_group_is_deterministic_and_spread() {
        let group = PsiGroup::new();
        let h1 = group.hash_to_group(b"id-1");
        let h2 = group.hash_to_group(b"id-1");
        let h3 = group.hash_to_group(b"id-2");
        assert_eq!(h1, h2);
        assert_ne!(h1, h3);
    }
}
