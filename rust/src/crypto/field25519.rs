//! Field arithmetic over GF(2²⁵⁵ − 19), from scratch.
//!
//! Radix-2⁵¹ representation (five 51-bit limbs in `u64`), the classic
//! donna/ref10 layout. Shared by the X25519 Montgomery ladder
//! ([`crate::crypto::x25519`]) and the Ed25519 Edwards-curve signature
//! scheme ([`crate::crypto::ed25519`]).

/// An element of GF(2²⁵⁵−19); limbs may be loosely reduced (< 2⁵² each).
#[derive(Clone, Copy, Debug)]
pub struct Fe(pub [u64; 5]);

const MASK51: u64 = (1u64 << 51) - 1;

impl Fe {
    pub const ZERO: Fe = Fe([0; 5]);
    pub const ONE: Fe = Fe([1, 0, 0, 0, 0]);

    /// Load from 32 little-endian bytes (top bit ignored, per RFC 7748).
    pub fn from_bytes(b: &[u8; 32]) -> Fe {
        let lo = |i: usize| -> u64 { u64::from_le_bytes(b[i..i + 8].try_into().unwrap()) };
        let f0 = lo(0) & MASK51;
        let f1 = (lo(6) >> 3) & MASK51;
        let f2 = (lo(12) >> 6) & MASK51;
        let f3 = (lo(19) >> 1) & MASK51;
        let f4 = (lo(24) >> 12) & MASK51;
        Fe([f0, f1, f2, f3, f4])
    }

    /// Serialize to 32 little-endian bytes with full canonical reduction.
    pub fn to_bytes(self) -> [u8; 32] {
        let mut t = self.reduce_limbs().0;
        // canonical reduction: compute t + 19, if it carries past 2^255 then subtract p
        // standard trick: q = (t + 19) >> 255
        let mut q = (t[0] + 19) >> 51;
        q = (t[1] + q) >> 51;
        q = (t[2] + q) >> 51;
        q = (t[3] + q) >> 51;
        q = (t[4] + q) >> 51;
        t[0] += 19 * q;
        let mut carry = t[0] >> 51;
        t[0] &= MASK51;
        t[1] += carry;
        carry = t[1] >> 51;
        t[1] &= MASK51;
        t[2] += carry;
        carry = t[2] >> 51;
        t[2] &= MASK51;
        t[3] += carry;
        carry = t[3] >> 51;
        t[3] &= MASK51;
        t[4] += carry;
        t[4] &= MASK51;

        let mut out = [0u8; 32];
        let lo0 = t[0] | (t[1] << 51);
        let lo1 = (t[1] >> 13) | (t[2] << 38);
        let lo2 = (t[2] >> 26) | (t[3] << 25);
        let lo3 = (t[3] >> 39) | (t[4] << 12);
        out[0..8].copy_from_slice(&lo0.to_le_bytes());
        out[8..16].copy_from_slice(&lo1.to_le_bytes());
        out[16..24].copy_from_slice(&lo2.to_le_bytes());
        out[24..32].copy_from_slice(&lo3.to_le_bytes());
        out
    }

    /// Carry-propagate so every limb is < 2⁵¹ (plus the ×19 folding).
    pub fn reduce_limbs(self) -> Fe {
        let mut t = self.0;
        let mut c: u64;
        c = t[0] >> 51;
        t[0] &= MASK51;
        t[1] += c;
        c = t[1] >> 51;
        t[1] &= MASK51;
        t[2] += c;
        c = t[2] >> 51;
        t[2] &= MASK51;
        t[3] += c;
        c = t[3] >> 51;
        t[3] &= MASK51;
        t[4] += c;
        c = t[4] >> 51;
        t[4] &= MASK51;
        t[0] += c * 19;
        c = t[0] >> 51;
        t[0] &= MASK51;
        t[1] += c;
        Fe(t)
    }

    pub fn add(self, rhs: Fe) -> Fe {
        let a = self.0;
        let b = rhs.0;
        Fe([a[0] + b[0], a[1] + b[1], a[2] + b[2], a[3] + b[3], a[4] + b[4]]).reduce_limbs()
    }

    pub fn sub(self, rhs: Fe) -> Fe {
        // add 2p to avoid underflow (limbs are < 2^52)
        let a = self.0;
        let b = rhs.0;
        Fe([
            a[0] + 0xfffffffffffda - b[0],
            a[1] + 0xffffffffffffe - b[1],
            a[2] + 0xffffffffffffe - b[2],
            a[3] + 0xffffffffffffe - b[3],
            a[4] + 0xffffffffffffe - b[4],
        ])
        .reduce_limbs()
    }

    pub fn neg(self) -> Fe {
        Fe::ZERO.sub(self)
    }

    pub fn mul(self, rhs: Fe) -> Fe {
        let a = self.reduce_limbs().0;
        let b = rhs.reduce_limbs().0;
        let m = |x: u64, y: u64| -> u128 { (x as u128) * (y as u128) };
        let b1_19 = b[1] * 19;
        let b2_19 = b[2] * 19;
        let b3_19 = b[3] * 19;
        let b4_19 = b[4] * 19;

        let c0 = m(a[0], b[0]) + m(a[1], b4_19) + m(a[2], b3_19) + m(a[3], b2_19) + m(a[4], b1_19);
        let c1 = m(a[0], b[1]) + m(a[1], b[0]) + m(a[2], b4_19) + m(a[3], b3_19) + m(a[4], b2_19);
        let c2 = m(a[0], b[2]) + m(a[1], b[1]) + m(a[2], b[0]) + m(a[3], b4_19) + m(a[4], b3_19);
        let c3 = m(a[0], b[3]) + m(a[1], b[2]) + m(a[2], b[1]) + m(a[3], b[0]) + m(a[4], b4_19);
        let c4 = m(a[0], b[4]) + m(a[1], b[3]) + m(a[2], b[2]) + m(a[3], b[1]) + m(a[4], b[0]);

        Self::carry_wide([c0, c1, c2, c3, c4])
    }

    pub fn square(self) -> Fe {
        self.mul(self)
    }

    fn carry_wide(mut c: [u128; 5]) -> Fe {
        let mut t = [0u64; 5];
        let mut carry: u128 = 0;
        for i in 0..5 {
            c[i] += carry;
            t[i] = (c[i] as u64) & MASK51;
            carry = c[i] >> 51;
        }
        t[0] += (carry as u64) * 19;
        Fe(t).reduce_limbs()
    }

    /// Multiply by a small scalar.
    pub fn mul_small(self, k: u64) -> Fe {
        let a = self.reduce_limbs().0;
        let c: [u128; 5] = core::array::from_fn(|i| (a[i] as u128) * (k as u128));
        Self::carry_wide(c)
    }

    /// Raise to an arbitrary power given big-endian exponent bits.
    fn pow_bits(self, bits: &[u8]) -> Fe {
        let mut acc = Fe::ONE;
        for &bit in bits {
            acc = acc.square();
            if bit == 1 {
                acc = acc.mul(self);
            }
        }
        acc
    }

    fn exponent_bits(bytes_le: &[u8; 32]) -> Vec<u8> {
        let mut bits = Vec::with_capacity(256);
        for i in (0..32).rev() {
            for j in (0..8).rev() {
                bits.push((bytes_le[i] >> j) & 1);
            }
        }
        // strip leading zeros
        let first_one = bits.iter().position(|&b| b == 1).unwrap_or(bits.len());
        bits.split_off(first_one)
    }

    /// Multiplicative inverse via Fermat: self^(p−2).
    pub fn invert(self) -> Fe {
        // p - 2 = 2^255 - 21
        let mut e = [0xffu8; 32];
        e[0] = 0xeb; // 0xed - 2
        e[31] = 0x7f;
        self.pow_bits(&Self::exponent_bits(&e))
    }

    /// self^((p−5)/8), used for square roots (ref10 `pow22523`).
    pub fn pow_p58(self) -> Fe {
        // (p-5)/8 = (2^255 - 24)/8 = 2^252 - 3
        let mut e = [0xffu8; 32];
        e[0] = 0xfd;
        e[31] = 0x0f;
        self.pow_bits(&Self::exponent_bits(&e))
    }

    pub fn is_zero(self) -> bool {
        self.to_bytes() == [0u8; 32]
    }

    /// Parity of the canonical representation (bit 0).
    pub fn is_negative(self) -> bool {
        self.to_bytes()[0] & 1 == 1
    }

    pub fn equals(self, rhs: Fe) -> bool {
        self.to_bytes() == rhs.to_bytes()
    }

    /// Constant-time conditional swap.
    pub fn cswap(a: &mut Fe, b: &mut Fe, swap: u64) {
        let mask = 0u64.wrapping_sub(swap);
        for i in 0..5 {
            let x = mask & (a.0[i] ^ b.0[i]);
            a.0[i] ^= x;
            b.0[i] ^= x;
        }
    }

    /// Small-constant constructor.
    pub fn from_u64(v: u64) -> Fe {
        Fe([v & MASK51, v >> 51, 0, 0, 0])
    }
}

/// √−1 mod p (for Ed25519 point decompression).
pub fn sqrt_m1() -> Fe {
    // 2^((p-1)/4)
    let two = Fe::from_u64(2);
    // (p-1)/4 = (2^255 - 20) / 4 = 2^253 - 5
    let mut e = [0xffu8; 32];
    e[0] = 0xfb;
    e[31] = 0x1f;
    let mut bits = Vec::with_capacity(256);
    for i in (0..32).rev() {
        for j in (0..8).rev() {
            bits.push((e[i] >> j) & 1);
        }
    }
    let first_one = bits.iter().position(|&b| b == 1).unwrap();
    let bits = &bits[first_one..];
    let mut acc = Fe::ONE;
    for &bit in bits {
        acc = acc.square();
        if bit == 1 {
            acc = acc.mul(two);
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fe(v: u64) -> Fe {
        Fe::from_u64(v)
    }

    #[test]
    fn add_sub_roundtrip() {
        let a = fe(123456789);
        let b = fe(987654321);
        assert!(a.add(b).sub(b).equals(a));
        assert!(a.sub(b).add(b).equals(a));
    }

    #[test]
    fn mul_matches_small_ints() {
        assert!(fe(7).mul(fe(6)).equals(fe(42)));
        assert!(fe(1 << 30).mul(fe(1 << 30)).equals(Fe([0, 0x200, 0, 0, 0]))); // 2^60
    }

    #[test]
    fn invert_roundtrip() {
        let a = fe(0xdeadbeefcafe);
        let inv = a.invert();
        assert!(a.mul(inv).equals(Fe::ONE));
    }

    #[test]
    fn neg_and_sub() {
        let a = fe(5);
        assert!(a.add(a.neg()).is_zero());
    }

    #[test]
    fn sqrt_m1_squares_to_minus_one() {
        let i = sqrt_m1();
        let m1 = Fe::ZERO.sub(Fe::ONE);
        assert!(i.square().equals(m1));
    }

    #[test]
    fn bytes_roundtrip() {
        let mut b = [0u8; 32];
        for i in 0..32 {
            b[i] = (i as u8).wrapping_mul(37).wrapping_add(1);
        }
        b[31] &= 0x7f;
        let f = Fe::from_bytes(&b);
        // from_bytes . to_bytes is canonical-reduce; applying twice is stable
        let c = f.to_bytes();
        let f2 = Fe::from_bytes(&c);
        assert_eq!(f2.to_bytes(), c);
    }

    #[test]
    fn p_reduces_to_zero() {
        // p = 2^255 - 19 in little-endian bytes
        let mut p = [0xffu8; 32];
        p[0] = 0xed;
        p[31] = 0x7f;
        let f = Fe::from_bytes(&p);
        assert!(f.is_zero());
    }

    #[test]
    fn cswap_works() {
        let mut a = fe(1);
        let mut b = fe(2);
        Fe::cswap(&mut a, &mut b, 0);
        assert!(a.equals(fe(1)));
        Fe::cswap(&mut a, &mut b, 1);
        assert!(a.equals(fe(2)) && b.equals(fe(1)));
    }

    #[test]
    fn distributive_law() {
        let a = fe(0x123456789abcd);
        let b = fe(0xfedcba987654);
        let c = fe(0x1111111111111);
        let lhs = a.mul(b.add(c));
        let rhs = a.mul(b).add(a.mul(c));
        assert!(lhs.equals(rhs));
    }
}
