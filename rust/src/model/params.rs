//! Model parameters: per-party embedding modules + the aggregator's
//! global module, with Xavier init and flat (de)serialization for the
//! wire.

use super::config::ModelConfig;
use super::linalg::Mat;
use crate::crypto::rng::DetRng;

/// One party's linear module: W (in_dim × hidden), optional bias.
/// Per §6.2 only the active party's module is biased.
#[derive(Clone, Debug, PartialEq)]
pub struct PartyParams {
    pub w: Mat,
    pub b: Option<Vec<f32>>,
}

/// The aggregator's global module: Linear(hidden, 1).
#[derive(Clone, Debug, PartialEq)]
pub struct GlobalParams {
    pub w: Mat, // hidden × 1
    pub b: f32,
}

/// The complete model state.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelParams {
    pub active: PartyParams,
    /// One weight matrix per *group* (parties in a group share weights,
    /// since they hold the same feature set over disjoint samples).
    pub groups: Vec<PartyParams>,
    pub global: GlobalParams,
}

fn xavier(rows: usize, cols: usize, rng: &mut DetRng) -> Mat {
    let bound = (6.0 / (rows + cols) as f64).sqrt();
    let data =
        (0..rows * cols).map(|_| ((rng.next_f64() * 2.0 - 1.0) * bound) as f32).collect();
    Mat { rows, cols, data }
}

impl ModelParams {
    /// Xavier-initialized parameters for a configuration.
    pub fn init(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = DetRng::from_seed(seed);
        let active = PartyParams {
            w: xavier(cfg.active_dim, cfg.hidden, &mut rng),
            b: Some(vec![0.0; cfg.hidden]),
        };
        let groups = cfg
            .group_dims
            .iter()
            .map(|&d| PartyParams { w: xavier(d, cfg.hidden, &mut rng), b: None })
            .collect();
        let global = GlobalParams { w: xavier(cfg.hidden, 1, &mut rng), b: 0.0 };
        ModelParams { active, groups, global }
    }

    /// Flatten all parameters to a single vector (wire format /
    /// artifact input order: active W, active b, group Ws, global W, global b).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::new();
        out.extend_from_slice(&self.active.w.data);
        out.extend_from_slice(self.active.b.as_ref().expect("active bias"));
        for g in &self.groups {
            out.extend_from_slice(&g.w.data);
        }
        out.extend_from_slice(&self.global.w.data);
        out.push(self.global.b);
        out
    }

    /// Inverse of [`flatten`].
    pub fn unflatten(cfg: &ModelConfig, flat: &[f32]) -> Self {
        let h = cfg.hidden;
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| {
            let s = flat[*pos..*pos + n].to_vec();
            *pos += n;
            s
        };
        let aw = Mat::from_vec(cfg.active_dim, h, take(&mut pos, cfg.active_dim * h));
        let ab = take(&mut pos, h);
        let groups = cfg
            .group_dims
            .iter()
            .map(|&d| PartyParams { w: Mat::from_vec(d, h, take(&mut pos, d * h)), b: None })
            .collect();
        let gw = Mat::from_vec(h, 1, take(&mut pos, h));
        let gb = take(&mut pos, 1)[0];
        assert_eq!(pos, flat.len(), "flat length mismatch");
        ModelParams {
            active: PartyParams { w: aw, b: Some(ab) },
            groups,
            global: GlobalParams { w: gw, b: gb },
        }
    }

    pub fn n_params(&self) -> usize {
        self.flatten().len()
    }
}

/// Gradients, same shape as the parameters.
#[derive(Clone, Debug)]
pub struct ModelGrads {
    pub active_w: Mat,
    pub active_b: Vec<f32>,
    pub group_ws: Vec<Mat>,
    pub global_w: Mat,
    pub global_b: f32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_shapes() {
        let cfg = ModelConfig::for_dataset("banking").unwrap();
        let p = ModelParams::init(&cfg, 1);
        assert_eq!((p.active.w.rows, p.active.w.cols), (57, 64));
        assert_eq!(p.active.b.as_ref().unwrap().len(), 64);
        assert_eq!(p.groups.len(), 2);
        assert_eq!((p.groups[0].w.rows, p.groups[1].w.rows), (3, 20));
        assert!(p.groups.iter().all(|g| g.b.is_none()));
        assert_eq!((p.global.w.rows, p.global.w.cols), (64, 1));
        assert_eq!(p.n_params(), cfg.n_params());
    }

    #[test]
    fn flatten_roundtrip() {
        let cfg = ModelConfig::for_dataset("adult").unwrap();
        let p = ModelParams::init(&cfg, 7);
        let flat = p.flatten();
        let q = ModelParams::unflatten(&cfg, &flat);
        assert_eq!(p, q);
    }

    #[test]
    fn init_deterministic_and_bounded() {
        let cfg = ModelConfig::for_dataset("banking").unwrap();
        let a = ModelParams::init(&cfg, 3);
        let b = ModelParams::init(&cfg, 3);
        assert_eq!(a, b);
        let c = ModelParams::init(&cfg, 4);
        assert_ne!(a, c);
        let bound = (6.0f64 / (57 + 64) as f64).sqrt() as f32;
        assert!(a.active.w.data.iter().all(|v| v.abs() <= bound));
        // bias starts at zero
        assert!(a.active.b.unwrap().iter().all(|&v| v == 0.0));
    }
}
