//! Evaluation metrics for the testing phase: accuracy, ROC-AUC,
//! log-loss, confusion counts. Used by the examples and the experiment
//! reports (the paper's datasets are heavily imbalanced — bank
//! marketing ~12% positives — so AUC is the metric practitioners
//! actually read).

/// Binary confusion counts at a threshold.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Confusion {
    pub tp: usize,
    pub fp: usize,
    pub tn: usize,
    pub fn_: usize,
}

impl Confusion {
    pub fn from_preds(probs: &[f32], labels: &[f32], threshold: f32) -> Self {
        assert_eq!(probs.len(), labels.len());
        let mut c = Confusion { tp: 0, fp: 0, tn: 0, fn_: 0 };
        for (&p, &y) in probs.iter().zip(labels) {
            match (p > threshold, y == 1.0) {
                (true, true) => c.tp += 1,
                (true, false) => c.fp += 1,
                (false, false) => c.tn += 1,
                (false, true) => c.fn_ += 1,
            }
        }
        c
    }

    pub fn accuracy(&self) -> f64 {
        let n = self.tp + self.fp + self.tn + self.fn_;
        if n == 0 {
            return 0.0;
        }
        (self.tp + self.tn) as f64 / n as f64
    }

    pub fn precision(&self) -> f64 {
        if self.tp + self.fp == 0 {
            return 0.0;
        }
        self.tp as f64 / (self.tp + self.fp) as f64
    }

    pub fn recall(&self) -> f64 {
        if self.tp + self.fn_ == 0 {
            return 0.0;
        }
        self.tp as f64 / (self.tp + self.fn_) as f64
    }

    pub fn f1(&self) -> f64 {
        let p = self.precision();
        let r = self.recall();
        if p + r == 0.0 {
            return 0.0;
        }
        2.0 * p * r / (p + r)
    }
}

/// ROC-AUC via the rank statistic (Mann–Whitney U), ties handled by
/// midranks. O(n log n).
pub fn roc_auc(probs: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(probs.len(), labels.len());
    let n_pos = labels.iter().filter(|&&y| y == 1.0).count();
    let n_neg = labels.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    let mut idx: Vec<usize> = (0..probs.len()).collect();
    idx.sort_by(|&a, &b| probs[a].partial_cmp(&probs[b]).unwrap());
    // midrank assignment
    let mut ranks = vec![0.0f64; probs.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && probs[idx[j + 1]] == probs[idx[i]] {
            j += 1;
        }
        let mid = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[idx[k]] = mid;
        }
        i = j + 1;
    }
    let rank_sum_pos: f64 =
        labels.iter().zip(&ranks).filter(|(&y, _)| y == 1.0).map(|(_, &r)| r).sum();
    let u = rank_sum_pos - (n_pos * (n_pos + 1)) as f64 / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

/// Mean log-loss (same definition as the training objective).
pub fn log_loss(probs: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(probs.len(), labels.len());
    let eps = 1e-7f64;
    let s: f64 = probs
        .iter()
        .zip(labels)
        .map(|(&p, &y)| {
            let p = (p as f64).clamp(eps, 1.0 - eps);
            -(y as f64 * p.ln() + (1.0 - y as f64) * (1.0 - p).ln())
        })
        .sum();
    s / probs.len() as f64
}

/// Full evaluation summary.
#[derive(Clone, Copy, Debug)]
pub struct Evaluation {
    pub accuracy: f64,
    pub auc: f64,
    pub log_loss: f64,
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
}

pub fn evaluate(probs: &[f32], labels: &[f32]) -> Evaluation {
    let c = Confusion::from_preds(probs, labels, 0.5);
    Evaluation {
        accuracy: c.accuracy(),
        auc: roc_auc(probs, labels),
        log_loss: log_loss(probs, labels),
        precision: c.precision(),
        recall: c.recall(),
        f1: c.f1(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn confusion_counts() {
        let probs = [0.9, 0.8, 0.2, 0.1];
        let labels = [1.0, 0.0, 1.0, 0.0];
        let c = Confusion::from_preds(&probs, &labels, 0.5);
        assert_eq!(c, Confusion { tp: 1, fp: 1, tn: 1, fn_: 1 });
        assert_eq!(c.accuracy(), 0.5);
        assert_eq!(c.precision(), 0.5);
        assert_eq!(c.recall(), 0.5);
        assert_eq!(c.f1(), 0.5);
    }

    #[test]
    fn auc_perfect_and_inverted() {
        let labels = [0.0, 0.0, 1.0, 1.0];
        assert_eq!(roc_auc(&[0.1, 0.2, 0.8, 0.9], &labels), 1.0);
        assert_eq!(roc_auc(&[0.9, 0.8, 0.2, 0.1], &labels), 0.0);
        assert_eq!(roc_auc(&[0.5, 0.5, 0.5, 0.5], &labels), 0.5);
    }

    #[test]
    fn auc_with_ties_midrank() {
        // one tie crossing classes: AUC = 0.5 contribution for that pair
        let labels = [0.0, 1.0, 0.0, 1.0];
        let probs = [0.3, 0.3, 0.1, 0.9];
        // pairs: (0.3n,0.3p)=0.5, (0.3n,0.9p)=1, (0.1n,0.3p)=1, (0.1n,0.9p)=1 → 3.5/4
        assert!((roc_auc(&probs, &labels) - 0.875).abs() < 1e-9);
    }

    #[test]
    fn degenerate_labels() {
        assert_eq!(roc_auc(&[0.1, 0.9], &[1.0, 1.0]), 0.5);
        assert_eq!(roc_auc(&[0.1, 0.9], &[0.0, 0.0]), 0.5);
    }

    #[test]
    fn log_loss_matches_manual() {
        let ll = log_loss(&[0.5, 0.5], &[1.0, 0.0]);
        assert!((ll - 0.6931472).abs() < 1e-5);
        assert!(log_loss(&[1.0, 0.0], &[1.0, 0.0]) < 1e-5);
    }

    #[test]
    fn evaluate_bundle() {
        let e = evaluate(&[0.9, 0.1, 0.7, 0.3], &[1.0, 0.0, 1.0, 0.0]);
        assert_eq!(e.accuracy, 1.0);
        assert_eq!(e.auc, 1.0);
        assert!(e.log_loss < 0.4);
        assert_eq!(e.f1, 1.0);
    }
}
