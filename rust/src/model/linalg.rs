//! Minimal dense linear algebra on row-major `Vec<f32>` matrices.
//!
//! This is the *reference* math used by tests (as the oracle for both
//! the PJRT artifacts and the masked protocol), by the HE ablation
//! (which needs plain dot products to compare against), and as a
//! fallback compute engine when artifacts are absent.

/// Row-major matrix view: data.len() == rows * cols.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
}

/// C = A · B  ((m×k) · (k×n) → (m×n)), ikj loop order for locality.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        for p in 0..k {
            let aip = a.at(i, p);
            if aip == 0.0 {
                continue; // one-hot rows are mostly zero
            }
            let brow = &b.data[p * n..(p + 1) * n];
            let crow = &mut c.data[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
    }
    c
}

/// C = Aᵀ · B  ((m×k)ᵀ · (m×n) → (k×n)) — the backward-pass product.
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(k, n);
    for i in 0..m {
        for p in 0..k {
            let aip = a.at(i, p);
            if aip == 0.0 {
                continue;
            }
            let brow = &b.data[i * n..(i + 1) * n];
            let crow = &mut c.data[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
    }
    c
}

/// C = A · Bᵀ  ((m×k) · (n×k)ᵀ → (m×n)).
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            let arow = &a.data[i * k..(i + 1) * k];
            let brow = &b.data[j * k..(j + 1) * k];
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            *c.at_mut(i, j) = acc;
        }
    }
    c
}

pub fn add_inplace(a: &mut Mat, b: &Mat) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += y;
    }
}

pub fn add_row_vector(a: &mut Mat, bias: &[f32]) {
    assert_eq!(a.cols, bias.len());
    for r in 0..a.rows {
        for c in 0..a.cols {
            *a.at_mut(r, c) += bias[c];
        }
    }
}

pub fn relu(a: &Mat) -> Mat {
    Mat { rows: a.rows, cols: a.cols, data: a.data.iter().map(|&v| v.max(0.0)).collect() }
}

/// Elementwise ReLU-gate: out = g ⊙ 1[z > 0].
pub fn relu_grad(z: &Mat, g: &Mat) -> Mat {
    assert_eq!((z.rows, z.cols), (g.rows, g.cols));
    Mat {
        rows: z.rows,
        cols: z.cols,
        data: z.data.iter().zip(&g.data).map(|(&z, &g)| if z > 0.0 { g } else { 0.0 }).collect(),
    }
}

pub fn sigmoid(a: &Mat) -> Mat {
    Mat { rows: a.rows, cols: a.cols, data: a.data.iter().map(|&v| 1.0 / (1.0 + (-v).exp())).collect() }
}

/// Mean binary cross-entropy of probabilities `p` against labels `y`.
pub fn bce_loss(p: &[f32], y: &[f32]) -> f32 {
    assert_eq!(p.len(), y.len());
    let eps = 1e-7f32;
    let s: f32 = p
        .iter()
        .zip(y)
        .map(|(&p, &y)| {
            let p = p.clamp(eps, 1.0 - eps);
            -(y * p.ln() + (1.0 - y) * (1.0 - p).ln())
        })
        .sum();
    s / p.len() as f32
}

/// Column sums (for bias gradients).
pub fn col_sums(a: &Mat) -> Vec<f32> {
    let mut out = vec![0.0f32; a.cols];
    for r in 0..a.rows {
        for c in 0..a.cols {
            out[c] += a.at(r, c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(matmul(&a, &b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_tn_matches_explicit_transpose() {
        let a = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let t = matmul_tn(&a, &b);
        // Aᵀ(2x3)·B(3x2): [[1,3,5],[2,4,6]]·[[7,8],[9,10],[11,12]]
        assert_eq!(t.data, vec![1.*7.+3.*9.+5.*11., 1.*8.+3.*10.+5.*12., 2.*7.+4.*9.+6.*11., 2.*8.+4.*10.+6.*12.]);
    }

    #[test]
    fn matmul_nt_matches() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(2, 3, vec![1., 0., 1., 0., 1., 0.]);
        let c = matmul_nt(&a, &b);
        assert_eq!(c.data, vec![4., 2., 10., 5.]);
    }

    #[test]
    fn relu_and_grad() {
        let z = Mat::from_vec(1, 4, vec![-1.0, 0.0, 2.0, -3.0]);
        assert_eq!(relu(&z).data, vec![0.0, 0.0, 2.0, 0.0]);
        let g = Mat::from_vec(1, 4, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(relu_grad(&z, &g).data, vec![0.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn sigmoid_bounds() {
        let z = Mat::from_vec(1, 3, vec![-100.0, 0.0, 100.0]);
        let p = sigmoid(&z);
        assert!(p.data[0] < 1e-6);
        assert_eq!(p.data[1], 0.5);
        assert!(p.data[2] > 1.0 - 1e-6);
    }

    #[test]
    fn bce_perfect_and_wrong() {
        assert!(bce_loss(&[1.0, 0.0], &[1.0, 0.0]) < 1e-5);
        assert!(bce_loss(&[0.0, 1.0], &[1.0, 0.0]) > 10.0);
        let half = bce_loss(&[0.5, 0.5], &[1.0, 0.0]);
        assert!((half - 0.6931).abs() < 1e-3);
    }

    #[test]
    fn bias_and_colsums() {
        let mut a = Mat::zeros(2, 3);
        add_row_vector(&mut a, &[1.0, 2.0, 3.0]);
        assert_eq!(a.data, vec![1., 2., 3., 1., 2., 3.]);
        assert_eq!(col_sums(&a), vec![2.0, 4.0, 6.0]);
    }
}
