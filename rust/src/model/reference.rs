//! Pure-Rust reference implementation of the paper's model (§3, §6.2):
//! per-party linear embeddings, summed at the aggregator, ReLU, global
//! Linear(h, 1), sigmoid + BCE.
//!
//! This is (a) the numerical oracle the PJRT artifacts and the masked
//! protocol are tested against, and (b) the fallback compute engine
//! when `artifacts/` has not been built.

use super::linalg::{
    add_row_vector, bce_loss, col_sums, matmul, matmul_nt, matmul_tn, relu, relu_grad, sigmoid,
    Mat,
};
use super::params::{ModelGrads, ModelParams, PartyParams};

/// A party's contribution to the summed embedding: x·W (+ b for the
/// active party). This is the quantity that gets masked in Eq. 2.
pub fn party_forward(x: &Mat, p: &PartyParams) -> Mat {
    let mut z = matmul(x, &p.w);
    if let Some(b) = &p.b {
        add_row_vector(&mut z, b);
    }
    z
}

/// Outputs of the aggregator's global module.
pub struct GlobalForward {
    /// ReLU(z) — kept for the backward pass.
    pub h1: Mat,
    /// σ(h1·Wg + bg), shape (B, 1).
    pub probs: Mat,
    pub loss: f32,
}

/// Global module forward + loss.
pub fn global_forward(params: &ModelParams, z: &Mat, y: &[f32]) -> GlobalForward {
    let h1 = relu(z);
    let mut logits = matmul(&h1, &params.global.w);
    for v in logits.data.iter_mut() {
        *v += params.global.b;
    }
    let probs = sigmoid(&logits);
    let loss = bce_loss(&probs.data, y);
    GlobalForward { h1, probs, loss }
}

/// Gradient of the loss w.r.t. the summed embedding `z`, plus global-
/// module gradients. `dz` is what the aggregator broadcasts (the paper's
/// backward pass); per-party weight grads are then x_pᵀ·dz.
pub struct GlobalBackward {
    pub dz: Mat,
    pub d_global_w: Mat,
    pub d_global_b: f32,
}

pub fn global_backward(params: &ModelParams, z: &Mat, fwd: &GlobalForward, y: &[f32]) -> GlobalBackward {
    let batch = z.rows as f32;
    // dlogit = (p - y) / B
    let dlogit = Mat {
        rows: z.rows,
        cols: 1,
        data: fwd.probs.data.iter().zip(y).map(|(&p, &y)| (p - y) / batch).collect(),
    };
    let d_global_w = matmul_tn(&fwd.h1, &dlogit);
    let d_global_b: f32 = dlogit.data.iter().sum();
    // dh1 = dlogit · Wgᵀ ; dz = dh1 ⊙ 1[z>0]
    let dh1 = matmul_nt(&dlogit, &params.global.w);
    let dz = relu_grad(z, &dh1);
    GlobalBackward { dz, d_global_w, d_global_b }
}

/// A party's weight gradient given the broadcast `dz` (Eq. 6): xᵀ·dz,
/// plus the bias gradient for the active party.
pub fn party_backward(x: &Mat, dz: &Mat, has_bias: bool) -> (Mat, Option<Vec<f32>>) {
    let dw = matmul_tn(x, dz);
    let db = if has_bias { Some(col_sums(dz)) } else { None };
    (dw, db)
}

/// One full centralized training step (the §3 "centralized solution"
/// upper bound): returns loss, probabilities and all gradients.
/// `x_groups[g]` is the (B × d_g) feature block of group g.
pub fn full_step(params: &ModelParams, x_active: &Mat, x_groups: &[Mat], y: &[f32]) -> (f32, Mat, ModelGrads) {
    let mut z = party_forward(x_active, &params.active);
    for (x, p) in x_groups.iter().zip(&params.groups) {
        let zg = party_forward(x, p);
        super::linalg::add_inplace(&mut z, &zg);
    }
    let fwd = global_forward(params, &z, y);
    let bwd = global_backward(params, &z, &fwd, y);
    let (active_w, active_b) = party_backward(x_active, &bwd.dz, true);
    let group_ws: Vec<Mat> =
        x_groups.iter().map(|x| party_backward(x, &bwd.dz, false).0).collect();
    let grads = ModelGrads {
        active_w,
        active_b: active_b.unwrap(),
        group_ws,
        global_w: bwd.d_global_w,
        global_b: bwd.d_global_b,
    };
    (fwd.loss, fwd.probs, grads)
}

/// In-place SGD update.
pub fn sgd_step(params: &mut ModelParams, grads: &ModelGrads, lr: f32) {
    for (w, g) in params.active.w.data.iter_mut().zip(&grads.active_w.data) {
        *w -= lr * g;
    }
    if let Some(b) = params.active.b.as_mut() {
        for (b, g) in b.iter_mut().zip(&grads.active_b) {
            *b -= lr * g;
        }
    }
    for (p, gw) in params.groups.iter_mut().zip(&grads.group_ws) {
        for (w, g) in p.w.data.iter_mut().zip(&gw.data) {
            *w -= lr * g;
        }
    }
    for (w, g) in params.global.w.data.iter_mut().zip(&grads.global_w.data) {
        *w -= lr * g;
    }
    params.global.b -= lr * grads.global_b;
}

/// Inference: probabilities for a batch.
pub fn predict(params: &ModelParams, x_active: &Mat, x_groups: &[Mat]) -> Vec<f32> {
    let mut z = party_forward(x_active, &params.active);
    for (x, p) in x_groups.iter().zip(&params.groups) {
        super::linalg::add_inplace(&mut z, &party_forward(x, p));
    }
    let h1 = relu(&z);
    let mut logits = matmul(&h1, &params.global.w);
    for v in logits.data.iter_mut() {
        *v += params.global.b;
    }
    sigmoid(&logits).data
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::DetRng;
    use crate::model::config::ModelConfig;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            dataset: "tiny".into(),
            active_dim: 4,
            group_dims: vec![3, 2],
            group_parties: vec![2, 2],
            hidden: 8,
            lr: 0.1,
            batch_size: 16,
            rotation_period: 5,
        }
    }

    fn rand_mat(rows: usize, cols: usize, rng: &mut DetRng) -> Mat {
        Mat::from_vec(rows, cols, (0..rows * cols).map(|_| rng.next_f64() as f32 - 0.5).collect())
    }

    #[test]
    fn party_forward_bias_only_for_active() {
        let cfg = tiny_cfg();
        let p = ModelParams::init(&cfg, 1);
        let x = Mat::zeros(2, 4);
        let z = party_forward(&x, &p.active);
        // zero input → bias rows (which init to 0)
        assert!(z.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn gradients_match_finite_differences() {
        let cfg = tiny_cfg();
        let mut rng = DetRng::from_seed(2);
        let params = ModelParams::init(&cfg, 3);
        let x_active = rand_mat(6, 4, &mut rng);
        let xg: Vec<Mat> = vec![rand_mat(6, 3, &mut rng), rand_mat(6, 2, &mut rng)];
        let y: Vec<f32> = (0..6).map(|i| (i % 2) as f32).collect();
        let (_, _, grads) = full_step(&params, &x_active, &xg, &y);

        let eps = 1e-3f32;
        let loss_at = |p: &ModelParams| full_step(p, &x_active, &xg, &y).0;

        // check a handful of weights in every tensor
        let check = |get: &dyn Fn(&ModelParams) -> f32,
                         set: &dyn Fn(&mut ModelParams, f32),
                         analytic: f32,
                         what: &str| {
            let mut p_plus = params.clone();
            set(&mut p_plus, get(&params) + eps);
            let mut p_minus = params.clone();
            set(&mut p_minus, get(&params) - eps);
            let numeric = (loss_at(&p_plus) - loss_at(&p_minus)) / (2.0 * eps);
            assert!(
                (numeric - analytic).abs() < 2e-2 * (1.0 + analytic.abs()),
                "{what}: numeric={numeric} analytic={analytic}"
            );
        };

        check(&|p| p.active.w.data[5], &|p, v| p.active.w.data[5] = v, grads.active_w.data[5], "active w");
        check(
            &|p| p.active.b.as_ref().unwrap()[2],
            &|p, v| p.active.b.as_mut().unwrap()[2] = v,
            grads.active_b[2],
            "active b",
        );
        check(&|p| p.groups[0].w.data[7], &|p, v| p.groups[0].w.data[7] = v, grads.group_ws[0].data[7], "group0 w");
        check(&|p| p.groups[1].w.data[3], &|p, v| p.groups[1].w.data[3] = v, grads.group_ws[1].data[3], "group1 w");
        check(&|p| p.global.w.data[4], &|p, v| p.global.w.data[4] = v, grads.global_w.data[4], "global w");
        check(&|p| p.global.b, &|p, v| p.global.b = v, grads.global_b, "global b");
    }

    #[test]
    fn training_reduces_loss() {
        let cfg = tiny_cfg();
        let mut rng = DetRng::from_seed(5);
        let mut params = ModelParams::init(&cfg, 5);
        let x_active = rand_mat(32, 4, &mut rng);
        let xg: Vec<Mat> = vec![rand_mat(32, 3, &mut rng), rand_mat(32, 2, &mut rng)];
        // learnable labels: function of the first feature
        let y: Vec<f32> = (0..32).map(|i| if x_active.at(i, 0) > 0.0 { 1.0 } else { 0.0 }).collect();
        let (loss0, _, _) = full_step(&params, &x_active, &xg, &y);
        for _ in 0..200 {
            let (_, _, grads) = full_step(&params, &x_active, &xg, &y);
            sgd_step(&mut params, &grads, 0.5);
        }
        let (loss1, _, _) = full_step(&params, &x_active, &xg, &y);
        assert!(loss1 < loss0 * 0.5, "loss should halve: {loss0} → {loss1}");
    }

    #[test]
    fn predict_matches_forward_probs() {
        let cfg = tiny_cfg();
        let mut rng = DetRng::from_seed(6);
        let params = ModelParams::init(&cfg, 6);
        let x_active = rand_mat(4, 4, &mut rng);
        let xg: Vec<Mat> = vec![rand_mat(4, 3, &mut rng), rand_mat(4, 2, &mut rng)];
        let y = vec![0.0; 4];
        let (_, probs, _) = full_step(&params, &x_active, &xg, &y);
        assert_eq!(predict(&params, &x_active, &xg), probs.data);
    }
}
