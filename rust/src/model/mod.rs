//! Model substrate: configuration, parameters, reference math (the
//! oracle for the PJRT artifacts and the masked protocol), and SGD.

pub mod config;
pub mod eval;
pub mod linalg;
pub mod params;
pub mod reference;

pub use config::ModelConfig;
pub use linalg::Mat;
pub use params::{GlobalParams, ModelGrads, ModelParams, PartyParams};
