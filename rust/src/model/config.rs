//! Model configuration: ties a dataset's vertical partition to the
//! per-party Linear-module shapes of §6.2.

use crate::data::{by_name, hidden_dim, PartitionSpec, Schema};

/// Full model + training configuration for one experiment.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub dataset: String,
    /// Active party input width (encoded).
    pub active_dim: usize,
    /// One entry per passive group: encoded input width.
    pub group_dims: Vec<usize>,
    /// Parties per group.
    pub group_parties: Vec<usize>,
    pub hidden: usize,
    /// Learning rate (paper: 0.01).
    pub lr: f32,
    /// Batch size (paper: 256).
    pub batch_size: usize,
    /// Key-rotation period in rounds (paper experiments: 5).
    pub rotation_period: usize,
}

impl ModelConfig {
    /// Build the paper's configuration for a named dataset.
    pub fn for_dataset(name: &str) -> Option<ModelConfig> {
        let (schema, spec, _rows) = by_name(name)?;
        Some(Self::from_parts(name, &schema, &spec))
    }

    pub fn from_parts(name: &str, schema: &Schema, spec: &PartitionSpec) -> ModelConfig {
        let a: Vec<&str> = spec.active_features.iter().map(|s| s.as_str()).collect();
        let active_dim = schema.encoded_width_of(&a);
        let group_dims = spec
            .groups
            .iter()
            .map(|g| {
                let names: Vec<&str> = g.features.iter().map(|s| s.as_str()).collect();
                schema.encoded_width_of(&names)
            })
            .collect();
        let group_parties = spec.groups.iter().map(|g| g.n_parties).collect();
        ModelConfig {
            dataset: name.to_string(),
            active_dim,
            group_dims,
            group_parties,
            hidden: hidden_dim(name),
            lr: 0.01,
            batch_size: 256,
            rotation_period: 5,
        }
    }

    /// Total number of clients (1 active + passives).
    pub fn n_clients(&self) -> usize {
        1 + self.group_parties.iter().sum::<usize>()
    }

    /// The combined input width (what a centralized model would see).
    pub fn total_dim(&self) -> usize {
        self.active_dim + self.group_dims.iter().sum::<usize>()
    }

    /// Trainable parameter count.
    pub fn n_params(&self) -> usize {
        self.active_dim * self.hidden
            + self.hidden // active bias
            + self.group_dims.iter().map(|d| d * self.hidden).sum::<usize>()
            + self.hidden // global weight (hidden x 1)
            + 1 // global bias
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn banking_config_matches_paper() {
        let c = ModelConfig::for_dataset("banking").unwrap();
        assert_eq!(c.active_dim, 57);
        assert_eq!(c.group_dims, vec![3, 20]);
        assert_eq!(c.hidden, 64);
        assert_eq!(c.total_dim(), 80);
        assert_eq!(c.n_clients(), 5);
        assert_eq!(c.lr, 0.01);
        assert_eq!(c.batch_size, 256);
    }

    #[test]
    fn adult_and_taobao() {
        let a = ModelConfig::for_dataset("adult").unwrap();
        assert_eq!((a.active_dim, a.total_dim(), a.hidden), (27, 106, 64));
        let t = ModelConfig::for_dataset("taobao").unwrap();
        assert_eq!((t.active_dim, t.total_dim(), t.hidden), (197, 214, 128));
    }

    #[test]
    fn param_counts() {
        let c = ModelConfig::for_dataset("banking").unwrap();
        // 57*64 + 64 + (3+20)*64 + 64 + 1
        assert_eq!(c.n_params(), 57 * 64 + 64 + 23 * 64 + 64 + 1);
    }

    #[test]
    fn unknown_dataset() {
        assert!(ModelConfig::for_dataset("none").is_none());
    }
}
