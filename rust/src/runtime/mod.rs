//! PJRT runtime layer: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.

pub mod engine;

pub use engine::{artifact_keys, Engine, ARTIFACT_BATCH};
