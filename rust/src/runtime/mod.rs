//! PJRT runtime layer: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! The real engine binds the `xla` crate and is gated behind the
//! `pjrt` cargo feature; default builds get an API-compatible stub
//! whose `load` fails with a clear message, so the crate (and every
//! test, via the pure-Rust reference backend) builds on a clean
//! checkout with no native XLA toolchain.

#[cfg(feature = "pjrt")]
pub mod engine;

#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;

pub use engine::Engine;

/// The fixed batch size the artifacts are lowered with (== aot.py BATCH).
pub const ARTIFACT_BATCH: usize = 256;

/// The artifact keys every dataset provides.
pub fn artifact_keys(n_groups: usize) -> Vec<String> {
    let mut keys = vec!["fwd_active".to_string(), "bwd_active".to_string()];
    for g in 0..n_groups {
        keys.push(format!("fwd_g{g}"));
        keys.push(format!("bwd_g{g}"));
    }
    keys.push("global_step".to_string());
    keys.push("predict".to_string());
    keys
}

/// Whether this build can execute PJRT artifacts.
pub fn pjrt_enabled() -> bool {
    cfg!(feature = "pjrt")
}
