//! API-compatible stand-in for the PJRT engine, used when the crate is
//! built without the `pjrt` feature (the default — the `xla` binding
//! needs a native XLA toolchain the CI image doesn't carry).
//!
//! `load` and `execute` fail with an actionable message; everything
//! that matters for tests runs on the pure-Rust reference backend
//! instead.

use std::path::Path;

use anyhow::{bail, Result};

use crate::model::ModelConfig;

/// Stub engine: carries the dataset/batch metadata but cannot execute.
pub struct Engine {
    pub dataset: String,
    pub batch: usize,
}

impl Engine {
    pub fn load(_dir: impl AsRef<Path>, _cfg: &ModelConfig) -> Result<Engine> {
        bail!(
            "this build has no PJRT runtime — rebuild with `--features pjrt` \
             (and run `make artifacts`), or use the reference backend"
        )
    }

    pub fn has(&self, _key: &str) -> bool {
        false
    }

    pub fn keys(&self) -> Vec<&str> {
        Vec::new()
    }

    pub fn execute(&self, key: &str, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        bail!("PJRT graph {key} unavailable: built without the `pjrt` feature")
    }
}
