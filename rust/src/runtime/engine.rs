//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the coordinator's hot path.
//!
//! `make artifacts` (Python, build-time only) lowers the L2 graphs to
//! `artifacts/<dataset>_<graph>.hlo.txt`; this engine parses the text
//! with `HloModuleProto::from_text_file`, compiles each module once on
//! a PJRT CPU client, and serves `execute` calls with zero Python
//! involvement.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::model::ModelConfig;

use super::{artifact_keys, ARTIFACT_BATCH};

/// A named, compiled executable set for one dataset.
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Serializes every `execute` call: the `xla` wrapper is not
    /// audited for concurrent use, so cross-thread access is mutually
    /// excluded rather than assumed safe.
    ffi_lock: Mutex<()>,
    pub dataset: String,
    pub batch: usize,
}

// SAFETY: needed so parties holding a `Backend::Pjrt(&Engine)` satisfy
// the `Party: Send` supertrait. Send: the PJRT CPU client and its
// executables are plain heap FFI handles with no thread affinity (no
// TLS), so moving the owner between threads is sound. Sync: all
// post-load access to the FFI objects goes through `execute`, which
// takes `ffi_lock` — shared references never touch the unaudited
// wrapper concurrently. (`ThreadedTransport` additionally refuses
// shared-engine party sets, so the lock is a backstop, not a hot-path
// serializer.)
unsafe impl Send for Engine {}
// SAFETY: see the Send/Sync argument above — shared access is
// serialized by `ffi_lock`.
unsafe impl Sync for Engine {}

impl Engine {
    /// Load and compile all artifacts for `cfg.dataset` from `dir`.
    pub fn load(dir: impl AsRef<Path>, cfg: &ModelConfig) -> Result<Engine> {
        let dir = dir.as_ref();
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut execs = HashMap::new();
        for key in artifact_keys(cfg.group_dims.len()) {
            let path: PathBuf = dir.join(format!("{}_{}.hlo.txt", cfg.dataset, key));
            if !path.exists() {
                bail!(
                    "artifact {} missing — run `make artifacts` first",
                    path.display()
                );
            }
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .with_context(|| format!("parse {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compile {}", path.display()))?;
            execs.insert(key, exe);
        }
        Ok(Engine {
            client,
            execs,
            ffi_lock: Mutex::new(()),
            dataset: cfg.dataset.clone(),
            batch: ARTIFACT_BATCH,
        })
    }

    /// Whether a graph is available.
    pub fn has(&self, key: &str) -> bool {
        self.execs.contains_key(key)
    }

    pub fn keys(&self) -> Vec<&str> {
        self.execs.keys().map(|s| s.as_str()).collect()
    }

    /// Execute a graph. `inputs` are (flat f32 data, dims) pairs in the
    /// graph's parameter order; returns the flattened tuple outputs.
    pub fn execute(&self, key: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        // mutual exclusion over the unaudited FFI layer (see the
        // SAFETY note on the Send/Sync impls)
        let _ffi = self.ffi_lock.lock().unwrap();
        let exe = self.execs.get(key).with_context(|| format!("unknown graph {key}"))?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let n: i64 = dims.iter().product();
                assert_eq!(n as usize, data.len(), "shape/data mismatch for {key}");
                xla::Literal::vec1(data).reshape(dims).map_err(anyhow::Error::from)
            })
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // graphs are lowered with return_tuple=True
        let parts = result.to_tuple()?;
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(anyhow::Error::from))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::DetRng;
    use crate::model::linalg::Mat;
    use crate::model::params::ModelParams;
    use crate::model::reference;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("banking_global_step.hlo.txt").exists()
    }

    fn rand_vec(n: usize, rng: &mut DetRng) -> Vec<f32> {
        (0..n).map(|_| rng.next_f64() as f32 - 0.5).collect()
    }

    #[test]
    fn load_all_datasets() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        for ds in ["banking", "adult", "taobao"] {
            let cfg = ModelConfig::for_dataset(ds).unwrap();
            let e = Engine::load(artifacts_dir(), &cfg).unwrap();
            assert_eq!(e.keys().len(), 8, "{ds}");
            assert!(e.has("global_step"));
        }
    }

    #[test]
    fn fwd_active_matches_reference() {
        if !have_artifacts() {
            return;
        }
        let cfg = ModelConfig::for_dataset("banking").unwrap();
        let e = Engine::load(artifacts_dir(), &cfg).unwrap();
        let (b, d, h) = (ARTIFACT_BATCH, cfg.active_dim, cfg.hidden);
        let mut rng = DetRng::from_seed(1);
        let x = rand_vec(b * d, &mut rng);
        let w = rand_vec(d * h, &mut rng);
        let bias = rand_vec(h, &mut rng);
        let mask = vec![0.0f32; b * h];
        let out = e
            .execute(
                "fwd_active",
                &[
                    (&x, &[b as i64, d as i64]),
                    (&w, &[d as i64, h as i64]),
                    (&bias, &[h as i64]),
                    (&mask, &[b as i64, h as i64]),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        // reference
        let xm = Mat::from_vec(b, d, x);
        let wm = Mat::from_vec(d, h, w);
        let pp = crate::model::PartyParams { w: wm, b: Some(bias) };
        let want = reference::party_forward(&xm, &pp);
        for (g, w) in out[0].iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-3, "pjrt={g} ref={w}");
        }
    }

    #[test]
    fn global_step_matches_reference() {
        if !have_artifacts() {
            return;
        }
        let cfg = ModelConfig::for_dataset("banking").unwrap();
        let e = Engine::load(artifacts_dir(), &cfg).unwrap();
        let (b, h) = (ARTIFACT_BATCH, cfg.hidden);
        let mut rng = DetRng::from_seed(2);
        let z = rand_vec(b * h, &mut rng);
        let wg = rand_vec(h, &mut rng);
        let bg = vec![0.125f32];
        let y: Vec<f32> = (0..b).map(|i| (i % 2) as f32).collect();
        let out = e
            .execute(
                "global_step",
                &[
                    (&z, &[b as i64, h as i64]),
                    (&wg, &[h as i64, 1]),
                    (&bg, &[1]),
                    (&y, &[b as i64]),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 5, "loss, probs, dz, dwg, dbg");
        // reference comparison
        let params = {
            let mut p = ModelParams::init(&cfg, 3);
            p.global.w = Mat::from_vec(h, 1, wg.clone());
            p.global.b = bg[0];
            p
        };
        let zm = Mat::from_vec(b, h, z);
        let fwd = reference::global_forward(&params, &zm, &y);
        let bwd = reference::global_backward(&params, &zm, &fwd, &y);
        assert!((out[0][0] - fwd.loss).abs() < 1e-4, "loss {} vs {}", out[0][0], fwd.loss);
        for (g, w) in out[1].iter().zip(&fwd.probs.data) {
            assert!((g - w).abs() < 1e-4);
        }
        for (g, w) in out[2].iter().zip(&bwd.dz.data) {
            assert!((g - w).abs() < 1e-5);
        }
        for (g, w) in out[3].iter().zip(&bwd.d_global_w.data) {
            assert!((g - w).abs() < 1e-4);
        }
        assert!((out[4][0] - bwd.d_global_b).abs() < 1e-5);
    }

    #[test]
    fn bwd_group_matches_reference() {
        if !have_artifacts() {
            return;
        }
        let cfg = ModelConfig::for_dataset("adult").unwrap();
        let e = Engine::load(artifacts_dir(), &cfg).unwrap();
        let (b, d, h) = (ARTIFACT_BATCH, cfg.group_dims[0], cfg.hidden);
        let mut rng = DetRng::from_seed(3);
        let x = rand_vec(b * d, &mut rng);
        let dz = rand_vec(b * h, &mut rng);
        let mask = vec![0.0f32; d * h];
        let out = e
            .execute(
                "bwd_g0",
                &[
                    (&x, &[b as i64, d as i64]),
                    (&dz, &[b as i64, h as i64]),
                    (&mask, &[d as i64, h as i64]),
                ],
            )
            .unwrap();
        let xm = Mat::from_vec(b, d, x);
        let dzm = Mat::from_vec(b, h, dz);
        let (want, _) = reference::party_backward(&xm, &dzm, false);
        for (g, w) in out[0].iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-3);
        }
    }

    #[test]
    fn unknown_graph_errors() {
        if !have_artifacts() {
            return;
        }
        let cfg = ModelConfig::for_dataset("banking").unwrap();
        let e = Engine::load(artifacts_dir(), &cfg).unwrap();
        assert!(e.execute("nope", &[]).is_err());
    }
}
