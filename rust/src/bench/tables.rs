//! Table 1 & Table 2 harness: runs the paper's exact experiment shape
//! (1 setup phase + 5 training rounds + a testing pass, batch 256, key
//! rotation every 5 iterations, repeated N times) and prints the same
//! rows the paper reports.

use anyhow::Result;

use crate::coordinator::metrics::AGGREGATOR;
use crate::coordinator::{
    run_experiment, BackendKind, PipelineStats, RunConfig, RunReport, SecurityMode,
};
use crate::net::{Addr, Phase};
use crate::runtime::Engine;

use super::{pm, stats, Stats};

/// One dataset's Table-1 row (all ms): active/passive × train/test,
/// total + overhead.
pub struct Table1Row {
    pub dataset: String,
    pub active_train_total: Stats,
    pub active_train_overhead: Stats,
    pub active_test_total: Stats,
    pub active_test_overhead: Stats,
    pub passive_train_total: Stats,
    pub passive_train_overhead: Stats,
    pub passive_test_total: Stats,
    pub passive_test_overhead: Stats,
    /// Round window width the runs used (`--rounds-in-flight`).
    pub window: usize,
    /// Scheduler pipelining counters of the last secure repetition
    /// (overlap counts are schedule-deterministic; the idle gap is the
    /// wall-clock the window saved vs left on the table).
    pub pipeline: PipelineStats,
}

/// One dataset's Table-2 row (bytes per run).
pub struct Table2Row {
    pub dataset: String,
    pub active_train: u64,
    pub active_train_overhead: u64,
    pub active_test: u64,
    pub active_test_overhead: u64,
    pub passive_train: u64,
    pub passive_train_overhead: u64,
    pub passive_test: u64,
    pub passive_test_overhead: u64,
}

fn paper_cfg(dataset: &str, mode: SecurityMode, engine: Option<&Engine>) -> RunConfig {
    let mut cfg = RunConfig::paper(dataset).expect("dataset");
    cfg.security = mode;
    cfg.backend = if engine.is_some() { BackendKind::Pjrt } else { BackendKind::Reference };
    cfg
}

fn passive_nodes(report: &RunReport) -> Vec<usize> {
    // passive clients are 1..n_clients; metrics node index = client + 1
    (2..=report.net.n_clients()).collect()
}

/// Run one secure experiment and return (report, plain-twin report).
fn run_pair(dataset: &str, engine: Option<&Engine>, seed: u64) -> Result<(RunReport, RunReport)> {
    run_pair_windowed(dataset, engine, seed, 1)
}

fn run_pair_windowed(
    dataset: &str,
    engine: Option<&Engine>,
    seed: u64,
    window: usize,
) -> Result<(RunReport, RunReport)> {
    let mut sc = paper_cfg(dataset, SecurityMode::SecureExact, engine);
    sc.seed = seed;
    sc.rounds_in_flight = window;
    let mut pc = paper_cfg(dataset, SecurityMode::Plain, engine);
    pc.seed = seed;
    pc.rounds_in_flight = window;
    Ok((run_experiment(sc, engine)?, run_experiment(pc, engine)?))
}

/// Table 1: CPU time (ms), averaged over `reps` repetitions, with the
/// round window at `window` (`--rounds-in-flight`; 1 = the paper's
/// serial measurement shape). "Total" is the secure run; "overhead" is
/// the directly metered security-op time (cross-checked against
/// secure − plain in tests).
pub fn table1(
    dataset: &str,
    reps: usize,
    engine: Option<&Engine>,
    window: usize,
) -> Result<Table1Row> {
    let mut at_t = vec![];
    let mut at_o = vec![];
    let mut ae_t = vec![];
    let mut ae_o = vec![];
    let mut pt_t = vec![];
    let mut pt_o = vec![];
    let mut pe_t = vec![];
    let mut pe_o = vec![];
    let mut pipeline = PipelineStats::default();
    for rep in 0..reps {
        let (secure, _plain) = run_pair_windowed(dataset, engine, 7 + rep as u64, window)?;
        pipeline = secure.metrics.pipeline();
        let m = &secure.metrics;
        // setup is part of the training phase the paper reports
        // (1 setup phase + 5 training rounds)
        let active = 1usize; // node index of client 0
        at_t.push(m.total_ms(active, Phase::Training) + m.total_ms(active, Phase::Setup));
        at_o.push(m.overhead_ms(active, Phase::Training) + m.overhead_ms(active, Phase::Setup));
        ae_t.push(m.total_ms(active, Phase::Testing));
        ae_o.push(m.overhead_ms(active, Phase::Testing));
        let passives = passive_nodes(&secure);
        let (t, o) = m.avg_ms(&passives, Phase::Training);
        let (ts, os) = m.avg_ms(&passives, Phase::Setup);
        pt_t.push(t + ts);
        pt_o.push(o + os);
        let (t, o) = m.avg_ms(&passives, Phase::Testing);
        pe_t.push(t);
        pe_o.push(o);
    }
    Ok(Table1Row {
        dataset: dataset.into(),
        active_train_total: stats(&at_t),
        active_train_overhead: stats(&at_o),
        active_test_total: stats(&ae_t),
        active_test_overhead: stats(&ae_o),
        passive_train_total: stats(&pt_t),
        passive_train_overhead: stats(&pt_o),
        passive_test_total: stats(&pe_t),
        passive_test_overhead: stats(&pe_o),
        window,
        pipeline,
    })
}

/// Streaming-pipeline memory stats for one dataset: the aggregator's
/// resident fan-in peak under the chunked pipeline (vs the monolithic
/// baseline), its per-shard split, and the rollback-log spill of a
/// dropout-tolerant twin — the numbers behind the O(d) memory claim,
/// surfaced so the perf trajectory has data points
/// (`benches/table2_comm.rs` prints them and emits
/// `BENCH_streaming.json`).
pub struct StreamingStats {
    pub dataset: String,
    pub chunk_words: usize,
    pub shards: usize,
    /// Monolithic secure run: O(n·d) fan-in peak.
    pub mono_peak_buffered: u64,
    /// Chunked secure run: O(d) shard-accumulator peak.
    pub peak_buffered: u64,
    /// Per-shard peaks of the chunked run (tile `peak_buffered`).
    pub peak_shard_buffered: Vec<u64>,
    /// Rollback-log spill peak of the chunked dropout-tolerant twin.
    pub peak_spilled: u64,
}

/// Measure [`StreamingStats`]: one chunked run and one chunked
/// dropout-tolerant run (threshold = n, so no client may drop — we
/// only want the rollback log exercised). `mono_peak_buffered` is the
/// monolithic secure run's fan-in peak, taken from the report
/// [`table2_with_report`] already produced so the identical experiment
/// is not re-run.
pub fn streaming_stats(
    dataset: &str,
    engine: Option<&Engine>,
    chunk_words: usize,
    shards: usize,
    mono_peak_buffered: u64,
) -> Result<StreamingStats> {
    let mut chunked_cfg = paper_cfg(dataset, SecurityMode::SecureExact, engine);
    chunked_cfg.chunk_words = Some(chunk_words);
    chunked_cfg.shards = shards;
    let chunked = run_experiment(chunked_cfg.clone(), engine)?;
    let mut tolerant_cfg = chunked_cfg;
    tolerant_cfg.shamir_threshold = Some(tolerant_cfg.model.n_clients());
    let tolerant = run_experiment(tolerant_cfg, engine)?;
    Ok(StreamingStats {
        dataset: dataset.into(),
        chunk_words,
        shards,
        mono_peak_buffered,
        peak_buffered: chunked.metrics.peak_buffered_bytes(AGGREGATOR),
        peak_shard_buffered: (0..shards)
            .map(|k| chunked.metrics.peak_shard_buffered_bytes(AGGREGATOR, k))
            .collect(),
        peak_spilled: tolerant.metrics.peak_spilled_bytes(AGGREGATOR),
    })
}

/// Print the streaming memory stats as a small table.
pub fn print_streaming(rows: &[StreamingStats]) {
    println!("\nStreaming aggregation — aggregator memory (bytes)");
    println!(
        "{:<14} | {:>14} {:>14} {:>14} | per-shard peaks",
        "", "mono_peak", "chunked_peak", "spill_peak"
    );
    for r in rows {
        println!(
            "{:<14} | {:>14} {:>14} {:>14} | {:?}",
            r.dataset, r.mono_peak_buffered, r.peak_buffered, r.peak_spilled,
            r.peak_shard_buffered
        );
    }
}

/// Table 2: transmission bytes. Byte counts are deterministic per
/// config, so a single secure/plain pair suffices; overhead = secure −
/// plain, exactly as the paper defines it.
pub fn table2(dataset: &str, engine: Option<&Engine>) -> Result<Table2Row> {
    Ok(table2_with_report(dataset, engine)?.0)
}

/// [`table2`] plus the secure run's full report, so callers that also
/// need its metrics (e.g. the monolithic fan-in peak the streaming
/// stats compare against) don't re-run the identical experiment.
pub fn table2_with_report(
    dataset: &str,
    engine: Option<&Engine>,
) -> Result<(Table2Row, RunReport)> {
    let (secure, plain) = run_pair(dataset, engine, 7)?;
    let tx = |r: &RunReport, node: Addr, ph: Phase| r.net.transmission_bytes(node, ph);
    let active = Addr::Client(0);
    // setup traffic counts toward the training phase (paper reports
    // "1 setup phase and 5 training rounds" as one number)
    let a_train_s = tx(&secure, active, Phase::Training) + tx(&secure, active, Phase::Setup);
    let a_train_p = tx(&plain, active, Phase::Training);
    let a_test_s = tx(&secure, active, Phase::Testing);
    let a_test_p = tx(&plain, active, Phase::Testing);

    let n_passive = secure.net.n_clients() - 1; // minus the active party
    let avg_passive = |r: &RunReport, ph: Phase| -> u64 {
        (1..=n_passive)
            .map(|i| tx(r, Addr::Client(i), ph))
            .sum::<u64>()
            / n_passive as u64
    };
    let p_train_s = avg_passive(&secure, Phase::Training)
        + (1..=n_passive).map(|i| tx(&secure, Addr::Client(i), Phase::Setup)).sum::<u64>()
            / n_passive as u64;
    let p_train_p = avg_passive(&plain, Phase::Training);
    let p_test_s = avg_passive(&secure, Phase::Testing);
    let p_test_p = avg_passive(&plain, Phase::Testing);

    let row = Table2Row {
        dataset: dataset.into(),
        active_train: a_train_s,
        active_train_overhead: a_train_s - a_train_p,
        active_test: a_test_s,
        active_test_overhead: a_test_s - a_test_p,
        passive_train: p_train_s,
        passive_train_overhead: p_train_s - p_train_p,
        passive_test: p_test_s,
        passive_test_overhead: p_test_s - p_test_p,
    };
    Ok((row, secure))
}

/// Print Table 1 in the paper's layout.
pub fn print_table1(rows: &[Table1Row]) {
    println!("\nTable 1 — CPU time (ms) with secure aggregation on VFL");
    println!("{:<14} | {:>14} {:>12} | {:>14} {:>12} | {:>14} {:>12} | {:>14} {:>12}",
        "", "Active/train", "overhead", "Active/test", "overhead",
        "Passive/train", "overhead", "Passive/test", "overhead");
    for r in rows {
        println!(
            "{:<14} | {:>14} {:>12} | {:>14} {:>12} | {:>14} {:>12} | {:>14} {:>12}",
            r.dataset,
            pm(&r.active_train_total),
            pm(&r.active_train_overhead),
            pm(&r.active_test_total),
            pm(&r.active_test_overhead),
            pm(&r.passive_train_total),
            pm(&r.passive_train_overhead),
            pm(&r.passive_test_total),
            pm(&r.passive_test_overhead),
        );
        let p = &r.pipeline;
        println!(
            "{:<14} | pipeline: W={} rounds={} overlapped={} max_in_flight={} idle_gap={:.2}ms",
            "",
            r.window,
            p.rounds_started,
            p.overlapped_starts,
            p.max_in_flight,
            p.idle_gap_ns as f64 / 1e6,
        );
    }
}

/// Print Table 2 in the paper's layout.
pub fn print_table2(rows: &[Table2Row]) {
    println!("\nTable 2 — data transmission (bytes) with secure aggregation on VFL");
    println!("{:<14} | {:>12} {:>10} | {:>12} {:>10} | {:>13} {:>10} | {:>12} {:>10}",
        "", "Active/train", "overhead", "Active/test", "overhead",
        "Passive/train", "overhead", "Passive/test", "overhead");
    for r in rows {
        println!(
            "{:<14} | {:>12} {:>10} | {:>12} {:>10} | {:>13} {:>10} | {:>12} {:>10}",
            r.dataset,
            r.active_train,
            r.active_train_overhead,
            r.active_test,
            r.active_test_overhead,
            r.passive_train,
            r.passive_train_overhead,
            r.passive_test,
            r.passive_test_overhead,
        );
    }
}

/// E5: scalability sweep — setup+round cost vs number of passive
/// parties (the §5.2 discussion). Uses a synthetic schema so the party
/// count can grow beyond the paper's 4.
pub fn scaling(parties: &[usize]) -> Result<Vec<(usize, f64, u64)>> {
    use crate::crypto::rng::DetRng;
    use crate::secagg::setup_all;
    let mut out = Vec::new();
    for &n in parties {
        // measure the SA fabric directly: setup + one masked round for
        // n clients on a 256×64 activation
        let mut rng = DetRng::from_seed(n as u64);
        let (ms, sessions) = super::time_ms(|| setup_all(n, 0, &mut rng));
        let len = 256 * 64;
        let t = vec![0.5f32; len];
        let (mask_ms, masked) = super::time_ms(|| {
            sessions.iter().map(|s| s.mask_tensor(&t, 0, 0)).collect::<Vec<_>>()
        });
        let bytes: u64 = masked.iter().map(|m| m.len() as u64 * 8).sum();
        out.push((n, ms + mask_ms, bytes));
    }
    Ok(out)
}
