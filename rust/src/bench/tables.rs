//! Table 1 & Table 2 harness: runs the paper's exact experiment shape
//! (1 setup phase + 5 training rounds + a testing pass, batch 256, key
//! rotation every 5 iterations, repeated N times) and prints the same
//! rows the paper reports.

use anyhow::Result;

use crate::coordinator::{run_experiment, BackendKind, RunConfig, RunReport, SecurityMode};
use crate::net::{Addr, Phase};
use crate::runtime::Engine;

use super::{pm, stats, Stats};

/// One dataset's Table-1 row (all ms): active/passive × train/test,
/// total + overhead.
pub struct Table1Row {
    pub dataset: String,
    pub active_train_total: Stats,
    pub active_train_overhead: Stats,
    pub active_test_total: Stats,
    pub active_test_overhead: Stats,
    pub passive_train_total: Stats,
    pub passive_train_overhead: Stats,
    pub passive_test_total: Stats,
    pub passive_test_overhead: Stats,
}

/// One dataset's Table-2 row (bytes per run).
pub struct Table2Row {
    pub dataset: String,
    pub active_train: u64,
    pub active_train_overhead: u64,
    pub active_test: u64,
    pub active_test_overhead: u64,
    pub passive_train: u64,
    pub passive_train_overhead: u64,
    pub passive_test: u64,
    pub passive_test_overhead: u64,
}

fn paper_cfg(dataset: &str, mode: SecurityMode, engine: Option<&Engine>) -> RunConfig {
    let mut cfg = RunConfig::paper(dataset).expect("dataset");
    cfg.security = mode;
    cfg.backend = if engine.is_some() { BackendKind::Pjrt } else { BackendKind::Reference };
    cfg
}

fn passive_nodes(report: &RunReport) -> Vec<usize> {
    // passive clients are 1..n_clients; metrics node index = client + 1
    (2..=report.net.n_clients()).collect()
}

/// Run one secure experiment and return (report, plain-twin report).
fn run_pair(dataset: &str, engine: Option<&Engine>, seed: u64) -> Result<(RunReport, RunReport)> {
    let mut sc = paper_cfg(dataset, SecurityMode::SecureExact, engine);
    sc.seed = seed;
    let mut pc = paper_cfg(dataset, SecurityMode::Plain, engine);
    pc.seed = seed;
    Ok((run_experiment(sc, engine)?, run_experiment(pc, engine)?))
}

/// Table 1: CPU time (ms), averaged over `reps` repetitions.
/// "Total" is the secure run; "overhead" is the directly metered
/// security-op time (cross-checked against secure − plain in tests).
pub fn table1(dataset: &str, reps: usize, engine: Option<&Engine>) -> Result<Table1Row> {
    let mut at_t = vec![];
    let mut at_o = vec![];
    let mut ae_t = vec![];
    let mut ae_o = vec![];
    let mut pt_t = vec![];
    let mut pt_o = vec![];
    let mut pe_t = vec![];
    let mut pe_o = vec![];
    for rep in 0..reps {
        let (secure, _plain) = run_pair(dataset, engine, 7 + rep as u64)?;
        let m = &secure.metrics;
        // setup is part of the training phase the paper reports
        // (1 setup phase + 5 training rounds)
        let active = 1usize; // node index of client 0
        at_t.push(m.total_ms(active, Phase::Training) + m.total_ms(active, Phase::Setup));
        at_o.push(m.overhead_ms(active, Phase::Training) + m.overhead_ms(active, Phase::Setup));
        ae_t.push(m.total_ms(active, Phase::Testing));
        ae_o.push(m.overhead_ms(active, Phase::Testing));
        let passives = passive_nodes(&secure);
        let (t, o) = m.avg_ms(&passives, Phase::Training);
        let (ts, os) = m.avg_ms(&passives, Phase::Setup);
        pt_t.push(t + ts);
        pt_o.push(o + os);
        let (t, o) = m.avg_ms(&passives, Phase::Testing);
        pe_t.push(t);
        pe_o.push(o);
    }
    Ok(Table1Row {
        dataset: dataset.into(),
        active_train_total: stats(&at_t),
        active_train_overhead: stats(&at_o),
        active_test_total: stats(&ae_t),
        active_test_overhead: stats(&ae_o),
        passive_train_total: stats(&pt_t),
        passive_train_overhead: stats(&pt_o),
        passive_test_total: stats(&pe_t),
        passive_test_overhead: stats(&pe_o),
    })
}

/// Table 2: transmission bytes. Byte counts are deterministic per
/// config, so a single secure/plain pair suffices; overhead = secure −
/// plain, exactly as the paper defines it.
pub fn table2(dataset: &str, engine: Option<&Engine>) -> Result<Table2Row> {
    let (secure, plain) = run_pair(dataset, engine, 7)?;
    let tx = |r: &RunReport, node: Addr, ph: Phase| r.net.transmission_bytes(node, ph);
    let active = Addr::Client(0);
    // setup traffic counts toward the training phase (paper reports
    // "1 setup phase and 5 training rounds" as one number)
    let a_train_s = tx(&secure, active, Phase::Training) + tx(&secure, active, Phase::Setup);
    let a_train_p = tx(&plain, active, Phase::Training);
    let a_test_s = tx(&secure, active, Phase::Testing);
    let a_test_p = tx(&plain, active, Phase::Testing);

    let n_passive = secure.net.n_clients() - 1; // minus the active party
    let avg_passive = |r: &RunReport, ph: Phase| -> u64 {
        (1..=n_passive)
            .map(|i| tx(r, Addr::Client(i), ph))
            .sum::<u64>()
            / n_passive as u64
    };
    let p_train_s = avg_passive(&secure, Phase::Training)
        + (1..=n_passive).map(|i| tx(&secure, Addr::Client(i), Phase::Setup)).sum::<u64>()
            / n_passive as u64;
    let p_train_p = avg_passive(&plain, Phase::Training);
    let p_test_s = avg_passive(&secure, Phase::Testing);
    let p_test_p = avg_passive(&plain, Phase::Testing);

    Ok(Table2Row {
        dataset: dataset.into(),
        active_train: a_train_s,
        active_train_overhead: a_train_s - a_train_p,
        active_test: a_test_s,
        active_test_overhead: a_test_s - a_test_p,
        passive_train: p_train_s,
        passive_train_overhead: p_train_s - p_train_p,
        passive_test: p_test_s,
        passive_test_overhead: p_test_s - p_test_p,
    })
}

/// Print Table 1 in the paper's layout.
pub fn print_table1(rows: &[Table1Row]) {
    println!("\nTable 1 — CPU time (ms) with secure aggregation on VFL");
    println!("{:<14} | {:>14} {:>12} | {:>14} {:>12} | {:>14} {:>12} | {:>14} {:>12}",
        "", "Active/train", "overhead", "Active/test", "overhead",
        "Passive/train", "overhead", "Passive/test", "overhead");
    for r in rows {
        println!(
            "{:<14} | {:>14} {:>12} | {:>14} {:>12} | {:>14} {:>12} | {:>14} {:>12}",
            r.dataset,
            pm(&r.active_train_total),
            pm(&r.active_train_overhead),
            pm(&r.active_test_total),
            pm(&r.active_test_overhead),
            pm(&r.passive_train_total),
            pm(&r.passive_train_overhead),
            pm(&r.passive_test_total),
            pm(&r.passive_test_overhead),
        );
    }
}

/// Print Table 2 in the paper's layout.
pub fn print_table2(rows: &[Table2Row]) {
    println!("\nTable 2 — data transmission (bytes) with secure aggregation on VFL");
    println!("{:<14} | {:>12} {:>10} | {:>12} {:>10} | {:>13} {:>10} | {:>12} {:>10}",
        "", "Active/train", "overhead", "Active/test", "overhead",
        "Passive/train", "overhead", "Passive/test", "overhead");
    for r in rows {
        println!(
            "{:<14} | {:>12} {:>10} | {:>12} {:>10} | {:>13} {:>10} | {:>12} {:>10}",
            r.dataset,
            r.active_train,
            r.active_train_overhead,
            r.active_test,
            r.active_test_overhead,
            r.passive_train,
            r.passive_train_overhead,
            r.passive_test,
            r.passive_test_overhead,
        );
    }
}

/// E5: scalability sweep — setup+round cost vs number of passive
/// parties (the §5.2 discussion). Uses a synthetic schema so the party
/// count can grow beyond the paper's 4.
pub fn scaling(parties: &[usize]) -> Result<Vec<(usize, f64, u64)>> {
    use crate::crypto::rng::DetRng;
    use crate::secagg::setup_all;
    let mut out = Vec::new();
    for &n in parties {
        // measure the SA fabric directly: setup + one masked round for
        // n clients on a 256×64 activation
        let mut rng = DetRng::from_seed(n as u64);
        let (ms, sessions) = super::time_ms(|| setup_all(n, 0, &mut rng));
        let len = 256 * 64;
        let t = vec![0.5f32; len];
        let (mask_ms, masked) = super::time_ms(|| {
            sessions.iter().map(|s| s.mask_tensor(&t, 0, 0)).collect::<Vec<_>>()
        });
        let bytes: u64 = masked.iter().map(|m| m.len() as u64 * 8).sum();
        out.push((n, ms + mask_ms, bytes));
    }
    Ok(out)
}
