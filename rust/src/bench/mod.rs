//! Benchmark utilities (criterion is not vendored in this sandbox, so
//! the `harness = false` bench targets use these helpers for timing,
//! statistics, and paper-style table printing).

use std::time::Instant;

/// Summary statistics over repeated measurements.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

pub fn stats(samples: &[f64]) -> Stats {
    assert!(!samples.is_empty());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    Stats {
        mean,
        std: var.sqrt(),
        min: samples.iter().copied().fold(f64::INFINITY, f64::min),
        max: samples.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        n,
    }
}

/// Time one invocation in milliseconds.
pub fn time_ms<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let t0 = Instant::now();
    let out = f();
    (t0.elapsed().as_secs_f64() * 1e3, out)
}

/// Run `reps` timed repetitions (plus one warmup) and return stats in ms.
pub fn bench_ms(reps: usize, mut f: impl FnMut()) -> Stats {
    f(); // warmup
    let samples: Vec<f64> = (0..reps)
        .map(|_| {
            let (ms, _) = time_ms(&mut f);
            ms
        })
        .collect();
    stats(&samples)
}

/// Format `mean ± std` the way the paper's tables do.
pub fn pm(s: &Stats) -> String {
    if s.mean >= 100.0 {
        format!("{:.0} ± {:.0}", s.mean, s.std)
    } else if s.mean >= 1.0 {
        format!("{:.1} ± {:.1}", s.mean, s.std)
    } else {
        format!("{:.3} ± {:.3}", s.mean, s.std)
    }
}

/// Print a markdown-ish table row.
pub fn row(cells: &[String]) -> String {
    format!("| {} |", cells.join(" | "))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = stats(&[1.0, 2.0, 3.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.std - 1.0).abs() < 1e-9);
        assert_eq!(s.n, 3);
    }

    #[test]
    fn single_sample() {
        let s = stats(&[5.0]);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0;
        let s = bench_ms(3, || count += 1);
        assert_eq!(count, 4); // warmup + 3
        assert_eq!(s.n, 3);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(pm(&stats(&[1162.0, 1162.0])), "1162 ± 0");
        assert!(pm(&stats(&[1.5, 2.5])).starts_with("2.0"));
    }
}

pub mod fig2;
pub mod tables;
