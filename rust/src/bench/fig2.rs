//! Figure 2 harness: SA vs homomorphic encryption on dot products.
//!
//! The paper's ablation (§6.5): process a `(B, 8) · (8, 8)` dot product
//! under (a) secure aggregation, (b) Paillier (the Python `phe`
//! comparator), (c) SEAL-style BFV — per-element, exactly as the
//! paper's nested-loop implementations — plus (d) our coefficient-
//! packed BFV as the "what SEAL users would actually do" extension.
//!
//! SA's cost model is the full client-side pipeline: fixed-point
//! encoding of the result + pairwise-mask PRG + masked add, then
//! aggregator-side summation and decode for two parties. HE's cost is
//! encrypt-inputs → homomorphic matmul → decrypt-outputs.

use crate::crypto::bfv::{Bfv, BfvParams};
use crate::crypto::paillier::{EncryptedDot, PrivateKey};
use crate::crypto::rng::DetRng;
use crate::secagg::{aggregate, setup_all, FixedPoint};

use super::{bench_ms, Stats};

/// Fixed-point scale for HE plaintexts (both schemes integer-only).
const HE_SCALE: f64 = 4096.0;

/// One (batch-size, scheme) measurement.
pub struct Fig2Point {
    pub batch: usize,
    pub scheme: &'static str,
    pub stats: Stats,
}

fn gen_inputs(batch: usize, rng: &mut DetRng) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let x: Vec<Vec<f32>> =
        (0..batch).map(|_| (0..8).map(|_| rng.next_f64() as f32 - 0.5).collect()).collect();
    let w: Vec<Vec<f32>> =
        (0..8).map(|_| (0..8).map(|_| rng.next_f64() as f32 - 0.5).collect()).collect();
    (x, w)
}

fn plain_matmul(x: &[Vec<f32>], w: &[Vec<f32>]) -> Vec<Vec<f32>> {
    x.iter()
        .map(|row| {
            (0..8)
                .map(|j| (0..8).map(|k| row[k] * w[k][j]).sum::<f32>())
                .collect()
        })
        .collect()
}

/// Secure aggregation path: two parties each hold a (B,8) result share;
/// both mask, the aggregator sums & decodes (the protocol's actual
/// per-tensor work for a dot product of this shape).
pub fn sa_dot(batch: usize, reps: usize, seed: u64) -> Stats {
    let mut rng = DetRng::from_seed(seed);
    let (x, w) = gen_inputs(batch, &mut rng);
    let sessions = setup_all(2, 0, &mut rng);
    let fp = FixedPoint::default();
    bench_ms(reps, || {
        // each party computes its local dot product share...
        let z = plain_matmul(&x, &w);
        let flat: Vec<f32> = z.iter().flatten().copied().collect();
        let half: Vec<f32> = flat.iter().map(|v| v * 0.5).collect();
        // ...masks it (Eq. 2)...
        let m0 = sessions[0].mask_tensor(&half, 0, 0);
        let m1 = sessions[1].mask_tensor(&half, 0, 0);
        // ...and the aggregator unmasks by summation (Eq. 5)
        let out = aggregate(&fp, &[m0, m1]);
        std::hint::black_box(out);
    })
}

/// Paillier path (the `phe` comparator): encrypt every input element,
/// homomorphic matvec per row, decrypt every output element.
pub fn paillier_dot(batch: usize, reps: usize, key_bits: usize, seed: u64) -> Stats {
    let mut rng = DetRng::from_seed(seed);
    let (x, w) = gen_inputs(batch, &mut rng);
    let mut keyrng = DetRng::from_seed(seed ^ 0xff).as_fill_fn();
    let sk = PrivateKey::generate(key_bits, &mut keyrng);
    let pk = sk.public.clone();
    let wi: Vec<Vec<i64>> = w
        .iter()
        .map(|r| r.iter().map(|&v| (v as f64 * HE_SCALE) as i64).collect())
        .collect();
    let mut encrng = DetRng::from_seed(seed ^ 0xaa).as_fill_fn();
    bench_ms(reps, || {
        let dot = EncryptedDot { key: &pk };
        for row in &x {
            let enc: Vec<_> = row
                .iter()
                .map(|&v| pk.encrypt_i64((v as f64 * HE_SCALE) as i64, &mut encrng))
                .collect();
            let out = dot.matvec(&enc, &wi);
            for c in &out {
                std::hint::black_box(sk.decrypt_i64(c));
            }
        }
    })
}

/// BFV path (the SEAL comparator), per-element like the paper's
/// SEAL-Python nested loops.
pub fn bfv_dot_naive(batch: usize, reps: usize, n_poly: usize, seed: u64) -> Stats {
    let mut rng = DetRng::from_seed(seed);
    let (x, w) = gen_inputs(batch, &mut rng);
    let mut keyrng = DetRng::from_seed(seed ^ 0x77).as_fill_fn();
    let bfv = Bfv::keygen(BfvParams::new(n_poly, 1 << 32), &mut keyrng);
    let wi: Vec<Vec<i64>> = w
        .iter()
        .map(|r| r.iter().map(|&v| (v as f64 * HE_SCALE) as i64).collect())
        .collect();
    let mut encrng = DetRng::from_seed(seed ^ 0xbb).as_fill_fn();
    bench_ms(reps, || {
        for row in &x {
            let enc: Vec<_> = row
                .iter()
                .map(|&v| {
                    bfv.encrypt(&bfv.encode_scalar((v as f64 * HE_SCALE) as i64), &mut encrng)
                })
                .collect();
            for j in 0..8 {
                let col: Vec<i64> = (0..8).map(|k| wi[k][j]).collect();
                let ct = bfv.dot_naive(&enc, &col);
                std::hint::black_box(bfv.decode_scalar(&bfv.decrypt(&ct)));
            }
        }
    })
}

/// BFV with coefficient packing: one ciphertext per input row.
pub fn bfv_dot_packed(batch: usize, reps: usize, n_poly: usize, seed: u64) -> Stats {
    let mut rng = DetRng::from_seed(seed);
    let (x, w) = gen_inputs(batch, &mut rng);
    let mut keyrng = DetRng::from_seed(seed ^ 0x33).as_fill_fn();
    let bfv = Bfv::keygen(BfvParams::new(n_poly, 1 << 32), &mut keyrng);
    let wi: Vec<Vec<i64>> = w
        .iter()
        .map(|r| r.iter().map(|&v| (v as f64 * HE_SCALE) as i64).collect())
        .collect();
    let mut encrng = DetRng::from_seed(seed ^ 0x44).as_fill_fn();
    bench_ms(reps, || {
        for row in &x {
            let xi: Vec<i64> = row.iter().map(|&v| (v as f64 * HE_SCALE) as i64).collect();
            let enc = bfv.encrypt(&bfv.encode_coeffs(&xi), &mut encrng);
            for j in 0..8 {
                let col: Vec<i64> = (0..8).map(|k| wi[k][j]).collect();
                let (ct, idx) = bfv.dot_packed(&enc, &col, 8);
                std::hint::black_box(bfv.decode_coeff(&bfv.decrypt(&ct), idx));
            }
        }
    })
}

/// Run the full Figure-2 sweep.
pub fn sweep(batches: &[usize], quick: bool) -> Vec<Fig2Point> {
    let mut out = Vec::new();
    let (pail_bits, bfv_n) = if quick { (256, 512) } else { (1024, 4096) };
    for &b in batches {
        let reps = if b <= 16 { 10 } else if b <= 64 { 5 } else { 3 };
        let reps = if quick { 2 } else { reps };
        out.push(Fig2Point { batch: b, scheme: "SA", stats: sa_dot(b, reps.max(3), 1) });
        out.push(Fig2Point {
            batch: b,
            scheme: "Paillier(phe)",
            stats: paillier_dot(b, reps, pail_bits, 1),
        });
        out.push(Fig2Point {
            batch: b,
            scheme: "BFV(SEAL)",
            stats: bfv_dot_naive(b, reps, bfv_n, 1),
        });
        out.push(Fig2Point {
            batch: b,
            scheme: "BFV-packed",
            stats: bfv_dot_packed(b, reps, bfv_n, 1),
        });
    }
    out
}

/// Print the sweep as the paper's figure data (log-scale y in spirit).
pub fn print_sweep(points: &[Fig2Point]) {
    println!("\nFigure 2 — avg CPU time (ms) per (B,8)·(8,8) dot product");
    println!("{:<8} {:<16} {:>12} {:>10} {:>14}", "batch", "scheme", "mean_ms", "std_ms", "speedup_vs_SA");
    let mut sa_by_batch = std::collections::HashMap::new();
    for p in points.iter().filter(|p| p.scheme == "SA") {
        sa_by_batch.insert(p.batch, p.stats.mean);
    }
    for p in points {
        let speedup = sa_by_batch
            .get(&p.batch)
            .map(|sa| p.stats.mean / sa)
            .unwrap_or(f64::NAN);
        println!(
            "{:<8} {:<16} {:>12.3} {:>10.3} {:>13.1}x",
            p.batch, p.scheme, p.stats.mean, p.stats.std, speedup
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sa_beats_he_by_orders_of_magnitude() {
        // the paper's headline: 9.1e2 ~ 3.8e4 × speedup. At quick
        // parameters the gap is smaller but must still be ≫ 10×.
        let sa = sa_dot(8, 3, 42);
        let pail = paillier_dot(8, 2, 256, 42);
        let bfv = bfv_dot_naive(8, 2, 512, 42);
        assert!(
            pail.mean > sa.mean * 10.0,
            "Paillier {:.3}ms should dwarf SA {:.3}ms",
            pail.mean,
            sa.mean
        );
        assert!(bfv.mean > sa.mean * 10.0, "BFV {:.3}ms vs SA {:.3}ms", bfv.mean, sa.mean);
    }

    #[test]
    fn packed_bfv_faster_than_naive() {
        let naive = bfv_dot_naive(8, 2, 512, 1);
        let packed = bfv_dot_packed(8, 2, 512, 1);
        assert!(
            packed.mean < naive.mean,
            "packing should win: packed {:.3}ms vs naive {:.3}ms",
            packed.mean,
            naive.mean
        );
    }
}
