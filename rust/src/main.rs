//! `vfl-sa` — launcher for the VFL + secure-aggregation system.
//!
//! Subcommands (hand-rolled parser; clap is not vendored here):
//!   train    --dataset <banking|adult|taobao> [--rounds N] [--rows N]
//!            [--plain|--float] [--reference] [--threaded] [--seed N]
//!   serve    --listen HOST:PORT [train flags] — host the aggregator +
//!            driver; waits for every client to `join`
//!   join     --connect HOST:PORT --party I [train flags] — run client
//!            party I (0 = active) against a serving aggregator
//!   leaf     --listen HOST:PORT --connect HOST:PORT --leaf-index K
//!            --leaves L [train flags] — run leaf aggregator K of the
//!            hierarchical fan-in tree: owns one contiguous client
//!            shard, folds its masked fan-in into partial ℤ₂⁶⁴ sums,
//!            relays everything else to the root (`serve`) verbatim
//!   bench    table1|table2|fig2|scaling [--reps N] [--quick] [--reference]
//!   swarm    --clients N — C10K load generator: N simulated clients
//!            against one event-loop aggregator over real sockets
//!   info     print dataset/model configurations
//!
//! `train` and `bench` default to the PJRT backend and expect
//! `make artifacts` (plus a `--features pjrt` build); `serve`/`join`
//! run on the reference backend so a multi-process demo needs nothing
//! but this binary. Every process of a serve/join run must pass the
//! same dataset/rows/rounds/seed flags — the schedule and synthetic
//! data are derived deterministically from them.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use vfl::bench::{fig2, tables};
use vfl::coordinator::{
    build, run_experiment, summarize, BackendKind, Built, RunConfig, SecurityMode, TransportKind,
    SETUP_ROUND,
};
use vfl::model::ModelConfig;
use vfl::net::{tcp, Addr, Fault, FaultPlan, Phase};
use vfl::runtime::Engine;

/// A token is a flag if it starts with `-` and is not a number —
/// `-3` and `-0.5` are values (e.g. `--seed -3`), `--plain` is not.
fn looks_like_flag(tok: &str) -> bool {
    tok.starts_with('-') && tok.parse::<f64>().is_err()
}

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if let Some((n, v)) = name.split_once('=') {
                flags.insert(n.to_string(), v.to_string());
                i += 1;
            } else if let Some(v) = args.get(i + 1).filter(|v| !looks_like_flag(v)) {
                flags.insert(name.to_string(), v.clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".into());
                i += 1;
            }
        } else {
            pos.push(a.clone());
            i += 1;
        }
    }
    (pos, flags)
}

/// Parse a `--dropout-schedule` spec: comma-separated
/// `client@round[+after_sends]` crash points, `round` being a training
/// round number or `setup`. Example: `2@1,4@3+1` — client 2 crashes at
/// the start of round 1, client 4 after one send in round 3.
fn parse_dropout_schedule(spec: &str) -> Result<FaultPlan> {
    let mut plan = FaultPlan::default();
    for part in spec.split(',').filter(|p| !p.is_empty()) {
        let (client, rest) = part
            .split_once('@')
            .with_context(|| format!("bad crash point {part:?} (want client@round[+sends])"))?;
        let client: usize = client.trim().parse().context("bad client index")?;
        let (round, after_sends) = match rest.split_once('+') {
            Some((r, s)) => (r, s.trim().parse().context("bad send count")?),
            None => (rest, 0usize),
        };
        let round = match round.trim() {
            "setup" => SETUP_ROUND,
            r => r.parse().context("bad round (number or 'setup')")?,
        };
        plan = plan.with(client, Fault::Crash { round, after_sends });
    }
    if plan.faults.is_empty() {
        bail!("empty --dropout-schedule");
    }
    Ok(plan)
}

/// Build a RunConfig from the shared train/serve/join flags.
fn cfg_from_flags(flags: &HashMap<String, String>) -> Result<RunConfig> {
    let dataset = flags.get("dataset").map(String::as_str).unwrap_or("banking");
    let mut cfg = RunConfig::paper(dataset).context("unknown dataset")?;
    if let Some(r) = flags.get("rounds") {
        cfg.train_rounds = r.parse()?;
    }
    if let Some(r) = flags.get("rows") {
        cfg.n_rows = r.parse()?;
    }
    if let Some(s) = flags.get("seed") {
        cfg.seed = match s.parse::<u64>() {
            Ok(v) => v,
            Err(_) => s.parse::<i64>().context("bad --seed")? as u64,
        };
    }
    if flags.contains_key("plain") {
        cfg.security = SecurityMode::Plain;
    } else if flags.contains_key("float") {
        cfg.security = SecurityMode::SecureFloat;
    }
    if flags.contains_key("reference") {
        cfg.backend = BackendKind::Reference;
    }
    if flags.contains_key("threaded") {
        cfg.transport = TransportKind::Threaded;
    }
    if flags.contains_key("evloop") {
        if cfg.transport != TransportKind::Sim {
            bail!("--evloop conflicts with --threaded (pick one transport)");
        }
        cfg.transport = TransportKind::Evloop;
    }
    cfg.test_rounds = flags.get("test-rounds").map(|v| v.parse()).transpose()?.unwrap_or(1);
    if let Some(t) = flags.get("shamir-threshold") {
        cfg.shamir_threshold = Some(t.parse().context("bad --shamir-threshold")?);
    }
    if let Some(cw) = flags.get("chunk-words") {
        cfg.chunk_words = Some(cw.parse().context("bad --chunk-words")?);
    }
    if let Some(s) = flags.get("shards") {
        cfg.shards = s.parse().context("bad --shards")?;
    }
    if let Some(w) = flags.get("agg-workers") {
        cfg.agg_workers = w.parse().context("bad --agg-workers")?;
    }
    if let Some(w) = flags.get("expand-workers") {
        cfg.expand_workers = w.parse().context("bad --expand-workers")?;
    }
    if let Some(k) = flags.get("evloop-threads") {
        cfg.evloop_threads = k.parse().context("bad --evloop-threads")?;
    }
    if let Some(l) = flags.get("leaves") {
        cfg.leaves = Some(l.parse().context("bad --leaves")?);
    }
    if let Some(w) = flags.get("rounds-in-flight") {
        cfg.rounds_in_flight = w.parse().context("bad --rounds-in-flight")?;
    }
    if flags.contains_key("rollback-fsync") {
        cfg.rollback_fsync = true;
    }
    if let Some(b) = flags.get("rollback-max-bytes") {
        cfg.rollback_max_bytes = Some(b.parse().context("bad --rollback-max-bytes")?);
    }
    if let Some(ms) = flags.get("stall-timeout-ms") {
        cfg.stall_timeout_ms = Some(ms.parse().context("bad --stall-timeout-ms")?);
    }
    if let Some(ms) = flags.get("stall-cap-ms") {
        cfg.stall_cap_ms = Some(ms.parse().context("bad --stall-cap-ms")?);
    }
    // fail the streaming, timing, window, and topology flags here, at
    // parse time, with the full validation the driver applies —
    // `--chunk-words 0`, `--shards 0`, `--agg-workers 0`, `--leaves 0`,
    // oversized shard/worker/window/leaf counts, zero-width stall
    // windows, and a zero-byte rollback bound must never reach a
    // running round
    vfl::coordinator::validate_streaming(&cfg)?;
    vfl::coordinator::validate_timing(&cfg)?;
    vfl::coordinator::validate_window(&cfg)?;
    vfl::coordinator::validate_evloop(&cfg)?;
    vfl::coordinator::validate_topology(&cfg)?;
    if let Some(spec) = flags.get("dropout-schedule") {
        if cfg.shamir_threshold.is_none() {
            bail!("--dropout-schedule needs --shamir-threshold (the run cannot recover otherwise)");
        }
        let plan = parse_dropout_schedule(spec)?;
        // validate against the actual run shape: a silently out-of-range
        // crash point would make a "recovery worked" run prove nothing
        let n = cfg.model.n_clients();
        for (c, f) in &plan.faults {
            if *c >= n {
                bail!("dropout schedule client {c} out of range (this config has clients 0..{n})");
            }
            if let Fault::Crash { round, .. } = f {
                if *round != SETUP_ROUND && *round as usize >= cfg.train_rounds {
                    bail!(
                        "dropout schedule round {round} out of range (0..{} or 'setup')",
                        cfg.train_rounds
                    );
                }
            }
        }
        cfg.fault_plan = Some(plan);
    }
    Ok(cfg)
}

fn load_engine(dataset: &str) -> Result<Engine> {
    let cfg = ModelConfig::for_dataset(dataset).context("unknown dataset")?;
    Engine::load("artifacts", &cfg)
}

fn cmd_train(flags: &HashMap<String, String>) -> Result<()> {
    let cfg = cfg_from_flags(flags)?;
    let dataset = cfg.model.dataset.clone();
    let reference = cfg.backend == BackendKind::Reference;
    // reject before any engine gets loaded: a shared PJRT engine may
    // not be driven from several party threads
    if cfg.transport == TransportKind::Threaded && !reference {
        bail!("--threaded requires --reference (a shared PJRT engine is not driven from several threads)");
    }
    if cfg.transport == TransportKind::Evloop && !reference {
        bail!("--evloop requires --reference (a shared PJRT engine is not driven from several threads)");
    }

    println!(
        "training {dataset}: {} rounds, {} rows, {:?}, backend {:?}, transport {:?}",
        cfg.train_rounds, cfg.n_rows, cfg.security, cfg.backend, cfg.transport
    );
    let engine = if reference { None } else { Some(load_engine(&dataset)?) };
    let report = run_experiment(cfg, engine.as_ref())?;
    for (i, l) in report.losses.iter().enumerate() {
        println!("round {i:>4}  loss {l:.5}");
    }
    println!("test accuracy: {:.4}", report.test_accuracy);
    println!("setups (1 + rotations): {}", report.setups);
    println!(
        "active tx bytes: setup {} / train {} / test {}",
        report.net.transmission_bytes(Addr::Client(0), Phase::Setup),
        report.net.transmission_bytes(Addr::Client(0), Phase::Training),
        report.net.transmission_bytes(Addr::Client(0), Phase::Testing),
    );
    println!(
        "active CPU ms: train {:.1} (overhead {:.1}) / test {:.1} (overhead {:.1})",
        report.metrics.total_ms(1, Phase::Training),
        report.metrics.overhead_ms(1, Phase::Training),
        report.metrics.total_ms(1, Phase::Testing),
        report.metrics.overhead_ms(1, Phase::Testing),
    );
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let listen =
        flags.get("listen").cloned().unwrap_or_else(|| "127.0.0.1:7800".to_string());
    let mut cfg = cfg_from_flags(flags)?;
    cfg.backend = BackendKind::Reference; // serve/join runs are self-contained
    let n_clients = cfg.model.n_clients();
    let Built { mut parties, schedule, test_labels, setups } = build(&cfg, None)?;
    let aggregator = parties.remove(0);
    drop(parties); // the clients run in their own `join` processes

    println!(
        "serving {} on {listen}: {} train rounds, {} clients — start them with:",
        cfg.model.dataset, cfg.train_rounds, n_clients
    );
    for i in 0..n_clients {
        println!("  vfl-sa join --connect {listen} --party {i} <same train flags>");
    }
    let clock = vfl::net::StallClock::from_config(cfg.stall_timeout_ms, cfg.stall_cap_ms);
    let out =
        tcp::serve(&listen, aggregator, &schedule, n_clients, clock, cfg.rounds_in_flight)?;
    let s = summarize(&schedule, &test_labels, &out.notes);
    for (i, l) in s.losses.iter().enumerate() {
        println!("round {i:>4}  loss {l:.5}");
    }
    println!("test accuracy: {:.4}", s.test_accuracy);
    println!("setups (1 + rotations): {setups}");
    println!(
        "active tx bytes: setup {} / train {} / test {}",
        out.net.transmission_bytes(Addr::Client(0), Phase::Setup),
        out.net.transmission_bytes(Addr::Client(0), Phase::Training),
        out.net.transmission_bytes(Addr::Client(0), Phase::Testing),
    );
    Ok(())
}

fn cmd_join(flags: &HashMap<String, String>) -> Result<()> {
    let connect =
        flags.get("connect").cloned().unwrap_or_else(|| "127.0.0.1:7800".to_string());
    let party_idx: usize =
        flags.get("party").context("--party <index> required (0 = active)")?.parse()?;
    let mut cfg = cfg_from_flags(flags)?;
    cfg.backend = BackendKind::Reference;
    let n_clients = cfg.model.n_clients();
    if party_idx >= n_clients {
        bail!("--party {party_idx} out of range ({} has {n_clients} clients)", cfg.model.dataset);
    }
    let Built { mut parties, .. } = build(&cfg, None)?;
    let party = parties.remove(party_idx + 1); // node 0 is the aggregator
    drop(parties);
    // each join process applies only its own slice of the schedule
    let party = match &cfg.fault_plan {
        Some(plan) => plan.wrap_one(party_idx, party),
        None => party,
    };

    let metrics = tcp::join(&connect, party_idx, party)?;
    let node = party_idx + 1;
    println!(
        "party {party_idx} done — CPU ms: setup {:.1} / train {:.1} (overhead {:.1}) / test {:.1}",
        metrics.total_ms(node, Phase::Setup),
        metrics.total_ms(node, Phase::Training),
        metrics.overhead_ms(node, Phase::Training),
        metrics.total_ms(node, Phase::Testing),
    );
    Ok(())
}

/// `vfl-sa leaf`: one leaf aggregator of the hierarchical fan-in tree
/// (`--leaves`), serving its shard's clients and relaying to the root.
/// The shard map is derived from (dataset, --leaves, --leaf-index)
/// alone, so every process of the run computes the identical
/// partition; the root runs a plain `vfl-sa serve` (no `--leaves`) —
/// the topology is invisible to it, its aggregator stitches whatever
/// mix of direct masked tensors and leaf partials arrives.
fn cmd_leaf(flags: &HashMap<String, String>) -> Result<()> {
    let listen =
        flags.get("listen").cloned().unwrap_or_else(|| "127.0.0.1:7900".to_string());
    let connect =
        flags.get("connect").cloned().unwrap_or_else(|| "127.0.0.1:7800".to_string());
    let index: usize =
        flags.get("leaf-index").context("--leaf-index <k> required (0-based)")?.parse()?;
    let cfg = cfg_from_flags(flags)?;
    let Some(leaves) = vfl::coordinator::validate_topology(&cfg)? else {
        bail!("leaf needs --leaves <L> (the shard map every process derives)");
    };
    if index >= leaves {
        bail!("--leaf-index {index} out of range (this run has {leaves} leaves)");
    }
    let stream = vfl::coordinator::validate_streaming(&cfg)?;
    let map = vfl::coordinator::ShardMap::new(cfg.model.n_clients(), leaves);
    let (start, end) = map.range(index);
    println!(
        "leaf {index}/{leaves} on {}: clients {start}..{end}, root {connect} — join them with:",
        cfg.model.dataset
    );
    for c in start..end {
        println!("  vfl-sa join --connect {listen} --party {c} <same train flags>");
    }
    tcp::leaf(&listen, &connect, index, start, end, &stream, cfg.shamir_threshold.is_some())
}

fn cmd_bench(pos: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let which = pos.first().map(String::as_str).unwrap_or("table1");
    let reference = flags.contains_key("reference");
    let reps: usize = flags.get("reps").map(|v| v.parse()).transpose()?.unwrap_or(10);
    let quick = flags.contains_key("quick");
    match which {
        "table1" => {
            let window: usize =
                flags.get("window").map(|v| v.parse()).transpose()?.unwrap_or(1);
            let mut rows = Vec::new();
            for ds in ["banking", "adult", "taobao"] {
                let engine = if reference { None } else { Some(load_engine(ds)?) };
                rows.push(tables::table1(ds, reps, engine.as_ref(), window)?);
            }
            tables::print_table1(&rows);
        }
        "table2" => {
            let mut rows = Vec::new();
            for ds in ["banking", "adult", "taobao"] {
                let engine = if reference { None } else { Some(load_engine(ds)?) };
                rows.push(tables::table2(ds, engine.as_ref())?);
            }
            tables::print_table2(&rows);
        }
        "fig2" => {
            let batches = [1usize, 2, 4, 8, 16, 32, 64, 128, 256];
            let pts = fig2::sweep(&batches, quick);
            fig2::print_sweep(&pts);
        }
        "scaling" => {
            let pts = tables::scaling(&[2, 4, 8, 16, 32])?;
            println!("\nE5 — SA fabric scaling (setup + one masked 256×64 round)");
            println!("{:<10} {:>12} {:>14}", "clients", "cpu_ms", "masked_bytes");
            for (n, ms, bytes) in pts {
                println!("{n:<10} {ms:>12.2} {bytes:>14}");
            }
        }
        w => bail!("unknown bench {w} (table1|table2|fig2|scaling)"),
    }
    Ok(())
}

/// `vfl-sa swarm --clients N`: the event-loop C10K load generator —
/// N simulated passive clients against one evloop aggregator over real
/// localhost sockets, with a checksum proving no frame was lost.
#[cfg(unix)]
fn cmd_swarm(flags: &HashMap<String, String>) -> Result<()> {
    use vfl::net::evloop::swarm::{self, SwarmCfg};
    use vfl::net::evloop::PollerKind;

    let mut cfg = SwarmCfg::default();
    if let Some(v) = flags.get("clients") {
        cfg.clients = v.parse().context("bad --clients")?;
    }
    if let Some(v) = flags.get("rounds") {
        cfg.rounds = v.parse().context("bad --rounds")?;
    }
    if let Some(v) = flags.get("payload-words") {
        cfg.payload_words = v.parse().context("bad --payload-words")?;
    }
    if let Some(v) = flags.get("client-threads") {
        cfg.client_threads = v.parse().context("bad --client-threads")?;
    }
    if let Some(v) = flags.get("evloop-threads") {
        cfg.server_threads = v.parse().context("bad --evloop-threads")?;
        if cfg.server_threads == 0 {
            bail!("--evloop-threads 0 is invalid (the swarm server needs at least one loop)");
        }
    }
    if flags.contains_key("poll-fallback") {
        cfg.poller = PollerKind::PollFallback;
    }
    println!(
        "swarm: {} clients x {} rounds x {} words ({} client threads, {} server loops)...",
        cfg.clients, cfg.rounds, cfg.payload_words, cfg.client_threads, cfg.server_threads
    );
    let report = swarm::run(&cfg)?;
    println!(
        "swarm done in {:.1} ms on {}: peak {} live connections, \
         peak {} B buffered on any one connection, {} payload bytes in, rss peak {} kB",
        report.wall_ms,
        report.poller,
        report.peak_live_connections,
        report.peak_conn_buffered_bytes,
        report.bytes_received,
        report.rss_peak_kb,
    );
    println!("{}", report.json());
    if !report.verified() {
        bail!(
            "swarm checksum mismatch: got {:#x}, expected {:#x} — a frame was lost or corrupted",
            report.checksum,
            report.expected_checksum
        );
    }
    Ok(())
}

#[cfg(not(unix))]
fn cmd_swarm(_flags: &HashMap<String, String>) -> Result<()> {
    bail!("swarm needs a unix platform (the evloop transport uses nonblocking sockets)")
}

fn cmd_info() -> Result<()> {
    println!("dataset configurations (§6.2 of the paper):");
    for ds in ["banking", "adult", "taobao"] {
        let c = ModelConfig::for_dataset(ds).unwrap();
        println!(
            "  {ds:<10} active-dim {:>3}  groups {:?}  hidden {:>3}  clients {}  params {}",
            c.active_dim,
            c.group_dims,
            c.hidden,
            c.n_clients(),
            c.n_params()
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    match pos.first().map(String::as_str) {
        Some("train") => cmd_train(&flags),
        Some("serve") => cmd_serve(&flags),
        Some("join") => cmd_join(&flags),
        Some("leaf") => cmd_leaf(&flags),
        Some("bench") => cmd_bench(&pos[1..], &flags),
        Some("swarm") => cmd_swarm(&flags),
        Some("info") => cmd_info(),
        _ => {
            eprintln!("usage: vfl-sa <train|serve|join|leaf|bench|swarm|info> [flags]");
            eprintln!("  train --dataset banking [--rounds 5] [--rows 4096] [--plain|--float] [--reference] [--threaded|--evloop]");
            eprintln!("        [--shamir-threshold 3] [--dropout-schedule 2@1,4@3+1]   dropout-tolerant run");
            eprintln!("        [--chunk-words 1024] [--shards 4] [--agg-workers 4]   streaming shard-parallel aggregation");
            eprintln!("        [--expand-workers 4]                                   parallel mask expansion (1 = serial)");
            eprintln!("        [--evloop-threads 4]                                   sharded event-loop pollers (evloop only)");
            eprintln!("        [--rounds-in-flight 2]                                 pipelined round window (1 = serial)");
            eprintln!("        [--rollback-fsync] [--rollback-max-bytes N]            rollback-log durability/bound");
            eprintln!("        [--stall-timeout-ms 500] [--stall-cap-ms 10000]       adaptive dropout-window floor/cap");
            eprintln!("        [--leaves 4]                                           hierarchical fan-in tree (leaf aggregators)");
            eprintln!("  serve --listen 127.0.0.1:7800 [train flags]");
            eprintln!("  join  --connect 127.0.0.1:7800 --party 0 [train flags]");
            eprintln!("  leaf  --listen 127.0.0.1:7900 --connect 127.0.0.1:7800 --leaf-index 0 --leaves 2 [train flags]");
            eprintln!("  bench <table1|table2|fig2|scaling> [--reps 10] [--quick] [--reference]");
            eprintln!("  swarm --clients 10240 [--rounds 3] [--payload-words 32] [--client-threads 4] [--evloop-threads 4] [--poll-fallback]");
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|a| a.to_string()).collect()
    }

    #[test]
    fn positional_and_boolean_flags() {
        let (pos, flags) = parse_flags(&args(&["bench", "table2", "--quick", "--reference"]));
        assert_eq!(pos, vec!["bench", "table2"]);
        assert_eq!(flags.get("quick").map(String::as_str), Some("true"));
        assert_eq!(flags.get("reference").map(String::as_str), Some("true"));
    }

    #[test]
    fn valued_flags() {
        let (pos, flags) = parse_flags(&args(&["train", "--rounds", "7", "--dataset", "adult"]));
        assert_eq!(pos, vec!["train"]);
        assert_eq!(flags.get("rounds").map(String::as_str), Some("7"));
        assert_eq!(flags.get("dataset").map(String::as_str), Some("adult"));
    }

    #[test]
    fn negative_numbers_are_values_not_flags() {
        let (_, flags) = parse_flags(&args(&["train", "--seed", "-3", "--rounds", "2"]));
        assert_eq!(flags.get("seed").map(String::as_str), Some("-3"));
        assert_eq!(flags.get("rounds").map(String::as_str), Some("2"));
        let (_, flags) = parse_flags(&args(&["train", "--lr", "-0.5"]));
        assert_eq!(flags.get("lr").map(String::as_str), Some("-0.5"));
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let (_, flags) = parse_flags(&args(&["train", "--plain", "--rounds", "3"]));
        assert_eq!(flags.get("plain").map(String::as_str), Some("true"));
        assert_eq!(flags.get("rounds").map(String::as_str), Some("3"));
    }

    #[test]
    fn equals_syntax() {
        let (_, flags) = parse_flags(&args(&["train", "--seed=-3", "--dataset=taobao"]));
        assert_eq!(flags.get("seed").map(String::as_str), Some("-3"));
        assert_eq!(flags.get("dataset").map(String::as_str), Some("taobao"));
    }

    #[test]
    fn negative_seed_accepted_by_config() {
        let mut flags = HashMap::new();
        flags.insert("seed".to_string(), "-3".to_string());
        let cfg = cfg_from_flags(&flags).unwrap();
        assert_eq!(cfg.seed, (-3i64) as u64);
    }

    #[test]
    fn streaming_flags_wire_into_config_and_invalid_values_rejected() {
        let mut flags = HashMap::new();
        flags.insert("chunk-words".to_string(), "1024".to_string());
        flags.insert("shards".to_string(), "4".to_string());
        let cfg = cfg_from_flags(&flags).unwrap();
        assert_eq!(cfg.chunk_words, Some(1024));
        assert_eq!(cfg.shards, 4);

        // zero values must fail at flag parsing, not panic mid-round
        for (k, v) in [("chunk-words", "0"), ("shards", "0")] {
            let mut flags = HashMap::new();
            flags.insert("chunk-words".to_string(), "64".to_string());
            flags.insert(k.to_string(), v.to_string());
            let err = cfg_from_flags(&flags).unwrap_err().to_string();
            assert!(err.contains("invalid"), "{k}={v}: {err}");
        }
        // shard count beyond the smallest masked tensor rejected
        let mut flags = HashMap::new();
        flags.insert("chunk-words".to_string(), "64".to_string());
        flags.insert("shards".to_string(), "9999999".to_string());
        assert!(cfg_from_flags(&flags).unwrap_err().to_string().contains("exceeds"));
        // sharding without chunking rejected
        let mut flags = HashMap::new();
        flags.insert("shards".to_string(), "2".to_string());
        assert!(cfg_from_flags(&flags).unwrap_err().to_string().contains("--chunk-words"));
        // chunking is exact-masking only
        let mut flags = HashMap::new();
        flags.insert("chunk-words".to_string(), "64".to_string());
        flags.insert("plain".to_string(), "true".to_string());
        assert!(cfg_from_flags(&flags).unwrap_err().to_string().contains("SecureExact"));
        // stall floor/cap parse
        let mut flags = HashMap::new();
        flags.insert("stall-timeout-ms".to_string(), "250".to_string());
        flags.insert("stall-cap-ms".to_string(), "2500".to_string());
        let cfg = cfg_from_flags(&flags).unwrap();
        assert_eq!(cfg.stall_timeout_ms, Some(250));
        assert_eq!(cfg.stall_cap_ms, Some(2500));
    }

    #[test]
    fn agg_workers_flag_wires_into_config_and_zero_rejected() {
        let mut flags = HashMap::new();
        flags.insert("chunk-words".to_string(), "1024".to_string());
        flags.insert("shards".to_string(), "4".to_string());
        flags.insert("agg-workers".to_string(), "3".to_string());
        assert_eq!(cfg_from_flags(&flags).unwrap().agg_workers, 3);
        // zero workers fail at flag parsing
        let mut flags = HashMap::new();
        flags.insert("chunk-words".to_string(), "1024".to_string());
        flags.insert("agg-workers".to_string(), "0".to_string());
        assert!(cfg_from_flags(&flags).unwrap_err().to_string().contains("invalid"));
        // workers without the chunked pipeline rejected
        let mut flags = HashMap::new();
        flags.insert("agg-workers".to_string(), "3".to_string());
        assert!(cfg_from_flags(&flags).unwrap_err().to_string().contains("--chunk-words"));
    }

    #[test]
    fn expand_workers_flag_wires_into_config_and_zero_rejected() {
        // meaningful without chunking — a monolithic run accepts it
        let mut flags = HashMap::new();
        flags.insert("expand-workers".to_string(), "4".to_string());
        assert_eq!(cfg_from_flags(&flags).unwrap().expand_workers, 4);
        // and alongside the chunked pipeline
        let mut flags = HashMap::new();
        flags.insert("chunk-words".to_string(), "1024".to_string());
        flags.insert("shards".to_string(), "4".to_string());
        flags.insert("expand-workers".to_string(), "2".to_string());
        assert_eq!(cfg_from_flags(&flags).unwrap().expand_workers, 2);
        // zero workers fail at flag parsing
        let mut flags = HashMap::new();
        flags.insert("expand-workers".to_string(), "0".to_string());
        assert!(cfg_from_flags(&flags).unwrap_err().to_string().contains("invalid"));
        // a runaway count fails at flag parsing
        let mut flags = HashMap::new();
        flags.insert("expand-workers".to_string(), "1000".to_string());
        assert!(cfg_from_flags(&flags).unwrap_err().to_string().contains("cap"));
    }

    #[test]
    fn evloop_threads_flag_wires_into_config_and_zero_rejected() {
        let mut flags = HashMap::new();
        flags.insert("evloop".to_string(), "true".to_string());
        flags.insert("evloop-threads".to_string(), "4".to_string());
        let cfg = cfg_from_flags(&flags).unwrap();
        assert_eq!(cfg.transport, TransportKind::Evloop);
        assert_eq!(cfg.evloop_threads, 4);
        // default is one loop
        assert_eq!(cfg_from_flags(&HashMap::new()).unwrap().evloop_threads, 1);
        // zero loops fail at flag parsing
        let mut flags = HashMap::new();
        flags.insert("evloop-threads".to_string(), "0".to_string());
        assert!(cfg_from_flags(&flags).unwrap_err().to_string().contains("--evloop-threads 0"));
        // a runaway count fails at flag parsing
        let mut flags = HashMap::new();
        flags.insert("evloop-threads".to_string(), "1000".to_string());
        assert!(cfg_from_flags(&flags).unwrap_err().to_string().contains("cap"));
    }

    #[test]
    fn leaves_flag_wires_into_config_and_invalid_values_rejected() {
        let mut flags = HashMap::new();
        flags.insert("leaves".to_string(), "2".to_string());
        assert_eq!(cfg_from_flags(&flags).unwrap().leaves, Some(2));
        // default is the flat topology
        assert_eq!(cfg_from_flags(&HashMap::new()).unwrap().leaves, None);
        // zero leaves fail at flag parsing
        let mut flags = HashMap::new();
        flags.insert("leaves".to_string(), "0".to_string());
        assert!(cfg_from_flags(&flags).unwrap_err().to_string().contains("--leaves 0"));
        // a runaway count fails at flag parsing
        let mut flags = HashMap::new();
        flags.insert("leaves".to_string(), "1000".to_string());
        assert!(cfg_from_flags(&flags).unwrap_err().to_string().contains("cap"));
        // more leaves than clients fail at flag parsing (every leaf
        // needs a nonempty shard)
        let n = RunConfig::paper("banking").unwrap().model.n_clients();
        let mut flags = HashMap::new();
        flags.insert("leaves".to_string(), (n + 1).to_string());
        assert!(cfg_from_flags(&flags).unwrap_err().to_string().contains("client count"));
        // the tree is exact-masking only
        let mut flags = HashMap::new();
        flags.insert("leaves".to_string(), "2".to_string());
        flags.insert("float".to_string(), "true".to_string());
        assert!(cfg_from_flags(&flags).unwrap_err().to_string().contains("SecureExact"));
    }

    #[test]
    fn zero_stall_knobs_rejected_at_flag_parse() {
        for knob in ["stall-timeout-ms", "stall-cap-ms"] {
            let mut flags = HashMap::new();
            flags.insert(knob.to_string(), "0".to_string());
            let err = cfg_from_flags(&flags).unwrap_err().to_string();
            assert!(err.contains(knob) && err.contains("invalid"), "{knob}: {err}");
        }
    }

    #[test]
    fn window_flag_wires_into_config_and_invalid_values_rejected() {
        let mut flags = HashMap::new();
        flags.insert("rounds-in-flight".to_string(), "4".to_string());
        assert_eq!(cfg_from_flags(&flags).unwrap().rounds_in_flight, 4);
        // default is the serial window
        assert_eq!(cfg_from_flags(&HashMap::new()).unwrap().rounds_in_flight, 1);
        // zero and runaway widths fail at flag parsing
        let mut flags = HashMap::new();
        flags.insert("rounds-in-flight".to_string(), "0".to_string());
        assert!(cfg_from_flags(&flags).unwrap_err().to_string().contains("--rounds-in-flight 0"));
        let mut flags = HashMap::new();
        flags.insert("rounds-in-flight".to_string(), "1000".to_string());
        assert!(cfg_from_flags(&flags).unwrap_err().to_string().contains("cap"));
    }

    #[test]
    fn rollback_flags_wire_into_config() {
        let mut flags = HashMap::new();
        flags.insert("chunk-words".to_string(), "1024".to_string());
        flags.insert("shamir-threshold".to_string(), "3".to_string());
        flags.insert("rollback-fsync".to_string(), "true".to_string());
        flags.insert("rollback-max-bytes".to_string(), "65536".to_string());
        let cfg = cfg_from_flags(&flags).unwrap();
        assert!(cfg.rollback_fsync);
        assert_eq!(cfg.rollback_max_bytes, Some(65536));
        // a zero bound fails at flag parsing
        let mut flags = HashMap::new();
        flags.insert("rollback-max-bytes".to_string(), "0".to_string());
        assert!(cfg_from_flags(&flags)
            .unwrap_err()
            .to_string()
            .contains("--rollback-max-bytes 0"));
        // knobs without a dropout-tolerant chunked run are inert: rejected
        let mut flags = HashMap::new();
        flags.insert("rollback-fsync".to_string(), "true".to_string());
        assert!(cfg_from_flags(&flags)
            .unwrap_err()
            .to_string()
            .contains("--shamir-threshold"));
    }

    #[test]
    fn dropout_schedule_parses() {
        let plan = parse_dropout_schedule("2@1,4@3+1,1@setup").unwrap();
        assert_eq!(
            plan.faults,
            vec![
                (2, Fault::Crash { round: 1, after_sends: 0 }),
                (4, Fault::Crash { round: 3, after_sends: 1 }),
                (1, Fault::Crash { round: SETUP_ROUND, after_sends: 0 }),
            ]
        );
        assert!(parse_dropout_schedule("").is_err());
        assert!(parse_dropout_schedule("2").is_err());
        assert!(parse_dropout_schedule("x@1").is_err());
        assert!(parse_dropout_schedule("2@y").is_err());
    }

    #[test]
    fn dropout_flags_wire_into_config() {
        let mut flags = HashMap::new();
        flags.insert("shamir-threshold".to_string(), "3".to_string());
        flags.insert("dropout-schedule".to_string(), "2@0".to_string());
        let cfg = cfg_from_flags(&flags).unwrap();
        assert_eq!(cfg.shamir_threshold, Some(3));
        assert_eq!(cfg.fault_plan.as_ref().unwrap().faults.len(), 1);
        // schedule without threshold rejected
        let mut flags = HashMap::new();
        flags.insert("dropout-schedule".to_string(), "2@0".to_string());
        assert!(cfg_from_flags(&flags).is_err());
    }
}
