//! `vfl-sa` — launcher for the VFL + secure-aggregation system.
//!
//! Subcommands (hand-rolled parser; clap is not vendored here):
//!   train    --dataset <banking|adult|taobao> [--rounds N] [--rows N]
//!            [--plain|--float] [--reference] [--seed N]
//!   bench    table1|table2|fig2|scaling [--reps N] [--quick] [--reference]
//!   info     print dataset/model configurations
//!
//! `train` and `bench` default to the PJRT backend and expect
//! `make artifacts` to have produced `artifacts/`.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use vfl::bench::{fig2, tables};
use vfl::coordinator::{run_experiment, BackendKind, RunConfig, SecurityMode};
use vfl::model::ModelConfig;
use vfl::net::{Addr, Phase};
use vfl::runtime::Engine;

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".into());
                i += 1;
            }
        } else {
            pos.push(a.clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn load_engine(dataset: &str) -> Result<Engine> {
    let cfg = ModelConfig::for_dataset(dataset).context("unknown dataset")?;
    Engine::load("artifacts", &cfg)
}

fn cmd_train(flags: &HashMap<String, String>) -> Result<()> {
    let dataset = flags.get("dataset").map(String::as_str).unwrap_or("banking");
    let mut cfg = RunConfig::paper(dataset).context("unknown dataset")?;
    if let Some(r) = flags.get("rounds") {
        cfg.train_rounds = r.parse()?;
    }
    if let Some(r) = flags.get("rows") {
        cfg.n_rows = r.parse()?;
    }
    if let Some(s) = flags.get("seed") {
        cfg.seed = s.parse()?;
    }
    if flags.contains_key("plain") {
        cfg.security = SecurityMode::Plain;
    } else if flags.contains_key("float") {
        cfg.security = SecurityMode::SecureFloat;
    }
    let reference = flags.contains_key("reference");
    if reference {
        cfg.backend = BackendKind::Reference;
    }
    cfg.test_rounds = flags.get("test-rounds").map(|v| v.parse()).transpose()?.unwrap_or(1);

    println!(
        "training {dataset}: {} rounds, {} rows, {:?}, backend {:?}",
        cfg.train_rounds, cfg.n_rows, cfg.security, cfg.backend
    );
    let engine = if reference { None } else { Some(load_engine(dataset)?) };
    let report = run_experiment(cfg, engine.as_ref())?;
    for (i, l) in report.losses.iter().enumerate() {
        println!("round {i:>4}  loss {l:.5}");
    }
    println!("test accuracy: {:.4}", report.test_accuracy);
    println!("setups (1 + rotations): {}", report.setups);
    println!(
        "active tx bytes: setup {} / train {} / test {}",
        report.net.transmission_bytes(Addr::Client(0), Phase::Setup),
        report.net.transmission_bytes(Addr::Client(0), Phase::Training),
        report.net.transmission_bytes(Addr::Client(0), Phase::Testing),
    );
    println!(
        "active CPU ms: train {:.1} (overhead {:.1}) / test {:.1} (overhead {:.1})",
        report.metrics.total_ms(1, Phase::Training),
        report.metrics.overhead_ms(1, Phase::Training),
        report.metrics.total_ms(1, Phase::Testing),
        report.metrics.overhead_ms(1, Phase::Testing),
    );
    Ok(())
}

fn cmd_bench(pos: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let which = pos.first().map(String::as_str).unwrap_or("table1");
    let reference = flags.contains_key("reference");
    let reps: usize = flags.get("reps").map(|v| v.parse()).transpose()?.unwrap_or(10);
    let quick = flags.contains_key("quick");
    match which {
        "table1" => {
            let mut rows = Vec::new();
            for ds in ["banking", "adult", "taobao"] {
                let engine = if reference { None } else { Some(load_engine(ds)?) };
                rows.push(tables::table1(ds, reps, engine.as_ref())?);
            }
            tables::print_table1(&rows);
        }
        "table2" => {
            let mut rows = Vec::new();
            for ds in ["banking", "adult", "taobao"] {
                let engine = if reference { None } else { Some(load_engine(ds)?) };
                rows.push(tables::table2(ds, engine.as_ref())?);
            }
            tables::print_table2(&rows);
        }
        "fig2" => {
            let batches = [1usize, 2, 4, 8, 16, 32, 64, 128, 256];
            let pts = fig2::sweep(&batches, quick);
            fig2::print_sweep(&pts);
        }
        "scaling" => {
            let pts = tables::scaling(&[2, 4, 8, 16, 32])?;
            println!("\nE5 — SA fabric scaling (setup + one masked 256×64 round)");
            println!("{:<10} {:>12} {:>14}", "clients", "cpu_ms", "masked_bytes");
            for (n, ms, bytes) in pts {
                println!("{n:<10} {ms:>12.2} {bytes:>14}");
            }
        }
        w => bail!("unknown bench {w} (table1|table2|fig2|scaling)"),
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("dataset configurations (§6.2 of the paper):");
    for ds in ["banking", "adult", "taobao"] {
        let c = ModelConfig::for_dataset(ds).unwrap();
        println!(
            "  {ds:<10} active-dim {:>3}  groups {:?}  hidden {:>3}  clients {}  params {}",
            c.active_dim,
            c.group_dims,
            c.hidden,
            c.n_clients(),
            c.n_params()
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    match pos.first().map(String::as_str) {
        Some("train") => cmd_train(&flags),
        Some("bench") => cmd_bench(&pos[1..], &flags),
        Some("info") => cmd_info(),
        _ => {
            eprintln!("usage: vfl-sa <train|bench|info> [flags]");
            eprintln!("  train --dataset banking [--rounds 5] [--rows 4096] [--plain|--float] [--reference]");
            eprintln!("  bench <table1|table2|fig2|scaling> [--reps 10] [--quick] [--reference]");
            Ok(())
        }
    }
}
