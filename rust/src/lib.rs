//! # vfl — Efficient Vertical Federated Learning with Secure Aggregation
//!
//! A full reproduction of *"Efficient Vertical Federated Learning with
//! Secure Aggregation"* (Qiu, Pan, et al., FLSys @ MLSys 2023).
//!
//! The crate is organised as a three-layer system:
//!
//! * **Layer 3 (this crate)** — the coordination protocol: X25519 key
//!   agreement, encrypted mini-batch selection, Bonawitz-style pairwise
//!   masking, the aggregator / active-party / passive-party state
//!   machines, a byte-metered simulated network, and the training loop.
//! * **Layer 2 (JAX, build time)** — per-party and global compute graphs
//!   lowered once to HLO text (`python/compile/`), loaded here through
//!   [`runtime`].
//! * **Layer 1 (Pallas, build time)** — the fused masked-matmul kernel
//!   the L2 graphs call.
//!
//! Everything the paper depends on is implemented from scratch in this
//! crate: the crypto stack ([`crypto`]), the secure-aggregation core
//! ([`secagg`]), the dataset substrate ([`data`]), the model substrate
//! ([`model`]), the simulated network ([`net`]) and the homomorphic
//! encryption baselines (Paillier and BFV) used by the Figure-2
//! ablation.

pub mod bench;
pub mod coordinator;
pub mod crypto;
pub mod data;
pub mod model;
pub mod net;
pub mod runtime;
pub mod secagg;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
