//! # vfl — Efficient Vertical Federated Learning with Secure Aggregation
//!
//! A full reproduction of *"Efficient Vertical Federated Learning with
//! Secure Aggregation"* (Qiu, Pan, et al., FLSys @ MLSys 2023).
//!
//! The crate is organised as a three-layer system:
//!
//! * **Layer 3 (this crate)** — the coordination protocol: X25519 key
//!   agreement, encrypted mini-batch selection, Bonawitz-style pairwise
//!   masking, and the §4 state machines, all behind an event-driven
//!   [`Party`](coordinator::Party) / [`Transport`](net::Transport)
//!   split (see below).
//! * **Layer 2 (JAX, build time)** — per-party and global compute graphs
//!   lowered once to HLO text (`python/compile/`), loaded here through
//!   [`runtime`] (requires the `pjrt` cargo feature; without it the
//!   pure-Rust reference backend runs everything).
//! * **Layer 1 (Pallas, build time)** — the fused masked-matmul kernel
//!   the L2 graphs call.
//!
//! ## Architecture: parties × transports
//!
//! Protocol logic lives in three event-driven state machines —
//! [`Aggregator`](coordinator::parties::Aggregator),
//! [`ActiveParty`](coordinator::parties::ActiveParty),
//! [`PassiveParty`](coordinator::parties::PassiveParty) — that
//! implement the [`Party`](coordinator::Party) trait: react to a
//! round-boundary hook or an incoming message by pushing outgoing
//! messages into an [`Outbox`](coordinator::Outbox). How those
//! messages move is a [`Transport`](net::Transport) decision:
//!
//! * [`SimTransport`](net::SimTransport) — deterministic
//!   single-threaded simulation over the byte-metered
//!   [`Network`](net::Network); its counters are Table 2 and its CPU
//!   attribution is Table 1 (the paper measures the same way, via
//!   Flower's VCE).
//! * [`ThreadedTransport`](net::ThreadedTransport) — one OS thread per
//!   party. Bit-identical reports to the simulator (asserted in
//!   `tests/transport_equivalence.rs`).
//! * `vfl-sa serve` / `vfl-sa join` — the same machines over TCP
//!   sockets, one process per party, one blocking thread per
//!   connection ([`net::tcp`]).
//! * `EvloopTransport` (`--evloop`; [`net::evloop`], unix) — the same
//!   sockets and frames, multiplexed on a **single readiness-driven
//!   event-loop thread**: nonblocking reads reassemble partial frames
//!   per connection, writes go through bounded per-connection queues
//!   (never a blocking `write_all` on the loop), so one aggregator
//!   thread scales to 10k+ concurrent clients with flat per-client
//!   memory — `vfl-sa swarm --clients 10240` demonstrates it against
//!   real sockets and `tests/evloop.rs` asserts the scaling counters.
//!
//! All four run the identical party machines and produce bit-identical
//! reports; the equivalence suites pin `sim ≡ threaded ≡ tcp ≡
//! evloop`.
//!
//! The [`Experiment`](coordinator::Experiment) driver builds the party
//! set, lays out a static round schedule (setup → training with §5.1
//! key rotation → testing), pumps the configured transport, and folds
//! the emitted notes into a [`RunReport`](coordinator::RunReport).
//! [`run_experiment`](coordinator::run_experiment) does all of that in
//! one call:
//!
//! ```no_run
//! use vfl::coordinator::{run_experiment, RunConfig};
//! let report = run_experiment(RunConfig::test("banking").unwrap(), None).unwrap();
//! println!("losses: {:?}", report.losses);
//! ```
//!
//! ## Round lifecycle: per-round contexts and the pipelined window
//!
//! There is no "current round" anywhere in the stack. Every party
//! keeps a bounded ring of **per-round protocol contexts** keyed by
//! round number — fan-in buffers, chunk assemblers, batch caches,
//! pending gradient sums — and every protocol message routes to its
//! context by the `round` tag it already carries. The driver side is
//! the [`RoundWindow`](coordinator::RoundWindow) scheduler
//! (`--rounds-in-flight W`): up to `W` rounds run simultaneously,
//! started strictly in schedule order, with three barriers that make
//! any width bit-identical to the serial `W = 1` run — setup/rotation
//! rounds run alone (no round straddles a key epoch), a phase boundary
//! drains the window (per-phase Table-2 counters stay exact), and the
//! first dropout declaration drains the window to 1 for the rest of
//! the run (`Note::WindowDrain`), so Bonawitz recovery composes with
//! pipelining without a single new case. Within those barriers the
//! overlap is real: testing rounds are mutually independent, so
//! passive parties forward round *r + 1* while the aggregator still
//! folds round *r*; training rounds chain through the active party's
//! SGD step — its context for round *r + 1* defers opening until round
//! *r*'s update lands, which is exactly why wider windows cannot
//! change a value, only shrink idle gaps.
//! [`PipelineStats`](coordinator::PipelineStats) (overlapped starts,
//! peak rounds in flight, driver idle gap) measure the win;
//! `tests/round_pipeline.rs` asserts the W ∈ {1, 2, 4} sweep
//! bit-identical on every transport, sockets included.
//!
//! ## Streaming shard-parallel aggregation (`--chunk-words` / `--shards` / `--agg-workers`)
//!
//! The masked-tensor path is a *chunked streaming pipeline* end to
//! end. The pairwise-mask PRG is seekable
//! ([`crypto::prg::MaskStream`]), so a sender masks and ships a tensor
//! window by window (`Msg::MaskedChunk { tag, shard, offset, .. }`)
//! without ever materializing a full-tensor mask; the aggregator's
//! routing layer validates each sender's stream and folds every
//! chunk into its shard's accumulator on arrival
//! ([`ChunkAssembler`](coordinator::streaming::ChunkAssembler)). With
//! `--agg-workers` > 1 the folding fans out across per-shard
//! accumulator *workers* (worker `w` owns shards `k % workers == w`),
//! fed over bounded channels; `take_sum` is the deterministic merge
//! that stitches every worker's disjoint shard ranges back into one
//! vector. The aggregator→active `GradientSum` downlink streams too:
//! `Msg::GradientChunk` mirrors `MaskedChunk` over the same
//! [`ShardLayout`](coordinator::streaming::ShardLayout). Because ℤ₂⁶⁴
//! wrap-addition is order-independent and shard ranges are disjoint, a
//! chunked run with *any* worker count is **bit-identical** to the
//! monolithic one — predictions, parameters, losses, and Table-2 sums
//! modulo the documented headers: 22 bytes per uplink chunk (vs 11
//! monolithic) and 19 per downlink chunk (vs the 9-byte
//! `GradientSum`). `tests/chunk_equivalence.rs` asserts all of it on
//! the simulator, the threaded transport, and TCP.
//!
//! Memory model: the monolithic fan-in peaks at O(n·d) (one full
//! vector per sender); the streaming pipeline holds exactly the shard
//! accumulators — O(d) — in the base protocol *and* in
//! dropout-tolerant runs. Exact purge of a declared-dropped sender is
//! preserved by a per-round **rollback log**: every committed chunk is
//! appended to a spill file, and purging a sender replays the log,
//! wrap-subtracting its records from the accumulators — so the
//! dropout-path RAM peak is below the monolithic baseline too. The
//! mechanics are spelled out in [`coordinator::streaming`].
//!
//! ## Topology: the hierarchical fan-in tree (`--leaves L`)
//!
//! Even streamed, a single aggregator still *receives* all n·d masked
//! words per round. `--leaves L` splits that fan-in across a static
//! two-level tree ([`coordinator::topology`]): a
//! [`ShardMap`](coordinator::ShardMap) partitions the clients into L
//! contiguous, disjoint shards — derived deterministically from
//! `(n_clients, L)` alone, so every process computes the identical
//! partition — and each shard's
//! [`LeafAggregator`](coordinator::LeafAggregator) folds its members'
//! masked tensors/chunks into one partial ℤ₂⁶⁴ sum (the same
//! `ChunkAssembler`/[`z64`] kernels and worker pool the root uses),
//! forwarded as `Msg::PartialSum { round, tag, shard_range, words }`.
//! The root stitches the L disjoint partials, so per-node fan-in drops
//! from O(n·d) to max(O((n/L)·d), O(L·d)) — `benches/tree_fanin.rs`
//! measures it (`BENCH_tree.json`).
//!
//! Mask safety needs no new mechanism: pairwise masks telescope to
//! zero only in the *full* cross-client sum, so a leaf's partial stays
//! masked by every cross-shard pairwise term
//! (`tests/security_properties.rs::leaf_partial_sums_stay_masked`).
//! And because ℤ₂⁶⁴ wrap-addition commutes, the tree is
//! **bit-invisible**: any L produces the flat run's exact reports and
//! Table-2 counters (`tests/tree_topology.rs` pins L ∈ {1, 2, 4} ≡
//! flat on every transport). Tree mode requires `SecureExact` — float
//! addition would change with association order. Dropout recovery
//! routes through the owning leaf unchanged (a leaf purges the
//! declared sender and re-emits corrected partials; a crashed *leaf*
//! is exactly a whole-shard dropout), and the root's `WindowDrain`
//! propagates tree-wide.
//!
//! In-process transports (sim/threaded/evloop, and `serve --leaves`)
//! host the tree inside the aggregator process
//! ([`TreeAggregator`](coordinator::TreeAggregator) wraps the root),
//! so the client-visible wire traffic is unchanged. The distributed
//! deployment runs real leaf processes: `vfl-sa leaf --leaves L
//! --leaf-index k` ([`net::tcp::leaf`]) owns shard k's client sockets
//! and relays upstream to a plain `vfl-sa serve` root — there the
//! root's receive counters *show* the O(L·d) fan-in reduction, which
//! is the measured win, while reports stay bit-identical.
//!
//! ## Dropout tolerance (Bonawitz'17, §5.1)
//!
//! With [`RunConfig::shamir_threshold`](coordinator::RunConfig) set,
//! the setup phase additionally Shamir-shares every client's mask seed
//! t-of-n (bundles sealed under the pairwise AEAD channels, relayed by
//! the aggregator), and every transport detects quiescence — an empty
//! FIFO in the simulator, a stall timeout on threads and TCP — and
//! probes the aggregator ([`Party::on_stall`](coordinator::Party)).
//! The aggregator declares the silent clients dropped, collects
//! surrendered shares from ≥ t survivors, reconstructs the dropped
//! seeds, and adds the missing total masks so every fan-in still
//! cancels exactly. Below t survivors the run aborts with a typed
//! [`DropoutError`](secagg::DropoutError) instead of a wrong answer.
//! The deterministic fault-injection harness ([`net::faulty`]) and
//! `tests/dropout_recovery.rs` prove recovery bit-exact against the
//! zero-contribution twin run on every transport.
//!
//! ## SIMD dispatch and the zero-copy chunk path
//!
//! The per-word compute cost of a round is ChaCha20 mask expansion
//! plus ℤ₂⁶⁴ wrapping folds, and both are vectorized behind one
//! runtime probe ([`crypto::simd::active_isa`]): a 4-block-parallel
//! ChaCha20 core (AVX2 / NEON / portable lanes) in
//! [`crypto::chacha20`] and lane-chunked accumulator folds in [`z64`].
//! The scalar single-block core remains the reference semantics and
//! the `VFL_SIMD=off` escape hatch; every vector kernel is asserted
//! bit-identical to it (see the [`crypto`] module docs for the full
//! dispatch contract — a mask expanded on an AVX2 server must cancel
//! against one expanded on a NEON client).
//!
//! Between the mask PRG and the socket, the chunk path is zero-copy:
//! masked words are fixed-point encoded and folded directly into the
//! outgoing wire buffer. The **frame-encode rule** is that a
//! pre-encoded message must be byte-identical to the `Msg` it
//! replaces: chunk senders build
//! `coordinator::messages::begin_masked_chunk` /
//! `begin_gradient_chunk` headers in an exact-capacity
//! [`net::wire::Writer`], append payload words with `u64s_raw`, and
//! ship the buffer as an `OutMsg::Encoded` — transports meter and
//! frame those bytes exactly as if `Msg::encode` had produced them
//! (asserted by the builder bit-identity tests and the equivalence
//! suites, whose Table-2 byte counters would shift on any divergence).
//!
//! ## Threading model: the multi-core hot paths
//!
//! Every thread pool in the crate is hand-rolled std-only machinery
//! (no rayon, no tokio), each bounded, each deterministic, and each
//! **bit-invisible**: any worker/thread count produces the identical
//! report, so parallelism is purely a wall-clock knob. Four families:
//!
//! * **Aggregator accumulator workers** (`--agg-workers N`) — the
//!   chunked pipeline's per-shard fold fans out across `N` detached
//!   workers owning disjoint shard sets (`k % N`), fed over bounded
//!   channels; `take_sum` stitches the disjoint ranges back
//!   deterministically ([`coordinator::streaming`]).
//! * **Mask-expansion pool** (`--expand-workers N`) — client masking
//!   and the aggregator's dropout total-mask correction partition each
//!   tensor window into disjoint sub-windows
//!   ([`crypto::prg::partition_window`]), expand each on a pool worker
//!   via the seekable PRG, and stitch in offset order
//!   ([`crypto::prg::ExpandPool`]). The window-partition property of
//!   the wrap-added keystream makes any partition bit-identical to the
//!   serial expansion.
//! * **Event-loop shards** (`--evloop-threads K`) — the evloop
//!   transport's connections are token-sharded at accept time across
//!   `K` poller threads, each exclusively owning its connections' read
//!   and write buffers (no lock on any byte path); frames funnel to
//!   the single `RoundWindow` driver over an order-preserving channel
//!   ([`net::evloop::shard`]). `K = 1` *is* the classic single loop.
//! * **Transport/driver threads** — `ThreadedTransport` runs one
//!   thread per party; the swarm harness multiplexes its simulated
//!   clients over a few `client_threads` pollers and (with
//!   `--evloop-threads`) shards its server the same way the protocol
//!   transport does.
//!
//! The CI matrix re-runs the equivalence suites under
//! `VFL_AGG_WORKERS`, `VFL_EXPAND_WORKERS`, `VFL_ROUNDS_IN_FLIGHT`,
//! `VFL_TRANSPORT=evloop`, `VFL_EVLOOP_THREADS`, and `VFL_LEAVES`, so
//! every pool's (and the fan-in tree's) bit-invisibility claim is
//! continuously enforced, not just documented.
//!
//! ## Enforced invariants (tools/vflint)
//!
//! The safety properties above are machine-checked, not just
//! documented: `tools/vflint/vflint.py` is a zero-dependency static
//! analyzer that runs as the first step of every CI job (and in
//! toolchain-free authoring containers) and fails the build on any
//! unallowlisted violation. Check ↔ invariant:
//!
//! * **`unsafe-audit`** — every `unsafe` site carries a `// SAFETY:`
//!   justification and an entry in the reviewed
//!   `tools/vflint/unsafe_inventory.txt`; unsafe code cannot appear
//!   without review.
//! * **`no-blocking-io`** — no `write_all`/`read_exact`/
//!   `set_nonblocking(false)` in [`net::evloop`]: poller threads never
//!   block on a socket (the invariant behind the C10K claim and the
//!   old TCP write-deadlock fix).
//! * **`bounded-channels`** — hot-path channels are `sync_channel`
//!   (bounded, backpressure); the deliberately-unbounded `LoopEvt`
//!   funnels are allowlisted with their justification.
//! * **`env-registry`** — every `VFL_*` knob is declared in
//!   `tools/vflint/env_registry.txt`, and every declared CI axis is
//!   actually exercised by `.github/workflows/ci.yml` — the
//!   bit-invisibility matrix cannot silently lose a leg.
//! * **`frame-encode-rule`** — the tag constants and the 22/19-byte
//!   chunk and 14-byte partial-sum headers are cross-checked between
//!   the `begin_masked_chunk`/`begin_gradient_chunk`/
//!   `begin_partial_sum` builders, `Msg::encode_into`/`encoded_len`,
//!   `decode`, and the Table-2 accounting constants, so the zero-copy
//!   path cannot silently diverge from `Msg::encode()`.
//! * **`panic-discipline`** — no `unwrap()`/`expect(` in non-test
//!   `net/`, `coordinator/`, `secagg/` code except allowlisted sites
//!   with a stated reason; protocol failures surface as typed errors.
//! * **`cfg-coverage`** — every `#[target_feature]` intrinsic names
//!   its scalar reference (`// vflint: scalar-ref = …`) and both are
//!   exercised by a bit-identity test in the same file.
//!
//! The compile-time half lives in `rust/Cargo.toml` `[lints]`
//! (`unsafe_op_in_unsafe_fn = "deny"`, `undocumented_unsafe_blocks`)
//! plus gated CI jobs for Miri and the thread/address sanitizers.
//!
//! Everything the paper depends on is implemented from scratch in this
//! crate: the crypto stack ([`crypto`]), the secure-aggregation core
//! ([`secagg`]), the dataset substrate ([`data`]), the model substrate
//! ([`model`]), the transports ([`net`]) and the homomorphic
//! encryption baselines (Paillier and BFV) used by the Figure-2
//! ablation.

pub mod bench;
pub mod coordinator;
pub mod crypto;
pub mod data;
pub mod model;
pub mod net;
pub mod runtime;
pub mod secagg;
pub mod z64;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
