//! ℤ₂⁶⁴ vector arithmetic — the accumulator-fold hot path.
//!
//! Every fan-in in the system is element-wise wrapping add/sub over
//! `u64` slices: masked-chunk shard accumulation
//! ([`crate::coordinator::streaming`]), the aggregator's wrap-sum and
//! dropout mask correction, and the mask PRG's window folds
//! ([`crate::crypto::prg`]). These helpers chunk those loops into
//! 4-wide lanes the compiler keeps in vector registers on any ISA,
//! plus an explicit AVX2 leg (4 × u64 per 256-bit op) behind the
//! shared [`crate::crypto::simd`] probe for when the autovectorizer
//! refuses. NEON gets no explicit leg: the portable 4-chunk form
//! compiles to paired `add.2d` already.
//!
//! Bit-identity: wrapping add/sub is element-wise and associative, so
//! lane width and dispatch *cannot* change results — asserted anyway
//! by the property tests below, and re-proven at protocol level by the
//! `VFL_SIMD=off` CI axis.

/// `dst[i] = dst[i] ⊞ src[i]` (wrapping add in ℤ₂⁶⁴).
pub fn wrap_add(dst: &mut [u64], src: &[u64]) {
    assert_eq!(dst.len(), src.len(), "z64 fold length mismatch");
    #[cfg(target_arch = "x86_64")]
    if crate::crypto::simd::active_isa() == crate::crypto::simd::SimdIsa::Avx2 {
        // SAFETY: AVX2 verified at runtime by the probe.
        unsafe { avx2::wrap_add(dst, src) };
        return;
    }
    wrap_add_portable(dst, src);
}

/// `dst[i] = dst[i] ⊟ src[i]` (wrapping sub in ℤ₂⁶⁴) — the negated
/// mask direction (peer < me, Eq. 3) and dropout mask correction.
pub fn wrap_sub(dst: &mut [u64], src: &[u64]) {
    assert_eq!(dst.len(), src.len(), "z64 fold length mismatch");
    #[cfg(target_arch = "x86_64")]
    if crate::crypto::simd::active_isa() == crate::crypto::simd::SimdIsa::Avx2 {
        // SAFETY: AVX2 verified at runtime by the probe.
        unsafe { avx2::wrap_sub(dst, src) };
        return;
    }
    wrap_sub_portable(dst, src);
}

/// `dst[i] = ⊟dst[i]` in place (additive inverse in ℤ₂⁶⁴). Replaces
/// the old `into_iter().map(wrapping_neg).collect()` pattern that
/// allocated a second full tensor on the client hot path.
pub fn wrap_neg(dst: &mut [u64]) {
    // 0 - x == wrapping_neg(x); the 4-chunk form autovectorizes
    let mut chunks = dst.chunks_exact_mut(4);
    for c in &mut chunks {
        c[0] = c[0].wrapping_neg();
        c[1] = c[1].wrapping_neg();
        c[2] = c[2].wrapping_neg();
        c[3] = c[3].wrapping_neg();
    }
    for v in chunks.into_remainder() {
        *v = v.wrapping_neg();
    }
}

fn wrap_add_portable(dst: &mut [u64], src: &[u64]) {
    let mut d = dst.chunks_exact_mut(4);
    let mut s = src.chunks_exact(4);
    for (dc, sc) in (&mut d).zip(&mut s) {
        dc[0] = dc[0].wrapping_add(sc[0]);
        dc[1] = dc[1].wrapping_add(sc[1]);
        dc[2] = dc[2].wrapping_add(sc[2]);
        dc[3] = dc[3].wrapping_add(sc[3]);
    }
    for (dv, sv) in d.into_remainder().iter_mut().zip(s.remainder()) {
        *dv = dv.wrapping_add(*sv);
    }
}

fn wrap_sub_portable(dst: &mut [u64], src: &[u64]) {
    let mut d = dst.chunks_exact_mut(4);
    let mut s = src.chunks_exact(4);
    for (dc, sc) in (&mut d).zip(&mut s) {
        dc[0] = dc[0].wrapping_sub(sc[0]);
        dc[1] = dc[1].wrapping_sub(sc[1]);
        dc[2] = dc[2].wrapping_sub(sc[2]);
        dc[3] = dc[3].wrapping_sub(sc[3]);
    }
    for (dv, sv) in d.into_remainder().iter_mut().zip(s.remainder()) {
        *dv = dv.wrapping_sub(*sv);
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    /// # Safety
    /// Caller must have verified AVX2 support at runtime. `dst` and
    /// `src` must have equal length (checked by the public wrappers).
    // vflint: scalar-ref = wrap_add_portable
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn wrap_add(dst: &mut [u64], src: &[u64]) {
        let n4 = dst.len() & !3;
        let d = dst.as_mut_ptr();
        let s = src.as_ptr();
        let mut i = 0;
        // SAFETY: caller guarantees AVX2; the unaligned loads/stores
        // cover words `[0, n4)` of two live, equal-length slices.
        unsafe {
            while i < n4 {
                let dv = _mm256_loadu_si256(d.add(i) as *const __m256i);
                let sv = _mm256_loadu_si256(s.add(i) as *const __m256i);
                _mm256_storeu_si256(d.add(i) as *mut __m256i, _mm256_add_epi64(dv, sv));
                i += 4;
            }
        }
        for j in n4..dst.len() {
            dst[j] = dst[j].wrapping_add(src[j]);
        }
    }

    /// # Safety
    /// Same contract as [`wrap_add`].
    // vflint: scalar-ref = wrap_sub_portable
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn wrap_sub(dst: &mut [u64], src: &[u64]) {
        let n4 = dst.len() & !3;
        let d = dst.as_mut_ptr();
        let s = src.as_ptr();
        let mut i = 0;
        // SAFETY: caller guarantees AVX2; the unaligned loads/stores
        // cover words `[0, n4)` of two live, equal-length slices.
        unsafe {
            while i < n4 {
                let dv = _mm256_loadu_si256(d.add(i) as *const __m256i);
                let sv = _mm256_loadu_si256(s.add(i) as *const __m256i);
                _mm256_storeu_si256(d.add(i) as *mut __m256i, _mm256_sub_epi64(dv, sv));
                i += 4;
            }
        }
        for j in n4..dst.len() {
            dst[j] = dst[j].wrapping_sub(src[j]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pattern(len: usize, salt: u64) -> Vec<u64> {
        // values chosen to force wraparound in both directions
        (0..len as u64)
            .map(|i| (u64::MAX - i.wrapping_mul(0x9e3779b97f4a7c15)) ^ salt)
            .collect()
    }

    #[test]
    fn add_and_sub_match_reference_for_all_tail_lengths() {
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 31, 32, 33, 100, 257] {
            let src = pattern(len, 7);
            let mut add = pattern(len, 99);
            let mut sub = add.clone();
            let want_add: Vec<u64> =
                add.iter().zip(&src).map(|(a, b)| a.wrapping_add(*b)).collect();
            let want_sub: Vec<u64> =
                sub.iter().zip(&src).map(|(a, b)| a.wrapping_sub(*b)).collect();
            wrap_add(&mut add, &src);
            wrap_sub(&mut sub, &src);
            assert_eq!(add, want_add, "add len={len}");
            assert_eq!(sub, want_sub, "sub len={len}");
        }
    }

    #[test]
    fn neg_is_additive_inverse() {
        for len in [0usize, 1, 3, 4, 5, 63, 64, 65] {
            let orig = pattern(len, 3);
            let mut neg = orig.clone();
            wrap_neg(&mut neg);
            let mut sum = orig;
            wrap_add(&mut sum, &neg);
            assert!(sum.iter().all(|&v| v == 0), "len={len}");
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_legs_match_portable() {
        // direct gate on the intrinsic legs whenever the CPU has AVX2,
        // independent of what VFL_SIMD pinned for the dispatch
        if !std::arch::is_x86_feature_detected!("avx2") {
            eprintln!("skipping avx2_legs_match_portable: no AVX2 on this host");
            return;
        }
        for len in [0usize, 1, 4, 5, 100, 257] {
            let src = pattern(len, 21);
            let mut a = pattern(len, 8);
            let mut b = a.clone();
            wrap_add_portable(&mut a, &src);
            // SAFETY: AVX2 presence checked above.
            unsafe { avx2::wrap_add(&mut b, &src) };
            assert_eq!(a, b, "add len={len}");
            wrap_sub_portable(&mut a, &src);
            // SAFETY: AVX2 presence checked above.
            unsafe { avx2::wrap_sub(&mut b, &src) };
            assert_eq!(a, b, "sub len={len}");
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn length_mismatch_panics() {
        let mut d = [0u64; 3];
        wrap_add(&mut d, &[1u64; 4]);
    }
}
