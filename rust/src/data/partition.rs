//! Vertical feature partitioning (§6.2 of the paper).
//!
//! Features are split between one *active* party (which also holds the
//! labels) and several *passive-party groups*. All parties in a group
//! share a feature set but hold **disjoint sample subsets** — exactly
//! the paper's "multiple passive parties can hold different samples
//! with the same feature set".

use std::collections::HashMap;

use super::encode::encode_subset;
use super::synth::Dataset;

/// One passive-party group: a feature set replicated across `n_parties`
/// parties that each hold a disjoint slice of the samples.
#[derive(Clone, Debug)]
pub struct GroupSpec {
    pub features: Vec<String>,
    pub n_parties: usize,
}

/// A full vertical partition specification.
#[derive(Clone, Debug)]
pub struct PartitionSpec {
    pub active_features: Vec<String>,
    pub groups: Vec<GroupSpec>,
}

impl PartitionSpec {
    pub fn total_passive_parties(&self) -> usize {
        self.groups.iter().map(|g| g.n_parties).sum()
    }
}

/// The active party's materialized view.
pub struct ActiveData {
    /// Sample IDs in dataset order.
    pub ids: Vec<u64>,
    /// Row-major (n × d_active) encoded features.
    pub x: Vec<Vec<f32>>,
    pub labels: Vec<f32>,
    pub dim: usize,
}

/// One passive party's materialized view.
pub struct PassiveData {
    /// Global passive-party index (0-based across all groups).
    pub party_id: usize,
    /// Which group this party belongs to.
    pub group: usize,
    /// Encoded width of this party's features.
    pub dim: usize,
    /// id → encoded feature vector, only for samples this party holds.
    pub rows: HashMap<u64, Vec<f32>>,
}

/// The fully partitioned dataset.
pub struct VerticalDataset {
    pub active: ActiveData,
    pub passives: Vec<PassiveData>,
    pub spec: PartitionSpec,
}

/// Materialize a vertical split of `data` according to `spec`.
/// Within a group, sample row `i` goes to party `i % n_parties`.
pub fn partition(data: &Dataset, spec: &PartitionSpec) -> VerticalDataset {
    let schema = &data.schema;
    let active_names: Vec<&str> = spec.active_features.iter().map(|s| s.as_str()).collect();
    let active_dim = schema.encoded_width_of(&active_names);
    assert!(active_dim > 0, "active party has no features");

    let active = ActiveData {
        ids: data.ids.clone(),
        x: data.rows.iter().map(|r| encode_subset(schema, r, &active_names)).collect(),
        labels: data.labels.clone(),
        dim: active_dim,
    };

    let mut passives = Vec::new();
    let mut party_id = 0usize;
    for (g, group) in spec.groups.iter().enumerate() {
        let names: Vec<&str> = group.features.iter().map(|s| s.as_str()).collect();
        let dim = schema.encoded_width_of(&names);
        assert!(dim > 0, "group {g} has no encoded features");
        let mut maps: Vec<HashMap<u64, Vec<f32>>> =
            (0..group.n_parties).map(|_| HashMap::new()).collect();
        for (i, (row, &id)) in data.rows.iter().zip(&data.ids).enumerate() {
            let owner = i % group.n_parties;
            maps[owner].insert(id, encode_subset(schema, row, &names));
        }
        for map in maps {
            passives.push(PassiveData { party_id, group: g, dim, rows: map });
            party_id += 1;
        }
    }
    VerticalDataset { active, passives, spec: spec.clone() }
}

impl VerticalDataset {
    /// Total number of clients (active + passives).
    pub fn n_clients(&self) -> usize {
        1 + self.passives.len()
    }

    /// The summed per-group dims (what the aggregated embedding covers).
    pub fn group_dims(&self) -> Vec<usize> {
        self.spec
            .groups
            .iter()
            .enumerate()
            .map(|(g, _)| self.passives.iter().find(|p| p.group == g).map(|p| p.dim).unwrap_or(0))
            .collect()
    }

    /// Which passive party (global index) holds sample `id` for group `g`.
    pub fn holder_of(&self, g: usize, id: u64) -> Option<usize> {
        self.passives
            .iter()
            .filter(|p| p.group == g)
            .find(|p| p.rows.contains_key(&id))
            .map(|p| p.party_id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::{Feature, Schema};
    use crate::data::synth::generate;

    fn setup() -> (Dataset, PartitionSpec) {
        let schema = Schema::new(
            "t",
            vec![
                Feature::cat("a", 3),
                Feature::num("b", 0.0, 1.0),
                Feature::cat("c", 4),
                Feature::num("d", -1.0, 1.0),
            ],
        );
        let data = generate(&schema, 101, 9);
        let spec = PartitionSpec {
            active_features: vec!["a".into(), "b".into()],
            groups: vec![
                GroupSpec { features: vec!["c".into()], n_parties: 2 },
                GroupSpec { features: vec!["d".into()], n_parties: 2 },
            ],
        };
        (data, spec)
    }

    #[test]
    fn dims_and_counts() {
        let (data, spec) = setup();
        let v = partition(&data, &spec);
        assert_eq!(v.active.dim, 4); // 3 + 1
        assert_eq!(v.passives.len(), 4);
        assert_eq!(v.passives[0].dim, 4);
        assert_eq!(v.passives[2].dim, 1);
        assert_eq!(v.n_clients(), 5);
        assert_eq!(v.group_dims(), vec![4, 1]);
    }

    #[test]
    fn group_samples_disjoint_and_complete() {
        let (data, spec) = setup();
        let v = partition(&data, &spec);
        for g in 0..2 {
            let parties: Vec<&PassiveData> = v.passives.iter().filter(|p| p.group == g).collect();
            let total: usize = parties.iter().map(|p| p.rows.len()).sum();
            assert_eq!(total, data.len(), "group {g} must cover all samples");
            // disjoint
            for id in &data.ids {
                let holders = parties.iter().filter(|p| p.rows.contains_key(id)).count();
                assert_eq!(holders, 1, "sample {id} must have exactly one holder in group {g}");
            }
        }
    }

    #[test]
    fn holder_lookup() {
        let (data, spec) = setup();
        let v = partition(&data, &spec);
        let id = data.ids[3];
        let h = v.holder_of(0, id).unwrap();
        assert!(v.passives[h].rows.contains_key(&id));
        assert_eq!(v.holder_of(0, 0xdead_beef), None);
    }

    #[test]
    fn encoded_features_match_full_row() {
        let (data, spec) = setup();
        let v = partition(&data, &spec);
        // active view row 0 equals the subset encoding of raw row 0
        let want = encode_subset(&data.schema, &data.rows[0], &["a", "b"]);
        assert_eq!(v.active.x[0], want);
    }
}
