//! The paper's three evaluation datasets (§6.1–6.2), as schema-faithful
//! synthetic generators with the exact published feature partition.
//!
//! Encoded dimensions reproduce the paper's Linear-layer shapes:
//!
//! | Dataset | active | group 1 (parties 1,2) | group 2 (parties 3,4) | total |
//! |---------|--------|----------------------|----------------------|-------|
//! | Banking | 57     | 3                    | 20                   | 80    |
//! | Adult   | 27     | 63                   | 16                   | 106   |
//! | Taobao  | 197    | 11                   | 6                    | 214   |
//!
//! Categorical cardinalities follow the real datasets where documented
//! (e.g. 12 banking job classes, 42 adult native countries); Taobao's
//! huge `cate_id`/`brand` vocabularies are capped to match the paper's
//! Linear(197, 128) active module (see DESIGN.md §Substitutions).

use super::partition::{GroupSpec, PartitionSpec};
use super::schema::{Feature, Schema};

/// Paper row counts (§6.1).
pub const BANKING_ROWS: usize = 45_211;
pub const ADULT_ROWS: usize = 48_842;
pub const TAOBAO_ROWS: usize = 26_000_000;

/// Hidden width per dataset (§6.2 model architecture).
pub fn hidden_dim(name: &str) -> usize {
    match name {
        "taobao" => 128,
        _ => 64,
    }
}

/// Banking (Moro et al. 2011): 18 columns, direct-marketing outcome.
pub fn banking_schema() -> Schema {
    Schema::new(
        "banking",
        vec![
            // active party features (57 encoded)
            Feature::cat("housing", 2),
            Feature::cat("loan", 2),
            Feature::cat("contact", 3),
            Feature::cat("day", 31),
            Feature::cat("month", 12),
            Feature::num("campaign", 1.0, 63.0),
            Feature::num("pdays", -1.0, 871.0),
            Feature::num("previous", 0.0, 275.0),
            Feature::cat("poutcome", 4),
            // passive group 1 (3 encoded)
            Feature::cat("default", 2),
            Feature::num("balance", -8019.0, 102127.0),
            // passive group 2 (20 encoded)
            Feature::num("age", 18.0, 95.0),
            Feature::cat("job", 12),
            Feature::cat("marital", 3),
            Feature::cat("education", 4),
        ],
    )
}

pub fn banking_partition() -> PartitionSpec {
    PartitionSpec {
        active_features: vec![
            "housing".into(),
            "loan".into(),
            "contact".into(),
            "day".into(),
            "month".into(),
            "campaign".into(),
            "pdays".into(),
            "previous".into(),
            "poutcome".into(),
        ],
        groups: vec![
            GroupSpec { features: vec!["default".into(), "balance".into()], n_parties: 2 },
            GroupSpec {
                features: vec!["age".into(), "job".into(), "marital".into(), "education".into()],
                n_parties: 2,
            },
        ],
    }
}

/// Adult income (Kohavi 1996): census columns, >50K prediction.
pub fn adult_schema() -> Schema {
    Schema::new(
        "adult",
        vec![
            // active (27 encoded)
            Feature::cat("workclass", 9),
            Feature::cat("occupation", 15),
            Feature::num("capital-gain", 0.0, 99999.0),
            Feature::num("capital-loss", 0.0, 4356.0),
            Feature::num("hours-per-week", 1.0, 99.0),
            // passive group 1 (63 encoded)
            Feature::cat("race", 5),
            Feature::cat("marital-status", 7),
            Feature::cat("relationship", 6),
            Feature::num("age", 17.0, 90.0),
            Feature::cat("gender", 2),
            Feature::cat("native-country", 42),
            // passive group 2 (16 encoded)
            Feature::cat("education", 16),
        ],
    )
}

pub fn adult_partition() -> PartitionSpec {
    PartitionSpec {
        active_features: vec![
            "workclass".into(),
            "occupation".into(),
            "capital-gain".into(),
            "capital-loss".into(),
            "hours-per-week".into(),
        ],
        groups: vec![
            GroupSpec {
                features: vec![
                    "race".into(),
                    "marital-status".into(),
                    "relationship".into(),
                    "age".into(),
                    "gender".into(),
                    "native-country".into(),
                ],
                n_parties: 2,
            },
            GroupSpec { features: vec!["education".into()], n_parties: 2 },
        ],
    }
}

/// Taobao ad display/click (Li et al. 2021): CTR prediction.
pub fn taobao_schema() -> Schema {
    Schema::new(
        "taobao",
        vec![
            // active (197 encoded)
            Feature::cat("pid", 2),
            Feature::cat("cms_group_id", 13),
            Feature::cat("final_gender_code", 2),
            Feature::cat("age_level", 7),
            Feature::cat("pvalue_level", 4),
            Feature::cat("shopping_level", 3),
            Feature::cat("occupation", 2),
            Feature::cat("cate_id", 99),
            Feature::cat("brand", 59),
            Feature::cat("new_user_class_level", 5),
            Feature::num("price", 0.0, 10000.0),
            // passive group 1 (11 encoded): the user-profile mirror columns
            Feature::cat("p_final_gender_code", 2),
            Feature::cat("p_age_level", 7),
            Feature::cat("p_occupation", 2),
            // passive group 2 (6 encoded)
            Feature::cat("p_pvalue_level", 3),
            Feature::cat("p_shopping_level", 3),
        ],
    )
}

pub fn taobao_partition() -> PartitionSpec {
    PartitionSpec {
        active_features: vec![
            "pid".into(),
            "cms_group_id".into(),
            "final_gender_code".into(),
            "age_level".into(),
            "pvalue_level".into(),
            "shopping_level".into(),
            "occupation".into(),
            "cate_id".into(),
            "brand".into(),
            "new_user_class_level".into(),
            "price".into(),
        ],
        groups: vec![
            GroupSpec {
                features: vec![
                    "p_final_gender_code".into(),
                    "p_age_level".into(),
                    "p_occupation".into(),
                ],
                n_parties: 2,
            },
            GroupSpec {
                features: vec!["p_pvalue_level".into(), "p_shopping_level".into()],
                n_parties: 2,
            },
        ],
    }
}

/// Look up a dataset by name: (schema, partition, paper row count).
pub fn by_name(name: &str) -> Option<(Schema, PartitionSpec, usize)> {
    match name {
        "banking" => Some((banking_schema(), banking_partition(), BANKING_ROWS)),
        "adult" => Some((adult_schema(), adult_partition(), ADULT_ROWS)),
        "taobao" => Some((taobao_schema(), taobao_partition(), TAOBAO_ROWS)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims(schema: &Schema, spec: &PartitionSpec) -> (usize, Vec<usize>) {
        let a: Vec<&str> = spec.active_features.iter().map(|s| s.as_str()).collect();
        let active = schema.encoded_width_of(&a);
        let groups = spec
            .groups
            .iter()
            .map(|g| {
                let names: Vec<&str> = g.features.iter().map(|s| s.as_str()).collect();
                schema.encoded_width_of(&names)
            })
            .collect();
        (active, groups)
    }

    #[test]
    fn banking_dims_match_paper() {
        let (active, groups) = dims(&banking_schema(), &banking_partition());
        assert_eq!(active, 57); // Linear(57, 64)
        assert_eq!(groups, vec![3, 20]); // Linear(3,64), Linear(20,64)
        assert_eq!(active + groups.iter().sum::<usize>(), 80); // ≡ Linear(80, 64)
    }

    #[test]
    fn adult_dims_match_paper() {
        let (active, groups) = dims(&adult_schema(), &adult_partition());
        assert_eq!(active, 27);
        assert_eq!(groups, vec![63, 16]);
        assert_eq!(active + groups.iter().sum::<usize>(), 106);
    }

    #[test]
    fn taobao_dims_match_paper() {
        let (active, groups) = dims(&taobao_schema(), &taobao_partition());
        assert_eq!(active, 197);
        assert_eq!(groups, vec![11, 6]);
        assert_eq!(active + groups.iter().sum::<usize>(), 214);
    }

    #[test]
    fn hidden_dims() {
        assert_eq!(hidden_dim("banking"), 64);
        assert_eq!(hidden_dim("adult"), 64);
        assert_eq!(hidden_dim("taobao"), 128);
    }

    #[test]
    fn four_passive_parties_each() {
        for name in ["banking", "adult", "taobao"] {
            let (_, spec, _) = by_name(name).unwrap();
            assert_eq!(spec.total_passive_parties(), 4, "{name}");
        }
    }

    #[test]
    fn by_name_unknown() {
        assert!(by_name("mnist").is_none());
    }

    #[test]
    fn partition_features_cover_schema() {
        for name in ["banking", "adult", "taobao"] {
            let (schema, spec, _) = by_name(name).unwrap();
            let mut covered: Vec<&str> = spec.active_features.iter().map(|s| s.as_str()).collect();
            for g in &spec.groups {
                covered.extend(g.features.iter().map(|s| s.as_str()));
            }
            assert_eq!(covered.len(), schema.features.len(), "{name}: every feature placed once");
            for f in &schema.features {
                assert!(covered.contains(&f.name.as_str()), "{name}: {} missing", f.name);
            }
        }
    }
}
