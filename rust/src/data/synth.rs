//! Synthetic tabular data generation.
//!
//! The sandbox has no network access to the UCI/Taobao sources, so the
//! three evaluation datasets are generated synthetically against their
//! published schemas (same columns, cardinalities, row counts — see
//! DESIGN.md §Substitutions). Labels are planted through a logistic
//! ground-truth model over the one-hot encoding so that training has
//! real signal and the "no accuracy impact" claim (secure ≡ unsecured)
//! can be checked on a learnable task.

use super::encode::encode_row;
use super::schema::{FeatureKind, RawValue, Schema};
use crate::crypto::rng::DetRng;

/// A generated dataset: raw rows, binary labels, and stable sample IDs.
#[derive(Clone)]
pub struct Dataset {
    pub schema: Schema,
    pub rows: Vec<Vec<RawValue>>,
    pub labels: Vec<f32>,
    /// Stable 8-byte sample identifiers (shared across parties; §4.0.2).
    pub ids: Vec<u64>,
}

/// Generate `n_rows` rows with a planted logistic labelling.
pub fn generate(schema: &Schema, n_rows: usize, seed: u64) -> Dataset {
    let mut rng = DetRng::from_seed(seed);
    // ground-truth weights over the encoded space
    let width = schema.encoded_width();
    let w: Vec<f32> = (0..width).map(|_| (rng.next_gaussian() as f32) * 1.5).collect();
    let b: f32 = rng.next_gaussian() as f32 * 0.25;

    let mut rows = Vec::with_capacity(n_rows);
    let mut labels = Vec::with_capacity(n_rows);
    let mut ids = Vec::with_capacity(n_rows);
    for i in 0..n_rows {
        let row: Vec<RawValue> = schema
            .features
            .iter()
            .map(|f| match f.kind {
                FeatureKind::Categorical(c) => RawValue::Cat(rng.next_range(0, c as u64) as usize),
                FeatureKind::Numeric { min, max } => {
                    RawValue::Num(min + (max - min) * rng.next_f64() as f32)
                }
            })
            .collect();
        let x = encode_row(schema, &row);
        let logit: f32 = x.iter().zip(&w).map(|(a, b)| a * b).sum::<f32>() + b;
        let p = 1.0 / (1.0 + (-logit).exp());
        let y = if (rng.next_f64() as f32) < p { 1.0 } else { 0.0 };
        rows.push(row);
        labels.push(y);
        // non-sequential, unique IDs (simulating real account numbers)
        ids.push(((i as u64) << 20) | (rng.next_u64() & 0xfffff));
        let _ = i;
    }
    Dataset { schema: schema.clone(), rows, labels, ids }
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Train/test split by fraction (deterministic, no shuffle — rows
    /// are already i.i.d. by construction).
    pub fn split(&self, train_frac: f32) -> (Dataset, Dataset) {
        let k = ((self.len() as f32) * train_frac) as usize;
        let take = |lo: usize, hi: usize| Dataset {
            schema: self.schema.clone(),
            rows: self.rows[lo..hi].to_vec(),
            labels: self.labels[lo..hi].to_vec(),
            ids: self.ids[lo..hi].to_vec(),
        };
        (take(0, k), take(k, self.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::Feature;

    fn schema() -> Schema {
        Schema::new(
            "test",
            vec![Feature::cat("c1", 4), Feature::num("n1", -1.0, 1.0), Feature::cat("c2", 2)],
        )
    }

    #[test]
    fn deterministic_per_seed() {
        let s = schema();
        let a = generate(&s, 100, 7);
        let b = generate(&s, 100, 7);
        assert_eq!(a.rows, b.rows);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.ids, b.ids);
        let c = generate(&s, 100, 8);
        assert_ne!(a.rows, c.rows);
    }

    #[test]
    fn values_respect_schema() {
        let s = schema();
        let d = generate(&s, 500, 1);
        for row in &d.rows {
            match row[0] {
                RawValue::Cat(v) => assert!(v < 4),
                _ => panic!("c1 should be categorical"),
            }
            match row[1] {
                RawValue::Num(v) => assert!((-1.0..=1.0).contains(&v)),
                _ => panic!("n1 should be numeric"),
            }
        }
    }

    #[test]
    fn labels_are_binary_and_balanced_ish() {
        let d = generate(&schema(), 2000, 3);
        let pos: usize = d.labels.iter().filter(|&&y| y == 1.0).count();
        assert!(d.labels.iter().all(|&y| y == 0.0 || y == 1.0));
        // planted logistic labels shouldn't be degenerate
        assert!(pos > 200 && pos < 1800, "pos={pos}");
    }

    #[test]
    fn ids_unique() {
        let d = generate(&schema(), 5000, 4);
        let mut ids = d.ids.clone();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 5000);
    }

    #[test]
    fn split_partitions_rows() {
        let d = generate(&schema(), 100, 5);
        let (tr, te) = d.split(0.8);
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
        assert_eq!(tr.ids[0], d.ids[0]);
        assert_eq!(te.ids[0], d.ids[80]);
    }

    #[test]
    fn labels_learnable_signal() {
        // a trivial logistic fit on the encoded features should beat chance
        let s = schema();
        let d = generate(&s, 3000, 6);
        let width = s.encoded_width();
        let xs: Vec<Vec<f32>> = d.rows.iter().map(|r| encode_row(&s, r)).collect();
        let mut w = vec![0.0f32; width];
        let mut b = 0.0f32;
        let lr = 0.5;
        for _ in 0..200 {
            let mut gw = vec![0.0f32; width];
            let mut gb = 0.0;
            for (x, &y) in xs.iter().zip(&d.labels) {
                let z: f32 = x.iter().zip(&w).map(|(a, b)| a * b).sum::<f32>() + b;
                let p = 1.0 / (1.0 + (-z).exp());
                let g = p - y;
                for (gwi, xi) in gw.iter_mut().zip(x) {
                    *gwi += g * xi;
                }
                gb += g;
            }
            for (wi, gwi) in w.iter_mut().zip(&gw) {
                *wi -= lr * gwi / xs.len() as f32;
            }
            b -= lr * gb / xs.len() as f32;
        }
        let correct: usize = xs
            .iter()
            .zip(&d.labels)
            .filter(|(x, &y)| {
                let z: f32 = x.iter().zip(&w).map(|(a, b)| a * b).sum::<f32>() + b;
                (z > 0.0) == (y == 1.0)
            })
            .count();
        let acc = correct as f32 / xs.len() as f32;
        assert!(acc > 0.65, "planted signal should be learnable, acc={acc}");
    }
}
