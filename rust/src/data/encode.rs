//! Feature encoding: raw rows → dense f32 vectors.
//!
//! Categoricals are one-hot encoded; numerics are min-max normalized to
//! [0, 1]. Column layout follows schema order, which is what the
//! per-party Linear modules in the paper consume (e.g. Banking active
//! party = 57 encoded columns → Linear(57, 64)).

use super::schema::{FeatureKind, RawValue, Schema};

/// Encode a full row against its schema.
pub fn encode_row(schema: &Schema, row: &[RawValue]) -> Vec<f32> {
    assert_eq!(row.len(), schema.features.len(), "row arity mismatch");
    let mut out = Vec::with_capacity(schema.encoded_width());
    for (f, v) in schema.features.iter().zip(row) {
        match (&f.kind, v) {
            (FeatureKind::Categorical(c), RawValue::Cat(idx)) => {
                assert!(idx < c, "category {idx} out of range for {}", f.name);
                let start = out.len();
                out.resize(start + c, 0.0);
                out[start + idx] = 1.0;
            }
            (FeatureKind::Numeric { min, max }, RawValue::Num(x)) => {
                out.push(((x - min) / (max - min)).clamp(0.0, 1.0));
            }
            _ => panic!("value kind mismatch for feature {}", f.name),
        }
    }
    out
}

/// Encode only a named subset of features (a party's view), in schema
/// order. Returns the encoded sub-vector.
pub fn encode_subset(schema: &Schema, row: &[RawValue], names: &[&str]) -> Vec<f32> {
    let mut out = Vec::new();
    for (f, v) in schema.features.iter().zip(row) {
        if !names.contains(&f.name.as_str()) {
            continue;
        }
        match (&f.kind, v) {
            (FeatureKind::Categorical(c), RawValue::Cat(idx)) => {
                assert!(idx < c);
                let start = out.len();
                out.resize(start + c, 0.0);
                out[start + idx] = 1.0;
            }
            (FeatureKind::Numeric { min, max }, RawValue::Num(x)) => {
                out.push(((x - min) / (max - min)).clamp(0.0, 1.0));
            }
            _ => panic!("value kind mismatch for feature {}", f.name),
        }
    }
    out
}

/// Encode a batch of subset views into a row-major (B × d) matrix.
pub fn encode_batch(schema: &Schema, rows: &[&[RawValue]], names: &[&str]) -> Vec<f32> {
    let mut out = Vec::new();
    for row in rows {
        out.extend(encode_subset(schema, row, names));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::Feature;

    fn schema() -> Schema {
        Schema::new(
            "t",
            vec![Feature::cat("c", 3), Feature::num("n", 10.0, 20.0), Feature::cat("d", 2)],
        )
    }

    #[test]
    fn one_hot_layout() {
        let s = schema();
        let row = [RawValue::Cat(1), RawValue::Num(15.0), RawValue::Cat(0)];
        assert_eq!(encode_row(&s, &row), vec![0.0, 1.0, 0.0, 0.5, 1.0, 0.0]);
    }

    #[test]
    fn numeric_clamped() {
        let s = schema();
        let row = [RawValue::Cat(0), RawValue::Num(25.0), RawValue::Cat(1)];
        let e = encode_row(&s, &row);
        assert_eq!(e[3], 1.0);
    }

    #[test]
    fn subset_matches_full_projection() {
        let s = schema();
        let row = [RawValue::Cat(2), RawValue::Num(12.5), RawValue::Cat(1)];
        let full = encode_row(&s, &row);
        let sub = encode_subset(&s, &row, &["c", "d"]);
        assert_eq!(sub, vec![full[0], full[1], full[2], full[4], full[5]]);
        let sub_n = encode_subset(&s, &row, &["n"]);
        assert_eq!(sub_n, vec![full[3]]);
    }

    #[test]
    fn subset_ignores_order_of_names() {
        let s = schema();
        let row = [RawValue::Cat(0), RawValue::Num(11.0), RawValue::Cat(1)];
        // schema order governs, not the order of `names`
        assert_eq!(encode_subset(&s, &row, &["d", "c"]), encode_subset(&s, &row, &["c", "d"]));
    }

    #[test]
    fn batch_is_row_major() {
        let s = schema();
        let r1 = [RawValue::Cat(0), RawValue::Num(10.0), RawValue::Cat(0)];
        let r2 = [RawValue::Cat(1), RawValue::Num(20.0), RawValue::Cat(1)];
        let b = encode_batch(&s, &[&r1, &r2], &["n", "d"]);
        assert_eq!(b, vec![0.0, 1.0, 0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        encode_row(&schema(), &[RawValue::Cat(0)]);
    }
}
