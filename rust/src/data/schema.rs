//! Dataset schemas: typed feature descriptions with one-hot encoded
//! widths, used by the synthetic generators and the vertical
//! partitioner.

/// The type of a feature column.
#[derive(Clone, Debug, PartialEq)]
pub enum FeatureKind {
    /// Categorical with the given cardinality (one-hot encoded).
    Categorical(usize),
    /// Numeric in [min, max] (min-max normalized to one column).
    Numeric { min: f32, max: f32 },
}

/// A named feature column.
#[derive(Clone, Debug, PartialEq)]
pub struct Feature {
    pub name: String,
    pub kind: FeatureKind,
}

impl Feature {
    pub fn cat(name: &str, cardinality: usize) -> Self {
        assert!(cardinality >= 2, "categorical needs ≥ 2 levels");
        Feature { name: name.into(), kind: FeatureKind::Categorical(cardinality) }
    }

    pub fn num(name: &str, min: f32, max: f32) -> Self {
        assert!(max > min);
        Feature { name: name.into(), kind: FeatureKind::Numeric { min, max } }
    }

    /// Encoded width: cardinality for categoricals, 1 for numerics.
    pub fn encoded_width(&self) -> usize {
        match self.kind {
            FeatureKind::Categorical(c) => c,
            FeatureKind::Numeric { .. } => 1,
        }
    }
}

/// One raw cell value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RawValue {
    Cat(usize),
    Num(f32),
}

/// A dataset schema: ordered features + binary label.
#[derive(Clone, Debug)]
pub struct Schema {
    pub name: String,
    pub features: Vec<Feature>,
}

impl Schema {
    pub fn new(name: &str, features: Vec<Feature>) -> Self {
        Schema { name: name.into(), features }
    }

    /// Total one-hot encoded width of all features.
    pub fn encoded_width(&self) -> usize {
        self.features.iter().map(|f| f.encoded_width()).sum()
    }

    /// Encoded width of a named subset, in schema order.
    pub fn encoded_width_of(&self, names: &[&str]) -> usize {
        self.features
            .iter()
            .filter(|f| names.contains(&f.name.as_str()))
            .map(|f| f.encoded_width())
            .sum()
    }

    pub fn feature_index(&self, name: &str) -> Option<usize> {
        self.features.iter().position(|f| f.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encoded_widths() {
        let s = Schema::new(
            "t",
            vec![Feature::cat("color", 3), Feature::num("age", 0.0, 100.0), Feature::cat("yn", 2)],
        );
        assert_eq!(s.encoded_width(), 6);
        assert_eq!(s.encoded_width_of(&["color", "age"]), 4);
        assert_eq!(s.encoded_width_of(&["yn"]), 2);
        assert_eq!(s.feature_index("age"), Some(1));
        assert_eq!(s.feature_index("nope"), None);
    }

    #[test]
    #[should_panic]
    fn cat_needs_two_levels() {
        Feature::cat("bad", 1);
    }
}
