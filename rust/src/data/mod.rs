//! Dataset substrate: schemas, synthetic generation, encoding, and the
//! vertical feature/sample partitioning of §6.1–6.2.

pub mod csv;
pub mod datasets;
pub mod encode;
pub mod partition;
pub mod schema;
pub mod synth;

pub use datasets::{adult_partition, adult_schema, banking_partition, banking_schema, by_name, hidden_dim, taobao_partition, taobao_schema};
pub use partition::{partition, GroupSpec, PartitionSpec, VerticalDataset};
pub use schema::{Feature, FeatureKind, RawValue, Schema};
pub use synth::{generate, Dataset};
