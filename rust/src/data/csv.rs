//! CSV loading: run the system on the *real* UCI Banking / Adult files
//! when available (the synthetic generators exist because this build
//! sandbox has no network; the protocol itself is data-agnostic).
//!
//! Hand-rolled parser (no csv crate in the vendored registry):
//! delimiter-configurable, quoted-field aware, with schema-driven
//! typing — categorical levels are interned in first-seen order and
//! clamped to the schema's cardinality; numerics are parsed and later
//! min-max normalized by the schema bounds.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::schema::{FeatureKind, RawValue, Schema};
use super::synth::Dataset;

/// Split one CSV line honoring double-quoted fields.
pub fn split_line(line: &str, delim: char) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' => {
                if in_quotes && chars.peek() == Some(&'"') {
                    cur.push('"'); // escaped quote
                    chars.next();
                } else {
                    in_quotes = !in_quotes;
                }
            }
            c if c == delim && !in_quotes => {
                out.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    out.push(cur);
    out
}

/// A parsed CSV table: header + string rows.
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

pub fn parse_csv(text: &str, delim: char) -> Result<CsvTable> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header: Vec<String> = split_line(lines.next().context("empty csv")?, delim)
        .into_iter()
        .map(|h| h.trim().trim_matches('"').to_string())
        .collect();
    let mut rows = Vec::new();
    for (i, line) in lines.enumerate() {
        let fields = split_line(line, delim);
        if fields.len() != header.len() {
            bail!("row {}: {} fields, header has {}", i + 2, fields.len(), header.len());
        }
        rows.push(fields.into_iter().map(|f| f.trim().to_string()).collect());
    }
    Ok(CsvTable { header, rows })
}

/// Convert a parsed table into a [`Dataset`] under `schema`, reading
/// the label from `label_col` (values matching `positive` → 1.0).
/// Categorical levels are interned per column in first-seen order;
/// unseen levels beyond the schema cardinality are clamped to the last
/// level (standard rare-category bucketing).
pub fn table_to_dataset(
    table: &CsvTable,
    schema: &Schema,
    label_col: &str,
    positive: &str,
) -> Result<Dataset> {
    let col_of = |name: &str| -> Result<usize> {
        table
            .header
            .iter()
            .position(|h| h == name)
            .with_context(|| format!("column {name} missing (header: {:?})", table.header))
    };
    let label_idx = col_of(label_col)?;
    let feat_idx: Vec<usize> =
        schema.features.iter().map(|f| col_of(&f.name)).collect::<Result<_>>()?;

    let mut interned: Vec<HashMap<String, usize>> =
        schema.features.iter().map(|_| HashMap::new()).collect();

    let mut rows = Vec::with_capacity(table.rows.len());
    let mut labels = Vec::with_capacity(table.rows.len());
    let mut ids = Vec::with_capacity(table.rows.len());
    for (ri, raw) in table.rows.iter().enumerate() {
        let mut row = Vec::with_capacity(schema.features.len());
        for ((f, &ci), intern) in
            schema.features.iter().zip(&feat_idx).zip(interned.iter_mut())
        {
            let cell = &raw[ci];
            match f.kind {
                FeatureKind::Categorical(card) => {
                    let next = intern.len();
                    let level = *intern.entry(cell.clone()).or_insert(next);
                    row.push(RawValue::Cat(level.min(card - 1)));
                }
                FeatureKind::Numeric { .. } => {
                    let v: f32 = cell
                        .parse()
                        .with_context(|| format!("row {}: bad numeric {cell:?} for {}", ri + 2, f.name))?;
                    row.push(RawValue::Num(v));
                }
            }
        }
        rows.push(row);
        labels.push(if raw[label_idx] == positive { 1.0 } else { 0.0 });
        ids.push(ri as u64 + 1);
    }
    Ok(Dataset { schema: schema.clone(), rows, labels, ids })
}

/// Load a delimited file against a schema (e.g. the UCI `bank-full.csv`
/// with `;` and label column `y`/`yes`).
pub fn load_csv_dataset(
    path: &str,
    schema: &Schema,
    delim: char,
    label_col: &str,
    positive: &str,
) -> Result<Dataset> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
    let table = parse_csv(&text, delim)?;
    table_to_dataset(&table, schema, label_col, positive)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::schema::Feature;

    #[test]
    fn split_basic_and_quoted() {
        assert_eq!(split_line("a,b,c", ','), vec!["a", "b", "c"]);
        assert_eq!(split_line("a;;c", ';'), vec!["a", "", "c"]);
        assert_eq!(split_line(r#""x,y",z"#, ','), vec!["x,y", "z"]);
        assert_eq!(split_line(r#""he said ""hi""",ok"#, ','), vec![r#"he said "hi""#, "ok"]);
    }

    #[test]
    fn parse_and_convert() {
        let csv = "\
age;job;balance;y
30;admin;100.5;yes
45;technician;-20.0;no
30;admin;0.0;yes
";
        let table = parse_csv(csv, ';').unwrap();
        assert_eq!(table.header, vec!["age", "job", "balance", "y"]);
        assert_eq!(table.rows.len(), 3);

        let schema = Schema::new(
            "mini",
            vec![
                Feature::num("age", 18.0, 95.0),
                Feature::cat("job", 3),
                Feature::num("balance", -100.0, 200.0),
            ],
        );
        let ds = table_to_dataset(&table, &schema, "y", "yes").unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.labels, vec![1.0, 0.0, 1.0]);
        assert_eq!(ds.rows[0][1], RawValue::Cat(0)); // admin interned first
        assert_eq!(ds.rows[1][1], RawValue::Cat(1)); // technician second
        assert_eq!(ds.rows[2][1], RawValue::Cat(0)); // admin again
        assert_eq!(ds.rows[1][2], RawValue::Num(-20.0));
        // ids unique & stable
        assert_eq!(ds.ids, vec![1, 2, 3]);
    }

    #[test]
    fn cardinality_clamping() {
        let csv = "c,y\na,1\nb,1\nc,1\nd,1\n";
        let table = parse_csv(csv, ',').unwrap();
        let schema = Schema::new("t", vec![Feature::cat("c", 3)]);
        let ds = table_to_dataset(&table, &schema, "y", "1").unwrap();
        // levels a,b,c then d clamps into the last bucket
        assert_eq!(ds.rows[3][0], RawValue::Cat(2));
    }

    #[test]
    fn errors_are_informative() {
        assert!(parse_csv("", ',').is_err());
        let bad = parse_csv("a,b\n1\n", ',');
        assert!(bad.is_err());
        let table = parse_csv("a,y\nxx,1\n", ',').unwrap();
        let schema = Schema::new("t", vec![Feature::num("a", 0.0, 1.0)]);
        assert!(table_to_dataset(&table, &schema, "y", "1").is_err()); // xx not numeric
        assert!(table_to_dataset(&table, &schema, "nope", "1").is_err()); // missing col
    }

    #[test]
    fn real_banking_schema_compatible() {
        // a two-row synthetic slice in the real bank-full.csv layout
        let csv = "\
age;job;marital;education;default;balance;housing;loan;contact;day;month;campaign;pdays;previous;poutcome;y
58;management;married;tertiary;no;2143;yes;no;unknown;5;may;1;-1;0;unknown;no
44;technician;single;secondary;no;29;yes;no;unknown;5;may;1;-1;0;unknown;yes
";
        let table = parse_csv(csv, ';').unwrap();
        let schema = crate::data::banking_schema();
        let ds = table_to_dataset(&table, &schema, "y", "yes").unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.labels, vec![0.0, 1.0]);
        // encodes to the full 80-wide vector
        let enc = crate::data::encode::encode_row(&schema, &ds.rows[0]);
        assert_eq!(enc.len(), 80);
    }
}
