//! CPU-time accounting per (node, phase), with a separate bucket for
//! security overhead — the measurement behind Table 1.
//!
//! "Overhead" is the time spent in operations that exist only because
//! of the security modules: mask PRG expansion + fixed-point encoding,
//! AEAD sealing / trial decryption of sample IDs, and key
//! agreement/rotation. The unsecured baseline run provides the
//! cross-check (secure total − plain total ≈ overhead bucket).

use std::collections::HashMap;
use std::time::Instant;

use crate::net::Phase;

/// Driver-side pipelining counters for the windowed round scheduler
/// (`--rounds-in-flight`): how much round overlap a run actually
/// achieved, and how long the driver sat with *zero* rounds in flight
/// between retiring one round and opening the next (the idle gap the
/// window exists to close). Collected by
/// [`RoundWindow`](super::window::RoundWindow) and folded into the
/// run's [`Metrics`] by every transport, so
/// `benches/table1_cpu_time.rs` can report the win next to the CPU
/// numbers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PipelineStats {
    /// Rounds the scheduler opened over the run.
    pub rounds_started: u64,
    /// Rounds opened while at least one other round was still in
    /// flight — each one is a round-start the serial driver would have
    /// delayed behind a `RoundDone` round-trip.
    pub overlapped_starts: u64,
    /// Peak rounds simultaneously in flight (1 for a serial run).
    pub max_in_flight: u64,
    /// Wall-clock the driver spent with an empty window while schedule
    /// rounds remained — the serialization gap between a round's
    /// completion and the next round's start.
    pub idle_gap_ns: u128,
}

/// Node index: 0 = aggregator, i+1 = client i (active party = client 0).
pub type Node = usize;

pub const AGGREGATOR: Node = 0;

pub fn client(i: usize) -> Node {
    i + 1
}

#[derive(Clone, Copy, Debug, Default)]
pub struct CpuEntry {
    pub total_ns: u128,
    pub overhead_ns: u128,
}

/// CPU meters for one experiment run.
#[derive(Default)]
pub struct Metrics {
    entries: HashMap<(Node, Phase), CpuEntry>,
    /// Peak bytes a node held in fan-in buffers at any point of the
    /// run — the memory claim of the streaming aggregation pipeline
    /// (monolithic fan-ins buffer O(n·d); chunked fan-ins hold O(d)
    /// shard accumulators, in base-protocol *and* dropout-tolerant
    /// runs — tolerant purge history spills to the rollback log,
    /// metered separately by [`record_spilled`](Metrics::record_spilled)).
    peak_buffered: HashMap<Node, u64>,
    /// Peak resident bytes per (node, shard) — the per-shard view of
    /// `peak_buffered` for shard-parallel aggregation (`--agg-workers`):
    /// the footprint each shard's accumulator worker owns.
    peak_shard_buffered: HashMap<(Node, usize), u64>,
    /// Peak bytes a node spilled to its rollback log (dropout-tolerant
    /// chunked runs; 0 everywhere else). Spilled bytes are on disk,
    /// not resident — kept apart from `peak_buffered` so the RAM claim
    /// stays honest.
    peak_spilled: HashMap<Node, u64>,
    /// Peak simultaneously-live socket connections at a node — the
    /// event-loop transport's concurrency meter (the C10K claim is
    /// "this reaches 10k+ on one aggregator process").
    peak_connections: HashMap<Node, u64>,
    /// Peak bytes any *single* connection at a node held across its
    /// partial-frame reassembly buffer and bounded outbound queue —
    /// the per-client memory claim of the event-loop transport (flat
    /// in the client count is what makes the concurrency meter above
    /// affordable).
    peak_conn_buffered: HashMap<Node, u64>,
    /// Driver-side round-pipelining counters (see [`PipelineStats`]).
    pipeline: PipelineStats,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record an already-measured duration. `overhead` marks security
    /// operations, which count toward both buckets.
    pub fn record(&mut self, node: Node, phase: Phase, ns: u128, overhead: bool) {
        let e = self.entries.entry((node, phase)).or_default();
        e.total_ns += ns;
        if overhead {
            e.overhead_ns += ns;
        }
    }

    /// Time a unit of ordinary (non-security) work.
    pub fn time<T>(&mut self, node: Node, phase: Phase, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(node, phase, t0.elapsed().as_nanos(), false);
        out
    }

    /// Time a security operation: counts toward both total and overhead.
    pub fn time_overhead<T>(&mut self, node: Node, phase: Phase, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(node, phase, t0.elapsed().as_nanos(), true);
        out
    }

    /// Record the current buffered-byte level of a node's fan-in
    /// state; the meter keeps the maximum ever observed.
    pub fn record_buffered(&mut self, node: Node, current_bytes: u64) {
        let peak = self.peak_buffered.entry(node).or_default();
        *peak = (*peak).max(current_bytes);
    }

    /// Peak fan-in buffer bytes observed at `node` (0 if never metered).
    pub fn peak_buffered_bytes(&self, node: Node) -> u64 {
        self.peak_buffered.get(&node).copied().unwrap_or(0)
    }

    /// Record the current resident bytes of one shard's accumulator
    /// state at a node; the meter keeps the maximum ever observed.
    pub fn record_shard_buffered(&mut self, node: Node, shard: usize, current_bytes: u64) {
        let peak = self.peak_shard_buffered.entry((node, shard)).or_default();
        *peak = (*peak).max(current_bytes);
    }

    /// Peak resident bytes observed for `shard` at `node` (0 if never
    /// metered).
    pub fn peak_shard_buffered_bytes(&self, node: Node, shard: usize) -> u64 {
        self.peak_shard_buffered.get(&(node, shard)).copied().unwrap_or(0)
    }

    /// Record the current rollback-log spill level of a node; the
    /// meter keeps the maximum ever observed.
    pub fn record_spilled(&mut self, node: Node, current_bytes: u64) {
        let peak = self.peak_spilled.entry(node).or_default();
        *peak = (*peak).max(current_bytes);
    }

    /// Peak rollback-log bytes spilled by `node` (0 if never metered).
    pub fn peak_spilled_bytes(&self, node: Node) -> u64 {
        self.peak_spilled.get(&node).copied().unwrap_or(0)
    }

    /// Record the current count of live connections multiplexed at a
    /// node; the meter keeps the maximum ever observed.
    pub fn record_connections(&mut self, node: Node, current: u64) {
        let peak = self.peak_connections.entry(node).or_default();
        *peak = (*peak).max(current);
    }

    /// Peak simultaneously-live connections observed at `node` (0 if
    /// never metered — only the event-loop transport meters this).
    pub fn peak_connections(&self, node: Node) -> u64 {
        self.peak_connections.get(&node).copied().unwrap_or(0)
    }

    /// Record the current buffered bytes (read reassembly + outbound
    /// queue) of one connection at a node; the meter keeps the
    /// maximum any single connection ever held.
    pub fn record_conn_buffered(&mut self, node: Node, current_bytes: u64) {
        let peak = self.peak_conn_buffered.entry(node).or_default();
        *peak = (*peak).max(current_bytes);
    }

    /// Peak per-connection buffered bytes observed at `node` (0 if
    /// never metered).
    pub fn peak_conn_buffered_bytes(&self, node: Node) -> u64 {
        self.peak_conn_buffered.get(&node).copied().unwrap_or(0)
    }

    /// Fold the round scheduler's pipelining counters into this run's
    /// meters (counts sum, the in-flight peak takes the maximum —
    /// consistent with how distributed per-party meters merge).
    pub fn record_pipeline(&mut self, p: PipelineStats) {
        self.pipeline.rounds_started += p.rounds_started;
        self.pipeline.overlapped_starts += p.overlapped_starts;
        self.pipeline.max_in_flight = self.pipeline.max_in_flight.max(p.max_in_flight);
        self.pipeline.idle_gap_ns += p.idle_gap_ns;
    }

    /// The run's round-pipelining counters (all-zero when no transport
    /// recorded them, e.g. a `join`-side client process).
    pub fn pipeline(&self) -> PipelineStats {
        self.pipeline
    }

    /// Fold another party's meters into this one (used by the driver to
    /// assemble one run-wide view from per-party meters).
    pub fn merge(&mut self, other: Metrics) {
        for ((node, phase), e) in other.entries {
            let slot = self.entries.entry((node, phase)).or_default();
            slot.total_ns += e.total_ns;
            slot.overhead_ns += e.overhead_ns;
        }
        for (node, peak) in other.peak_buffered {
            self.record_buffered(node, peak);
        }
        for ((node, shard), peak) in other.peak_shard_buffered {
            self.record_shard_buffered(node, shard, peak);
        }
        for (node, peak) in other.peak_spilled {
            self.record_spilled(node, peak);
        }
        for (node, peak) in other.peak_connections {
            self.record_connections(node, peak);
        }
        for (node, peak) in other.peak_conn_buffered {
            self.record_conn_buffered(node, peak);
        }
        self.record_pipeline(other.pipeline);
    }

    pub fn get(&self, node: Node, phase: Phase) -> CpuEntry {
        self.entries.get(&(node, phase)).copied().unwrap_or_default()
    }

    /// Milliseconds helpers for reporting.
    pub fn total_ms(&self, node: Node, phase: Phase) -> f64 {
        self.get(node, phase).total_ns as f64 / 1e6
    }

    pub fn overhead_ms(&self, node: Node, phase: Phase) -> f64 {
        self.get(node, phase).overhead_ns as f64 / 1e6
    }

    /// Average totals over a set of nodes (e.g. all passive parties).
    pub fn avg_ms(&self, nodes: &[Node], phase: Phase) -> (f64, f64) {
        if nodes.is_empty() {
            return (0.0, 0.0);
        }
        let (mut t, mut o) = (0.0, 0.0);
        for &n in nodes {
            t += self.total_ms(n, phase);
            o += self.overhead_ms(n, phase);
        }
        (t / nodes.len() as f64, o / nodes.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_accumulate() {
        let mut m = Metrics::new();
        m.time(client(0), Phase::Training, || std::thread::sleep(std::time::Duration::from_millis(2)));
        m.time_overhead(client(0), Phase::Training, || {
            std::thread::sleep(std::time::Duration::from_millis(1))
        });
        let e = m.get(client(0), Phase::Training);
        assert!(e.total_ns >= 3_000_000, "total {}", e.total_ns);
        assert!(e.overhead_ns >= 1_000_000 && e.overhead_ns < e.total_ns);
        // other cells untouched
        assert_eq!(m.get(AGGREGATOR, Phase::Training).total_ns, 0);
        assert_eq!(m.get(client(0), Phase::Testing).total_ns, 0);
    }

    #[test]
    fn averages() {
        let mut m = Metrics::new();
        m.time(client(1), Phase::Testing, || std::thread::sleep(std::time::Duration::from_millis(1)));
        m.time(client(2), Phase::Testing, || std::thread::sleep(std::time::Duration::from_millis(3)));
        let (t, o) = m.avg_ms(&[client(1), client(2)], Phase::Testing);
        assert!(t >= 2.0, "avg total {t}");
        assert_eq!(o, 0.0);
    }

    #[test]
    fn peak_buffered_keeps_maximum_and_merges() {
        let mut m = Metrics::new();
        m.record_buffered(AGGREGATOR, 100);
        m.record_buffered(AGGREGATOR, 50);
        assert_eq!(m.peak_buffered_bytes(AGGREGATOR), 100);
        let mut other = Metrics::new();
        other.record_buffered(AGGREGATOR, 300);
        m.merge(other);
        assert_eq!(m.peak_buffered_bytes(AGGREGATOR), 300);
        assert_eq!(m.peak_buffered_bytes(client(0)), 0);
    }

    #[test]
    fn per_shard_and_spill_peaks_keep_maximum_and_merge() {
        let mut m = Metrics::new();
        m.record_shard_buffered(AGGREGATOR, 0, 64);
        m.record_shard_buffered(AGGREGATOR, 0, 32);
        m.record_shard_buffered(AGGREGATOR, 1, 16);
        m.record_spilled(AGGREGATOR, 500);
        m.record_spilled(AGGREGATOR, 100);
        assert_eq!(m.peak_shard_buffered_bytes(AGGREGATOR, 0), 64);
        assert_eq!(m.peak_shard_buffered_bytes(AGGREGATOR, 1), 16);
        assert_eq!(m.peak_shard_buffered_bytes(AGGREGATOR, 2), 0, "unmetered shard");
        assert_eq!(m.peak_spilled_bytes(AGGREGATOR), 500);
        assert_eq!(m.peak_spilled_bytes(client(0)), 0);
        let mut other = Metrics::new();
        other.record_shard_buffered(AGGREGATOR, 1, 128);
        other.record_spilled(AGGREGATOR, 900);
        m.merge(other);
        assert_eq!(m.peak_shard_buffered_bytes(AGGREGATOR, 0), 64);
        assert_eq!(m.peak_shard_buffered_bytes(AGGREGATOR, 1), 128);
        assert_eq!(m.peak_spilled_bytes(AGGREGATOR), 900);
    }

    #[test]
    fn connection_peaks_keep_maximum_and_merge() {
        let mut m = Metrics::new();
        m.record_connections(AGGREGATOR, 512);
        m.record_connections(AGGREGATOR, 100);
        m.record_conn_buffered(AGGREGATOR, 4096);
        m.record_conn_buffered(AGGREGATOR, 64);
        assert_eq!(m.peak_connections(AGGREGATOR), 512);
        assert_eq!(m.peak_conn_buffered_bytes(AGGREGATOR), 4096);
        assert_eq!(m.peak_connections(client(0)), 0, "unmetered node");
        assert_eq!(m.peak_conn_buffered_bytes(client(0)), 0);
        let mut other = Metrics::new();
        other.record_connections(AGGREGATOR, 10_240);
        other.record_conn_buffered(AGGREGATOR, 1024);
        m.merge(other);
        assert_eq!(m.peak_connections(AGGREGATOR), 10_240, "merge keeps the max");
        assert_eq!(m.peak_conn_buffered_bytes(AGGREGATOR), 4096);
    }

    #[test]
    fn pipeline_counters_sum_and_max_on_merge() {
        let mut m = Metrics::new();
        m.record_pipeline(PipelineStats {
            rounds_started: 8,
            overlapped_starts: 3,
            max_in_flight: 2,
            idle_gap_ns: 100,
        });
        let mut other = Metrics::new();
        other.record_pipeline(PipelineStats {
            rounds_started: 1,
            overlapped_starts: 0,
            max_in_flight: 4,
            idle_gap_ns: 50,
        });
        m.merge(other);
        let p = m.pipeline();
        assert_eq!(p.rounds_started, 9);
        assert_eq!(p.overlapped_starts, 3);
        assert_eq!(p.max_in_flight, 4, "peaks take the maximum");
        assert_eq!(p.idle_gap_ns, 150);
    }

    #[test]
    fn node_indexing() {
        assert_eq!(AGGREGATOR, 0);
        assert_eq!(client(0), 1);
        assert_eq!(client(4), 5);
    }
}
