//! The windowed round scheduler behind `--rounds-in-flight`.
//!
//! PR 1's driver ran the static schedule strictly serially: round
//! *k + 1* started only after round *k*'s `RoundDone` note had crossed
//! back to the driver, so every party idled while the aggregator
//! drained a fan-in and the active party waited on the gradient
//! downlink. [`RoundWindow`] replaces that loop with a *window*: up to
//! `W` rounds may be in flight simultaneously, each isolated in its own
//! per-round protocol context ([`parties`](super::parties)) and routed
//! by the `round` tag every protocol message already carries.
//!
//! Every transport drives the same scheduler — the simulator, the
//! threaded transport, and TCP `serve` all loop `next_start` /
//! `complete` — so the window semantics cannot drift between them.
//!
//! ## Why `W = 1` (and any `W`) stays bit-identical
//!
//! The scheduler never reorders rounds: starts are issued strictly in
//! schedule order, and three *barriers* keep every round's inputs
//! exactly what the serial driver would have fed it:
//!
//! * **Setup/rotation barrier.** A `Setup` round or a training round
//!   with `rotate = true` replaces every client's masking session. It
//!   starts only when the window is empty and blocks all successors
//!   until it completes, so no round ever straddles a key epoch.
//! * **Phase barrier.** A round whose [`Phase`] differs from the rounds
//!   in flight waits for the window to empty. Phases partition the
//!   schedule contiguously, so this serializes exactly one boundary
//!   (training → testing) — and it is what keeps the per-phase Table-2
//!   byte counters bit-identical to a serial run (every transport
//!   meters against one global "current phase").
//! * **Dropout drain.** At the first dropout declaration the aggregator
//!   emits [`Note::WindowDrain`](super::party::Note); [`drain`] pins
//!   the effective width to 1 for the rest of the run, so recovery,
//!   purge, and re-key semantics compose with pipelining without a
//!   single new case: in-flight rounds finish, then the run proceeds
//!   exactly like the serial dropout-tolerant protocol.
//!
//! Within those barriers the remaining overlap is real: testing rounds
//! are mutually independent (parameters are frozen), so with `W > 1`
//! passive parties run round *r + 1*'s forward pass and window-masking
//! while the aggregator is still folding round *r*'s chunks; training
//! rounds chain through the active party's SGD step by data dependency
//! (its `RoundCtx` defers opening round *r + 1* until round *r*'s
//! update lands), which is precisely why their overlap is safe — the
//! values cannot differ, only the idle gaps shrink. [`stats`] reports
//! how much overlap a run achieved ([`PipelineStats`]).
//!
//! [`drain`]: RoundWindow::drain
//! [`stats`]: RoundWindow::stats

use std::collections::BTreeSet;
use std::time::Instant;

use crate::net::Phase;

use super::metrics::PipelineStats;
use super::party::{Note, RoundKind, RoundSpec};

/// Hard cap on `--rounds-in-flight`: enough to hide any realistic
/// fan-in drain latency, low enough that per-round contexts (fan-in
/// buffers, assemblers, rollback logs) stay a small bounded ring.
pub const MAX_ROUNDS_IN_FLIGHT: usize = 64;

/// The windowed scheduler: hands out rounds to start (in schedule
/// order, up to the window width, respecting the barriers above) and
/// retires them as their `RoundDone` notes arrive.
pub struct RoundWindow<'s> {
    schedule: &'s [RoundSpec],
    width: usize,
    /// Next schedule index to hand out.
    next: usize,
    /// Round numbers started but not yet completed.
    in_flight: BTreeSet<u32>,
    /// A setup/rotation round is in flight: nothing else may start.
    barrier_round: Option<u32>,
    /// Phase shared by every in-flight round (`None` when empty).
    phase: Option<Phase>,
    /// A dropout was declared: effective width is 1 from here on.
    drained: bool,
    stats: PipelineStats,
    /// Set when the window empties with schedule rounds remaining —
    /// the serialization gap the pipeline exists to close.
    idle_since: Option<Instant>,
}

impl<'s> RoundWindow<'s> {
    /// `width` is `--rounds-in-flight`, already validated ≥ 1 (a zero
    /// width is clamped rather than trusted — it would deadlock).
    pub fn new(schedule: &'s [RoundSpec], width: usize) -> Self {
        RoundWindow {
            schedule,
            width: width.max(1),
            next: 0,
            in_flight: BTreeSet::new(),
            barrier_round: None,
            phase: None,
            drained: false,
            stats: PipelineStats::default(),
            idle_since: None,
        }
    }

    /// The next round to start right now, or `None` if the window is
    /// full, a barrier is pending, or the schedule is exhausted.
    /// Callers loop until `None` so an emptied window refills at once.
    pub fn next_start(&mut self) -> Option<&'s RoundSpec> {
        let spec = self.schedule.get(self.next)?;
        let width = if self.drained { 1 } else { self.width };
        if self.in_flight.len() >= width || self.barrier_round.is_some() {
            return None;
        }
        let barrier = spec.kind == RoundKind::Setup || spec.rotate;
        if !self.in_flight.is_empty() && (barrier || self.phase != Some(spec.phase)) {
            return None;
        }
        if let Some(t0) = self.idle_since.take() {
            self.stats.idle_gap_ns += t0.elapsed().as_nanos();
        }
        self.stats.rounds_started += 1;
        if !self.in_flight.is_empty() {
            self.stats.overlapped_starts += 1;
        }
        let fresh = self.in_flight.insert(spec.round);
        debug_assert!(fresh, "schedule round numbers are unique");
        self.stats.max_in_flight = self.stats.max_in_flight.max(self.in_flight.len() as u64);
        self.phase = Some(spec.phase);
        if barrier {
            self.barrier_round = Some(spec.round);
        }
        self.next += 1;
        Some(spec)
    }

    /// Retire a completed round (its `RoundDone` note arrived). Returns
    /// whether the round was actually in flight — a `false` means a
    /// stray completion the caller should treat as an ordinary note.
    pub fn complete(&mut self, round: u32) -> bool {
        if !self.in_flight.remove(&round) {
            return false;
        }
        if self.barrier_round == Some(round) {
            self.barrier_round = None;
        }
        if self.in_flight.is_empty() {
            self.phase = None;
            if self.next < self.schedule.len() {
                self.idle_since = Some(Instant::now());
            }
        }
        true
    }

    /// A dropout was declared: stop opening new rounds until the
    /// in-flight ones finish, then run serially (width 1) for the rest
    /// of the run — the recovery path's purge/re-key semantics are
    /// exactly the serial protocol's.
    pub fn drain(&mut self) {
        self.drained = true;
    }

    /// Feed one driver note through the scheduler — the single
    /// note-dispatch protocol every transport shares, so the window
    /// semantics cannot drift between them: `WindowDrain` drains the
    /// window and is consumed (returns `None`), `RoundDone` retires its
    /// round and passes through, everything else passes through
    /// untouched. Callers record whatever comes back as a result note.
    pub fn observe(&mut self, note: Note) -> Option<Note> {
        match note {
            Note::WindowDrain { .. } => {
                self.drain();
                None
            }
            Note::RoundDone { round } => {
                self.complete(round);
                Some(Note::RoundDone { round })
            }
            other => Some(other),
        }
    }

    /// Rounds currently in flight.
    pub fn in_flight(&self) -> usize {
        self.in_flight.len()
    }

    /// The oldest in-flight round (stall diagnostics name this one:
    /// its prerequisites are all delivered, so a quiescent transport
    /// means *its* missing senders are the dropped ones).
    pub fn oldest_in_flight(&self) -> Option<u32> {
        self.in_flight.iter().next().copied()
    }

    /// Every scheduled round has started and completed.
    pub fn done(&self) -> bool {
        self.next >= self.schedule.len() && self.in_flight.is_empty()
    }

    /// The run's pipelining counters (fold into the run's `Metrics`).
    pub fn stats(&self) -> PipelineStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::party::SETUP_ROUND;

    fn spec(round: u32, kind: RoundKind, rotate: bool, phase: Phase) -> RoundSpec {
        RoundSpec { round, kind, rotate, phase, ids: Vec::new() }
    }

    /// setup → rotate-train → train ×3 → test ×2 (round numbers as the
    /// driver lays them out).
    fn schedule() -> Vec<RoundSpec> {
        vec![
            spec(SETUP_ROUND, RoundKind::Setup, false, Phase::Setup),
            spec(0, RoundKind::Train, true, Phase::Training),
            spec(1, RoundKind::Train, false, Phase::Training),
            spec(2, RoundKind::Train, false, Phase::Training),
            spec(3, RoundKind::Train, false, Phase::Training),
            spec(4, RoundKind::Test, false, Phase::Testing),
            spec(5, RoundKind::Test, false, Phase::Testing),
        ]
    }

    fn rounds_startable(win: &mut RoundWindow) -> Vec<u32> {
        let mut out = Vec::new();
        while let Some(s) = win.next_start() {
            out.push(s.round);
        }
        out
    }

    #[test]
    fn width_one_is_strictly_serial() {
        let sched = schedule();
        let mut win = RoundWindow::new(&sched, 1);
        for s in &sched {
            assert_eq!(rounds_startable(&mut win), vec![s.round], "one at a time");
            assert!(win.next_start().is_none(), "window full at W=1");
            assert!(win.complete(s.round));
        }
        assert!(win.done());
        let p = win.stats();
        assert_eq!(p.rounds_started, sched.len() as u64);
        assert_eq!(p.overlapped_starts, 0, "serial runs never overlap");
        assert_eq!(p.max_in_flight, 1);
    }

    #[test]
    fn setup_and_rotation_rounds_are_barriers() {
        let sched = schedule();
        let mut win = RoundWindow::new(&sched, 4);
        // the setup round starts alone and blocks everything
        assert_eq!(rounds_startable(&mut win), vec![SETUP_ROUND]);
        assert!(win.complete(SETUP_ROUND));
        // the rotate round is a barrier too
        assert_eq!(rounds_startable(&mut win), vec![0]);
        assert!(win.complete(0));
        // plain training rounds fill the window
        assert_eq!(rounds_startable(&mut win), vec![1, 2, 3]);
        assert_eq!(win.in_flight(), 3);
        assert_eq!(win.oldest_in_flight(), Some(1));
        // the phase barrier keeps test rounds out until training drains
        assert!(win.complete(1));
        assert!(win.next_start().is_none(), "testing waits for the training window");
        assert!(win.complete(2));
        assert!(win.complete(3));
        assert_eq!(rounds_startable(&mut win), vec![4, 5], "tests overlap each other");
        // out-of-order completion is fine
        assert!(win.complete(5));
        assert!(win.complete(4));
        assert!(win.done());
        let p = win.stats();
        assert_eq!(p.max_in_flight, 3);
        assert_eq!(p.overlapped_starts, 3, "rounds 2, 3 and 5 piggybacked");
    }

    #[test]
    fn drain_pins_width_to_one() {
        let sched = schedule();
        let mut win = RoundWindow::new(&sched, 4);
        assert!(win.complete(rounds_startable(&mut win)[0])); // setup
        assert!(win.complete(rounds_startable(&mut win)[0])); // rotate
        assert_eq!(rounds_startable(&mut win), vec![1, 2, 3]);
        win.drain();
        assert!(win.next_start().is_none(), "draining: no new starts");
        win.complete(1);
        win.complete(2);
        assert!(win.next_start().is_none(), "still draining");
        win.complete(3);
        // drained: strictly serial from here on
        assert_eq!(rounds_startable(&mut win), vec![4]);
        assert!(win.next_start().is_none());
        win.complete(4);
        assert_eq!(rounds_startable(&mut win), vec![5]);
    }

    #[test]
    fn stray_completions_are_reported() {
        let sched = schedule();
        let mut win = RoundWindow::new(&sched, 2);
        assert!(!win.complete(3), "round 3 was never started");
        assert_eq!(rounds_startable(&mut win), vec![SETUP_ROUND]);
        assert!(!win.complete(7), "unknown round");
        assert!(win.complete(SETUP_ROUND));
        assert!(!win.complete(SETUP_ROUND), "double completion");
    }

    #[test]
    fn observe_dispatches_scheduler_notes() {
        let sched = schedule();
        let mut win = RoundWindow::new(&sched, 4);
        assert_eq!(rounds_startable(&mut win), vec![SETUP_ROUND]);
        // RoundDone retires its round and passes through
        assert_eq!(
            win.observe(Note::RoundDone { round: SETUP_ROUND }),
            Some(Note::RoundDone { round: SETUP_ROUND })
        );
        assert_eq!(win.in_flight(), 0);
        // WindowDrain is consumed and pins the width
        assert_eq!(win.observe(Note::WindowDrain { round: 0 }), None);
        assert_eq!(rounds_startable(&mut win), vec![0]);
        win.complete(0);
        assert_eq!(rounds_startable(&mut win), vec![1], "drained: serial");
        // everything else passes through untouched
        let loss = Note::Loss { round: 1, loss: 0.5 };
        assert_eq!(win.observe(loss.clone()), Some(loss));
    }

    #[test]
    fn zero_width_is_clamped_not_deadlocked() {
        let sched = schedule();
        let mut win = RoundWindow::new(&sched, 0);
        assert_eq!(rounds_startable(&mut win), vec![SETUP_ROUND]);
    }
}
