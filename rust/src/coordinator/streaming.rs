//! The chunked streaming pipeline: shard layout, the sender-side chunk
//! plan, and the aggregator-side [`ChunkAssembler`] — since the
//! shard-parallel refactor, a *routing layer* over per-shard
//! accumulator workers.
//!
//! ## Memory model
//!
//! The monolithic fan-in buffers one full-length ℤ₂⁶⁴ vector per
//! sender until every live sender contributed — O(n·d) peak at the
//! aggregator. The streaming pipeline splits each tensor into
//! `shards` contiguous shards, streamed as chunks of ≤ `chunk_words`
//! words each. Because ℤ₂⁶⁴ wrap-addition is order-independent, every
//! validated chunk is folded into its shard's accumulator *on
//! arrival* — the aggregator's resident fan-in state is exactly one
//! tensor-length set of shard accumulators, O(d), for the base
//! protocol **and** dropout-tolerant runs alike.
//!
//! * **Base protocol** (no dropout tolerance): a sender whose stream
//!   breaks can never complete, the fan-in can never be consumed, and
//!   the round aborts as stalled — so chunks already committed for it
//!   are unreachable garbage, not corruption. Nothing beyond the
//!   accumulators is retained.
//! * **Dropout-tolerant runs** (`shamir_threshold` set): a sender may
//!   be declared dropped at any time before the sum is consumed (even
//!   with a complete contribution buffered, e.g. when it fails to
//!   surrender shares), and the recovery math re-adds the dropped
//!   client's entire total mask — sound only if its data contributed
//!   nothing. Exact purge therefore needs every sender's committed
//!   words to stay *subtractable* until the fan-in is consumed. Instead
//!   of holding per-sender shard sums in RAM (the pre-rollback design,
//!   which matched the monolithic O(n·d) peak), each committed chunk is
//!   appended to a per-round **rollback log** — an append-only spill
//!   file, never resident. Purging a declared-dropped sender *replays*
//!   the log, wrap-subtracting that sender's entries from the shard
//!   accumulators record by record (one chunk of transient memory), so
//!   the dropout-path aggregator RAM peak is O(d) too — below the
//!   monolithic baseline for the first time. The log is truncated at
//!   every round reset and deleted when the assembler drops.
//!
//! ## Shard-parallel workers (`--agg-workers`) and the shared pool
//!
//! With `agg_workers > 1` the aggregator spawns **one** [`WorkerPool`]
//! of that many accumulator workers (capped at the shard count) and
//! every assembler — acts and grads, across every live round context —
//! shares it. Jobs are addressed by a *slot*: a small id unique to one
//! (round, fan-in) pair, so worker `w` holds, per slot, the
//! accumulators of the shards `k` with `k % workers == w`, and two
//! rounds' chunks fold concurrently without cross-talk. The routing
//! layer — the per-sender stream validation below — stays
//! single-threaded in the aggregator's event loop; validated chunk
//! payloads are handed to the owning worker over a bounded channel
//! (backpressure keeps in-flight chunks small), and rollback replays
//! route wrap-subtractions the same way. [`ChunkAssembler::take_sum`]
//! is the deterministic merge: it drains the slot from every worker
//! and stitches the accumulators into the one global vector at their
//! fixed shard offsets, retiring the slot worker-side. Workers perform
//! nothing but ℤ₂⁶⁴ wrap-arithmetic on disjoint ranges, so any worker
//! count — including 1, the inline default that spawns no threads —
//! produces bit-identical sums on every transport
//! (`tests/chunk_equivalence.rs` sweeps worker counts across sim,
//! threaded, and TCP). One metering caveat: with workers > 1 the
//! aggregator's Table-1 CPU meters time only the routing layer — the
//! folding runs off-thread. The paper's measurement configuration is
//! the default inline path (workers = 1), where attribution stays
//! exact.
//!
//! ## Rollback-log durability (`--rollback-fsync`, `--rollback-max-bytes`)
//!
//! The rollback log is a local temp spill file. Two production knobs
//! bound it: `--rollback-fsync` fsyncs every appended record (so a
//! crash-restarted aggregator could replay a consistent log — at the
//! cost of one `fdatasync` per committed chunk), and
//! `--rollback-max-bytes` caps the file size, failing the run with the
//! typed [`StreamError::RollbackLogFull`] instead of growing a temp
//! file without bound. The default cap is
//! [`DEFAULT_ROLLBACK_MAX_BYTES`] (1 GiB).
//!
//! A sender whose chunk stream has a gap (a lost chunk under fault
//! injection) is marked bad, its committed words rolled back (tolerant
//! runs), and its remaining chunks ignored: at the next quiescence
//! probe it is declared dropped (tolerant runs) or the round aborts as
//! stalled (base protocol).

use std::collections::{BTreeMap, BTreeSet};
use std::fs::File;
use std::io::{BufReader, Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};

use anyhow::{bail, Context, Result};

/// Default cap on one rollback log's size: far above anything a
/// tolerant round spills in practice, low enough to fail loudly before
/// a runaway stream fills the temp filesystem.
pub const DEFAULT_ROLLBACK_MAX_BYTES: u64 = 1 << 30;

/// Typed streaming-pipeline errors (`anyhow` carries them; callers
/// downcast to react to a specific failure).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamError {
    /// Appending a committed chunk would push the rollback log past its
    /// configured bound (`--rollback-max-bytes`).
    RollbackLogFull { limit: u64, needed: u64 },
}

impl std::fmt::Display for StreamError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StreamError::RollbackLogFull { limit, needed } => write!(
                f,
                "rollback log full: appending would need {needed} bytes, \
                 --rollback-max-bytes caps it at {limit}"
            ),
        }
    }
}

impl std::error::Error for StreamError {}

/// Rollback-log durability policy (`--rollback-fsync`,
/// `--rollback-max-bytes`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RollbackCfg {
    /// fsync every appended record.
    pub fsync: bool,
    /// Hard cap on the log size; exceeding it is the typed
    /// [`StreamError::RollbackLogFull`].
    pub max_bytes: u64,
}

impl Default for RollbackCfg {
    fn default() -> Self {
        RollbackCfg { fsync: false, max_bytes: DEFAULT_ROLLBACK_MAX_BYTES }
    }
}

/// Chunking parameters, carried from [`RunConfig`](super::RunConfig)
/// into every party. `chunk_words: None` = the monolithic path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamCfg {
    /// Maximum ℤ₂⁶⁴ words per [`MaskedChunk`](super::messages::Msg)
    /// payload. `None` disables chunking entirely.
    pub chunk_words: Option<usize>,
    /// Shards per tensor (≥ 1). Only meaningful with `chunk_words`.
    pub shards: usize,
    /// Aggregator-side shard workers (`--agg-workers`, ≥ 1). 1 = the
    /// inline sequential path (no threads); > 1 makes the aggregator
    /// spawn one shared [`WorkerPool`] of that many accumulator
    /// workers (capped at the shard count) that every fan-in
    /// assembler, across all live rounds, folds through.
    pub agg_workers: usize,
    /// Mask-expansion workers (`--expand-workers`, ≥ 1). 1 = the
    /// inline serial path (no threads); > 1 makes every party spawn an
    /// [`ExpandPool`](crate::crypto::prg::ExpandPool) that partitions
    /// each tensor window into disjoint sub-windows and expands them
    /// in parallel — bit-identical to serial by the window-partition
    /// property. Meaningful with and without chunking.
    pub expand_workers: usize,
    /// Rollback-log durability policy (revocable assemblers only).
    pub rollback: RollbackCfg,
}

impl Default for StreamCfg {
    fn default() -> Self {
        Self::monolithic()
    }
}

impl StreamCfg {
    pub fn monolithic() -> Self {
        StreamCfg {
            chunk_words: None,
            shards: 1,
            agg_workers: 1,
            expand_workers: 1,
            rollback: RollbackCfg::default(),
        }
    }

    pub fn chunked(chunk_words: usize, shards: usize) -> Self {
        StreamCfg { chunk_words: Some(chunk_words), shards, ..Self::monolithic() }
    }

    /// Set the aggregator-side worker count.
    pub fn with_workers(mut self, agg_workers: usize) -> Self {
        self.agg_workers = agg_workers;
        self
    }

    /// Set the mask-expansion worker count.
    pub fn with_expand_workers(mut self, expand_workers: usize) -> Self {
        self.expand_workers = expand_workers;
        self
    }

    /// Set the rollback-log durability policy.
    pub fn with_rollback(mut self, rollback: RollbackCfg) -> Self {
        self.rollback = rollback;
        self
    }
}

/// Wire-header bytes of one `MaskedChunk` message: tag(1) + round(4) +
/// from(2) + tensor-tag(1) + shard(2) + offset(4) + total(4) +
/// word-count(4). The byte-accounting rule for Table 2 lives with the
/// [`Network`](crate::net::Network) counters; [`chunk_overhead_bytes`]
/// computes the exact delta.
pub const CHUNK_MSG_HEADER_BYTES: u64 = 22;

/// Wire-header bytes of a monolithic `MaskedActivation` /
/// `MaskedGradient`: tag(1) + round(4) + from(2) + word-count(4).
pub const MONO_MSG_HEADER_BYTES: u64 = 11;

/// Wire-header bytes of one `GradientChunk` (the aggregator→active
/// downlink window): tag(1) + round(4) + shard(2) + offset(4) +
/// total(4) + word-count(4). No `from` field — the downlink has exactly
/// one sender.
pub const GRAD_CHUNK_MSG_HEADER_BYTES: u64 = 19;

/// Wire-header bytes of a monolithic `GradientSum`: tag(1) + round(4)
/// + word-count(4).
pub const GRAD_SUM_HEADER_BYTES: u64 = 9;

/// Wire-header bytes of one `PartialSum` (a leaf aggregator's folded
/// shard uplink in the `--leaves` fan-in tree): tag(1) + round(4) +
/// tensor-tag(1) + shard_start(2) + shard_end(2) + word-count(4).
pub const PARTIAL_SUM_HEADER_BYTES: u64 = 14;

/// How a tensor of `total` words is cut into `shards` contiguous
/// shards: the first `total % shards` shards get one extra word, so
/// shard sizes differ by at most one and every shard is non-empty
/// (requires `1 ≤ shards ≤ total`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardLayout {
    pub total: usize,
    pub shards: usize,
}

impl ShardLayout {
    pub fn new(total: usize, shards: usize) -> Self {
        assert!(shards >= 1, "shard count must be ≥ 1");
        assert!(shards <= total, "shard count {shards} exceeds tensor length {total}");
        ShardLayout { total, shards }
    }

    /// (start word, length) of shard `k`.
    pub fn shard_range(&self, k: usize) -> (usize, usize) {
        assert!(k < self.shards);
        let base = self.total / self.shards;
        let rem = self.total % self.shards;
        let start = k * base + k.min(rem);
        let len = base + usize::from(k < rem);
        (start, len)
    }

    /// The shard containing global word `w`.
    pub fn shard_of(&self, w: usize) -> usize {
        assert!(w < self.total);
        let base = self.total / self.shards;
        let rem = self.total % self.shards;
        let boundary = rem * (base + 1);
        if w < boundary {
            w / (base + 1)
        } else {
            rem + (w - boundary) / base
        }
    }
}

/// One planned chunk: shard index, global word offset, word count.
/// Chunks never cross a shard boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Chunk {
    pub shard: usize,
    pub offset: usize,
    pub len: usize,
}

/// The chunk sequence for one tensor: shards in order, each cut into
/// `chunk_words`-sized chunks (the last chunk of a shard may be
/// shorter).
pub fn chunk_plan(layout: ShardLayout, chunk_words: usize) -> Vec<Chunk> {
    assert!(chunk_words >= 1, "chunk size must be ≥ 1");
    let mut plan = Vec::new();
    for k in 0..layout.shards {
        let (start, len) = layout.shard_range(k);
        let mut off = 0;
        while off < len {
            let n = chunk_words.min(len - off);
            plan.push(Chunk { shard: k, offset: start + off, len: n });
            off += n;
        }
    }
    plan
}

/// Number of chunk messages one tensor of `total` words becomes.
pub fn chunk_count(total: usize, shards: usize, chunk_words: usize) -> u64 {
    let layout = ShardLayout::new(total, shards);
    (0..shards)
        .map(|k| {
            let (_, len) = layout.shard_range(k);
            len.div_ceil(chunk_words) as u64
        })
        .sum()
}

/// The exact Table-2 byte delta of sending one `total`-word tensor
/// chunked instead of monolithic: both carry `8 · total` payload
/// bytes, the monolithic message adds one 11-byte header, the chunked
/// stream one 22-byte header per chunk.
pub fn chunk_overhead_bytes(total: usize, shards: usize, chunk_words: usize) -> u64 {
    CHUNK_MSG_HEADER_BYTES * chunk_count(total, shards, chunk_words) - MONO_MSG_HEADER_BYTES
}

/// The exact Table-2 byte delta of the chunked aggregator→active
/// `GradientSum` downlink vs the monolithic message: same `8 · total`
/// payload, one 19-byte header per `GradientChunk` instead of one
/// 9-byte `GradientSum` header.
pub fn grad_chunk_overhead_bytes(total: usize, shards: usize, chunk_words: usize) -> u64 {
    GRAD_CHUNK_MSG_HEADER_BYTES * chunk_count(total, shards, chunk_words)
        - GRAD_SUM_HEADER_BYTES
}

// ---------------------------------------------------------------------------
// Aggregator-side assembly
// ---------------------------------------------------------------------------

fn wrap_add_at(dst: &mut [u64], at: usize, src: &[u64]) {
    for (d, s) in dst[at..at + src.len()].iter_mut().zip(src) {
        *d = d.wrapping_add(*s);
    }
}

fn wrap_sub_at(dst: &mut [u64], at: usize, src: &[u64]) {
    for (d, s) in dst[at..at + src.len()].iter_mut().zip(src) {
        *d = d.wrapping_sub(*s);
    }
}

/// The shard accumulators one executor (the inline path or one worker
/// thread) owns: shard index → (global start word, accumulator).
#[derive(Default)]
struct ShardBank {
    accs: BTreeMap<usize, (usize, Vec<u64>)>,
}

impl ShardBank {
    fn init(&mut self, layout: ShardLayout, owned: impl Iterator<Item = usize>) {
        self.accs.clear();
        for k in owned {
            let (start, len) = layout.shard_range(k);
            self.accs.insert(k, (start, vec![0u64; len]));
        }
    }

    fn add(&mut self, shard: usize, at: usize, words: &[u64]) {
        let (_, acc) = self.accs.get_mut(&shard).expect("shard bank initialized");
        wrap_add_at(acc, at, words);
    }

    fn sub(&mut self, shard: usize, at: usize, words: &[u64]) {
        let (_, acc) = self.accs.get_mut(&shard).expect("shard bank initialized");
        wrap_sub_at(acc, at, words);
    }

    fn drain(&mut self) -> Vec<(usize, Vec<u64>)> {
        std::mem::take(&mut self.accs).into_values().collect()
    }

    /// Non-consuming copy of the current accumulators (the leaf
    /// aggregators' re-emittable partial sums).
    fn snapshot(&self) -> Vec<(usize, Vec<u64>)> {
        self.accs.values().cloned().collect()
    }

    fn reset(&mut self) {
        self.accs.clear();
    }
}

/// One unit of work for a shard worker, addressed by *slot* — the id
/// of the (round, fan-in) assembler it belongs to, so one shared pool
/// serves every live round context without cross-talk. Workers do
/// nothing but ℤ₂⁶⁴ wrap-arithmetic on the shard accumulators they
/// own — all stream validation happens in the routing layer before
/// dispatch.
enum Job {
    Init { slot: u64, layout: ShardLayout },
    Add { slot: u64, shard: usize, at: usize, words: Vec<u64> },
    Sub { slot: u64, shard: usize, at: usize, words: Vec<u64> },
    Drain { slot: u64, reply: Sender<Vec<(usize, Vec<u64>)>> },
    /// Copy a slot's accumulators without draining them (the leaf
    /// aggregators' re-emittable partial snapshot).
    Snapshot { slot: u64, reply: Sender<Vec<(usize, Vec<u64>)>> },
    /// Free a slot's accumulators without draining them (assembler
    /// reset or drop).
    Retire { slot: u64 },
}

/// Bounded job-queue depth per worker: backpressure keeps the RAM held
/// by in-flight chunk payloads at ≤ `workers · JOB_QUEUE_DEPTH` chunks.
const JOB_QUEUE_DEPTH: usize = 64;

fn worker_loop(rx: Receiver<Job>, w: usize, workers: usize) {
    // slot → the shard accumulators this worker owns for that slot
    // (shards k with k % workers == w of the slot's layout)
    let mut banks: BTreeMap<u64, ShardBank> = BTreeMap::new();
    while let Ok(job) = rx.recv() {
        match job {
            Job::Init { slot, layout } => {
                banks.entry(slot).or_default().init(layout, (w..layout.shards).step_by(workers));
            }
            Job::Add { slot, shard, at, words } => {
                banks.get_mut(&slot).expect("slot initialized").add(shard, at, &words);
            }
            Job::Sub { slot, shard, at, words } => {
                banks.get_mut(&slot).expect("slot initialized").sub(shard, at, &words);
            }
            Job::Drain { slot, reply } => {
                let part = banks.remove(&slot).map(|mut b| b.drain()).unwrap_or_default();
                let _ = reply.send(part);
            }
            Job::Snapshot { slot, reply } => {
                let part = banks.get(&slot).map(|b| b.snapshot()).unwrap_or_default();
                let _ = reply.send(part);
            }
            Job::Retire { slot } => {
                banks.remove(&slot);
            }
        }
    }
}

/// One shared pool of accumulator worker threads (`--agg-workers`),
/// created once by the aggregator and folded through by *every*
/// chunked fan-in assembler — acts and grads, across every live round
/// context — instead of the pre-refactor one-pool-per-fan-in shape
/// that doubled the thread count. Slots keep the assemblers' state
/// disjoint worker-side.
pub struct WorkerPool {
    txs: Vec<SyncSender<Job>>,
}

impl WorkerPool {
    /// Spawn `workers` accumulator workers (≥ 1; callers cap at the
    /// shard count — a worker that owns no shard of a slot's layout
    /// simply replies with an empty drain).
    ///
    /// The threads are detached on purpose: each worker's loop ends
    /// when every sender to its job channel is gone, i.e. when the
    /// pool *and* every [`PoolClient`]-holding assembler have dropped —
    /// joining from the pool's `Drop` would deadlock whenever an
    /// assembler legitimately outlives it. Workers hold nothing but
    /// memory, so exit-by-channel-closure is a clean shutdown.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let mut txs = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = sync_channel::<Job>(JOB_QUEUE_DEPTH);
            std::thread::Builder::new()
                .name(format!("agg-shard-worker-{w}"))
                .spawn(move || worker_loop(rx, w, workers))
                .expect("spawn shard worker");
            txs.push(tx);
        }
        WorkerPool { txs }
    }

    /// A cheap handle assemblers route jobs through.
    pub fn client(&self) -> PoolClient {
        PoolClient { txs: self.txs.clone() }
    }

    pub fn workers(&self) -> usize {
        self.txs.len()
    }
}

/// An assembler's route into the shared [`WorkerPool`].
#[derive(Clone)]
pub struct PoolClient {
    txs: Vec<SyncSender<Job>>,
}

impl PoolClient {
    fn to_owner(&self, shard: usize, job: Job) {
        self.txs[shard % self.txs.len()].send(job).expect("shard worker alive");
    }

    fn to_all(&self, mut make: impl FnMut() -> Job) {
        for tx in &self.txs {
            tx.send(make()).expect("shard worker alive");
        }
    }
}

/// How one assembler's shard accumulators execute: inline in the
/// aggregator's event loop (`agg_workers = 1`, no threads), or as a
/// slot of the shared [`WorkerPool`].
enum Exec {
    Inline(ShardBank),
    Pool { client: PoolClient, slot: u64 },
}

impl Exec {
    fn init(&mut self, layout: ShardLayout) {
        match self {
            Exec::Inline(bank) => bank.init(layout, 0..layout.shards),
            Exec::Pool { client, slot } => client.to_all(|| Job::Init { slot: *slot, layout }),
        }
    }

    fn add(&mut self, shard: usize, at: usize, words: Vec<u64>) {
        match self {
            Exec::Inline(bank) => bank.add(shard, at, &words),
            Exec::Pool { client, slot } => {
                client.to_owner(shard, Job::Add { slot: *slot, shard, at, words })
            }
        }
    }

    fn sub(&mut self, shard: usize, at: usize, words: Vec<u64>) {
        match self {
            Exec::Inline(bank) => bank.sub(shard, at, &words),
            Exec::Pool { client, slot } => {
                client.to_owner(shard, Job::Sub { slot: *slot, shard, at, words })
            }
        }
    }

    /// The deterministic merge barrier: every executor hands back its
    /// (start, accumulator) pairs for this slot (retiring the slot
    /// worker-side). Shard ranges are disjoint, so the caller's stitch
    /// order is immaterial — any worker count yields a bit-identical
    /// global vector. Per-worker job channels are FIFO, so the drain
    /// necessarily observes every add/sub dispatched before it.
    fn drain(&mut self) -> Vec<(usize, Vec<u64>)> {
        match self {
            Exec::Inline(bank) => bank.drain(),
            Exec::Pool { client, slot } => {
                let (rtx, rrx) = channel();
                client.to_all(|| Job::Drain { slot: *slot, reply: rtx.clone() });
                drop(rtx);
                let mut out = Vec::new();
                while let Ok(part) = rrx.recv() {
                    out.extend(part);
                }
                out
            }
        }
    }

    /// [`drain`](Exec::drain)'s non-consuming twin: copy every
    /// executor's (start, accumulator) pairs, leaving the slot intact
    /// so folding (and purging) can continue afterwards. Same FIFO
    /// guarantee — the snapshot observes every add/sub dispatched
    /// before it.
    fn snapshot(&mut self) -> Vec<(usize, Vec<u64>)> {
        match self {
            Exec::Inline(bank) => bank.snapshot(),
            Exec::Pool { client, slot } => {
                let (rtx, rrx) = channel();
                client.to_all(|| Job::Snapshot { slot: *slot, reply: rtx.clone() });
                drop(rtx);
                let mut out = Vec::new();
                while let Ok(part) = rrx.recv() {
                    out.extend(part);
                }
                out
            }
        }
    }

    fn reset(&mut self) {
        match self {
            Exec::Inline(bank) => bank.reset(),
            Exec::Pool { client, slot } => client.to_all(|| Job::Retire { slot: *slot }),
        }
    }
}

// ---------------------------------------------------------------------------
// Rollback log (dropout-tolerant purge)
// ---------------------------------------------------------------------------

static LOG_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Append-only spill file of committed chunks, `(from, offset, words)`
/// per record. Exists only in revocable (dropout-tolerant) mode: it is
/// what makes an already-committed sender's contribution subtractable
/// without holding per-sender shard sums in RAM. Truncated at every
/// round reset, deleted on drop.
struct RollbackLog {
    file: File,
    path: PathBuf,
    spilled: u64,
    cfg: RollbackCfg,
}

impl RollbackLog {
    fn create(cfg: RollbackCfg) -> Result<Self> {
        let n = LOG_COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir()
            .join(format!("vfl-sa-rollback-{}-{n}.bin", std::process::id()));
        let file = File::options()
            .read(true)
            .write(true)
            .create_new(true)
            .open(&path)
            .with_context(|| format!("create rollback log {}", path.display()))?;
        Ok(RollbackLog { file, path, spilled: 0, cfg })
    }

    /// Record one committed chunk: from(2) ‖ offset(4) ‖ len(4) ‖ words.
    /// Fails with the typed [`StreamError::RollbackLogFull`] before the
    /// log can outgrow its configured bound; fsyncs the record when the
    /// durability knob asks for it.
    fn append(&mut self, from: u16, offset: u32, words: &[u64]) -> Result<()> {
        let mut rec = Vec::with_capacity(10 + words.len() * 8);
        rec.extend_from_slice(&from.to_le_bytes());
        rec.extend_from_slice(&offset.to_le_bytes());
        rec.extend_from_slice(&(words.len() as u32).to_le_bytes());
        for w in words {
            rec.extend_from_slice(&w.to_le_bytes());
        }
        let needed = self.spilled + rec.len() as u64;
        if needed > self.cfg.max_bytes {
            bail!(StreamError::RollbackLogFull { limit: self.cfg.max_bytes, needed });
        }
        self.file.write_all(&rec).context("append rollback log")?;
        if self.cfg.fsync {
            self.file.sync_data().context("fsync rollback log")?;
        }
        self.spilled += rec.len() as u64;
        Ok(())
    }

    /// Replay the log, invoking `f(offset, words)` for every record of
    /// `from` — streamed record by record, so replay holds at most one
    /// chunk of transient memory.
    fn replay(&mut self, from: u16, mut f: impl FnMut(u32, Vec<u64>)) -> Result<()> {
        self.file.seek(SeekFrom::Start(0)).context("seek rollback log")?;
        {
            let mut rdr = BufReader::new(&self.file);
            let mut consumed = 0u64;
            while consumed < self.spilled {
                let mut head = [0u8; 10];
                rdr.read_exact(&mut head).context("rollback log header")?;
                let sender = u16::from_le_bytes([head[0], head[1]]);
                let offset = u32::from_le_bytes([head[2], head[3], head[4], head[5]]);
                let len = u32::from_le_bytes([head[6], head[7], head[8], head[9]]) as usize;
                consumed += 10 + 8 * len as u64;
                if sender == from {
                    let mut buf = vec![0u8; len * 8];
                    rdr.read_exact(&mut buf).context("rollback log words")?;
                    let words: Vec<u64> = buf
                        .chunks_exact(8)
                        .map(|c| u64::from_le_bytes(c.try_into().expect("8-byte chunk")))
                        .collect();
                    f(offset, words);
                } else {
                    rdr.seek_relative(len as i64 * 8).context("skip rollback record")?;
                }
            }
        }
        self.file.seek(SeekFrom::End(0)).context("reposition rollback log")?;
        Ok(())
    }

    fn truncate(&mut self) -> Result<()> {
        self.file.set_len(0).context("truncate rollback log")?;
        self.file.seek(SeekFrom::Start(0)).context("rewind rollback log")?;
        self.spilled = 0;
        Ok(())
    }
}

impl Drop for RollbackLog {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

// ---------------------------------------------------------------------------
// ChunkAssembler: the routing layer
// ---------------------------------------------------------------------------

/// Folds one fan-in's `MaskedChunk` stream into per-shard accumulators
/// (see the module docs for the memory model, the worker pool, and the
/// rollback log). This struct is the *routing layer*: it validates
/// each sender's stream (cursor order, shard boundaries, gaps), routes
/// payloads to the owning executor, logs committed chunks in revocable
/// mode, and performs the deterministic merge at [`take_sum`].
///
/// [`take_sum`]: ChunkAssembler::take_sum
pub struct ChunkAssembler {
    /// Rollback-capable commitment for exact dropout purge
    /// (threshold set).
    revocable: bool,
    shards: usize,
    layout: Option<ShardLayout>,
    /// Per-sender next expected global word (incomplete, non-bad
    /// senders only; chunks ride per-sender FIFO order).
    cursors: BTreeMap<u16, usize>,
    complete: BTreeSet<u16>,
    /// Senders whose stream broke (gap/overlap): state rolled back,
    /// further chunks ignored until the next round reset.
    bad: BTreeSet<u16>,
    /// Senders whose committed words were already replayed out of the
    /// accumulators — a later purge must not subtract twice.
    rolled_back: BTreeSet<u16>,
    exec: Exec,
    log: Option<RollbackLog>,
    rollback: RollbackCfg,
}

impl ChunkAssembler {
    /// An assembler folding inline in the caller's event loop — no
    /// threads (the `--agg-workers 1` default, and the active party's
    /// single-sender downlink assembler).
    pub fn inline(revocable: bool, shards: usize, rollback: RollbackCfg) -> Self {
        assert!(shards >= 1);
        Self::with_exec(revocable, shards, rollback, Exec::Inline(ShardBank::default()))
    }

    /// An assembler folding through the shared [`WorkerPool`] under
    /// `slot` — a caller-unique id per (round, fan-in), so concurrent
    /// round contexts never touch each other's accumulators.
    pub fn pooled(
        revocable: bool,
        shards: usize,
        rollback: RollbackCfg,
        pool: PoolClient,
        slot: u64,
    ) -> Self {
        assert!(shards >= 1);
        Self::with_exec(revocable, shards, rollback, Exec::Pool { client: pool, slot })
    }

    fn with_exec(revocable: bool, shards: usize, rollback: RollbackCfg, exec: Exec) -> Self {
        ChunkAssembler {
            revocable,
            shards,
            layout: None,
            cursors: BTreeMap::new(),
            complete: BTreeSet::new(),
            bad: BTreeSet::new(),
            rolled_back: BTreeSet::new(),
            exec,
            log: None,
            rollback,
        }
    }

    /// Reset for a new round.
    pub fn reset(&mut self) -> Result<()> {
        self.layout = None;
        self.cursors.clear();
        self.complete.clear();
        self.bad.clear();
        self.rolled_back.clear();
        self.exec.reset();
        if let Some(log) = &mut self.log {
            log.truncate()?;
        }
        Ok(())
    }

    /// Wrap-subtract every logged chunk of `from` back out of the
    /// shard accumulators (revocable mode only). Idempotent: a gap
    /// rollback followed by a dropout purge subtracts once.
    fn rollback(&mut self, from: u16) -> Result<()> {
        if !self.revocable || !self.rolled_back.insert(from) {
            return Ok(());
        }
        let (Some(log), Some(layout)) = (self.log.as_mut(), self.layout) else {
            return Ok(());
        };
        let exec = &mut self.exec;
        log.replay(from, |offset, words| {
            let shard = layout.shard_of(offset as usize);
            let (start, _) = layout.shard_range(shard);
            exec.sub(shard, offset as usize - start, words);
        })
    }

    /// Fold one chunk in. A malformed *message* (inconsistent total,
    /// shard/offset outside the layout) is a protocol error and fails
    /// the run; a *gap* in an otherwise well-formed per-sender stream
    /// is a lost message — the sender is marked bad, its committed
    /// words rolled back (revocable mode), and it is silently ignored
    /// so quiescence-based dropout declaration can handle it.
    pub fn add_chunk(
        &mut self,
        from: u16,
        shard: u16,
        offset: u32,
        total: u32,
        words: &[u64],
    ) -> Result<()> {
        if self.bad.contains(&from) {
            return Ok(());
        }
        let total = total as usize;
        if total == 0 || words.is_empty() {
            bail!("empty masked chunk from sender {from}");
        }
        let layout = match self.layout {
            Some(l) => {
                if l.total != total {
                    bail!("chunk total {total} from sender {from} != fan-in total {}", l.total);
                }
                l
            }
            None => {
                if self.shards > total {
                    bail!("{} shards exceed tensor length {total}", self.shards);
                }
                let l = ShardLayout::new(total, self.shards);
                self.layout = Some(l);
                self.exec.init(l);
                if self.revocable && self.log.is_none() {
                    self.log = Some(RollbackLog::create(self.rollback)?);
                }
                l
            }
        };
        let offset = offset as usize;
        let shard = shard as usize;
        if shard >= layout.shards || offset >= total {
            bail!("chunk shard {shard}/offset {offset} out of range from sender {from}");
        }
        let (shard_start, shard_len) = layout.shard_range(shard);
        if offset < shard_start || offset + words.len() > shard_start + shard_len {
            bail!("chunk crosses shard boundary (sender {from}, shard {shard}, offset {offset})");
        }
        if self.complete.contains(&from) {
            bail!("chunk after completed stream from sender {from}");
        }

        let cursor = self.cursors.get(&from).copied().unwrap_or(0);
        if offset != cursor || shard != layout.shard_of(cursor) {
            // a hole in the stream (lost chunk): roll back whatever was
            // committed and let dropout handling (or a stalled-round
            // abort, where the sum is never consumed) take over
            self.cursors.remove(&from);
            self.bad.insert(from);
            return self.rollback(from);
        }
        if let Some(log) = &mut self.log {
            log.append(from, offset as u32, words)?;
        }
        self.exec.add(shard, offset - shard_start, words.to_vec());
        let next = offset + words.len();
        if next == total {
            self.cursors.remove(&from);
            self.complete.insert(from);
        } else {
            self.cursors.insert(from, next);
        }
        Ok(())
    }

    /// Senders whose whole tensor arrived.
    pub fn complete_count(&self) -> usize {
        self.complete.len()
    }

    pub fn complete_senders(&self) -> impl Iterator<Item = u16> + '_ {
        self.complete.iter().copied()
    }

    /// Remove everything a (declared-dropped) sender contributed. In
    /// revocable mode this replays the rollback log, wrap-subtracting
    /// the sender's committed chunks from the shard accumulators — the
    /// invariant the recovery mask-correction relies on. Only reachable
    /// in revocable mode: the base protocol never declares dropouts.
    pub fn purge(&mut self, from: u16) -> Result<()> {
        debug_assert!(
            self.revocable || !self.complete.contains(&from),
            "purging a committed sender from a non-revocable assembler"
        );
        self.rollback(from)?;
        self.cursors.remove(&from);
        self.complete.remove(&from);
        self.bad.remove(&from);
        Ok(())
    }

    /// Consume the fan-in: the deterministic merge. Drains every
    /// executor's shard accumulators and stitches them into one global
    /// vector at their fixed offsets (ranges are disjoint, so the
    /// result is bit-identical for any worker count). `Ok(None)` when
    /// no chunk traffic arrived (the monolithic or float path carried
    /// this round); `Err` if the post-drain reset cannot truncate the
    /// rollback log.
    pub fn take_sum(&mut self) -> Result<Option<Vec<u64>>> {
        let Some(layout) = self.layout else {
            return Ok(None);
        };
        let mut global = vec![0u64; layout.total];
        for (start, acc) in self.exec.drain() {
            global[start..start + acc.len()].copy_from_slice(&acc);
        }
        self.reset()?;
        Ok(Some(global))
    }

    /// [`take_sum`](ChunkAssembler::take_sum)'s non-consuming twin:
    /// stitch the *current* accumulators into one global vector
    /// without draining or resetting anything, so the caller can keep
    /// folding chunks and purging senders afterwards. This is what
    /// lets a leaf aggregator re-emit a corrected `PartialSum` after a
    /// post-emission dropout purge. `Ok(None)` when no chunk traffic
    /// arrived yet.
    pub fn snapshot_sum(&mut self) -> Result<Option<Vec<u64>>> {
        let Some(layout) = self.layout else {
            return Ok(None);
        };
        let mut global = vec![0u64; layout.total];
        for (start, acc) in self.exec.snapshot() {
            global[start..start + acc.len()].copy_from_slice(&acc);
        }
        Ok(Some(global))
    }

    /// Resident bytes of this fan-in's accumulator state — the
    /// quantity behind the streaming pipeline's peak-memory claim
    /// (metered into [`Metrics`](super::Metrics) by the aggregator).
    /// Exactly the shard accumulators: one tensor length, O(d),
    /// regardless of sender count or revocability — rollback state
    /// lives in the spill log ([`spilled_bytes`]), not in RAM.
    ///
    /// [`spilled_bytes`]: ChunkAssembler::spilled_bytes
    pub fn buffered_bytes(&self) -> u64 {
        self.layout.map_or(0, |l| (l.total * 8) as u64)
    }

    /// Per-shard resident accumulator bytes, indexed by shard (all
    /// zeros before the first chunk fixes the layout).
    pub fn shard_buffered_bytes(&self) -> Vec<u64> {
        match self.layout {
            None => vec![0; self.shards],
            Some(l) => (0..l.shards).map(|k| (l.shard_range(k).1 * 8) as u64).collect(),
        }
    }

    /// Bytes currently spilled to the rollback log (0 outside
    /// revocable mode or before any chunk committed).
    pub fn spilled_bytes(&self) -> u64 {
        self.log.as_ref().map_or(0, |l| l.spilled)
    }
}

impl Drop for ChunkAssembler {
    fn drop(&mut self) {
        if let Exec::Pool { client, slot } = &self.exec {
            // free the slot's accumulators worker-side; best-effort
            // because the pool may legitimately be gone already
            for tx in &client.txs {
                let _ = tx.send(Job::Retire { slot: *slot });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_layout_tiles_exactly() {
        for (total, shards) in [(10, 1), (10, 3), (10, 10), (16384, 7), (5184, 4), (3, 2)] {
            let l = ShardLayout::new(total, shards);
            let mut covered = 0usize;
            for k in 0..shards {
                let (start, len) = l.shard_range(k);
                assert_eq!(start, covered, "shards must be contiguous");
                assert!(len >= 1, "every shard non-empty");
                for w in start..start + len {
                    assert_eq!(l.shard_of(w), k, "total={total} shards={shards} w={w}");
                }
                covered += len;
            }
            assert_eq!(covered, total);
        }
    }

    #[test]
    #[should_panic]
    fn more_shards_than_words_rejected() {
        ShardLayout::new(3, 4);
    }

    #[test]
    fn chunk_plan_covers_tensor_within_shards() {
        for (total, shards, cw) in [(100, 1, 7), (100, 3, 7), (100, 3, 1000), (7, 7, 2)] {
            let layout = ShardLayout::new(total, shards);
            let plan = chunk_plan(layout, cw);
            assert_eq!(plan.len() as u64, chunk_count(total, shards, cw));
            let mut cursor = 0usize;
            for c in &plan {
                assert_eq!(c.offset, cursor, "chunks in stream order");
                assert!(c.len <= cw);
                let (start, len) = layout.shard_range(c.shard);
                assert!(c.offset >= start && c.offset + c.len <= start + len, "within shard");
                cursor += c.len;
            }
            assert_eq!(cursor, total);
        }
    }

    /// Build an assembler the way the aggregator does: inline for
    /// `workers ≤ 1`, else a slot of a fresh shared pool (capped at
    /// the shard count). The pool handle can drop immediately — its
    /// detached workers live as long as the assembler's client.
    fn asm(revocable: bool, shards: usize, workers: usize) -> ChunkAssembler {
        if workers <= 1 {
            ChunkAssembler::inline(revocable, shards, RollbackCfg::default())
        } else {
            let pool = WorkerPool::new(workers.min(shards));
            ChunkAssembler::pooled(revocable, shards, RollbackCfg::default(), pool.client(), 1)
        }
    }

    fn feed(asm: &mut ChunkAssembler, from: u16, layout: ShardLayout, cw: usize, vals: &[u64]) {
        for c in chunk_plan(layout, cw) {
            asm.add_chunk(
                from,
                c.shard as u16,
                c.offset as u32,
                layout.total as u32,
                &vals[c.offset..c.offset + c.len],
            )
            .unwrap();
        }
    }

    #[test]
    fn assembler_sums_match_direct_sum_all_modes_and_worker_counts() {
        let total = 37;
        let layout = ShardLayout::new(total, 4);
        let tensors: Vec<Vec<u64>> = (0..3u64)
            .map(|i| (0..total as u64).map(|j| i.wrapping_mul(1 << 40).wrapping_add(j)).collect())
            .collect();
        let mut want = vec![0u64; total];
        for t in &tensors {
            for (w, v) in want.iter_mut().zip(t) {
                *w = w.wrapping_add(*v);
            }
        }
        for revocable in [false, true] {
            for workers in [1, 2, 4, 7] {
                let mut asm = asm(revocable, 4, workers);
                for (i, t) in tensors.iter().enumerate() {
                    feed(&mut asm, i as u16, layout, 5, t);
                }
                assert_eq!(asm.complete_count(), 3);
                assert_eq!(
                    asm.take_sum().unwrap().unwrap(),
                    want,
                    "revocable={revocable} workers={workers}"
                );
                assert!(asm.take_sum().unwrap().is_none(), "take_sum resets");
            }
        }
    }

    #[test]
    fn revocable_purge_removes_whole_contribution() {
        let total = 24;
        let layout = ShardLayout::new(total, 3);
        let a: Vec<u64> = (0..total as u64).collect();
        let b: Vec<u64> = (0..total as u64).map(|j| j * 100).collect();
        for workers in [1, 3] {
            let mut asm = asm(true, 3, workers);
            feed(&mut asm, 1, layout, 4, &a);
            // sender 2 streams only its first shard then stalls
            let (s0, l0) = layout.shard_range(0);
            asm.add_chunk(2, 0, s0 as u32, total as u32, &b[s0..s0 + l0]).unwrap();
            assert!(asm.spilled_bytes() > 0, "revocable commits spill to the rollback log");
            asm.purge(2).unwrap();
            assert_eq!(asm.complete_count(), 1);
            assert_eq!(
                asm.take_sum().unwrap().unwrap(),
                a,
                "purged sender must contribute nothing (workers={workers})"
            );
        }
    }

    #[test]
    fn purge_after_gap_rollback_subtracts_once() {
        let total = 16;
        let layout = ShardLayout::new(total, 2);
        let v: Vec<u64> = (1..=total as u64).collect();
        let mut asm = asm(true, 2, 1);
        let plan = chunk_plan(layout, 3);
        let send = |asm: &mut ChunkAssembler, c: Chunk| {
            asm.add_chunk(
                1,
                c.shard as u16,
                c.offset as u32,
                total as u32,
                &v[c.offset..c.offset + c.len],
            )
            .unwrap();
        };
        // commit two chunks, then a gap triggers the rollback...
        send(&mut asm, plan[0]);
        send(&mut asm, plan[1]);
        send(&mut asm, plan[3]);
        // ...and the later dropout purge must not subtract again
        asm.purge(1).unwrap();
        feed(&mut asm, 2, layout, 3, &v);
        assert_eq!(asm.take_sum().unwrap().unwrap(), v, "double rollback would corrupt the sum");
    }

    #[test]
    fn gap_marks_sender_bad_and_discards() {
        let total = 16;
        let layout = ShardLayout::new(total, 2);
        let v: Vec<u64> = (0..total as u64).collect();
        let mut asm = asm(true, 2, 1);
        let plan = chunk_plan(layout, 3);
        // drop the second chunk: offset skips ahead → bad stream
        let send = |asm: &mut ChunkAssembler, c: Chunk| {
            asm.add_chunk(
                1,
                c.shard as u16,
                c.offset as u32,
                total as u32,
                &v[c.offset..c.offset + c.len],
            )
            .unwrap();
        };
        send(&mut asm, plan[0]);
        send(&mut asm, plan[2]);
        assert_eq!(asm.complete_count(), 0);
        // the bad sender is silently ignored from here on
        send(&mut asm, plan[3]);
        assert_eq!(asm.complete_count(), 0);
        // a healthy sender still completes
        feed(&mut asm, 2, layout, 3, &v);
        asm.purge(1).unwrap();
        assert_eq!(asm.take_sum().unwrap().unwrap(), v);
    }

    #[test]
    fn malformed_chunks_error() {
        let mut asm = asm(false, 2, 1);
        // inconsistent total
        asm.add_chunk(1, 0, 0, 16, &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        assert!(asm.add_chunk(2, 0, 0, 20, &[1]).is_err());
        // out-of-range shard / offset
        assert!(asm.add_chunk(3, 9, 0, 16, &[1]).is_err());
        assert!(asm.add_chunk(3, 0, 99, 16, &[1]).is_err());
        // crossing a shard boundary (shard 0 = words 0..8)
        assert!(asm.add_chunk(3, 0, 6, 16, &[1, 2, 3]).is_err());
        // empty chunk
        assert!(asm.add_chunk(3, 0, 0, 16, &[]).is_err());
    }

    #[test]
    fn buffered_bytes_is_one_tensor_in_both_modes() {
        let total = 32;
        let layout = ShardLayout::new(total, 4);
        let v = vec![1u64; total];
        // base protocol: chunks commit on arrival — accumulators only
        let mut base = asm(false, 4, 1);
        assert_eq!(base.buffered_bytes(), 0, "nothing resident before the first chunk");
        feed(&mut base, 1, layout, 8, &v);
        assert_eq!(base.buffered_bytes(), (total * 8) as u64, "accumulators only");
        assert_eq!(base.spilled_bytes(), 0, "base protocol never spills");
        // revocable: same resident footprint; history goes to the log
        let mut rev = asm(true, 4, 1);
        feed(&mut rev, 1, layout, 8, &v);
        assert_eq!(rev.buffered_bytes(), (total * 8) as u64, "rollback state is not resident");
        // 4 chunks of 8 words: 4 · (10 + 64) log bytes
        assert_eq!(rev.spilled_bytes(), 4 * (10 + 64));
        // per-shard accounting tiles the tensor
        assert_eq!(rev.shard_buffered_bytes().iter().sum::<u64>(), (total * 8) as u64);
        // reset truncates the log
        rev.reset().unwrap();
        assert_eq!(rev.spilled_bytes(), 0);
        assert_eq!(rev.buffered_bytes(), 0);
    }

    #[test]
    fn shared_pool_slots_fold_concurrently_without_cross_talk() {
        // one pool, four assemblers — two fan-ins × two "rounds in
        // flight", exactly the aggregator's shape under the windowed
        // scheduler — fed interleaved, with different tensor lengths
        let pool = WorkerPool::new(3);
        let la = ShardLayout::new(37, 4);
        let lb = ShardLayout::new(24, 3);
        let rb = RollbackCfg::default();
        let mut asms: Vec<(ShardLayout, ChunkAssembler)> = vec![
            (la, ChunkAssembler::pooled(false, 4, rb, pool.client(), 10)),
            (lb, ChunkAssembler::pooled(false, 3, rb, pool.client(), 11)),
            (la, ChunkAssembler::pooled(true, 4, rb, pool.client(), 12)),
            (lb, ChunkAssembler::pooled(true, 3, rb, pool.client(), 13)),
        ];
        let tensor = |slot: u64, len: usize| -> Vec<u64> {
            (0..len as u64).map(|j| slot.wrapping_mul(1 << 32).wrapping_add(j)).collect()
        };
        // interleave the four streams chunk by chunk
        let plans: Vec<Vec<Chunk>> =
            asms.iter().map(|(l, _)| chunk_plan(*l, 5)).collect();
        let longest = plans.iter().map(Vec::len).max().unwrap();
        for i in 0..longest {
            for (s, ((layout, asm), plan)) in asms.iter_mut().zip(&plans).enumerate() {
                let Some(c) = plan.get(i) else { continue };
                let v = tensor(10 + s as u64, layout.total);
                asm.add_chunk(
                    7,
                    c.shard as u16,
                    c.offset as u32,
                    layout.total as u32,
                    &v[c.offset..c.offset + c.len],
                )
                .unwrap();
            }
        }
        for (s, (layout, asm)) in asms.iter_mut().enumerate() {
            assert_eq!(
                asm.take_sum().unwrap().unwrap(),
                tensor(10 + s as u64, layout.total),
                "slot {} must see only its own chunks",
                10 + s
            );
        }
    }

    #[test]
    fn snapshot_sum_is_non_consuming_and_tracks_purges() {
        let total = 24;
        let layout = ShardLayout::new(total, 3);
        let a: Vec<u64> = (0..total as u64).collect();
        let b: Vec<u64> = (0..total as u64).map(|j| j * 100).collect();
        let mut want_ab = vec![0u64; total];
        for (w, (x, y)) in want_ab.iter_mut().zip(a.iter().zip(&b)) {
            *w = x.wrapping_add(*y);
        }
        for workers in [1, 3] {
            let mut asm = asm(true, 3, workers);
            assert!(asm.snapshot_sum().unwrap().is_none(), "no traffic yet");
            feed(&mut asm, 1, layout, 4, &a);
            feed(&mut asm, 2, layout, 4, &b);
            assert_eq!(asm.snapshot_sum().unwrap().unwrap(), want_ab, "workers={workers}");
            // snapshotting consumed nothing: purge + re-snapshot works
            asm.purge(2).unwrap();
            assert_eq!(asm.snapshot_sum().unwrap().unwrap(), a, "corrected re-emission");
            // the consuming merge still agrees afterwards
            assert_eq!(asm.take_sum().unwrap().unwrap(), a);
        }
    }

    #[test]
    fn rollback_log_bound_is_a_typed_error() {
        let total = 16;
        let layout = ShardLayout::new(total, 2);
        let v: Vec<u64> = (0..total as u64).collect();
        // each 4-word chunk spills 10 + 32 bytes; allow exactly one
        let tight = RollbackCfg { fsync: false, max_bytes: 42 };
        let mut asm = ChunkAssembler::inline(true, 2, tight);
        let plan = chunk_plan(layout, 4);
        asm.add_chunk(1, 0, 0, total as u32, &v[..plan[0].len]).unwrap();
        let err = asm
            .add_chunk(1, plan[1].shard as u16, plan[1].offset as u32, total as u32, &v[4..8])
            .unwrap_err();
        match err.downcast_ref::<StreamError>() {
            Some(StreamError::RollbackLogFull { limit: 42, needed }) => {
                assert!(*needed > 42, "needed {needed}")
            }
            other => panic!("want RollbackLogFull, got {other:?}"),
        }
    }

    #[test]
    fn fsynced_log_replays_identically() {
        let total = 24;
        let layout = ShardLayout::new(total, 3);
        let v: Vec<u64> = (1..=total as u64).collect();
        let mut asm =
            ChunkAssembler::inline(true, 3, RollbackCfg { fsync: true, max_bytes: 1 << 20 });
        feed(&mut asm, 1, layout, 4, &v);
        feed(&mut asm, 2, layout, 4, &v);
        asm.purge(2).unwrap();
        assert_eq!(asm.take_sum().unwrap().unwrap(), v, "fsync must not change replay");
    }

    #[test]
    fn overhead_accounting_rule() {
        // monolithic: 11 + 8d; chunked: 22/chunk + 8d
        assert_eq!(chunk_count(100, 1, 100), 1);
        assert_eq!(chunk_overhead_bytes(100, 1, 100), 22 - 11);
        assert_eq!(chunk_count(100, 4, 10), 12, "4 shards of 25 → 3 chunks each");
        assert_eq!(chunk_overhead_bytes(100, 4, 10), 22 * 12 - 11);
        // downlink: 9 + 8d monolithic; 19/chunk + 8d chunked
        assert_eq!(grad_chunk_overhead_bytes(100, 1, 100), 19 - 9);
        assert_eq!(grad_chunk_overhead_bytes(100, 4, 10), 19 * 12 - 9);
    }
}
