//! The chunked streaming pipeline: shard layout, the sender-side chunk
//! plan, and the aggregator-side [`ChunkAssembler`].
//!
//! ## Memory model
//!
//! The monolithic fan-in buffers one full-length ℤ₂⁶⁴ vector per
//! sender until every live sender contributed — O(n·d) peak at the
//! aggregator. The streaming pipeline splits each tensor into
//! `shards` contiguous shards, streamed as chunks of ≤ `chunk_words`
//! words each, and the aggregator folds arriving chunks into one
//! per-sender *current-shard* buffer:
//!
//! * **Base protocol** (no dropout tolerance): a completed shard is
//!   committed into the single global accumulator immediately —
//!   ℤ₂⁶⁴ wrap-addition is order-independent, so early commitment is
//!   bit-identical to the monolithic sum. Peak memory is
//!   O(d + n · shard), the O(n·chunk + d) regime the streaming
//!   refactor exists for.
//! * **Dropout-tolerant runs** (`shamir_threshold` set): commitment is
//!   deferred — completed shards are *held per sender* until the whole
//!   fan-in completes, because a sender may be declared dropped at any
//!   time before the sum is consumed (even with a complete
//!   contribution buffered, e.g. when it fails to surrender shares)
//!   and the recovery math re-adds the dropped client's entire total
//!   mask, which is only sound if its data contributed nothing. Exact
//!   purge therefore requires per-sender separability until the sum —
//!   peak memory matches the monolithic path, and the chunked dropout
//!   run stays bit-identical to the zero-contribution twin.
//!
//! A sender whose chunk stream has a gap (a lost chunk under fault
//! injection) is marked bad, its buffered state discarded, and its
//! remaining chunks ignored: at the next quiescence probe it is
//! declared dropped (tolerant runs) or the round aborts as stalled
//! (base protocol — where nothing was committed for it only if the
//! run aborts anyway, which it does: an incomplete fan-in can never
//! complete without recovery).

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{bail, Result};

/// Chunking parameters, carried from [`RunConfig`](super::RunConfig)
/// into every party. `chunk_words: None` = the monolithic path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StreamCfg {
    /// Maximum ℤ₂⁶⁴ words per [`MaskedChunk`](super::messages::Msg)
    /// payload. `None` disables chunking entirely.
    pub chunk_words: Option<usize>,
    /// Shards per tensor (≥ 1). Only meaningful with `chunk_words`.
    pub shards: usize,
}

impl StreamCfg {
    pub fn monolithic() -> Self {
        StreamCfg { chunk_words: None, shards: 1 }
    }

    pub fn chunked(chunk_words: usize, shards: usize) -> Self {
        StreamCfg { chunk_words: Some(chunk_words), shards }
    }
}

/// Wire-header bytes of one `MaskedChunk` message: tag(1) + round(4) +
/// from(2) + tensor-tag(1) + shard(2) + offset(4) + total(4) +
/// word-count(4). The byte-accounting rule for Table 2 lives with the
/// [`Network`](crate::net::Network) counters; [`chunk_overhead_bytes`]
/// computes the exact delta.
pub const CHUNK_MSG_HEADER_BYTES: u64 = 22;

/// Wire-header bytes of a monolithic `MaskedActivation` /
/// `MaskedGradient`: tag(1) + round(4) + from(2) + word-count(4).
pub const MONO_MSG_HEADER_BYTES: u64 = 11;

/// How a tensor of `total` words is cut into `shards` contiguous
/// shards: the first `total % shards` shards get one extra word, so
/// shard sizes differ by at most one and every shard is non-empty
/// (requires `1 ≤ shards ≤ total`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardLayout {
    pub total: usize,
    pub shards: usize,
}

impl ShardLayout {
    pub fn new(total: usize, shards: usize) -> Self {
        assert!(shards >= 1, "shard count must be ≥ 1");
        assert!(shards <= total, "shard count {shards} exceeds tensor length {total}");
        ShardLayout { total, shards }
    }

    /// (start word, length) of shard `k`.
    pub fn shard_range(&self, k: usize) -> (usize, usize) {
        assert!(k < self.shards);
        let base = self.total / self.shards;
        let rem = self.total % self.shards;
        let start = k * base + k.min(rem);
        let len = base + usize::from(k < rem);
        (start, len)
    }

    /// The shard containing global word `w`.
    pub fn shard_of(&self, w: usize) -> usize {
        assert!(w < self.total);
        let base = self.total / self.shards;
        let rem = self.total % self.shards;
        let boundary = rem * (base + 1);
        if w < boundary {
            w / (base + 1)
        } else {
            rem + (w - boundary) / base
        }
    }
}

/// One planned chunk: shard index, global word offset, word count.
/// Chunks never cross a shard boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Chunk {
    pub shard: usize,
    pub offset: usize,
    pub len: usize,
}

/// The chunk sequence for one tensor: shards in order, each cut into
/// `chunk_words`-sized chunks (the last chunk of a shard may be
/// shorter).
pub fn chunk_plan(layout: ShardLayout, chunk_words: usize) -> Vec<Chunk> {
    assert!(chunk_words >= 1, "chunk size must be ≥ 1");
    let mut plan = Vec::new();
    for k in 0..layout.shards {
        let (start, len) = layout.shard_range(k);
        let mut off = 0;
        while off < len {
            let n = chunk_words.min(len - off);
            plan.push(Chunk { shard: k, offset: start + off, len: n });
            off += n;
        }
    }
    plan
}

/// Number of chunk messages one tensor of `total` words becomes.
pub fn chunk_count(total: usize, shards: usize, chunk_words: usize) -> u64 {
    let layout = ShardLayout::new(total, shards);
    (0..shards)
        .map(|k| {
            let (_, len) = layout.shard_range(k);
            len.div_ceil(chunk_words) as u64
        })
        .sum()
}

/// The exact Table-2 byte delta of sending one `total`-word tensor
/// chunked instead of monolithic: both carry `8 · total` payload
/// bytes, the monolithic message adds one 11-byte header, the chunked
/// stream one 22-byte header per chunk.
pub fn chunk_overhead_bytes(total: usize, shards: usize, chunk_words: usize) -> u64 {
    CHUNK_MSG_HEADER_BYTES * chunk_count(total, shards, chunk_words) - MONO_MSG_HEADER_BYTES
}

// ---------------------------------------------------------------------------
// Aggregator-side assembly
// ---------------------------------------------------------------------------

/// Per-sender assembly state.
struct SenderState {
    /// Next expected global word (chunks ride per-sender FIFO order).
    cursor: usize,
    /// Current shard index.
    shard: usize,
    /// Partial sum of the current shard (filled front to back).
    buf: Vec<u64>,
    /// Completed shards awaiting fan-in completion (revocable mode
    /// only): (shard start, words).
    held: Vec<(usize, Vec<u64>)>,
}

/// Folds one fan-in's `MaskedChunk` stream into a single global
/// accumulator, with per-sender shard staging (see the module docs for
/// the memory model and the revocable/commit split).
pub struct ChunkAssembler {
    /// Deferred commitment for exact dropout purge (threshold set).
    revocable: bool,
    shards: usize,
    layout: Option<ShardLayout>,
    global: Vec<u64>,
    senders: BTreeMap<u16, SenderState>,
    complete: BTreeSet<u16>,
    /// Senders whose stream broke (gap/overlap): state discarded,
    /// further chunks ignored until the next round reset.
    bad: BTreeSet<u16>,
}

impl ChunkAssembler {
    pub fn new(revocable: bool, shards: usize) -> Self {
        assert!(shards >= 1);
        ChunkAssembler {
            revocable,
            shards,
            layout: None,
            global: Vec::new(),
            senders: BTreeMap::new(),
            complete: BTreeSet::new(),
            bad: BTreeSet::new(),
        }
    }

    /// Reset for a new round.
    pub fn reset(&mut self) {
        self.layout = None;
        self.global = Vec::new();
        self.senders.clear();
        self.complete.clear();
        self.bad.clear();
    }

    fn wrap_add_at(dst: &mut [u64], at: usize, src: &[u64]) {
        for (d, s) in dst[at..at + src.len()].iter_mut().zip(src) {
            *d = d.wrapping_add(*s);
        }
    }

    /// Fold one chunk in. A malformed *message* (inconsistent total,
    /// shard/offset outside the layout) is a protocol error and fails
    /// the run; a *gap* in an otherwise well-formed per-sender stream
    /// is a lost message — the sender is marked bad and silently
    /// ignored so quiescence-based dropout declaration can handle it.
    pub fn add_chunk(
        &mut self,
        from: u16,
        shard: u16,
        offset: u32,
        total: u32,
        words: &[u64],
    ) -> Result<()> {
        if self.bad.contains(&from) {
            return Ok(());
        }
        let total = total as usize;
        if total == 0 || words.is_empty() {
            bail!("empty masked chunk from client {from}");
        }
        let layout = match self.layout {
            Some(l) => {
                if l.total != total {
                    bail!("chunk total {total} from client {from} != fan-in total {}", l.total);
                }
                l
            }
            None => {
                if self.shards > total {
                    bail!("{} shards exceed tensor length {total}", self.shards);
                }
                let l = ShardLayout::new(total, self.shards);
                self.layout = Some(l);
                self.global = vec![0u64; total];
                l
            }
        };
        let offset = offset as usize;
        let (shard, offset_ok) = {
            let s = shard as usize;
            if s >= layout.shards || offset >= total {
                bail!("chunk shard {s}/offset {offset} out of range from client {from}");
            }
            let (start, len) = layout.shard_range(s);
            (s, offset >= start && offset + words.len() <= start + len)
        };
        if !offset_ok {
            bail!("chunk crosses shard boundary (client {from}, shard {shard}, offset {offset})");
        }
        if self.complete.contains(&from) {
            bail!("chunk after completed stream from client {from}");
        }

        let cursor = self.senders.get(&from).map(|s| s.cursor).unwrap_or(0);
        if offset != cursor || shard != layout.shard_of(cursor) {
            // a hole in the stream (lost chunk): discard and let
            // dropout handling (or a stalled-round abort) take over
            self.senders.remove(&from);
            self.bad.insert(from);
            return Ok(());
        }
        let (shard_start, shard_len) = layout.shard_range(shard);
        let (finished_shard, finished_sender) = {
            let st = self.senders.entry(from).or_insert_with(|| SenderState {
                cursor: 0,
                shard: 0,
                buf: Vec::new(),
                held: Vec::new(),
            });
            if st.buf.is_empty() {
                st.buf = vec![0u64; shard_len];
                st.shard = shard;
            }
            Self::wrap_add_at(&mut st.buf, st.cursor - shard_start, words);
            st.cursor += words.len();
            let fs = if st.cursor == shard_start + shard_len {
                // shard complete: commit now (base protocol) or hold
                // for the fan-in barrier (revocable mode)
                Some(std::mem::take(&mut st.buf))
            } else {
                None
            };
            (fs, st.cursor == total)
        };
        if let Some(buf) = finished_shard {
            if self.revocable {
                self.senders.get_mut(&from).expect("sender state").held.push((shard_start, buf));
            } else {
                Self::wrap_add_at(&mut self.global, shard_start, &buf);
            }
        }
        if finished_sender {
            self.complete.insert(from);
            if !self.revocable {
                self.senders.remove(&from);
            }
        }
        Ok(())
    }

    /// Senders whose whole tensor arrived.
    pub fn complete_count(&self) -> usize {
        self.complete.len()
    }

    pub fn complete_senders(&self) -> impl Iterator<Item = u16> + '_ {
        self.complete.iter().copied()
    }

    /// Discard everything a (declared-dropped) sender buffered. In
    /// revocable mode this removes its *entire* contribution — the
    /// invariant the recovery mask-correction relies on. Only reachable
    /// in revocable mode: the base protocol never declares dropouts.
    pub fn purge(&mut self, from: u16) {
        debug_assert!(
            self.revocable || !self.complete.contains(&from),
            "purging a committed sender from a non-revocable assembler"
        );
        self.senders.remove(&from);
        self.complete.remove(&from);
        self.bad.remove(&from);
    }

    /// Consume the fan-in: fold every held shard (sender order, though
    /// ℤ₂⁶⁴ addition makes the order immaterial) and hand back the
    /// accumulated sum. `None` when no chunk traffic arrived (the
    /// monolithic or float path carried this round).
    pub fn take_sum(&mut self) -> Option<Vec<u64>> {
        self.layout?;
        let mut global = std::mem::take(&mut self.global);
        for (_, st) in std::mem::take(&mut self.senders) {
            debug_assert!(st.buf.is_empty(), "consuming a fan-in with an incomplete shard");
            for (start, buf) in st.held {
                Self::wrap_add_at(&mut global, start, &buf);
            }
        }
        self.reset();
        Some(global)
    }

    /// Bytes currently buffered across the global accumulator, shard
    /// buffers, and held shards — the quantity behind the streaming
    /// pipeline's peak-memory claim (metered into
    /// [`Metrics`](super::Metrics) by the aggregator).
    pub fn buffered_bytes(&self) -> u64 {
        let sender_words: usize = self
            .senders
            .values()
            .map(|s| s.buf.len() + s.held.iter().map(|(_, h)| h.len()).sum::<usize>())
            .sum();
        ((self.global.len() + sender_words) * 8) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_layout_tiles_exactly() {
        for (total, shards) in [(10, 1), (10, 3), (10, 10), (16384, 7), (5184, 4), (3, 2)] {
            let l = ShardLayout::new(total, shards);
            let mut covered = 0usize;
            for k in 0..shards {
                let (start, len) = l.shard_range(k);
                assert_eq!(start, covered, "shards must be contiguous");
                assert!(len >= 1, "every shard non-empty");
                for w in start..start + len {
                    assert_eq!(l.shard_of(w), k, "total={total} shards={shards} w={w}");
                }
                covered += len;
            }
            assert_eq!(covered, total);
        }
    }

    #[test]
    #[should_panic]
    fn more_shards_than_words_rejected() {
        ShardLayout::new(3, 4);
    }

    #[test]
    fn chunk_plan_covers_tensor_within_shards() {
        for (total, shards, cw) in [(100, 1, 7), (100, 3, 7), (100, 3, 1000), (7, 7, 2)] {
            let layout = ShardLayout::new(total, shards);
            let plan = chunk_plan(layout, cw);
            assert_eq!(plan.len() as u64, chunk_count(total, shards, cw));
            let mut cursor = 0usize;
            for c in &plan {
                assert_eq!(c.offset, cursor, "chunks in stream order");
                assert!(c.len <= cw);
                let (start, len) = layout.shard_range(c.shard);
                assert!(c.offset >= start && c.offset + c.len <= start + len, "within shard");
                cursor += c.len;
            }
            assert_eq!(cursor, total);
        }
    }

    fn feed(asm: &mut ChunkAssembler, from: u16, layout: ShardLayout, cw: usize, vals: &[u64]) {
        for c in chunk_plan(layout, cw) {
            asm.add_chunk(
                from,
                c.shard as u16,
                c.offset as u32,
                layout.total as u32,
                &vals[c.offset..c.offset + c.len],
            )
            .unwrap();
        }
    }

    #[test]
    fn assembler_sums_match_direct_sum_both_modes() {
        let total = 37;
        let layout = ShardLayout::new(total, 4);
        let tensors: Vec<Vec<u64>> = (0..3u64)
            .map(|i| (0..total as u64).map(|j| i.wrapping_mul(1 << 40).wrapping_add(j)).collect())
            .collect();
        let mut want = vec![0u64; total];
        for t in &tensors {
            for (w, v) in want.iter_mut().zip(t) {
                *w = w.wrapping_add(*v);
            }
        }
        for revocable in [false, true] {
            let mut asm = ChunkAssembler::new(revocable, 4);
            for (i, t) in tensors.iter().enumerate() {
                feed(&mut asm, i as u16, layout, 5, t);
            }
            assert_eq!(asm.complete_count(), 3);
            assert_eq!(asm.take_sum().unwrap(), want, "revocable={revocable}");
            assert!(asm.take_sum().is_none(), "take_sum resets");
        }
    }

    #[test]
    fn revocable_purge_removes_whole_contribution() {
        let total = 24;
        let layout = ShardLayout::new(total, 3);
        let a: Vec<u64> = (0..total as u64).collect();
        let b: Vec<u64> = (0..total as u64).map(|j| j * 100).collect();
        let mut asm = ChunkAssembler::new(true, 3);
        feed(&mut asm, 1, layout, 4, &a);
        // sender 2 streams only its first shard then stalls
        let (s0, l0) = layout.shard_range(0);
        asm.add_chunk(2, 0, s0 as u32, total as u32, &b[s0..s0 + l0]).unwrap();
        asm.purge(2);
        assert_eq!(asm.complete_count(), 1);
        assert_eq!(asm.take_sum().unwrap(), a, "purged sender must contribute nothing");
    }

    #[test]
    fn gap_marks_sender_bad_and_discards() {
        let total = 16;
        let layout = ShardLayout::new(total, 2);
        let v: Vec<u64> = (0..total as u64).collect();
        let mut asm = ChunkAssembler::new(true, 2);
        let plan = chunk_plan(layout, 3);
        // drop the second chunk: offset skips ahead → bad stream
        let send = |asm: &mut ChunkAssembler, c: Chunk| {
            asm.add_chunk(
                1,
                c.shard as u16,
                c.offset as u32,
                total as u32,
                &v[c.offset..c.offset + c.len],
            )
            .unwrap();
        };
        send(&mut asm, plan[0]);
        send(&mut asm, plan[2]);
        assert_eq!(asm.complete_count(), 0);
        // the bad sender is silently ignored from here on
        send(&mut asm, plan[3]);
        assert_eq!(asm.complete_count(), 0);
        // a healthy sender still completes
        feed(&mut asm, 2, layout, 3, &v);
        asm.purge(1);
        assert_eq!(asm.take_sum().unwrap(), v);
    }

    #[test]
    fn malformed_chunks_error() {
        let mut asm = ChunkAssembler::new(false, 2);
        // inconsistent total
        asm.add_chunk(1, 0, 0, 16, &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        assert!(asm.add_chunk(2, 0, 0, 20, &[1]).is_err());
        // out-of-range shard / offset
        assert!(asm.add_chunk(3, 9, 0, 16, &[1]).is_err());
        assert!(asm.add_chunk(3, 0, 99, 16, &[1]).is_err());
        // crossing a shard boundary (shard 0 = words 0..8)
        assert!(asm.add_chunk(3, 0, 6, 16, &[1, 2, 3]).is_err());
        // empty chunk
        assert!(asm.add_chunk(3, 0, 0, 16, &[]).is_err());
    }

    #[test]
    fn buffered_bytes_tracks_held_state() {
        let total = 32;
        let layout = ShardLayout::new(total, 4);
        let v = vec![1u64; total];
        // base protocol: commit-on-shard keeps only global + in-flight
        let mut base = ChunkAssembler::new(false, 4);
        feed(&mut base, 1, layout, 8, &v);
        assert_eq!(base.buffered_bytes(), (total * 8) as u64, "global only");
        // revocable: held shards stay per sender
        let mut rev = ChunkAssembler::new(true, 4);
        feed(&mut rev, 1, layout, 8, &v);
        assert_eq!(rev.buffered_bytes(), (2 * total * 8) as u64, "global + held");
    }

    #[test]
    fn overhead_accounting_rule() {
        // monolithic: 11 + 8d; chunked: 22/chunk + 8d
        assert_eq!(chunk_count(100, 1, 100), 1);
        assert_eq!(chunk_overhead_bytes(100, 1, 100), 22 - 11);
        assert_eq!(chunk_count(100, 4, 10), 12, "4 shards of 25 → 3 chunks each");
        assert_eq!(chunk_overhead_bytes(100, 4, 10), 22 * 12 - 11);
    }
}
