//! Run configuration for a VFL experiment.

use crate::model::ModelConfig;
use crate::net::FaultPlan;

/// How activations/gradients are protected in transit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SecurityMode {
    /// Bonawitz-style pairwise masks in ℤ₂⁶⁴ over fixed-point encodings
    /// (exact cancellation) + AEAD-sealed sample IDs. The default.
    SecureExact,
    /// Pairwise float masks (exact payload-size parity with the
    /// unsecured baseline; cancellation up to float addition order).
    SecureFloat,
    /// Unsecured VFL: plaintext IDs and tensors — the baseline the
    /// paper's "overhead" columns are measured against.
    Plain,
}

impl SecurityMode {
    pub fn is_secure(&self) -> bool {
        !matches!(self, SecurityMode::Plain)
    }
}

/// Which compute engine the parties use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT-compiled HLO artifacts on the PJRT CPU client (production).
    Pjrt,
    /// Pure-Rust reference math (tests / artifact-less runs).
    Reference,
}

/// Which transport carries the protocol messages.
///
/// All of them run the identical [`Party`](super::party::Party)
/// machines and produce bit-identical reports; they differ only in who
/// schedules the work. (Cross-process TCP runs use `vfl-sa
/// serve`/`join`, which split one party set across processes instead
/// of configuring it here.)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// Single-threaded deterministic simulation with exact byte
    /// metering — the paper's measurement setup. The default.
    Sim,
    /// One OS thread per party, channels in between.
    Threaded,
    /// Real localhost sockets multiplexed on readiness-driven
    /// event-loop threads (`--evloop`; unix only). The aggregator runs
    /// the nonblocking `net::evloop` server — one poller loop by
    /// default, or `--evloop-threads K` token-sharded loops behind one
    /// acceptor — while each client keeps one lightweight socket
    /// thread. The C10K-capable path.
    Evloop,
}

/// A full experiment configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: ModelConfig,
    /// Rows of synthetic data to generate.
    pub n_rows: usize,
    /// Training rounds (mini-batch steps). Paper's tables: 5.
    pub train_rounds: usize,
    /// Testing-phase batches to run. Paper's tables: per test pass.
    pub test_rounds: usize,
    pub security: SecurityMode,
    pub backend: BackendKind,
    pub transport: TransportKind,
    /// RNG seed for data, init, and key generation.
    pub seed: u64,
    /// Enable Bonawitz-style dropout tolerance with this Shamir
    /// threshold t: every client's mask seed is t-of-n shared during
    /// setup, and a round recovers whenever ≥ t clients survive.
    /// Requires [`SecurityMode::SecureExact`]. None = base protocol
    /// (a mid-round drop stalls the run).
    pub shamir_threshold: Option<usize>,
    /// Deterministic fault-injection plan (tests and the
    /// `--dropout-schedule` CLI flag). None = no injected faults.
    pub fault_plan: Option<FaultPlan>,
    /// Override the timeout-based transports' dropout-detection
    /// *floor* in milliseconds (None = the transport default, 500 ms).
    /// Tests shrink it so crash-recovery suites don't sleep through
    /// full windows. The effective window adapts upward from this
    /// floor via an EWMA of observed inter-event gaps.
    pub stall_timeout_ms: Option<u64>,
    /// Cap on the adaptive dropout-detection window in milliseconds
    /// (None = the transport default, 10 s): however slow the observed
    /// rounds, a silent peer is declared within this bound.
    pub stall_cap_ms: Option<u64>,
    /// Streaming pipeline: maximum ℤ₂⁶⁴ words per masked-tensor chunk
    /// (`--chunk-words`). None = monolithic masked messages. Requires
    /// [`SecurityMode::SecureExact`] — only ℤ₂⁶⁴ sums are
    /// order-independent, which is what keeps a chunked run
    /// bit-identical to a monolithic one.
    pub chunk_words: Option<usize>,
    /// Streaming pipeline: shards per masked tensor (`--shards`, ≥ 1).
    /// Every validated chunk folds into its shard's accumulator on
    /// arrival. Only meaningful with `chunk_words`.
    pub shards: usize,
    /// Shard-parallel aggregation (`--agg-workers`, ≥ 1): the number
    /// of accumulator workers in the aggregator's one shared
    /// [`WorkerPool`](super::streaming::WorkerPool), which every
    /// chunked fan-in — acts and grads, across all rounds in flight —
    /// distributes its shards across (capped at the shard count).
    /// 1 = the inline sequential path, no threads. Any worker count
    /// produces bit-identical reports — ℤ₂⁶⁴ wrap-addition commutes
    /// and the merge stitches disjoint shard ranges. Only meaningful
    /// with `chunk_words`.
    pub agg_workers: usize,
    /// Parallel mask expansion (`--expand-workers`, ≥ 1): the number
    /// of workers in each party's
    /// [`ExpandPool`](crate::crypto::prg::ExpandPool). Tensor windows
    /// are partitioned into disjoint sub-windows, expanded/masked in
    /// parallel through the seekable PRG, and stitched in offset
    /// order — bit-identical to serial for any worker count by the
    /// window-partition property. 1 = the inline serial path, no
    /// threads. Unlike `agg_workers`, meaningful with and without
    /// chunking (it also drives the aggregator's dropout total-mask
    /// correction).
    pub expand_workers: usize,
    /// Windowed round scheduler (`--rounds-in-flight`, ≥ 1): how many
    /// protocol rounds may be in flight simultaneously. 1 = the
    /// strictly serial pre-pipeline behavior. Any width produces
    /// bit-identical reports and Table-2 counters: rounds start in
    /// schedule order, setup/rotation rounds and phase boundaries act
    /// as barriers, and the window drains to 1 at the first dropout
    /// declaration (see [`RoundWindow`](super::window::RoundWindow)).
    pub rounds_in_flight: usize,
    /// Rollback-log durability (`--rollback-fsync`): fsync every
    /// record appended to a dropout-tolerant chunked run's rollback
    /// log. Off by default — the log is a purge aid, not a journal.
    pub rollback_fsync: bool,
    /// Rollback-log bound (`--rollback-max-bytes`): cap one rollback
    /// log's size, failing the run with the typed
    /// [`StreamError::RollbackLogFull`](super::streaming::StreamError)
    /// instead of unbounded temp-file growth. `None` = the default cap
    /// ([`DEFAULT_ROLLBACK_MAX_BYTES`](super::streaming::DEFAULT_ROLLBACK_MAX_BYTES)).
    pub rollback_max_bytes: Option<u64>,
    /// Sharded event loop (`--evloop-threads`, ≥ 1; Evloop transport
    /// only): how many poller threads the aggregator-side event loop
    /// runs. 1 = today's single-loop `serve_on`, byte-identical. K > 1
    /// accepts on a dedicated acceptor thread and hands sockets to K
    /// loops round-robin; each loop owns its connections' buffers
    /// exclusively (no locks on the read/write path), protocol events
    /// funnel to the one round-window driver, and peak metrics
    /// max-merge across loops. Any K produces bit-identical reports.
    pub evloop_threads: usize,
    /// Hierarchical fan-in tree (`--leaves L`): partition the clients
    /// into L contiguous shards, each owned by a
    /// [`LeafAggregator`](super::topology::LeafAggregator) that folds
    /// its shard's masked fan-in into a partial ℤ₂⁶⁴ sum and forwards
    /// one [`Msg::PartialSum`](super::messages::Msg) per (round, tag)
    /// to the root — per-node fan-in drops from O(n·d) to
    /// O((n/L)·d + L·d). Requires [`SecurityMode::SecureExact`] (only
    /// ℤ₂⁶⁴ sums are order-independent, and a float partial would
    /// change addition order). `None` = the flat single-aggregator
    /// topology. Any L produces bit-identical reports and Table-2
    /// counters: a leaf partial stays masked by every cross-shard
    /// pairwise term, so the tree changes *where* words are added,
    /// never *what* is added.
    pub leaves: Option<usize>,
}

impl RunConfig {
    /// The paper's experimental setup for a dataset (§6.3): batch 256,
    /// lr 0.01, key rotation every 5 rounds, 5 training rounds.
    pub fn paper(dataset: &str) -> Option<RunConfig> {
        let model = ModelConfig::for_dataset(dataset)?;
        Some(RunConfig {
            model,
            n_rows: 4096,
            train_rounds: 5,
            test_rounds: 1,
            security: SecurityMode::SecureExact,
            backend: BackendKind::Pjrt,
            transport: TransportKind::Sim,
            seed: 7,
            shamir_threshold: None,
            fault_plan: None,
            stall_timeout_ms: None,
            stall_cap_ms: None,
            chunk_words: None,
            shards: 1,
            agg_workers: 1,
            expand_workers: 1,
            rounds_in_flight: 1,
            rollback_fsync: false,
            rollback_max_bytes: None,
            evloop_threads: 1,
            leaves: None,
        })
    }

    /// Small/fast configuration for tests.
    pub fn test(dataset: &str) -> Option<RunConfig> {
        let mut cfg = Self::paper(dataset)?;
        cfg.n_rows = 2048;
        cfg.backend = BackendKind::Reference;
        Some(cfg)
    }
}
