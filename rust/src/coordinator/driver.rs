//! The driver: builds the party set, precomputes the round schedule,
//! and pumps it through whichever [`Transport`] the run configures.
//!
//! This is all that remains of the old ~600-line hand-threaded
//! orchestrator: protocol logic lives in the [`Party`] machines
//! ([`parties`](super::parties)), message routing in the transports
//! ([`net`](crate::net)). The driver only decides *what* rounds happen
//! (setup → training with §5.1 key rotation → testing) and assembles a
//! [`RunReport`] from the notes the parties emit.
//!
//! The schedule is fully static: batch ids are a deterministic
//! function of the seed, so the same `RunConfig` yields the same
//! schedule in every process — which is what lets `vfl-sa serve` and
//! `vfl-sa join` agree on the experiment without exchanging it.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::data::{by_name, generate, partition};
use crate::model::ModelParams;
use crate::net::{FaultyTransport, Network, Phase, SimTransport, ThreadedTransport, Transport};
use crate::runtime::Engine;

use super::backend::Backend;
use super::config::{BackendKind, RunConfig, SecurityMode, TransportKind};
use super::metrics::Metrics;
use super::parties::{ActiveParty, Aggregator, GradLayout, PassiveParty};
use super::party::{Note, Party, RoundKind, RoundSpec, SETUP_ROUND};
use super::streaming::{RollbackCfg, StreamCfg, DEFAULT_ROLLBACK_MAX_BYTES};
use super::topology::{validate_topology, TreeAggregator};
use super::window::MAX_ROUNDS_IN_FLIGHT;

/// Everything a run produces.
pub struct RunReport {
    pub losses: Vec<f32>,
    /// Test-set accuracy (threshold 0.5).
    pub test_accuracy: f64,
    /// Test-phase predictions (for equivalence checks).
    pub predictions: Vec<f32>,
    /// Ground-truth labels aligned with `predictions` (for metrics).
    pub prediction_labels: Vec<f32>,
    pub final_params: ModelParams,
    pub metrics: Metrics,
    pub net: Network,
    /// Number of setup phases executed (1 + rotations).
    pub setups: usize,
}

/// A wired party set plus the static round schedule — ready for any
/// transport (or for `serve`/`join` to split across processes).
pub struct Built<'e> {
    /// Indexed by node: `[aggregator, client 0 (active), client 1, …]`.
    pub parties: Vec<Box<dyn Party + 'e>>,
    pub schedule: Vec<RoundSpec>,
    pub test_labels: HashMap<u64, f32>,
    /// Setup phases the schedule will execute (initial + rotations).
    pub setups: usize,
}

/// Validate the streaming flags against the run shape and produce the
/// per-party [`StreamCfg`]. Rejecting here means `--chunk-words 0`,
/// `--shards 0`, `--agg-workers 0`, or shard/worker counts exceeding
/// their caps fail at configuration time with a clear error instead of
/// panicking mid-round.
pub fn validate_streaming(cfg: &RunConfig) -> Result<StreamCfg> {
    if cfg.shards == 0 {
        bail!("--shards 0 is invalid (need at least 1 shard)");
    }
    if cfg.agg_workers == 0 {
        bail!("--agg-workers 0 is invalid (need at least 1 aggregation worker)");
    }
    if cfg.agg_workers > MAX_AGG_WORKERS {
        bail!("--agg-workers {} exceeds the cap ({MAX_AGG_WORKERS})", cfg.agg_workers);
    }
    if cfg.expand_workers == 0 {
        bail!("--expand-workers 0 is invalid (need at least 1 expansion worker)");
    }
    if cfg.expand_workers > MAX_EXPAND_WORKERS {
        bail!("--expand-workers {} exceeds the cap ({MAX_EXPAND_WORKERS})", cfg.expand_workers);
    }
    if cfg.rollback_max_bytes == Some(0) {
        bail!("--rollback-max-bytes 0 is invalid (a zero-byte rollback log cannot record \
               any committed chunk; omit the flag for the default bound)");
    }
    if (cfg.rollback_fsync || cfg.rollback_max_bytes.is_some())
        && (cfg.chunk_words.is_none() || cfg.shamir_threshold.is_none())
    {
        bail!(
            "--rollback-fsync / --rollback-max-bytes require --chunk-words and \
             --shamir-threshold (only dropout-tolerant chunked runs keep a rollback log; \
             accepting the knobs elsewhere would fake durability that is never in force)"
        );
    }
    let rollback = RollbackCfg {
        fsync: cfg.rollback_fsync,
        max_bytes: cfg.rollback_max_bytes.unwrap_or(DEFAULT_ROLLBACK_MAX_BYTES),
    };
    let Some(cw) = cfg.chunk_words else {
        if cfg.shards != 1 {
            bail!(
                "--shards {} requires --chunk-words (sharding only applies to the chunked \
                 streaming pipeline)",
                cfg.shards
            );
        }
        if cfg.agg_workers != 1 {
            bail!(
                "--agg-workers {} requires --chunk-words (only chunked fan-ins are \
                 shard-structured, so only they can be folded in parallel)",
                cfg.agg_workers
            );
        }
        return Ok(StreamCfg::monolithic()
            .with_expand_workers(cfg.expand_workers)
            .with_rollback(rollback));
    };
    if cw == 0 {
        bail!("--chunk-words 0 is invalid (need at least 1 word per chunk)");
    }
    if cfg.security != SecurityMode::SecureExact {
        bail!(
            "--chunk-words requires SecureExact: only Z_2^64 sums are order-independent, \
             which is what keeps a chunked run bit-identical to a monolithic one"
        );
    }
    if cfg.shards > u16::MAX as usize {
        bail!("--shards {} exceeds the wire limit ({})", cfg.shards, u16::MAX);
    }
    // both masked fan-in tensors must accommodate the shard count
    let act_len = cfg.model.batch_size * cfg.model.hidden;
    let grad_len = GradLayout::new(&cfg.model).total;
    let min_len = act_len.min(grad_len);
    if cfg.shards > min_len {
        bail!(
            "--shards {} exceeds the smallest masked tensor length {min_len} \
             (activation {act_len} words, gradient {grad_len} words)",
            cfg.shards
        );
    }
    Ok(StreamCfg::chunked(cw, cfg.shards)
        .with_workers(cfg.agg_workers)
        .with_expand_workers(cfg.expand_workers)
        .with_rollback(rollback))
}

/// Validate the windowed-scheduler knob. A zero window could never
/// start a round (instant deadlock), and an absurd width would keep an
/// unbounded ring of per-round contexts alive; both fail at
/// configuration time.
pub fn validate_window(cfg: &RunConfig) -> Result<()> {
    if cfg.rounds_in_flight == 0 {
        bail!("--rounds-in-flight 0 is invalid (the scheduler needs at least one live round)");
    }
    if cfg.rounds_in_flight > MAX_ROUNDS_IN_FLIGHT {
        bail!(
            "--rounds-in-flight {} exceeds the cap ({MAX_ROUNDS_IN_FLIGHT})",
            cfg.rounds_in_flight
        );
    }
    Ok(())
}

/// Hard cap on `--agg-workers`: far above any sensible shard fan-out,
/// low enough that a typo cannot spawn thousands of OS threads.
pub const MAX_AGG_WORKERS: usize = 256;

/// Hard cap on `--expand-workers`: far above any core count the mask
/// expansion could saturate, low enough that a typo cannot spawn
/// thousands of OS threads per party.
pub const MAX_EXPAND_WORKERS: usize = 64;

/// Hard cap on `--evloop-threads`: one poller thread per core is
/// already generous; a typo must not spawn thousands of loops.
pub const MAX_EVLOOP_THREADS: usize = 64;

/// Validate the sharded-event-loop knob. Zero loops could never poll a
/// socket, and an absurd count would spawn a thread per typo'd digit;
/// both fail at configuration time. The knob is inert (but harmless)
/// on the Sim/Threaded transports, mirroring how `--stall-timeout-ms`
/// behaves, so no transport cross-check is enforced here.
pub fn validate_evloop(cfg: &RunConfig) -> Result<()> {
    if cfg.evloop_threads == 0 {
        bail!("--evloop-threads 0 is invalid (the event loop needs at least one poller thread)");
    }
    if cfg.evloop_threads > MAX_EVLOOP_THREADS {
        bail!(
            "--evloop-threads {} exceeds the cap ({MAX_EVLOOP_THREADS})",
            cfg.evloop_threads
        );
    }
    Ok(())
}

/// Validate the dropout-detection timing knobs. A zero floor or cap
/// would produce a zero-width quiescence window that instantly
/// declares every peer stalled (a busy-spin dropout storm on the
/// timeout-based transports), so both are rejected at configuration
/// time; [`StallClock::new`](crate::net::StallClock) additionally
/// clamps as defense in depth.
pub fn validate_timing(cfg: &RunConfig) -> Result<()> {
    if cfg.stall_timeout_ms == Some(0) {
        bail!("--stall-timeout-ms 0 is invalid (a zero-width quiescence window declares every \
               peer stalled instantly)");
    }
    if cfg.stall_cap_ms == Some(0) {
        bail!("--stall-cap-ms 0 is invalid (the adaptive window cap must be positive)");
    }
    Ok(())
}

/// Generate data, partition it, wire up all parties, and lay out the
/// round schedule.
pub fn build<'e>(cfg: &RunConfig, engine: Option<&'e Engine>) -> Result<Built<'e>> {
    let backend = match cfg.backend {
        BackendKind::Reference => Backend::Reference,
        BackendKind::Pjrt => {
            Backend::Pjrt(engine.context("PJRT backend requires a loaded Engine")?)
        }
    };
    if let Some(t) = cfg.shamir_threshold {
        if cfg.security != SecurityMode::SecureExact {
            bail!("shamir threshold requires SecureExact (recovery needs exact Z_2^64 masks)");
        }
        let n = cfg.model.n_clients();
        if t < 2 || t > n {
            bail!("shamir threshold {t} out of range (need 2 ≤ t ≤ {n} clients)");
        }
    }
    let stream = validate_streaming(cfg)?;
    validate_timing(cfg)?;
    validate_window(cfg)?;
    validate_evloop(cfg)?;
    let leaves = validate_topology(cfg)?;
    let (schema, spec, _) = by_name(&cfg.model.dataset).context("unknown dataset")?;
    let data = generate(&schema, cfg.n_rows, cfg.seed);
    let mut vertical = partition(&data, &spec);
    vertical.passives.sort_by_key(|p| p.party_id);

    // blank parties (the crash twin used by the recovery equivalence
    // tests): feature rows zeroed, protocol participation unchanged
    if let Some(plan) = &cfg.fault_plan {
        for &client in &plan.blanks {
            let p = vertical
                .passives
                .iter_mut()
                .find(|p| p.party_id + 1 == client)
                .with_context(|| format!("blank client {client} is not a passive party"))?;
            for row in p.rows.values_mut() {
                row.iter_mut().for_each(|v| *v = 0.0);
            }
        }
    }

    let batch = cfg.model.batch_size;
    let n_train = ((cfg.n_rows as f32) * 0.8) as usize;
    if n_train < batch || cfg.n_rows - n_train < batch {
        bail!("need ≥ {batch} rows in both train and test splits");
    }
    let train_ids = data.ids[..n_train].to_vec();
    let test_ids = data.ids[n_train..].to_vec();
    let test_labels: HashMap<u64, f32> = data.ids[n_train..]
        .iter()
        .zip(&data.labels[n_train..])
        .map(|(&i, &l)| (i, l))
        .collect();

    // holder maps: per group, id → client index of the holding party
    let holders: Vec<HashMap<u64, usize>> = (0..spec.groups.len())
        .map(|g| {
            let mut m = HashMap::new();
            for p in vertical.passives.iter().filter(|p| p.group == g) {
                for &id in p.rows.keys() {
                    m.insert(id, p.party_id + 1); // client idx (active = 0)
                }
            }
            m
        })
        .collect();
    let groups: Vec<usize> = vertical.passives.iter().map(|p| p.group).collect();

    let threshold = cfg.shamir_threshold;
    let mut parties: Vec<Box<dyn Party + 'e>> = Vec::with_capacity(cfg.model.n_clients() + 1);
    let agg = Aggregator::new(&cfg.model, cfg.seed, backend, groups, threshold, stream);
    match leaves {
        // in-process tree: the aggregator slot holds the TreeAggregator
        // wrapper (root + L leaf folds); cross-process TCP trees run
        // the root unwrapped and put each leaf in a `vfl-sa leaf`
        // relay process instead
        Some(l) => parties.push(Box::new(TreeAggregator::new(
            agg,
            l,
            stream,
            threshold.is_some(),
        ))),
        None => parties.push(Box::new(agg)),
    }
    parties.push(Box::new(ActiveParty::new(
        vertical.active,
        holders,
        cfg.model.clone(),
        cfg.security,
        threshold,
        stream,
        cfg.seed,
        backend,
    )));
    for pd in vertical.passives {
        parties.push(Box::new(PassiveParty::new(
            pd.party_id + 1,
            pd,
            &cfg.model,
            cfg.security,
            threshold,
            stream,
            cfg.seed,
            backend,
        )));
    }

    let (schedule, setups) = build_schedule(cfg, &train_ids, &test_ids);
    Ok(Built { parties, schedule, test_labels, setups })
}

/// Lay out the full run: initial setup (secure modes only), training
/// rounds with key rotation every `rotation_period` rounds (round 0
/// included — matching §5.1's "every K iterations"), then full-batch
/// testing rounds.
fn build_schedule(cfg: &RunConfig, train_ids: &[u64], test_ids: &[u64]) -> (Vec<RoundSpec>, usize) {
    let secure = cfg.security.is_secure();
    let batch = cfg.model.batch_size;
    let mut schedule = Vec::new();
    let mut setups = 0usize;
    if secure {
        schedule.push(RoundSpec {
            round: SETUP_ROUND,
            kind: RoundKind::Setup,
            rotate: false,
            phase: Phase::Setup,
            ids: Vec::new(),
        });
        setups += 1;
    }
    let n = train_ids.len();
    let mut cursor = 0usize;
    for r in 0..cfg.train_rounds {
        let rotate = secure && r % cfg.model.rotation_period == 0;
        if rotate {
            setups += 1;
        }
        let ids: Vec<u64> = (0..batch).map(|k| train_ids[(cursor + k) % n]).collect();
        cursor = (cursor + batch) % n;
        schedule.push(RoundSpec {
            round: r as u32,
            kind: RoundKind::Train,
            rotate,
            phase: Phase::Training,
            ids,
        });
    }
    for t in 0..cfg.test_rounds {
        let start = t * batch;
        if start + batch > test_ids.len() {
            break;
        }
        schedule.push(RoundSpec {
            round: (cfg.train_rounds + t) as u32,
            kind: RoundKind::Test,
            rotate: false,
            phase: Phase::Testing,
            ids: test_ids[start..start + batch].to_vec(),
        });
    }
    (schedule, setups)
}

/// The training/testing results reconstructable from a run's notes.
pub struct Summary {
    pub losses: Vec<f32>,
    pub predictions: Vec<f32>,
    pub prediction_labels: Vec<f32>,
    pub test_accuracy: f64,
}

/// Fold a run's notes against its schedule: losses in round order,
/// predictions matched to each test round's ids.
pub fn summarize(
    schedule: &[RoundSpec],
    test_labels: &HashMap<u64, f32>,
    notes: &[Note],
) -> Summary {
    let mut losses: Vec<(u32, f32)> = notes
        .iter()
        .filter_map(|n| match n {
            Note::Loss { round, loss } => Some((*round, *loss)),
            _ => None,
        })
        .collect();
    losses.sort_by_key(|(r, _)| *r);
    let losses: Vec<f32> = losses.into_iter().map(|(_, l)| l).collect();

    let mut predictions = Vec::new();
    let mut prediction_labels = Vec::new();
    let mut correct = 0usize;
    let mut total = 0usize;
    for spec in schedule.iter().filter(|s| s.kind == RoundKind::Test) {
        let probs = notes.iter().find_map(|n| match n {
            Note::Predictions { round, probs } if *round == spec.round => Some(probs),
            _ => None,
        });
        let Some(probs) = probs else { continue };
        for (id, p) in spec.ids.iter().zip(probs) {
            let y = test_labels[id];
            prediction_labels.push(y);
            if (*p > 0.5) == (y == 1.0) {
                correct += 1;
            }
            total += 1;
        }
        predictions.extend_from_slice(probs);
    }
    let test_accuracy = if total > 0 { correct as f64 / total as f64 } else { 0.0 };
    Summary { losses, predictions, prediction_labels, test_accuracy }
}

/// A fully wired experiment: parties + schedule + configured transport.
pub struct Experiment<'e> {
    pub cfg: RunConfig,
    built: Built<'e>,
}

impl<'e> Experiment<'e> {
    /// Generate data, partition it, and wire up all parties.
    pub fn new(cfg: RunConfig, engine: Option<&'e Engine>) -> Result<Self> {
        let built = build(&cfg, engine)?;
        Ok(Experiment { cfg, built })
    }

    /// Run the full experiment on the configured transport; a
    /// configured fault plan wraps it in [`FaultyTransport`].
    pub fn run(self) -> Result<RunReport> {
        let Experiment { cfg, built } = self;
        let Built { parties, schedule, test_labels, setups } = built;
        let n_clients = cfg.model.n_clients();
        let threaded = || {
            let mut t = ThreadedTransport::new(n_clients);
            if let Some(ms) = cfg.stall_timeout_ms {
                t = t.with_stall_timeout(std::time::Duration::from_millis(ms));
            }
            if let Some(ms) = cfg.stall_cap_ms {
                t = t.with_stall_cap(std::time::Duration::from_millis(ms));
            }
            t
        };
        let window = cfg.rounds_in_flight;
        let outcome = match (cfg.transport, cfg.fault_plan.clone()) {
            (TransportKind::Sim, None) => {
                SimTransport::new(n_clients).execute(parties, &schedule, window)?
            }
            (TransportKind::Sim, Some(plan)) => {
                FaultyTransport::new(SimTransport::new(n_clients), plan)
                    .execute(parties, &schedule, window)?
            }
            (TransportKind::Threaded, None) => {
                threaded().execute(parties, &schedule, window)?
            }
            (TransportKind::Threaded, Some(plan)) => {
                FaultyTransport::new(threaded(), plan).execute(parties, &schedule, window)?
            }
            #[cfg(unix)]
            (TransportKind::Evloop, plan) => {
                let mut t =
                    crate::net::EvloopTransport::new(n_clients).with_threads(cfg.evloop_threads);
                if let Some(ms) = cfg.stall_timeout_ms {
                    t = t.with_stall_timeout(std::time::Duration::from_millis(ms));
                }
                if let Some(ms) = cfg.stall_cap_ms {
                    t = t.with_stall_cap(std::time::Duration::from_millis(ms));
                }
                match plan {
                    None => t.execute(parties, &schedule, window)?,
                    Some(plan) => {
                        FaultyTransport::new(t, plan).execute(parties, &schedule, window)?
                    }
                }
            }
            #[cfg(not(unix))]
            (TransportKind::Evloop, _) => {
                anyhow::bail!("the evloop transport needs a unix platform (nonblocking sockets)")
            }
        };
        let s = summarize(&schedule, &test_labels, &outcome.notes);
        Ok(RunReport {
            losses: s.losses,
            test_accuracy: s.test_accuracy,
            predictions: s.predictions,
            prediction_labels: s.prediction_labels,
            final_params: outcome.final_params,
            metrics: outcome.metrics,
            net: outcome.net,
            setups,
        })
    }
}

/// Convenience: build and run in one call.
pub fn run_experiment(cfg: RunConfig, engine: Option<&Engine>) -> Result<RunReport> {
    Experiment::new(cfg, engine)?.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SecurityMode;

    fn cfg() -> RunConfig {
        RunConfig::test("banking").unwrap()
    }

    #[test]
    fn schedule_shape_secure() {
        let mut c = cfg();
        c.train_rounds = 6; // K = 5 → rotations at rounds 0 and 5
        let train: Vec<u64> = (0..1024).collect();
        let test: Vec<u64> = (1024..1024 + 512).collect();
        let (sched, setups) = build_schedule(&c, &train, &test);
        assert_eq!(setups, 3, "initial + rotations at r0 and r5");
        assert_eq!(sched.len(), 1 + 6 + 1);
        assert_eq!(sched[0].kind, RoundKind::Setup);
        assert!(sched[1].rotate && !sched[2].rotate && sched[6].rotate);
        assert_eq!(sched[7].kind, RoundKind::Test);
        assert_eq!(sched[7].round, 6);
        assert_eq!(sched[7].ids.len(), c.model.batch_size);
        // batch ids wrap deterministically
        assert_eq!(sched[1].ids[0], 0);
        assert_eq!(sched[2].ids[0], c.model.batch_size as u64);
    }

    #[test]
    fn streaming_flags_validated() {
        // defaults: monolithic
        assert_eq!(validate_streaming(&cfg()).unwrap(), StreamCfg::monolithic());
        // zero chunk words / zero shards rejected with clear errors
        let mut c = cfg();
        c.chunk_words = Some(0);
        assert!(validate_streaming(&c).unwrap_err().to_string().contains("--chunk-words 0"));
        let mut c = cfg();
        c.shards = 0;
        assert!(validate_streaming(&c).unwrap_err().to_string().contains("--shards 0"));
        // shards without chunking rejected
        let mut c = cfg();
        c.shards = 2;
        assert!(validate_streaming(&c).unwrap_err().to_string().contains("requires --chunk-words"));
        // shard count beyond the smallest masked tensor rejected
        let mut c = cfg();
        c.chunk_words = Some(64);
        c.shards = 1 << 20;
        assert!(validate_streaming(&c).unwrap_err().to_string().contains("exceeds"));
        // chunking is exact-masking only
        let mut c = cfg();
        c.chunk_words = Some(64);
        c.security = SecurityMode::SecureFloat;
        assert!(validate_streaming(&c).unwrap_err().to_string().contains("SecureExact"));
        // a valid chunked config passes through
        let mut c = cfg();
        c.chunk_words = Some(1024);
        c.shards = 4;
        assert_eq!(validate_streaming(&c).unwrap(), StreamCfg::chunked(1024, 4));
    }

    #[test]
    fn agg_worker_flags_validated() {
        // zero workers rejected
        let mut c = cfg();
        c.agg_workers = 0;
        assert!(validate_streaming(&c).unwrap_err().to_string().contains("--agg-workers 0"));
        // workers without chunking rejected
        let mut c = cfg();
        c.agg_workers = 4;
        assert!(validate_streaming(&c)
            .unwrap_err()
            .to_string()
            .contains("requires --chunk-words"));
        // a runaway worker count rejected
        let mut c = cfg();
        c.chunk_words = Some(1024);
        c.agg_workers = MAX_AGG_WORKERS + 1;
        assert!(validate_streaming(&c).unwrap_err().to_string().contains("cap"));
        // a valid shard-parallel config carries the worker count through
        let mut c = cfg();
        c.chunk_words = Some(1024);
        c.shards = 4;
        c.agg_workers = 3;
        assert_eq!(validate_streaming(&c).unwrap(), StreamCfg::chunked(1024, 4).with_workers(3));
    }

    #[test]
    fn expand_worker_flags_validated() {
        // zero workers rejected
        let mut c = cfg();
        c.expand_workers = 0;
        assert!(validate_streaming(&c).unwrap_err().to_string().contains("--expand-workers 0"));
        // a runaway worker count rejected
        let mut c = cfg();
        c.expand_workers = MAX_EXPAND_WORKERS + 1;
        assert!(validate_streaming(&c).unwrap_err().to_string().contains("cap"));
        // unlike --agg-workers, expansion parallelism does not require
        // chunking: the count rides into a monolithic StreamCfg…
        let mut c = cfg();
        c.expand_workers = 4;
        assert_eq!(
            validate_streaming(&c).unwrap(),
            StreamCfg::monolithic().with_expand_workers(4)
        );
        // …and into a chunked one
        let mut c = cfg();
        c.chunk_words = Some(1024);
        c.shards = 4;
        c.expand_workers = 3;
        assert_eq!(
            validate_streaming(&c).unwrap(),
            StreamCfg::chunked(1024, 4).with_expand_workers(3)
        );
    }

    #[test]
    fn evloop_thread_flag_validated() {
        assert!(validate_evloop(&cfg()).is_ok(), "default K=1 passes");
        let mut c = cfg();
        c.evloop_threads = 0;
        assert!(validate_evloop(&c).unwrap_err().to_string().contains("--evloop-threads 0"));
        let mut c = cfg();
        c.evloop_threads = MAX_EVLOOP_THREADS + 1;
        assert!(validate_evloop(&c).unwrap_err().to_string().contains("cap"));
        let mut c = cfg();
        c.evloop_threads = 4;
        assert!(validate_evloop(&c).is_ok());
    }

    #[test]
    fn topology_flag_validated() {
        use crate::coordinator::topology::MAX_LEAVES;
        // default: flat topology passes through as None
        assert_eq!(validate_topology(&cfg()).unwrap(), None);
        // zero leaves rejected
        let mut c = cfg();
        c.leaves = Some(0);
        assert!(validate_topology(&c).unwrap_err().to_string().contains("--leaves 0"));
        // more leaves than clients rejected
        let mut c = cfg();
        c.leaves = Some(c.model.n_clients() + 1);
        assert!(validate_topology(&c).unwrap_err().to_string().contains("client count"));
        // a runaway leaf count rejected at the cap
        let mut c = cfg();
        c.leaves = Some(MAX_LEAVES + 1);
        assert!(validate_topology(&c).unwrap_err().to_string().contains("cap"));
        // the tree is exact-masking only
        let mut c = cfg();
        c.leaves = Some(2);
        c.security = SecurityMode::SecureFloat;
        assert!(validate_topology(&c).unwrap_err().to_string().contains("SecureExact"));
        // valid leaf counts pass (L = 1 is a legal one-shard tree)
        for l in [1, 2, c.model.n_clients()] {
            let mut c = cfg();
            c.leaves = Some(l);
            assert_eq!(validate_topology(&c).unwrap(), Some(l));
        }
    }

    #[test]
    fn window_flag_validated() {
        assert!(validate_window(&cfg()).is_ok(), "default W=1 passes");
        let mut c = cfg();
        c.rounds_in_flight = 0;
        assert!(validate_window(&c).unwrap_err().to_string().contains("--rounds-in-flight 0"));
        let mut c = cfg();
        c.rounds_in_flight = MAX_ROUNDS_IN_FLIGHT + 1;
        assert!(validate_window(&c).unwrap_err().to_string().contains("cap"));
        let mut c = cfg();
        c.rounds_in_flight = 4;
        assert!(validate_window(&c).is_ok());
    }

    #[test]
    fn rollback_knobs_validated_and_carried() {
        // zero bound rejected
        let mut c = cfg();
        c.rollback_max_bytes = Some(0);
        assert!(validate_streaming(&c)
            .unwrap_err()
            .to_string()
            .contains("--rollback-max-bytes 0"));
        // knobs on a run that never creates a rollback log are inert
        // and rejected rather than silently ignored
        let mut c = cfg();
        c.rollback_fsync = true;
        assert!(validate_streaming(&c).unwrap_err().to_string().contains("--shamir-threshold"));
        let mut c = cfg();
        c.chunk_words = Some(1024);
        c.rollback_max_bytes = Some(4096);
        assert!(validate_streaming(&c).unwrap_err().to_string().contains("--shamir-threshold"));
        // knobs ride into the StreamCfg on a tolerant chunked run
        let mut c = cfg();
        c.chunk_words = Some(1024);
        c.shards = 4;
        c.shamir_threshold = Some(3);
        c.rollback_fsync = true;
        c.rollback_max_bytes = Some(4096);
        let s = validate_streaming(&c).unwrap();
        assert_eq!(s.rollback, RollbackCfg { fsync: true, max_bytes: 4096 });
        // defaults: no fsync, the 1 GiB bound
        let s = validate_streaming(&cfg()).unwrap();
        assert_eq!(s.rollback, RollbackCfg::default());
        assert_eq!(s.rollback.max_bytes, DEFAULT_ROLLBACK_MAX_BYTES);
    }

    #[test]
    fn zero_stall_knobs_rejected() {
        let mut c = cfg();
        c.stall_timeout_ms = Some(0);
        assert!(validate_timing(&c).unwrap_err().to_string().contains("--stall-timeout-ms 0"));
        let mut c = cfg();
        c.stall_cap_ms = Some(0);
        assert!(validate_timing(&c).unwrap_err().to_string().contains("--stall-cap-ms 0"));
        // positive values and the defaults pass
        assert!(validate_timing(&cfg()).is_ok());
        let mut c = cfg();
        c.stall_timeout_ms = Some(100);
        c.stall_cap_ms = Some(2000);
        assert!(validate_timing(&c).is_ok());
    }

    #[test]
    fn schedule_shape_plain() {
        let mut c = cfg();
        c.security = SecurityMode::Plain;
        let train: Vec<u64> = (0..1024).collect();
        let test: Vec<u64> = (1024..1024 + 512).collect();
        let (sched, setups) = build_schedule(&c, &train, &test);
        assert_eq!(setups, 0, "plain mode never runs setup");
        assert!(sched.iter().all(|s| s.kind != RoundKind::Setup && !s.rotate));
    }
}
