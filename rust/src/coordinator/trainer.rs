//! The orchestrator: runs the full §4 protocol — setup, training
//! rounds (with key rotation), and the testing phase — over the
//! byte-metered network, timing every party's compute.
//!
//! Single-threaded by design: parties only interact through serialized
//! [`Msg`]s routed via [`Network`], so the byte counters are exact and
//! per-party CPU attribution is deterministic (the same reason the
//! paper simulates with Flower's VCE rather than real sockets).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::crypto::rng::DetRng;
use crate::data::{generate, partition, by_name};
use crate::model::linalg::Mat;
use crate::model::ModelParams;
use crate::net::{Addr, Network, Phase};
use crate::runtime::Engine;

use super::backend::Backend;
use super::config::{BackendKind, RunConfig};
use super::messages::Msg;
use super::metrics::{client, Metrics, AGGREGATOR};
use super::parties::{ActiveParty, Aggregator, GradSum, PassiveParty};

/// Everything a run produces.
pub struct RunReport {
    pub losses: Vec<f32>,
    /// Test-set accuracy (threshold 0.5).
    pub test_accuracy: f64,
    /// Test-phase predictions (for equivalence checks).
    pub predictions: Vec<f32>,
    /// Ground-truth labels aligned with `predictions` (for metrics).
    pub prediction_labels: Vec<f32>,
    pub final_params: ModelParams,
    pub metrics: Metrics,
    pub net: Network,
    /// Number of setup phases executed (1 + rotations).
    pub setups: usize,
}

/// A fully wired experiment.
pub struct Experiment<'e> {
    pub cfg: RunConfig,
    backend: Backend<'e>,
    active: ActiveParty,
    passives: Vec<PassiveParty>,
    aggregator: Aggregator,
    pub net: Network,
    pub metrics: Metrics,
    rng: DetRng,
    train_ids: Vec<u64>,
    test_ids: Vec<u64>,
    test_labels: HashMap<u64, f32>,
    cursor: usize,
    epoch: u64,
    setups: usize,
}

impl<'e> Experiment<'e> {
    /// Generate data, partition it, and wire up all parties.
    pub fn new(cfg: RunConfig, engine: Option<&'e Engine>) -> Result<Self> {
        let backend = match cfg.backend {
            BackendKind::Reference => Backend::Reference,
            BackendKind::Pjrt => {
                Backend::Pjrt(engine.context("PJRT backend requires a loaded Engine")?)
            }
        };
        let (schema, spec, _) =
            by_name(&cfg.model.dataset).context("unknown dataset")?;
        let data = generate(&schema, cfg.n_rows, cfg.seed);
        let vertical = partition(&data, &spec);

        let batch = cfg.model.batch_size;
        let n_train = ((cfg.n_rows as f32) * 0.8) as usize;
        if n_train < batch || cfg.n_rows - n_train < batch {
            bail!("need ≥ {batch} rows in both train and test splits");
        }
        let train_ids = data.ids[..n_train].to_vec();
        let test_ids = data.ids[n_train..].to_vec();
        let test_labels: HashMap<u64, f32> = data.ids[n_train..]
            .iter()
            .zip(&data.labels[n_train..])
            .map(|(&i, &l)| (i, l))
            .collect();

        // holder maps: per group, id → client index of the holding party
        let holders: Vec<HashMap<u64, usize>> = (0..spec.groups.len())
            .map(|g| {
                let mut m = HashMap::new();
                for p in vertical.passives.iter().filter(|p| p.group == g) {
                    for &id in p.rows.keys() {
                        m.insert(id, p.party_id + 1); // client idx (active = 0)
                    }
                }
                m
            })
            .collect();

        let active =
            ActiveParty::new(vertical.active, holders, cfg.model.clone(), cfg.security, cfg.seed);
        let passives: Vec<PassiveParty> = vertical
            .passives
            .into_iter()
            .map(|pd| PassiveParty::new(pd.party_id + 1, pd, &cfg.model, cfg.security))
            .collect();
        let aggregator = Aggregator::new(&cfg.model, cfg.seed);
        let n_clients = cfg.model.n_clients();
        let rng = DetRng::from_seed(cfg.seed ^ 0x5eed_0f_5a);

        Ok(Experiment {
            cfg,
            backend,
            active,
            passives,
            aggregator,
            net: Network::new(n_clients),
            metrics: Metrics::new(),
            rng,
            train_ids,
            test_ids,
            test_labels,
            cursor: 0,
            epoch: 0,
            setups: 0,
        })
    }

    /// §4.0.1 setup phase (also §5.1 key rotation when called again).
    pub fn run_setup(&mut self) -> Result<()> {
        if !self.cfg.security.is_secure() {
            return Ok(()); // unsecured VFL has no setup
        }
        let epoch = self.epoch;
        let n = self.cfg.model.n_clients();
        // aggregator requests keys
        for i in 0..n {
            self.net.send(Addr::Aggregator, Addr::Client(i), Msg::RequestKeys { epoch }.encode());
        }
        // clients generate keypairs and publish
        for i in 0..n {
            let _ = self.net.recv_one(Addr::Client(i));
            let msg = if i == 0 {
                let rng = &mut self.rng;
                let a = &mut self.active;
                self.metrics
                    .time_overhead(client(0), self.net.phase, || a.begin_setup(n, epoch, rng))
            } else {
                let rng = &mut self.rng;
                let p = &mut self.passives[i - 1];
                self.metrics
                    .time_overhead(client(i), self.net.phase, || p.begin_setup(n, epoch, rng))
            };
            self.net.send(Addr::Client(i), Addr::Aggregator, msg.encode());
        }
        // aggregator assembles the directory and relays it
        let mut all = Vec::with_capacity(n);
        for (_, raw) in self.net.deliver(Addr::Aggregator) {
            match Msg::decode(&raw)? {
                Msg::PublishKeys(k) => all.push(k),
                m => bail!("unexpected setup message {m:?}"),
            }
        }
        all.sort_by_key(|k| k.from);
        let dir = Msg::KeyDirectory { epoch, all };
        for i in 0..n {
            self.net.send(Addr::Aggregator, Addr::Client(i), dir.encode());
        }
        // clients derive pairwise secrets
        for i in 0..n {
            let (_, raw) = self.net.recv_one(Addr::Client(i)).context("directory missing")?;
            let Msg::KeyDirectory { all, .. } = Msg::decode(&raw)? else {
                bail!("expected directory")
            };
            if i == 0 {
                let a = &mut self.active;
                self.metrics
                    .time_overhead(client(0), self.net.phase, || a.finish_setup(&all));
            } else {
                let p = &mut self.passives[i - 1];
                self.metrics
                    .time_overhead(client(i), self.net.phase, || p.finish_setup(&all));
            }
        }
        self.epoch += 1;
        self.setups += 1;
        Ok(())
    }

    /// Pick the next training batch ids (sequential, wrapping).
    fn next_train_batch(&mut self) -> Vec<u64> {
        let b = self.cfg.model.batch_size;
        let n = self.train_ids.len();
        let ids: Vec<u64> = (0..b).map(|k| self.train_ids[(self.cursor + k) % n]).collect();
        self.cursor = (self.cursor + b) % n;
        ids
    }

    /// One §4.0.2 training round. Returns the batch loss.
    pub fn train_round(&mut self, round: u32) -> Result<f32> {
        self.net.phase = Phase::Training;
        let secure = self.cfg.security.is_secure();
        let batch = self.cfg.model.batch_size;
        let n = self.cfg.model.n_clients();
        let lr = self.cfg.model.lr;

        // key rotation (§5.1): re-run setup every K rounds
        if secure && round as usize % self.cfg.model.rotation_period == 0 {
            self.run_setup()?;
        }

        // 1. active: batch selection + sealing, weights redistribution
        let ids = self.next_train_batch();
        let batch_msg = {
            let a = &mut self.active;
            let ids = &ids;
            if secure {
                self.metrics
                    .time_overhead(client(0), Phase::Training, || a.make_batch(ids, round))
            } else {
                self.metrics.time(client(0), Phase::Training, || a.make_batch(ids, round))
            }
        };
        let weights_msg = Msg::WeightsUpdate { round, flat: self.active.group_weights_flat() };
        self.net.send(Addr::Client(0), Addr::Aggregator, batch_msg.encode());
        self.net.send(Addr::Client(0), Addr::Aggregator, weights_msg.encode());

        // 2. aggregator relays batch + per-group weights
        let mut relay_entries: Option<Vec<Vec<u8>>> = None;
        let mut relay_ids: Option<Vec<u64>> = None;
        let mut labels: Vec<f32> = Vec::new();
        let mut group_flats: Vec<Vec<f32>> = Vec::new();
        for (_, raw) in self.net.deliver(Addr::Aggregator) {
            match Msg::decode(&raw)? {
                Msg::BatchSelect { labels: l, entries, .. } => {
                    labels = l;
                    relay_entries = Some(entries);
                }
                Msg::PlainBatch { labels: l, ids, .. } => {
                    labels = l;
                    relay_ids = Some(ids);
                }
                Msg::WeightsUpdate { flat, .. } => {
                    group_flats = self.split_group_weights(&flat);
                }
                m => bail!("unexpected message {m:?}"),
            }
        }
        for p in 0..self.passives.len() {
            let ci = self.passives[p].id;
            let relay = match (&relay_entries, &relay_ids) {
                (Some(e), _) => Msg::BatchRelay { round, entries: e.clone() },
                (_, Some(ids)) => Msg::PlainBatchRelay { round, ids: ids.clone() },
                _ => bail!("no batch message received"),
            };
            self.net.send(Addr::Aggregator, Addr::Client(ci), relay.encode());
            let g = self.passives[p].group;
            let gw = Msg::GroupWeights { round, group: g as u8, flat: group_flats[g].clone() };
            self.net.send(Addr::Aggregator, Addr::Client(ci), gw.encode());
        }

        // 3. passive forward passes
        for p in 0..self.passives.len() {
            let ci = self.passives[p].id;
            let msgs = self.net.deliver(Addr::Client(ci));
            let mut resolved: Vec<(usize, u64)> = Vec::new();
            for (_, raw) in msgs {
                match Msg::decode(&raw)? {
                    Msg::BatchRelay { entries, round: r } => {
                        let pp = &self.passives[p];
                        resolved = self.metrics.time_overhead(client(ci), Phase::Training, || {
                            pp.resolve_batch(r, &entries, batch)
                        });
                    }
                    Msg::PlainBatchRelay { ids, .. } => {
                        resolved = self.passives[p].resolve_plain(&ids);
                    }
                    Msg::GroupWeights { flat, .. } => self.passives[p].set_weights(&flat),
                    m => bail!("unexpected message {m:?}"),
                }
            }
            let x = self.passives[p].batch_features(&resolved, batch);
            let graph = format!("fwd_g{}", self.passives[p].group);
            let weights = crate::model::PartyParams {
                w: self.passives[p].weights.clone(),
                b: None,
            };
            let backend = &self.backend;
            let z = self.metrics.time(client(ci), Phase::Training, || {
                backend.party_fwd(&graph, &x, &weights, None)
            })?;
            let pp = &self.passives[p];
            let msg = if secure {
                self.metrics
                    .time_overhead(client(ci), Phase::Training, || pp.masked_activation(round, &z))
            } else {
                self.metrics.time(client(ci), Phase::Training, || pp.masked_activation(round, &z))
            };
            self.net.send(Addr::Client(ci), Addr::Aggregator, msg.encode());
        }

        // 4. active forward pass
        let xa = self.active.batch_features(&ids);
        let a_params = crate::model::PartyParams {
            w: self.active.params.active.w.clone(),
            b: self.active.params.active.b.clone(),
        };
        let backend = &self.backend;
        let za = self.metrics.time(client(0), Phase::Training, || {
            backend.party_fwd("fwd_active", &xa, &a_params, None)
        })?;
        let a = &self.active;
        let msg = if secure {
            self.metrics
                .time_overhead(client(0), Phase::Training, || a.masked_activation(round, &za))
        } else {
            self.metrics.time(client(0), Phase::Training, || a.masked_activation(round, &za))
        };
        self.net.send(Addr::Client(0), Addr::Aggregator, msg.encode());

        // 5. aggregator: unmask-by-summation, global step, dz broadcast
        let mut exact_parts: Vec<Vec<u64>> = Vec::new();
        let mut float_parts: Vec<Vec<f32>> = Vec::new();
        for (_, raw) in self.net.deliver(Addr::Aggregator) {
            match Msg::decode(&raw)? {
                Msg::MaskedActivation { words, .. } => exact_parts.push(words),
                Msg::FloatActivation { vals, .. } => float_parts.push(vals),
                m => bail!("unexpected activation message {m:?}"),
            }
        }
        let agg = &self.aggregator;
        let z = self.metrics.time(AGGREGATOR, Phase::Training, || {
            if !exact_parts.is_empty() {
                agg.sum_activations_exact(batch, &exact_parts)
            } else {
                agg.sum_activations_float(batch, &float_parts)
            }
        });
        let (gw, gb) = (self.aggregator.global_w.clone(), self.aggregator.global_b);
        let out = self.metrics.time(AGGREGATOR, Phase::Training, || {
            backend.global_step(&z, &gw, gb, &labels)
        })?;
        self.aggregator.update_global(&out.d_global_w, out.d_global_b, lr);
        let dz_msg = Msg::DzBroadcast { round, dz: out.dz.data.clone() };
        for i in 0..n {
            self.net.send(Addr::Aggregator, Addr::Client(i), dz_msg.encode());
        }

        // 6. passive backward passes
        let h = self.cfg.model.hidden;
        for p in 0..self.passives.len() {
            let ci = self.passives[p].id;
            let (_, raw) = self.net.recv_one(Addr::Client(ci)).context("dz missing")?;
            let Msg::DzBroadcast { dz, .. } = Msg::decode(&raw)? else { bail!("expected dz") };
            let dzm = Mat::from_vec(batch, h, dz);
            let graph = format!("bwd_g{}", self.passives[p].group);
            let x = self.passives[p].last_x().clone();
            let backend = &self.backend;
            let (dw, _) = self.metrics.time(client(ci), Phase::Training, || {
                backend.party_bwd(&graph, &x, &dzm, false)
            })?;
            let pp = &self.passives[p];
            let msg = if secure {
                self.metrics
                    .time_overhead(client(ci), Phase::Training, || pp.masked_gradient(round, &dw))
            } else {
                self.metrics.time(client(ci), Phase::Training, || pp.masked_gradient(round, &dw))
            };
            self.net.send(Addr::Client(ci), Addr::Aggregator, msg.encode());
        }

        // 7. aggregator sums passive gradients → still masked → active
        let (_, raw) = self.net.recv_one(Addr::Client(0)).context("dz missing")?;
        let Msg::DzBroadcast { dz, .. } = Msg::decode(&raw)? else { bail!("expected dz") };
        let dzm = Mat::from_vec(batch, h, dz);

        let mut gexact: Vec<Vec<u64>> = Vec::new();
        let mut gfloat: Vec<Vec<f32>> = Vec::new();
        for (_, raw) in self.net.deliver(Addr::Aggregator) {
            match Msg::decode(&raw)? {
                Msg::MaskedGradient { words, .. } => gexact.push(words),
                Msg::FloatGradient { vals, .. } => gfloat.push(vals),
                m => bail!("unexpected gradient message {m:?}"),
            }
        }
        let agg = &self.aggregator;
        let gsum_msg = self.metrics.time(AGGREGATOR, Phase::Training, || {
            if !gexact.is_empty() {
                Msg::GradientSum { round, words: agg.sum_gradients_exact(&gexact) }
            } else {
                Msg::FloatGradientSum { round, vals: agg.sum_gradients_float(&gfloat) }
            }
        });
        self.net.send(Addr::Aggregator, Addr::Client(0), gsum_msg.encode());

        // 8. active: own backward + unmask + SGD
        let xa = self.active.last_x().clone();
        let backend = &self.backend;
        let (own_dw, own_db) = self.metrics.time(client(0), Phase::Training, || {
            backend.party_bwd("bwd_active", &xa, &dzm, true)
        })?;
        let (_, raw) = self.net.recv_one(Addr::Client(0)).context("gradient sum missing")?;
        let gsum = match Msg::decode(&raw)? {
            Msg::GradientSum { words, .. } => GradSum::Words(words),
            Msg::FloatGradientSum { vals, .. } => GradSum::Floats(vals),
            m => bail!("unexpected message {m:?}"),
        };
        let a = &mut self.active;
        let own_db = own_db.unwrap();
        let own = if secure {
            self.metrics.time_overhead(client(0), Phase::Training, || {
                a.own_grad_contribution(round, &own_dw, &own_db)
            })
        } else {
            self.metrics
                .time(client(0), Phase::Training, || a.own_grad_contribution(round, &own_dw, &own_db))
        };
        let a = &mut self.active;
        self.metrics
            .time(client(0), Phase::Training, || a.apply_gradients(gsum, own, lr))?;

        Ok(out.loss)
    }

    fn split_group_weights(&self, flat: &[f32]) -> Vec<Vec<f32>> {
        // flat is ModelParams::flatten(); extract the group blocks
        let cfg = &self.cfg.model;
        let h = cfg.hidden;
        let mut off = cfg.active_dim * h + h;
        cfg.group_dims
            .iter()
            .map(|&d| {
                let s = flat[off..off + d * h].to_vec();
                off += d * h;
                s
            })
            .collect()
    }

    /// §4.0.3 testing phase over one batch of test ids; returns probs.
    pub fn test_batch(&mut self, round: u32, ids: &[u64]) -> Result<Vec<f32>> {
        self.net.phase = Phase::Testing;
        let secure = self.cfg.security.is_secure();
        let batch = self.cfg.model.batch_size;
        assert_eq!(ids.len(), batch);

        // active: sealed batch + masked activation (no labels in testing)
        let a = &mut self.active;
        let batch_msg = if secure {
            self.metrics.time_overhead(client(0), Phase::Testing, || a.make_batch_unlabeled(ids, round))
        } else {
            self.metrics.time(client(0), Phase::Testing, || a.make_batch_unlabeled(ids, round))
        };
        self.net.send(Addr::Client(0), Addr::Aggregator, batch_msg.encode());
        let xa = self.active.batch_features(ids);
        let a_params = crate::model::PartyParams {
            w: self.active.params.active.w.clone(),
            b: self.active.params.active.b.clone(),
        };
        let backend = &self.backend;
        let za = self.metrics.time(client(0), Phase::Testing, || {
            backend.party_fwd("fwd_active", &xa, &a_params, None)
        })?;
        let a = &self.active;
        let act_msg = if secure {
            self.metrics.time_overhead(client(0), Phase::Testing, || a.masked_activation(round, &za))
        } else {
            self.metrics.time(client(0), Phase::Testing, || a.masked_activation(round, &za))
        };
        self.net.send(Addr::Client(0), Addr::Aggregator, act_msg.encode());

        // aggregator relays the batch to passives
        let mut relay_entries: Option<Vec<Vec<u8>>> = None;
        let mut relay_ids: Option<Vec<u64>> = None;
        let mut exact_parts: Vec<Vec<u64>> = Vec::new();
        let mut float_parts: Vec<Vec<f32>> = Vec::new();
        for (_, raw) in self.net.deliver(Addr::Aggregator) {
            match Msg::decode(&raw)? {
                Msg::BatchSelect { entries, .. } => relay_entries = Some(entries),
                Msg::PlainBatch { ids, .. } => relay_ids = Some(ids),
                Msg::MaskedActivation { words, .. } => exact_parts.push(words),
                Msg::FloatActivation { vals, .. } => float_parts.push(vals),
                m => bail!("unexpected message {m:?}"),
            }
        }
        for p in 0..self.passives.len() {
            let ci = self.passives[p].id;
            let relay = match (&relay_entries, &relay_ids) {
                (Some(e), _) => Msg::BatchRelay { round, entries: e.clone() },
                (_, Some(ids)) => Msg::PlainBatchRelay { round, ids: ids.clone() },
                _ => bail!("no batch message"),
            };
            self.net.send(Addr::Aggregator, Addr::Client(ci), relay.encode());
        }

        // passive forwards
        for p in 0..self.passives.len() {
            let ci = self.passives[p].id;
            let mut resolved = Vec::new();
            for (_, raw) in self.net.deliver(Addr::Client(ci)) {
                match Msg::decode(&raw)? {
                    Msg::BatchRelay { entries, round: r } => {
                        let pp = &self.passives[p];
                        resolved = self.metrics.time_overhead(client(ci), Phase::Testing, || {
                            pp.resolve_batch(r, &entries, batch)
                        });
                    }
                    Msg::PlainBatchRelay { ids, .. } => {
                        resolved = self.passives[p].resolve_plain(&ids);
                    }
                    m => bail!("unexpected message {m:?}"),
                }
            }
            let x = self.passives[p].batch_features(&resolved, batch);
            let graph = format!("fwd_g{}", self.passives[p].group);
            let weights =
                crate::model::PartyParams { w: self.passives[p].weights.clone(), b: None };
            let backend = &self.backend;
            let z = self.metrics.time(client(ci), Phase::Testing, || {
                backend.party_fwd(&graph, &x, &weights, None)
            })?;
            let pp = &self.passives[p];
            let msg = if secure {
                self.metrics
                    .time_overhead(client(ci), Phase::Testing, || pp.masked_activation(round, &z))
            } else {
                self.metrics.time(client(ci), Phase::Testing, || pp.masked_activation(round, &z))
            };
            self.net.send(Addr::Client(ci), Addr::Aggregator, msg.encode());
        }

        // aggregator: sum + predict
        for (_, raw) in self.net.deliver(Addr::Aggregator) {
            match Msg::decode(&raw)? {
                Msg::MaskedActivation { words, .. } => exact_parts.push(words),
                Msg::FloatActivation { vals, .. } => float_parts.push(vals),
                m => bail!("unexpected message {m:?}"),
            }
        }
        let agg = &self.aggregator;
        let z = self.metrics.time(AGGREGATOR, Phase::Testing, || {
            if !exact_parts.is_empty() {
                agg.sum_activations_exact(batch, &exact_parts)
            } else {
                agg.sum_activations_float(batch, &float_parts)
            }
        });
        let (gw, gb) = (self.aggregator.global_w.clone(), self.aggregator.global_b);
        let backend = &self.backend;
        let probs =
            self.metrics.time(AGGREGATOR, Phase::Testing, || backend.predict(&z, &gw, gb))?;
        self.net
            .send(Addr::Aggregator, Addr::Client(0), Msg::Predictions { round, probs: probs.clone() }.encode());
        let _ = self.net.recv_one(Addr::Client(0));
        Ok(probs)
    }

    /// Run the full experiment per the configuration.
    pub fn run(mut self) -> Result<RunReport> {
        // initial setup (counted under Phase::Setup)
        self.net.phase = Phase::Setup;
        self.run_setup()?;

        let mut losses = Vec::with_capacity(self.cfg.train_rounds);
        for r in 0..self.cfg.train_rounds {
            losses.push(self.train_round(r as u32)?);
        }

        // testing phase
        let batch = self.cfg.model.batch_size;
        let mut predictions = Vec::new();
        let mut prediction_labels = Vec::new();
        let mut correct = 0usize;
        let mut total = 0usize;
        for t in 0..self.cfg.test_rounds {
            let start = t * batch;
            if start + batch > self.test_ids.len() {
                break;
            }
            let ids: Vec<u64> = self.test_ids[start..start + batch].to_vec();
            let probs = self.test_batch(self.cfg.train_rounds as u32 + t as u32, &ids)?;
            for (id, p) in ids.iter().zip(&probs) {
                let y = self.test_labels[id];
                prediction_labels.push(y);
                if (*p > 0.5) == (y == 1.0) {
                    correct += 1;
                }
                total += 1;
            }
            predictions.extend(probs);
        }
        let test_accuracy = if total > 0 { correct as f64 / total as f64 } else { 0.0 };

        Ok(RunReport {
            losses,
            test_accuracy,
            predictions,
            prediction_labels,
            final_params: self.active.params.clone(),
            metrics: self.metrics,
            net: self.net,
            setups: self.setups,
        })
    }
}

/// Convenience: build and run in one call.
pub fn run_experiment(cfg: RunConfig, engine: Option<&Engine>) -> Result<RunReport> {
    Experiment::new(cfg, engine)?.run()
}
