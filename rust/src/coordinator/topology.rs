//! Hierarchical fan-in aggregation tree (`--leaves L`).
//!
//! The flat protocol funnels every masked fan-in message of every
//! client into the one aggregator, so its per-round fan-in cost is
//! O(n·d) however many workers fold the chunks. This module removes
//! that serial choke point: the clients are partitioned into L
//! contiguous shards, each owned by a [`LeafAggregator`] that folds
//! its shard's masked tensors into a partial ℤ₂⁶⁴ sum and forwards a
//! single [`Msg::PartialSum`] per (round, tensor) up to the root,
//! which stitches the L disjoint partials by wrap-addition exactly
//! like the [`ChunkAssembler`](super::streaming::ChunkAssembler)
//! shard merge. Per-node fan-in drops to O((n/L)·d + L·d).
//!
//! **Mask safety needs no new crypto.** Pairwise masks telescope to
//! zero only in the *full* cross-client sum (Eq. 4-5): a leaf's
//! partial over shard S still carries every pairwise term between a
//! member of S and a client outside S, so no intermediate node — leaf
//! or root before the final stitch — ever sees an unmasked value.
//! `tests/security_properties.rs` asserts this directly.
//!
//! **Bit-identity.** ℤ₂⁶⁴ wrap-addition is commutative and
//! associative, so regrouping the same summands per shard changes
//! *where* words are added, never *what* is added: reports and
//! Table-2 counters are bit-identical to the flat topology for every
//! L (asserted for L ∈ {1, 2, 4} in `tests/tree_topology.rs` on all
//! four transports). Float modes would change addition order, which
//! is why [`validate_topology`] requires
//! [`SecurityMode::SecureExact`].
//!
//! **Dropout routing.** Recovery control traffic (`DropoutNotice`,
//! `SurrenderShares`, seed reconstruction, mask corrections) stays
//! between the root and the clients, unchanged. The tree's only new
//! obligation is the exact-purge invariant: the recovery correction
//! adds a dropped client's *entire* total mask, which is sound only
//! if nothing of theirs remains in any buffer. The root therefore
//! discards every buffered partial whose client range contains a
//! newly-declared-dropped client, the owning leaf purges the member
//! from its fold (mono buffers and the revocable assembler's rollback
//! log), and re-emits a corrected partial for every still-complete
//! entry — keyed by `shard_start`, so the re-emission replaces its
//! stale predecessor. The root's `WindowDrain` note reaches the
//! scheduler exactly as in a flat run, so the pipelined window drains
//! tree-wide.
//!
//! In-process transports (sim/threaded/evloop) run the tree as a
//! [`TreeAggregator`]: one [`Party`] at `Addr::Aggregator` that
//! routes fan-in messages to the owning leaf and delegates everything
//! else to the wrapped root [`Aggregator`]. Cross-process TCP runs
//! place each leaf in its own `vfl-sa leaf` process, which relays all
//! non-fan-in frames verbatim (per-sender FIFO preserved) and sends
//! the folded `PartialSum` upstream; see `net/tcp.rs`.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{bail, Context, Result};

use crate::net::Addr;
use crate::z64;

use super::config::{RunConfig, SecurityMode};
use super::messages::Msg;
use super::metrics::Metrics;
use super::parties::{Aggregator, TAG_ACTIVATION, TAG_GRADIENT};
use super::party::{Outbox, Party, RoundSpec};
use super::streaming::{ChunkAssembler, PoolClient, RollbackCfg, StreamCfg, WorkerPool};
use super::window::MAX_ROUNDS_IN_FLIGHT;

/// Hard cap on `--leaves`: a fan-in tree wider than this buys nothing
/// (the root's O(L·d) stitch would dominate), and a typo must not
/// spawn dozens of leaf processes or worker pools.
pub const MAX_LEAVES: usize = 64;

/// Validate the tree-topology knob against the run shape, returning
/// the leaf count (`None` = the flat topology). Rejecting here means
/// `--leaves 0`, a leaf count beyond the client count, or a float
/// security mode fail at configuration time with a clear error
/// instead of deadlocking mid-round — the same contract as
/// [`validate_streaming`](super::driver::validate_streaming).
pub fn validate_topology(cfg: &RunConfig) -> Result<Option<usize>> {
    let Some(l) = cfg.leaves else {
        return Ok(None);
    };
    if l == 0 {
        bail!("--leaves 0 is invalid (the fan-in tree needs at least one leaf aggregator)");
    }
    if l > MAX_LEAVES {
        bail!("--leaves {l} exceeds the cap ({MAX_LEAVES})");
    }
    let n = cfg.model.n_clients();
    if l > n {
        bail!("--leaves {l} exceeds the client count ({n}): every leaf needs a nonempty shard");
    }
    if cfg.security != SecurityMode::SecureExact {
        bail!(
            "--leaves requires SecureExact: only Z_2^64 partial sums are order-independent, \
             which is what keeps a tree run bit-identical to the flat topology"
        );
    }
    Ok(Some(l))
}

/// The static client → leaf partition: L contiguous, disjoint,
/// nonempty shards covering `[0, n_clients)`, sizes differing by at
/// most one (the same balanced-split rule as
/// [`ShardLayout`](super::streaming::ShardLayout)). Static by design:
/// a dropped client leaves the live set, never its shard, so every
/// process in a distributed tree derives the identical map from
/// (n_clients, leaves) alone.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardMap {
    n_clients: usize,
    leaves: usize,
}

impl ShardMap {
    pub fn new(n_clients: usize, leaves: usize) -> Self {
        assert!(leaves >= 1, "need at least one leaf");
        assert!(leaves <= n_clients, "leaf count {leaves} exceeds client count {n_clients}");
        ShardMap { n_clients, leaves }
    }

    pub fn leaves(&self) -> usize {
        self.leaves
    }

    /// Half-open client range `[start, end)` owned by leaf `k`.
    pub fn range(&self, k: usize) -> (u16, u16) {
        assert!(k < self.leaves);
        let s = k * self.n_clients / self.leaves;
        let e = (k + 1) * self.n_clients / self.leaves;
        (s as u16, e as u16)
    }

    /// The leaf owning client `c`.
    pub fn owner(&self, c: u16) -> usize {
        assert!((c as usize) < self.n_clients, "client {c} out of range");
        // start from the proportional guess and walk to the owner —
        // the ranges are monotone, so this terminates in ≤ 1 step
        let mut k = (c as usize) * self.leaves / self.n_clients;
        loop {
            let (s, e) = self.range(k);
            if c < s {
                k -= 1;
            } else if c >= e {
                k += 1;
            } else {
                return k;
            }
        }
    }
}

/// One leaf's fold state for a single (round, tensor tag) fan-in:
/// monolithic masked tensors buffered by sender (client order, as at
/// the root) plus a [`ChunkAssembler`] for the chunked path.
struct LeafEntry {
    mono: BTreeMap<u16, Vec<u64>>,
    asm: ChunkAssembler,
    /// A partial for this entry already went upstream (purges re-emit
    /// over it; the root replaces by `shard_start`).
    emitted: bool,
}

/// A leaf aggregator: owns the contiguous client shard `[start, end)`,
/// folds its members' masked fan-in into one partial ℤ₂⁶⁴ sum per
/// (round, tensor), and hands the [`Msg::PartialSum`] to its caller —
/// the in-process [`TreeAggregator`] or the `vfl-sa leaf` TCP relay.
///
/// The leaf never unmasks anything: it wrap-adds opaque masked words,
/// reusing the exact [`ChunkAssembler`]/[`z64`] kernels the root uses,
/// including the rollback log for exact dropout purge in tolerant
/// runs. Contributions are buffered per sender and kept after
/// emission so a post-emission dropout can subtract exactly the
/// dropped member's words and re-emit.
pub struct LeafAggregator {
    start: u16,
    end: u16,
    /// Shard members still live at the root (the owner syncs this
    /// through [`LeafAggregator::purge`]).
    live: BTreeSet<u16>,
    revocable: bool,
    shards: usize,
    rollback: RollbackCfg,
    /// Shared fold pool (`--agg-workers` > 1 on a chunked run); slots
    /// are namespaced by leaf index so leaves never cross-talk.
    pool: Option<PoolClient>,
    slot_base: u64,
    entries: BTreeMap<(u32, u8), LeafEntry>,
}

impl LeafAggregator {
    pub fn new(
        idx: usize,
        start: u16,
        end: u16,
        stream: &StreamCfg,
        revocable: bool,
        pool: Option<PoolClient>,
    ) -> Self {
        assert!(start < end, "leaf shard must be nonempty");
        LeafAggregator {
            start,
            end,
            live: (start..end).collect(),
            revocable,
            shards: stream.shards.max(1),
            rollback: stream.rollback,
            pool,
            // root assembler slots are ((round << 1) | tag) < 2^33;
            // leaf slots live in disjoint high windows
            slot_base: ((idx as u64) + 1) << 40,
            entries: BTreeMap::new(),
        }
    }

    /// The static client range this leaf owns.
    pub fn shard_range(&self) -> (u16, u16) {
        (self.start, self.end)
    }

    fn entry(&mut self, round: u32, tag: u8) -> &mut LeafEntry {
        if !self.entries.contains_key(&(round, tag))
            && self.entries.len() >= 2 * MAX_ROUNDS_IN_FLIGHT
        {
            // backstop ring bound: entries normally retire through
            // finish_round, but a driver that never completes rounds
            // must not grow the fold state without bound
            self.entries.pop_first();
        }
        let slot = self.slot_base | ((round as u64) << 1) | (tag as u64 & 1);
        let asm = match &self.pool {
            Some(p) => ChunkAssembler::pooled(
                self.revocable,
                self.shards,
                self.rollback,
                p.clone(),
                slot,
            ),
            None => ChunkAssembler::inline(self.revocable, self.shards, self.rollback),
        };
        self.entries
            .entry((round, tag))
            .or_insert(LeafEntry { mono: BTreeMap::new(), asm, emitted: false })
    }

    /// Expected contributors under the current live view: every live
    /// shard member, minus the active party for the gradient fan-in.
    fn expected(&self, tag: u8) -> BTreeSet<u16> {
        self.live
            .iter()
            .copied()
            .filter(|&c| tag as u32 != TAG_GRADIENT || c != 0)
            .collect()
    }

    /// Whether `sender`'s tensor for (round, tag) is fully buffered
    /// here — the tree's stall-diagnosis presence signal (a
    /// half-streamed sender counts as missing, exactly as at a flat
    /// root).
    pub fn sender_complete(&self, round: u32, tag: u8, sender: u16) -> bool {
        self.entries.get(&(round, tag)).is_some_and(|e| {
            e.mono.contains_key(&sender) || e.asm.complete_senders().any(|s| s == sender)
        })
    }

    fn complete(&self, round: u32, tag: u8) -> bool {
        let Some(e) = self.entries.get(&(round, tag)) else {
            return false;
        };
        let expected = self.expected(tag);
        !expected.is_empty()
            && expected
                .iter()
                .all(|c| e.mono.contains_key(c) || e.asm.complete_senders().any(|s| s == *c))
    }

    /// A monolithic masked tensor from a shard member. Returns the
    /// emitted partial once the fold completes.
    pub fn on_masked(
        &mut self,
        round: u32,
        tag: u8,
        from: u16,
        words: Vec<u64>,
    ) -> Result<Option<Msg>> {
        if !self.live.contains(&from) {
            return Ok(None);
        }
        self.entry(round, tag).mono.insert(from, words);
        self.maybe_emit(round, tag)
    }

    /// One masked chunk from a shard member (the streaming path).
    #[allow(clippy::too_many_arguments)]
    pub fn on_chunk(
        &mut self,
        round: u32,
        tag: u8,
        from: u16,
        shard: u16,
        offset: u32,
        total: u32,
        words: &[u64],
    ) -> Result<Option<Msg>> {
        if !self.live.contains(&from) {
            return Ok(None);
        }
        self.entry(round, tag).asm.add_chunk(from, shard, offset, total, words)?;
        self.maybe_emit(round, tag)
    }

    fn maybe_emit(&mut self, round: u32, tag: u8) -> Result<Option<Msg>> {
        if !self.complete(round, tag) {
            return Ok(None);
        }
        Ok(Some(self.partial(round, tag)?))
    }

    /// Build the partial for a complete (round, tag) fold: the
    /// assembler's non-consuming snapshot plus every buffered
    /// monolithic tensor, wrap-added in ℤ₂⁶⁴. Non-consuming so a
    /// post-emission purge can re-emit a corrected partial.
    fn partial(&mut self, round: u32, tag: u8) -> Result<Msg> {
        let (start, end) = (self.start, self.end);
        let e = self
            .entries
            .get_mut(&(round, tag))
            .with_context(|| format!("no leaf fold for round {round} tag {tag}"))?;
        let mut acc = match e.asm.snapshot_sum()? {
            Some(a) => a,
            None => {
                let len =
                    e.mono.values().next().map(|v| v.len()).context("empty leaf fold")?;
                vec![0u64; len]
            }
        };
        for w in e.mono.values() {
            assert_eq!(w.len(), acc.len(), "masked vectors must be equal length");
            z64::wrap_add(&mut acc, w);
        }
        e.emitted = true;
        Ok(Msg::PartialSum { round, tag, shard_start: start, shard_end: end, words: acc })
    }

    /// A shard member was declared dropped: remove it from the live
    /// view, subtract exactly its contribution from every fold (the
    /// revocable assembler replays its rollback log), and return
    /// corrected partials for every fold that is complete under the
    /// shrunken expectation — including folds the dropped member was
    /// the last missing contributor of, which become emittable only
    /// now.
    pub fn purge(&mut self, gone: u16) -> Result<Vec<Msg>> {
        if !self.live.remove(&gone) {
            return Ok(Vec::new());
        }
        let keys: Vec<(u32, u8)> = self.entries.keys().copied().collect();
        let mut msgs = Vec::new();
        for (round, tag) in keys {
            if let Some(e) = self.entries.get_mut(&(round, tag)) {
                e.mono.remove(&gone);
                e.asm.purge(gone)?;
            }
            if self.complete(round, tag) {
                msgs.push(self.partial(round, tag)?);
            }
        }
        Ok(msgs)
    }

    /// The driver reported `round` complete: its folds retire (both
    /// tensor tags), freeing the assemblers' pool slots.
    pub fn finish_round(&mut self, round: u32) {
        self.entries.retain(|&(r, _), _| r != round);
    }
}

/// The in-process fan-in tree: one [`Party`] at `Addr::Aggregator`
/// wrapping the root [`Aggregator`] and L [`LeafAggregator`]s.
///
/// Masked fan-in traffic from a client routes to its owning leaf;
/// everything else — setup, batch relays, recovery control — delegates
/// straight to the root with the same [`Outbox`], so downlink bytes
/// and Table-2 counters are bit-identical to a flat run. A completed
/// leaf fold feeds its [`Msg::PartialSum`] to the root as internal
/// (unmetered) traffic from `Addr::Aggregator`, mirroring what a
/// `vfl-sa leaf` process sends over its upstream socket.
///
/// After every root call the wrapper diffs the root's live set
/// against its cache: newly-declared-dropped clients are purged from
/// their owning leaf, and any corrected partials are fed back to the
/// root — which already discarded the stale ones in its own purge —
/// before recovery completes, preserving the exact-purge invariant.
pub struct TreeAggregator<'e> {
    root: Aggregator<'e>,
    map: ShardMap,
    leaves: Vec<LeafAggregator>,
    /// Cached copy of the root's live set (drop detection).
    live: BTreeSet<u16>,
    /// One shared leaf fold pool (`--agg-workers` > 1 on a chunked
    /// run); kept alive here, handed to leaves as clients.
    _pool: Option<WorkerPool>,
}

impl<'e> TreeAggregator<'e> {
    pub fn new(root: Aggregator<'e>, leaves: usize, stream: StreamCfg, revocable: bool) -> Self {
        let map = ShardMap::new(root.n_clients, leaves);
        let pool = if stream.chunk_words.is_some() && stream.agg_workers > 1 {
            Some(WorkerPool::new(stream.agg_workers.min(stream.shards.max(1))))
        } else {
            None
        };
        let leaves = (0..leaves)
            .map(|k| {
                let (s, e) = map.range(k);
                LeafAggregator::new(k, s, e, &stream, revocable, pool.as_ref().map(|p| p.client()))
            })
            .collect();
        let live = root.live_clients().clone();
        TreeAggregator { root, map, leaves, live, _pool: pool }
    }

    /// Diff the root's live set against the cache; purge newly-gone
    /// members from their owning leaf and feed corrected partials
    /// back to the root. Loops until quiescent — a fed partial can in
    /// principle complete a sum whose handling shrinks the set again.
    fn sync_live(&mut self, out: &mut Outbox) -> Result<()> {
        loop {
            let gone: Vec<u16> = self
                .live
                .iter()
                .copied()
                .filter(|c| !self.root.live_clients().contains(c))
                .collect();
            if gone.is_empty() {
                return Ok(());
            }
            let mut emissions = Vec::new();
            for g in gone {
                self.live.remove(&g);
                emissions.extend(self.leaves[self.map.owner(g)].purge(g)?);
            }
            for m in emissions {
                // a retired round's sum already went out (same
                // semantics as a flat round completed pre-drop):
                // nothing to correct there
                if m.round().is_some_and(|r| !self.root.has_round_ctx(r)) {
                    continue;
                }
                self.root.on_message(Addr::Aggregator, m, out)?;
            }
        }
    }

    /// Route one fan-in contribution to the owning leaf; on fold
    /// completion feed the partial to the root.
    fn fold(
        &mut self,
        round: u32,
        tag: u8,
        sender: u16,
        msg: Msg,
        out: &mut Outbox,
    ) -> Result<()> {
        // mirror the root's declared-dropped filter and its
        // unknown-round error, so tree and flat runs fail identically
        if !self.live.contains(&sender) {
            return Ok(());
        }
        if !self.root.has_round_ctx(round) {
            bail!("fan-in traffic for unknown round {round}");
        }
        let k = self.map.owner(sender);
        let emission = match msg {
            Msg::MaskedActivation { round, from, words }
            | Msg::MaskedGradient { round, from, words } => {
                self.leaves[k].on_masked(round, tag, from, words)?
            }
            Msg::MaskedChunk { round, from, tag, shard, offset, total, words } => {
                self.leaves[k].on_chunk(round, tag, from, shard, offset, total, &words)?
            }
            m => bail!("tree fold on a non-fan-in message {m:?}"),
        };
        // presence for the root's stall diagnosis — only once the
        // sender's tensor is complete at its leaf, so a half-streamed
        // sender is declared dropped exactly as at a flat root
        if self.leaves[k].sender_complete(round, tag, sender) {
            self.root.note_tree_presence(round, tag, sender);
        }
        if let Some(m) = emission {
            self.root.on_message(Addr::Aggregator, m, out)?;
            self.sync_live(out)?;
        }
        Ok(())
    }
}

impl<'e> Party for TreeAggregator<'e> {
    fn addr(&self) -> Addr {
        Addr::Aggregator
    }

    fn on_round_start(&mut self, spec: &RoundSpec, out: &mut Outbox) -> Result<()> {
        self.root.on_round_start(spec, out)
    }

    fn on_message(&mut self, from: Addr, msg: Msg, out: &mut Outbox) -> Result<()> {
        match &msg {
            Msg::MaskedActivation { round, from: sender, .. } => {
                let (round, sender) = (*round, *sender);
                self.fold(round, TAG_ACTIVATION as u8, sender, msg, out)
            }
            Msg::MaskedGradient { round, from: sender, .. } => {
                let (round, sender) = (*round, *sender);
                self.fold(round, TAG_GRADIENT as u8, sender, msg, out)
            }
            Msg::MaskedChunk { round, from: sender, tag, .. } => {
                let (round, tag, sender) = (*round, *tag, *sender);
                self.fold(round, tag, sender, msg, out)
            }
            _ => {
                self.root.on_message(from, msg, out)?;
                self.sync_live(out)
            }
        }
    }

    fn on_stall(&mut self, out: &mut Outbox) -> Result<()> {
        self.root.on_stall(out)?;
        self.sync_live(out)
    }

    fn on_round_complete(&mut self, round: u32) {
        self.root.on_round_complete(round);
        for leaf in &mut self.leaves {
            leaf.finish_round(round);
        }
    }

    fn concurrent_safe(&self) -> bool {
        self.root.concurrent_safe()
    }

    fn take_metrics(&mut self) -> Metrics {
        self.root.take_metrics()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_map_partitions_exactly() {
        for (n, l) in [(4, 1), (4, 2), (4, 4), (5, 2), (9, 4), (64, 64)] {
            let m = ShardMap::new(n, l);
            let mut covered = Vec::new();
            for k in 0..l {
                let (s, e) = m.range(k);
                assert!(s < e, "shard {k} of ({n},{l}) is empty");
                for c in s..e {
                    assert_eq!(m.owner(c), k);
                    covered.push(c);
                }
            }
            assert_eq!(covered, (0..n as u16).collect::<Vec<_>>(), "({n},{l}) must partition");
            // balanced: sizes differ by at most one
            let sizes: Vec<usize> =
                (0..l).map(|k| { let (s, e) = m.range(k); (e - s) as usize }).collect();
            let (mn, mx) = (sizes.iter().min().copied(), sizes.iter().max().copied());
            assert!(mx.zip(mn).is_some_and(|(a, b)| a - b <= 1));
        }
    }

    #[test]
    fn leaf_folds_and_emits_partial() {
        let stream = StreamCfg::monolithic();
        let mut leaf = LeafAggregator::new(0, 1, 3, &stream, false, None);
        assert!(leaf.on_masked(0, 0, 1, vec![1, 2, 3]).unwrap().is_none(), "incomplete");
        let m = leaf.on_masked(0, 0, 2, vec![10, 20, u64::MAX]).unwrap();
        match m {
            Some(Msg::PartialSum { round: 0, tag: 0, shard_start: 1, shard_end: 3, words }) => {
                assert_eq!(words, vec![11, 22, 3u64.wrapping_add(u64::MAX)]);
            }
            other => panic!("expected a PartialSum, got {other:?}"),
        }
    }

    #[test]
    fn leaf_gradient_excludes_active_party() {
        let stream = StreamCfg::monolithic();
        // shard [0, 2): client 0 is the active party — the gradient
        // fan-in completes on client 1 alone
        let mut leaf = LeafAggregator::new(0, 0, 2, &stream, false, None);
        let m = leaf.on_masked(3, TAG_GRADIENT as u8, 1, vec![7, 8]).unwrap();
        assert!(matches!(m, Some(Msg::PartialSum { round: 3, tag: 1, .. })));
    }

    #[test]
    fn leaf_purge_reemits_corrected_partial() {
        let stream = StreamCfg::monolithic();
        let mut leaf = LeafAggregator::new(0, 1, 4, &stream, true, None);
        leaf.on_masked(0, 0, 1, vec![100]).unwrap();
        leaf.on_masked(0, 0, 2, vec![10]).unwrap();
        let full = leaf.on_masked(0, 0, 3, vec![1]).unwrap();
        assert!(matches!(full, Some(Msg::PartialSum { ref words, .. }) if *words == vec![111]));
        // post-emission drop of member 2: exact subtraction, re-emit
        let re = leaf.purge(2).unwrap();
        assert_eq!(re.len(), 1);
        assert!(matches!(re[0], Msg::PartialSum { ref words, .. } if *words == vec![101]));
        // a second drop re-emits every complete fold: round 0 again
        // (now member 1 alone) and round 1, which member 3's drop
        // makes emittable only now
        leaf.on_masked(1, 0, 1, vec![5]).unwrap();
        let re = leaf.purge(3).unwrap();
        assert_eq!(re.len(), 2);
        assert!(matches!(re[0], Msg::PartialSum { round: 0, ref words, .. } if *words == vec![100]));
        assert!(matches!(re[1], Msg::PartialSum { round: 1, ref words, .. } if *words == vec![5]));
    }

    #[test]
    fn leaf_ignores_dead_and_foreign_rounds_retire() {
        let stream = StreamCfg::monolithic();
        let mut leaf = LeafAggregator::new(0, 1, 3, &stream, true, None);
        leaf.purge(2).unwrap();
        assert!(leaf.on_masked(0, 0, 2, vec![9]).unwrap().is_none(), "dead member ignored");
        // fold now completes on member 1 alone
        let m = leaf.on_masked(0, 0, 1, vec![4]).unwrap();
        assert!(matches!(m, Some(Msg::PartialSum { ref words, .. }) if *words == vec![4]));
        leaf.finish_round(0);
        assert!(leaf.entries.is_empty());
    }
}
