//! Protocol messages (§4 of the paper) and their wire encoding.
//!
//! Secure and plain (unsecured-VFL baseline) variants are distinct
//! message types so the transport's byte counters cleanly attribute the
//! communication overhead (Table 2).

use anyhow::{bail, Result};

use crate::net::wire::{Reader, Writer};

/// One client's published per-peer X25519 public keys (`pk_i^{(j)}`).
#[derive(Clone, Debug, PartialEq)]
pub struct WireKeys {
    pub from: u16,
    /// Index j: key intended for peer j; `None` at the own slot.
    pub keys: Vec<Option<[u8; 32]>>,
}

/// Protocol message.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    // ---- setup phase (§4.0.1) ----
    /// Aggregator asks every client for fresh keys (key rotation, §5.1).
    RequestKeys { epoch: u64 },
    /// Client → aggregator: per-peer public keys.
    PublishKeys(WireKeys),
    /// Aggregator → client: everyone's published keys.
    KeyDirectory { epoch: u64, all: Vec<WireKeys> },

    // ---- training phase (§4.0.2) ----
    /// Active → aggregator: updated flat party weights (after SGD).
    WeightsUpdate { round: u32, flat: Vec<f32> },
    /// Aggregator → passive: its group's weight block.
    GroupWeights { round: u32, group: u8, flat: Vec<f32> },
    /// Active → aggregator: labels + per-sample sealed IDs
    /// (entry = AEAD(id) under the holder's pairwise key).
    BatchSelect { round: u32, labels: Vec<f32>, entries: Vec<Vec<u8>> },
    /// Aggregator → every passive: the sealed ID broadcast.
    BatchRelay { round: u32, entries: Vec<Vec<u8>> },
    /// Unsecured baseline: plaintext IDs.
    PlainBatch { round: u32, labels: Vec<f32>, ids: Vec<u64> },
    PlainBatchRelay { round: u32, ids: Vec<u64> },
    /// Client → aggregator: masked activation (Eq. 2), ℤ₂⁶⁴ words.
    MaskedActivation { round: u32, from: u16, words: Vec<u64> },
    /// Client → aggregator: one window of a masked tensor (the
    /// streaming pipeline; `--chunk-words`). `tag` selects the fan-in
    /// (0 = activation, 1 = gradient), `shard` the shard the window
    /// belongs to, `offset` the window's starting word in the *full*
    /// tensor of `total` words. Chunks ride per-sender FIFO in stream
    /// order and never cross a shard boundary. Header cost: 22 bytes
    /// per chunk vs 11 for a monolithic masked message (the Table-2
    /// accounting rule, see `coordinator::streaming`).
    MaskedChunk {
        round: u32,
        from: u16,
        tag: u8,
        shard: u16,
        offset: u32,
        total: u32,
        words: Vec<u64>,
    },
    /// Client → aggregator: float-mask or plain activation.
    FloatActivation { round: u32, from: u16, vals: Vec<f32> },
    /// Aggregator → clients: ∂L/∂z broadcast for the backward pass.
    DzBroadcast { round: u32, dz: Vec<f32> },
    /// Passive → aggregator: masked full-length gradient (Eq. 6).
    MaskedGradient { round: u32, from: u16, words: Vec<u64> },
    FloatGradient { round: u32, from: u16, vals: Vec<f32> },
    /// Aggregator → active: Σ passive masked gradients (still masked by
    /// the active party's own total mask — §4.0.2's privacy argument).
    GradientSum { round: u32, words: Vec<u64> },
    /// Aggregator → active: one window of the chunked `GradientSum`
    /// downlink (the streaming pipeline; mirrors `MaskedChunk` minus
    /// the `from`/`tag` fields — the 1:1 link has one sender and one
    /// tensor). Windows ride in stream order and never cross a shard
    /// boundary. Header cost: 19 bytes per chunk vs 9 for the
    /// monolithic `GradientSum` (the Table-2 accounting rule, see
    /// `coordinator::streaming::grad_chunk_overhead_bytes`).
    GradientChunk { round: u32, shard: u16, offset: u32, total: u32, words: Vec<u64> },
    FloatGradientSum { round: u32, vals: Vec<f32> },

    // ---- hierarchical fan-in tree (`--leaves`) ----
    /// Leaf aggregator → root: the folded ℤ₂⁶⁴ partial sum of one
    /// client shard's masked tensors for `(round, tag)` (`tag` as in
    /// [`Msg::MaskedChunk`]: 0 = activation, 1 = gradient). The
    /// half-open client range `[shard_start, shard_end)` names exactly
    /// which clients the partial covers; the root stitches L disjoint
    /// partials by plain wrap-addition — the same commuting-sum
    /// algebra as the shard merge. The words stay masked: pairwise
    /// masks only telescope to zero in the *full* cross-client sum, so
    /// every cross-shard pairwise term survives in a leaf's partial
    /// (the mask-safety argument in `coordinator::topology`). Header
    /// cost: 14 bytes per partial (the Table-2 accounting rule, see
    /// `coordinator::streaming::PARTIAL_SUM_HEADER_BYTES`).
    PartialSum { round: u32, tag: u8, shard_start: u16, shard_end: u16, words: Vec<u64> },

    // ---- testing phase (§4.0.3) ----
    /// Aggregator → active: predictions for the requested batch.
    Predictions { round: u32, probs: Vec<f32> },

    // ---- dropout tolerance (Bonawitz'17 extension, §5.1) ----
    /// Client → aggregator: Shamir shares of its mask seed, one
    /// AEAD-sealed bundle per recipient peer (empty at the own slot and
    /// at peers with no shared secret). Sealed so the relaying
    /// aggregator can never collect t readable shares itself. The
    /// `commitment` binds the shared seed
    /// ([`dropout::seed_commitment`](crate::secagg::dropout::seed_commitment)):
    /// the aggregator pins it at setup and rejects a reconstruction
    /// that does not match — a malicious surrenderer can no longer
    /// corrupt recovery undetected.
    SeedShares { epoch: u64, from: u16, commitment: [u8; 32], sealed: Vec<Vec<u8>> },
    /// Aggregator → client: every peer's sealed bundle addressed to
    /// this client (`sealed[i]` = client i's bundle, empty slots where
    /// no bundle exists).
    ShareRelay { epoch: u64, sealed: Vec<Vec<u8>> },
    /// Aggregator → survivors: these clients were declared dropped
    /// mid-round; surrender your shares of their seeds.
    DropoutNotice { round: u32, dropped: Vec<u16> },
    /// Survivor → aggregator: its (plaintext — that is the point of
    /// recovery) share bundles for each requested dropped client.
    SurrenderShares { round: u32, from: u16, bundles: Vec<(u16, Vec<u8>)> },
}

impl Msg {
    /// The protocol round this message belongs to, `None` for the
    /// setup-phase messages (which carry an epoch instead). This is
    /// the routing key for the per-round contexts and the attribution
    /// anchor for the fault-injection harness — keep it beside the
    /// wire definitions so a new variant cannot forget it.
    pub fn round(&self) -> Option<u32> {
        match self {
            Msg::RequestKeys { .. }
            | Msg::PublishKeys(..)
            | Msg::KeyDirectory { .. }
            | Msg::SeedShares { .. }
            | Msg::ShareRelay { .. } => None,
            Msg::WeightsUpdate { round, .. }
            | Msg::GroupWeights { round, .. }
            | Msg::BatchSelect { round, .. }
            | Msg::BatchRelay { round, .. }
            | Msg::PlainBatch { round, .. }
            | Msg::PlainBatchRelay { round, .. }
            | Msg::MaskedActivation { round, .. }
            | Msg::MaskedChunk { round, .. }
            | Msg::FloatActivation { round, .. }
            | Msg::DzBroadcast { round, .. }
            | Msg::MaskedGradient { round, .. }
            | Msg::FloatGradient { round, .. }
            | Msg::GradientSum { round, .. }
            | Msg::GradientChunk { round, .. }
            | Msg::FloatGradientSum { round, .. }
            | Msg::PartialSum { round, .. }
            | Msg::Predictions { round, .. }
            | Msg::DropoutNotice { round, .. }
            | Msg::SurrenderShares { round, .. } => Some(*round),
        }
    }
}

const T_REQUEST_KEYS: u8 = 1;
const T_PUBLISH_KEYS: u8 = 2;
const T_KEY_DIRECTORY: u8 = 3;
const T_WEIGHTS_UPDATE: u8 = 4;
const T_GROUP_WEIGHTS: u8 = 5;
const T_BATCH_SELECT: u8 = 6;
const T_BATCH_RELAY: u8 = 7;
const T_PLAIN_BATCH: u8 = 8;
const T_PLAIN_BATCH_RELAY: u8 = 9;
const T_MASKED_ACTIVATION: u8 = 10;
const T_FLOAT_ACTIVATION: u8 = 11;
const T_DZ_BROADCAST: u8 = 12;
const T_MASKED_GRADIENT: u8 = 13;
const T_FLOAT_GRADIENT: u8 = 14;
const T_GRADIENT_SUM: u8 = 15;
const T_FLOAT_GRADIENT_SUM: u8 = 16;
const T_PREDICTIONS: u8 = 17;
const T_SEED_SHARES: u8 = 18;
const T_SHARE_RELAY: u8 = 19;
const T_DROPOUT_NOTICE: u8 = 20;
const T_SURRENDER_SHARES: u8 = 21;
const T_MASKED_CHUNK: u8 = 22;
const T_GRADIENT_CHUNK: u8 = 23;
const T_PARTIAL_SUM: u8 = 24;

fn blob_list_len(blobs: &[Vec<u8>]) -> usize {
    4 + blobs.iter().map(|b| 4 + b.len()).sum::<usize>()
}

fn write_blob_list(w: &mut Writer, blobs: &[Vec<u8>]) {
    w.u32(blobs.len() as u32);
    for b in blobs {
        w.bytes(b);
    }
}

fn read_blob_list(r: &mut Reader) -> Result<Vec<Vec<u8>>> {
    let n = r.u32()? as usize;
    let mut out = Vec::with_capacity(n.min(r.remaining()));
    for _ in 0..n {
        out.push(r.bytes()?);
    }
    Ok(out)
}

fn wire_keys_len(k: &WireKeys) -> usize {
    2 + 4 + k.keys.iter().map(|key| if key.is_some() { 33 } else { 1 }).sum::<usize>()
}

fn write_wire_keys(w: &mut Writer, k: &WireKeys) {
    w.u16(k.from);
    w.u32(k.keys.len() as u32);
    for key in &k.keys {
        match key {
            None => w.u8(0),
            Some(pk) => {
                w.u8(1);
                w.fixed(pk);
            }
        }
    }
}

fn read_wire_keys(r: &mut Reader) -> Result<WireKeys> {
    let from = r.u16()?;
    let n = r.u32()? as usize;
    // cap: never pre-allocate more than the buffer could possibly hold
    let mut keys = Vec::with_capacity(n.min(r.remaining()));
    for _ in 0..n {
        keys.push(match r.u8()? {
            0 => None,
            1 => Some(r.fixed::<32>()?),
            t => bail!("bad key tag {t}"),
        });
    }
    Ok(WireKeys { from, keys })
}

/// Write the full `MaskedChunk` wire header — variant tag through the
/// payload word-count prefix — into `w`. The caller appends exactly
/// `count` words with [`Writer::u64s_raw`] and ships the buffer; the
/// result is byte-identical to
/// `Msg::MaskedChunk { .. }.encode()` (the frame-encode rule of the
/// zero-copy chunk path, pinned by `chunk_builders_match_encode`).
#[allow(clippy::too_many_arguments)]
pub fn begin_masked_chunk(
    w: &mut Writer,
    round: u32,
    from: u16,
    tag: u8,
    shard: u16,
    offset: u32,
    total: u32,
    count: u32,
) {
    w.u8(T_MASKED_CHUNK);
    w.u32(round);
    w.u16(from);
    w.u8(tag);
    w.u16(shard);
    w.u32(offset);
    w.u32(total);
    w.u32(count);
}

/// `begin_masked_chunk`'s downlink twin: the `GradientChunk` header
/// through the word-count prefix, byte-identical to
/// `Msg::GradientChunk { .. }.encode()` once `count` raw words follow.
pub fn begin_gradient_chunk(
    w: &mut Writer,
    round: u32,
    shard: u16,
    offset: u32,
    total: u32,
    count: u32,
) {
    w.u8(T_GRADIENT_CHUNK);
    w.u32(round);
    w.u16(shard);
    w.u32(offset);
    w.u32(total);
    w.u32(count);
}

/// The `PartialSum` header — variant tag through the payload
/// word-count prefix — for the leaf aggregators' zero-copy uplink.
/// The caller appends exactly `count` words with [`Writer::u64s_raw`];
/// the result is byte-identical to `Msg::PartialSum { .. }.encode()`
/// (the frame-encode rule, pinned by `chunk_builders_match_encode`).
pub fn begin_partial_sum(
    w: &mut Writer,
    round: u32,
    tag: u8,
    shard_start: u16,
    shard_end: u16,
    count: u32,
) {
    w.u8(T_PARTIAL_SUM);
    w.u32(round);
    w.u8(tag);
    w.u16(shard_start);
    w.u16(shard_end);
    w.u32(count);
}

impl Msg {
    /// Exact wire size of [`Msg::encode`]'s output, computed without
    /// encoding. The zero-copy path sizes its single allocation with
    /// this; `encode` itself debug-asserts the two stay in sync, and
    /// the roundtrip tests assert it for every variant.
    pub fn encoded_len(&self) -> usize {
        match self {
            Msg::RequestKeys { .. } => 1 + 8,
            Msg::PublishKeys(k) => 1 + wire_keys_len(k),
            Msg::KeyDirectory { all, .. } => {
                1 + 8 + 4 + all.iter().map(wire_keys_len).sum::<usize>()
            }
            Msg::WeightsUpdate { flat, .. } => 1 + 4 + 4 + 4 * flat.len(),
            Msg::GroupWeights { flat, .. } => 1 + 4 + 1 + 4 + 4 * flat.len(),
            Msg::BatchSelect { labels, entries, .. } => {
                1 + 4 + 4 + 4 * labels.len() + blob_list_len(entries)
            }
            Msg::BatchRelay { entries, .. } => 1 + 4 + blob_list_len(entries),
            Msg::PlainBatch { labels, ids, .. } => {
                1 + 4 + 4 + 4 * labels.len() + 4 + 8 * ids.len()
            }
            Msg::PlainBatchRelay { ids, .. } => 1 + 4 + 4 + 8 * ids.len(),
            Msg::MaskedActivation { words, .. } => 1 + 4 + 2 + 4 + 8 * words.len(),
            Msg::MaskedChunk { words, .. } => 1 + 4 + 2 + 1 + 2 + 4 + 4 + 4 + 8 * words.len(),
            Msg::FloatActivation { vals, .. } => 1 + 4 + 2 + 4 + 4 * vals.len(),
            Msg::DzBroadcast { dz, .. } => 1 + 4 + 4 + 4 * dz.len(),
            Msg::MaskedGradient { words, .. } => 1 + 4 + 2 + 4 + 8 * words.len(),
            Msg::FloatGradient { vals, .. } => 1 + 4 + 2 + 4 + 4 * vals.len(),
            Msg::GradientSum { words, .. } => 1 + 4 + 4 + 8 * words.len(),
            Msg::GradientChunk { words, .. } => 1 + 4 + 2 + 4 + 4 + 4 + 8 * words.len(),
            Msg::FloatGradientSum { vals, .. } => 1 + 4 + 4 + 4 * vals.len(),
            Msg::PartialSum { words, .. } => 1 + 4 + 1 + 2 + 2 + 4 + 8 * words.len(),
            Msg::Predictions { probs, .. } => 1 + 4 + 4 + 4 * probs.len(),
            Msg::SeedShares { sealed, .. } => 1 + 8 + 2 + 32 + blob_list_len(sealed),
            Msg::ShareRelay { sealed, .. } => 1 + 8 + blob_list_len(sealed),
            Msg::DropoutNotice { dropped, .. } => 1 + 4 + 4 + 2 * dropped.len(),
            Msg::SurrenderShares { bundles, .. } => {
                1 + 4 + 2 + 4 + bundles.iter().map(|(_, b)| 2 + 4 + b.len()).sum::<usize>()
            }
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::with_capacity(self.encoded_len());
        self.encode_into(&mut w);
        debug_assert_eq!(w.buf.len(), self.encoded_len(), "encoded_len out of sync: {self:?}");
        w.finish()
    }

    /// Append this message's encoding to an existing [`Writer`].
    pub fn encode_into(&self, w: &mut Writer) {
        match self {
            Msg::RequestKeys { epoch } => {
                w.u8(T_REQUEST_KEYS);
                w.u64(*epoch);
            }
            Msg::PublishKeys(k) => {
                w.u8(T_PUBLISH_KEYS);
                write_wire_keys(w, k);
            }
            Msg::KeyDirectory { epoch, all } => {
                w.u8(T_KEY_DIRECTORY);
                w.u64(*epoch);
                w.u32(all.len() as u32);
                for k in all {
                    write_wire_keys(w, k);
                }
            }
            Msg::WeightsUpdate { round, flat } => {
                w.u8(T_WEIGHTS_UPDATE);
                w.u32(*round);
                w.f32s(flat);
            }
            Msg::GroupWeights { round, group, flat } => {
                w.u8(T_GROUP_WEIGHTS);
                w.u32(*round);
                w.u8(*group);
                w.f32s(flat);
            }
            Msg::BatchSelect { round, labels, entries } => {
                w.u8(T_BATCH_SELECT);
                w.u32(*round);
                w.f32s(labels);
                write_blob_list(w, entries);
            }
            Msg::BatchRelay { round, entries } => {
                w.u8(T_BATCH_RELAY);
                w.u32(*round);
                write_blob_list(w, entries);
            }
            Msg::PlainBatch { round, labels, ids } => {
                w.u8(T_PLAIN_BATCH);
                w.u32(*round);
                w.f32s(labels);
                w.u64s(ids);
            }
            Msg::PlainBatchRelay { round, ids } => {
                w.u8(T_PLAIN_BATCH_RELAY);
                w.u32(*round);
                w.u64s(ids);
            }
            Msg::MaskedActivation { round, from, words } => {
                w.u8(T_MASKED_ACTIVATION);
                w.u32(*round);
                w.u16(*from);
                w.u64s(words);
            }
            Msg::MaskedChunk { round, from, tag, shard, offset, total, words } => {
                w.u8(T_MASKED_CHUNK);
                w.u32(*round);
                w.u16(*from);
                w.u8(*tag);
                w.u16(*shard);
                w.u32(*offset);
                w.u32(*total);
                w.u64s(words);
            }
            Msg::FloatActivation { round, from, vals } => {
                w.u8(T_FLOAT_ACTIVATION);
                w.u32(*round);
                w.u16(*from);
                w.f32s(vals);
            }
            Msg::DzBroadcast { round, dz } => {
                w.u8(T_DZ_BROADCAST);
                w.u32(*round);
                w.f32s(dz);
            }
            Msg::MaskedGradient { round, from, words } => {
                w.u8(T_MASKED_GRADIENT);
                w.u32(*round);
                w.u16(*from);
                w.u64s(words);
            }
            Msg::FloatGradient { round, from, vals } => {
                w.u8(T_FLOAT_GRADIENT);
                w.u32(*round);
                w.u16(*from);
                w.f32s(vals);
            }
            Msg::GradientSum { round, words } => {
                w.u8(T_GRADIENT_SUM);
                w.u32(*round);
                w.u64s(words);
            }
            Msg::GradientChunk { round, shard, offset, total, words } => {
                w.u8(T_GRADIENT_CHUNK);
                w.u32(*round);
                w.u16(*shard);
                w.u32(*offset);
                w.u32(*total);
                w.u64s(words);
            }
            Msg::FloatGradientSum { round, vals } => {
                w.u8(T_FLOAT_GRADIENT_SUM);
                w.u32(*round);
                w.f32s(vals);
            }
            Msg::PartialSum { round, tag, shard_start, shard_end, words } => {
                w.u8(T_PARTIAL_SUM);
                w.u32(*round);
                w.u8(*tag);
                w.u16(*shard_start);
                w.u16(*shard_end);
                w.u64s(words);
            }
            Msg::Predictions { round, probs } => {
                w.u8(T_PREDICTIONS);
                w.u32(*round);
                w.f32s(probs);
            }
            Msg::SeedShares { epoch, from, commitment, sealed } => {
                w.u8(T_SEED_SHARES);
                w.u64(*epoch);
                w.u16(*from);
                w.fixed(commitment);
                write_blob_list(w, sealed);
            }
            Msg::ShareRelay { epoch, sealed } => {
                w.u8(T_SHARE_RELAY);
                w.u64(*epoch);
                write_blob_list(w, sealed);
            }
            Msg::DropoutNotice { round, dropped } => {
                w.u8(T_DROPOUT_NOTICE);
                w.u32(*round);
                w.u32(dropped.len() as u32);
                for d in dropped {
                    w.u16(*d);
                }
            }
            Msg::SurrenderShares { round, from, bundles } => {
                w.u8(T_SURRENDER_SHARES);
                w.u32(*round);
                w.u16(*from);
                w.u32(bundles.len() as u32);
                for (d, b) in bundles {
                    w.u16(*d);
                    w.bytes(b);
                }
            }
        }
    }

    pub fn decode(buf: &[u8]) -> Result<Msg> {
        let mut r = Reader::new(buf);
        let tag = r.u8()?;
        let msg = match tag {
            T_REQUEST_KEYS => Msg::RequestKeys { epoch: r.u64()? },
            T_PUBLISH_KEYS => Msg::PublishKeys(read_wire_keys(&mut r)?),
            T_KEY_DIRECTORY => {
                let epoch = r.u64()?;
                let n = r.u32()? as usize;
                let mut all = Vec::with_capacity(n.min(r.remaining()));
                for _ in 0..n {
                    all.push(read_wire_keys(&mut r)?);
                }
                Msg::KeyDirectory { epoch, all }
            }
            T_WEIGHTS_UPDATE => Msg::WeightsUpdate { round: r.u32()?, flat: r.f32s()? },
            T_GROUP_WEIGHTS => {
                Msg::GroupWeights { round: r.u32()?, group: r.u8()?, flat: r.f32s()? }
            }
            T_BATCH_SELECT => {
                let round = r.u32()?;
                let labels = r.f32s()?;
                Msg::BatchSelect { round, labels, entries: read_blob_list(&mut r)? }
            }
            T_BATCH_RELAY => {
                Msg::BatchRelay { round: r.u32()?, entries: read_blob_list(&mut r)? }
            }
            T_PLAIN_BATCH => {
                Msg::PlainBatch { round: r.u32()?, labels: r.f32s()?, ids: r.u64s()? }
            }
            T_PLAIN_BATCH_RELAY => Msg::PlainBatchRelay { round: r.u32()?, ids: r.u64s()? },
            T_MASKED_ACTIVATION => {
                Msg::MaskedActivation { round: r.u32()?, from: r.u16()?, words: r.u64s()? }
            }
            T_MASKED_CHUNK => Msg::MaskedChunk {
                round: r.u32()?,
                from: r.u16()?,
                tag: r.u8()?,
                shard: r.u16()?,
                offset: r.u32()?,
                total: r.u32()?,
                words: r.u64s()?,
            },
            T_FLOAT_ACTIVATION => {
                Msg::FloatActivation { round: r.u32()?, from: r.u16()?, vals: r.f32s()? }
            }
            T_DZ_BROADCAST => Msg::DzBroadcast { round: r.u32()?, dz: r.f32s()? },
            T_MASKED_GRADIENT => {
                Msg::MaskedGradient { round: r.u32()?, from: r.u16()?, words: r.u64s()? }
            }
            T_FLOAT_GRADIENT => {
                Msg::FloatGradient { round: r.u32()?, from: r.u16()?, vals: r.f32s()? }
            }
            T_GRADIENT_SUM => Msg::GradientSum { round: r.u32()?, words: r.u64s()? },
            T_GRADIENT_CHUNK => Msg::GradientChunk {
                round: r.u32()?,
                shard: r.u16()?,
                offset: r.u32()?,
                total: r.u32()?,
                words: r.u64s()?,
            },
            T_FLOAT_GRADIENT_SUM => Msg::FloatGradientSum { round: r.u32()?, vals: r.f32s()? },
            T_PARTIAL_SUM => Msg::PartialSum {
                round: r.u32()?,
                tag: r.u8()?,
                shard_start: r.u16()?,
                shard_end: r.u16()?,
                words: r.u64s()?,
            },
            T_PREDICTIONS => Msg::Predictions { round: r.u32()?, probs: r.f32s()? },
            T_SEED_SHARES => Msg::SeedShares {
                epoch: r.u64()?,
                from: r.u16()?,
                commitment: r.fixed::<32>()?,
                sealed: read_blob_list(&mut r)?,
            },
            T_SHARE_RELAY => {
                Msg::ShareRelay { epoch: r.u64()?, sealed: read_blob_list(&mut r)? }
            }
            T_DROPOUT_NOTICE => {
                let round = r.u32()?;
                let n = r.u32()? as usize;
                let mut dropped = Vec::with_capacity(n.min(r.remaining()));
                for _ in 0..n {
                    dropped.push(r.u16()?);
                }
                Msg::DropoutNotice { round, dropped }
            }
            T_SURRENDER_SHARES => {
                let round = r.u32()?;
                let from = r.u16()?;
                let n = r.u32()? as usize;
                let mut bundles = Vec::with_capacity(n.min(r.remaining()));
                for _ in 0..n {
                    bundles.push((r.u16()?, r.bytes()?));
                }
                Msg::SurrenderShares { round, from, bundles }
            }
            t => bail!("unknown message tag {t}"),
        };
        if !r.done() {
            bail!("trailing bytes in message (tag {tag}, {} left)", r.remaining());
        }
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(m: Msg) {
        let enc = m.encode();
        assert_eq!(enc.len(), m.encoded_len(), "encoded_len out of sync: {m:?}");
        let dec = Msg::decode(&enc).unwrap();
        assert_eq!(m, dec);
    }

    #[test]
    fn all_variants_roundtrip() {
        roundtrip(Msg::RequestKeys { epoch: 3 });
        roundtrip(Msg::PublishKeys(WireKeys {
            from: 2,
            keys: vec![Some([1u8; 32]), None, Some([3u8; 32])],
        }));
        roundtrip(Msg::KeyDirectory {
            epoch: 1,
            all: vec![
                WireKeys { from: 0, keys: vec![None, Some([7u8; 32])] },
                WireKeys { from: 1, keys: vec![Some([8u8; 32]), None] },
            ],
        });
        roundtrip(Msg::WeightsUpdate { round: 4, flat: vec![1.0, -2.0] });
        roundtrip(Msg::GroupWeights { round: 4, group: 1, flat: vec![0.5; 7] });
        roundtrip(Msg::BatchSelect {
            round: 9,
            labels: vec![1.0, 0.0],
            entries: vec![vec![1, 2, 3], vec![], vec![9; 24]],
        });
        roundtrip(Msg::BatchRelay { round: 9, entries: vec![vec![4; 24]] });
        roundtrip(Msg::PlainBatch { round: 1, labels: vec![0.0], ids: vec![42, 43] });
        roundtrip(Msg::PlainBatchRelay { round: 1, ids: vec![u64::MAX] });
        roundtrip(Msg::MaskedActivation { round: 2, from: 3, words: vec![u64::MAX, 0, 7] });
        roundtrip(Msg::MaskedChunk {
            round: 2,
            from: 3,
            tag: 1,
            shard: 4,
            offset: 1024,
            total: 5184,
            words: vec![u64::MAX, 0, 7],
        });
        roundtrip(Msg::FloatActivation { round: 2, from: 3, vals: vec![1.5, -0.5] });
        roundtrip(Msg::DzBroadcast { round: 2, dz: vec![0.25; 10] });
        roundtrip(Msg::MaskedGradient { round: 2, from: 1, words: vec![5; 9] });
        roundtrip(Msg::FloatGradient { round: 2, from: 1, vals: vec![-1.0; 3] });
        roundtrip(Msg::GradientSum { round: 2, words: vec![11, 12] });
        roundtrip(Msg::GradientChunk {
            round: 2,
            shard: 3,
            offset: 4032,
            total: 5184,
            words: vec![11, 12, u64::MAX],
        });
        roundtrip(Msg::FloatGradientSum { round: 2, vals: vec![3.0] });
        roundtrip(Msg::PartialSum {
            round: 2,
            tag: 1,
            shard_start: 3,
            shard_end: 5,
            words: vec![u64::MAX, 0, 17],
        });
        roundtrip(Msg::Predictions { round: 5, probs: vec![0.9, 0.1] });
        roundtrip(Msg::SeedShares {
            epoch: 2,
            from: 3,
            commitment: [0xA5; 32],
            sealed: vec![vec![], vec![1, 2, 3], vec![0xFF; 96]],
        });
        roundtrip(Msg::ShareRelay { epoch: 2, sealed: vec![vec![9; 40], vec![]] });
        roundtrip(Msg::DropoutNotice { round: 7, dropped: vec![2, 4] });
        roundtrip(Msg::SurrenderShares {
            round: 7,
            from: 1,
            bundles: vec![(2, vec![5; 84]), (4, vec![])],
        });
    }

    #[test]
    fn corrupt_messages_rejected() {
        let enc = Msg::RequestKeys { epoch: 1 }.encode();
        assert!(Msg::decode(&enc[..enc.len() - 1]).is_err());
        assert!(Msg::decode(&[99, 0, 0]).is_err());
        // trailing garbage
        let mut e2 = Msg::DzBroadcast { round: 0, dz: vec![] }.encode();
        e2.push(0);
        assert!(Msg::decode(&e2).is_err());
    }

    #[test]
    fn masked_activation_size_is_8b_per_word() {
        let m = Msg::MaskedActivation { round: 0, from: 0, words: vec![0; 1000] };
        // 1 tag + 4 round + 2 from + 4 len + 8000
        assert_eq!(m.encode().len(), 1 + 4 + 2 + 4 + 8000);
        let f = Msg::FloatActivation { round: 0, from: 0, vals: vec![0.0; 1000] };
        assert_eq!(f.encode().len(), 1 + 4 + 2 + 4 + 4000);
    }

    #[test]
    fn masked_chunk_header_is_22_bytes() {
        use crate::coordinator::streaming::CHUNK_MSG_HEADER_BYTES;
        let m = Msg::MaskedChunk {
            round: 0,
            from: 0,
            tag: 0,
            shard: 0,
            offset: 0,
            total: 1000,
            words: vec![0; 250],
        };
        // the documented per-chunk Table-2 accounting constant
        assert_eq!(m.encode().len() as u64, CHUNK_MSG_HEADER_BYTES + 250 * 8);
    }

    #[test]
    fn chunk_builders_match_encode() {
        // the zero-copy senders' frame-encode rule: header builder +
        // raw payload words must be byte-identical to Msg::encode()
        for words in [vec![], vec![u64::MAX], vec![7u64, 0, u64::MAX, 0x0102030405060708]] {
            let m = Msg::MaskedChunk {
                round: 9,
                from: 3,
                tag: 1,
                shard: 4,
                offset: 1024,
                total: 5184,
                words: words.clone(),
            };
            let mut w = Writer::with_capacity(m.encoded_len());
            begin_masked_chunk(&mut w, 9, 3, 1, 4, 1024, 5184, words.len() as u32);
            w.u64s_raw(&words);
            assert_eq!(w.finish(), m.encode(), "masked n={}", words.len());

            let g = Msg::GradientChunk {
                round: 9,
                shard: 4,
                offset: 1024,
                total: 5184,
                words: words.clone(),
            };
            let mut w = Writer::with_capacity(g.encoded_len());
            begin_gradient_chunk(&mut w, 9, 4, 1024, 5184, words.len() as u32);
            w.u64s_raw(&words);
            assert_eq!(w.finish(), g.encode(), "gradient n={}", words.len());

            let p = Msg::PartialSum {
                round: 9,
                tag: 0,
                shard_start: 2,
                shard_end: 4,
                words: words.clone(),
            };
            let mut w = Writer::with_capacity(p.encoded_len());
            begin_partial_sum(&mut w, 9, 0, 2, 4, words.len() as u32);
            w.u64s_raw(&words);
            assert_eq!(w.finish(), p.encode(), "partial n={}", words.len());
        }
    }

    #[test]
    fn partial_sum_header_is_14_bytes() {
        use crate::coordinator::streaming::PARTIAL_SUM_HEADER_BYTES;
        let m = Msg::PartialSum {
            round: 0,
            tag: 0,
            shard_start: 0,
            shard_end: 3,
            words: vec![0; 250],
        };
        // the documented per-partial Table-2 accounting constant
        assert_eq!(m.encode().len() as u64, PARTIAL_SUM_HEADER_BYTES + 250 * 8);
    }

    #[test]
    fn gradient_chunk_header_is_19_bytes() {
        use crate::coordinator::streaming::{GRAD_CHUNK_MSG_HEADER_BYTES, GRAD_SUM_HEADER_BYTES};
        let m =
            Msg::GradientChunk { round: 0, shard: 0, offset: 0, total: 1000, words: vec![0; 250] };
        assert_eq!(m.encode().len() as u64, GRAD_CHUNK_MSG_HEADER_BYTES + 250 * 8);
        let s = Msg::GradientSum { round: 0, words: vec![0; 1000] };
        assert_eq!(s.encode().len() as u64, GRAD_SUM_HEADER_BYTES + 1000 * 8);
    }
}
