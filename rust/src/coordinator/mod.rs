//! Layer-3 coordinator: the paper's system contribution, organised as
//! event-driven party state machines over pluggable transports.
//!
//! * [`party`] — the [`Party`] trait (`on_round_start` / `on_message`
//!   / `on_stall` → [`Outbox`]), round schedule types, and driver
//!   notes. `on_stall` is the quiescence probe every transport fires
//!   when a round cannot make progress — the hook the aggregator's
//!   Bonawitz'17 dropout recovery hangs off.
//! * [`parties`] — the §4 machines: [`parties::ActiveParty`],
//!   [`parties::PassiveParty`], [`parties::Aggregator`]. The same
//!   machines run on every transport. Each keeps a bounded ring of
//!   per-round contexts (messages route by their `round` tag), so
//!   several rounds can be in flight at once.
//! * [`window`] — the windowed round scheduler behind
//!   `--rounds-in-flight`: [`window::RoundWindow`] starts rounds in
//!   schedule order up to the window width, with setup/rotation and
//!   phase barriers plus the dropout drain that keep every width
//!   bit-identical to the serial run. All three transports drive it.
//! * [`messages`] — the §4 protocol messages and wire encoding.
//! * [`streaming`] — the chunked streaming pipeline (`--chunk-words`/
//!   `--shards`/`--agg-workers`): shard layout, the sender-side chunk
//!   plan, and the aggregator-side [`streaming::ChunkAssembler`] — a
//!   routing layer over per-shard accumulator workers that folds
//!   masked chunks on arrival instead of buffering one full tensor
//!   per sender, with a deterministic merge and a rollback log for
//!   exact dropout purge. Bit-identical reports to the monolithic
//!   path for any worker count; see the module docs for the memory
//!   model.
//! * [`topology`] — the hierarchical fan-in tree (`--leaves L`):
//!   [`topology::ShardMap`] partitions the clients into L contiguous
//!   shards, each owned by a [`topology::LeafAggregator`] that folds
//!   its shard's masked fan-in into a partial ℤ₂⁶⁴ sum and forwards
//!   one [`Msg::PartialSum`] per (round, tensor) to the root — fan-in
//!   drops from O(n·d) per node to O((n/L)·d + L·d). A partial stays
//!   masked by every cross-shard pairwise term, so no intermediate
//!   node sees plaintext; in-process transports run the tree as the
//!   [`topology::TreeAggregator`] wrapper, TCP runs as `vfl-sa leaf`
//!   relay processes. Bit-identical reports and Table-2 counters for
//!   every L.
//! * [`driver`] — builds the party set, lays out the static round
//!   schedule (setup → training with §5.1 key rotation → testing),
//!   hands it with the configured window width to the
//!   [`Transport`](crate::net::Transport), and assembles a
//!   [`RunReport`].
//! * [`backend`] — PJRT-artifact or pure-Rust compute.
//! * [`metrics`] — per-(node, phase) CPU accounting with the security-
//!   overhead bucket (Table 1), plus the peak fan-in-buffer, per-shard
//!   peak, and rollback-spill meters behind the streaming pipeline's
//!   memory claims.
//! * [`config`] — experiment configuration (§6.3's setup) including
//!   the transport selection and the streaming knobs.

pub mod backend;
pub mod config;
pub mod driver;
pub mod messages;
pub mod metrics;
pub mod parties;
pub mod party;
pub mod streaming;
pub mod topology;
pub mod window;

pub use backend::Backend;
pub use config::{BackendKind, RunConfig, SecurityMode, TransportKind};
pub use driver::{
    build, run_experiment, summarize, validate_evloop, validate_streaming, validate_timing,
    validate_window, Built, Experiment, RunReport, Summary, MAX_AGG_WORKERS, MAX_EVLOOP_THREADS,
    MAX_EXPAND_WORKERS,
};
pub use messages::Msg;
pub use metrics::{Metrics, PipelineStats};
pub use party::{Note, Outbox, Party, RoundKind, RoundSpec, SETUP_ROUND};
pub use streaming::StreamCfg;
pub use topology::{
    validate_topology, LeafAggregator, ShardMap, TreeAggregator, MAX_LEAVES,
};
pub use window::{RoundWindow, MAX_ROUNDS_IN_FLIGHT};
