//! Layer-3 coordinator: the paper's system contribution.
//!
//! * [`messages`] — the §4 protocol messages and wire encoding.
//! * [`parties`] — active / passive / aggregator state machines.
//! * [`trainer`] — the orchestrator running setup → training (with key
//!   rotation) → testing over the byte-metered network.
//! * [`backend`] — PJRT-artifact or pure-Rust compute.
//! * [`metrics`] — per-(node, phase) CPU accounting with the security-
//!   overhead bucket (Table 1).
//! * [`config`] — experiment configuration (§6.3's setup).

pub mod backend;
pub mod config;
pub mod messages;
pub mod metrics;
pub mod parties;
pub mod trainer;

pub use backend::Backend;
pub use config::{BackendKind, RunConfig, SecurityMode};
pub use messages::Msg;
pub use metrics::Metrics;
pub use trainer::{run_experiment, Experiment, RunReport};
