//! Compute backend: PJRT artifacts (production) or pure-Rust reference.
//!
//! Parties call through this enum so the protocol code is agnostic to
//! where the math runs. The PJRT path executes the AOT-lowered L2
//! graphs (which embed the L1 Pallas kernel); the reference path runs
//! `model::reference`. A test asserts the two agree.

use anyhow::Result;

use crate::model::linalg::Mat;
use crate::model::reference;
use crate::model::PartyParams;
use crate::runtime::Engine;

/// Output of the aggregator's global step.
pub struct GlobalStepOut {
    pub loss: f32,
    pub probs: Vec<f32>,
    pub dz: Mat,
    pub d_global_w: Vec<f32>,
    pub d_global_b: f32,
}

/// Cheap-to-copy handle: parties each hold one, so the same machine
/// code runs on either backend under any transport.
#[derive(Clone, Copy)]
pub enum Backend<'e> {
    Reference,
    Pjrt(&'e Engine),
}

impl<'e> Backend<'e> {
    /// Whether this backend may be driven from several party threads
    /// at once. The PJRT engine is shared by reference and the `xla`
    /// wrapper is not audited for concurrent use, so only the
    /// reference backend qualifies — `ThreadedTransport` enforces
    /// this before spawning.
    pub fn concurrent_safe(&self) -> bool {
        matches!(self, Backend::Reference)
    }

    /// Party forward: x·W (+ b) + float-mask (Eq. 2's unmasked core when
    /// `mask` is zeros — the exact-ℤ₂⁶⁴ mode masks after this call).
    /// `graph` is the artifact key, e.g. "fwd_active" / "fwd_g0".
    pub fn party_fwd(
        &self,
        graph: &str,
        x: &Mat,
        params: &PartyParams,
        mask: Option<&[f32]>,
    ) -> Result<Mat> {
        let h = params.w.cols;
        match self {
            Backend::Reference => {
                let mut z = reference::party_forward(x, params);
                if let Some(m) = mask {
                    for (v, m) in z.data.iter_mut().zip(m) {
                        *v += m;
                    }
                }
                Ok(z)
            }
            Backend::Pjrt(engine) => {
                let b = x.rows;
                let d = x.cols;
                let zeros;
                let m: &[f32] = match mask {
                    Some(m) => m,
                    None => {
                        zeros = vec![0.0f32; b * h];
                        &zeros
                    }
                };
                let out = if let Some(bias) = &params.b {
                    engine.execute(
                        graph,
                        &[
                            (&x.data, &[b as i64, d as i64]),
                            (&params.w.data, &[d as i64, h as i64]),
                            (bias, &[h as i64]),
                            (m, &[b as i64, h as i64]),
                        ],
                    )?
                } else {
                    engine.execute(
                        graph,
                        &[
                            (&x.data, &[b as i64, d as i64]),
                            (&params.w.data, &[d as i64, h as i64]),
                            (m, &[b as i64, h as i64]),
                        ],
                    )?
                };
                Ok(Mat::from_vec(b, h, out.into_iter().next().unwrap()))
            }
        }
    }

    /// Party backward: xᵀ·dz (+ Σdz bias grad when `bias`), Eq. 6's core.
    pub fn party_bwd(
        &self,
        graph: &str,
        x: &Mat,
        dz: &Mat,
        bias: bool,
    ) -> Result<(Mat, Option<Vec<f32>>)> {
        match self {
            Backend::Reference => Ok(reference::party_backward(x, dz, bias)),
            Backend::Pjrt(engine) => {
                let (b, d, h) = (x.rows, x.cols, dz.cols);
                if bias {
                    let mw = vec![0.0f32; d * h];
                    let mb = vec![0.0f32; h];
                    let out = engine.execute(
                        graph,
                        &[
                            (&x.data, &[b as i64, d as i64]),
                            (&dz.data, &[b as i64, h as i64]),
                            (&mw, &[d as i64, h as i64]),
                            (&mb, &[h as i64]),
                        ],
                    )?;
                    let mut it = out.into_iter();
                    let dw = Mat::from_vec(d, h, it.next().unwrap());
                    let db = it.next().unwrap();
                    Ok((dw, Some(db)))
                } else {
                    let m = vec![0.0f32; d * h];
                    let out = engine.execute(
                        graph,
                        &[
                            (&x.data, &[b as i64, d as i64]),
                            (&dz.data, &[b as i64, h as i64]),
                            (&m, &[d as i64, h as i64]),
                        ],
                    )?;
                    Ok((Mat::from_vec(d, h, out.into_iter().next().unwrap()), None))
                }
            }
        }
    }

    /// Aggregator global module: fused forward + loss + backward.
    pub fn global_step(&self, z: &Mat, wg: &[f32], bg: f32, y: &[f32]) -> Result<GlobalStepOut> {
        let (b, h) = (z.rows, z.cols);
        match self {
            Backend::Reference => {
                let params = crate::model::ModelParams {
                    active: PartyParams { w: Mat::zeros(1, 1), b: None },
                    groups: vec![],
                    global: crate::model::GlobalParams {
                        w: Mat::from_vec(h, 1, wg.to_vec()),
                        b: bg,
                    },
                };
                let fwd = reference::global_forward(&params, z, y);
                let bwd = reference::global_backward(&params, z, &fwd, y);
                Ok(GlobalStepOut {
                    loss: fwd.loss,
                    probs: fwd.probs.data,
                    dz: bwd.dz,
                    d_global_w: bwd.d_global_w.data,
                    d_global_b: bwd.d_global_b,
                })
            }
            Backend::Pjrt(engine) => {
                let out = engine.execute(
                    "global_step",
                    &[
                        (&z.data, &[b as i64, h as i64]),
                        (wg, &[h as i64, 1]),
                        (&[bg], &[1]),
                        (y, &[b as i64]),
                    ],
                )?;
                let mut it = out.into_iter();
                let loss = it.next().unwrap()[0];
                let probs = it.next().unwrap();
                let dz = Mat::from_vec(b, h, it.next().unwrap());
                let d_global_w = it.next().unwrap();
                let d_global_b = it.next().unwrap()[0];
                Ok(GlobalStepOut { loss, probs, dz, d_global_w, d_global_b })
            }
        }
    }

    /// Testing-phase forward: probabilities only (§4.0.3).
    pub fn predict(&self, z: &Mat, wg: &[f32], bg: f32) -> Result<Vec<f32>> {
        let (b, h) = (z.rows, z.cols);
        match self {
            Backend::Reference => {
                let h1 = crate::model::linalg::relu(z);
                let wgm = Mat::from_vec(h, 1, wg.to_vec());
                let mut logits = crate::model::linalg::matmul(&h1, &wgm);
                for v in logits.data.iter_mut() {
                    *v += bg;
                }
                Ok(crate::model::linalg::sigmoid(&logits).data)
            }
            Backend::Pjrt(engine) => {
                let out = engine.execute(
                    "predict",
                    &[(&z.data, &[b as i64, h as i64]), (wg, &[h as i64, 1]), (&[bg], &[1])],
                )?;
                Ok(out.into_iter().next().unwrap())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::DetRng;
    use crate::model::ModelConfig;
    use crate::runtime::ARTIFACT_BATCH;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn rand_mat(rows: usize, cols: usize, rng: &mut DetRng) -> Mat {
        Mat::from_vec(rows, cols, (0..rows * cols).map(|_| rng.next_f64() as f32 - 0.5).collect())
    }

    #[test]
    fn pjrt_and_reference_agree_end_to_end() {
        if !crate::runtime::pjrt_enabled() {
            eprintln!("skipping: built without the `pjrt` feature");
            return;
        }
        if !artifacts_dir().join("banking_global_step.hlo.txt").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let cfg = ModelConfig::for_dataset("banking").unwrap();
        let engine = Engine::load(artifacts_dir(), &cfg).unwrap();
        let pjrt = Backend::Pjrt(&engine);
        let refb = Backend::Reference;
        let mut rng = DetRng::from_seed(1);
        let b = ARTIFACT_BATCH;

        // fwd active
        let x = rand_mat(b, cfg.active_dim, &mut rng);
        let params = PartyParams {
            w: rand_mat(cfg.active_dim, cfg.hidden, &mut rng),
            b: Some((0..cfg.hidden).map(|_| rng.next_f64() as f32).collect()),
        };
        let mask: Vec<f32> = (0..b * cfg.hidden).map(|_| rng.next_f64() as f32).collect();
        let zp = pjrt.party_fwd("fwd_active", &x, &params, Some(&mask)).unwrap();
        let zr = refb.party_fwd("fwd_active", &x, &params, Some(&mask)).unwrap();
        for (a, c) in zp.data.iter().zip(&zr.data) {
            assert!((a - c).abs() < 1e-3, "fwd {a} vs {c}");
        }

        // bwd group
        let xg = rand_mat(b, cfg.group_dims[1], &mut rng);
        let dz = rand_mat(b, cfg.hidden, &mut rng);
        let (gp, _) = pjrt.party_bwd("bwd_g1", &xg, &dz, false).unwrap();
        let (gr, _) = refb.party_bwd("bwd_g1", &xg, &dz, false).unwrap();
        for (a, c) in gp.data.iter().zip(&gr.data) {
            assert!((a - c).abs() < 1e-2, "bwd {a} vs {c}");
        }

        // global step
        let z = rand_mat(b, cfg.hidden, &mut rng);
        let wg: Vec<f32> = (0..cfg.hidden).map(|_| rng.next_f64() as f32 - 0.5).collect();
        let y: Vec<f32> = (0..b).map(|i| (i % 2) as f32).collect();
        let op = pjrt.global_step(&z, &wg, 0.1, &y).unwrap();
        let or = refb.global_step(&z, &wg, 0.1, &y).unwrap();
        assert!((op.loss - or.loss).abs() < 1e-4);
        for (a, c) in op.dz.data.iter().zip(&or.dz.data) {
            assert!((a - c).abs() < 1e-5);
        }
        // predict
        let pp = pjrt.predict(&z, &wg, 0.1).unwrap();
        let pr = refb.predict(&z, &wg, 0.1).unwrap();
        for (a, c) in pp.iter().zip(&pr) {
            assert!((a - c).abs() < 1e-5);
        }
    }
}
