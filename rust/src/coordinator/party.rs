//! The event-driven party abstraction the whole coordination layer is
//! built on.
//!
//! Every protocol participant — [`Aggregator`](super::parties::Aggregator),
//! [`ActiveParty`](super::parties::ActiveParty),
//! [`PassiveParty`](super::parties::PassiveParty) — implements [`Party`]:
//! a state machine that reacts to round-boundary hooks and incoming
//! [`Msg`]s by pushing outgoing messages and driver notes into an
//! [`Outbox`]. Parties never block and never talk to a transport
//! directly, so the *same* state machines run under the byte-metered
//! [`SimTransport`](crate::net::SimTransport), the multi-threaded
//! [`ThreadedTransport`](crate::net::ThreadedTransport), and the TCP
//! `serve`/`join` plumbing in `main.rs`.
//!
//! Determinism contract: a party's behaviour may depend only on its own
//! state and the per-sender-FIFO message streams it receives — never on
//! cross-sender arrival order. (The aggregator, for instance, buffers
//! masked shares keyed by sender and combines them in client order.)
//! That is what makes sim and threaded runs bit-identical.

use anyhow::Result;

use crate::model::ModelParams;
use crate::net::wire::{Reader, Writer};
use crate::net::{Addr, Phase};

use super::messages::Msg;
use super::metrics::Metrics;

/// What kind of work a scheduled round performs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundKind {
    /// §4.0.1 key agreement only (the initial setup phase).
    Setup,
    /// §4.0.2 training round (forward, global step, backward, SGD).
    Train,
    /// §4.0.3 testing round (forward + predict, no labels leave the
    /// active party).
    Test,
}

/// One scheduled protocol round, announced to every party by the
/// driver through [`Party::on_round_start`].
#[derive(Clone, Debug, PartialEq)]
pub struct RoundSpec {
    /// Protocol round counter (test rounds continue the training
    /// numbering; the initial setup uses [`SETUP_ROUND`]).
    pub round: u32,
    pub kind: RoundKind,
    /// Whether this round begins with a §5.1 key rotation.
    pub rotate: bool,
    /// Phase bucket for byte counters and CPU attribution.
    pub phase: Phase,
    /// The mini-batch sample ids this round operates on (empty for
    /// pure-setup rounds). Only the active party reads these.
    pub ids: Vec<u64>,
}

/// Round number used by the initial setup round.
pub const SETUP_ROUND: u32 = u32::MAX;

/// Out-of-band signals a party reports to the driver (these are *not*
/// protocol traffic and are never metered).
#[derive(Clone, Debug, PartialEq)]
pub enum Note {
    /// Aggregator: the global module's training loss for a round.
    Loss { round: u32, loss: f32 },
    /// Active party: the predictions received for a testing round.
    Predictions { round: u32, probs: Vec<f32> },
    /// Active party: the round's terminal event — the driver starts
    /// the next scheduled round only after seeing this.
    RoundDone { round: u32 },
    /// A party hit a protocol error (threaded/remote runs surface it
    /// through this instead of a panic).
    Failed { who: u16, error: String },
    /// Transport bookkeeping: the outcome of a quiescence probe
    /// ([`Party::on_stall`]) — `acted` says whether the probed party
    /// pushed recovery traffic, `processed` how many events it handled
    /// since the previous probe. Never part of a run's result notes.
    Stall { acted: bool, processed: u64 },
    /// Transport bookkeeping: the aggregator declared a dropout while
    /// diagnosing `round` — the windowed scheduler must drain to one
    /// round in flight ([`RoundWindow::drain`](super::window::RoundWindow))
    /// so recovery composes with pipelining. Consumed by the driver
    /// loop, never part of a run's result notes.
    WindowDrain { round: u32 },
}

/// One outgoing protocol message: either a structured [`Msg`] (encoded
/// by the transport at send time) or pre-encoded wire bytes from the
/// zero-copy chunk path.
///
/// The frame-encode rule: an `Encoded` payload MUST be byte-identical
/// to `Msg::encode()` of the message it replaces — transports meter
/// and frame the bytes without knowing which variant produced them, so
/// Table-2 counters and every cross-transport bit-identity assertion
/// hold regardless of which path a sender took.
#[derive(Clone, Debug, PartialEq)]
pub enum OutMsg {
    /// A structured message; the transport calls [`Msg::encode`].
    Msg(Msg),
    /// Pre-encoded message bytes (e.g. a `MaskedChunk` whose masked
    /// words were written straight into the wire buffer), with the
    /// round tag carried alongside for routing/fault-injection —
    /// mirroring [`Msg::round`].
    Encoded { round: Option<u32>, bytes: Vec<u8> },
}

impl OutMsg {
    /// The round this message belongs to (`None` for setup-phase
    /// traffic) — same contract as [`Msg::round`].
    pub fn round(&self) -> Option<u32> {
        match self {
            OutMsg::Msg(m) => m.round(),
            OutMsg::Encoded { round, .. } => *round,
        }
    }

    /// The wire encoding: identical bytes whichever variant carried
    /// the message.
    pub fn into_bytes(self) -> Vec<u8> {
        match self {
            OutMsg::Msg(m) => m.encode(),
            OutMsg::Encoded { bytes, .. } => bytes,
        }
    }
}

impl From<Msg> for OutMsg {
    fn from(m: Msg) -> Self {
        OutMsg::Msg(m)
    }
}

/// Messages and notes a party produced while handling one event.
#[derive(Default)]
pub struct Outbox {
    /// Protocol messages to route: (destination, message).
    pub msgs: Vec<(Addr, OutMsg)>,
    /// Driver notes (loss, predictions, round completion).
    pub notes: Vec<Note>,
}

impl Outbox {
    pub fn send(&mut self, to: Addr, msg: Msg) {
        self.msgs.push((to, OutMsg::Msg(msg)));
    }

    /// Queue an already-wrapped [`OutMsg`] (structured or pre-encoded).
    pub fn send_out(&mut self, to: Addr, msg: OutMsg) {
        self.msgs.push((to, msg));
    }

    /// Queue pre-encoded message bytes (the zero-copy chunk path).
    /// `bytes` must obey the frame-encode rule documented on
    /// [`OutMsg`].
    pub fn send_encoded(&mut self, to: Addr, round: Option<u32>, bytes: Vec<u8>) {
        self.msgs.push((to, OutMsg::Encoded { round, bytes }));
    }

    pub fn note(&mut self, n: Note) {
        self.notes.push(n);
    }
}

/// An event-driven protocol participant.
///
/// `Send` is required so transports may run each party on its own
/// thread; parties built on the reference backend are trivially `Send`,
/// and the PJRT engine is shared behind a `Sync` handle.
pub trait Party: Send {
    /// This party's network address (stable across rounds).
    fn addr(&self) -> Addr;

    /// Round boundary: reset per-round state and, for initiating
    /// parties, emit the round's opening messages.
    fn on_round_start(&mut self, spec: &RoundSpec, out: &mut Outbox) -> Result<()>;

    /// A protocol message arrived. Per-sender FIFO ordering is
    /// guaranteed by every transport; cross-sender order is not.
    fn on_message(&mut self, from: Addr, msg: Msg, out: &mut Outbox) -> Result<()>;

    /// The transport detected quiescence: no traffic in flight (sim) or
    /// none for the stall timeout (threads, TCP), yet the round has not
    /// completed. A party that can act on missing peers — the
    /// aggregator's dropout recovery — pushes recovery traffic into
    /// `out`; everyone else leaves it empty. Returning an error aborts
    /// the run (e.g. [`DropoutError`](crate::secagg::DropoutError) when
    /// fewer than t clients survive).
    fn on_stall(&mut self, _out: &mut Outbox) -> Result<()> {
        Ok(())
    }

    /// Driver bookkeeping: the scheduler observed `round`'s `RoundDone`
    /// note. Under the pipelined window a round's *announcement* no
    /// longer implies its predecessor finished (rounds are announced
    /// ahead), so the aggregator needs this signal to tell "the active
    /// party is still finishing an earlier round" apart from "the
    /// active party died without opening the round" during stall
    /// diagnosis. Transports deliver it to the aggregator only; it is
    /// not protocol traffic and is never metered.
    fn on_round_complete(&mut self, _round: u32) {}

    /// Whether this party may run concurrently with its peers. False
    /// when it holds a shared engine handle that is not audited for
    /// cross-thread use; `ThreadedTransport` refuses such party sets.
    fn concurrent_safe(&self) -> bool {
        true
    }

    /// Harvest the party's CPU meters after the run (leaves empty
    /// meters behind).
    fn take_metrics(&mut self) -> Metrics;

    /// The final model parameters, for the party that owns them (the
    /// active party); `None` for everyone else.
    fn final_params(&mut self) -> Option<ModelParams> {
        None
    }
}

// ---------------------------------------------------------------------------
// Wire codecs for the driver-control plane (used by the TCP transport;
// in-process transports pass these types directly).
// ---------------------------------------------------------------------------

fn phase_tag(p: Phase) -> u8 {
    match p {
        Phase::Setup => 0,
        Phase::Training => 1,
        Phase::Testing => 2,
    }
}

fn phase_from(t: u8) -> Result<Phase> {
    Ok(match t {
        0 => Phase::Setup,
        1 => Phase::Training,
        2 => Phase::Testing,
        t => anyhow::bail!("bad phase tag {t}"),
    })
}

fn kind_tag(k: RoundKind) -> u8 {
    match k {
        RoundKind::Setup => 0,
        RoundKind::Train => 1,
        RoundKind::Test => 2,
    }
}

fn kind_from(t: u8) -> Result<RoundKind> {
    Ok(match t {
        0 => RoundKind::Setup,
        1 => RoundKind::Train,
        2 => RoundKind::Test,
        t => anyhow::bail!("bad round kind tag {t}"),
    })
}

impl RoundSpec {
    pub fn encode_into(&self, w: &mut Writer) {
        w.u32(self.round);
        w.u8(kind_tag(self.kind));
        w.u8(self.rotate as u8);
        w.u8(phase_tag(self.phase));
        w.u64s(&self.ids);
    }

    pub fn decode_from(r: &mut Reader) -> Result<RoundSpec> {
        Ok(RoundSpec {
            round: r.u32()?,
            kind: kind_from(r.u8()?)?,
            rotate: r.u8()? != 0,
            phase: phase_from(r.u8()?)?,
            ids: r.u64s()?,
        })
    }
}

const N_LOSS: u8 = 1;
const N_PREDICTIONS: u8 = 2;
const N_ROUND_DONE: u8 = 3;
const N_FAILED: u8 = 4;
const N_STALL: u8 = 5;
const N_WINDOW_DRAIN: u8 = 6;

impl Note {
    pub fn encode_into(&self, w: &mut Writer) {
        match self {
            Note::Loss { round, loss } => {
                w.u8(N_LOSS);
                w.u32(*round);
                w.f32(*loss);
            }
            Note::Predictions { round, probs } => {
                w.u8(N_PREDICTIONS);
                w.u32(*round);
                w.f32s(probs);
            }
            Note::RoundDone { round } => {
                w.u8(N_ROUND_DONE);
                w.u32(*round);
            }
            Note::Failed { who, error } => {
                w.u8(N_FAILED);
                w.u16(*who);
                w.bytes(error.as_bytes());
            }
            Note::Stall { acted, processed } => {
                w.u8(N_STALL);
                w.u8(*acted as u8);
                w.u64(*processed);
            }
            Note::WindowDrain { round } => {
                w.u8(N_WINDOW_DRAIN);
                w.u32(*round);
            }
        }
    }

    pub fn decode_from(r: &mut Reader) -> Result<Note> {
        Ok(match r.u8()? {
            N_LOSS => Note::Loss { round: r.u32()?, loss: r.f32()? },
            N_PREDICTIONS => Note::Predictions { round: r.u32()?, probs: r.f32s()? },
            N_ROUND_DONE => Note::RoundDone { round: r.u32()? },
            N_FAILED => Note::Failed {
                who: r.u16()?,
                error: String::from_utf8_lossy(&r.bytes()?).into_owned(),
            },
            N_STALL => Note::Stall { acted: r.u8()? != 0, processed: r.u64()? },
            N_WINDOW_DRAIN => Note::WindowDrain { round: r.u32()? },
            t => anyhow::bail!("bad note tag {t}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_spec_roundtrip() {
        let spec = RoundSpec {
            round: 42,
            kind: RoundKind::Train,
            rotate: true,
            phase: Phase::Training,
            ids: vec![1, u64::MAX, 7],
        };
        let mut w = Writer::new();
        spec.encode_into(&mut w);
        let buf = w.finish();
        let mut r = Reader::new(&buf);
        assert_eq!(RoundSpec::decode_from(&mut r).unwrap(), spec);
        assert!(r.done());
    }

    #[test]
    fn note_roundtrip() {
        for n in [
            Note::Loss { round: 3, loss: 0.25 },
            Note::Predictions { round: 9, probs: vec![0.5, 0.125] },
            Note::RoundDone { round: SETUP_ROUND },
            Note::Failed { who: 2, error: "boom".into() },
            Note::Stall { acted: true, processed: 42 },
            Note::WindowDrain { round: 3 },
        ] {
            let mut w = Writer::new();
            n.encode_into(&mut w);
            let buf = w.finish();
            let mut r = Reader::new(&buf);
            assert_eq!(Note::decode_from(&mut r).unwrap(), n);
            assert!(r.done());
        }
    }
}
