//! Party state machines: the active party, passive parties, and the
//! aggregator (§4 of the paper).
//!
//! All parties are driven by the single-threaded orchestrator in
//! [`super::trainer`]; every inter-party byte flows through the
//! byte-metered [`Network`](crate::net::Network), and every security
//! operation runs inside a [`Metrics`](super::metrics::Metrics)
//! overhead timer.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::crypto::aead;
use crate::crypto::rng::DetRng;
use crate::data::partition::{ActiveData, PassiveData};
use crate::model::linalg::Mat;
use crate::model::{ModelConfig, ModelParams};
use crate::net::wire::Writer;
use crate::secagg::{ClientSession, FixedPoint, PublishedKeys};

use super::config::SecurityMode;
use super::messages::{Msg, WireKeys};

/// Gradient-vector layout: every party reports a full-length flat
/// gradient (Eq. 6's indicator zeroing what it doesn't own), so the
/// pairwise masks — which must be identically shaped across parties —
/// telescope over the whole vector.
#[derive(Clone, Debug)]
pub struct GradLayout {
    pub active_w: (usize, usize), // (offset, len)
    pub active_b: (usize, usize),
    pub groups: Vec<(usize, usize)>,
    pub total: usize,
}

impl GradLayout {
    pub fn new(cfg: &ModelConfig) -> Self {
        let h = cfg.hidden;
        let mut off = 0usize;
        let active_w = (off, cfg.active_dim * h);
        off += active_w.1;
        let active_b = (off, h);
        off += h;
        let groups = cfg
            .group_dims
            .iter()
            .map(|&d| {
                let e = (off, d * h);
                off += d * h;
                e
            })
            .collect();
        GradLayout { active_w, active_b, groups, total: off }
    }
}

/// Convert a ClientSession publication to the wire representation.
pub fn keys_to_wire(pk: &PublishedKeys) -> WireKeys {
    WireKeys {
        from: pk.from as u16,
        keys: pk.keys.iter().map(|k| k.map(|p| p.0)).collect(),
    }
}

/// Rebuild `PublishedKeys` from the wire.
pub fn keys_from_wire(wk: &WireKeys) -> PublishedKeys {
    PublishedKeys {
        from: wk.from as usize,
        keys: wk.keys.iter().map(|k| k.map(crate::crypto::x25519::PublicKey)).collect(),
    }
}

/// AAD used for sample-ID sealing.
const BATCH_AAD: &[u8] = b"vfl-sa/batch-id/v1";

/// Seal one 8-byte sample ID for a holder under the pairwise channel
/// key. Nonce binds (active=0, round, seq), so entries are never
/// nonce-reused within a key epoch (rotation refreshes keys).
pub fn seal_id(key: &[u8; 32], round: u32, seq: u32, id: u64) -> Vec<u8> {
    let nonce = aead::make_nonce(0, round, seq);
    aead::seal(key, &nonce, BATCH_AAD, &id.to_le_bytes())
}

/// Attempt to open a sealed ID (returns None if not ours).
pub fn open_id(key: &[u8; 32], round: u32, seq: u32, sealed: &[u8]) -> Option<u64> {
    let nonce = aead::make_nonce(0, round, seq);
    let pt = aead::open(key, &nonce, BATCH_AAD, sealed)?;
    Some(u64::from_le_bytes(pt.try_into().ok()?))
}

// ---------------------------------------------------------------------------
// Active party
// ---------------------------------------------------------------------------

pub struct ActiveParty {
    /// Client index (always 0).
    pub id: usize,
    pub data: ActiveData,
    /// All party weights (active module + every group module). The
    /// active party owns initialization and the SGD step (§4.0.2).
    pub params: ModelParams,
    /// Per group: sample id → holder client index (from PSI alignment).
    pub holders: Vec<HashMap<u64, usize>>,
    pub session: Option<ClientSession>,
    pub cfg: ModelConfig,
    pub security: SecurityMode,
    pub layout: GradLayout,
    /// id → row index (for feature/label lookup).
    index: HashMap<u64, usize>,
    /// Cached per-round state for the backward pass.
    last_batch_x: Option<Mat>,
}

impl ActiveParty {
    pub fn new(
        data: ActiveData,
        holders: Vec<HashMap<u64, usize>>,
        cfg: ModelConfig,
        security: SecurityMode,
        seed: u64,
    ) -> Self {
        let params = ModelParams::init(&cfg, seed);
        let layout = GradLayout::new(&cfg);
        let index = data.ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        ActiveParty {
            id: 0,
            data,
            params,
            holders,
            session: None,
            cfg,
            security,
            layout,
            index,
            last_batch_x: None,
        }
    }

    /// Begin a setup epoch: generate per-peer keypairs.
    pub fn begin_setup(&mut self, n_clients: usize, epoch: u64, rng: &mut DetRng) -> Msg {
        let s = ClientSession::new(self.id, n_clients, epoch, rng);
        let msg = Msg::PublishKeys(keys_to_wire(&s.published_keys()));
        self.session = Some(s);
        msg
    }

    pub fn finish_setup(&mut self, all: &[WireKeys]) {
        let keys: Vec<PublishedKeys> = all.iter().map(keys_from_wire).collect();
        self.session.as_mut().expect("setup started").derive_secrets(&keys);
    }

    /// Seal one mini-batch's IDs for their holders (training phase:
    /// includes labels, which the paper deems safe to share, §4.0.2).
    pub fn make_batch(&self, ids: &[u64], round: u32) -> Msg {
        let labels: Vec<f32> = ids.iter().map(|id| self.data.labels[self.index[id]]).collect();
        self.make_batch_inner(ids, labels, round)
    }

    /// Testing-phase variant (§4.0.3): no labels leave the active party.
    pub fn make_batch_unlabeled(&self, ids: &[u64], round: u32) -> Msg {
        self.make_batch_inner(ids, Vec::new(), round)
    }

    fn make_batch_inner(&self, ids: &[u64], labels: Vec<f32>, round: u32) -> Msg {
        if self.security.is_secure() {
            let session = self.session.as_ref().expect("setup done");
            let batch = ids.len();
            let n_groups = self.holders.len();
            let mut entries = Vec::with_capacity(batch * n_groups);
            for (g, holder_map) in self.holders.iter().enumerate() {
                for (pos, &id) in ids.iter().enumerate() {
                    let holder = *holder_map.get(&id).expect("holder known via PSI");
                    let key = session.channel_key(holder);
                    let seq = (g * batch + pos) as u32;
                    entries.push(seal_id(&key, round, seq, id));
                }
            }
            Msg::BatchSelect { round, labels, entries }
        } else {
            Msg::PlainBatch { round, labels, ids: ids.to_vec() }
        }
    }

    /// The flat party weights to redistribute this round.
    pub fn group_weights_flat(&self) -> Vec<f32> {
        self.params.flatten()
    }

    /// Build this round's feature matrix for the selected batch.
    pub fn batch_features(&mut self, ids: &[u64]) -> Mat {
        let d = self.data.dim;
        let mut x = Mat::zeros(ids.len(), d);
        for (r, id) in ids.iter().enumerate() {
            let i = self.index[id];
            x.data[r * d..(r + 1) * d].copy_from_slice(&self.data.x[i]);
        }
        self.last_batch_x = Some(x.clone());
        x
    }

    /// Mask an activation for upload (Eq. 2). Returns the message.
    pub fn masked_activation(&self, round: u32, z: &Mat) -> Msg {
        match self.security {
            SecurityMode::SecureExact => {
                let words =
                    self.session.as_ref().unwrap().mask_tensor(&z.data, round as u64, 0);
                Msg::MaskedActivation { round, from: self.id as u16, words }
            }
            SecurityMode::SecureFloat => {
                let vals =
                    self.session.as_ref().unwrap().mask_tensor_f32(&z.data, round as u64, 0);
                Msg::FloatActivation { round, from: self.id as u16, vals }
            }
            SecurityMode::Plain => {
                Msg::FloatActivation { round, from: self.id as u16, vals: z.data.clone() }
            }
        }
    }

    /// The cached batch features (for the backward pass).
    pub fn last_x(&self) -> &Mat {
        self.last_batch_x.as_ref().expect("forward ran")
    }

    /// The active party's own full-length gradient contribution,
    /// masked with its total mask n₀ (Eq. 3). Adding this to the
    /// aggregator's passive sum cancels every mask — the full gradient
    /// becomes visible ONLY here (§4.0.2's privacy argument).
    pub fn own_grad_contribution(&self, round: u32, own_dw: &Mat, own_db: &[f32]) -> GradSum {
        let l = self.layout.total;
        let mut own = vec![0.0f32; l];
        own[self.layout.active_w.0..self.layout.active_w.0 + self.layout.active_w.1]
            .copy_from_slice(&own_dw.data);
        own[self.layout.active_b.0..self.layout.active_b.0 + self.layout.active_b.1]
            .copy_from_slice(own_db);
        match self.security {
            SecurityMode::SecureExact => {
                GradSum::Words(self.session.as_ref().unwrap().mask_tensor(&own, round as u64, 1))
            }
            SecurityMode::SecureFloat => GradSum::Floats(
                self.session.as_ref().unwrap().mask_tensor_f32(&own, round as u64, 1),
            ),
            SecurityMode::Plain => GradSum::Floats(own),
        }
    }

    /// Unmask the full gradient (aggregator sum + own contribution) and
    /// apply SGD. Returns the new flat party weights.
    pub fn apply_gradients(&mut self, grad_sum: GradSum, own: GradSum, lr: f32) -> Result<Vec<f32>> {
        let l = self.layout.total;
        let full: Vec<f32> = match (grad_sum, own) {
            (GradSum::Words(words), GradSum::Words(own_w)) => {
                if words.len() != l {
                    bail!("gradient sum length {} != {}", words.len(), l);
                }
                let fp = FixedPoint::default();
                let mut acc = words;
                for (a, w) in acc.iter_mut().zip(&own_w) {
                    *a = a.wrapping_add(*w);
                }
                fp.decode_vec(&acc)
            }
            (GradSum::Floats(vals), GradSum::Floats(own_f)) => {
                vals.iter().zip(&own_f).map(|(a, b)| a + b).collect()
            }
            _ => bail!("gradient sum domain mismatch"),
        };

        // SGD on all party weights
        let (ow, lw) = self.layout.active_w;
        for (w, g) in self.params.active.w.data.iter_mut().zip(&full[ow..ow + lw]) {
            *w -= lr * g;
        }
        let (ob, lb) = self.layout.active_b;
        if let Some(b) = self.params.active.b.as_mut() {
            for (w, g) in b.iter_mut().zip(&full[ob..ob + lb]) {
                *w -= lr * g;
            }
        }
        for (gi, &(og, lg)) in self.layout.groups.iter().enumerate() {
            for (w, g) in self.params.groups[gi].w.data.iter_mut().zip(&full[og..og + lg]) {
                *w -= lr * g;
            }
        }
        Ok(self.params.flatten())
    }
}

/// The aggregator→active gradient sum, in either mask domain.
pub enum GradSum {
    Words(Vec<u64>),
    Floats(Vec<f32>),
}

// ---------------------------------------------------------------------------
// Passive party
// ---------------------------------------------------------------------------

pub struct PassiveParty {
    /// Client index (1-based among clients; active is 0).
    pub id: usize,
    pub group: usize,
    pub dim: usize,
    pub hidden: usize,
    pub data: PassiveData,
    pub session: Option<ClientSession>,
    pub security: SecurityMode,
    pub layout: GradLayout,
    /// Current group weights (distributed by the aggregator).
    pub weights: Mat,
    /// Cached batch features for the backward pass.
    last_batch_x: Option<Mat>,
}

impl PassiveParty {
    pub fn new(
        id: usize,
        data: PassiveData,
        cfg: &ModelConfig,
        security: SecurityMode,
    ) -> Self {
        let group = data.group;
        let dim = data.dim;
        PassiveParty {
            id,
            group,
            dim,
            hidden: cfg.hidden,
            data,
            session: None,
            security,
            layout: GradLayout::new(cfg),
            weights: Mat::zeros(dim, cfg.hidden),
            last_batch_x: None,
        }
    }

    pub fn begin_setup(&mut self, n_clients: usize, epoch: u64, rng: &mut DetRng) -> Msg {
        let s = ClientSession::new(self.id, n_clients, epoch, rng);
        let msg = Msg::PublishKeys(keys_to_wire(&s.published_keys()));
        self.session = Some(s);
        msg
    }

    pub fn finish_setup(&mut self, all: &[WireKeys]) {
        let keys: Vec<PublishedKeys> = all.iter().map(keys_from_wire).collect();
        self.session.as_mut().expect("setup started").derive_secrets(&keys);
    }

    /// Decrypt what we can from the sealed ID broadcast (§4.0.2): every
    /// entry is tried; only those sealed under our pairwise key open.
    /// Returns (position-in-batch, id) pairs.
    pub fn resolve_batch(&self, round: u32, entries: &[Vec<u8>], batch: usize) -> Vec<(usize, u64)> {
        let session = self.session.as_ref().expect("setup done");
        let key = session.channel_key(0); // channel with the active party
        let mut out = Vec::new();
        for (seq, sealed) in entries.iter().enumerate() {
            if let Some(id) = open_id(&key, round, seq as u32, sealed) {
                if self.data.rows.contains_key(&id) {
                    out.push((seq % batch, id));
                }
            }
        }
        out
    }

    /// Plain-mode batch resolution.
    pub fn resolve_plain(&self, ids: &[u64]) -> Vec<(usize, u64)> {
        ids.iter()
            .enumerate()
            .filter(|(_, id)| self.data.rows.contains_key(id))
            .map(|(p, &id)| (p, id))
            .collect()
    }

    /// Build the (B × d) feature matrix, zero rows for absent samples
    /// (Eq. 2's indicator function).
    pub fn batch_features(&mut self, resolved: &[(usize, u64)], batch: usize) -> Mat {
        let mut x = Mat::zeros(batch, self.dim);
        for &(pos, id) in resolved {
            let row = &self.data.rows[&id];
            x.data[pos * self.dim..(pos + 1) * self.dim].copy_from_slice(row);
        }
        self.last_batch_x = Some(x.clone());
        x
    }

    pub fn last_x(&self) -> &Mat {
        self.last_batch_x.as_ref().expect("forward ran")
    }

    /// Mask an activation for upload (Eq. 2).
    pub fn masked_activation(&self, round: u32, z: &Mat) -> Msg {
        match self.security {
            SecurityMode::SecureExact => {
                let words =
                    self.session.as_ref().unwrap().mask_tensor(&z.data, round as u64, 0);
                Msg::MaskedActivation { round, from: self.id as u16, words }
            }
            SecurityMode::SecureFloat => {
                let vals =
                    self.session.as_ref().unwrap().mask_tensor_f32(&z.data, round as u64, 0);
                Msg::FloatActivation { round, from: self.id as u16, vals }
            }
            SecurityMode::Plain => {
                Msg::FloatActivation { round, from: self.id as u16, vals: z.data.clone() }
            }
        }
    }

    /// Embed the local weight gradient into the full-length layout and
    /// mask it (Eq. 6).
    pub fn masked_gradient(&self, round: u32, dw: &Mat) -> Msg {
        let l = self.layout.total;
        let (off, len) = self.layout.groups[self.group];
        assert_eq!(dw.data.len(), len);
        let mut full = vec![0.0f32; l];
        full[off..off + len].copy_from_slice(&dw.data);
        match self.security {
            SecurityMode::SecureExact => {
                let words = self.session.as_ref().unwrap().mask_tensor(&full, round as u64, 1);
                Msg::MaskedGradient { round, from: self.id as u16, words }
            }
            SecurityMode::SecureFloat => {
                let vals =
                    self.session.as_ref().unwrap().mask_tensor_f32(&full, round as u64, 1);
                Msg::FloatGradient { round, from: self.id as u16, vals }
            }
            SecurityMode::Plain => {
                Msg::FloatGradient { round, from: self.id as u16, vals: full }
            }
        }
    }

    /// Install redistributed group weights.
    pub fn set_weights(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.dim * self.hidden, "group weight size");
        self.weights = Mat::from_vec(self.dim, self.hidden, flat.to_vec());
    }
}

// ---------------------------------------------------------------------------
// Aggregator
// ---------------------------------------------------------------------------

/// The aggregator: relays traffic, owns the global module, sums masked
/// vectors (masks cancel per Eq. 4-5), and never sees an individual
/// party's plaintext tensor.
pub struct Aggregator {
    pub n_clients: usize,
    pub hidden: usize,
    /// Global module Linear(hidden, 1) — lives here per §6.2.
    pub global_w: Vec<f32>,
    pub global_b: f32,
    pub fp: FixedPoint,
}

impl Aggregator {
    pub fn new(cfg: &ModelConfig, seed: u64) -> Self {
        // aggregator receives the initial global module from the active
        // party's init (same seed → same init as ModelParams::init)
        let params = ModelParams::init(cfg, seed);
        Aggregator {
            n_clients: cfg.n_clients(),
            hidden: cfg.hidden,
            global_w: params.global.w.data,
            global_b: params.global.b,
            fp: FixedPoint::default(),
        }
    }

    /// Sum masked activations into the clear aggregate z (Eq. 5).
    pub fn sum_activations_exact(&self, batch: usize, parts: &[Vec<u64>]) -> Mat {
        assert_eq!(parts.len(), self.n_clients, "need every client's share");
        let mut acc = vec![0u64; batch * self.hidden];
        for p in parts {
            assert_eq!(p.len(), acc.len());
            for (a, v) in acc.iter_mut().zip(p) {
                *a = a.wrapping_add(*v);
            }
        }
        Mat::from_vec(batch, self.hidden, self.fp.decode_vec(&acc))
    }

    pub fn sum_activations_float(&self, batch: usize, parts: &[Vec<f32>]) -> Mat {
        assert_eq!(parts.len(), self.n_clients);
        let mut acc = vec![0.0f32; batch * self.hidden];
        for p in parts {
            for (a, v) in acc.iter_mut().zip(p) {
                *a += v;
            }
        }
        Mat::from_vec(batch, self.hidden, acc)
    }

    /// Sum the passives' masked gradients. The result is still masked
    /// by the active party's total mask (its share is absent), so the
    /// aggregator learns nothing (§4.0.2).
    pub fn sum_gradients_exact(&self, parts: &[Vec<u64>]) -> Vec<u64> {
        let l = parts[0].len();
        let mut acc = vec![0u64; l];
        for p in parts {
            assert_eq!(p.len(), l);
            for (a, v) in acc.iter_mut().zip(p) {
                *a = a.wrapping_add(*v);
            }
        }
        acc
    }

    pub fn sum_gradients_float(&self, parts: &[Vec<f32>]) -> Vec<f32> {
        let l = parts[0].len();
        let mut acc = vec![0.0f32; l];
        for p in parts {
            for (a, v) in acc.iter_mut().zip(p) {
                *a += v;
            }
        }
        acc
    }

    /// Apply the global-module SGD update (the aggregator computes
    /// dwg/dbg itself from the clear z — which is legitimately public
    /// to it under the protocol).
    pub fn update_global(&mut self, d_w: &[f32], d_b: f32, lr: f32) {
        for (w, g) in self.global_w.iter_mut().zip(d_w) {
            *w -= lr * g;
        }
        self.global_b -= lr * d_b;
    }
}

/// Helper: serialize a message and return (encoded, byte length).
pub fn encode_msg(m: &Msg) -> Vec<u8> {
    let mut w = Writer::new();
    w.buf = m.encode();
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_layout_offsets() {
        let cfg = ModelConfig::for_dataset("banking").unwrap();
        let l = GradLayout::new(&cfg);
        assert_eq!(l.active_w, (0, 57 * 64));
        assert_eq!(l.active_b, (57 * 64, 64));
        assert_eq!(l.groups[0], (57 * 64 + 64, 3 * 64));
        assert_eq!(l.groups[1], (57 * 64 + 64 + 3 * 64, 20 * 64));
        assert_eq!(l.total, 57 * 64 + 64 + 3 * 64 + 20 * 64);
    }

    #[test]
    fn seal_open_id() {
        let key = [9u8; 32];
        let sealed = seal_id(&key, 3, 17, 0xdeadbeef);
        assert_eq!(sealed.len(), 8 + 16); // id + tag
        assert_eq!(open_id(&key, 3, 17, &sealed), Some(0xdeadbeef));
        // wrong seq / round / key → None
        assert_eq!(open_id(&key, 3, 18, &sealed), None);
        assert_eq!(open_id(&key, 4, 17, &sealed), None);
        assert_eq!(open_id(&[8u8; 32], 3, 17, &sealed), None);
    }
}
