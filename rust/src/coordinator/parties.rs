//! Party state machines: the active party, passive parties, and the
//! aggregator (§4 of the paper), as event-driven [`Party`]
//! implementations.
//!
//! Each machine owns its deterministic RNG, its CPU meters, and its
//! protocol state, and reacts to round-boundary hooks plus incoming
//! [`Msg`]s by pushing outgoing messages into an [`Outbox`]. Nothing
//! here knows which [`Transport`](crate::net::Transport) is routing the
//! bytes — the same machines run single-threaded inside the
//! byte-metered simulation, one-thread-per-party, or over TCP sockets.
//!
//! Round state is **per-round**: each machine keeps a bounded ring of
//! round contexts keyed by round number (fan-in buffers, assemblers,
//! batch caches, pending sums), and incoming messages route to their
//! context by the `round` tag every protocol message carries. That is
//! what lets the windowed scheduler ([`window`](super::window),
//! `--rounds-in-flight`) keep several rounds in flight: contexts are
//! created at announcement, detached while an event operates on them,
//! and retired in completion order when their round's last obligation
//! is met. The active party enforces the one true cross-round data
//! dependency — round *r + 1*'s weights depend on round *r*'s SGD — by
//! deferring a training round's opening until every earlier round
//! retired; testing rounds are mutually independent and open on
//! announcement.
//!
//! Cross-transport determinism: wherever the §4 protocol fans in
//! (activation sums, gradient sums, key directories), the aggregator
//! buffers contributions keyed by sender and combines them in client
//! order, so float addition order — and therefore every output bit —
//! is independent of message arrival order. Chunked fan-ins
//! (`--chunk-words`, [`streaming`](super::streaming)) are exact-ℤ₂⁶⁴
//! only, where wrap-addition is order-independent outright.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::crypto::aead;
use crate::crypto::prg::ExpandPool;
use crate::crypto::rng::DetRng;
use crate::crypto::shamir::Share;
use crate::data::partition::{ActiveData, PassiveData};
use crate::model::linalg::Mat;
use crate::model::{ModelConfig, ModelParams, PartyParams};
use crate::net::wire::Writer;
use crate::net::{Addr, Phase};
use crate::secagg::dropout::{self, RobustClientSession};
use crate::secagg::{ClientSession, DropoutError, FixedPoint, PartySession, PublishedKeys};
use crate::z64;

use super::backend::Backend;
use super::config::SecurityMode;
use super::messages::{begin_gradient_chunk, begin_masked_chunk, Msg, WireKeys};
use super::metrics::{client, Metrics, AGGREGATOR};
use super::party::{Note, OutMsg, Outbox, Party, RoundKind, RoundSpec};
use super::streaming::{
    chunk_plan, ChunkAssembler, ShardLayout, StreamCfg, WorkerPool, CHUNK_MSG_HEADER_BYTES,
    GRAD_CHUNK_MSG_HEADER_BYTES,
};
use super::window::MAX_ROUNDS_IN_FLIGHT;

/// Gradient-vector layout: every party reports a full-length flat
/// gradient (Eq. 6's indicator zeroing what it doesn't own), so the
/// pairwise masks — which must be identically shaped across parties —
/// telescope over the whole vector.
#[derive(Clone, Debug)]
pub struct GradLayout {
    pub active_w: (usize, usize), // (offset, len)
    pub active_b: (usize, usize),
    pub groups: Vec<(usize, usize)>,
    pub total: usize,
}

impl GradLayout {
    pub fn new(cfg: &ModelConfig) -> Self {
        let h = cfg.hidden;
        let mut off = 0usize;
        let active_w = (off, cfg.active_dim * h);
        off += active_w.1;
        let active_b = (off, h);
        off += h;
        let groups = cfg
            .group_dims
            .iter()
            .map(|&d| {
                let e = (off, d * h);
                off += d * h;
                e
            })
            .collect();
        GradLayout { active_w, active_b, groups, total: off }
    }
}

/// Convert a ClientSession publication to the wire representation.
pub fn keys_to_wire(pk: &PublishedKeys) -> WireKeys {
    WireKeys {
        from: pk.from as u16,
        keys: pk.keys.iter().map(|k| k.map(|p| p.0)).collect(),
    }
}

/// Rebuild `PublishedKeys` from the wire.
pub fn keys_from_wire(wk: &WireKeys) -> PublishedKeys {
    PublishedKeys {
        from: wk.from as usize,
        keys: wk.keys.iter().map(|k| k.map(crate::crypto::x25519::PublicKey)).collect(),
    }
}

/// Deterministic per-party RNG: every party derives its own stream
/// from (run seed, client index), so key generation does not depend on
/// the order a transport schedules parties in.
pub fn party_rng(seed: u64, client_idx: usize) -> DetRng {
    DetRng::from_seed(
        seed ^ 0x5eed_0f5a ^ (client_idx as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15),
    )
}

/// Tensor tags of the two masked fan-ins (must match what the parties
/// pass to `mask_tensor`). Shared with the tree topology layer, which
/// tags its leaf [`Msg::PartialSum`]s with the same values.
pub(crate) const TAG_ACTIVATION: u32 = 0;
pub(crate) const TAG_GRADIENT: u32 = 1;

/// Build the upload for one masked ℤ₂⁶⁴ tensor: a single monolithic
/// message, or — when the streaming pipeline is on (`chunk_words`
/// set) — the equivalent `MaskedChunk` stream, masked window by window
/// through the seekable PRG so no full-tensor mask is ever
/// materialized. Chunked windows go out *zero-copy*: the wire header
/// is built into an exact-capacity [`Writer`] and the masked words are
/// encoded straight behind it ([`ClientSession::mask_tensor_window_into`]),
/// so no intermediate `Vec<u64>` or re-encode exists between the PRG
/// and the transport. The bytes are identical to what
/// `Msg::MaskedChunk { .. }.encode()` would produce (the frame-encode
/// rule), so metering and every receiver are unchanged.
/// With an [`ExpandPool`] (`--expand-workers` > 1) the expansion fans
/// out across cores: chunked senders mask one chunk per pool job (each
/// job runs the identical header + [`crate::secagg::mask_window_into`]
/// encode the serial loop runs, against its own clone of the seekable
/// stream) and the monolithic path partitions the tensor into
/// per-worker sub-windows — both stitched in plan/offset order, so the
/// produced bytes are bit-identical to serial for any worker count.
fn masked_exact_msgs(
    session: &ClientSession,
    stream: StreamCfg,
    expand: Option<&ExpandPool>,
    round: u32,
    from: u16,
    tag: u32,
    vals: &[f32],
) -> Vec<OutMsg> {
    match stream.chunk_words {
        Some(cw) => {
            let layout = ShardLayout::new(vals.len(), stream.shards);
            let mask = session.total_mask_stream(round as u64, tag);
            let plan = chunk_plan(layout, cw);
            if let Some(pool) = expand.filter(|p| p.workers() > 1 && plan.len() > 1) {
                let total = vals.len() as u32;
                let fp = session.fp;
                let jobs: Vec<Box<dyn FnOnce() -> Vec<u8> + Send + 'static>> = plan
                    .iter()
                    .map(|&c| {
                        let mask = mask.clone();
                        let vals = vals[c.offset..c.offset + c.len].to_vec();
                        let f: Box<dyn FnOnce() -> Vec<u8> + Send + 'static> =
                            Box::new(move || {
                                let mut w = Writer::with_capacity(
                                    CHUNK_MSG_HEADER_BYTES as usize + 8 * c.len,
                                );
                                begin_masked_chunk(
                                    &mut w,
                                    round,
                                    from,
                                    tag as u8,
                                    c.shard as u16,
                                    c.offset as u32,
                                    total,
                                    c.len as u32,
                                );
                                crate::secagg::mask_window_into(fp, &mask, &vals, c.offset, &mut w);
                                w.finish()
                            });
                        f
                    })
                    .collect();
                return pool
                    .run(jobs)
                    .into_iter()
                    .map(|bytes| OutMsg::Encoded { round: Some(round), bytes })
                    .collect();
            }
            plan.into_iter()
                .map(|c| {
                    let mut w =
                        Writer::with_capacity(CHUNK_MSG_HEADER_BYTES as usize + 8 * c.len);
                    begin_masked_chunk(
                        &mut w,
                        round,
                        from,
                        tag as u8,
                        c.shard as u16,
                        c.offset as u32,
                        vals.len() as u32,
                        c.len as u32,
                    );
                    session.mask_tensor_window_into(
                        &mask,
                        &vals[c.offset..c.offset + c.len],
                        c.offset,
                        &mut w,
                    );
                    OutMsg::Encoded { round: Some(round), bytes: w.finish() }
                })
                .collect()
        }
        None => {
            let words = match expand {
                Some(pool) => session.mask_tensor_pooled(pool, vals, round as u64, tag),
                None => session.mask_tensor(vals, round as u64, tag),
            };
            vec![OutMsg::Msg(if tag == TAG_ACTIVATION {
                Msg::MaskedActivation { round, from, words }
            } else {
                Msg::MaskedGradient { round, from, words }
            })]
        }
    }
}

/// The per-party mask-expansion pool, spawned only when
/// `--expand-workers` asks for parallelism (1 = today's inline serial
/// path, no threads). Every party — active, passive, aggregator —
/// builds its own, since each masks (or corrects) its own tensors.
fn expand_pool(stream: &StreamCfg) -> Option<ExpandPool> {
    (stream.expand_workers > 1).then(|| ExpandPool::new(stream.expand_workers))
}

/// AAD used for sample-ID sealing.
const BATCH_AAD: &[u8] = b"vfl-sa/batch-id/v1";

// ---------------------------------------------------------------------------
// Dropout-tolerance client helpers (shared by active & passive parties)
// ---------------------------------------------------------------------------

/// Open a fresh session for one setup epoch: plain, or — when a Shamir
/// threshold is configured — robust (seed-derived keys + share state).
fn open_session(
    id: usize,
    n: usize,
    epoch: u64,
    threshold: Option<usize>,
    rng: &mut DetRng,
) -> PartySession {
    match threshold {
        None => PartySession::Plain(ClientSession::new(id, n, epoch, rng)),
        Some(t) => PartySession::Robust(RobustClientSession::new(id, n, epoch, t, rng)),
    }
}

/// Pad a (possibly incomplete) wire directory to one `PublishedKeys`
/// per client id; absent clients get all-`None` key slots, which
/// `derive_secrets` treats as "no shared secret, no masks". Entries
/// with an out-of-range id (corrupt or hostile wire input) are ignored
/// rather than indexed — the sender then simply has no keys, which the
/// lenient derivation already handles.
pub fn pad_directory(all: &[WireKeys], n: usize) -> Vec<PublishedKeys> {
    let mut keys: Vec<PublishedKeys> =
        (0..n).map(|i| PublishedKeys { from: i, keys: vec![None; n] }).collect();
    for wk in all {
        if (wk.from as usize) < n {
            keys[wk.from as usize] = keys_from_wire(wk);
        }
    }
    keys
}

/// Shamir-share our seed and seal one bundle per peer: the
/// share-distribution leg of the dropout-tolerant setup phase. The
/// message carries a binding commitment to the seed so the aggregator
/// can verify any later reconstruction against what *this* client
/// pinned — a corrupted surrendered share becomes a typed abort.
fn seed_share_msg(session: &mut PartySession, rng: &mut DetRng, epoch: u64) -> Result<Msg> {
    let robust = session.robust_mut().context("seed shares need a robust session")?;
    let shares = robust.share_seed(rng);
    let commitment = robust.commitment();
    let id = robust.inner.id;
    let n = robust.inner.n_clients;
    let mut sealed = vec![Vec::new(); n];
    for (j, bundle) in shares.bundles.iter().enumerate() {
        if j == id || !robust.inner.has_secret(j) {
            continue;
        }
        sealed[j] = dropout::seal_bundle(&robust.inner.channel_key(j), id, j, bundle);
    }
    Ok(Msg::SeedShares { epoch, from: id as u16, commitment, sealed })
}

/// Unseal and store the bundles the aggregator relayed to us. Slots
/// that cannot be genuine — out-of-range owners, owners we share no
/// secret with — are skipped rather than indexed (corrupt or hostile
/// wire input must not panic a client process).
fn store_share_relay(session: &mut PartySession, sealed: &[Vec<u8>]) -> Result<()> {
    let robust = session.robust_mut().context("share relay needs a robust session")?;
    let id = robust.inner.id;
    let n = robust.inner.n_clients;
    for (owner, bytes) in sealed.iter().enumerate() {
        if owner == id || owner >= n || bytes.is_empty() || !robust.inner.has_secret(owner) {
            continue;
        }
        let key = robust.inner.channel_key(owner);
        let shares = dropout::open_bundle(&key, owner, id, bytes)
            .with_context(|| format!("bad seed-share bundle from client {owner}"))?;
        robust.receive_share(owner, shares);
    }
    Ok(())
}

/// Answer a dropout notice: surrender our held shares of each dropped
/// client's seed (skipping any we never received a bundle for).
fn surrender_msg(session: &PartySession, round: u32, dropped: &[u16]) -> Result<Msg> {
    let robust = session.robust().context("dropout notice needs a robust session")?;
    let from = robust.inner.id as u16;
    let bundles: Vec<(u16, Vec<u8>)> = dropped
        .iter()
        .filter_map(|&d| {
            robust.surrender_share(d as usize).map(|s| (d, dropout::encode_shares(s)))
        })
        .collect();
    Ok(Msg::SurrenderShares { round, from, bundles })
}

/// Seal one 8-byte sample ID for a holder under the pairwise channel
/// key. Nonce binds (active=0, round, seq), so entries are never
/// nonce-reused within a key epoch (rotation refreshes keys).
pub fn seal_id(key: &[u8; 32], round: u32, seq: u32, id: u64) -> Vec<u8> {
    let nonce = aead::make_nonce(0, round, seq);
    aead::seal(key, &nonce, BATCH_AAD, &id.to_le_bytes())
}

/// Attempt to open a sealed ID (returns None if not ours).
pub fn open_id(key: &[u8; 32], round: u32, seq: u32, sealed: &[u8]) -> Option<u64> {
    let nonce = aead::make_nonce(0, round, seq);
    let pt = aead::open(key, &nonce, BATCH_AAD, sealed)?;
    Some(u64::from_le_bytes(pt.try_into().ok()?))
}

// ---------------------------------------------------------------------------
// Active party
// ---------------------------------------------------------------------------

/// Per-round protocol context of the active party. One lives per round
/// in flight, keyed by round number — the bounded ring behind
/// `--rounds-in-flight` (incoming messages route to their context by
/// the `round` tag every protocol message carries).
struct ActiveRoundCtx {
    kind: RoundKind,
    /// The round's mini-batch sample ids (from the `RoundSpec`).
    ids: Vec<u64>,
    /// The round's opening messages went out. Training rounds defer
    /// opening until every earlier round's SGD update has landed — the
    /// data dependency that makes window overlap bit-identical.
    opened: bool,
    /// This round's batch features, cached for the backward pass.
    batch_x: Option<Mat>,
    own: Option<GradSum>,
    pending_gsum: Option<GradSum>,
    /// Reassembles the chunked `GradientChunk` downlink (streaming
    /// runs only; single sender, single inline executor).
    gsum_asm: ChunkAssembler,
}

pub struct ActiveParty<'e> {
    /// Client index (always 0).
    pub id: usize,
    pub data: ActiveData,
    /// All party weights (active module + every group module). The
    /// active party owns initialization and the SGD step (§4.0.2).
    pub params: ModelParams,
    /// Per group: sample id → holder client index (from PSI alignment).
    pub holders: Vec<HashMap<u64, usize>>,
    pub session: Option<PartySession>,
    pub cfg: ModelConfig,
    pub security: SecurityMode,
    pub layout: GradLayout,
    /// Shamir threshold for dropout tolerance (None = base protocol).
    threshold: Option<usize>,
    /// Streaming-pipeline parameters (monolithic when not chunked).
    stream: StreamCfg,
    /// Parallel mask-expansion pool (`--expand-workers` > 1 only).
    expand: Option<ExpandPool>,
    backend: Backend<'e>,
    metrics: Metrics,
    rng: DetRng,
    /// id → row index (for feature/label lookup).
    index: HashMap<u64, usize>,
    // --- event-driven round state ---
    /// Current metering phase (every round in flight shares it — the
    /// scheduler's phase barrier).
    phase: Phase,
    /// Live per-round contexts, keyed by round number.
    ctxs: BTreeMap<u32, ActiveRoundCtx>,
    /// The round waiting for a key directory (and, in robust mode, the
    /// seed-share relay) before opening. Setup/rotation rounds are
    /// scheduler barriers, so at most one such round exists at a time.
    await_setup: Option<u32>,
}

impl<'e> ActiveParty<'e> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        data: ActiveData,
        holders: Vec<HashMap<u64, usize>>,
        cfg: ModelConfig,
        security: SecurityMode,
        threshold: Option<usize>,
        stream: StreamCfg,
        seed: u64,
        backend: Backend<'e>,
    ) -> Self {
        let params = ModelParams::init(&cfg, seed);
        let layout = GradLayout::new(&cfg);
        let index = data.ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        ActiveParty {
            id: 0,
            data,
            params,
            holders,
            session: None,
            cfg,
            security,
            layout,
            threshold,
            expand: expand_pool(&stream),
            stream,
            backend,
            metrics: Metrics::new(),
            rng: party_rng(seed, 0),
            index,
            phase: Phase::Setup,
            ctxs: BTreeMap::new(),
            await_setup: None,
        }
    }

    /// A fresh per-round context for `spec`.
    fn new_ctx(&self, spec: &RoundSpec) -> ActiveRoundCtx {
        ActiveRoundCtx {
            kind: spec.kind,
            ids: spec.ids.clone(),
            opened: false,
            batch_x: None,
            own: None,
            pending_gsum: None,
            gsum_asm: ChunkAssembler::inline(
                false,
                self.stream.shards.max(1),
                self.stream.rollback,
            ),
        }
    }

    /// Record elapsed time against this party's current phase.
    fn rec(&mut self, t0: Instant, overhead: bool) {
        self.metrics.record(client(self.id), self.phase, t0.elapsed().as_nanos(), overhead);
    }

    /// Begin a setup epoch: generate per-peer keypairs.
    pub fn begin_setup(&mut self, n_clients: usize, epoch: u64) -> Msg {
        let s = open_session(self.id, n_clients, epoch, self.threshold, &mut self.rng);
        let msg = Msg::PublishKeys(keys_to_wire(&s.client().published_keys()));
        self.session = Some(s);
        msg
    }

    /// Errors if no setup epoch is open (a `KeyDirectory` arriving
    /// before `RequestKeys` is a protocol violation, not a panic).
    pub fn finish_setup(&mut self, all: &[WireKeys]) -> Result<()> {
        let s = self.session.as_mut().context("setup started")?;
        let keys = pad_directory(all, s.client().n_clients);
        s.client_mut().derive_secrets(&keys);
        Ok(())
    }

    /// The masking session (post `begin_setup`).
    fn sess(&self) -> &ClientSession {
        self.session.as_ref().expect("setup done").client()
    }

    /// Seal one mini-batch's IDs for their holders (training phase:
    /// includes labels, which the paper deems safe to share, §4.0.2).
    pub fn make_batch(&self, ids: &[u64], round: u32) -> Msg {
        let labels: Vec<f32> = ids.iter().map(|id| self.data.labels[self.index[id]]).collect();
        self.make_batch_inner(ids, labels, round)
    }

    /// Testing-phase variant (§4.0.3): no labels leave the active party.
    pub fn make_batch_unlabeled(&self, ids: &[u64], round: u32) -> Msg {
        self.make_batch_inner(ids, Vec::new(), round)
    }

    fn make_batch_inner(&self, ids: &[u64], labels: Vec<f32>, round: u32) -> Msg {
        if self.security.is_secure() {
            let session = self.sess();
            let batch = ids.len();
            let n_groups = self.holders.len();
            let mut entries = Vec::with_capacity(batch * n_groups);
            for (g, holder_map) in self.holders.iter().enumerate() {
                for (pos, &id) in ids.iter().enumerate() {
                    let holder = *holder_map.get(&id).expect("holder known via PSI");
                    let seq = (g * batch + pos) as u32;
                    // a holder that dropped during setup has no channel
                    // key: emit an unopenable placeholder so entry
                    // positions (and thus seq numbers) stay aligned
                    if session.has_secret(holder) {
                        let key = session.channel_key(holder);
                        entries.push(seal_id(&key, round, seq, id));
                    } else {
                        entries.push(Vec::new());
                    }
                }
            }
            Msg::BatchSelect { round, labels, entries }
        } else {
            Msg::PlainBatch { round, labels, ids: ids.to_vec() }
        }
    }

    /// The flat party weights to redistribute this round.
    pub fn group_weights_flat(&self) -> Vec<f32> {
        self.params.flatten()
    }

    /// Build one round's feature matrix for the selected batch (the
    /// caller caches it in that round's context for the backward pass).
    pub fn batch_features(&self, ids: &[u64]) -> Mat {
        let d = self.data.dim;
        let mut x = Mat::zeros(ids.len(), d);
        for (r, id) in ids.iter().enumerate() {
            let i = self.index[id];
            x.data[r * d..(r + 1) * d].copy_from_slice(&self.data.x[i]);
        }
        x
    }

    /// Mask an activation for upload (Eq. 2): one monolithic message,
    /// or the chunked stream when the streaming pipeline is on.
    pub fn masked_activation(&self, round: u32, z: &Mat) -> Vec<OutMsg> {
        match self.security {
            SecurityMode::SecureExact => masked_exact_msgs(
                self.sess(),
                self.stream,
                self.expand.as_ref(),
                round,
                self.id as u16,
                TAG_ACTIVATION,
                &z.data,
            ),
            SecurityMode::SecureFloat => {
                let vals = self.sess().mask_tensor_f32(&z.data, round as u64, TAG_ACTIVATION);
                vec![Msg::FloatActivation { round, from: self.id as u16, vals }.into()]
            }
            SecurityMode::Plain => {
                vec![Msg::FloatActivation { round, from: self.id as u16, vals: z.data.clone() }
                    .into()]
            }
        }
    }

    /// The active party's own full-length gradient contribution,
    /// masked with its total mask n₀ (Eq. 3). Adding this to the
    /// aggregator's passive sum cancels every mask — the full gradient
    /// becomes visible ONLY here (§4.0.2's privacy argument).
    pub fn own_grad_contribution(&self, round: u32, own_dw: &Mat, own_db: &[f32]) -> GradSum {
        let l = self.layout.total;
        let mut own = vec![0.0f32; l];
        own[self.layout.active_w.0..self.layout.active_w.0 + self.layout.active_w.1]
            .copy_from_slice(&own_dw.data);
        own[self.layout.active_b.0..self.layout.active_b.0 + self.layout.active_b.1]
            .copy_from_slice(own_db);
        match self.security {
            SecurityMode::SecureExact => GradSum::Words(match &self.expand {
                Some(pool) => {
                    self.sess().mask_tensor_pooled(pool, &own, round as u64, TAG_GRADIENT)
                }
                None => self.sess().mask_tensor(&own, round as u64, TAG_GRADIENT),
            }),
            SecurityMode::SecureFloat => {
                GradSum::Floats(self.sess().mask_tensor_f32(&own, round as u64, TAG_GRADIENT))
            }
            SecurityMode::Plain => GradSum::Floats(own),
        }
    }

    /// Unmask the full gradient (aggregator sum + own contribution) and
    /// apply SGD. Returns the new flat party weights.
    pub fn apply_gradients(&mut self, grad_sum: GradSum, own: GradSum, lr: f32) -> Result<Vec<f32>> {
        let l = self.layout.total;
        let full: Vec<f32> = match (grad_sum, own) {
            (GradSum::Words(words), GradSum::Words(own_w)) => {
                if words.len() != l {
                    bail!("gradient sum length {} != {}", words.len(), l);
                }
                let fp = FixedPoint::default();
                let mut acc = words;
                z64::wrap_add(&mut acc, &own_w);
                fp.decode_vec(&acc)
            }
            (GradSum::Floats(vals), GradSum::Floats(own_f)) => {
                vals.iter().zip(&own_f).map(|(a, b)| a + b).collect()
            }
            _ => bail!("gradient sum domain mismatch"),
        };

        // SGD on all party weights
        let (ow, lw) = self.layout.active_w;
        for (w, g) in self.params.active.w.data.iter_mut().zip(&full[ow..ow + lw]) {
            *w -= lr * g;
        }
        let (ob, lb) = self.layout.active_b;
        if let Some(b) = self.params.active.b.as_mut() {
            for (w, g) in b.iter_mut().zip(&full[ob..ob + lb]) {
                *w -= lr * g;
            }
        }
        for (gi, &(og, lg)) in self.layout.groups.iter().enumerate() {
            for (w, g) in self.params.groups[gi].w.data.iter_mut().zip(&full[og..og + lg]) {
                *w -= lr * g;
            }
        }
        Ok(self.params.flatten())
    }

    /// Open a training round: sealed batch + weights redistribution +
    /// own masked forward activation. The context must be detached
    /// from the ring (take/operate/put-back — see `on_message`).
    fn start_train_round(
        &mut self,
        round: u32,
        ctx: &mut ActiveRoundCtx,
        out: &mut Outbox,
    ) -> Result<()> {
        ctx.opened = true;
        let ids = ctx.ids.clone();
        let t0 = Instant::now();
        let batch_msg = self.make_batch(&ids, round);
        self.rec(t0, self.security.is_secure());
        out.send(Addr::Aggregator, batch_msg);
        out.send(Addr::Aggregator, Msg::WeightsUpdate { round, flat: self.group_weights_flat() });
        self.forward_and_upload(round, ctx, &ids, out)
    }

    /// Open a testing round: unlabeled sealed batch + masked activation.
    fn start_test_round(
        &mut self,
        round: u32,
        ctx: &mut ActiveRoundCtx,
        out: &mut Outbox,
    ) -> Result<()> {
        ctx.opened = true;
        let ids = ctx.ids.clone();
        let t0 = Instant::now();
        let batch_msg = self.make_batch_unlabeled(&ids, round);
        self.rec(t0, self.security.is_secure());
        out.send(Addr::Aggregator, batch_msg);
        self.forward_and_upload(round, ctx, &ids, out)
    }

    fn forward_and_upload(
        &mut self,
        round: u32,
        ctx: &mut ActiveRoundCtx,
        ids: &[u64],
        out: &mut Outbox,
    ) -> Result<()> {
        let xa = self.batch_features(ids);
        ctx.batch_x = Some(xa.clone());
        let a_params = PartyParams {
            w: self.params.active.w.clone(),
            b: self.params.active.b.clone(),
        };
        let t0 = Instant::now();
        let za = self.backend.party_fwd("fwd_active", &xa, &a_params, None);
        self.rec(t0, false);
        let za = za?;
        let t0 = Instant::now();
        let msgs = self.masked_activation(round, &za);
        self.rec(t0, self.security.is_secure());
        for msg in msgs {
            out.send_out(Addr::Aggregator, msg);
        }
        Ok(())
    }

    /// A full gradient sum arrived for `round` (the context is already
    /// detached). Finishes the round if the backward pass ran, else
    /// parks the sum and puts the context back.
    fn on_grad_sum(
        &mut self,
        round: u32,
        mut ctx: ActiveRoundCtx,
        gsum: GradSum,
        out: &mut Outbox,
    ) -> Result<()> {
        if ctx.own.is_some() {
            self.finish_train_round(round, ctx, gsum, out)
        } else {
            // defensive: tolerate the sum overtaking the dz broadcast
            ctx.pending_gsum = Some(gsum);
            self.ctxs.insert(round, ctx);
            Ok(())
        }
    }

    /// Unmask + SGD, retire the round's context, and open the next
    /// deferred round (its parameter dependency is now satisfied).
    fn finish_train_round(
        &mut self,
        round: u32,
        mut ctx: ActiveRoundCtx,
        gsum: GradSum,
        out: &mut Outbox,
    ) -> Result<()> {
        let own = ctx.own.take().context("own gradient contribution missing")?;
        let lr = self.cfg.lr;
        let t0 = Instant::now();
        let res = self.apply_gradients(gsum, own, lr);
        self.rec(t0, false);
        res?;
        out.note(Note::RoundDone { round });
        // ctx dropped here: the round is retired
        self.open_deferred(out)
    }

    /// Open every announced round whose dependencies are satisfied: a
    /// training round may open only when it is the oldest live round
    /// (its parameters depend on every earlier SGD step); testing
    /// rounds are mutually independent and open as soon as no training
    /// round precedes them. Setup/rotation rounds open through
    /// `setup_complete` instead.
    fn open_deferred(&mut self, out: &mut Outbox) -> Result<()> {
        let rounds: Vec<u32> = self.ctxs.keys().copied().collect();
        let mut earlier_live = false;
        let mut earlier_train = false;
        for round in rounds {
            let (kind, opened) = {
                let ctx = &self.ctxs[&round];
                (ctx.kind, ctx.opened)
            };
            if !opened && self.await_setup != Some(round) {
                let can_open = match kind {
                    RoundKind::Train => !earlier_live,
                    RoundKind::Test => !earlier_train,
                    RoundKind::Setup => false,
                };
                if can_open {
                    let mut ctx = self.ctxs.remove(&round).expect("ctx just read");
                    let res = match kind {
                        RoundKind::Train => self.start_train_round(round, &mut ctx, out),
                        RoundKind::Test => self.start_test_round(round, &mut ctx, out),
                        RoundKind::Setup => unreachable!("setup rounds never open here"),
                    };
                    self.ctxs.insert(round, ctx);
                    res?;
                }
            }
            earlier_live = true;
            if kind == RoundKind::Train {
                earlier_train = true;
            }
        }
        Ok(())
    }

    /// The setup phase of the awaited round finished (key directory
    /// installed and, in robust mode, seed shares stored): open the
    /// round proper.
    fn setup_complete(&mut self, out: &mut Outbox) -> Result<()> {
        let Some(round) = self.await_setup.take() else { return Ok(()) };
        let mut ctx = self.ctxs.remove(&round).context("awaited round has a context")?;
        match ctx.kind {
            RoundKind::Setup => out.note(Note::RoundDone { round }), // ctx retired
            RoundKind::Train => {
                self.start_train_round(round, &mut ctx, out)?;
                self.ctxs.insert(round, ctx);
            }
            RoundKind::Test => bail!("testing rounds do not rotate keys"),
        }
        Ok(())
    }
}

impl<'e> Party for ActiveParty<'e> {
    fn addr(&self) -> Addr {
        Addr::Client(self.id)
    }

    fn on_round_start(&mut self, spec: &RoundSpec, out: &mut Outbox) -> Result<()> {
        self.phase = spec.phase;
        if self.ctxs.len() >= MAX_ROUNDS_IN_FLIGHT {
            bail!(
                "active party: round-context ring overflow ({} live rounds)",
                self.ctxs.len()
            );
        }
        let ctx = self.new_ctx(spec);
        match spec.kind {
            // The aggregator opens setup with RequestKeys; we respond,
            // and the round opens once the directory (and, in robust
            // mode, the share relay) lands.
            RoundKind::Setup => {
                self.await_setup = Some(spec.round);
                self.ctxs.insert(spec.round, ctx);
            }
            RoundKind::Train if spec.rotate => {
                self.await_setup = Some(spec.round);
                self.ctxs.insert(spec.round, ctx);
            }
            RoundKind::Train | RoundKind::Test => {
                self.ctxs.insert(spec.round, ctx);
                // opens now if its dependencies allow, else defers
                // until the preceding round's SGD lands
                self.open_deferred(out)?;
            }
        }
        Ok(())
    }

    fn on_message(&mut self, _from: Addr, msg: Msg, out: &mut Outbox) -> Result<()> {
        match msg {
            Msg::RequestKeys { epoch } => {
                let n = self.cfg.n_clients();
                let t0 = Instant::now();
                let reply = self.begin_setup(n, epoch);
                self.rec(t0, true);
                out.send(Addr::Aggregator, reply);
            }
            Msg::KeyDirectory { all, .. } => {
                let t0 = Instant::now();
                self.finish_setup(&all)?;
                if self.threshold.is_some() {
                    // robust setup continues: distribute Shamir seed
                    // shares; the round opens on our ShareRelay
                    let sess = self.session.as_mut().context("setup started")?;
                    let epoch = sess.client().epoch;
                    let msg = seed_share_msg(sess, &mut self.rng, epoch)?;
                    self.rec(t0, true);
                    out.send(Addr::Aggregator, msg);
                } else {
                    self.rec(t0, true);
                    self.setup_complete(out)?;
                }
            }
            Msg::ShareRelay { sealed, .. } => {
                let t0 = Instant::now();
                store_share_relay(self.session.as_mut().context("setup started")?, &sealed)?;
                self.rec(t0, true);
                self.setup_complete(out)?;
            }
            Msg::DropoutNotice { round, dropped } => {
                let t0 = Instant::now();
                let reply =
                    surrender_msg(self.session.as_ref().context("setup done")?, round, &dropped)?;
                self.rec(t0, true);
                out.send(Addr::Aggregator, reply);
            }
            Msg::DzBroadcast { round, dz } => {
                let mut ctx = self
                    .ctxs
                    .remove(&round)
                    .with_context(|| format!("dz broadcast for unknown round {round}"))?;
                let batch = self.cfg.batch_size;
                let h = self.cfg.hidden;
                let dzm = Mat::from_vec(batch, h, dz);
                let xa = ctx.batch_x.clone().context("forward ran")?;
                let t0 = Instant::now();
                let bwd = self.backend.party_bwd("bwd_active", &xa, &dzm, true);
                self.rec(t0, false);
                let (own_dw, own_db) = bwd?;
                let own_db = own_db.context("bias gradient missing")?;
                let t0 = Instant::now();
                let own = self.own_grad_contribution(round, &own_dw, &own_db);
                self.rec(t0, self.security.is_secure());
                ctx.own = Some(own);
                if let Some(gsum) = ctx.pending_gsum.take() {
                    self.finish_train_round(round, ctx, gsum, out)?;
                } else {
                    self.ctxs.insert(round, ctx);
                }
            }
            Msg::GradientSum { round, words } => {
                let ctx = self
                    .ctxs
                    .remove(&round)
                    .with_context(|| format!("gradient sum for unknown round {round}"))?;
                self.on_grad_sum(round, ctx, GradSum::Words(words), out)?;
            }
            Msg::GradientChunk { round, shard, offset, total, words } => {
                let mut ctx = self
                    .ctxs
                    .remove(&round)
                    .with_context(|| format!("gradient chunk for unknown round {round}"))?;
                let t0 = Instant::now();
                // single-sender stream: the aggregator is "sender 0"
                ctx.gsum_asm.add_chunk(0, shard, offset, total, &words)?;
                self.rec(t0, false);
                if ctx.gsum_asm.complete_count() == 1 {
                    let words = ctx.gsum_asm.take_sum()?.context("complete downlink stream")?;
                    self.on_grad_sum(round, ctx, GradSum::Words(words), out)?;
                } else {
                    self.ctxs.insert(round, ctx);
                }
            }
            Msg::FloatGradientSum { round, vals } => {
                let ctx = self
                    .ctxs
                    .remove(&round)
                    .with_context(|| format!("gradient sum for unknown round {round}"))?;
                self.on_grad_sum(round, ctx, GradSum::Floats(vals), out)?;
            }
            Msg::Predictions { round, probs } => {
                // retire the test round's context
                self.ctxs
                    .remove(&round)
                    .with_context(|| format!("predictions for unknown round {round}"))?;
                out.note(Note::Predictions { round, probs });
                out.note(Note::RoundDone { round });
            }
            m => bail!("active party: unexpected message {m:?}"),
        }
        Ok(())
    }

    fn concurrent_safe(&self) -> bool {
        self.backend.concurrent_safe()
    }

    fn take_metrics(&mut self) -> Metrics {
        std::mem::take(&mut self.metrics)
    }

    fn final_params(&mut self) -> Option<ModelParams> {
        Some(self.params.clone())
    }
}

/// The aggregator→active gradient sum, in either mask domain.
pub enum GradSum {
    Words(Vec<u64>),
    Floats(Vec<f32>),
}

// ---------------------------------------------------------------------------
// Passive party
// ---------------------------------------------------------------------------

/// Per-round protocol context of a passive party (the bounded ring
/// behind `--rounds-in-flight`; messages route by their `round` tag).
struct PassiveRoundCtx {
    kind: RoundKind,
    /// The round's resolved (position, id) pairs, consumed by the
    /// forward pass.
    resolved: Option<Vec<(usize, u64)>>,
    /// This round's batch features, cached for the backward pass.
    batch_x: Option<Mat>,
}

pub struct PassiveParty<'e> {
    /// Client index (1-based among clients; active is 0).
    pub id: usize,
    pub group: usize,
    pub dim: usize,
    pub hidden: usize,
    pub data: PassiveData,
    pub session: Option<PartySession>,
    pub security: SecurityMode,
    pub layout: GradLayout,
    /// Current group weights (distributed by the aggregator). Global,
    /// not per-round: weights only change between training rounds,
    /// which the active party's SGD dependency serializes.
    pub weights: Mat,
    /// Shamir threshold for dropout tolerance (None = base protocol).
    threshold: Option<usize>,
    /// Streaming-pipeline parameters (monolithic when not chunked).
    stream: StreamCfg,
    /// Parallel mask-expansion pool (`--expand-workers` > 1 only).
    expand: Option<ExpandPool>,
    backend: Backend<'e>,
    metrics: Metrics,
    rng: DetRng,
    batch_size: usize,
    n_clients: usize,
    // --- event-driven round state ---
    /// Current metering phase (shared by every round in flight — the
    /// scheduler's phase barrier).
    phase: Phase,
    /// Live per-round contexts, keyed by round number.
    ctxs: BTreeMap<u32, PassiveRoundCtx>,
}

impl<'e> PassiveParty<'e> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        data: PassiveData,
        cfg: &ModelConfig,
        security: SecurityMode,
        threshold: Option<usize>,
        stream: StreamCfg,
        seed: u64,
        backend: Backend<'e>,
    ) -> Self {
        let group = data.group;
        let dim = data.dim;
        PassiveParty {
            id,
            group,
            dim,
            hidden: cfg.hidden,
            data,
            session: None,
            security,
            layout: GradLayout::new(cfg),
            weights: Mat::zeros(dim, cfg.hidden),
            threshold,
            expand: expand_pool(&stream),
            stream,
            backend,
            metrics: Metrics::new(),
            rng: party_rng(seed, id),
            batch_size: cfg.batch_size,
            n_clients: cfg.n_clients(),
            phase: Phase::Setup,
            ctxs: BTreeMap::new(),
        }
    }

    fn rec(&mut self, t0: Instant, overhead: bool) {
        self.metrics.record(client(self.id), self.phase, t0.elapsed().as_nanos(), overhead);
    }

    pub fn begin_setup(&mut self, n_clients: usize, epoch: u64) -> Msg {
        let s = open_session(self.id, n_clients, epoch, self.threshold, &mut self.rng);
        let msg = Msg::PublishKeys(keys_to_wire(&s.client().published_keys()));
        self.session = Some(s);
        msg
    }

    /// Errors if no setup epoch is open (a `KeyDirectory` arriving
    /// before `RequestKeys` is a protocol violation, not a panic).
    pub fn finish_setup(&mut self, all: &[WireKeys]) -> Result<()> {
        let s = self.session.as_mut().context("setup started")?;
        let keys = pad_directory(all, s.client().n_clients);
        s.client_mut().derive_secrets(&keys);
        Ok(())
    }

    /// The masking session (post `begin_setup`).
    fn sess(&self) -> &ClientSession {
        self.session.as_ref().expect("setup done").client()
    }

    /// Decrypt what we can from the sealed ID broadcast (§4.0.2): every
    /// entry is tried; only those sealed under our pairwise key open.
    /// Returns (position-in-batch, id) pairs.
    pub fn resolve_batch(&self, round: u32, entries: &[Vec<u8>], batch: usize) -> Vec<(usize, u64)> {
        let session = self.sess();
        let key = session.channel_key(0); // channel with the active party
        let mut out = Vec::new();
        for (seq, sealed) in entries.iter().enumerate() {
            if let Some(id) = open_id(&key, round, seq as u32, sealed) {
                if self.data.rows.contains_key(&id) {
                    out.push((seq % batch, id));
                }
            }
        }
        out
    }

    /// Plain-mode batch resolution.
    pub fn resolve_plain(&self, ids: &[u64]) -> Vec<(usize, u64)> {
        ids.iter()
            .enumerate()
            .filter(|(_, id)| self.data.rows.contains_key(id))
            .map(|(p, &id)| (p, id))
            .collect()
    }

    /// Build the (B × d) feature matrix, zero rows for absent samples
    /// (Eq. 2's indicator function). The caller caches it in the
    /// round's context for the backward pass.
    pub fn batch_features(&self, resolved: &[(usize, u64)], batch: usize) -> Mat {
        let mut x = Mat::zeros(batch, self.dim);
        for &(pos, id) in resolved {
            let row = &self.data.rows[&id];
            x.data[pos * self.dim..(pos + 1) * self.dim].copy_from_slice(row);
        }
        x
    }

    /// Mask an activation for upload (Eq. 2): one monolithic message,
    /// or the chunked stream when the streaming pipeline is on.
    pub fn masked_activation(&self, round: u32, z: &Mat) -> Vec<OutMsg> {
        match self.security {
            SecurityMode::SecureExact => masked_exact_msgs(
                self.sess(),
                self.stream,
                self.expand.as_ref(),
                round,
                self.id as u16,
                TAG_ACTIVATION,
                &z.data,
            ),
            SecurityMode::SecureFloat => {
                let vals = self.sess().mask_tensor_f32(&z.data, round as u64, TAG_ACTIVATION);
                vec![Msg::FloatActivation { round, from: self.id as u16, vals }.into()]
            }
            SecurityMode::Plain => {
                vec![Msg::FloatActivation { round, from: self.id as u16, vals: z.data.clone() }
                    .into()]
            }
        }
    }

    /// Embed the local weight gradient into the full-length layout and
    /// mask it (Eq. 6), monolithic or chunked.
    pub fn masked_gradient(&self, round: u32, dw: &Mat) -> Vec<OutMsg> {
        let l = self.layout.total;
        let (off, len) = self.layout.groups[self.group];
        assert_eq!(dw.data.len(), len);
        let mut full = vec![0.0f32; l];
        full[off..off + len].copy_from_slice(&dw.data);
        match self.security {
            SecurityMode::SecureExact => masked_exact_msgs(
                self.sess(),
                self.stream,
                self.expand.as_ref(),
                round,
                self.id as u16,
                TAG_GRADIENT,
                &full,
            ),
            SecurityMode::SecureFloat => {
                let vals = self.sess().mask_tensor_f32(&full, round as u64, TAG_GRADIENT);
                vec![Msg::FloatGradient { round, from: self.id as u16, vals }.into()]
            }
            SecurityMode::Plain => {
                vec![Msg::FloatGradient { round, from: self.id as u16, vals: full }.into()]
            }
        }
    }

    /// Install redistributed group weights.
    pub fn set_weights(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.dim * self.hidden, "group weight size");
        self.weights = Mat::from_vec(self.dim, self.hidden, flat.to_vec());
    }

    /// Run the group forward pass on one round's resolved batch and
    /// upload the masked activation (the context is detached from the
    /// ring while we operate on it).
    fn forward_and_upload(
        &mut self,
        round: u32,
        ctx: &mut PassiveRoundCtx,
        out: &mut Outbox,
    ) -> Result<()> {
        let batch = self.batch_size;
        let resolved = ctx.resolved.take().context("batch relay not yet received")?;
        let x = self.batch_features(&resolved, batch);
        ctx.batch_x = Some(x.clone());
        let graph = format!("fwd_g{}", self.group);
        let weights = PartyParams { w: self.weights.clone(), b: None };
        let t0 = Instant::now();
        let z = self.backend.party_fwd(&graph, &x, &weights, None);
        self.rec(t0, false);
        let z = z?;
        let t0 = Instant::now();
        let msgs = self.masked_activation(round, &z);
        self.rec(t0, self.security.is_secure());
        for msg in msgs {
            out.send_out(Addr::Aggregator, msg);
        }
        Ok(())
    }
}

impl<'e> Party for PassiveParty<'e> {
    fn addr(&self) -> Addr {
        Addr::Client(self.id)
    }

    fn on_round_start(&mut self, spec: &RoundSpec, _out: &mut Outbox) -> Result<()> {
        self.phase = spec.phase;
        // pure-setup rounds route no round-tagged traffic to a passive
        // (key exchange is epoch-scoped), so a context would never
        // retire — skip it, as the aggregator does
        if spec.kind == RoundKind::Setup {
            return Ok(());
        }
        if self.ctxs.len() >= MAX_ROUNDS_IN_FLIGHT {
            bail!(
                "passive party {}: round-context ring overflow ({} live rounds)",
                self.id,
                self.ctxs.len()
            );
        }
        self.ctxs.insert(
            spec.round,
            PassiveRoundCtx { kind: spec.kind, resolved: None, batch_x: None },
        );
        Ok(())
    }

    fn on_message(&mut self, _from: Addr, msg: Msg, out: &mut Outbox) -> Result<()> {
        match msg {
            Msg::RequestKeys { epoch } => {
                let n = self.n_clients;
                let t0 = Instant::now();
                let reply = self.begin_setup(n, epoch);
                self.rec(t0, true);
                out.send(Addr::Aggregator, reply);
            }
            Msg::KeyDirectory { all, .. } => {
                let t0 = Instant::now();
                self.finish_setup(&all)?;
                if self.threshold.is_some() {
                    let sess = self.session.as_mut().context("setup started")?;
                    let epoch = sess.client().epoch;
                    let msg = seed_share_msg(sess, &mut self.rng, epoch)?;
                    self.rec(t0, true);
                    out.send(Addr::Aggregator, msg);
                } else {
                    self.rec(t0, true);
                }
            }
            Msg::ShareRelay { sealed, .. } => {
                let t0 = Instant::now();
                store_share_relay(self.session.as_mut().context("setup started")?, &sealed)?;
                self.rec(t0, true);
            }
            Msg::DropoutNotice { round, dropped } => {
                let t0 = Instant::now();
                let reply =
                    surrender_msg(self.session.as_ref().context("setup done")?, round, &dropped)?;
                self.rec(t0, true);
                out.send(Addr::Aggregator, reply);
            }
            Msg::BatchRelay { entries, round } => {
                let mut ctx = self
                    .ctxs
                    .remove(&round)
                    .with_context(|| format!("batch relay for unknown round {round}"))?;
                let batch = self.batch_size;
                let t0 = Instant::now();
                let resolved = self.resolve_batch(round, &entries, batch);
                self.rec(t0, true);
                ctx.resolved = Some(resolved);
                // testing rounds carry no weights: forward immediately,
                // and nothing else arrives for them — retire the ctx
                if ctx.kind == RoundKind::Test {
                    self.forward_and_upload(round, &mut ctx, out)?;
                } else {
                    self.ctxs.insert(round, ctx);
                }
            }
            Msg::PlainBatchRelay { ids, round } => {
                let mut ctx = self
                    .ctxs
                    .remove(&round)
                    .with_context(|| format!("batch relay for unknown round {round}"))?;
                ctx.resolved = Some(self.resolve_plain(&ids));
                if ctx.kind == RoundKind::Test {
                    self.forward_and_upload(round, &mut ctx, out)?;
                } else {
                    self.ctxs.insert(round, ctx);
                }
            }
            Msg::GroupWeights { flat, round, .. } => {
                let mut ctx = self
                    .ctxs
                    .remove(&round)
                    .with_context(|| format!("group weights for unknown round {round}"))?;
                self.set_weights(&flat);
                // training: the weights follow the relay (per-sender
                // FIFO), so the batch is resolved by now; the backward
                // pass still needs the ctx, so it stays live
                if ctx.kind == RoundKind::Train {
                    self.forward_and_upload(round, &mut ctx, out)?;
                }
                self.ctxs.insert(round, ctx);
            }
            Msg::DzBroadcast { round, dz } => {
                let ctx = self
                    .ctxs
                    .remove(&round)
                    .with_context(|| format!("dz broadcast for unknown round {round}"))?;
                let batch = self.batch_size;
                let dzm = Mat::from_vec(batch, self.hidden, dz);
                let graph = format!("bwd_g{}", self.group);
                let x = ctx.batch_x.clone().context("forward ran")?;
                let t0 = Instant::now();
                let bwd = self.backend.party_bwd(&graph, &x, &dzm, false);
                self.rec(t0, false);
                let (dw, _) = bwd?;
                let t0 = Instant::now();
                let msgs = self.masked_gradient(round, &dw);
                self.rec(t0, self.security.is_secure());
                for msg in msgs {
                    out.send_out(Addr::Aggregator, msg);
                }
                // the gradient upload is this round's last obligation:
                // ctx retired (dropped here)
            }
            m => bail!("passive party {}: unexpected message {m:?}", self.id),
        }
        Ok(())
    }

    fn concurrent_safe(&self) -> bool {
        self.backend.concurrent_safe()
    }

    fn take_metrics(&mut self) -> Metrics {
        std::mem::take(&mut self.metrics)
    }
}

// ---------------------------------------------------------------------------
// Aggregator
// ---------------------------------------------------------------------------

/// The aggregator: relays traffic, owns the global module, sums masked
/// vectors (masks cancel per Eq. 4-5), and never sees an individual
/// party's plaintext tensor.
///
/// All fan-in state lives in per-round [`AggRoundCtx`]s (a bounded
/// ring keyed by round number), so several rounds fold concurrently
/// under the windowed scheduler; a declared dropout purges the client
/// from *every* live round context, and the per-(round, tag) mask
/// corrections recover each round independently. Monolithic fan-in
/// points buffer contributions in [`BTreeMap`]s
/// keyed by sender so sums run in client order regardless of arrival
/// order — the transport-independence invariant. Chunked fan-ins
/// (`--chunk-words`) run through a [`ChunkAssembler`] per tensor tag
/// instead: ℤ₂⁶⁴ wrap-addition is order-independent, so committing
/// every validated chunk into its shard accumulator on arrival is
/// bit-identical to the buffered sum while holding O(d) instead of
/// O(n·d) — with `--agg-workers` > 1 the folding itself fans out
/// across per-shard accumulator workers, and dropout-tolerant runs
/// keep exact purge via the rollback log (see
/// [`streaming`](super::streaming) for the memory model). When the
/// streaming pipeline is on, the aggregator→active `GradientSum` is
/// chunked too ([`Msg::GradientChunk`]), so the downlink streams with
/// the same shard layout as the uplinks.
/// Per-round protocol context of the aggregator: one per Train/Test
/// round in flight (setup rounds have no fan-in state), keyed by round
/// number in a bounded ring. Incoming fan-in messages route to their
/// context by the `round` tag; the context retires when the round's
/// terminal send goes out (`GradientSum`/`GradientChunk`s for
/// training, `Predictions` for testing).
struct AggRoundCtx {
    kind: RoundKind,
    labels: Vec<f32>,
    relay_entries: Option<Vec<Vec<u8>>>,
    relay_ids: Option<Vec<u64>>,
    group_flats: Option<Vec<Vec<f32>>>,
    relayed: bool,
    acts_exact: BTreeMap<u16, Vec<u64>>,
    acts_float: BTreeMap<u16, Vec<f32>>,
    grads_exact: BTreeMap<u16, Vec<u64>>,
    grads_float: BTreeMap<u16, Vec<f32>>,
    /// Streaming fan-ins: chunked masked tensors folded shard by shard
    /// (slots of the shared worker pool, so two rounds fold
    /// concurrently without cross-talk).
    acts_asm: ChunkAssembler,
    grads_asm: ChunkAssembler,
    /// Leaf partial ℤ₂⁶⁴ sums (`--leaves` tree runs): `shard_start` →
    /// (`shard_end`, words), a half-open client range. Each partial
    /// folds every live client in its range; the root stitches the
    /// disjoint partials by wrap-addition exactly like the shard
    /// merge, so the total is bit-identical to the flat fan-in.
    acts_partial: BTreeMap<u16, (u16, Vec<u64>)>,
    grads_partial: BTreeMap<u16, (u16, Vec<u64>)>,
    /// Clients whose fan-in contribution is buffered at their owning
    /// leaf (tree runs). Counted for stall diagnosis only — the data
    /// itself arrives later as a [`Msg::PartialSum`], so completeness
    /// must never count these.
    tree_acts_present: BTreeSet<u16>,
    tree_grads_present: BTreeSet<u16>,
    /// This round's fan-ins were summed and consumed (the buffers
    /// empty out on consumption, so stall diagnosis needs the flags).
    acts_done: bool,
    grads_done: bool,
    /// Last (mono, asm, spill) byte totals this context contributed to
    /// the aggregator's running meters — the delta bookkeeping that
    /// keeps `note_buffered` O(1) per message instead of rescanning
    /// every live round context on the per-chunk hot path.
    metered: (u64, u64, u64),
}

impl AggRoundCtx {
    /// Resident fan-in bytes (monolithic buffers + shard accumulators).
    fn buffered(&self) -> (u64, u64) {
        let mono = self.acts_exact.values().map(|v| v.len() * 8).sum::<usize>()
            + self.acts_float.values().map(|v| v.len() * 4).sum::<usize>()
            + self.grads_exact.values().map(|v| v.len() * 8).sum::<usize>()
            + self.grads_float.values().map(|v| v.len() * 4).sum::<usize>()
            + self.acts_partial.values().map(|(_, v)| v.len() * 8).sum::<usize>()
            + self.grads_partial.values().map(|(_, v)| v.len() * 8).sum::<usize>();
        (mono as u64, self.acts_asm.buffered_bytes() + self.grads_asm.buffered_bytes())
    }

    /// The aggregator's obligations for this round are all met.
    fn finished(&self) -> bool {
        match self.kind {
            RoundKind::Test => self.acts_done,
            RoundKind::Train => self.acts_done && self.grads_done,
            RoundKind::Setup => true,
        }
    }
}

pub struct Aggregator<'e> {
    pub n_clients: usize,
    pub hidden: usize,
    /// Global module Linear(hidden, 1) — lives here per §6.2.
    pub global_w: Vec<f32>,
    pub global_b: f32,
    pub fp: FixedPoint,
    backend: Backend<'e>,
    cfg: ModelConfig,
    /// `groups[i]` = feature group held by client `i + 1`.
    groups: Vec<usize>,
    /// Streaming-pipeline parameters (drives the chunked
    /// `GradientSum` downlink and the assembler shard/worker shape).
    stream: StreamCfg,
    metrics: Metrics,
    /// The one shared accumulator worker pool (`--agg-workers` > 1 on
    /// a chunked run): every fan-in assembler of every live round
    /// folds through it, addressed by per-(round, fan-in) slots.
    pool: Option<WorkerPool>,
    /// Parallel mask-expansion pool (`--expand-workers` > 1 only):
    /// drives the recovered dropped-client total-mask correction.
    expand: Option<ExpandPool>,
    // --- event-driven round state ---
    /// Current metering phase (shared by every round in flight — the
    /// scheduler's phase barrier).
    phase: Phase,
    /// Latest announced round (DropoutNotice tagging fallback when no
    /// fan-in context is live).
    round: u32,
    /// Live per-round contexts, keyed by round number.
    ctxs: BTreeMap<u32, AggRoundCtx>,
    /// Rounds announced but not yet reported complete by the driver
    /// ([`Party::on_round_complete`]): while any round below the one
    /// being diagnosed is still here, the active party may simply be
    /// finishing it — an unopened round is not evidence of its death.
    pending_done: BTreeSet<u32>,
    /// Setup epochs completed (drives RequestKeys numbering).
    epoch: u64,
    keys: Vec<WireKeys>,
    /// Running fan-in byte totals across every live round context
    /// (monolithic buffers, shard accumulators, rollback spill),
    /// maintained by per-context deltas so the per-message meter stays
    /// O(1) regardless of the window width.
    cur_mono: u64,
    cur_asm: u64,
    cur_spill: u64,
    /// Last assembler resident-byte total seen by `note_buffered` —
    /// gates the per-shard re-metering off the per-chunk hot path.
    last_asm_buffered: u64,
    // --- dropout-tolerance state (enabled by `threshold`) ---
    /// Shamir threshold t: any t surviving clients can reconstruct a
    /// dropped client's seed. None = base protocol (a drop stalls).
    threshold: Option<usize>,
    /// Clients still participating; declared-dropped ids leave forever.
    live: BTreeSet<u16>,
    /// Epoch of the sessions the current directory established.
    session_epoch: u64,
    /// The broadcast key directory, padded to one entry per client —
    /// kept so a reconstructed seed can be rebuilt into a session.
    directory: Vec<PublishedKeys>,
    /// Setup sub-phase tracking (initial setup and §5.1 rotations).
    in_setup: bool,
    directory_sent: bool,
    /// Seed-share bundles collected during setup: from → per-recipient.
    setup_shares: BTreeMap<u16, Vec<Vec<u8>>>,
    /// Seed commitments pinned at setup (from → commitment): any
    /// reconstructed seed must match, or recovery aborts with
    /// [`DropoutError::SeedCommitmentMismatch`].
    commitments: BTreeMap<u16, [u8; 32]>,
    /// Dropped clients of the current epoch with rebuilt sessions: the
    /// source of the mask corrections added at every fan-in.
    recovered: BTreeMap<u16, ClientSession>,
    /// Declared dropped, seeds not yet reconstructed.
    unrecovered: BTreeSet<u16>,
    /// Live clients whose SurrenderShares we still await.
    awaiting_surrender: BTreeSet<u16>,
    /// dropped id → (source id → decoded share bundle).
    surrendered: BTreeMap<u16, BTreeMap<u16, Vec<Share>>>,
}

impl<'e> Aggregator<'e> {
    pub fn new(
        cfg: &ModelConfig,
        seed: u64,
        backend: Backend<'e>,
        groups: Vec<usize>,
        threshold: Option<usize>,
        stream: StreamCfg,
    ) -> Self {
        // aggregator receives the initial global module from the active
        // party's init (same seed → same init as ModelParams::init)
        let params = ModelParams::init(cfg, seed);
        assert_eq!(groups.len(), cfg.n_clients() - 1, "one group per passive client");
        // one shared worker pool for every chunked fan-in of every
        // round in flight (the pre-refactor shape spawned one pool per
        // fan-in, doubling the thread count)
        let pool = if stream.chunk_words.is_some() && stream.agg_workers > 1 {
            Some(WorkerPool::new(stream.agg_workers.min(stream.shards.max(1))))
        } else {
            None
        };
        Aggregator {
            n_clients: cfg.n_clients(),
            hidden: cfg.hidden,
            global_w: params.global.w.data,
            global_b: params.global.b,
            fp: FixedPoint::default(),
            backend,
            cfg: cfg.clone(),
            groups,
            stream,
            metrics: Metrics::new(),
            pool,
            expand: expand_pool(&stream),
            phase: Phase::Setup,
            round: 0,
            ctxs: BTreeMap::new(),
            pending_done: BTreeSet::new(),
            epoch: 0,
            keys: Vec::new(),
            cur_mono: 0,
            cur_asm: 0,
            cur_spill: 0,
            last_asm_buffered: 0,
            threshold,
            live: (0..cfg.n_clients() as u16).collect(),
            session_epoch: 0,
            directory: Vec::new(),
            in_setup: false,
            directory_sent: false,
            setup_shares: BTreeMap::new(),
            commitments: BTreeMap::new(),
            recovered: BTreeMap::new(),
            unrecovered: BTreeSet::new(),
            awaiting_surrender: BTreeSet::new(),
            surrendered: BTreeMap::new(),
        }
    }

    fn rec(&mut self, t0: Instant, overhead: bool) {
        self.metrics.record(AGGREGATOR, self.phase, t0.elapsed().as_nanos(), overhead);
    }

    /// A fresh fan-in context for a Train/Test round. Exact dropout
    /// purge needs every sender's committed words to stay subtractable
    /// until the fan-in is consumed, so tolerant runs keep a rollback
    /// log beside the shard accumulators. Assembler slots are derived
    /// from the round number (unique per run), so concurrent rounds
    /// share the worker pool without cross-talk.
    fn new_ctx(&self, round: u32, kind: RoundKind) -> AggRoundCtx {
        let revocable = self.threshold.is_some();
        let shards = self.stream.shards.max(1);
        let rollback = self.stream.rollback;
        let asm = |tag: u64| match &self.pool {
            Some(pool) => ChunkAssembler::pooled(
                revocable,
                shards,
                rollback,
                pool.client(),
                ((round as u64) << 1) | tag,
            ),
            None => ChunkAssembler::inline(revocable, shards, rollback),
        };
        AggRoundCtx {
            kind,
            labels: Vec::new(),
            relay_entries: None,
            relay_ids: None,
            group_flats: None,
            relayed: false,
            acts_exact: BTreeMap::new(),
            acts_float: BTreeMap::new(),
            grads_exact: BTreeMap::new(),
            grads_float: BTreeMap::new(),
            acts_asm: asm(0),
            grads_asm: asm(1),
            acts_partial: BTreeMap::new(),
            grads_partial: BTreeMap::new(),
            tree_acts_present: BTreeSet::new(),
            tree_grads_present: BTreeSet::new(),
            acts_done: false,
            grads_done: false,
            metered: (0, 0, 0),
        }
    }

    /// Put a detached context back into the ring — unless the round's
    /// obligations are all met, in which case it retires (dropping the
    /// assemblers frees their worker-pool slots, and its metered bytes
    /// leave the running totals). Contexts detach for processing and
    /// return here, so retirement happens in completion order.
    fn park(&mut self, round: u32, ctx: AggRoundCtx) {
        if ctx.finished() {
            let (m, a, s) = ctx.metered;
            self.cur_mono -= m;
            self.cur_asm -= a;
            self.cur_spill -= s;
        } else {
            self.ctxs.insert(round, ctx);
        }
    }

    /// Meter the bytes currently buffered across every live round's
    /// fan-ins (the peak is the streaming pipeline's memory claim,
    /// asserted in `tests/chunk_equivalence.rs`; with `W` rounds in
    /// flight the chunked bound is O(W·d)). Only the touched, detached
    /// context is recomputed — its delta updates the running totals, so
    /// the per-chunk cost is O(1) regardless of the window width.
    fn note_buffered(&mut self, ctx: &mut AggRoundCtx) {
        let (mono, asm) = ctx.buffered();
        let spill = ctx.acts_asm.spilled_bytes() + ctx.grads_asm.spilled_bytes();
        let (pm, pa, ps) = ctx.metered;
        ctx.metered = (mono, asm, spill);
        self.cur_mono = self.cur_mono - pm + mono;
        self.cur_asm = self.cur_asm - pa + asm;
        self.cur_spill = self.cur_spill - ps + spill;
        self.metrics.record_buffered(AGGREGATOR, self.cur_mono + self.cur_asm);
        self.metrics.record_spilled(AGGREGATOR, self.cur_spill);
        // per-shard footprints are a pure function of the fixed shard
        // layouts, so re-meter them only when an assembler's resident
        // state changed (a layout was fixed or consumed) — an O(live
        // rounds) walk kept off the per-chunk hot path
        if self.cur_asm != self.last_asm_buffered {
            self.last_asm_buffered = self.cur_asm;
            let mut per_shard = vec![0u64; self.stream.shards.max(1)];
            for c in self.ctxs.values().chain(std::iter::once(&*ctx)) {
                let acts = c.acts_asm.shard_buffered_bytes();
                let grads = c.grads_asm.shard_buffered_bytes();
                for (k, (a, g)) in acts.iter().zip(&grads).enumerate() {
                    per_shard[k] += a + g;
                }
            }
            for (k, b) in per_shard.iter().enumerate() {
                self.metrics.record_shard_buffered(AGGREGATOR, k, *b);
            }
        }
    }

    /// Rebuild the running byte totals from scratch — a dropout purge
    /// mutates every live context at once, so the per-context deltas
    /// are re-established here (recovery path only, never per-chunk).
    fn remeter_all(&mut self) {
        self.cur_mono = 0;
        self.cur_asm = 0;
        self.cur_spill = 0;
        for ctx in self.ctxs.values_mut() {
            let (mono, asm) = ctx.buffered();
            let spill = ctx.acts_asm.spilled_bytes() + ctx.grads_asm.spilled_bytes();
            ctx.metered = (mono, asm, spill);
            self.cur_mono += mono;
            self.cur_asm += asm;
            self.cur_spill += spill;
        }
    }

    /// Wrap-sum equal-length masked word vectors (Eq. 5's fan-in).
    fn wrap_sum(parts: &[Vec<u64>]) -> Vec<u64> {
        let l = parts[0].len();
        let mut acc = vec![0u64; l];
        for p in parts {
            assert_eq!(p.len(), l, "masked vectors must be equal length");
            z64::wrap_add(&mut acc, p);
        }
        acc
    }

    fn float_sum(parts: &[Vec<f32>]) -> Vec<f32> {
        let l = parts[0].len();
        let mut acc = vec![0.0f32; l];
        for p in parts {
            for (a, v) in acc.iter_mut().zip(p) {
                *a += v;
            }
        }
        acc
    }

    /// The combined total mask of every recovered dropped client for
    /// (round, tag): adding this to a fan-in sum cancels the survivors'
    /// dangling pairwise masks (the Bonawitz'17 recovery step). Zero
    /// when nothing dropped this epoch. With `--expand-workers` > 1
    /// each session's mask expands across the pool in disjoint
    /// sub-windows — bit-identical to the serial fold, since
    /// `total_mask` is exactly the stream's `[0, len)` window.
    fn dropped_mask_correction(&self, round: u64, tag: u32, len: usize) -> Option<Vec<u64>> {
        if self.recovered.is_empty() {
            return None;
        }
        let mut acc = vec![0u64; len];
        for session in self.recovered.values() {
            match &self.expand {
                Some(pool) => {
                    // epoch mixing happens inside total_mask_stream,
                    // exactly as it does inside total_mask
                    let stream = session.total_mask_stream(round, tag);
                    pool.add_window(&stream, 0, &mut acc);
                }
                None => {
                    let m = session.total_mask(round, tag, len);
                    z64::wrap_add(&mut acc, &m);
                }
            }
        }
        Some(acc)
    }

    /// Number of live passive clients (gradient fan-in width).
    fn live_passives(&self) -> usize {
        self.live.iter().filter(|&&c| c != 0).count()
    }

    /// Live clients covered by a round's buffered leaf partials (tree
    /// runs): each partial's half-open client range is intersected
    /// with the live set, so a shard that shrank after emission never
    /// over-counts. `skip_active` excludes client 0 (gradient fan-in).
    fn partial_cover(
        live: &BTreeSet<u16>,
        partials: &BTreeMap<u16, (u16, Vec<u64>)>,
        skip_active: bool,
    ) -> usize {
        partials
            .iter()
            .map(|(&s, v)| live.range(s..v.0).filter(|&&c| !skip_active || c != 0).count())
            .sum()
    }

    /// Clients still participating — the tree wrapper syncs each
    /// leaf's shard view off this after every delegated call.
    pub(crate) fn live_clients(&self) -> &BTreeSet<u16> {
        &self.live
    }

    /// Whether `round`'s fan-in context is still live (a retired round
    /// must not receive a re-emitted leaf partial: its sum already went
    /// out, exactly as a flat round keeps a pre-drop contribution).
    pub(crate) fn has_round_ctx(&self, round: u32) -> bool {
        self.ctxs.contains_key(&round)
    }

    /// Tree runs: record that `from`'s (`round`, `tag`) fan-in
    /// contribution is buffered at its owning leaf, so stall diagnosis
    /// does not declare a client dropped while its shard's partial is
    /// still folding. Never counted toward completeness — the words
    /// arrive later as a [`Msg::PartialSum`].
    pub(crate) fn note_tree_presence(&mut self, round: u32, tag: u8, from: u16) {
        if let Some(ctx) = self.ctxs.get_mut(&round) {
            match tag as u32 {
                TAG_ACTIVATION => ctx.tree_acts_present.insert(from),
                TAG_GRADIENT => ctx.tree_grads_present.insert(from),
                _ => false,
            };
        }
    }

    /// Apply the global-module SGD update (the aggregator computes
    /// dwg/dbg itself from the clear z — which is legitimately public
    /// to it under the protocol).
    pub fn update_global(&mut self, d_w: &[f32], d_b: f32, lr: f32) {
        for (w, g) in self.global_w.iter_mut().zip(d_w) {
            *w -= lr * g;
        }
        self.global_b -= lr * d_b;
    }

    /// Extract the per-group weight blocks from a flat ModelParams.
    fn split_group_weights(&self, flat: &[f32]) -> Vec<Vec<f32>> {
        let cfg = &self.cfg;
        let h = cfg.hidden;
        let mut off = cfg.active_dim * h + h;
        cfg.group_dims
            .iter()
            .map(|&d| {
                let s = flat[off..off + d * h].to_vec();
                off += d * h;
                s
            })
            .collect()
    }

    /// Relay one round's sealed batch (and, in training, each group's
    /// weights) to every live passive party once the prerequisites
    /// arrived.
    fn maybe_relay(&mut self, round: u32, ctx: &mut AggRoundCtx, out: &mut Outbox) {
        if ctx.relayed {
            return;
        }
        let have_batch = ctx.relay_entries.is_some() || ctx.relay_ids.is_some();
        let need_weights = ctx.kind == RoundKind::Train;
        if !have_batch || (need_weights && ctx.group_flats.is_none()) {
            return;
        }
        for ci in 1..self.n_clients {
            if !self.live.contains(&(ci as u16)) {
                continue;
            }
            let relay = if let Some(e) = &ctx.relay_entries {
                Msg::BatchRelay { round, entries: e.clone() }
            } else {
                Msg::PlainBatchRelay { round, ids: ctx.relay_ids.clone().unwrap() }
            };
            out.send(Addr::Client(ci), relay);
            if need_weights {
                let g = self.groups[ci - 1];
                let flat = ctx.group_flats.as_ref().unwrap()[g].clone();
                out.send(Addr::Client(ci), Msg::GroupWeights { round, group: g as u8, flat });
            }
        }
        ctx.relayed = true;
    }

    /// Once every live client's masked activation for `round` is in
    /// (and any pending recovery finished): unmask by summation —
    /// adding the recovered dropped-client masks so the survivors'
    /// danglers cancel — then either run the global training step and
    /// broadcast ∂L/∂z, or (testing) predict and reply to the active
    /// party. The context is detached from the ring.
    fn maybe_sum_activations(
        &mut self,
        round: u32,
        ctx: &mut AggRoundCtx,
        out: &mut Outbox,
    ) -> Result<()> {
        let contributed = ctx.acts_exact.len()
            + ctx.acts_float.len()
            + ctx.acts_asm.complete_count()
            + Self::partial_cover(&self.live, &ctx.acts_partial, false);
        if !self.unrecovered.is_empty() || contributed < self.live.len() {
            return Ok(());
        }
        let batch = self.cfg.batch_size;
        ctx.acts_done = true;
        // BTreeMap order = client order: float addition order (and thus
        // every output bit) is the same on every transport. The chunked
        // sum is ℤ₂⁶⁴-only, where addition order is immaterial — and so
        // are the disjoint leaf partials of a tree run.
        let mut exact: Vec<Vec<u64>> = std::mem::take(&mut ctx.acts_exact).into_values().collect();
        exact.extend(std::mem::take(&mut ctx.acts_partial).into_values().map(|(_, w)| w));
        let float: Vec<Vec<f32>> = std::mem::take(&mut ctx.acts_float).into_values().collect();
        let chunked = ctx.acts_asm.take_sum()?;
        let t0 = Instant::now();
        let z = if !exact.is_empty() || chunked.is_some() {
            let mut acc = match chunked {
                Some(mut g) => {
                    for p in &exact {
                        assert_eq!(p.len(), g.len(), "masked vectors must be equal length");
                        z64::wrap_add(&mut g, p);
                    }
                    g
                }
                None => Self::wrap_sum(&exact),
            };
            if let Some(corr) =
                self.dropped_mask_correction(round as u64, TAG_ACTIVATION, acc.len())
            {
                z64::wrap_add(&mut acc, &corr);
            }
            Mat::from_vec(batch, self.hidden, self.fp.decode_vec(&acc))
        } else {
            Mat::from_vec(batch, self.hidden, Self::float_sum(&float))
        };
        self.rec(t0, false);
        let (gw, gb) = (self.global_w.clone(), self.global_b);
        match ctx.kind {
            RoundKind::Train => {
                let labels = std::mem::take(&mut ctx.labels);
                let t0 = Instant::now();
                let step = self.backend.global_step(&z, &gw, gb, &labels);
                self.rec(t0, false);
                let step = step?;
                self.update_global(&step.d_global_w, step.d_global_b, self.cfg.lr);
                out.note(Note::Loss { round, loss: step.loss });
                let dz = Msg::DzBroadcast { round, dz: step.dz.data };
                for i in 0..self.n_clients {
                    if self.live.contains(&(i as u16)) {
                        out.send(Addr::Client(i), dz.clone());
                    }
                }
            }
            RoundKind::Test => {
                let t0 = Instant::now();
                let probs = self.backend.predict(&z, &gw, gb);
                self.rec(t0, false);
                out.send(Addr::Client(0), Msg::Predictions { round, probs: probs? });
            }
            RoundKind::Setup => bail!("activation received during a setup round"),
        }
        Ok(())
    }

    /// Once every live passive's masked gradient for `round` is in:
    /// sum (still masked by the active party's total mask — §4.0.2's
    /// privacy argument), add the recovered dropped-client gradient
    /// masks, and forward to the active party. The context is detached
    /// from the ring.
    fn maybe_sum_gradients(
        &mut self,
        round: u32,
        ctx: &mut AggRoundCtx,
        out: &mut Outbox,
    ) -> Result<()> {
        let n_passive = self.live_passives();
        let contributed = ctx.grads_exact.len()
            + ctx.grads_float.len()
            + ctx.grads_asm.complete_count()
            + Self::partial_cover(&self.live, &ctx.grads_partial, true);
        if n_passive == 0 || !self.unrecovered.is_empty() || contributed < n_passive {
            return Ok(());
        }
        ctx.grads_done = true;
        let mut exact: Vec<Vec<u64>> =
            std::mem::take(&mut ctx.grads_exact).into_values().collect();
        exact.extend(std::mem::take(&mut ctx.grads_partial).into_values().map(|(_, w)| w));
        let float: Vec<Vec<f32>> = std::mem::take(&mut ctx.grads_float).into_values().collect();
        let chunked = ctx.grads_asm.take_sum()?;
        let t0 = Instant::now();
        if !exact.is_empty() || chunked.is_some() {
            let mut acc = match chunked {
                Some(mut g) => {
                    for p in &exact {
                        assert_eq!(p.len(), g.len(), "masked vectors must be equal length");
                        z64::wrap_add(&mut g, p);
                    }
                    g
                }
                None => Self::wrap_sum(&exact),
            };
            if let Some(corr) =
                self.dropped_mask_correction(round as u64, TAG_GRADIENT, acc.len())
            {
                z64::wrap_add(&mut acc, &corr);
            }
            match self.stream.chunk_words {
                // streaming runs chunk the 1:1 downlink too, so a
                // memory-constrained active party consumes the sum
                // window by window (Table-2 delta:
                // `streaming::grad_chunk_overhead_bytes`)
                Some(cw) => {
                    let layout = ShardLayout::new(acc.len(), self.stream.shards);
                    self.rec(t0, false);
                    // zero-copy: each window's header + words go into
                    // one exact-capacity wire buffer, no per-chunk
                    // `Vec<u64>` copy of the accumulator slice
                    for c in chunk_plan(layout, cw) {
                        let mut w = Writer::with_capacity(
                            GRAD_CHUNK_MSG_HEADER_BYTES as usize + 8 * c.len,
                        );
                        begin_gradient_chunk(
                            &mut w,
                            round,
                            c.shard as u16,
                            c.offset as u32,
                            acc.len() as u32,
                            c.len as u32,
                        );
                        w.u64s_raw(&acc[c.offset..c.offset + c.len]);
                        out.send_encoded(Addr::Client(0), Some(round), w.finish());
                    }
                }
                None => {
                    self.rec(t0, false);
                    out.send(Addr::Client(0), Msg::GradientSum { round, words: acc });
                }
            }
        } else {
            let msg = Msg::FloatGradientSum { round, vals: Self::float_sum(&float) };
            self.rec(t0, false);
            out.send(Addr::Client(0), msg);
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Dropout recovery (Bonawitz'17 over the live protocol)
    // -----------------------------------------------------------------

    /// Remove clients from the live set, enforcing the recoverability
    /// invariants: the active party must survive, and at least t
    /// clients must remain to reconstruct any dropped seed.
    ///
    /// Any fan-in contribution a now-dropped client already buffered is
    /// purged: the recovery math adds the client's *entire* total mask
    /// back, which is only correct if the client contributed nothing —
    /// keeping a buffered `enc(x) + M` entry while also adding `M`
    /// would corrupt the aggregate (and a stale entry could make the
    /// completeness count pass while a live client is still missing).
    fn remove_from_live(&mut self, gone: &BTreeSet<u16>) -> Result<()> {
        let t = self.threshold.expect("dropout tolerance enabled");
        for g in gone {
            self.live.remove(g);
            // a dropped client may have contributed to several rounds
            // in flight: purge it from every live context. Chunked
            // contributions are revocable in tolerant runs — the
            // rollback log replays the sender's committed chunks back
            // out of the shard accumulators.
            for ctx in self.ctxs.values_mut() {
                ctx.acts_exact.remove(g);
                ctx.acts_float.remove(g);
                ctx.grads_exact.remove(g);
                ctx.grads_float.remove(g);
                ctx.acts_asm.purge(*g)?;
                ctx.grads_asm.purge(*g)?;
                // a leaf partial that already folded the dropped
                // client's masked words cannot be corrected here —
                // discard the whole partial; the owning leaf purges
                // its fold and re-emits a corrected one (tree runs
                // only; flat runs buffer no partials)
                ctx.acts_partial.retain(|&s, v| !(s..v.0).contains(g));
                ctx.grads_partial.retain(|&s, v| !(s..v.0).contains(g));
                ctx.tree_acts_present.remove(g);
                ctx.tree_grads_present.remove(g);
            }
        }
        // the purge mutated every live context's buffers at once:
        // rebuild the delta-metered running totals
        self.remeter_all();
        if !self.live.contains(&0) {
            bail!(DropoutError::ActivePartyDropped);
        }
        if self.live.len() < t {
            bail!(DropoutError::BelowThreshold { survivors: self.live.len(), threshold: t });
        }
        Ok(())
    }

    /// The round a dropout declaration is diagnosed against: the
    /// oldest round in flight (its prerequisites are all delivered),
    /// falling back to the latest announced round during setup legs.
    fn diagnosis_round(&self) -> u32 {
        self.ctxs.keys().next().copied().unwrap_or(self.round)
    }

    /// Declare mid-round dropouts: these clients exchanged keys this
    /// epoch (their pairwise masks dangle in every fan-in), so the
    /// survivors must surrender shares of their seeds before any sum
    /// can be unmasked. Also tells the scheduler to drain the round
    /// window to 1 so recovery composes with pipelining.
    fn declare_dropped(&mut self, gone: BTreeSet<u16>, out: &mut Outbox) -> Result<()> {
        let round = self.diagnosis_round();
        self.remove_from_live(&gone)?;
        self.unrecovered.extend(gone.iter().copied());
        let msg = Msg::DropoutNotice { round, dropped: gone.iter().copied().collect() };
        self.awaiting_surrender = self.live.clone();
        for &c in &self.live {
            out.send(Addr::Client(c as usize), msg.clone());
        }
        out.note(Note::WindowDrain { round });
        Ok(())
    }

    /// All awaited surrenders arrived (or the laggards were themselves
    /// declared dropped): reconstruct every outstanding seed, rebuild
    /// the dropped sessions, and resume the stalled fan-in.
    fn finish_recovery(&mut self, out: &mut Outbox) -> Result<()> {
        let t = self.threshold.expect("dropout tolerance enabled");
        let t0 = Instant::now();
        for d in std::mem::take(&mut self.unrecovered) {
            let sources = self.surrendered.remove(&d).unwrap_or_default();
            if sources.len() < t {
                bail!(DropoutError::BelowThreshold { survivors: sources.len(), threshold: t });
            }
            // BTreeMap order: shares taken in source-id order on every
            // transport, so reconstruction is deterministic
            let bundles: Vec<Vec<Share>> = sources.into_values().take(t).collect();
            let seed = dropout::reconstruct_seed(&bundles)?;
            // verify against the commitment the dropped client pinned
            // at setup: a corrupted surrendered share must abort, not
            // silently mis-correct every fan-in of the epoch
            match self.commitments.get(&d) {
                Some(c) if dropout::seed_commitment(&seed) == *c => {}
                Some(_) => bail!(DropoutError::SeedCommitmentMismatch { client: d }),
                None => bail!("no pinned seed commitment for dropped client {d}"),
            }
            let session = dropout::rebuild_session(
                seed,
                d as usize,
                self.n_clients,
                self.session_epoch,
                &self.directory,
            );
            self.recovered.insert(d, session);
        }
        self.rec(t0, true);
        // the live set shrank and the recovery corrections exist:
        // every round in flight may now be summable, oldest first
        let rounds: Vec<u32> = self.ctxs.keys().copied().collect();
        for round in rounds {
            let Some(mut ctx) = self.ctxs.remove(&round) else { continue };
            self.maybe_sum_activations(round, &mut ctx, out)?;
            self.maybe_sum_gradients(round, &mut ctx, out)?;
            self.park(round, ctx);
        }
        Ok(())
    }

    /// Quiescence during a setup phase. Before the directory went out,
    /// non-publishers are simply excluded (no one derived a secret with
    /// them — nothing dangles). After it, the epoch is poisoned: peers
    /// already derived masks against the laggards, and no seed shares
    /// exist yet, so the only safe move is a fresh key exchange among
    /// the survivors.
    fn stall_setup(&mut self, out: &mut Outbox) -> Result<()> {
        if !self.directory_sent {
            let published: BTreeSet<u16> = self.keys.iter().map(|k| k.from).collect();
            let gone: BTreeSet<u16> =
                self.live.iter().copied().filter(|c| !published.contains(c)).collect();
            if gone.is_empty() {
                return Ok(());
            }
            self.remove_from_live(&gone)?;
            out.note(Note::WindowDrain { round: self.round });
            self.maybe_broadcast_directory(out);
        } else {
            let gone: BTreeSet<u16> = self
                .live
                .iter()
                .copied()
                .filter(|c| !self.setup_shares.contains_key(c))
                .collect();
            if gone.is_empty() {
                return Ok(());
            }
            self.remove_from_live(&gone)?;
            out.note(Note::WindowDrain { round: self.round });
            self.begin_key_exchange(out);
        }
        Ok(())
    }

    /// Quiescence mid-round: whoever owes the stalled fan-in its next
    /// contribution has dropped. The diagnosis targets the **oldest**
    /// round in flight — its prerequisites are fully delivered, so a
    /// quiescent transport means its missing senders are dead; younger
    /// in-flight rounds may be legitimately waiting on this one (e.g.
    /// a passive cannot forward round r+1 before its relay, which the
    /// active party only sends after finishing round r). The active
    /// party owning the round is unrecoverable; passive laggards are
    /// declared and recovered.
    fn stall_round(&mut self, out: &mut Outbox) -> Result<()> {
        if self.in_setup {
            return self.stall_setup(out);
        }
        // waiting for surrendered shares: laggards there have dropped
        // too — their fan-in contributions arrived (they were survivors
        // when declared), but their own seeds now need recovering
        if !self.awaiting_surrender.is_empty() {
            let gone = std::mem::take(&mut self.awaiting_surrender);
            return self.declare_dropped(gone, out);
        }
        // diagnose the oldest live context; decide first, then act, so
        // the ctx borrow ends before recovery mutates the ring
        enum Diag {
            Nothing,
            ActiveGone,
            Declare(BTreeSet<u16>),
        }
        let diag = {
            let Some((&round, ctx)) = self.ctxs.iter().next() else {
                // every fan-in retired: nothing we can recover (e.g.
                // the active party died after the gradient sum) —
                // leave the outbox empty and let the transport abort
                return Ok(());
            };
            if ctx.kind == RoundKind::Train && !ctx.relayed {
                // batch/weights never arrived: only the active party
                // sends those. If every earlier round has completed at
                // the driver and the active still never opened this
                // one, it is dead — the round has no owner. If an
                // earlier round is still pending, the active may
                // simply be finishing it (the window announces rounds
                // ahead): leave the outbox empty and let the
                // transport's idle-probe escalation decide.
                if self.pending_done.range(..round).next().is_none() {
                    Diag::ActiveGone
                } else {
                    Diag::Nothing
                }
            } else if !ctx.acts_done {
                // chunk senders count only once complete: a
                // half-streamed tensor is a stalled sender, exactly
                // like a missing one
                let mut acts: BTreeSet<u16> = ctx
                    .acts_exact
                    .keys()
                    .chain(ctx.acts_float.keys())
                    .chain(ctx.tree_acts_present.iter())
                    .copied()
                    .chain(ctx.acts_asm.complete_senders())
                    .collect();
                // tree runs: a buffered partial vouches for every live
                // client in its range
                for (&s, v) in &ctx.acts_partial {
                    acts.extend(self.live.range(s..v.0).copied());
                }
                if acts.len() < self.live.len() {
                    let gone: BTreeSet<u16> =
                        self.live.iter().copied().filter(|c| !acts.contains(c)).collect();
                    if gone.contains(&0) {
                        Diag::ActiveGone
                    } else {
                        Diag::Declare(gone)
                    }
                } else {
                    Diag::Nothing
                }
            } else if ctx.kind == RoundKind::Train && !ctx.grads_done {
                let mut grads: BTreeSet<u16> = ctx
                    .grads_exact
                    .keys()
                    .chain(ctx.grads_float.keys())
                    .chain(ctx.tree_grads_present.iter())
                    .copied()
                    .chain(ctx.grads_asm.complete_senders())
                    .collect();
                for (&s, v) in &ctx.grads_partial {
                    grads.extend(self.live.range(s..v.0).filter(|&&c| c != 0).copied());
                }
                if grads.len() < self.live_passives() {
                    let gone: BTreeSet<u16> = self
                        .live
                        .iter()
                        .copied()
                        .filter(|&c| c != 0 && !grads.contains(&c))
                        .collect();
                    Diag::Declare(gone)
                } else {
                    Diag::Nothing
                }
            } else {
                Diag::Nothing
            }
        };
        match diag {
            Diag::Nothing => Ok(()),
            Diag::ActiveGone => bail!(DropoutError::ActivePartyDropped),
            Diag::Declare(gone) => self.declare_dropped(gone, out),
        }
    }

    /// Open a key-exchange leg: request fresh keys from every live
    /// client (initial setup, §5.1 rotation, or post-drop re-key).
    fn begin_key_exchange(&mut self, out: &mut Outbox) {
        self.keys.clear();
        self.setup_shares.clear();
        self.commitments.clear();
        self.directory_sent = false;
        self.in_setup = true;
        for &c in &self.live {
            out.send(Addr::Client(c as usize), Msg::RequestKeys { epoch: self.epoch });
        }
    }

    /// Broadcast the key directory once every live client published.
    fn maybe_broadcast_directory(&mut self, out: &mut Outbox) {
        if self.keys.len() < self.live.len() {
            return;
        }
        let mut all = std::mem::take(&mut self.keys);
        all.sort_by_key(|k| k.from);
        // keep the padded directory: recovery rebuilds dropped sessions
        // against exactly what the clients derived from
        self.directory = pad_directory(&all, self.n_clients);
        let dir = Msg::KeyDirectory { epoch: self.epoch, all };
        for &i in &self.live {
            out.send(Addr::Client(i as usize), dir.clone());
        }
        self.session_epoch = self.epoch;
        self.epoch += 1;
        self.directory_sent = true;
        // a fresh epoch has no dangling masks: dropped clients are
        // excluded from the new directory entirely
        self.recovered.clear();
        if self.threshold.is_none() {
            self.in_setup = false;
        }
    }

    /// Relay the sealed seed-share bundles once every live client sent
    /// theirs — completing the dropout-tolerant setup phase.
    fn maybe_relay_shares(&mut self, out: &mut Outbox) {
        if self.setup_shares.len() < self.live.len() {
            return;
        }
        for &j in &self.live {
            let sealed: Vec<Vec<u8>> = (0..self.n_clients)
                .map(|i| {
                    self.setup_shares
                        .get(&(i as u16))
                        .and_then(|v| v.get(j as usize))
                        .cloned()
                        .unwrap_or_default()
                })
                .collect();
            out.send(
                Addr::Client(j as usize),
                Msg::ShareRelay { epoch: self.session_epoch, sealed },
            );
        }
        self.setup_shares.clear();
        self.in_setup = false;
    }
}

impl<'e> Party for Aggregator<'e> {
    fn addr(&self) -> Addr {
        Addr::Aggregator
    }

    fn on_round_start(&mut self, spec: &RoundSpec, out: &mut Outbox) -> Result<()> {
        self.round = spec.round;
        self.phase = spec.phase;
        self.pending_done.insert(spec.round);
        if spec.kind != RoundKind::Setup {
            if self.ctxs.len() >= MAX_ROUNDS_IN_FLIGHT {
                bail!(
                    "aggregator: round-context ring overflow ({} live rounds)",
                    self.ctxs.len()
                );
            }
            let ctx = self.new_ctx(spec.round, spec.kind);
            self.ctxs.insert(spec.round, ctx);
        }
        if spec.kind == RoundKind::Setup || spec.rotate {
            self.begin_key_exchange(out);
        }
        Ok(())
    }

    fn on_message(&mut self, from: Addr, msg: Msg, out: &mut Outbox) -> Result<()> {
        // traffic from a declared-dropped client (e.g. one that was
        // slow rather than dead, or a late message already in flight)
        // is ignored for the rest of the run
        // (a PartialSum is authored by a leaf on behalf of its whole
        // shard — the carrying connection's client id is immaterial,
        // and the root intersects the range with its own live set)
        if let Addr::Client(i) = from {
            if !self.live.contains(&(i as u16)) && !matches!(msg, Msg::PartialSum { .. }) {
                return Ok(());
            }
        }
        // per-round fan-in traffic detaches its context from the ring,
        // operates with full access to the recovery state, and parks it
        // back (or retires it when the round's obligations are met)
        let ctx_of = |ctxs: &mut BTreeMap<u32, AggRoundCtx>, round: u32| -> Result<AggRoundCtx> {
            ctxs.remove(&round)
                .with_context(|| format!("fan-in traffic for unknown round {round}"))
        };
        match msg {
            Msg::PublishKeys(k) => {
                self.keys.push(k);
                self.maybe_broadcast_directory(out);
            }
            Msg::SeedShares { epoch, from, commitment, sealed } => {
                // a re-key abandons the poisoned epoch: shares for it
                // that were still in flight must not mix into the new
                // collection (directory_sent is false between the
                // re-key request and the fresh directory)
                if self.directory_sent && epoch == self.session_epoch {
                    self.commitments.insert(from, commitment);
                    self.setup_shares.insert(from, sealed);
                    self.maybe_relay_shares(out);
                }
            }
            Msg::SurrenderShares { from, bundles, .. } => {
                if !self.awaiting_surrender.remove(&from) {
                    return Ok(());
                }
                let t0 = Instant::now();
                for (d, bytes) in bundles {
                    if self.unrecovered.contains(&d) {
                        let shares = dropout::decode_shares(&bytes)
                            .with_context(|| format!("bad surrendered shares from {from}"))?;
                        self.surrendered.entry(d).or_default().insert(from, shares);
                    }
                }
                self.rec(t0, true);
                if self.awaiting_surrender.is_empty() {
                    self.finish_recovery(out)?;
                }
            }
            Msg::BatchSelect { round, labels, entries } => {
                let mut ctx = ctx_of(&mut self.ctxs, round)?;
                ctx.labels = labels;
                ctx.relay_entries = Some(entries);
                self.maybe_relay(round, &mut ctx, out);
                self.park(round, ctx);
            }
            Msg::PlainBatch { round, labels, ids } => {
                let mut ctx = ctx_of(&mut self.ctxs, round)?;
                ctx.labels = labels;
                ctx.relay_ids = Some(ids);
                self.maybe_relay(round, &mut ctx, out);
                self.park(round, ctx);
            }
            Msg::WeightsUpdate { round, flat } => {
                let mut ctx = ctx_of(&mut self.ctxs, round)?;
                ctx.group_flats = Some(self.split_group_weights(&flat));
                self.maybe_relay(round, &mut ctx, out);
                self.park(round, ctx);
            }
            Msg::MaskedActivation { round, from, words } => {
                let mut ctx = ctx_of(&mut self.ctxs, round)?;
                ctx.acts_exact.insert(from, words);
                self.note_buffered(&mut ctx);
                self.maybe_sum_activations(round, &mut ctx, out)?;
                self.park(round, ctx);
            }
            Msg::FloatActivation { round, from, vals } => {
                let mut ctx = ctx_of(&mut self.ctxs, round)?;
                ctx.acts_float.insert(from, vals);
                self.note_buffered(&mut ctx);
                self.maybe_sum_activations(round, &mut ctx, out)?;
                self.park(round, ctx);
            }
            Msg::MaskedGradient { round, from, words } => {
                let mut ctx = ctx_of(&mut self.ctxs, round)?;
                ctx.grads_exact.insert(from, words);
                self.note_buffered(&mut ctx);
                self.maybe_sum_gradients(round, &mut ctx, out)?;
                self.park(round, ctx);
            }
            Msg::FloatGradient { round, from, vals } => {
                let mut ctx = ctx_of(&mut self.ctxs, round)?;
                ctx.grads_float.insert(from, vals);
                self.note_buffered(&mut ctx);
                self.maybe_sum_gradients(round, &mut ctx, out)?;
                self.park(round, ctx);
            }
            Msg::MaskedChunk { round, from, tag, shard, offset, total, words } => {
                let mut ctx = ctx_of(&mut self.ctxs, round)?;
                let t0 = Instant::now();
                match tag as u32 {
                    TAG_ACTIVATION => {
                        ctx.acts_asm.add_chunk(from, shard, offset, total, &words)?;
                        self.rec(t0, false);
                        self.note_buffered(&mut ctx);
                        self.maybe_sum_activations(round, &mut ctx, out)?;
                    }
                    TAG_GRADIENT => {
                        ctx.grads_asm.add_chunk(from, shard, offset, total, &words)?;
                        self.rec(t0, false);
                        self.note_buffered(&mut ctx);
                        self.maybe_sum_gradients(round, &mut ctx, out)?;
                    }
                    t => bail!("masked chunk with unknown tensor tag {t}"),
                }
                self.park(round, ctx);
            }
            Msg::PartialSum { round, tag, shard_start, shard_end, words } => {
                if shard_start >= shard_end || shard_end as usize > self.n_clients {
                    bail!("partial sum with invalid client range {shard_start}..{shard_end}");
                }
                // a partial for a round the root already retired is the
                // tree twin of a late message from a declared-dropped
                // client: the sum went out pre-drop, there is nothing
                // left to correct. A distributed leaf re-emits without
                // knowing the root's ring state, so this is tolerance,
                // not an error (the in-process wrapper filters the same
                // case before feeding).
                let Some(mut ctx) = self.ctxs.remove(&round) else {
                    return Ok(());
                };
                // keyed by shard_start: a corrected re-emission after a
                // post-emission dropout purge replaces its predecessor
                match tag as u32 {
                    TAG_ACTIVATION => {
                        ctx.acts_partial.insert(shard_start, (shard_end, words));
                        self.note_buffered(&mut ctx);
                        self.maybe_sum_activations(round, &mut ctx, out)?;
                    }
                    TAG_GRADIENT => {
                        ctx.grads_partial.insert(shard_start, (shard_end, words));
                        self.note_buffered(&mut ctx);
                        self.maybe_sum_gradients(round, &mut ctx, out)?;
                    }
                    t => bail!("partial sum with unknown tensor tag {t}"),
                }
                self.park(round, ctx);
            }
            m => bail!("aggregator: unexpected message {m:?}"),
        }
        Ok(())
    }

    fn on_stall(&mut self, out: &mut Outbox) -> Result<()> {
        if self.threshold.is_none() {
            // base protocol: a silent peer is a stall, not a dropout
            return Ok(());
        }
        if self.in_setup {
            self.stall_setup(out)
        } else {
            self.stall_round(out)
        }
    }

    fn on_round_complete(&mut self, round: u32) {
        self.pending_done.remove(&round);
    }

    fn concurrent_safe(&self) -> bool {
        self.backend.concurrent_safe()
    }

    fn take_metrics(&mut self) -> Metrics {
        std::mem::take(&mut self.metrics)
    }
}

/// Helper: serialize a message to its wire bytes.
pub fn encode_msg(m: &Msg) -> Vec<u8> {
    m.encode()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_layout_offsets() {
        let cfg = ModelConfig::for_dataset("banking").unwrap();
        let l = GradLayout::new(&cfg);
        assert_eq!(l.active_w, (0, 57 * 64));
        assert_eq!(l.active_b, (57 * 64, 64));
        assert_eq!(l.groups[0], (57 * 64 + 64, 3 * 64));
        assert_eq!(l.groups[1], (57 * 64 + 64 + 3 * 64, 20 * 64));
        assert_eq!(l.total, 57 * 64 + 64 + 3 * 64 + 20 * 64);
    }

    #[test]
    fn seal_open_id() {
        let key = [9u8; 32];
        let sealed = seal_id(&key, 3, 17, 0xdeadbeef);
        assert_eq!(sealed.len(), 8 + 16); // id + tag
        assert_eq!(open_id(&key, 3, 17, &sealed), Some(0xdeadbeef));
        // wrong seq / round / key → None
        assert_eq!(open_id(&key, 3, 18, &sealed), None);
        assert_eq!(open_id(&key, 4, 17, &sealed), None);
        assert_eq!(open_id(&[8u8; 32], 3, 17, &sealed), None);
    }

    #[test]
    fn party_rng_streams_distinct() {
        let mut a = party_rng(7, 0);
        let mut b = party_rng(7, 1);
        let mut a2 = party_rng(7, 0);
        assert_ne!(a.next_u64(), b.next_u64(), "distinct parties, distinct streams");
        let mut a = party_rng(7, 0);
        assert_eq!(a.next_u64(), a2.next_u64(), "same party, same stream");
    }
}
