//! Secure-aggregation core (§4 of the paper).
//!
//! * [`fixedpoint`] — the f32 ⇄ ℤ₂⁶⁴ codec that makes pairwise masks
//!   cancel exactly.
//! * [`session`] — the setup phase (per-peer X25519 keypairs, pairwise
//!   secret derivation, key rotation epochs) and per-round tensor
//!   masking (Eq. 2–6).
//! * [`dropout`] — the Bonawitz'17 Shamir-based dropout recovery
//!   extension (§5.1's robustness discussion): sealed seed-share
//!   distribution, surrendered-share reconstruction, and the typed
//!   [`DropoutError`] abort. Wired into the live protocol by the
//!   [`coordinator`](crate::coordinator) party machines.

pub mod dropout;
pub mod fixedpoint;
pub mod session;

pub use dropout::{DropoutError, PartySession, RobustClientSession};
pub use fixedpoint::FixedPoint;
pub use session::{aggregate, mask_window_into, setup_all, ClientSession, PublishedKeys};
