//! A secure-aggregation session: the paper's setup phase (§4.0.1) plus
//! the per-round masking machinery used by the training phase (§4.0.2).
//!
//! A session binds a set of clients. Each client generates one X25519
//! keypair *per peer* (exactly as §4.0.1 describes), public keys are
//! relayed through the aggregator, and every ordered pair (i, j)
//! derives `ss_ij = ss_ji`. From that shared secret we derive, with
//! domain separation: the pairwise AEAD key (sample-ID encryption) and
//! the pairwise mask-PRG seed. Key rotation (§5.1) is re-running this
//! setup every K rounds; the session tracks its `epoch` so rotated
//! sessions produce fresh, unrelated masks.

use crate::crypto::hkdf;
use crate::crypto::prg;
use crate::crypto::rng::DetRng;
use crate::crypto::x25519::{PublicKey, SecretKey};

use super::fixedpoint::FixedPoint;

/// Per-client state for one secure-aggregation epoch.
pub struct ClientSession {
    pub id: usize,
    pub n_clients: usize,
    pub epoch: u64,
    /// One secret key per peer (index: peer id). `None` at our own slot.
    secret_keys: Vec<Option<SecretKey>>,
    /// Derived pairwise shared secrets (raw X25519 output run through
    /// HKDF-extract). `None` at our own slot until setup completes.
    shared: Vec<Option<[u8; 32]>>,
    pub fp: FixedPoint,
}

/// The public keys a client publishes: element j is the key intended
/// for peer j (`pk_i^{(j)}` in the paper).
#[derive(Clone)]
pub struct PublishedKeys {
    pub from: usize,
    pub keys: Vec<Option<PublicKey>>,
}

impl ClientSession {
    /// Phase 1: generate one keypair per peer.
    pub fn new(id: usize, n_clients: usize, epoch: u64, rng: &mut DetRng) -> Self {
        assert!(id < n_clients);
        let mut secret_keys = Vec::with_capacity(n_clients);
        for j in 0..n_clients {
            if j == id {
                secret_keys.push(None);
            } else {
                let mut seed = [0u8; 32];
                rng.fill(&mut seed);
                secret_keys.push(Some(SecretKey::from_bytes(seed)));
            }
        }
        ClientSession {
            id,
            n_clients,
            epoch,
            secret_keys,
            shared: vec![None; n_clients],
            fp: FixedPoint::default(),
        }
    }

    /// Public keys to upload to the aggregator.
    pub fn published_keys(&self) -> PublishedKeys {
        PublishedKeys {
            from: self.id,
            keys: self.secret_keys.iter().map(|sk| sk.as_ref().map(|s| s.public_key())).collect(),
        }
    }

    /// Phase 2: after the aggregator relays everyone's published keys,
    /// derive the pairwise shared secrets. `all_keys[i]` is client i's
    /// `PublishedKeys`. Peers with no key for us (e.g. dropped before
    /// publishing — their directory slot is padded with `None`s) get no
    /// shared secret and contribute no masks; the pairwise telescoping
    /// (Eq. 4) still holds over the peers that do.
    pub fn derive_secrets(&mut self, all_keys: &[PublishedKeys]) {
        assert_eq!(all_keys.len(), self.n_clients);
        for j in 0..self.n_clients {
            if j == self.id {
                continue;
            }
            // peer j published pk_j^{(id)} for us; we use sk_id^{(j)}
            let Some(peer_pk) = all_keys[j].keys.get(self.id).copied().flatten() else {
                self.shared[j] = None;
                continue;
            };
            let my_sk = self.secret_keys[j].as_ref().expect("our key for peer");
            let raw = my_sk.diffie_hellman(&peer_pk);
            // bind the epoch so rotated sessions derive fresh secrets
            let mut info = Vec::with_capacity(16);
            info.extend_from_slice(b"ss");
            info.extend_from_slice(&self.epoch.to_le_bytes());
            self.shared[j] = Some(hkdf::derive_key32(b"vfl-sa/setup/v1", &raw, &info));
        }
    }

    /// Whether setup established a shared secret with peer `j`.
    pub fn has_secret(&self, j: usize) -> bool {
        self.shared[j].is_some()
    }

    /// The pairwise shared secret with peer `j` (post-setup).
    pub fn shared_secret(&self, j: usize) -> &[u8; 32] {
        self.shared[j].as_ref().expect("setup incomplete")
    }

    /// AEAD key for the (self, j) channel, independent of direction.
    pub fn channel_key(&self, j: usize) -> [u8; 32] {
        hkdf::derive_key32(b"vfl-sa/channel/v1", self.shared_secret(j), b"aead")
    }

    /// The total pairwise mask this client adds for (round, tag) —
    /// the quantity dropout recovery must reproduce and subtract
    /// (Eq. 3; epoch mixing included). Peers without a shared secret
    /// contribute nothing.
    pub fn total_mask(&self, round: u64, tensor_tag: u32, len: usize) -> Vec<u64> {
        let secrets: Vec<(usize, [u8; 32])> = (0..self.n_clients)
            .filter(|&j| j != self.id)
            .filter_map(|j| self.shared[j].map(|s| (j, s)))
            .collect();
        prg::total_mask(&secrets, self.id, round ^ (self.epoch << 32), tensor_tag, len)
    }

    /// Mask and fixed-point-encode a float tensor for a round
    /// (Eq. 2 / Eq. 6): returns the ℤ₂⁶⁴ words to send.
    pub fn mask_tensor(&self, values: &[f32], round: u64, tensor_tag: u32) -> Vec<u64> {
        let mut words = self.fp.encode_vec(values);
        let mask = self.total_mask(round, tensor_tag, words.len());
        for (w, m) in words.iter_mut().zip(mask.iter()) {
            *w = w.wrapping_add(*m);
        }
        words
    }

    /// The total mask as a windowed stream (the chunked pipeline's view
    /// of [`total_mask`]): no mask words are expanded until a window is
    /// requested, and windows reassemble the monolithic mask
    /// bit-for-bit. Peers without a shared secret contribute nothing.
    pub fn total_mask_stream(&self, round: u64, tensor_tag: u32) -> prg::TotalMaskStream {
        let secrets: Vec<(usize, [u8; 32])> = (0..self.n_clients)
            .filter(|&j| j != self.id)
            .filter_map(|j| self.shared[j].map(|s| (j, s)))
            .collect();
        prg::TotalMaskStream::new(&secrets, self.id, round ^ (self.epoch << 32), tensor_tag)
    }

    /// Mask and encode one window of a float tensor: `values` is the
    /// window's slice, `offset` its starting word in the full tensor.
    /// Equals `mask_tensor(full, ..)[offset..offset + values.len()]`
    /// bit-for-bit (fixed-point encoding is element-wise and ℤ₂⁶⁴
    /// addition is element-wise), which is what keeps a chunked run
    /// report-identical to a monolithic one.
    pub fn mask_tensor_window(
        &self,
        stream: &prg::TotalMaskStream,
        values: &[f32],
        offset: usize,
    ) -> Vec<u64> {
        let mut words = self.fp.encode_vec(values);
        stream.add_window(offset, &mut words);
        words
    }

    /// `mask_tensor_window` straight into a wire buffer: encode + mask
    /// in fixed-size stack groups and append the finished words with
    /// [`Writer::u64s_raw`], so the chunk sender never materializes a
    /// temporary full-window `Vec<u64>`. Bytes appended are exactly the
    /// serialization of `mask_tensor_window(stream, values, offset)`
    /// (the frame-encode rule; pinned by
    /// `windowed_masking_into_writer_matches_vec_path`).
    pub fn mask_tensor_window_into(
        &self,
        stream: &prg::TotalMaskStream,
        values: &[f32],
        offset: usize,
        w: &mut crate::net::wire::Writer,
    ) {
        mask_window_into(self.fp, stream, values, offset, w);
    }

    /// [`Self::mask_tensor`] expanded across an
    /// [`ExpandPool`](prg::ExpandPool) (`--expand-workers` > 1): the
    /// tensor is partitioned into disjoint sub-windows, each worker
    /// fixed-point-encodes its slice and folds its window of the total
    /// mask through its own clone of the seekable stream, and the
    /// segments are stitched in offset order. Bit-identical to the
    /// serial path: encoding is element-wise and the window-partition
    /// property makes any partition reassemble the monolithic mask.
    pub fn mask_tensor_pooled(
        &self,
        pool: &prg::ExpandPool,
        values: &[f32],
        round: u64,
        tensor_tag: u32,
    ) -> Vec<u64> {
        let parts = prg::partition_window(0, values.len(), pool.workers());
        if parts.len() <= 1 {
            return self.mask_tensor(values, round, tensor_tag);
        }
        let stream = self.total_mask_stream(round, tensor_tag);
        let fp = self.fp;
        let jobs: Vec<Box<dyn FnOnce() -> Vec<u64> + Send + 'static>> = parts
            .iter()
            .map(|&(off, len)| {
                let s = stream.clone();
                let vals = values[off..off + len].to_vec();
                let f: Box<dyn FnOnce() -> Vec<u64> + Send + 'static> = Box::new(move || {
                    let mut words = fp.encode_vec(&vals);
                    s.add_window(off, &mut words);
                    words
                });
                f
            })
            .collect();
        let mut out = Vec::with_capacity(values.len());
        for seg in pool.run(jobs) {
            out.extend(seg);
        }
        out
    }

    /// Float-domain masking (SecurityMode::SecureFloat): pairwise ±f32
    /// masks added directly to the values. Payload stays 4 B/element
    /// (size parity with unsecured VFL); cancellation is exact up to
    /// float addition order (≤ a few ulps of the mask magnitude).
    pub fn mask_tensor_f32(&self, values: &[f32], round: u64, tensor_tag: u32) -> Vec<f32> {
        let mut out = values.to_vec();
        for j in 0..self.n_clients {
            if j == self.id {
                continue;
            }
            let Some(ss) = self.shared[j].as_ref() else { continue };
            let words =
                prg::mask_words(ss, round ^ (self.epoch << 32), tensor_tag, values.len());
            let sign = if j > self.id { 1.0f32 } else { -1.0f32 };
            for (v, w) in out.iter_mut().zip(words.iter()) {
                // uniform in [-8, 8)
                let m = ((*w as f64 / 2f64.powi(64)) * 16.0 - 8.0) as f32;
                *v += sign * m;
            }
        }
        out
    }

    /// Pairwise mask contribution for a single dropped peer (used by
    /// dropout recovery to subtract a missing client's masks).
    pub fn pairwise_mask_with(&self, peer: usize, round: u64, tensor_tag: u32, len: usize) -> Vec<u64> {
        prg::pairwise_mask(
            self.shared_secret(peer),
            self.id,
            peer,
            round ^ (self.epoch << 32),
            tensor_tag,
            len,
        )
    }
}

/// The session-free body of [`ClientSession::mask_tensor_window_into`]:
/// encode + mask one window in fixed-size stack groups straight into a
/// wire buffer. Free-standing (parametrized by the [`FixedPoint`]
/// codec) so an [`ExpandPool`](prg::ExpandPool) job — which cannot
/// borrow the session across threads — runs the identical code path
/// the serial sender runs.
pub fn mask_window_into(
    fp: FixedPoint,
    stream: &prg::TotalMaskStream,
    values: &[f32],
    offset: usize,
    w: &mut crate::net::wire::Writer,
) {
    // group size in words; cut at absolute 256-word boundaries so
    // the mask stream's grouped x4 interior stays block-aligned
    const GROUP: usize = 256;
    let mut scratch = [0u64; GROUP];
    let mut done = 0;
    while done < values.len() {
        let abs = offset + done;
        let n = (GROUP - abs % GROUP).min(values.len() - done);
        for (s, v) in scratch[..n].iter_mut().zip(&values[done..done + n]) {
            *s = fp.encode(*v);
        }
        stream.add_window(abs, &mut scratch[..n]);
        w.u64s_raw(&scratch[..n]);
        done += n;
    }
}

/// Aggregator-side combine: wrap-add all masked vectors and decode.
/// With every client present the masks telescope to zero (Eq. 4-5).
pub fn aggregate(fp: &FixedPoint, masked: &[Vec<u64>]) -> Vec<f32> {
    assert!(!masked.is_empty());
    let len = masked[0].len();
    let mut acc = vec![0u64; len];
    for m in masked {
        assert_eq!(m.len(), len, "masked vectors must be equal length");
        crate::z64::wrap_add(&mut acc, m);
    }
    fp.decode_vec(&acc)
}

/// Run the full setup phase for n clients in-process (used by tests,
/// examples and the simulated coordinator).
pub fn setup_all(n: usize, epoch: u64, rng: &mut DetRng) -> Vec<ClientSession> {
    let mut sessions: Vec<ClientSession> =
        (0..n).map(|i| ClientSession::new(i, n, epoch, rng)).collect();
    let keys: Vec<PublishedKeys> = sessions.iter().map(|s| s.published_keys()).collect();
    for s in sessions.iter_mut() {
        s.derive_secrets(&keys);
    }
    sessions
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_secrets_symmetric() {
        let mut rng = DetRng::from_seed(1);
        let sessions = setup_all(4, 0, &mut rng);
        for i in 0..4 {
            for j in 0..4 {
                if i != j {
                    assert_eq!(
                        sessions[i].shared_secret(j),
                        sessions[j].shared_secret(i),
                        "ss_{i}{j} != ss_{j}{i}"
                    );
                }
            }
        }
    }

    #[test]
    fn secrets_distinct_across_pairs_and_epochs() {
        let mut rng = DetRng::from_seed(2);
        let s0 = setup_all(3, 0, &mut rng);
        assert_ne!(s0[0].shared_secret(1), s0[0].shared_secret(2));
        let mut rng2 = DetRng::from_seed(2); // same entropy!
        let s1 = setup_all(3, 1, &mut rng2);
        // same DH output, different epoch → different derived secret
        assert_ne!(s0[0].shared_secret(1), s1[0].shared_secret(1));
    }

    #[test]
    fn masked_aggregation_matches_plain_sum() {
        let mut rng = DetRng::from_seed(3);
        let n = 5;
        let len = 64;
        let sessions = setup_all(n, 0, &mut rng);
        let tensors: Vec<Vec<f32>> = (0..n)
            .map(|i| (0..len).map(|j| ((i * len + j) as f32) * 0.125 - 20.0).collect())
            .collect();
        let masked: Vec<Vec<u64>> =
            sessions.iter().zip(&tensors).map(|(s, t)| s.mask_tensor(t, 7, 1)).collect();
        let got = aggregate(&FixedPoint::default(), &masked);
        for j in 0..len {
            let want: f32 = tensors.iter().map(|t| t[j]).sum();
            assert!((got[j] - want).abs() < 1e-4, "j={j} got={} want={want}", got[j]);
        }
    }

    #[test]
    fn single_masked_vector_is_garbage() {
        // one masked tensor alone decodes to noise, not the plaintext
        let mut rng = DetRng::from_seed(4);
        let sessions = setup_all(3, 0, &mut rng);
        let t = vec![1.0f32; 16];
        let masked = sessions[0].mask_tensor(&t, 0, 0);
        let decoded = FixedPoint::default().decode_vec(&masked);
        let close = decoded.iter().filter(|&&v| (v - 1.0).abs() < 1.0).count();
        assert!(close <= 1, "masked vector leaks plaintext: {decoded:?}");
    }

    #[test]
    fn masks_fresh_per_round() {
        let mut rng = DetRng::from_seed(5);
        let sessions = setup_all(2, 0, &mut rng);
        let t = vec![0.0f32; 8];
        assert_ne!(sessions[0].mask_tensor(&t, 1, 0), sessions[0].mask_tensor(&t, 2, 0));
    }

    #[test]
    fn channel_keys_symmetric_and_domain_separated() {
        let mut rng = DetRng::from_seed(6);
        let sessions = setup_all(3, 0, &mut rng);
        assert_eq!(sessions[0].channel_key(1), sessions[1].channel_key(0));
        assert_ne!(sessions[0].channel_key(1), *sessions[0].shared_secret(1));
    }

    #[test]
    fn missing_peer_keys_tolerated_and_masks_still_telescope() {
        // client 2 never published (dropped during setup): the others
        // derive no secret with it, add no masks against it, and the
        // survivor sum still cancels exactly
        let n = 4;
        let absent = 2usize;
        let mut rng = DetRng::from_seed(9);
        let mut sessions: Vec<ClientSession> =
            (0..n).map(|i| ClientSession::new(i, n, 0, &mut rng)).collect();
        let mut keys: Vec<PublishedKeys> = sessions.iter().map(|s| s.published_keys()).collect();
        keys[absent] = PublishedKeys { from: absent, keys: vec![None; n] };
        for (i, s) in sessions.iter_mut().enumerate() {
            if i != absent {
                s.derive_secrets(&keys);
            }
        }
        assert!(!sessions[0].has_secret(absent));
        assert!(sessions[0].has_secret(1));
        let t = vec![1.5f32; 8];
        let masked: Vec<Vec<u64>> = (0..n)
            .filter(|&i| i != absent)
            .map(|i| sessions[i].mask_tensor(&t, 3, 0))
            .collect();
        let got = aggregate(&FixedPoint::default(), &masked);
        for v in got {
            assert!((v - 4.5).abs() < 1e-4, "survivor masks must telescope: {v}");
        }
    }

    #[test]
    fn chunked_masking_matches_monolithic() {
        // mask_tensor_window over any partition of the tensor must
        // reassemble mask_tensor bit-for-bit — including lengths not
        // divisible by the chunk size
        let mut rng = DetRng::from_seed(21);
        let sessions = setup_all(4, 1, &mut rng);
        let s = &sessions[2];
        for len in [1usize, 5, 8, 67, 256] {
            let vals: Vec<f32> = (0..len).map(|j| (j as f32) * 0.375 - 9.5).collect();
            let mono = s.mask_tensor(&vals, 13, 1);
            let stream = s.total_mask_stream(13, 1);
            for chunk in [1usize, 3, 16, 100] {
                let mut got = Vec::with_capacity(len);
                let mut off = 0;
                while off < len {
                    let n = chunk.min(len - off);
                    got.extend(s.mask_tensor_window(&stream, &vals[off..off + n], off));
                    off += n;
                }
                assert_eq!(got, mono, "len={len} chunk={chunk}");
            }
        }
    }

    #[test]
    fn windowed_masking_into_writer_matches_vec_path() {
        // the zero-copy writer path must append exactly the bytes of
        // the Vec<u64> path's serialization — window offsets straddling
        // the 256-word group boundary included
        use crate::net::wire::Writer;
        let mut rng = DetRng::from_seed(22);
        let sessions = setup_all(3, 1, &mut rng);
        let s = &sessions[1];
        let stream = s.total_mask_stream(5, 0);
        for (offset, len) in
            [(0usize, 1usize), (0, 256), (0, 300), (7, 250), (255, 2), (256, 513), (511, 600)]
        {
            let vals: Vec<f32> = (0..len).map(|j| (j as f32) * 0.25 - 31.0).collect();
            let words = s.mask_tensor_window(&stream, &vals, offset);
            let mut want = Writer::new();
            want.u64s_raw(&words);
            let mut got = Writer::new();
            s.mask_tensor_window_into(&stream, &vals, offset, &mut got);
            assert_eq!(got.finish(), want.finish(), "offset={offset} len={len}");
        }
    }

    #[test]
    fn pooled_masking_matches_serial_across_worker_counts() {
        // mask_tensor_pooled must be bit-identical to mask_tensor for
        // any worker count and tensor length — including lengths that
        // collapse to a single partition part
        let mut rng = DetRng::from_seed(23);
        let sessions = setup_all(4, 1, &mut rng);
        let s = &sessions[2];
        for workers in [1usize, 2, 5] {
            let pool = crate::crypto::prg::ExpandPool::new(workers);
            for len in [1usize, 31, 32, 67, 256, 1000] {
                let vals: Vec<f32> = (0..len).map(|j| (j as f32) * 0.375 - 9.5).collect();
                let serial = s.mask_tensor(&vals, 13, 1);
                let pooled = s.mask_tensor_pooled(&pool, &vals, 13, 1);
                assert_eq!(pooled, serial, "workers={workers} len={len}");
            }
        }
    }

    #[test]
    fn total_mask_matches_masked_minus_plain() {
        let mut rng = DetRng::from_seed(10);
        let sessions = setup_all(3, 2, &mut rng);
        let t = vec![0.25f32; 6];
        let masked = sessions[1].mask_tensor(&t, 7, 1);
        let enc = FixedPoint::default().encode_vec(&t);
        let mask = sessions[1].total_mask(7, 1, 6);
        for ((m, e), k) in masked.iter().zip(&enc).zip(&mask) {
            assert_eq!(*m, e.wrapping_add(*k));
        }
    }

    #[test]
    fn aggregation_with_two_to_sixteen_parties() {
        for n in [2usize, 3, 8, 16] {
            let mut rng = DetRng::from_seed(100 + n as u64);
            let sessions = setup_all(n, 0, &mut rng);
            let tensors: Vec<Vec<f32>> =
                (0..n).map(|i| vec![i as f32 + 0.5; 4]).collect();
            let masked: Vec<Vec<u64>> =
                sessions.iter().zip(&tensors).map(|(s, t)| s.mask_tensor(t, 0, 0)).collect();
            let got = aggregate(&FixedPoint::default(), &masked);
            let want: f32 = (0..n).map(|i| i as f32 + 0.5).sum();
            for v in got {
                assert!((v - want).abs() < 1e-4, "n={n}");
            }
        }
    }
}
