//! Dropout recovery for secure aggregation (Bonawitz et al. 2017).
//!
//! The base protocol of the paper assumes all parties stay online for a
//! round: if one drops after peers have already added pairwise masks
//! against it, the aggregate no longer cancels. The classic fix, which
//! the paper cites as its security foundation, is to have each client
//! Shamir-share its per-peer DH secret keys among all clients at setup;
//! if client d drops mid-round, any t surviving clients hand the
//! aggregator their shares, the aggregator reconstructs d's key,
//! re-derives the pairwise secrets and subtracts d's missing mask
//! contributions.
//!
//! This module implements that extension end-to-end on top of
//! [`ClientSession`](super::session::ClientSession) + [`shamir`]:
//! share bundles are serialized with [`encode_shares`], sealed under
//! the pairwise AEAD channel with [`seal_bundle`] (so the relaying
//! aggregator never sees a share in the clear), and a reconstructed
//! seed is turned back into a working session with
//! [`rebuild_session`]. [`DropoutError`] is the typed abort the
//! protocol raises when recovery is impossible.

use anyhow::{bail, Result};

use crate::crypto::rng::DetRng;
use crate::crypto::shamir::{self, Share};
use crate::crypto::{aead, hkdf};
use crate::net::wire::{Reader, Writer};

use super::session::{ClientSession, PublishedKeys};

/// Why a dropout-tolerant round had to abort instead of recovering.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DropoutError {
    /// Fewer than `threshold` clients survive: the dropped seeds can
    /// never be reconstructed, so aborting is the only safe outcome.
    BelowThreshold { survivors: usize, threshold: usize },
    /// The active party (labels, SGD step) dropped — the VFL round has
    /// no owner and cannot be completed by anyone else.
    ActivePartyDropped,
    /// The seed reconstructed for `client` does not match the
    /// commitment that client pinned at setup: at least one
    /// surrendered share was corrupted (a malicious surrenderer).
    /// Continuing would add a *wrong* mask correction and silently
    /// corrupt the aggregate, so the run aborts.
    SeedCommitmentMismatch { client: u16 },
}

impl std::fmt::Display for DropoutError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DropoutError::BelowThreshold { survivors, threshold } => write!(
                f,
                "below dropout threshold: {survivors} survivor(s), need {threshold} for recovery"
            ),
            DropoutError::ActivePartyDropped => write!(f, "active party dropped mid-round"),
            DropoutError::SeedCommitmentMismatch { client } => write!(
                f,
                "reconstructed seed for client {client} fails its pinned commitment \
                 (corrupted surrendered share)"
            ),
        }
    }
}

impl std::error::Error for DropoutError {}

/// Shares of one client's session seed, one bundle per recipient peer.
pub struct SeedShares {
    pub owner: usize,
    /// `bundles[j]` is the share vector entrusted to client j.
    pub bundles: Vec<Vec<Share>>,
}

/// A client session extended with dropout-recovery material.
pub struct RobustClientSession {
    pub inner: ClientSession,
    /// The seed from which this client's per-peer secret keys derive.
    seed: [u8; 32],
    /// Shares received from every peer (`held[i]` = shares of client i's seed).
    held: Vec<Option<Vec<Share>>>,
    threshold: usize,
}

impl RobustClientSession {
    /// Create a session whose per-peer keys derive deterministically
    /// from a single 32-byte seed (so sharing the seed shares the keys).
    pub fn new(id: usize, n: usize, epoch: u64, threshold: usize, rng: &mut DetRng) -> Self {
        let mut seed = [0u8; 32];
        rng.fill(&mut seed);
        let mut seeded = DetRng::new(seed);
        let inner = ClientSession::new(id, n, epoch, &mut seeded);
        RobustClientSession { inner, seed, held: vec![None; n], threshold }
    }

    /// Shamir-share our seed for distribution (t-of-n).
    ///
    /// The polynomial coefficients come from a one-shot sub-stream
    /// keyed by 32 fresh bytes of the caller's RNG — never from bytes
    /// the caller will hand out later. (Cloning the RNG and "skipping
    /// ahead" a fixed amount is wrong: the coefficient draw is
    /// t-dependent, and any overlap leaks future session seeds to
    /// whoever holds t shares of this epoch.)
    pub fn share_seed(&self, rng: &mut DetRng) -> SeedShares {
        let n = self.inner.n_clients;
        let mut sub = [0u8; 32];
        rng.fill(&mut sub);
        let mut fill = DetRng::new(sub).as_fill_fn();
        let bundles = shamir::split_bytes(&self.seed, self.threshold, n, &mut fill);
        SeedShares { owner: self.inner.id, bundles }
    }

    /// Store the share bundle entrusted to us by peer `owner`.
    pub fn receive_share(&mut self, owner: usize, bundle: Vec<Share>) {
        self.held[owner] = Some(bundle);
    }

    /// Surrender our share of a dropped peer's seed. Out-of-range ids
    /// (hostile or corrupt wire input) yield `None`, not a panic.
    pub fn surrender_share(&self, dropped: usize) -> Option<&Vec<Share>> {
        self.held.get(dropped)?.as_ref()
    }

    /// The reconstruction threshold this session was created with.
    pub fn threshold(&self) -> usize {
        self.threshold
    }

    /// The binding commitment to this session's seed, published with
    /// the seed shares so the aggregator can verify a reconstruction
    /// (see [`seed_commitment`]).
    pub fn commitment(&self) -> [u8; 32] {
        seed_commitment(&self.seed)
    }
}

/// A party's secure-aggregation session: the base protocol's
/// [`ClientSession`] or, when dropout tolerance is enabled, a
/// [`RobustClientSession`] carrying the Shamir seed-share material.
pub enum PartySession {
    Plain(ClientSession),
    Robust(RobustClientSession),
}

impl PartySession {
    /// The masking session, whichever variant is active.
    pub fn client(&self) -> &ClientSession {
        match self {
            PartySession::Plain(s) => s,
            PartySession::Robust(r) => &r.inner,
        }
    }

    pub fn client_mut(&mut self) -> &mut ClientSession {
        match self {
            PartySession::Plain(s) => s,
            PartySession::Robust(r) => &mut r.inner,
        }
    }

    /// The dropout-recovery extension, if enabled.
    pub fn robust(&self) -> Option<&RobustClientSession> {
        match self {
            PartySession::Plain(_) => None,
            PartySession::Robust(r) => Some(r),
        }
    }

    pub fn robust_mut(&mut self) -> Option<&mut RobustClientSession> {
        match self {
            PartySession::Plain(_) => None,
            PartySession::Robust(r) => Some(r),
        }
    }
}

// ---------------------------------------------------------------------------
// Share-bundle wire form + pairwise sealing
// ---------------------------------------------------------------------------

/// AAD for sealed seed-share bundles (distinct from sample-ID sealing).
const SHARE_AAD: &[u8] = b"vfl-sa/seed-share/v1";

/// Nonce for `owner`'s bundle destined to `recipient`. The round slot
/// is pinned to `u32::MAX`, which no protocol round ever uses, so
/// share nonces can never collide with the active party's sample-ID
/// nonces under the same (symmetric) channel key.
fn share_nonce(owner: usize, recipient: usize) -> [u8; 12] {
    aead::make_nonce(owner as u16, u32::MAX, recipient as u32)
}

/// Serialize one share bundle (u32 count, then (x, y) u64 pairs).
pub fn encode_shares(shares: &[Share]) -> Vec<u8> {
    let mut w = Writer::new();
    w.u32(shares.len() as u32);
    for s in shares {
        w.u64(s.x);
        w.u64(s.y);
    }
    w.finish()
}

/// Parse a share bundle serialized by [`encode_shares`].
pub fn decode_shares(buf: &[u8]) -> Result<Vec<Share>> {
    let mut r = Reader::new(buf);
    let n = r.u32()? as usize;
    let mut out = Vec::with_capacity(n.min(r.remaining()));
    for _ in 0..n {
        out.push(Share { x: r.u64()?, y: r.u64()? });
    }
    if !r.done() {
        bail!("trailing bytes in share bundle");
    }
    Ok(out)
}

/// Seal `owner`'s bundle for `recipient` under their pairwise channel
/// key: the aggregator relays bundles but can never read them (if it
/// could, it could reconstruct every seed and unmask everything).
pub fn seal_bundle(key: &[u8; 32], owner: usize, recipient: usize, shares: &[Share]) -> Vec<u8> {
    aead::seal(key, &share_nonce(owner, recipient), SHARE_AAD, &encode_shares(shares))
}

/// Open a sealed bundle from `owner` addressed to `recipient`.
pub fn open_bundle(
    key: &[u8; 32],
    owner: usize,
    recipient: usize,
    sealed: &[u8],
) -> Option<Vec<Share>> {
    let pt = aead::open(key, &share_nonce(owner, recipient), SHARE_AAD, sealed)?;
    decode_shares(&pt).ok()
}

// ---------------------------------------------------------------------------
// Aggregator-side reconstruction
// ---------------------------------------------------------------------------

/// Reconstruct a 32-byte session seed from ≥ t surrendered bundles.
pub fn reconstruct_seed(bundles: &[Vec<Share>]) -> Result<[u8; 32]> {
    if bundles.is_empty() {
        bail!("no share bundles to reconstruct from");
    }
    let bytes = shamir::reconstruct_bytes(bundles, 32);
    bytes.try_into().map_err(|_| anyhow::anyhow!("reconstructed seed is not 32 bytes"))
}

/// Rebuild a dropped client's full masking session from its
/// reconstructed seed and the published key directory. The returned
/// session yields, via [`ClientSession::total_mask`], exactly the mask
/// the dropped client would have added in any (round, tag) — which is
/// what the aggregator adds to cancel the survivors' dangling masks.
pub fn rebuild_session(
    seed: [u8; 32],
    id: usize,
    n: usize,
    epoch: u64,
    all_keys: &[PublishedKeys],
) -> ClientSession {
    let mut seeded = DetRng::new(seed);
    let mut session = ClientSession::new(id, n, epoch, &mut seeded);
    session.derive_secrets(all_keys);
    session
}

/// Aggregator-side recovery: reconstruct the dropped client's seed from
/// ≥ t shares, rebuild its session, and compute the total mask it would
/// have added for (round, tag, len) so it can be subtracted. Errors if
/// the surrendered bundles are empty or reconstruct to a malformed
/// seed — corrupted shares must surface as a typed failure, never a
/// panic in the recovery path.
#[allow(clippy::too_many_arguments)]
pub fn recover_dropped_mask(
    dropped: usize,
    n: usize,
    epoch: u64,
    shares: &[Vec<Share>],
    all_keys: &[PublishedKeys],
    round: u64,
    tensor_tag: u32,
    len: usize,
) -> Result<Vec<u64>> {
    let seed = reconstruct_seed(shares)?;
    let session = rebuild_session(seed, dropped, n, epoch, all_keys);
    Ok(session.total_mask(round, tensor_tag, len))
}

/// Deterministic binding commitment to a session seed. Every client
/// publishes `seed_commitment(seed)` alongside its sealed seed shares
/// (`Msg::SeedShares`); the aggregator pins the value for the epoch
/// and verifies any reconstructed seed against it before using the
/// rebuilt session — a corrupted surrendered share is then a typed
/// [`DropoutError::SeedCommitmentMismatch`] abort instead of a
/// silently wrong mask correction. (HKDF output reveals nothing about
/// the seed; binding holds under the PRF assumption.)
pub fn seed_commitment(seed: &[u8; 32]) -> [u8; 32] {
    hkdf::derive_key32(b"vfl-sa/seed-commit/v1", seed, b"commit")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::secagg::fixedpoint::FixedPoint;

    /// Full dropout scenario: n clients mask tensors, one drops after
    /// masking was committed by peers; t survivors reconstruct and the
    /// aggregator subtracts the missing masks.
    #[test]
    fn dropout_recovery_end_to_end() {
        let n = 5;
        let t = 3;
        let dropped = 2usize;
        let epoch = 0u64;
        let round = 4u64;
        let tag = 9u32;
        let len = 32usize;
        let mut rng = DetRng::from_seed(42);

        let mut clients: Vec<RobustClientSession> =
            (0..n).map(|i| RobustClientSession::new(i, n, epoch, t, &mut rng)).collect();

        // setup: exchange public keys
        let keys: Vec<PublishedKeys> = clients.iter().map(|c| c.inner.published_keys()).collect();
        for c in clients.iter_mut() {
            c.inner.derive_secrets(&keys);
        }
        // setup: distribute seed shares
        let all_shares: Vec<SeedShares> = clients.iter().map(|c| c.share_seed(&mut rng)).collect();
        for s in &all_shares {
            for (j, bundle) in s.bundles.iter().enumerate() {
                clients[j].receive_share(s.owner, bundle.clone());
            }
        }

        // round: every client except `dropped` sends its masked tensor
        let tensors: Vec<Vec<f32>> = (0..n).map(|i| vec![(i + 1) as f32; len]).collect();
        let masked: Vec<Vec<u64>> = (0..n)
            .filter(|&i| i != dropped)
            .map(|i| clients[i].inner.mask_tensor(&tensors[i], round, tag))
            .collect();

        // aggregate the survivors: garbage (dropped's pairwise masks dangle)
        let fp = FixedPoint::default();
        let mut acc = vec![0u64; len];
        for m in &masked {
            for (a, v) in acc.iter_mut().zip(m) {
                *a = a.wrapping_add(*v);
            }
        }
        let garbage = fp.decode_vec(&acc);
        let want_sum: f32 = (0..n).filter(|&i| i != dropped).map(|i| (i + 1) as f32).sum();
        assert!((garbage[0] - want_sum).abs() > 1.0, "sum should be masked before recovery");

        // recovery: t survivors surrender their share of dropped's seed
        let surrendered: Vec<Vec<Share>> = (0..n)
            .filter(|&i| i != dropped)
            .take(t)
            .map(|i| clients[i].surrender_share(dropped).unwrap().clone())
            .collect();
        let missing =
            recover_dropped_mask(dropped, n, epoch, &surrendered, &keys, round, tag, len)
                .unwrap();

        // subtract the dropped client's would-be mask: sum now decodes
        for (a, m) in acc.iter_mut().zip(&missing) {
            *a = a.wrapping_add(*m); // peers added ±PRG *against* dropped;
                                     // dropped's own total mask is the exact
                                     // negation of those danglers
        }
        let fixed = fp.decode_vec(&acc);
        for v in &fixed {
            assert!((v - want_sum).abs() < 1e-3, "recovered {v} want {want_sum}");
        }
    }

    #[test]
    fn recovery_needs_threshold_shares() {
        let n = 4;
        let t = 3;
        let mut rng = DetRng::from_seed(7);
        let client = RobustClientSession::new(0, n, 0, t, &mut rng);
        let shares = client.share_seed(&mut rng);
        // t-1 shares reconstruct the wrong seed (whp)
        let partial = &shares.bundles[..t - 1];
        let rec = shamir::reconstruct_bytes(partial, 32);
        assert_ne!(rec.as_slice(), client.seed.as_slice());
        // t shares reconstruct exactly
        let full = &shares.bundles[..t];
        let rec = shamir::reconstruct_bytes(full, 32);
        assert_eq!(rec.as_slice(), client.seed.as_slice());
    }

    #[test]
    fn commitments_bind_seeds() {
        assert_ne!(seed_commitment(&[1u8; 32]), seed_commitment(&[2u8; 32]));
        assert_eq!(seed_commitment(&[3u8; 32]), seed_commitment(&[3u8; 32]));
    }

    #[test]
    fn share_bundles_roundtrip_and_seal() {
        let shares = vec![Share { x: 1, y: 42 }, Share { x: 2, y: u64::MAX >> 3 }];
        assert_eq!(decode_shares(&encode_shares(&shares)).unwrap(), shares);
        // trailing garbage rejected
        let mut bad = encode_shares(&shares);
        bad.push(0);
        assert!(decode_shares(&bad).is_err());

        let key = [7u8; 32];
        let sealed = seal_bundle(&key, 1, 3, &shares);
        assert_eq!(open_bundle(&key, 1, 3, &sealed).unwrap(), shares);
        // wrong direction / wrong recipient / tampered → rejected
        assert!(open_bundle(&key, 3, 1, &sealed).is_none());
        assert!(open_bundle(&key, 1, 2, &sealed).is_none());
        let mut t = sealed.clone();
        t[0] ^= 1;
        assert!(open_bundle(&key, 1, 3, &t).is_none());
    }

    #[test]
    fn rebuilt_session_reproduces_masks() {
        // the aggregator-side rebuild path must yield exactly the mask
        // the dropped client's own session would have produced
        let n = 4;
        let mut rng = DetRng::from_seed(11);
        let mut clients: Vec<RobustClientSession> =
            (0..n).map(|i| RobustClientSession::new(i, n, 3, 2, &mut rng)).collect();
        let keys: Vec<PublishedKeys> = clients.iter().map(|c| c.inner.published_keys()).collect();
        for c in clients.iter_mut() {
            c.inner.derive_secrets(&keys);
        }
        let rebuilt = rebuild_session(clients[2].seed, 2, n, 3, &keys);
        assert_eq!(rebuilt.total_mask(9, 1, 16), clients[2].inner.total_mask(9, 1, 16));
    }

    #[test]
    fn below_threshold_error_displays() {
        let e = DropoutError::BelowThreshold { survivors: 2, threshold: 3 };
        assert!(e.to_string().contains("below dropout threshold"));
        let a: anyhow::Error = e.clone().into();
        assert_eq!(a.downcast_ref::<DropoutError>(), Some(&e));
    }
}
