//! Dropout recovery for secure aggregation (Bonawitz et al. 2017).
//!
//! The base protocol of the paper assumes all parties stay online for a
//! round: if one drops after peers have already added pairwise masks
//! against it, the aggregate no longer cancels. The classic fix, which
//! the paper cites as its security foundation, is to have each client
//! Shamir-share its per-peer DH secret keys among all clients at setup;
//! if client d drops mid-round, any t surviving clients hand the
//! aggregator their shares, the aggregator reconstructs d's key,
//! re-derives the pairwise secrets and subtracts d's missing mask
//! contributions.
//!
//! This module implements that extension end-to-end on top of
//! [`ClientSession`](super::session::ClientSession) + [`shamir`].

use crate::crypto::rng::DetRng;
use crate::crypto::shamir::{self, Share};
use crate::crypto::{hkdf, prg};

use super::session::{ClientSession, PublishedKeys};

/// Shares of one client's session seed, one bundle per recipient peer.
pub struct SeedShares {
    pub owner: usize,
    /// `bundles[j]` is the share vector entrusted to client j.
    pub bundles: Vec<Vec<Share>>,
}

/// A client session extended with dropout-recovery material.
pub struct RobustClientSession {
    pub inner: ClientSession,
    /// The seed from which this client's per-peer secret keys derive.
    seed: [u8; 32],
    /// Shares received from every peer (`held[i]` = shares of client i's seed).
    held: Vec<Option<Vec<Share>>>,
    threshold: usize,
}

impl RobustClientSession {
    /// Create a session whose per-peer keys derive deterministically
    /// from a single 32-byte seed (so sharing the seed shares the keys).
    pub fn new(id: usize, n: usize, epoch: u64, threshold: usize, rng: &mut DetRng) -> Self {
        let mut seed = [0u8; 32];
        rng.fill(&mut seed);
        let mut seeded = DetRng::new(seed);
        let inner = ClientSession::new(id, n, epoch, &mut seeded);
        RobustClientSession { inner, seed, held: vec![None; n], threshold }
    }

    /// Shamir-share our seed for distribution (t-of-n).
    pub fn share_seed(&self, rng: &mut DetRng) -> SeedShares {
        let n = self.inner.n_clients;
        let mut fill = {
            let r = rng.clone();
            r.as_fill_fn()
        };
        // advance caller rng state equivalently
        let mut skip = vec![0u8; 64];
        rng.fill(&mut skip);
        let bundles = shamir::split_bytes(&self.seed, self.threshold, n, &mut fill);
        SeedShares { owner: self.inner.id, bundles }
    }

    /// Store the share bundle entrusted to us by peer `owner`.
    pub fn receive_share(&mut self, owner: usize, bundle: Vec<Share>) {
        self.held[owner] = Some(bundle);
    }

    /// Surrender our share of a dropped peer's seed.
    pub fn surrender_share(&self, dropped: usize) -> Option<&Vec<Share>> {
        self.held[dropped].as_ref()
    }
}

/// Aggregator-side recovery: reconstruct the dropped client's seed from
/// ≥ t shares, rebuild its session, and compute the total mask it would
/// have added for (round, tag, len) so it can be subtracted.
pub fn recover_dropped_mask(
    dropped: usize,
    n: usize,
    epoch: u64,
    shares: &[Vec<Share>],
    all_keys: &[PublishedKeys],
    round: u64,
    tensor_tag: u32,
    len: usize,
) -> Vec<u64> {
    let seed_bytes = shamir::reconstruct_bytes(shares, 32);
    let seed: [u8; 32] = seed_bytes.try_into().expect("32-byte seed");
    let mut seeded = DetRng::new(seed);
    let mut session = ClientSession::new(dropped, n, epoch, &mut seeded);
    session.derive_secrets(all_keys);
    let secrets: Vec<(usize, [u8; 32])> = (0..n)
        .filter(|&j| j != dropped)
        .map(|j| (j, *session.shared_secret(j)))
        .collect();
    prg::total_mask(&secrets, dropped, round ^ (epoch << 32), tensor_tag, len)
}

/// Convenience wrapper used in docs/tests: derive a deterministic
/// "commitment" to a seed (what a verifying aggregator would pin).
pub fn seed_commitment(seed: &[u8; 32]) -> [u8; 32] {
    hkdf::derive_key32(b"vfl-sa/seed-commit/v1", seed, b"commit")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::secagg::fixedpoint::FixedPoint;

    /// Full dropout scenario: n clients mask tensors, one drops after
    /// masking was committed by peers; t survivors reconstruct and the
    /// aggregator subtracts the missing masks.
    #[test]
    fn dropout_recovery_end_to_end() {
        let n = 5;
        let t = 3;
        let dropped = 2usize;
        let epoch = 0u64;
        let round = 4u64;
        let tag = 9u32;
        let len = 32usize;
        let mut rng = DetRng::from_seed(42);

        let mut clients: Vec<RobustClientSession> =
            (0..n).map(|i| RobustClientSession::new(i, n, epoch, t, &mut rng)).collect();

        // setup: exchange public keys
        let keys: Vec<PublishedKeys> = clients.iter().map(|c| c.inner.published_keys()).collect();
        for c in clients.iter_mut() {
            c.inner.derive_secrets(&keys);
        }
        // setup: distribute seed shares
        let all_shares: Vec<SeedShares> = clients.iter().map(|c| c.share_seed(&mut rng)).collect();
        for s in &all_shares {
            for (j, bundle) in s.bundles.iter().enumerate() {
                clients[j].receive_share(s.owner, bundle.clone());
            }
        }

        // round: every client except `dropped` sends its masked tensor
        let tensors: Vec<Vec<f32>> = (0..n).map(|i| vec![(i + 1) as f32; len]).collect();
        let masked: Vec<Vec<u64>> = (0..n)
            .filter(|&i| i != dropped)
            .map(|i| clients[i].inner.mask_tensor(&tensors[i], round, tag))
            .collect();

        // aggregate the survivors: garbage (dropped's pairwise masks dangle)
        let fp = FixedPoint::default();
        let mut acc = vec![0u64; len];
        for m in &masked {
            for (a, v) in acc.iter_mut().zip(m) {
                *a = a.wrapping_add(*v);
            }
        }
        let garbage = fp.decode_vec(&acc);
        let want_sum: f32 = (0..n).filter(|&i| i != dropped).map(|i| (i + 1) as f32).sum();
        assert!((garbage[0] - want_sum).abs() > 1.0, "sum should be masked before recovery");

        // recovery: t survivors surrender their share of dropped's seed
        let surrendered: Vec<Vec<Share>> = (0..n)
            .filter(|&i| i != dropped)
            .take(t)
            .map(|i| clients[i].surrender_share(dropped).unwrap().clone())
            .collect();
        let missing =
            recover_dropped_mask(dropped, n, epoch, &surrendered, &keys, round, tag, len);

        // subtract the dropped client's would-be mask: sum now decodes
        for (a, m) in acc.iter_mut().zip(&missing) {
            *a = a.wrapping_add(*m); // peers added ±PRG *against* dropped;
                                     // dropped's own total mask is the exact
                                     // negation of those danglers
        }
        let fixed = fp.decode_vec(&acc);
        for v in &fixed {
            assert!((v - want_sum).abs() < 1e-3, "recovered {v} want {want_sum}");
        }
    }

    #[test]
    fn recovery_needs_threshold_shares() {
        let n = 4;
        let t = 3;
        let mut rng = DetRng::from_seed(7);
        let client = RobustClientSession::new(0, n, 0, t, &mut rng);
        let shares = client.share_seed(&mut rng);
        // t-1 shares reconstruct the wrong seed (whp)
        let partial = &shares.bundles[..t - 1];
        let rec = shamir::reconstruct_bytes(partial, 32);
        assert_ne!(rec.as_slice(), client.seed.as_slice());
        // t shares reconstruct exactly
        let full = &shares.bundles[..t];
        let rec = shamir::reconstruct_bytes(full, 32);
        assert_eq!(rec.as_slice(), client.seed.as_slice());
    }

    #[test]
    fn commitments_bind_seeds() {
        assert_ne!(seed_commitment(&[1u8; 32]), seed_commitment(&[2u8; 32]));
        assert_eq!(seed_commitment(&[3u8; 32]), seed_commitment(&[3u8; 32]));
    }
}
