//! Fixed-point codec between `f32` tensors and the ℤ₂⁶⁴ mask domain.
//!
//! Bonawitz-style pairwise masks only cancel *exactly* in modular
//! integer arithmetic, so float activations/gradients are encoded as
//! two's-complement fixed-point words (default scale 2²⁴) before
//! masking, and the aggregated sums are decoded back to floats.
//! Quantization error is ≤ 2⁻²⁵ per element per party — far below the
//! gradient noise floor, which is why the paper observes no accuracy
//! impact (§6, claim 1).

/// Default fractional bits. 2²⁴ leaves 39 integer bits: sums of up to
/// ~10⁹ parties × unit-scale values before wrap.
pub const DEFAULT_FRAC_BITS: u32 = 24;

/// Fixed-point codec with a configurable scale.
#[derive(Clone, Copy, Debug)]
pub struct FixedPoint {
    pub frac_bits: u32,
}

impl Default for FixedPoint {
    fn default() -> Self {
        FixedPoint { frac_bits: DEFAULT_FRAC_BITS }
    }
}

impl FixedPoint {
    pub fn new(frac_bits: u32) -> Self {
        assert!(frac_bits < 63);
        FixedPoint { frac_bits }
    }

    #[inline]
    pub fn scale(&self) -> f64 {
        (1u64 << self.frac_bits) as f64
    }

    /// Encode one float to a ℤ₂⁶⁴ word (two's complement).
    #[inline]
    pub fn encode(&self, v: f32) -> u64 {
        let scaled = (v as f64 * self.scale()).round();
        // clamp to i64 range to avoid UB on overflow
        let clamped = scaled.clamp(i64::MIN as f64, i64::MAX as f64) as i64;
        clamped as u64
    }

    /// Decode one ℤ₂⁶⁴ word back to a float.
    #[inline]
    pub fn decode(&self, w: u64) -> f32 {
        ((w as i64) as f64 / self.scale()) as f32
    }

    pub fn encode_vec(&self, vs: &[f32]) -> Vec<u64> {
        vs.iter().map(|&v| self.encode(v)).collect()
    }

    pub fn decode_vec(&self, ws: &[u64]) -> Vec<f32> {
        ws.iter().map(|&w| self.decode(w)).collect()
    }

    /// Worst-case absolute quantization error of a sum of `n_parties`
    /// independently encoded values.
    pub fn max_error(&self, n_parties: usize) -> f64 {
        0.5 / self.scale() * n_parties as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::DetRng;

    #[test]
    fn roundtrip_exact_for_representable() {
        let fp = FixedPoint::default();
        for v in [0.0f32, 1.0, -1.0, 0.5, -0.25, 1234.0625, -99.5] {
            assert_eq!(fp.decode(fp.encode(v)), v, "v={v}");
        }
    }

    #[test]
    fn roundtrip_error_bounded() {
        let fp = FixedPoint::default();
        let mut rng = DetRng::from_seed(1);
        for _ in 0..1000 {
            let v = (rng.next_f64() as f32 - 0.5) * 2000.0;
            let r = fp.decode(fp.encode(v));
            assert!((r - v).abs() <= 1.0 / fp.scale() as f32 + v.abs() * 1e-6, "v={v} r={r}");
        }
    }

    #[test]
    fn additive_homomorphism_mod_2_64() {
        // encode(a) + encode(b) decodes to ≈ a+b, including negatives
        let fp = FixedPoint::default();
        let mut rng = DetRng::from_seed(2);
        for _ in 0..500 {
            let a = (rng.next_f64() as f32 - 0.5) * 100.0;
            let b = (rng.next_f64() as f32 - 0.5) * 100.0;
            let sum = fp.decode(fp.encode(a).wrapping_add(fp.encode(b)));
            assert!((sum - (a + b)).abs() < 2.0 / fp.scale() as f32 + 1e-4, "a={a} b={b} sum={sum}");
        }
    }

    #[test]
    fn sum_with_masks_survives() {
        // (x0+m) + (x1-m) == x0+x1 exactly in the encoded domain
        let fp = FixedPoint::default();
        let m = 0xdead_beef_cafe_f00du64;
        let x0 = fp.encode(3.25);
        let x1 = fp.encode(-1.75);
        let total = x0.wrapping_add(m).wrapping_add(x1.wrapping_add(m.wrapping_neg()));
        assert_eq!(fp.decode(total), 1.5);
    }

    #[test]
    fn vec_roundtrip() {
        let fp = FixedPoint::new(16);
        let vs = vec![1.0f32, -2.5, 0.0, 1e4];
        assert_eq!(fp.decode_vec(&fp.encode_vec(&vs)), vs);
    }

    #[test]
    fn max_error_is_conservative() {
        let fp = FixedPoint::default();
        assert!(fp.max_error(100) < 1e-4);
    }
}
