//! Deterministic fault injection: the proof harness for the
//! dropout-tolerant protocol.
//!
//! A [`FaultPlan`] is a seeded, fully deterministic schedule of party
//! faults — crashes (permanent silence from a chosen point), message
//! drops, and bounded reordering — plus *blanking* (a party whose
//! feature rows are zeroed at build time). [`FaultyTransport`] wraps
//! any [`Transport`] and applies the plan by wrapping each client
//! party in a [`FaultyParty`] before delegating, so the identical plan
//! runs under the simulator, the threaded transport, and TCP.
//!
//! Blanking exists because it is the *algebraic twin* of a crash: a
//! blanked party submits masked all-zero tensors, so its masks
//! telescope normally while its data contributes nothing — exactly the
//! aggregate dropout recovery reconstructs when the same party crashes
//! before its first send. `tests/dropout_recovery.rs` asserts that
//! twin relationship bit-for-bit.
//!
//! The aggregator (node 0) is infrastructure and is never wrapped:
//! this harness models *party* failure, not coordinator failure.

use anyhow::Result;

use crate::coordinator::messages::Msg;
use crate::coordinator::party::{OutMsg, Outbox, Party, RoundSpec};
use crate::coordinator::Metrics;
use crate::crypto::rng::DetRng;
use crate::model::ModelParams;

use super::transport::{Transport, TransportOutcome};
use super::Addr;

/// One injected fault for one client.
///
/// Faults count *messages per round*, attributed by each outgoing
/// message's own `round` tag (setup-phase messages, which carry an
/// epoch instead, attribute to the latest announced round — setup legs
/// are scheduler barriers, so that is unambiguous). Anchoring to
/// protocol progress rather than to round announcements is what keeps
/// a fault schedule deterministic under the windowed scheduler
/// (`--rounds-in-flight` > 1 announces rounds early, and announcement
/// arrival order races against in-flight traffic on the threaded
/// transport); at width 1 the two anchors coincide, so the semantics
/// of every pre-window schedule are unchanged. Under the chunked
/// streaming pipeline (`--chunk-words`) crash points and drops land on
/// individual `MaskedChunk`s — a crash mid-tensor or a single lost
/// chunk are injectable states, and `tests/chunk_equivalence.rs`
/// proves the recovery path handles both.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Permanent silence: the party crashes when its `round`-attributed
    /// send count stands at `after_sends` and it is about to emit one
    /// more (0 = before its first send of that round; from the crash
    /// point on, nothing escapes — any round's traffic included).
    Crash { round: u32, after_sends: usize },
    /// Silently lose the `nth` outgoing message of `round` (the party
    /// stays alive — models a lossy link; the aggregator will declare
    /// the sender dropped and the run continues without it).
    DropMsg { round: u32, nth: usize },
    /// Bounded reordering: in `round`, each event's first `hold`
    /// emissions are appended after the rest of that event's outbox.
    /// Per-sender FIFO across events is preserved.
    Delay { round: u32, hold: usize },
    /// Malicious surrenderer: flip one byte in every `SurrenderShares`
    /// bundle this client hands the aggregator. The seed-commitment
    /// check must catch the corrupted reconstruction with a typed
    /// error instead of silently mis-correcting the aggregate.
    CorruptShares,
}

/// A deterministic fault schedule plus build-time blanking.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// (client index, fault) pairs; a client may carry several.
    pub faults: Vec<(usize, Fault)>,
    /// Clients (passive only) whose feature rows are zeroed at build
    /// time — the crash twin used by the recovery equivalence tests.
    pub blanks: Vec<usize>,
}

impl FaultPlan {
    /// A plan crashing `client` at the start of `round`.
    pub fn crash_at(client: usize, round: u32) -> Self {
        FaultPlan {
            faults: vec![(client, Fault::Crash { round, after_sends: 0 })],
            ..Default::default()
        }
    }

    /// Add another fault to the plan.
    pub fn with(mut self, client: usize, fault: Fault) -> Self {
        self.faults.push((client, fault));
        self
    }

    /// A plan blanking `clients` instead of crashing anyone.
    pub fn blank(clients: &[usize]) -> Self {
        FaultPlan { blanks: clients.to_vec(), ..Default::default() }
    }

    /// The blank twin of this plan's crash set: every crashed client
    /// blanked instead, no faults injected.
    pub fn blank_twin(&self) -> Self {
        let mut blanks: Vec<usize> = self
            .faults
            .iter()
            .filter(|(_, f)| matches!(f, Fault::Crash { .. }))
            .map(|(c, _)| *c)
            .collect();
        blanks.sort_unstable();
        blanks.dedup();
        FaultPlan::blank(&blanks)
    }

    /// Seeded random crash schedule: `1..=max_drops` distinct passive
    /// clients (the active party and the aggregator are exempt), each
    /// crashing at the start of a round drawn from `[0, rounds)`.
    /// Deterministic in `seed`, so the same plan replays identically on
    /// every transport.
    pub fn seeded(seed: u64, n_clients: usize, max_drops: usize, rounds: u32) -> Self {
        let mut rng = DetRng::from_seed(seed ^ 0xfa17_1e57);
        let n_drops = rng.next_range(1, max_drops as u64 + 1) as usize;
        let mut candidates: Vec<usize> = (1..n_clients).collect();
        rng.shuffle(&mut candidates);
        let faults = candidates
            .into_iter()
            .take(n_drops)
            .map(|c| {
                let round = rng.next_range(0, rounds as u64) as u32;
                (c, Fault::Crash { round, after_sends: 0 })
            })
            .collect();
        FaultPlan { faults, blanks: Vec::new() }
    }

    /// Like [`seeded`](Self::seeded), but crashes may also strike
    /// mid-round, after 1–2 sends (exercising the gradient-phase and
    /// next-round detection paths).
    pub fn seeded_mid_round(seed: u64, n_clients: usize, max_drops: usize, rounds: u32) -> Self {
        let mut plan = Self::seeded(seed, n_clients, max_drops, rounds);
        let mut rng = DetRng::from_seed(seed ^ 0x0dd_ba11);
        for (_, f) in plan.faults.iter_mut() {
            if let Fault::Crash { after_sends, .. } = f {
                *after_sends = rng.next_range(0, 3) as usize;
            }
        }
        plan
    }

    /// The faults targeting one client.
    fn faults_for(&self, client: usize) -> Vec<Fault> {
        self.faults.iter().filter(|(c, _)| *c == client).map(|(_, f)| *f).collect()
    }

    /// Wrap a full party set (node 0 = aggregator, node i+1 = client i)
    /// in fault wrappers. Clients without faults pass through unwrapped.
    pub fn wrap<'e>(&self, parties: Vec<Box<dyn Party + 'e>>) -> Vec<Box<dyn Party + 'e>> {
        parties
            .into_iter()
            .enumerate()
            .map(|(node, p)| {
                if node == 0 {
                    return p;
                }
                let faults = self.faults_for(node - 1);
                if faults.is_empty() {
                    p
                } else {
                    Box::new(FaultyParty::new(p, faults)) as Box<dyn Party + 'e>
                }
            })
            .collect()
    }

    /// Wrap a single client party (the `vfl-sa join` path, where each
    /// process owns exactly one party).
    pub fn wrap_one<'e>(&self, client: usize, party: Box<dyn Party + 'e>) -> Box<dyn Party + 'e> {
        let faults = self.faults_for(client);
        if faults.is_empty() {
            party
        } else {
            Box::new(FaultyParty::new(party, faults))
        }
    }
}

/// A party wrapper that applies a client's scheduled faults.
pub struct FaultyParty<'e> {
    inner: Box<dyn Party + 'e>,
    faults: Vec<Fault>,
    /// Latest announced round: the attribution fallback for messages
    /// that carry no round tag (key exchange and share distribution —
    /// setup legs are scheduler barriers, so this is unambiguous even
    /// under a pipelined window).
    round: u32,
    /// Escaped-message counts per attributed round.
    sent: std::collections::BTreeMap<u32, usize>,
    crashed: bool,
}

impl<'e> FaultyParty<'e> {
    pub fn new(inner: Box<dyn Party + 'e>, faults: Vec<Fault>) -> Self {
        FaultyParty {
            inner,
            faults,
            round: 0,
            sent: std::collections::BTreeMap::new(),
            crashed: false,
        }
    }

    /// Whether the crash point at (round, after `sent` messages) fires.
    fn crash_fires(&self, round: u32, sent: usize) -> bool {
        self.faults.iter().any(|f| {
            matches!(f, Fault::Crash { round: r, after_sends }
                if *r == round && *after_sends == sent)
        })
    }

    fn drop_fires(&self, round: u32, nth: usize) -> bool {
        self.faults.iter().any(|f| {
            matches!(f, Fault::DropMsg { round: r, nth: n } if *r == round && *n == nth)
        })
    }

    fn delay_hold(&self, round: u32) -> usize {
        self.faults
            .iter()
            .find_map(|f| match f {
                Fault::Delay { round: r, hold } if *r == round => Some(*hold),
                _ => None,
            })
            .unwrap_or(0)
    }

    fn corrupts_shares(&self) -> bool {
        self.faults.iter().any(|f| matches!(f, Fault::CorruptShares))
    }

    /// Route an inner outbox through the fault schedule. Each message
    /// counts against its own round ([`Msg::round`], fallback: the
    /// latest announced round); the event-level delay fault uses the
    /// first message's attribution. A crash with `after_sends: 0`
    /// fires just before the round's first send, so the inner party
    /// may process (and CPU-meter) the events leading up to that
    /// attempt — the price of anchoring fault points to protocol
    /// progress instead of racy round announcements.
    fn relay(&mut self, tmp: Outbox, out: &mut Outbox) {
        let mut msgs = tmp.msgs;
        let event_round = msgs
            .first()
            .and_then(|(_, m)| m.round())
            .unwrap_or(self.round);
        let hold = self.delay_hold(event_round);
        if hold > 0 && hold < msgs.len() {
            msgs.rotate_left(hold);
        }
        for (to, mut m) in msgs {
            if self.crashed {
                return; // silence from the crash point on, notes included
            }
            if self.corrupts_shares() {
                // SurrenderShares always travels structured (never the
                // pre-encoded chunk path), so matching the Msg variant
                // still covers every bundle a client can hand over
                if let OutMsg::Msg(Msg::SurrenderShares { bundles, .. }) = &mut m {
                    for (_, bytes) in bundles.iter_mut() {
                        if let Some(b) = bytes.last_mut() {
                            *b ^= 0x01;
                        }
                    }
                }
            }
            let round = m.round().unwrap_or(self.round);
            let nth = self.sent.get(&round).copied().unwrap_or(0);
            // an `after_sends: 0` crash point fires *before* the
            // round's first message escapes
            if self.crash_fires(round, nth) {
                self.crashed = true;
                return;
            }
            self.sent.insert(round, nth + 1);
            if !self.drop_fires(round, nth) {
                out.send_out(to, m);
            }
            // a mid-round crash point fires right *after* its round's
            // `after_sends`-th message — eagerly, so a crash at a
            // round's final send silences the party from that moment
            // (the pre-window harness semantics) instead of waiting
            // for a further send that may never come
            if self.crash_fires(round, nth + 1) {
                self.crashed = true;
                return;
            }
        }
        if !self.crashed {
            out.notes.extend(tmp.notes);
        }
    }
}

impl<'e> Party for FaultyParty<'e> {
    fn addr(&self) -> Addr {
        self.inner.addr()
    }

    fn on_round_start(&mut self, spec: &RoundSpec, out: &mut Outbox) -> Result<()> {
        if self.crashed {
            return Ok(());
        }
        self.round = spec.round;
        let mut tmp = Outbox::default();
        self.inner.on_round_start(spec, &mut tmp)?;
        self.relay(tmp, out);
        Ok(())
    }

    fn on_message(&mut self, from: Addr, msg: Msg, out: &mut Outbox) -> Result<()> {
        if self.crashed {
            return Ok(());
        }
        let mut tmp = Outbox::default();
        self.inner.on_message(from, msg, &mut tmp)?;
        self.relay(tmp, out);
        Ok(())
    }

    fn on_stall(&mut self, out: &mut Outbox) -> Result<()> {
        if self.crashed {
            return Ok(());
        }
        let mut tmp = Outbox::default();
        self.inner.on_stall(&mut tmp)?;
        self.relay(tmp, out);
        Ok(())
    }

    fn on_round_complete(&mut self, round: u32) {
        // driver bookkeeping, not party traffic: delivered even to a
        // crashed wrapper (the real aggregator is never wrapped anyway)
        self.inner.on_round_complete(round);
    }

    fn concurrent_safe(&self) -> bool {
        self.inner.concurrent_safe()
    }

    fn take_metrics(&mut self) -> Metrics {
        self.inner.take_metrics()
    }

    fn final_params(&mut self) -> Option<ModelParams> {
        self.inner.final_params()
    }
}

/// Wrap any transport with a fault plan: the plan wraps the party set,
/// the inner transport runs it unchanged.
pub struct FaultyTransport<T> {
    inner: T,
    plan: FaultPlan,
}

impl<T: Transport> FaultyTransport<T> {
    pub fn new(inner: T, plan: FaultPlan) -> Self {
        FaultyTransport { inner, plan }
    }
}

impl<T: Transport> Transport for FaultyTransport<T> {
    fn execute<'e>(
        &mut self,
        parties: Vec<Box<dyn Party + 'e>>,
        schedule: &[RoundSpec],
        window: usize,
    ) -> Result<TransportOutcome> {
        let wrapped = self.plan.wrap(parties);
        self.inner.execute(wrapped, schedule, window)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::party::Note;

    /// A scripted party that sends one message per round and one note.
    struct Chatter {
        sends: usize,
    }

    impl Party for Chatter {
        fn addr(&self) -> Addr {
            Addr::Client(1)
        }
        fn on_round_start(&mut self, spec: &RoundSpec, out: &mut Outbox) -> Result<()> {
            for k in 0..self.sends {
                out.send(
                    Addr::Aggregator,
                    Msg::RequestKeys { epoch: (spec.round as u64) * 10 + k as u64 },
                );
            }
            out.note(Note::RoundDone { round: spec.round });
            Ok(())
        }
        fn on_message(&mut self, _f: Addr, _m: Msg, _o: &mut Outbox) -> Result<()> {
            Ok(())
        }
        fn take_metrics(&mut self) -> Metrics {
            Metrics::new()
        }
    }

    fn spec(round: u32) -> RoundSpec {
        RoundSpec {
            round,
            kind: crate::coordinator::party::RoundKind::Train,
            rotate: false,
            phase: crate::net::Phase::Training,
            ids: Vec::new(),
        }
    }

    #[test]
    fn crash_at_round_start_silences_forever() {
        let inner = Box::new(Chatter { sends: 2 });
        let mut p = FaultyParty::new(inner, vec![Fault::Crash { round: 1, after_sends: 0 }]);
        let mut out = Outbox::default();
        p.on_round_start(&spec(0), &mut out).unwrap();
        assert_eq!(out.msgs.len(), 2);
        assert_eq!(out.notes.len(), 1);
        let mut out = Outbox::default();
        p.on_round_start(&spec(1), &mut out).unwrap();
        assert!(out.msgs.is_empty() && out.notes.is_empty(), "crashed at round 1 start");
        let mut out = Outbox::default();
        p.on_round_start(&spec(2), &mut out).unwrap();
        assert!(out.msgs.is_empty(), "crash is permanent");
    }

    #[test]
    fn mid_round_crash_cuts_after_n_sends() {
        let inner = Box::new(Chatter { sends: 3 });
        let mut p = FaultyParty::new(inner, vec![Fault::Crash { round: 0, after_sends: 2 }]);
        let mut out = Outbox::default();
        p.on_round_start(&spec(0), &mut out).unwrap();
        assert_eq!(out.msgs.len(), 2, "exactly two messages escape");
        assert!(out.notes.is_empty(), "notes after the crash point are swallowed");
    }

    #[test]
    fn drop_msg_loses_exactly_one() {
        let inner = Box::new(Chatter { sends: 3 });
        let mut p = FaultyParty::new(inner, vec![Fault::DropMsg { round: 0, nth: 1 }]);
        let mut out = Outbox::default();
        p.on_round_start(&spec(0), &mut out).unwrap();
        assert_eq!(out.msgs.len(), 2);
        // the dropped one was the middle emission
        let epochs: Vec<u64> = out
            .msgs
            .iter()
            .map(|(_, m)| match m {
                OutMsg::Msg(Msg::RequestKeys { epoch }) => *epoch,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(epochs, vec![0, 2]);
        assert_eq!(out.notes.len(), 1, "party stays alive");
    }

    #[test]
    fn delay_reorders_within_event() {
        let inner = Box::new(Chatter { sends: 3 });
        let mut p = FaultyParty::new(inner, vec![Fault::Delay { round: 0, hold: 1 }]);
        let mut out = Outbox::default();
        p.on_round_start(&spec(0), &mut out).unwrap();
        let epochs: Vec<u64> = out
            .msgs
            .iter()
            .map(|(_, m)| match m {
                OutMsg::Msg(Msg::RequestKeys { epoch }) => *epoch,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(epochs, vec![1, 2, 0], "first emission lands last");
    }

    #[test]
    fn seeded_plans_deterministic_and_passive_only() {
        for seed in 0..20u64 {
            let a = FaultPlan::seeded(seed, 5, 2, 6);
            let b = FaultPlan::seeded(seed, 5, 2, 6);
            assert_eq!(a, b, "same seed, same plan");
            assert!(!a.faults.is_empty() && a.faults.len() <= 2);
            for (c, f) in &a.faults {
                assert!((1..5).contains(c), "active party and aggregator exempt");
                assert!(matches!(f, Fault::Crash { round, .. } if *round < 6));
            }
            let clients: Vec<usize> = a.faults.iter().map(|(c, _)| *c).collect();
            let mut dedup = clients.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), clients.len(), "distinct clients");
        }
    }

    #[test]
    fn blank_twin_mirrors_crash_set() {
        let plan = FaultPlan::crash_at(3, 0).with(1, Fault::Crash { round: 2, after_sends: 1 });
        let twin = plan.blank_twin();
        assert_eq!(twin.blanks, vec![1, 3]);
        assert!(twin.faults.is_empty());
    }
}
