//! Simulated star-topology network with byte accounting.
//!
//! All protocol traffic flows through the aggregator (the paper's
//! topology). The transport delivers serialized messages between
//! in-process endpoints and meters every byte per (party, phase,
//! direction) — these counters *are* Table 2.

use std::collections::VecDeque;

/// Protocol phases, matching the paper's reporting granularity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    Setup,
    Training,
    Testing,
}

/// Node address: the aggregator or a client id.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Addr {
    Aggregator,
    Client(usize),
}

/// Per-node traffic counters, indexed by phase.
#[derive(Clone, Debug, Default)]
pub struct Traffic {
    pub sent: u64,
    pub received: u64,
}

/// The simulated network.
pub struct Network {
    n_clients: usize,
    pub phase: Phase,
    queue: VecDeque<(Addr, Addr, Vec<u8>)>,
    /// [phase][node] — node 0 = aggregator, node i+1 = client i.
    traffic: Vec<Vec<Traffic>>,
    /// Total messages delivered (for diagnostics).
    pub messages: u64,
}

fn phase_idx(p: Phase) -> usize {
    match p {
        Phase::Setup => 0,
        Phase::Training => 1,
        Phase::Testing => 2,
    }
}

impl Network {
    pub fn new(n_clients: usize) -> Self {
        Network {
            n_clients,
            phase: Phase::Setup,
            queue: VecDeque::new(),
            traffic: vec![vec![Traffic::default(); n_clients + 1]; 3],
            messages: 0,
        }
    }

    fn node_idx(&self, a: Addr) -> usize {
        match a {
            Addr::Aggregator => 0,
            Addr::Client(i) => {
                assert!(i < self.n_clients, "client {i} out of range");
                i + 1
            }
        }
    }

    /// Send serialized bytes; counts them against the current phase.
    pub fn send(&mut self, from: Addr, to: Addr, payload: Vec<u8>) {
        let p = phase_idx(self.phase);
        let fi = self.node_idx(from);
        let ti = self.node_idx(to);
        self.traffic[p][fi].sent += payload.len() as u64;
        self.traffic[p][ti].received += payload.len() as u64;
        self.messages += 1;
        self.queue.push_back((from, to, payload));
    }

    /// Deliver all queued messages addressed to `to` (FIFO).
    pub fn deliver(&mut self, to: Addr) -> Vec<(Addr, Vec<u8>)> {
        let mut out = Vec::new();
        let mut rest = VecDeque::new();
        while let Some((f, t, m)) = self.queue.pop_front() {
            if t == to {
                out.push((f, m));
            } else {
                rest.push_back((f, t, m));
            }
        }
        self.queue = rest;
        out
    }

    /// Pop exactly one message for `to`, if any.
    pub fn recv_one(&mut self, to: Addr) -> Option<(Addr, Vec<u8>)> {
        let pos = self.queue.iter().position(|(_, t, _)| *t == to)?;
        let (f, _, m) = self.queue.remove(pos).unwrap();
        Some((f, m))
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Bytes sent by a node in a phase.
    pub fn sent_bytes(&self, node: Addr, phase: Phase) -> u64 {
        self.traffic[phase_idx(phase)][self.node_idx(node)].sent
    }

    pub fn received_bytes(&self, node: Addr, phase: Phase) -> u64 {
        self.traffic[phase_idx(phase)][self.node_idx(node)].received
    }

    /// Total transmission (sent + received) — the paper's Table 2 metric.
    pub fn transmission_bytes(&self, node: Addr, phase: Phase) -> u64 {
        self.sent_bytes(node, phase) + self.received_bytes(node, phase)
    }

    /// Number of client nodes (excluding the aggregator).
    pub fn n_clients(&self) -> usize {
        self.n_clients
    }

    pub fn reset_counters(&mut self) {
        for p in self.traffic.iter_mut() {
            for t in p.iter_mut() {
                *t = Traffic::default();
            }
        }
        self.messages = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_and_deliver() {
        let mut net = Network::new(2);
        net.send(Addr::Client(0), Addr::Aggregator, vec![1, 2, 3]);
        net.send(Addr::Client(1), Addr::Aggregator, vec![4]);
        net.send(Addr::Aggregator, Addr::Client(0), vec![5, 6]);
        let msgs = net.deliver(Addr::Aggregator);
        assert_eq!(msgs.len(), 2);
        assert_eq!(msgs[0], (Addr::Client(0), vec![1, 2, 3]));
        assert_eq!(net.pending(), 1);
        let m = net.recv_one(Addr::Client(0)).unwrap();
        assert_eq!(m.1, vec![5, 6]);
        assert_eq!(net.pending(), 0);
    }

    #[test]
    fn byte_accounting_per_phase() {
        let mut net = Network::new(1);
        net.phase = Phase::Setup;
        net.send(Addr::Client(0), Addr::Aggregator, vec![0; 10]);
        net.phase = Phase::Training;
        net.send(Addr::Client(0), Addr::Aggregator, vec![0; 100]);
        net.send(Addr::Aggregator, Addr::Client(0), vec![0; 7]);
        assert_eq!(net.sent_bytes(Addr::Client(0), Phase::Setup), 10);
        assert_eq!(net.sent_bytes(Addr::Client(0), Phase::Training), 100);
        assert_eq!(net.received_bytes(Addr::Client(0), Phase::Training), 7);
        assert_eq!(net.transmission_bytes(Addr::Client(0), Phase::Training), 107);
        assert_eq!(net.sent_bytes(Addr::Aggregator, Phase::Training), 7);
        assert_eq!(net.transmission_bytes(Addr::Client(0), Phase::Testing), 0);
    }

    #[test]
    fn fifo_order_per_destination() {
        let mut net = Network::new(1);
        for i in 0..5u8 {
            net.send(Addr::Aggregator, Addr::Client(0), vec![i]);
        }
        let msgs = net.deliver(Addr::Client(0));
        let seq: Vec<u8> = msgs.iter().map(|(_, m)| m[0]).collect();
        assert_eq!(seq, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn reset() {
        let mut net = Network::new(1);
        net.send(Addr::Aggregator, Addr::Client(0), vec![0; 9]);
        net.reset_counters();
        assert_eq!(net.transmission_bytes(Addr::Client(0), Phase::Setup), 0);
    }

    #[test]
    #[should_panic]
    fn out_of_range_client() {
        let mut net = Network::new(1);
        net.send(Addr::Client(5), Addr::Aggregator, vec![]);
    }
}
