//! The pluggable transport layer: how [`Party`] state machines
//! exchange bytes.
//!
//! * [`Network`] — the byte-metered star-topology message queue. Every
//!   transport meters its traffic through one of these, because the
//!   per-(phase, party, direction) counters *are* Table 2.
//! * [`Transport`] — runs a set of parties over a round schedule.
//! * [`SimTransport`] — single-threaded deterministic simulation: one
//!   global FIFO, parties invoked inline (the paper's measurement
//!   setup, like Flower's VCE).
//!
//! The multi-threaded implementation lives in
//! [`threaded`](super::threaded); the cross-process TCP plumbing in
//! [`tcp`](super::tcp).

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::coordinator::messages::Msg;
use crate::coordinator::party::{Note, Outbox, Party, RoundSpec};
use crate::coordinator::window::RoundWindow;
use crate::coordinator::Metrics;
use crate::model::ModelParams;

/// Shared dropout-detection policy for the timeout-based transports
/// (threads, TCP) — one place so the two cannot drift apart.
///
/// A quiescence window with zero aggregator events triggers an
/// [`Party::on_stall`] probe; [`MAX_IDLE_PROBES`] consecutive no-op
/// probes abort the run as genuinely stalled (a false abort is worse
/// than a slow one, but strictly better than the pre-dropout behavior
/// of blocking forever).
///
/// The window itself is *adaptive* ([`StallClock`]): it starts at a
/// floor (500 ms by default) and grows with an EWMA of the observed
/// inter-event gaps, up to a configurable cap — so a party whose
/// single compute step keeps the aggregator quiet for seconds is no
/// longer falsely declared dropped, while a genuinely dead peer on a
/// fast workload is still detected at the floor.
pub const DEFAULT_STALL_TIMEOUT: std::time::Duration = std::time::Duration::from_millis(500);

/// Default cap on the adaptive quiescence window.
pub const DEFAULT_STALL_CAP: std::time::Duration = std::time::Duration::from_secs(10);

/// Consecutive no-op quiescence probes tolerated before declaring a
/// run stalled.
pub const MAX_IDLE_PROBES: u32 = 20;

/// Adaptive quiescence window: an exponentially weighted moving
/// average of inter-event gaps, mapped to a timeout of
/// `clamp(floor, GAP_MULTIPLIER × EWMA, cap)`.
///
/// Timing only steers *when* a silent peer is probed, never *what* the
/// protocol computes, so the adaptive window cannot affect
/// bit-identity across transports — only detection latency.
#[derive(Clone, Debug)]
pub struct StallClock {
    floor: std::time::Duration,
    cap: std::time::Duration,
    ewma_ns: Option<f64>,
}

/// Hard minimum for the quiescence-window floor. A zero floor (e.g. a
/// config built in code with `stall_timeout_ms = Some(0)`, bypassing
/// the flag-parse validation) would make every `recv_timeout` return
/// instantly — a busy-spin dropout storm that declares every peer
/// stalled. [`StallClock::new`] clamps to this as defense in depth;
/// the CLI additionally rejects zero knobs at parse time
/// (`coordinator::validate_timing`).
pub const MIN_STALL_FLOOR: std::time::Duration = std::time::Duration::from_millis(1);

/// EWMA smoothing factor (weight of the newest gap).
const STALL_EWMA_ALPHA: f64 = 0.25;

/// How many average gaps of silence count as quiescence. Generous on
/// purpose: a missed dropout costs one extra window, a false dropout
/// ejects a live party for the rest of the run.
const STALL_GAP_MULTIPLIER: f64 = 8.0;

impl StallClock {
    pub fn new(floor: std::time::Duration, cap: std::time::Duration) -> Self {
        // clamp zero-width windows (see MIN_STALL_FLOOR): the floor is
        // lifted first, then the cap is lifted to the floor, so a
        // (0, 0) configuration degrades to a 1 ms window instead of a
        // busy-spin that instantly declares every peer stalled
        let floor = floor.max(MIN_STALL_FLOOR);
        StallClock { floor, cap: cap.max(floor), ewma_ns: None }
    }

    /// Build from the `RunConfig` knobs (`stall_timeout_ms` floor,
    /// `stall_cap_ms` cap), defaulting to [`DEFAULT_STALL_TIMEOUT`] /
    /// [`DEFAULT_STALL_CAP`].
    pub fn from_config(floor_ms: Option<u64>, cap_ms: Option<u64>) -> Self {
        StallClock::new(
            floor_ms.map(std::time::Duration::from_millis).unwrap_or(DEFAULT_STALL_TIMEOUT),
            cap_ms.map(std::time::Duration::from_millis).unwrap_or(DEFAULT_STALL_CAP),
        )
    }

    /// Fold one observed gap between consecutive events into the EWMA.
    pub fn observe_gap(&mut self, gap: std::time::Duration) {
        let g = gap.as_nanos() as f64;
        self.ewma_ns = Some(match self.ewma_ns {
            None => g,
            Some(e) => (1.0 - STALL_EWMA_ALPHA) * e + STALL_EWMA_ALPHA * g,
        });
    }

    /// The current quiescence window.
    pub fn timeout(&self) -> std::time::Duration {
        let adaptive = self
            .ewma_ns
            .map(|e| std::time::Duration::from_nanos((e * STALL_GAP_MULTIPLIER) as u64))
            .unwrap_or(self.floor);
        adaptive.clamp(self.floor, self.cap)
    }
}

/// Protocol phases, matching the paper's reporting granularity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    Setup,
    Training,
    Testing,
}

/// Node address: the aggregator or a client id.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Addr {
    Aggregator,
    Client(usize),
}

/// Per-node traffic counters, indexed by phase.
#[derive(Clone, Debug, Default)]
pub struct Traffic {
    pub sent: u64,
    pub received: u64,
}

/// The byte-metered star-topology network.
///
/// Byte-accounting rule for the chunked streaming pipeline: the
/// counters meter *encoded message bytes*, so a masked tensor of `d`
/// words costs `11 + 8d` bytes monolithic and `22·k + 8d` bytes as a
/// `k`-chunk uplink stream — identical payload, 22 bytes of header per
/// chunk (`coordinator::streaming::CHUNK_MSG_HEADER_BYTES`). The
/// aggregator→active `GradientSum` downlink streams too when chunking
/// is on: `9 + 8d` bytes monolithic vs `19·k + 8d` chunked
/// (`GRAD_CHUNK_MSG_HEADER_BYTES`). Table-2 comparisons across the two
/// paths must add `coordinator::streaming::chunk_overhead_bytes` per
/// uplink tensor and `grad_chunk_overhead_bytes` per downlink sum;
/// everything else (relays, broadcasts, setup) is byte-identical.
/// `tests/chunk_equivalence.rs` asserts the exact relation.
pub struct Network {
    n_clients: usize,
    pub phase: Phase,
    queue: VecDeque<(Addr, Addr, Vec<u8>)>,
    /// [phase][node] — node 0 = aggregator, node i+1 = client i.
    traffic: Vec<Vec<Traffic>>,
    /// Total messages delivered (for diagnostics).
    pub messages: u64,
}

fn phase_idx(p: Phase) -> usize {
    match p {
        Phase::Setup => 0,
        Phase::Training => 1,
        Phase::Testing => 2,
    }
}

impl Network {
    pub fn new(n_clients: usize) -> Self {
        Network {
            n_clients,
            phase: Phase::Setup,
            queue: VecDeque::new(),
            traffic: vec![vec![Traffic::default(); n_clients + 1]; 3],
            messages: 0,
        }
    }

    fn node_idx(&self, a: Addr) -> usize {
        match a {
            Addr::Aggregator => 0,
            Addr::Client(i) => {
                assert!(i < self.n_clients, "client {i} out of range");
                i + 1
            }
        }
    }

    /// Count one message's bytes against the current phase without
    /// queueing it (transports that move bytes themselves — threads,
    /// sockets — still meter here so Table 2 is transport-independent).
    pub fn meter(&mut self, from: Addr, to: Addr, len: usize) {
        let p = phase_idx(self.phase);
        let fi = self.node_idx(from);
        let ti = self.node_idx(to);
        self.traffic[p][fi].sent += len as u64;
        self.traffic[p][ti].received += len as u64;
        self.messages += 1;
    }

    /// Send serialized bytes; counts them against the current phase.
    pub fn send(&mut self, from: Addr, to: Addr, payload: Vec<u8>) {
        self.meter(from, to, payload.len());
        self.queue.push_back((from, to, payload));
    }

    /// Pop the oldest queued message regardless of destination (the
    /// simulator's pump — one global FIFO).
    pub fn pop(&mut self) -> Option<(Addr, Addr, Vec<u8>)> {
        self.queue.pop_front()
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Bytes sent by a node in a phase.
    pub fn sent_bytes(&self, node: Addr, phase: Phase) -> u64 {
        self.traffic[phase_idx(phase)][self.node_idx(node)].sent
    }

    pub fn received_bytes(&self, node: Addr, phase: Phase) -> u64 {
        self.traffic[phase_idx(phase)][self.node_idx(node)].received
    }

    /// Total transmission (sent + received) — the paper's Table 2 metric.
    pub fn transmission_bytes(&self, node: Addr, phase: Phase) -> u64 {
        self.sent_bytes(node, phase) + self.received_bytes(node, phase)
    }

    /// Number of client nodes (excluding the aggregator).
    pub fn n_clients(&self) -> usize {
        self.n_clients
    }

    pub fn reset_counters(&mut self) {
        for p in self.traffic.iter_mut() {
            for t in p.iter_mut() {
                *t = Traffic::default();
            }
        }
        self.messages = 0;
    }
}

// ---------------------------------------------------------------------------
// Transport
// ---------------------------------------------------------------------------

/// What a completed transport run hands back to the driver.
pub struct TransportOutcome {
    /// Every driver note emitted during the run, in observation order.
    pub notes: Vec<Note>,
    /// The byte counters (Table 2).
    pub net: Network,
    /// Merged per-party CPU meters (Table 1).
    pub metrics: Metrics,
    /// Final model parameters, harvested from the active party.
    pub final_params: ModelParams,
}

/// Runs a full party set over a round schedule with up to `window`
/// rounds in flight (`--rounds-in-flight`; 1 = strictly serial).
///
/// `parties` is indexed by node: entry 0 is the aggregator, entry
/// `i + 1` is client `i`. Implementations must (a) preserve per-sender
/// FIFO message ordering, (b) drive the schedule through a
/// [`RoundWindow`] — rounds start in schedule order, at most `window`
/// in flight, honoring its setup/rotation/phase barriers and the
/// dropout drain — and (c) meter every protocol message through a
/// [`Network`] — under those three rules every transport produces
/// bit-identical results at every window width.
pub trait Transport {
    fn execute<'e>(
        &mut self,
        parties: Vec<Box<dyn Party + 'e>>,
        schedule: &[RoundSpec],
        window: usize,
    ) -> Result<TransportOutcome>;
}

pub(crate) fn addr_of_node(idx: usize) -> Addr {
    if idx == 0 {
        Addr::Aggregator
    } else {
        Addr::Client(idx - 1)
    }
}

pub(crate) fn node_of_addr(a: Addr) -> usize {
    match a {
        Addr::Aggregator => 0,
        Addr::Client(i) => i + 1,
    }
}

/// Harvest metrics + final params from a finished party set, folding
/// in the driver-side meters (the scheduler's pipeline counters).
pub(crate) fn harvest<'e>(
    mut parties: Vec<Box<dyn Party + 'e>>,
    notes: Vec<Note>,
    net: Network,
    driver: Metrics,
) -> Result<TransportOutcome> {
    let mut metrics = driver;
    let mut final_params = None;
    for p in parties.iter_mut() {
        metrics.merge(p.take_metrics());
        if let Some(fp) = p.final_params() {
            final_params = Some(fp);
        }
    }
    let final_params = match final_params {
        Some(fp) => fp,
        None => bail!("no party reported final parameters"),
    };
    Ok(TransportOutcome { notes, net, metrics, final_params })
}

/// Single-threaded deterministic simulation: parties run inline over
/// one global FIFO wrapped around the byte-metered [`Network`]. This
/// is the measurement configuration — exact byte counters, exact
/// per-party CPU attribution, zero scheduling noise.
pub struct SimTransport {
    n_clients: usize,
}

impl SimTransport {
    pub fn new(n_clients: usize) -> Self {
        SimTransport { n_clients }
    }
}

impl Transport for SimTransport {
    fn execute<'e>(
        &mut self,
        mut parties: Vec<Box<dyn Party + 'e>>,
        schedule: &[RoundSpec],
        window: usize,
    ) -> Result<TransportOutcome> {
        assert_eq!(parties.len(), self.n_clients + 1, "aggregator + clients");
        let mut net = Network::new(self.n_clients);
        let mut notes: Vec<Note> = Vec::new();
        let mut win = RoundWindow::new(schedule, window);

        /// Route an outbox; every note feeds the scheduler
        /// ([`RoundWindow::observe`]) before it is recorded. Returns
        /// the rounds whose completion was observed so the caller can
        /// notify the aggregator ([`Party::on_round_complete`]).
        fn flush(
            net: &mut Network,
            from: Addr,
            ob: Outbox,
            notes: &mut Vec<Note>,
            win: &mut RoundWindow,
        ) -> Vec<u32> {
            let mut completed = Vec::new();
            for (to, msg) in ob.msgs {
                net.send(from, to, msg.into_bytes());
            }
            for n in ob.notes {
                if let Some(n) = win.observe(n) {
                    if let Note::RoundDone { round } = &n {
                        completed.push(*round);
                    }
                    notes.push(n);
                }
            }
            completed
        }

        loop {
            let mut progress = false;
            // open every round the window allows, in schedule order —
            // aggregator first (it opens setup rounds), then clients
            while let Some(spec) = win.next_start() {
                progress = true;
                net.phase = spec.phase;
                let mut completed = Vec::new();
                for (idx, p) in parties.iter_mut().enumerate() {
                    let mut ob = Outbox::default();
                    p.on_round_start(spec, &mut ob)?;
                    completed.extend(flush(&mut net, addr_of_node(idx), ob, &mut notes, &mut win));
                }
                for r in completed {
                    parties[0].on_round_complete(r);
                }
            }
            // pump the global FIFO dry
            while let Some((from, to, bytes)) = net.pop() {
                progress = true;
                let msg = Msg::decode(&bytes)?;
                let idx = node_of_addr(to);
                let mut ob = Outbox::default();
                parties[idx].on_message(from, msg, &mut ob)?;
                let done = flush(&mut net, to, ob, &mut notes, &mut win);
                for r in done {
                    parties[0].on_round_complete(r);
                }
            }
            if win.done() {
                break;
            }
            if progress {
                // completions during the pump may have opened the
                // window: try to start the next rounds before probing
                continue;
            }
            // quiescent with rounds incomplete: a deterministic stall.
            // Probe the parties (aggregator first) so dropout recovery
            // can declare the silent peers and resume; if nobody
            // produces traffic, the protocol is truly stuck.
            let mut progressed = false;
            let mut completed = Vec::new();
            for (idx, p) in parties.iter_mut().enumerate() {
                let mut ob = Outbox::default();
                p.on_stall(&mut ob)?;
                progressed |= !ob.msgs.is_empty() || !ob.notes.is_empty();
                completed.extend(flush(&mut net, addr_of_node(idx), ob, &mut notes, &mut win));
            }
            for r in completed {
                parties[0].on_round_complete(r);
            }
            if !progressed {
                // A stall with an empty window would mean `win.done()`
                // lied; report it as its own typed error instead of
                // panicking inside the error path.
                match win.oldest_in_flight() {
                    Some(r) => bail!("protocol stalled: round {r} never completed"),
                    None => bail!("protocol stalled with no round in flight (window bug)"),
                }
            }
        }

        let mut driver = Metrics::new();
        driver.record_pipeline(win.stats());
        harvest(parties, notes, net, driver)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stall_clock_floor_ewma_and_cap() {
        use std::time::Duration;
        let floor = Duration::from_millis(500);
        let cap = Duration::from_secs(10);
        let mut c = StallClock::new(floor, cap);
        // no observations: the floor
        assert_eq!(c.timeout(), floor);
        // fast gaps keep the window at the floor
        for _ in 0..10 {
            c.observe_gap(Duration::from_millis(1));
        }
        assert_eq!(c.timeout(), floor);
        // slow gaps (a heavy compute step) stretch the window...
        for _ in 0..50 {
            c.observe_gap(Duration::from_millis(400));
        }
        let t = c.timeout();
        assert!(t > floor, "window must adapt upward, got {t:?}");
        assert!(t <= cap);
        // ...but never past the cap
        for _ in 0..50 {
            c.observe_gap(Duration::from_secs(30));
        }
        assert_eq!(c.timeout(), cap);
        // and it recovers once gaps shrink again
        for _ in 0..100 {
            c.observe_gap(Duration::from_micros(10));
        }
        assert_eq!(c.timeout(), floor);
        // a cap below the floor is lifted to the floor
        let c = StallClock::new(floor, Duration::from_millis(1));
        assert_eq!(c.timeout(), floor);
    }

    #[test]
    fn zero_width_windows_clamped() {
        use std::time::Duration;
        // a (0, 0) configuration must not busy-spin: both knobs clamp
        // to the hard minimum
        let c = StallClock::new(Duration::ZERO, Duration::ZERO);
        assert_eq!(c.timeout(), MIN_STALL_FLOOR);
        // a zero cap alone is lifted to the (clamped) floor
        let mut c = StallClock::new(Duration::from_millis(500), Duration::ZERO);
        assert_eq!(c.timeout(), Duration::from_millis(500));
        for _ in 0..50 {
            c.observe_gap(Duration::from_secs(30));
        }
        assert_eq!(c.timeout(), Duration::from_millis(500), "cap clamped to the floor");
        // the from_config path clamps the same way
        let c = StallClock::from_config(Some(0), Some(0));
        assert_eq!(c.timeout(), MIN_STALL_FLOOR);
    }

    #[test]
    fn send_queues_and_pops_in_order() {
        let mut net = Network::new(2);
        net.send(Addr::Client(0), Addr::Aggregator, vec![1, 2, 3]);
        net.send(Addr::Client(1), Addr::Aggregator, vec![4]);
        net.send(Addr::Aggregator, Addr::Client(0), vec![5, 6]);
        assert_eq!(net.pending(), 3);
        assert_eq!(net.pop().unwrap(), (Addr::Client(0), Addr::Aggregator, vec![1, 2, 3]));
        assert_eq!(net.pop().unwrap(), (Addr::Client(1), Addr::Aggregator, vec![4]));
        assert_eq!(net.pop().unwrap(), (Addr::Aggregator, Addr::Client(0), vec![5, 6]));
        assert_eq!(net.pending(), 0);
    }

    #[test]
    fn byte_accounting_per_phase() {
        let mut net = Network::new(1);
        net.phase = Phase::Setup;
        net.send(Addr::Client(0), Addr::Aggregator, vec![0; 10]);
        net.phase = Phase::Training;
        net.send(Addr::Client(0), Addr::Aggregator, vec![0; 100]);
        net.send(Addr::Aggregator, Addr::Client(0), vec![0; 7]);
        assert_eq!(net.sent_bytes(Addr::Client(0), Phase::Setup), 10);
        assert_eq!(net.sent_bytes(Addr::Client(0), Phase::Training), 100);
        assert_eq!(net.received_bytes(Addr::Client(0), Phase::Training), 7);
        assert_eq!(net.transmission_bytes(Addr::Client(0), Phase::Training), 107);
        assert_eq!(net.sent_bytes(Addr::Aggregator, Phase::Training), 7);
        assert_eq!(net.transmission_bytes(Addr::Client(0), Phase::Testing), 0);
    }

    #[test]
    fn meter_without_queueing() {
        let mut net = Network::new(1);
        net.phase = Phase::Training;
        net.meter(Addr::Client(0), Addr::Aggregator, 55);
        assert_eq!(net.pending(), 0, "meter must not enqueue");
        assert_eq!(net.sent_bytes(Addr::Client(0), Phase::Training), 55);
        assert_eq!(net.received_bytes(Addr::Aggregator, Phase::Training), 55);
        assert_eq!(net.messages, 1);
    }

    #[test]
    fn fifo_order_per_destination() {
        let mut net = Network::new(1);
        for i in 0..5u8 {
            net.send(Addr::Aggregator, Addr::Client(0), vec![i]);
        }
        let mut seq = Vec::new();
        while let Some((_, _, m)) = net.pop() {
            seq.push(m[0]);
        }
        assert_eq!(seq, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn pop_is_global_fifo() {
        let mut net = Network::new(2);
        net.send(Addr::Client(0), Addr::Aggregator, vec![1]);
        net.send(Addr::Aggregator, Addr::Client(1), vec![2]);
        assert_eq!(net.pop().unwrap().2, vec![1]);
        assert_eq!(net.pop().unwrap().2, vec![2]);
        assert!(net.pop().is_none());
    }

    #[test]
    fn reset() {
        let mut net = Network::new(1);
        net.send(Addr::Aggregator, Addr::Client(0), vec![0; 9]);
        net.reset_counters();
        assert_eq!(net.transmission_bytes(Addr::Client(0), Phase::Setup), 0);
    }

    #[test]
    #[should_panic]
    fn out_of_range_client() {
        let mut net = Network::new(1);
        net.send(Addr::Client(5), Addr::Aggregator, vec![]);
    }
}
