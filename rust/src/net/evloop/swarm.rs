//! The C10K load generator (`vfl-sa swarm --clients N`): N lightweight
//! simulated passive clients against one event-loop aggregator over
//! real localhost sockets, in one process.
//!
//! This is a *transport* benchmark, not a protocol run: the server
//! multiplexes every socket on one event-loop thread (the same
//! [`Poller`]/[`Conn`] machinery `evloop::serve_on` uses), paces
//! `rounds` barrier rounds — broadcast a tiny "go" frame, collect one
//! deterministic payload frame from every client — and folds every
//! payload word into a running ℤ₂⁶⁴ checksum. The checksum is
//! recomputed independently from the generator formula, so a single
//! lost, duplicated, or corrupted frame anywhere in 10k+ concurrent
//! streams fails the run loudly.
//!
//! Clients are nonblocking too, multiplexed across a few worker
//! threads (`client_threads`) with their own pollers — no
//! thread-per-client anywhere in the process. Memory flatness is
//! metered with the same [`Metrics`] counters the real transport
//! uses: peak live connections and peak per-connection buffered
//! bytes, plus the process-level `VmHWM` RSS high-water mark on
//! Linux.

use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::metrics::AGGREGATOR;
use crate::coordinator::Metrics;

use super::super::frame::Frame;
use super::conn::{Conn, ReadOutcome};
use super::poller::{Interest, Poller, PollerKind};
use super::shard::{self, LoopEvt, ShardLoop, ShardSet};

const LISTENER_TOKEN: usize = usize::MAX;
/// How long a quiescent swarm phase may sit before the run is
/// declared stalled (generous: a cold 10k join takes a few seconds).
const PHASE_TIMEOUT: Duration = Duration::from_secs(60);
const STOP_DRAIN: Duration = Duration::from_secs(10);

/// Swarm shape. `Default` is the acceptance-criteria configuration:
/// 10 240 clients, 3 rounds, 32-word payloads, 4 client threads.
#[derive(Clone, Debug)]
pub struct SwarmCfg {
    /// Concurrent simulated clients (≤ `u16::MAX`, the Hello index
    /// space).
    pub clients: usize,
    /// Barrier rounds: each broadcasts a go frame and collects one
    /// payload per client.
    pub rounds: u32,
    /// ℤ₂⁶⁴ words per payload frame.
    pub payload_words: usize,
    /// Worker threads multiplexing the client sockets.
    pub client_threads: usize,
    /// Aggregator-side event-loop threads (`--evloop-threads`): 1 is
    /// the classic single loop, K > 1 token-shards the connections
    /// across K [`ShardLoop`]s behind one acceptor/driver thread.
    pub server_threads: usize,
    /// Poller backend (tests pin the `poll(2)` fallback).
    pub poller: PollerKind,
}

impl Default for SwarmCfg {
    fn default() -> Self {
        SwarmCfg {
            clients: 10_240,
            rounds: 3,
            payload_words: 32,
            client_threads: 4,
            server_threads: 1,
            poller: PollerKind::Auto,
        }
    }
}

/// What a swarm run measured.
#[derive(Clone, Debug)]
pub struct SwarmReport {
    pub clients: usize,
    pub rounds: u32,
    pub payload_words: usize,
    /// Aggregator-side event-loop threads the run used.
    pub server_threads: usize,
    pub wall_ms: f64,
    /// Peak simultaneously-live connections at the aggregator
    /// (== `clients` when every join landed).
    pub peak_live_connections: u64,
    /// Peak bytes any single aggregator-side connection buffered —
    /// the flat-per-client memory claim.
    pub peak_conn_buffered_bytes: u64,
    /// Total payload bytes the aggregator received.
    pub bytes_received: u64,
    /// ℤ₂⁶⁴ fold of every payload word received.
    pub checksum: u64,
    /// The same fold recomputed from the generator formula.
    pub expected_checksum: u64,
    /// Which poller backend the server used.
    pub poller: &'static str,
    /// Process RSS high-water mark (`VmHWM`, Linux; 0 elsewhere).
    pub rss_peak_kb: u64,
}

impl SwarmReport {
    /// Every payload frame arrived intact, exactly once.
    pub fn verified(&self) -> bool {
        self.checksum == self.expected_checksum
    }

    /// Hand-rolled JSON (the repo's no-serde convention; same style as
    /// `BENCH_streaming.json`).
    pub fn json(&self) -> String {
        format!(
            "{{\"clients\": {}, \"rounds\": {}, \"payload_words\": {}, \"server_threads\": {}, \
             \"wall_ms\": {:.3}, \
             \"peak_live_connections\": {}, \"peak_conn_buffered_bytes\": {}, \
             \"bytes_received\": {}, \"checksum_ok\": {}, \"poller\": \"{}\", \
             \"rss_peak_kb\": {}}}",
            self.clients,
            self.rounds,
            self.payload_words,
            self.server_threads,
            self.wall_ms,
            self.peak_live_connections,
            self.peak_conn_buffered_bytes,
            self.bytes_received,
            self.verified(),
            self.poller,
            self.rss_peak_kb,
        )
    }
}

/// The deterministic payload word for (client, round, word index):
/// cheap to generate on the client, cheap to re-derive on the driver,
/// and position-sensitive enough that reordered or cross-wired bytes
/// change the fold.
fn word(c: u64, r: u64, j: u64) -> u64 {
    c.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (r << 32) ^ j
}

/// `[u16 client ‖ u32 round ‖ payload_words × u64]`, all LE.
fn payload_frame(c: usize, round: u32, payload_words: usize) -> Frame {
    let mut bytes = Vec::with_capacity(6 + payload_words * 8);
    bytes.extend_from_slice(&(c as u16).to_le_bytes());
    bytes.extend_from_slice(&round.to_le_bytes());
    for j in 0..payload_words {
        bytes.extend_from_slice(&word(c as u64, round as u64, j as u64).to_le_bytes());
    }
    Frame::Msg { bytes }
}

fn expected_checksum(cfg: &SwarmCfg) -> u64 {
    let mut sum = 0u64;
    for c in 0..cfg.clients as u64 {
        for r in 0..cfg.rounds as u64 {
            for j in 0..cfg.payload_words as u64 {
                sum = sum.wrapping_add(word(c, r, j));
            }
        }
    }
    sum
}

#[cfg(target_os = "linux")]
mod os {
    /// Best-effort: raise the soft `RLIMIT_NOFILE` to the hard limit
    /// (10k clients cost ~20k fds in one process) and return the
    /// resulting soft limit. Same extern-libc-symbol trick as the
    /// poller — std links libc.
    pub fn raise_nofile() -> u64 {
        #[repr(C)]
        struct Rlimit {
            cur: u64,
            max: u64,
        }
        const RLIMIT_NOFILE: i32 = 7;
        extern "C" {
            fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
            fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
        }
        // SAFETY: both calls take pointers to the stack-owned
        // `#[repr(C)]` Rlimit structs above, which outlive the calls;
        // return codes are checked before any value is trusted.
        unsafe {
            let mut r = Rlimit { cur: 0, max: 0 };
            if getrlimit(RLIMIT_NOFILE, &mut r) != 0 {
                return 0;
            }
            if r.cur < r.max {
                let want = Rlimit { cur: r.max, max: r.max };
                if setrlimit(RLIMIT_NOFILE, &want) == 0 {
                    return r.max;
                }
            }
            r.cur
        }
    }

    /// `VmHWM` from `/proc/self/status`, in kB (0 if unreadable).
    pub fn rss_peak_kb() -> u64 {
        let Ok(s) = std::fs::read_to_string("/proc/self/status") else { return 0 };
        for line in s.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                return rest.trim().trim_end_matches("kB").trim().parse().unwrap_or(0);
            }
        }
        0
    }
}

#[cfg(not(target_os = "linux"))]
mod os {
    /// Non-Linux: no rlimit shim; report "no limit known" so the
    /// preflight check passes and the OS enforces whatever it has.
    pub fn raise_nofile() -> u64 {
        u64::MAX
    }

    pub fn rss_peak_kb() -> u64 {
        0
    }
}

/// Run one swarm: returns the report; the caller decides whether an
/// unverified checksum is fatal (the CLI and tests both treat it so).
pub fn run(cfg: &SwarmCfg) -> Result<SwarmReport> {
    if cfg.clients == 0
        || cfg.rounds == 0
        || cfg.payload_words == 0
        || cfg.client_threads == 0
        || cfg.server_threads == 0
    {
        bail!(
            "swarm needs at least one client, round, payload word, client thread, \
             and server thread"
        );
    }
    if cfg.clients > u16::MAX as usize {
        bail!("--clients {} exceeds the Hello frame's u16 index space", cfg.clients);
    }
    let needed = cfg.clients as u64 * 2 + 64; // both socket ends live here
    let limit = os::raise_nofile();
    if limit < needed {
        bail!(
            "fd limit {limit} is too low for {} in-process clients (need ~{needed}; \
             raise `ulimit -n` or lower --clients)",
            cfg.clients
        );
    }
    let listener = TcpListener::bind("127.0.0.1:0").context("bind localhost")?;
    let addr = listener.local_addr().context("local addr")?.to_string();
    let t0 = Instant::now();

    let (io, bytes_received, checksum, poller_name) = thread::scope(|s| -> Result<_> {
        let mut handles = Vec::with_capacity(cfg.client_threads);
        // split the client index space into contiguous worker shares
        let per = cfg.clients.div_ceil(cfg.client_threads);
        for w in 0..cfg.client_threads {
            let lo = w * per;
            let hi = ((w + 1) * per).min(cfg.clients);
            if lo >= hi {
                break;
            }
            let addr = addr.clone();
            let (words, kind) = (cfg.payload_words, cfg.poller);
            handles.push(s.spawn(move || client_worker(&addr, lo..hi, words, kind)));
        }
        let served = if cfg.server_threads > 1 {
            swarm_serve_sharded(listener, cfg)
        } else {
            swarm_serve(listener, cfg)
        };
        let mut worker_err: Option<anyhow::Error> = None;
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    worker_err.get_or_insert(e);
                }
                Err(_) => {
                    worker_err.get_or_insert_with(|| anyhow::anyhow!("client worker panicked"));
                }
            }
        }
        let served = served?; // the server error wins
        if let Some(e) = worker_err {
            return Err(e.context("swarm client worker failed"));
        }
        Ok(served)
    })?;

    let report = SwarmReport {
        clients: cfg.clients,
        rounds: cfg.rounds,
        payload_words: cfg.payload_words,
        server_threads: cfg.server_threads,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        peak_live_connections: io.peak_connections(AGGREGATOR),
        peak_conn_buffered_bytes: io.peak_conn_buffered_bytes(AGGREGATOR),
        bytes_received,
        checksum,
        expected_checksum: expected_checksum(cfg),
        poller: poller_name,
        rss_peak_kb: os::rss_peak_kb(),
    };
    Ok(report)
}

/// Drain a conn's outbound queue and keep its poller interest honest.
/// Swarm semantics: any I/O failure is fatal (a benchmark with a
/// silently dropped client measures nothing).
fn flush(
    poller: &mut Poller,
    conns: &mut [Option<Conn>],
    token: usize,
    io: &mut Metrics,
) -> Result<()> {
    let Some(conn) = conns.get_mut(token).and_then(Option::as_mut) else { return Ok(()) };
    match conn.write_ready() {
        Ok(drained) => {
            io.record_conn_buffered(AGGREGATOR, conn.buffered_bytes() as u64);
            let want = if drained { Interest::READ } else { Interest::BOTH };
            if conn.interest != want {
                conn.interest = want;
                poller.reregister(conn.fd, token, want).context("reregister")?;
            }
            Ok(())
        }
        Err(e) => bail!("swarm conn {token} write failed: {e}"),
    }
}

fn enqueue(
    poller: &mut Poller,
    conns: &mut [Option<Conn>],
    token: usize,
    frame: &Frame,
    io: &mut Metrics,
) -> Result<()> {
    let Some(conn) = conns.get_mut(token).and_then(Option::as_mut) else {
        bail!("swarm conn {token} is gone")
    };
    conn.out.enqueue(frame, token)?;
    flush(poller, conns, token, io)
}

/// The aggregator side: accept every client, pace the rounds, fold
/// the checksum.
fn swarm_serve(listener: TcpListener, cfg: &SwarmCfg) -> Result<(Metrics, u64, u64, &'static str)> {
    listener.set_nonblocking(true).context("nonblocking listener")?;
    let mut poller = cfg.poller.build().context("build poller")?;
    let name = poller.name();
    poller
        .register(listener.as_raw_fd(), LISTENER_TOKEN, Interest::READ)
        .context("register listener")?;
    let mut conns: Vec<Option<Conn>> = Vec::with_capacity(cfg.clients);
    let mut seen: Vec<bool> = vec![false; cfg.clients];
    let mut io = Metrics::new();
    let mut live = 0u64;
    let mut joined = 0usize;
    let mut events = Vec::new();

    // -- join: accept until every client index said Hello
    while joined < cfg.clients {
        poller.wait(&mut events, Some(PHASE_TIMEOUT)).context("poll (join)")?;
        if events.is_empty() {
            bail!("swarm join stalled at {joined}/{} clients", cfg.clients);
        }
        for i in 0..events.len() {
            let ev = events[i];
            if ev.token == LISTENER_TOKEN {
                loop {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            stream.set_nodelay(true).ok();
                            stream.set_nonblocking(true).context("set_nonblocking")?;
                            let fd = stream.as_raw_fd();
                            let token = conns.len();
                            poller.register(fd, token, Interest::READ).context("register")?;
                            conns.push(Some(Conn::new(stream, fd)));
                            live += 1;
                            io.record_connections(AGGREGATOR, live);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                        Err(e) => return Err(e).context("accept"),
                    }
                }
                continue;
            }
            let Some(conn) = conns.get_mut(ev.token).and_then(Option::as_mut) else { continue };
            let mut frames = Vec::new();
            let outcome = conn.read_ready(&mut frames);
            io.record_conn_buffered(AGGREGATOR, conn.buffered_bytes() as u64);
            for f in frames {
                let Frame::Hello { client } = f else { bail!("expected Hello, got {f:?}") };
                let c = client as usize;
                if c >= cfg.clients || seen[c] {
                    bail!("bad or duplicate Hello for client {c}");
                }
                seen[c] = true;
                conn.client = Some(c);
                joined += 1;
            }
            if let ReadOutcome::Closed(why) = outcome {
                bail!("swarm client lost during join: {why}");
            }
        }
    }
    poller.deregister(listener.as_raw_fd()).ok();

    // -- rounds: go-barrier-collect, folding every payload word
    let mut checksum = 0u64;
    let mut bytes_received = 0u64;
    for r in 0..cfg.rounds {
        let go = Frame::Msg { bytes: r.to_le_bytes().to_vec() };
        for token in 0..conns.len() {
            enqueue(&mut poller, &mut conns, token, &go, &mut io)?;
        }
        let mut got = 0usize;
        while got < cfg.clients {
            poller.wait(&mut events, Some(PHASE_TIMEOUT)).context("poll (round)")?;
            if events.is_empty() {
                bail!("swarm round {r} stalled at {got}/{} payloads", cfg.clients);
            }
            for i in 0..events.len() {
                let ev = events[i];
                if ev.writable {
                    flush(&mut poller, &mut conns, ev.token, &mut io)?;
                }
                if !(ev.readable || ev.hangup) {
                    continue;
                }
                let Some(conn) = conns.get_mut(ev.token).and_then(Option::as_mut) else {
                    continue;
                };
                let mut frames = Vec::new();
                let outcome = conn.read_ready(&mut frames);
                io.record_conn_buffered(AGGREGATOR, conn.buffered_bytes() as u64);
                for f in frames {
                    let Frame::Msg { bytes } = f else { bail!("expected payload, got {f:?}") };
                    if bytes.len() != 6 + cfg.payload_words * 8 {
                        bail!("payload size {} unexpected", bytes.len());
                    }
                    let round = u32::from_le_bytes([bytes[2], bytes[3], bytes[4], bytes[5]]);
                    if round != r {
                        bail!("payload for round {round} during round {r}");
                    }
                    for w in bytes[6..].chunks_exact(8) {
                        checksum = checksum.wrapping_add(u64::from_le_bytes(
                            w.try_into().expect("exact 8-byte chunk"),
                        ));
                    }
                    bytes_received += bytes.len() as u64;
                    got += 1;
                }
                if let ReadOutcome::Closed(why) = outcome {
                    bail!("swarm client vanished mid-round: {why}");
                }
            }
        }
    }

    // -- orderly stop: enqueue Stop everywhere, drain, close
    for token in 0..conns.len() {
        enqueue(&mut poller, &mut conns, token, &Frame::Stop, &mut io)?;
    }
    let deadline = Instant::now() + STOP_DRAIN;
    loop {
        let mut pending = false;
        for token in 0..conns.len() {
            match conns[token].as_ref() {
                Some(c) if c.out.is_empty() => {
                    let fd = c.fd;
                    poller.deregister(fd).ok();
                    conns[token] = None;
                    live -= 1;
                }
                Some(_) => pending = true,
                None => {}
            }
        }
        if !pending || Instant::now() >= deadline {
            break;
        }
        poller.wait(&mut events, Some(Duration::from_millis(100))).context("poll (drain)")?;
        for i in 0..events.len() {
            let ev = events[i];
            if ev.writable {
                flush(&mut poller, &mut conns, ev.token, &mut io)?;
            }
        }
    }
    Ok((io, bytes_received, checksum, name))
}

/// The K > 1 aggregator: the same go-barrier-collect protocol as
/// [`swarm_serve`], but the sockets are dealt round-robin across
/// `server_threads` [`ShardLoop`]s and this (driver) thread only talks
/// channels — payload frames funnel up the shared [`LoopEvt`] channel,
/// go/Stop frames ride the per-loop control channels. The checksum
/// fold is commutative (`wrapping_add`), so any arrival interleaving
/// across loops produces the identical sum.
fn swarm_serve_sharded(
    listener: TcpListener,
    cfg: &SwarmCfg,
) -> Result<(Metrics, u64, u64, &'static str)> {
    let threads = cfg.server_threads.min(cfg.clients.max(1));
    let mut pollers = Vec::with_capacity(threads);
    for _ in 0..threads {
        pollers.push(cfg.poller.build().context("build shard poller")?);
    }
    let name = pollers[0].name();

    // this thread accepts everything (metering the connection peak),
    // dealing socket j to loop j % K
    let mut io = Metrics::new();
    let sockets =
        shard::accept_shards(&listener, cfg.clients, threads, &mut io, Some(PHASE_TIMEOUT))?;
    drop(listener);

    let (evt_tx, evt_rx) = mpsc::channel::<LoopEvt>();
    let mut ctls = Vec::with_capacity(threads);
    let mut wakes = Vec::with_capacity(threads);
    let mut loops = Vec::with_capacity(threads);
    for (l, (poller, socks)) in pollers.into_iter().zip(sockets).enumerate() {
        let (ctl_tx, ctl_rx) = mpsc::channel();
        let (wake_w, wake_r) = UnixStream::pair().context("wake socketpair")?;
        wake_w.set_nonblocking(true).context("nonblocking wake writer")?;
        loops.push(ShardLoop::new(l, poller, socks, cfg.clients, wake_r, ctl_rx, evt_tx.clone())?);
        ctls.push(ctl_tx);
        wakes.push(wake_w);
    }
    drop(evt_tx); // loops hold the only senders: Disconnected == all loops gone

    let (loop_io, bytes_received, checksum) = thread::scope(|s| -> Result<_> {
        // declared inside the scope so every exit path drops it (hanging
        // up wake pairs + control channels) before the implicit join
        let mut shards = ShardSet::new(ctls, wakes, cfg.clients);
        let mut handles = Vec::with_capacity(threads);
        for sl in loops {
            let h = thread::Builder::new()
                .name(format!("swarm-shard-{}", sl.id()))
                .spawn_scoped(s, move || sl.run())
                .expect("spawn swarm shard");
            handles.push(h);
        }
        let driven = swarm_drive_sharded(cfg, &mut shards, &evt_rx);
        if driven.is_ok() {
            for c in 0..cfg.clients {
                shards.send_frame(c, Frame::Stop);
            }
            shards.drain_all(STOP_DRAIN);
        }
        shards.wake();
        drop(shards);
        let mut loop_io = Metrics::new();
        for h in handles {
            match h.join() {
                Ok(m) => loop_io.merge(m),
                Err(_) => eprintln!("[swarm] shard loop panicked"),
            }
        }
        let (bytes, sum) = driven?;
        Ok((loop_io, bytes, sum))
    })?;
    io.merge(loop_io);
    Ok((io, bytes_received, checksum, name))
}

/// The sharded driver's protocol: wait out the joins, pace the rounds,
/// fold the checksum. Any lost client, stray frame, or stalled phase is
/// fatal — swarm semantics, identical to the single loop's.
fn swarm_drive_sharded(
    cfg: &SwarmCfg,
    shards: &mut ShardSet,
    evt_rx: &Receiver<LoopEvt>,
) -> Result<(u64, u64)> {
    // -- join: every client index says Hello on some loop
    let mut joined = 0usize;
    while joined < cfg.clients {
        match evt_rx.recv_timeout(PHASE_TIMEOUT) {
            Ok(LoopEvt::Joined { loop_id, client }) => {
                if shards.client_loop[client].is_some() {
                    bail!("client {client} connected twice");
                }
                shards.client_loop[client] = Some(loop_id);
                joined += 1;
            }
            Ok(LoopEvt::Frame { client, .. }) => {
                bail!("swarm client {client} sent a frame before the first go");
            }
            Ok(LoopEvt::Gone { why, .. }) => bail!("swarm client lost during join: {why}"),
            Ok(LoopEvt::Fatal(e)) => return Err(e),
            Err(RecvTimeoutError::Timeout) => {
                bail!("swarm join stalled at {joined}/{} clients", cfg.clients)
            }
            Err(RecvTimeoutError::Disconnected) => bail!("all swarm shard loops exited"),
        }
    }

    // -- rounds: go-barrier-collect, folding every payload word
    let mut checksum = 0u64;
    let mut bytes_received = 0u64;
    for r in 0..cfg.rounds {
        for c in 0..cfg.clients {
            shards.send_frame(c, Frame::Msg { bytes: r.to_le_bytes().to_vec() });
        }
        shards.wake();
        let mut got = 0usize;
        while got < cfg.clients {
            let f = match evt_rx.recv_timeout(PHASE_TIMEOUT) {
                Ok(LoopEvt::Frame { frame, .. }) => frame,
                Ok(LoopEvt::Joined { client, .. }) => bail!("client {client} connected twice"),
                Ok(LoopEvt::Gone { why, .. }) => bail!("swarm client vanished mid-round: {why}"),
                Ok(LoopEvt::Fatal(e)) => return Err(e),
                Err(RecvTimeoutError::Timeout) => {
                    bail!("swarm round {r} stalled at {got}/{} payloads", cfg.clients)
                }
                Err(RecvTimeoutError::Disconnected) => bail!("all swarm shard loops exited"),
            };
            let Frame::Msg { bytes } = f else { bail!("expected payload, got {f:?}") };
            if bytes.len() != 6 + cfg.payload_words * 8 {
                bail!("payload size {} unexpected", bytes.len());
            }
            let round = u32::from_le_bytes([bytes[2], bytes[3], bytes[4], bytes[5]]);
            if round != r {
                bail!("payload for round {round} during round {r}");
            }
            for w in bytes[6..].chunks_exact(8) {
                checksum = checksum
                    .wrapping_add(u64::from_le_bytes(w.try_into().expect("exact 8-byte chunk")));
            }
            bytes_received += bytes.len() as u64;
            got += 1;
        }
    }
    Ok((bytes_received, checksum))
}

/// Localhost connects can transiently fail while thousands of sockets
/// churn; retry with backoff before giving up.
fn connect_with_retry(addr: &str) -> Result<TcpStream> {
    let mut delay = Duration::from_millis(5);
    for _ in 0..40 {
        if let Ok(s) = TcpStream::connect(addr) {
            return Ok(s);
        }
        thread::sleep(delay);
        delay = (delay * 2).min(Duration::from_millis(200));
    }
    TcpStream::connect(addr).with_context(|| format!("connect {addr}"))
}

/// One worker thread's share of the swarm: connect its client range,
/// then multiplex them all on one poller — respond to each go frame
/// with the round's payload, close on Stop.
fn client_worker(
    addr: &str,
    ids: std::ops::Range<usize>,
    payload_words: usize,
    kind: PollerKind,
) -> Result<()> {
    let mut poller = kind.build().context("build client poller")?;
    let mut conns: Vec<Option<Conn>> = Vec::with_capacity(ids.len());
    for c in ids {
        let mut stream = connect_with_retry(addr)?;
        stream.set_nodelay(true).ok();
        // handshake while still blocking: a few bytes, never stalls
        Frame::Hello { client: c as u16 }.write_to(&mut stream)?;
        stream.set_nonblocking(true).context("set_nonblocking")?;
        let fd = stream.as_raw_fd();
        let token = conns.len();
        poller.register(fd, token, Interest::READ).context("register")?;
        let mut conn = Conn::new(stream, fd);
        conn.client = Some(c);
        conns.push(Some(conn));
    }
    let mut remaining = conns.len();
    let mut events = Vec::new();
    while remaining > 0 {
        poller.wait(&mut events, Some(PHASE_TIMEOUT)).context("poll (client)")?;
        if events.is_empty() {
            bail!("swarm clients stalled ({remaining} still open, server silent)");
        }
        for i in 0..events.len() {
            let ev = events[i];
            let token = ev.token;
            if ev.writable {
                flush_client(&mut poller, &mut conns, token)?;
            }
            if !(ev.readable || ev.hangup) {
                continue;
            }
            let Some(conn) = conns.get_mut(token).and_then(Option::as_mut) else { continue };
            let mut frames = Vec::new();
            let outcome = conn.read_ready(&mut frames);
            let c = conn.client.expect("swarm conns always carry a client id");
            let mut saw_stop = false;
            for f in frames {
                match f {
                    Frame::Msg { bytes } => {
                        if bytes.len() != 4 {
                            bail!("unexpected go frame size {}", bytes.len());
                        }
                        let round = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
                        let payload = payload_frame(c, round, payload_words);
                        conn.out.enqueue(&payload, token)?;
                    }
                    Frame::Stop => saw_stop = true,
                    f => bail!("unexpected frame {f:?}"),
                }
            }
            if saw_stop {
                poller.deregister(conn.fd).ok();
                conns[token] = None;
                remaining -= 1;
            } else if let ReadOutcome::Closed(why) = outcome {
                bail!("server dropped swarm client {c}: {why}");
            } else {
                flush_client(&mut poller, &mut conns, token)?;
            }
        }
    }
    Ok(())
}

fn flush_client(poller: &mut Poller, conns: &mut [Option<Conn>], token: usize) -> Result<()> {
    let Some(conn) = conns.get_mut(token).and_then(Option::as_mut) else { return Ok(()) };
    match conn.write_ready() {
        Ok(drained) => {
            let want = if drained { Interest::READ } else { Interest::BOTH };
            if conn.interest != want {
                conn.interest = want;
                poller.reregister(conn.fd, token, want).context("reregister")?;
            }
            Ok(())
        }
        Err(e) => bail!("swarm client write failed: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_roundtrips_through_the_checksum() {
        // the server-side fold of generated payloads equals the
        // independent expected fold
        let cfg = SwarmCfg {
            clients: 5,
            rounds: 2,
            payload_words: 3,
            client_threads: 1,
            server_threads: 1,
            poller: PollerKind::PollFallback,
        };
        let mut fold = 0u64;
        for c in 0..cfg.clients {
            for r in 0..cfg.rounds {
                let Frame::Msg { bytes } = payload_frame(c, r, cfg.payload_words) else {
                    unreachable!()
                };
                assert_eq!(bytes.len(), 6 + cfg.payload_words * 8);
                for w in bytes[6..].chunks_exact(8) {
                    fold = fold.wrapping_add(u64::from_le_bytes(w.try_into().unwrap()));
                }
            }
        }
        assert_eq!(fold, expected_checksum(&cfg));
    }

    #[test]
    fn word_formula_is_position_sensitive() {
        // swapping client/round/word indices changes the word — the
        // checksum can detect cross-wired frames, not just lost ones
        assert_ne!(word(1, 0, 0), word(0, 1, 0));
        assert_ne!(word(0, 1, 0), word(0, 0, 1));
        assert_ne!(word(2, 3, 4), word(4, 3, 2));
    }

    /// A tiny end-to-end swarm on the poll(2) fallback: every frame
    /// accounted for, peak connections == clients.
    #[test]
    fn small_swarm_end_to_end_on_poll_fallback() {
        let cfg = SwarmCfg {
            clients: 24,
            rounds: 2,
            payload_words: 8,
            client_threads: 2,
            server_threads: 1,
            poller: PollerKind::PollFallback,
        };
        let report = run(&cfg).unwrap();
        assert!(report.verified(), "checksum mismatch: {report:?}");
        assert_eq!(report.peak_live_connections, 24);
        assert_eq!(
            report.bytes_received,
            (24 * 2 * (6 + 8 * 8)) as u64,
            "every payload frame metered"
        );
        assert_eq!(report.poller, "poll");
        assert!(report.peak_conn_buffered_bytes > 0, "queue depths were metered");
    }

    /// The same swarm with the sockets sharded across 3 server loops:
    /// every frame still accounted for, the checksum identical, and the
    /// connection peak still the full federation (the acceptor meters
    /// it — loops only see their ~n/K share).
    #[test]
    fn small_swarm_sharded_server_matches_single_loop() {
        let mk = |server_threads| SwarmCfg {
            clients: 24,
            rounds: 2,
            payload_words: 8,
            client_threads: 2,
            server_threads,
            poller: PollerKind::PollFallback,
        };
        let single = run(&mk(1)).unwrap();
        let sharded = run(&mk(3)).unwrap();
        assert!(sharded.verified(), "checksum mismatch: {sharded:?}");
        assert_eq!(sharded.checksum, single.checksum, "K must not change the payload fold");
        assert_eq!(sharded.bytes_received, single.bytes_received);
        assert_eq!(sharded.peak_live_connections, 24, "driver meters the full peak at K>1");
        assert_eq!(sharded.server_threads, 3, "report records the shard count");
        assert!(sharded.peak_conn_buffered_bytes > 0, "loop queue depths max-merged in");
    }
}
