//! Event-loop transport: one-thread readiness-driven sockets that
//! scale the aggregator to 10k+ concurrent clients.
//!
//! The thread-per-connection socket transport ([`super::tcp`]) is the
//! honest small-federation baseline, but it carries two scaling
//! ceilings: a stack per client (10k clients ≈ 10k threads), and
//! blocking frame writes that can deadlock when both ends of a
//! connection fill their kernel buffers at once (see the "Blocking
//! writes and the deadlock bound" note in `tcp`). This module removes
//! both by multiplexing every connection on a single event-loop
//! thread with OS readiness notification.
//!
//! # Layering
//!
//! ```text
//!   poller.rs   Poller: epoll (Linux, via extern-libc shim) or
//!               portable poll(2) — register fds, wait for readiness
//!   conn.rs     Conn: per-connection state machine — FrameBuf
//!               partial-read reassembly + OutQueue bounded
//!               partial-write queue
//!   shard.rs    ShardLoop/ShardSet: K token-sharded loops behind one
//!               acceptor (`--evloop-threads K`)
//!   server.rs   serve_on / serve_sharded / EvloopTransport: the
//!               aggregator protocol loop, frame-for-frame equivalent
//!               to tcp::serve_on
//!   swarm.rs    the C10K load generator (`vfl-sa swarm`)
//! ```
//!
//! # Accept → shard handoff (`--evloop-threads K`)
//!
//! With K > 1 loops the driver thread plays acceptor: the `j`-th
//! accepted socket is dealt round-robin to loop `j % K` *before* the
//! loops start polling, and is owned by that one loop — its `FrameBuf`
//! and `OutQueue` — for its whole life. No lock guards the read/write
//! path; cross-thread traffic is confined to one shared event channel
//! (loop → driver: frames, joins, dead-connection notices) and a
//! per-loop control channel + wake socketpair (driver → loop: outbound
//! frames, routed by the `client → loop` map built from join events).
//! The one `RoundWindow` driver on the accepting thread runs the same
//! protocol loop `serve_on` runs; per-loop metrics peaks max-merge at
//! the end of the run. K = 1 *is* `serve_on`, byte-identical; any K
//! produces bit-identical reports because per-sender FIFO survives
//! sharding (one loop per connection, order-preserving channels).
//!
//! # The connection state machine
//!
//! Every socket is nonblocking and owned by exactly one [`Conn`]:
//!
//! * **Reads** — on readability, drain the socket into an append-only
//!   [`FrameBuf`] and pop every *complete* length-prefixed frame; a
//!   partial frame simply stays buffered until the next readiness
//!   event. Per-connection reads stay in arrival order, which
//!   preserves the per-sender FIFO ordering the protocol relies on —
//!   that is the whole bit-identity argument.
//! * **Writes** — frames are never written to the socket directly.
//!   They are encoded into the connection's bounded [`OutQueue`] and
//!   drained opportunistically whenever the socket is writable.
//!   Writable interest is registered only while the queue is
//!   non-empty, so an idle swarm costs zero wakeups.
//!
//! **The no-blocking-write invariant:** no code on the event-loop
//! thread ever issues a blocking socket write (or read). A slow or
//! stalled peer therefore cannot wedge the loop — its queue fills to
//! the [`DEFAULT_OUTBOUND_CAP_BYTES`] bound and overflows as a typed
//! [`QueueOverflow`] error, which the server handles the same way it
//! handles a dead socket: the client is marked dropped and secure
//! aggregation recovers it like any other dropout.
//!
//! # Equivalence
//!
//! [`serve_on`] drives [`crate::coordinator::window::RoundWindow`] and
//! `Party::on_round_complete` exactly as `tcp::serve_on` does — the
//! same stall-probe policy, the same dropout semantics, the same
//! failure messages — so `sim ≡ threaded ≡ tcp ≡ evloop` holds
//! bit-identically (`tests/transport_equivalence.rs` and
//! `tests/evloop.rs` enforce it).

pub mod conn;
pub mod poller;
pub mod server;
pub mod shard;
pub mod swarm;

pub use conn::{Conn, FrameBuf, OutQueue, QueueOverflow, ReadOutcome, DEFAULT_OUTBOUND_CAP_BYTES};
pub use poller::{Interest, PollEvent, Poller, PollerKind};
pub use server::{serve, serve_on, serve_sharded, EvloopTransport};
pub use shard::shard_of;
pub use swarm::{SwarmCfg, SwarmReport};
