//! Token-sharded event loops (`--evloop-threads K`): one acceptor
//! dealing sockets round-robin to K poller threads, each owning its
//! connections' buffers exclusively.
//!
//! # Accept → shard handoff
//!
//! The driver thread plays acceptor: it accepts every connection on
//! the (still-blocking-semantics) listener and deals the `j`-th
//! accepted socket to loop `j % K` ([`shard_of`]) *before* any loop
//! thread starts polling. Each socket is then owned by exactly one
//! [`ShardLoop`] for its whole life — its `FrameBuf`/`OutQueue` are
//! plain fields of that loop's slab, touched by no lock and no other
//! thread. Cross-thread traffic happens only at the edges:
//!
//! * **loop → driver**: complete frames, `Hello` joins, and dead-
//!   connection notices funnel over one shared [`LoopEvt`] channel.
//!   An mpsc channel preserves per-sender order, and each connection
//!   lives on one loop, so the per-sender FIFO the protocol relies on
//!   survives sharding — that is the bit-identity argument.
//! * **driver → loop**: outbound frames ride a per-loop [`Ctl`]
//!   channel, routed by the `client → loop` map the driver builds from
//!   `Joined` events. A loop parked in `Poller::wait` is woken by one
//!   byte on its wake socketpair (registered at [`WAKE_TOKEN`]); the
//!   driver batches wakes per burst, not per frame.
//!
//! Each loop meters its own per-connection queue depths into a private
//! [`Metrics`] returned when the loop exits; the driver max-merges
//! them ([`Metrics::merge`]) and meters total live connections itself,
//! so `peak_connections` reports the federation size at any K.
//! Dropping the driver-side handles ([`ShardSet`]) hangs up every wake
//! pair and control channel, which is how loops learn to exit on error
//! paths — no shared shutdown flag.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::metrics::AGGREGATOR;
use crate::coordinator::Metrics;

use super::super::frame::Frame;
use super::conn::{Conn, ReadOutcome};
use super::poller::{Interest, Poller, PollerKind};

/// The wake socketpair's registration token in each loop's poller
/// (connection tokens are slab indices, so they never reach this).
const WAKE_TOKEN: usize = usize::MAX;

/// Which loop the `j`-th accepted connection is dealt to: round-robin
/// at accept time. Pure so tests can assert the partition is disjoint
/// and covering without opening sockets.
pub fn shard_of(accept_index: usize, threads: usize) -> usize {
    accept_index % threads.max(1)
}

/// Accept exactly `n_clients` connections, dealing socket `j` to shard
/// `shard_of(j, threads)` and metering the growing live count into
/// `io` (the driver owns the connection peak — loops never see the
/// whole federation). `timeout` bounds each quiet stretch between
/// accepts (None = wait forever, the protocol server's join
/// semantics).
pub(super) fn accept_shards(
    listener: &TcpListener,
    n_clients: usize,
    threads: usize,
    io: &mut Metrics,
    timeout: Option<Duration>,
) -> Result<Vec<Vec<TcpStream>>> {
    listener.set_nonblocking(true).context("nonblocking listener")?;
    // a one-fd poll(2) poller: portable accept-with-timeout
    let mut poller = PollerKind::PollFallback.build().context("build accept poller")?;
    poller
        .register(listener.as_raw_fd(), 0, Interest::READ)
        .context("register listener")?;
    let mut shards: Vec<Vec<TcpStream>> = (0..threads).map(|_| Vec::new()).collect();
    let mut accepted = 0usize;
    let mut events = Vec::new();
    while accepted < n_clients {
        match listener.accept() {
            Ok((stream, _peer)) => {
                shards[shard_of(accepted, threads)].push(stream);
                accepted += 1;
                io.record_connections(AGGREGATOR, accepted as u64);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                poller.wait(&mut events, timeout).context("poll (accept)")?;
                if events.is_empty() {
                    bail!("join stalled at {accepted}/{n_clients} accepted connections");
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("accept"),
        }
    }
    Ok(shards)
}

/// Driver → loop control messages.
pub(super) enum Ctl {
    /// Enqueue one frame to a client this loop owns.
    Frame { client: usize, frame: Frame },
    /// Enqueue pre-encoded `Msg` wire bytes (the zero-copy sibling —
    /// the body crosses the channel by move, never by copy).
    Wire { client: usize, bytes: Vec<u8> },
    /// Flush every remaining outbound byte (bounded by `grace`), then
    /// exit and return the loop's metrics.
    Drain { grace: Duration },
}

/// Loop → driver events. One shared channel: mpsc preserves per-sender
/// order and every connection lives on exactly one loop, so each
/// client's frames arrive at the driver in read order.
pub(super) enum LoopEvt {
    /// A client's `Hello` landed on this loop — the driver records
    /// `client → loop_id` for outbound routing.
    Joined { loop_id: usize, client: usize },
    /// A complete post-handshake frame from a client.
    Frame { client: usize, frame: Frame },
    /// A connection died (EOF, I/O error, queue overflow); `client` is
    /// None if it never completed its handshake. The loop has already
    /// closed it — the driver decides whether that is a dropout or a
    /// join-phase failure.
    Gone { client: Option<usize>, why: String },
    /// A protocol violation inside the loop (bad `Hello`) — fatal.
    Fatal(anyhow::Error),
}

/// One event-loop shard: a poller plus the slab of connections it
/// exclusively owns. Built on the driver thread, then moved whole into
/// its thread — nothing here is shared.
pub(super) struct ShardLoop {
    id: usize,
    poller: Poller,
    /// Token-indexed slab; closed slots stay `None` (each client
    /// connects exactly once per run, so tokens are never reused).
    conns: Vec<Option<Conn>>,
    /// Client index → live token. Full federation width, but only this
    /// loop's clients ever fill in.
    client_slot: Vec<Option<usize>>,
    /// Per-connection queue-depth meters; the driver max-merges the
    /// loops' metrics at the end of the run.
    io: Metrics,
    /// Wake socketpair read end, registered at [`WAKE_TOKEN`].
    wake: UnixStream,
    ctl: Receiver<Ctl>,
    evt: Sender<LoopEvt>,
}

impl ShardLoop {
    /// This loop's shard index (thread naming / diagnostics).
    pub(super) fn id(&self) -> usize {
        self.id
    }

    /// Adopt this shard's pre-accepted sockets: nonblocking, slab
    /// tokens, read interest — the same setup `serve_on`'s accept path
    /// performs, minus the accepting.
    pub(super) fn new(
        id: usize,
        mut poller: Poller,
        sockets: Vec<TcpStream>,
        n_clients: usize,
        wake: UnixStream,
        ctl: Receiver<Ctl>,
        evt: Sender<LoopEvt>,
    ) -> Result<ShardLoop> {
        wake.set_nonblocking(true).context("nonblocking wake")?;
        poller
            .register(wake.as_raw_fd(), WAKE_TOKEN, Interest::READ)
            .context("register wake")?;
        let mut conns = Vec::with_capacity(sockets.len());
        for stream in sockets {
            stream.set_nodelay(true).ok();
            stream.set_nonblocking(true).context("set_nonblocking")?;
            let fd = stream.as_raw_fd();
            let token = conns.len();
            poller.register(fd, token, Interest::READ).context("register conn")?;
            conns.push(Some(Conn::new(stream, fd)));
        }
        Ok(ShardLoop {
            id,
            poller,
            conns,
            client_slot: vec![None; n_clients],
            io: Metrics::new(),
            wake,
            ctl,
            evt,
        })
    }

    /// The loop body: park in the poller, service socket readiness,
    /// then drain the control channel. Exits on `Ctl::Drain` (orderly,
    /// flushes outbound) or a disconnected driver (error path, just
    /// returns), either way handing back this loop's metrics.
    pub(super) fn run(mut self) -> Metrics {
        let mut events = Vec::new();
        loop {
            if self.poller.wait(&mut events, None).is_err() {
                return self.io;
            }
            for i in 0..events.len() {
                let ev = events[i];
                if ev.token == WAKE_TOKEN {
                    self.drain_wake();
                    continue;
                }
                if ev.writable {
                    self.flush(ev.token);
                }
                if ev.readable || ev.hangup {
                    self.handle_read(ev.token);
                }
            }
            // control after I/O, so outbound routing sees fresh slots
            loop {
                match self.ctl.try_recv() {
                    Ok(Ctl::Frame { client, frame }) => self.send_frame(client, &frame),
                    Ok(Ctl::Wire { client, bytes }) => self.send_wire(client, bytes),
                    Ok(Ctl::Drain { grace }) => {
                        self.drain_outbound(Instant::now() + grace);
                        return self.io;
                    }
                    Err(TryRecvError::Empty) => break,
                    // driver gone without Drain: an error path — exit
                    // without flushing (the run already failed)
                    Err(TryRecvError::Disconnected) => return self.io,
                }
            }
        }
    }

    /// Swallow queued wake bytes (EOF here means the driver hung up —
    /// the control channel's Disconnected handles the actual exit).
    fn drain_wake(&mut self) {
        let mut buf = [0u8; 64];
        loop {
            match (&self.wake).read(&mut buf) {
                Ok(0) => return,
                Ok(_) => continue,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return, // WouldBlock or a real error: parked either way
            }
        }
    }

    /// Close one connection: deregister, drop the socket, clear the
    /// client mapping; `gone` notifies the driver (None for the silent
    /// closes during the post-Drain flush).
    fn close(&mut self, token: usize, gone: Option<String>) {
        if let Some(conn) = self.conns.get_mut(token).and_then(Option::take) {
            let _ = self.poller.deregister(conn.fd);
            if let Some(ci) = conn.client {
                self.client_slot[ci] = None;
            }
            if let Some(why) = gone {
                let _ = self.evt.send(LoopEvt::Gone { client: conn.client, why });
            }
        }
    }

    fn set_interest(&mut self, token: usize, want: Interest) {
        let Some(conn) = self.conns.get_mut(token).and_then(Option::as_mut) else { return };
        if conn.interest != want {
            let fd = conn.fd;
            conn.interest = want;
            if let Err(e) = self.poller.reregister(fd, token, want) {
                self.close(token, Some(format!("reregister failed: {e}")));
            }
        }
    }

    /// Drain a readable socket, forwarding complete frames. The
    /// `Hello` handshake is handled inline exactly as `serve_on` does:
    /// frames before it are a protocol error, frames after it carry
    /// the sender's client index up the event channel.
    fn handle_read(&mut self, token: usize) {
        let Some(conn) = self.conns.get_mut(token).and_then(Option::as_mut) else {
            return; // stale event for an already-closed conn
        };
        let mut got = Vec::new();
        let outcome = conn.read_ready(&mut got);
        self.io.record_conn_buffered(AGGREGATOR, conn.buffered_bytes() as u64);
        let mut client = conn.client;
        for f in got {
            match client {
                Some(ci) => {
                    let _ = self.evt.send(LoopEvt::Frame { client: ci, frame: f });
                }
                None => {
                    let Frame::Hello { client: c } = f else {
                        let _ = self
                            .evt
                            .send(LoopEvt::Fatal(anyhow::anyhow!("expected Hello, got {f:?}")));
                        self.close(token, None);
                        return;
                    };
                    let ci = c as usize;
                    let n = self.client_slot.len();
                    if ci >= n {
                        let _ = self.evt.send(LoopEvt::Fatal(anyhow::anyhow!(
                            "client index {ci} out of range (need 0..{n})"
                        )));
                        self.close(token, None);
                        return;
                    }
                    if self.client_slot[ci].is_some() {
                        let _ = self
                            .evt
                            .send(LoopEvt::Fatal(anyhow::anyhow!("client {ci} connected twice")));
                        self.close(token, None);
                        return;
                    }
                    self.client_slot[ci] = Some(token);
                    if let Some(conn) = self.conns[token].as_mut() {
                        conn.client = Some(ci);
                    }
                    client = Some(ci);
                    let _ = self.evt.send(LoopEvt::Joined { loop_id: self.id, client: ci });
                }
            }
        }
        if let ReadOutcome::Closed(why) = outcome {
            self.close(token, Some(why));
        }
    }

    /// Drain a connection's outbound queue as far as the socket
    /// accepts, keeping writable interest exactly while bytes remain.
    fn flush(&mut self, token: usize) {
        let Some(conn) = self.conns.get_mut(token).and_then(Option::as_mut) else { return };
        match conn.write_ready() {
            Ok(drained) => {
                let bytes = conn.buffered_bytes();
                self.io.record_conn_buffered(AGGREGATOR, bytes as u64);
                let want = if drained { Interest::READ } else { Interest::BOTH };
                self.set_interest(token, want);
            }
            Err(e) => self.close(token, Some(format!("write failed: {e}"))),
        }
    }

    /// Enqueue one frame and opportunistically drain. Dead or dropped
    /// clients are skipped; a queue overflow marks the client dropped —
    /// never a blocking wait (same policy as the single loop).
    fn send_frame(&mut self, ci: usize, frame: &Frame) {
        let Some(token) = self.client_slot[ci] else { return };
        let Some(conn) = self.conns.get_mut(token).and_then(Option::as_mut) else { return };
        if let Err(e) = conn.out.enqueue(frame, token) {
            self.close(token, Some(format!("send failed: {e:#}")));
            return;
        }
        self.flush(token);
    }

    /// Enqueue pre-encoded `Msg` wire bytes (zero-copy path).
    fn send_wire(&mut self, ci: usize, bytes: Vec<u8>) {
        let Some(token) = self.client_slot[ci] else { return };
        let Some(conn) = self.conns.get_mut(token).and_then(Option::as_mut) else { return };
        if let Err(e) = conn.out.enqueue_msg(bytes, token) {
            self.close(token, Some(format!("send failed: {e:#}")));
            return;
        }
        self.flush(token);
    }

    /// Best-effort post-Drain flush: push every remaining outbound
    /// byte (the Stop frames), closing each connection as its queue
    /// empties so level-triggered EOF readiness from exiting clients
    /// cannot spin the loop.
    fn drain_outbound(&mut self, deadline: Instant) {
        let mut events = Vec::new();
        loop {
            let mut pending = false;
            for token in 0..self.conns.len() {
                let Some(conn) = self.conns[token].as_ref() else { continue };
                if conn.out.is_empty() {
                    self.close(token, None);
                } else {
                    pending = true;
                    self.set_interest(token, Interest::WRITE);
                }
            }
            if !pending {
                return;
            }
            let now = Instant::now();
            if now >= deadline {
                return;
            }
            let wait = (deadline - now).min(Duration::from_millis(100));
            if self.poller.wait(&mut events, Some(wait)).is_err() {
                return;
            }
            for i in 0..events.len() {
                let ev = events[i];
                if ev.token == WAKE_TOKEN {
                    self.drain_wake();
                } else if ev.hangup {
                    self.close(ev.token, None);
                } else if ev.writable {
                    self.flush(ev.token);
                }
            }
        }
    }
}

/// The driver's side of the shard fabric: per-loop control senders and
/// wake handles, plus the `client → loop` routing map. Dropping this
/// hangs up every loop (their wake reads hit EOF, their control
/// channels disconnect) — the error-path shutdown.
pub(super) struct ShardSet {
    ctls: Vec<Sender<Ctl>>,
    /// Wake socketpair write ends, nonblocking (a full pipe already
    /// guarantees a pending wakeup, so a short write is a no-op).
    wakes: Vec<UnixStream>,
    /// Client index → owning loop (filled from `Joined` events; None =
    /// not yet joined, or dropped).
    pub(super) client_loop: Vec<Option<usize>>,
    /// Loops with control traffic queued since the last [`wake`] — one
    /// wake byte per loop per burst, not per frame.
    touched: Vec<bool>,
}

impl ShardSet {
    pub(super) fn new(ctls: Vec<Sender<Ctl>>, wakes: Vec<UnixStream>, n_clients: usize) -> ShardSet {
        let k = ctls.len();
        ShardSet { ctls, wakes, client_loop: vec![None; n_clients], touched: vec![false; k] }
    }

    fn push(&mut self, l: usize, c: Ctl) {
        if self.ctls[l].send(c).is_ok() {
            self.touched[l] = true;
        }
    }

    /// Route one frame to whichever loop owns the client (dropped
    /// clients are skipped, matching the single loop's dead-slot
    /// behavior). Call [`wake`] after the burst.
    pub(super) fn send_frame(&mut self, client: usize, frame: Frame) {
        if let Some(l) = self.client_loop[client] {
            self.push(l, Ctl::Frame { client, frame });
        }
    }

    /// Route pre-encoded `Msg` wire bytes (zero-copy path).
    pub(super) fn send_wire(&mut self, client: usize, bytes: Vec<u8>) {
        if let Some(l) = self.client_loop[client] {
            self.push(l, Ctl::Wire { client, bytes });
        }
    }

    /// Tell every loop to flush its outbound queues and exit.
    pub(super) fn drain_all(&mut self, grace: Duration) {
        for l in 0..self.ctls.len() {
            self.push(l, Ctl::Drain { grace });
        }
    }

    /// Wake every loop with queued control traffic (one byte each).
    pub(super) fn wake(&mut self) {
        for (l, touched) in self.touched.iter_mut().enumerate() {
            if *touched {
                *touched = false;
                let _ = (&self.wakes[l]).write(&[1]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_dealing_is_disjoint_and_covering() {
        for threads in [1usize, 2, 3, 4, 7] {
            for n_clients in [0usize, 1, 2, 5, 16, 17] {
                let mut per_loop = vec![0usize; threads];
                for j in 0..n_clients {
                    let l = shard_of(j, threads);
                    assert!(l < threads, "{j} % {threads} in range");
                    per_loop[l] += 1;
                }
                // every connection lands on exactly one loop, and the
                // deal is balanced to within one socket
                assert_eq!(per_loop.iter().sum::<usize>(), n_clients);
                let (min, max) =
                    (per_loop.iter().min().unwrap(), per_loop.iter().max().unwrap());
                assert!(max - min <= 1, "balanced deal: {per_loop:?}");
            }
        }
        // zero threads clamp to one loop instead of dividing by zero
        assert_eq!(shard_of(5, 0), 0);
    }

    #[test]
    fn wake_pair_roundtrip() {
        // the wake mechanism: a byte written on the driver end shows up
        // readable on the loop end, and dropping the driver end reads
        // as EOF (the error-path hangup signal)
        let (driver, looped) = UnixStream::pair().unwrap();
        driver.set_nonblocking(true).unwrap();
        looped.set_nonblocking(true).unwrap();
        (&driver).write_all(&[1]).unwrap();
        let mut buf = [0u8; 8];
        assert_eq!((&looped).read(&mut buf).unwrap(), 1);
        assert_eq!(
            (&looped).read(&mut buf).unwrap_err().kind(),
            std::io::ErrorKind::WouldBlock
        );
        drop(driver);
        assert_eq!((&looped).read(&mut buf).unwrap(), 0, "driver hangup reads as EOF");
    }
}
